"""Quantized-expert subsystem (ISSUE 5, DESIGN.md §8): block-wise
int8/fp8 quant/dequant exactness, STE gradient flow, fused-dequant
esffn/esmm parity against the dequant-then-dense reference across
pallas-interpret/blocked/ref/ragged, uneven expert loads, the
weight_bits cost-model terms, precision-aware hetero execution, and the
island-level QAT / true-quant paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hetero import HeteroPlan, make_hetero_plan
from repro.core.reindex import build_reindex
from repro.core.routing import route
from repro.kernels import ops
from repro.kernels.esffn import esffn_cost
from repro.parallel import autotune
from repro.parallel.hetero_exec import HeteroExecutor
from repro.parallel.moe_parallel import MoEParams, MoEStatic, moe_layer
from repro.parallel.sharding import ParallelConfig
from repro.quant import core as qc

IMPLS = ("pallas", "blocked", "ref", "ragged")


def _setup(seed=0, n=24, d=32, f=48, e=4, k=2, blk=8, glu=True):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    router = jnp.asarray(rng.normal(size=(d, e)), jnp.float32)
    r = route(x, router, k)
    ri = build_reindex(r.expert_idx, r.gates, e, blk)
    if glu:
        ws = tuple(
            jnp.asarray(rng.normal(size=s), jnp.float32)
            for s in ((e, d, f), (e, d, f), (e, f, d))
        )
    else:
        ws = (
            jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32),
            jnp.asarray(rng.normal(size=(e, f)), jnp.float32),
            jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(e, d)), jnp.float32),
        )
    return x, ri, ws


# ---------------------------------------------------------------------------
# core quant/dequant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["int8", "fp8"])
def test_roundtrip_exact_on_representable_grid(mode):
    """quantize∘dequantize is idempotent: values already on a block's grid
    survive a second round-trip bit-exactly, and each block's amax maps to
    the top code exactly."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(3, 64, 32)), jnp.float32)
    q, s = qc.quantize_blockwise(w, mode=mode, tile=16)
    w1 = qc.dequantize_blockwise(q, s)
    q2, s2 = qc.quantize_blockwise(w1, mode=mode, tile=16)
    w2 = qc.dequantize_blockwise(q2, s2)
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
    # per-block amax is exactly representable (|amax| -> qmax * scale)
    np.testing.assert_allclose(
        np.max(np.abs(np.asarray(w1)), axis=(1, 2)),
        np.max(np.abs(np.asarray(w)), axis=(1, 2)), rtol=1e-6)


def test_int8_error_bounded_by_half_step():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(2, 32, 32)), jnp.float32)
    q, s = qc.quantize_blockwise(w, tile=32)
    err = np.abs(np.asarray(qc.dequantize_blockwise(q, s) - w))
    step = np.asarray(s)[:, :, None, None]  # one scale per whole block here
    assert (err <= 0.5 * step.reshape(2, 1, 1) + 1e-7).all()


def test_scales_shape_and_tile_clamping():
    w = jnp.ones((5, 2, 256, 48))
    q, s = qc.quantize_blockwise(w, tile=128)
    assert q.shape == w.shape and q.dtype == jnp.int8
    assert s.shape == (5, 2, 2, 1)  # 256/128 x 48/min(128,48)
    with pytest.raises(ValueError):
        qc.quantize_blockwise(jnp.ones((100, 48)), tile=64)  # 100 % 64 != 0


def test_stochastic_rounding_unbiased():
    """floor(x/s + u) averages to x/s over draws (the deterministic round
    would be off by the sub-step fraction)."""
    x = jnp.full((8, 8), 0.3)  # between int steps for scale ~ 1/127*amax...
    q, s = qc.quantize_blockwise(x, tile=8)  # amax==x -> code 127 exactly
    # use a value grid with a genuine fractional code instead
    w = jnp.asarray([[1.0, 0.3]] * 4, jnp.float32)  # scale = 1/127
    codes = []
    for i in range(300):
        q, s = qc.quantize_blockwise(w, tile=4, rng=jax.random.PRNGKey(i))
        codes.append(np.asarray(q, np.float64))
    mean_code = np.stack(codes).mean(0)
    target = np.asarray(w) / np.asarray(qc._upsample(s, w.shape))
    assert np.abs(mean_code - target).max() < 0.12  # ~0.5/sqrt(300) * 3σ


def test_ste_gradient_is_identity():
    w = jnp.asarray(np.random.default_rng(2).normal(size=(4, 16, 16)),
                    jnp.float32)
    c = jnp.asarray(np.random.default_rng(3).normal(size=w.shape), jnp.float32)
    g = jax.grad(lambda w_: jnp.sum(qc.fake_quant(w_, "int8", 16) * c))(w)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(c))


def test_kv_row_roundtrip():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(6, 4, 16)), jnp.float32)
    q, s = qc.quantize_rows(x)
    assert q.dtype == jnp.int8 and s.shape == (6, 4)
    err = np.abs(np.asarray(qc.dequantize_rows(q, s) - x))
    assert err.max() <= 0.5 * np.asarray(s).max() + 1e-7


def test_compression_reexports_are_the_same_objects():
    """One rounding convention repo-wide: optim.compression re-exports the
    quant.core primitives (satellite: unify quant primitives)."""
    from repro.optim import compression

    assert compression.quantize_int8 is qc.quantize_int8
    assert compression.dequantize_int8 is qc.dequantize_int8
    # the error-feedback path still round-trips exactly on its own output
    rec, res = compression.compress_roundtrip(
        jnp.asarray([[0.5, -1.0, 2.0]], jnp.float32))
    rec2, _ = compression.compress_roundtrip(rec)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(rec2))


# ---------------------------------------------------------------------------
# fused-dequant kernels == dequant-then-dense reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
def test_esffn_glu_quantized_matches_reference(impl):
    """Fused-dequant GLU forward AND grads (x, gate) are exactly the
    dequant-then-dense reference in f32 — the in-kernel VMEM dequant
    computes the same f32 weight values the reference materialises."""
    x, ri, (wg, wu, wd) = _setup(seed=5)
    (qg, sg), (qu, su), (qd, sd) = (qc.quantize_blockwise(w)
                                    for w in (wg, wu, wd))
    dg, du, dd = (qc.dequantize_blockwise(q, s)
                  for q, s in ((qg, sg), (qu, su), (qd, sd)))

    def f_q(x_, gate_):
        y = ops.esffn_glu(x_, ri.row_token, gate_, ri.block_expert,
                          ri.padded_counts, qg, qu, qd,
                          scales=(sg, su, sd), impl=impl)
        return jnp.sum(y * y), y

    def f_r(x_, gate_):
        y = ops.esffn_glu(x_, ri.row_token, gate_, ri.block_expert,
                          ri.padded_counts, dg, du, dd, impl=impl)
        return jnp.sum(y * y), y

    (lq, yq), gq = jax.value_and_grad(f_q, argnums=(0, 1), has_aux=True)(
        x, ri.row_gate)
    (lr, yr), gr = jax.value_and_grad(f_r, argnums=(0, 1), has_aux=True)(
        x, ri.row_gate)
    np.testing.assert_array_equal(np.asarray(yq), np.asarray(yr))
    for a, b in zip(gq, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("impl", IMPLS)
def test_esffn_mlp_quantized_matches_reference(impl):
    """Quantized 2-MLP fused op: forward + dx/dgate/db1/db2 match the
    dequant reference (biases stay full precision, so their grads flow)."""
    x, ri, (w1, b1, w2, b2) = _setup(seed=6, glu=False)
    (q1, s1), (q2, s2) = (qc.quantize_blockwise(w) for w in (w1, w2))
    d1, d2 = (qc.dequantize_blockwise(q, s) for q, s in ((q1, s1), (q2, s2)))

    def f_q(x_, gate_, b1_, b2_):
        y = ops.esffn_mlp(x_, ri.row_token, gate_, ri.block_expert,
                          ri.padded_counts, q1, b1_, q2, b2_,
                          scales=(s1, s2), act="gelu", impl=impl)
        return jnp.sum(y * y)

    def f_r(x_, gate_, b1_, b2_):
        y = ops.esffn_mlp(x_, ri.row_token, gate_, ri.block_expert,
                          ri.padded_counts, d1, b1_, d2, b2_,
                          act="gelu", impl=impl)
        return jnp.sum(y * y)

    args = (x, ri.row_gate, b1, b2)
    np.testing.assert_array_equal(np.asarray(f_q(*args)),
                                  np.asarray(f_r(*args)))
    gq = jax.grad(f_q, argnums=(0, 1, 2, 3))(*args)
    gr = jax.grad(f_r, argnums=(0, 1, 2, 3))(*args)
    for a, b, name in zip(gq, gr, ("dx", "dgate", "db1", "db2")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5, err_msg=name)


@pytest.mark.parametrize("impl", IMPLS)
def test_esffn_quantized_uneven_and_empty_experts(impl):
    """Skewed routing (one expert hoards tokens, one is empty) through the
    quantized fused op still matches the dequant reference exactly."""
    x, _, (wg, wu, wd) = _setup(seed=7)
    n, e, k, blk = x.shape[0], wg.shape[0], 2, 8
    # force expert 0 for everyone's first choice, expert 1 second; 2/3 empty
    expert_idx = jnp.stack([jnp.zeros((n,), jnp.int32),
                            jnp.ones((n,), jnp.int32)], -1)
    gates = jnp.full((n, k), 0.5, jnp.float32)
    ri = build_reindex(expert_idx, gates, e, blk)
    (qg, sg), (qu, su), (qd, sd) = (qc.quantize_blockwise(w)
                                    for w in (wg, wu, wd))
    yq = ops.esffn_glu(x, ri.row_token, ri.row_gate, ri.block_expert,
                       ri.padded_counts, qg, qu, qd, scales=(sg, su, sd),
                       impl=impl)
    yr = ops.esffn_glu(x, ri.row_token, ri.row_gate, ri.block_expert,
                       ri.padded_counts,
                       qc.dequantize_blockwise(qg, sg),
                       qc.dequantize_blockwise(qu, su),
                       qc.dequantize_blockwise(qd, sd), impl=impl)
    np.testing.assert_array_equal(np.asarray(yq), np.asarray(yr))


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("transpose", [False, True])
def test_esmm_quantized_matches_reference(impl, transpose):
    x, ri, (wg, _, wd) = _setup(seed=8)
    w = wd if transpose else wg  # (E, F, D) transposed / (E, D, F) plain
    q, s = qc.quantize_blockwise(w)
    w_dq = qc.dequantize_blockwise(q, s)
    xs = jnp.asarray(np.random.default_rng(9).normal(
        size=(ri.row_token.shape[0], x.shape[1])), jnp.float32)

    def f_q(xs_):
        y = ops.esmm(xs_, q, None, ri.block_expert, ri.padded_counts,
                     w_scales=s, transpose_rhs=transpose, impl=impl)
        return jnp.sum(y * y), y

    def f_r(xs_):
        y = ops.esmm(xs_, w_dq, None, ri.block_expert, ri.padded_counts,
                     transpose_rhs=transpose, impl=impl)
        return jnp.sum(y * y), y

    (_, yq), gq = jax.value_and_grad(f_q, has_aux=True)(xs)
    (_, yr), gr = jax.value_and_grad(f_r, has_aux=True)(xs)
    np.testing.assert_array_equal(np.asarray(yq), np.asarray(yr))
    np.testing.assert_allclose(np.asarray(gq), np.asarray(gr),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("mode", ["int8", "fp8"])
def test_esffn_fp8_and_int8_are_close_to_dense(mode):
    """Quantized execution approximates the ORIGINAL dense weights within
    the format's step (sanity that scales are per-block, not global)."""
    x, ri, (wg, wu, wd) = _setup(seed=10)
    qs = [qc.quantize_blockwise(w, mode=mode) for w in (wg, wu, wd)]
    yq = ops.esffn_glu(x, ri.row_token, ri.row_gate, ri.block_expert,
                       ri.padded_counts, qs[0][0], qs[1][0], qs[2][0],
                       scales=(qs[0][1], qs[1][1], qs[2][1]), impl="blocked")
    yd = ops.esffn_glu(x, ri.row_token, ri.row_gate, ri.block_expert,
                       ri.padded_counts, wg, wu, wd, impl="blocked")
    denom = np.abs(np.asarray(yd)).max() + 1e-6
    rel = np.abs(np.asarray(yq - yd)).max() / denom
    assert rel < 0.2, rel


# ---------------------------------------------------------------------------
# cost model: weight_bits terms
# ---------------------------------------------------------------------------

def test_esffn_cost_weight_bits():
    c16 = esffn_cost(256, 64, 128, 4, 2, glu=True, weight_bits=16)
    c8 = esffn_cost(256, 64, 128, 4, 2, glu=True, weight_bits=8)
    assert c8.bytes_accessed < c16.bytes_accessed
    assert c8.flops == c16.flops  # quantization changes bytes, not FLOPs
    # default (no weight_bits) equals the itemsize path
    assert esffn_cost(256, 64, 128, 4, 2, glu=True).bytes_accessed \
        == c16.bytes_accessed


def test_layer_latency_weight_bits_monotone():
    kw = dict(tokens=256, d=1024, f=4096, e=8, k=2)
    for mode in ("data_centric", "model_centric"):
        l8 = autotune.layer_latency(mode, kw["tokens"], kw["d"], kw["f"],
                                    kw["e"], kw["k"], 16, weight_bits=8)
        l16 = autotune.layer_latency(mode, kw["tokens"], kw["d"], kw["f"],
                                     kw["e"], kw["k"], 16, weight_bits=16)
        assert l8 <= l16


def test_crossover_shifts_toward_fewer_tokens_with_int8():
    """int8 experts halve the data-centric weight-movement bill, so the
    data-/model-centric crossover moves DOWN (data wins earlier) — the
    Fig. 10 roofline becoming precision-aware (DESIGN.md §8)."""
    xo16 = autotune.crossover_tokens(1024, 4096, 8, 2, n_dev=16,
                                     weight_bits=16)
    xo8 = autotune.crossover_tokens(1024, 4096, 8, 2, n_dev=16,
                                    weight_bits=8)
    assert xo16 is not None and xo8 is not None
    assert xo8 < xo16


def test_resolve_layer_mode_sees_quant():
    """A token count between the int8 and bf16 crossovers flips the
    chooser when cfg.quant is set."""
    xo16 = autotune.crossover_tokens(1024, 4096, 8, 2, n_dev=16,
                                     weight_bits=16)
    xo8 = autotune.crossover_tokens(1024, 4096, 8, 2, n_dev=16,
                                    weight_bits=8)
    tokens = (xo8 + xo16) // 2
    kw = dict(d=1024, f=4096, e=8, k=2)

    class _M:  # 16-wide TP group without a real mesh
        axis_names = ("model",)
        shape = {"model": 16}

    cfg16 = ParallelConfig(mode="auto")
    cfg8 = ParallelConfig(mode="auto", quant="int8")
    m16 = autotune.resolve_layer_mode(tokens, cfg=cfg16, mesh=_M(), **kw)
    m8 = autotune.resolve_layer_mode(tokens, cfg=cfg8, mesh=_M(), **kw)
    assert m16 == "model_centric" and m8 == "data_centric"


def test_layer_latency_uneven_per_device_bits():
    lat = (1.0, 1.0)
    all16 = autotune.layer_latency_uneven(
        "data_centric", 64, 1024, 4096, 8, 2, lat, weight_bits=16)
    all8 = autotune.layer_latency_uneven(
        "data_centric", 64, 1024, 4096, 8, 2, lat, weight_bits=[8, 8])
    assert all8 < all16
    with pytest.raises(ValueError):
        autotune.layer_latency_uneven(
            "data_centric", 64, 1024, 4096, 8, 2, lat, weight_bits=[8])


# ---------------------------------------------------------------------------
# precision-aware hetero planning / execution
# ---------------------------------------------------------------------------

def test_hetero_plan_expert_bits_validation_and_key():
    plan = make_hetero_plan((1.0, 2.0), global_batch=8, expert_bits=(8, 16))
    assert plan.expert_bits == (8, 16)
    assert plan.key() != dataclasses.replace(plan, expert_bits=None).key()
    with pytest.raises(ValueError):
        HeteroPlan(proxy_latencies=(1.0, 2.0), expert_bits=(4, 16))
    with pytest.raises(ValueError):
        HeteroPlan(proxy_latencies=(1.0, 2.0), expert_bits=(8,))


def test_hetero_exec_rejects_bits_split_mismatch():
    """expert_bits follows the data group's proxy latencies; a
    model-centric split over a different-width TP group must refuse
    rather than silently mis-map a class's precision."""
    rng = np.random.default_rng(15)
    d, f, e = 32, 512, 4
    params = {
        "router": jnp.asarray(rng.normal(size=(d, e)), jnp.float32),
        "w_gate": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32),
        "w_up": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32),
        "w_down": jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32),
    }
    plan = make_hetero_plan(
        (1.0, 2.0), global_batch=8, hidden_size=f,
        tp_latencies=(1.0, 1.0, 1.0, 2.0), expert_bits=(8, 16))
    assert len(plan.hidden_splits) == 4  # follows tp_latencies
    with pytest.raises(ValueError, match="expert_bits"):
        HeteroExecutor(params, num_experts=e, top_k=2, act="silu",
                       glu=True, blk=8, impl="blocked",
                       plan=plan, mode="model_centric")


@pytest.mark.parametrize("mode", ["data_centric", "model_centric"])
def test_hetero_exec_mixed_precision(mode):
    """Per-device-class precision (DESIGN.md §8): the int8 class holds
    measurably fewer expert-weight bytes, and its program output equals
    running the same shard against the fake-quantized (dequant∘quant)
    weights — the fused path computes the very same f32 values."""
    rng = np.random.default_rng(11)
    n, d, f, e, k = 16, 32, 256, 4, 2
    params = {
        "router": jnp.asarray(rng.normal(size=(d, e)), jnp.float32),
        "w_gate": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32),
        "w_up": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32),
        "w_down": jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    plan = make_hetero_plan((1.0, 1.0), global_batch=n, hidden_size=f,
                            expert_bits=(8, 16))
    kw = dict(num_experts=e, top_k=k, act="silu", glu=True, blk=8,
              impl="blocked", mode=mode)
    ex_q = HeteroExecutor(params, plan=plan, **kw)
    ex_d = HeteroExecutor(params, plan=dataclasses.replace(
        plan, expert_bits=None), **kw)
    bytes_q = ex_q.device_param_bytes()
    bytes_d = ex_d.device_param_bytes()
    assert bytes_q[0] < bytes_d[0]          # int8 class shrank
    assert bytes_q[1] == bytes_d[1]         # bf16 class untouched
    y_q = np.asarray(ex_q(x))
    # reference: same per-device split, weights fake-quantized where the
    # plan says 8 bits
    fq = {kk: (qc.fake_quant(v) if kk != "router" else v)
          for kk, v in params.items()}
    if mode == "data_centric":
        ex_ref0 = HeteroExecutor(fq, plan=dataclasses.replace(
            plan, expert_bits=None), **kw)
        ref0 = np.asarray(ex_ref0(x))[: plan.token_counts[0]]
        np.testing.assert_allclose(y_q[: plan.token_counts[0]], ref0,
                                   rtol=1e-6, atol=1e-6)
        # the bf16 device's shard is bit-identical to the all-dense run
        np.testing.assert_array_equal(
            y_q[plan.token_counts[0]:], np.asarray(ex_d(x))[
                plan.token_counts[0]:])
    else:
        # partial sums: quantizing one class only perturbs within the
        # int8 step of ITS hidden slice
        y_d = np.asarray(ex_d(x))
        assert not np.array_equal(y_q, y_d)
        rel = np.abs(y_q - y_d).max() / (np.abs(y_d).max() + 1e-6)
        assert rel < 0.2


# ---------------------------------------------------------------------------
# island-level integration (moe_layer / espec param dicts)
# ---------------------------------------------------------------------------

def _moe_params(rng, e, d, f, glu=True):
    p = {"router": jnp.asarray(rng.normal(size=(d, e)), jnp.float32)}
    if glu:
        p["w_gate"] = jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32)
        p["w_up"] = jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32)
        p["w_down"] = jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32)
    else:
        p["w1"] = jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32)
        p["b1"] = jnp.zeros((e, f), jnp.float32)
        p["w2"] = jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32)
        p["b2"] = jnp.zeros((e, d), jnp.float32)
    return p


@pytest.mark.parametrize("glu", [True, False])
def test_island_true_quant_matches_dequant_dense(glu):
    """moe_layer with quantize_ffn'd params (int8 payloads + scale leaves)
    equals moe_layer on the hand-dequantized dense weights."""
    from jax.sharding import PartitionSpec as P

    rng = np.random.default_rng(12)
    b, s, d, f, e, k = 2, 8, 32, 48, 4, 2
    p = _moe_params(rng, e, d, f, glu=glu)
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    ms = MoEStatic(num_experts=e, top_k=k, act="silu" if glu else "gelu",
                   glu=glu)
    cfg = ParallelConfig(blk=8, impl="blocked")
    qp = qc.quantize_ffn(p)
    dq = dict(p)
    for name in qc.EXPERT_WEIGHT_KEYS:
        if name in qp and f"{name}_scale" in qp:
            dq[name] = qc.dequantize_blockwise(qp[name], qp[f"{name}_scale"])

    def as_mp(src):
        return MoEParams(**{fld: src.get(fld)
                            for fld in MoEParams._fields
                            if src.get(fld) is not None or fld == "router"})

    y_q, aux_q, _ = moe_layer(x, as_mp(qp), ms, cfg, None,
                              x_spec=P(None, None, None))
    y_d, aux_d, _ = moe_layer(x, as_mp(dq), ms, cfg, None,
                              x_spec=P(None, None, None))
    np.testing.assert_array_equal(np.asarray(y_q), np.asarray(y_d))
    np.testing.assert_array_equal(np.asarray(aux_q), np.asarray(aux_d))


def test_island_qat_fake_quant_and_router_grads():
    """cfg.quant='int8' runs the STE fake-quant inside the island: outputs
    equal espec on hand-fake-quantized weights, weight/router grads flow
    (STE), and the router grad is computed at full precision (identical to
    the unquantized router-grad path given the same FFN output values)."""
    from jax.sharding import PartitionSpec as P

    rng = np.random.default_rng(13)
    b, s, d, f, e, k = 2, 8, 32, 48, 4, 2
    p = _moe_params(rng, e, d, f, glu=True)
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    ms = MoEStatic(num_experts=e, top_k=k, act="silu", glu=True)
    cfg_q = ParallelConfig(blk=8, impl="blocked", quant="int8")
    cfg_d = ParallelConfig(blk=8, impl="blocked")

    def as_mp(src):
        return MoEParams(**{fld: src.get(fld)
                            for fld in MoEParams._fields
                            if src.get(fld) is not None or fld == "router"})

    def loss(params, cfg):
        y, aux, z = moe_layer(x, as_mp(params), ms, cfg, None,
                              x_spec=P(None, None, None))
        return jnp.sum(y * y) + aux

    fq = {kk: (qc.fake_quant(v, "int8", cfg_q.quant_tile)
               if kk != "router" else v) for kk, v in p.items()}
    np.testing.assert_array_equal(
        np.asarray(loss(p, cfg_q)), np.asarray(loss(fq, cfg_d)))
    g = jax.grad(loss)(p, cfg_q)
    for name, gv in g.items():
        assert np.isfinite(np.asarray(gv)).all(), name
        assert np.abs(np.asarray(gv)).max() > 0, name


def test_island_rejects_quantized_with_tp_mesh():
    """True-quantized experts need whole-expert layouts: a TP'd island
    must refuse rather than silently mis-scale."""
    pytest.importorskip("jax")
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh

    rng = np.random.default_rng(14)
    d, f, e = 32, 64, 4
    p = qc.quantize_ffn(_moe_params(rng, e, d, f))
    x = jnp.asarray(rng.normal(size=(2, 8, d)), jnp.float32)
    ms = MoEStatic(num_experts=e, top_k=2)
    mesh = make_mesh((2,), ("model",))
    cfg = ParallelConfig(mode="model_centric", blk=8, impl="blocked")
    mp = MoEParams(**{fld: p.get(fld) for fld in MoEParams._fields
                      if p.get(fld) is not None or fld == "router"})
    with pytest.raises(NotImplementedError):
        moe_layer(x, mp, ms, cfg, mesh, x_spec=P(None, None, None))


def test_quantize_lm_params_walker():
    """Only MoE expert weights quantize; router/attention/embed/dense
    stay; total bytes shrink."""
    import dataclasses as dc

    from repro import configs as cfglib
    from repro.common import tree_bytes
    from repro.models import lm
    from repro.parallel.sharding import split_tree

    cfg = dc.replace(cfglib.get_smoke_config("qwen3-moe-30b-a3b"),
                     dtype="float32")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    qp = qc.quantize_lm_params(params, cfg, mode="int8")
    assert tree_bytes(qp) < tree_bytes(params)
    moe_pos = [i for i in range(cfg.period) if cfg.is_moe_layer(i)]
    for pos in moe_pos:
        ffn = qp["layers"][pos]["ffn"]
        assert ffn["w_gate"].dtype == jnp.int8
        assert ffn["w_gate_scale"].dtype == jnp.float32
        assert ffn["router"].dtype == jnp.float32
    np.testing.assert_array_equal(
        np.asarray(qp["embed"]), np.asarray(params["embed"]))
