"""Router behaviour: top-k selection, gate normalisation, aux losses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.routing import route


def test_topk_selects_argmax():
    x = jnp.eye(4, 8)
    w = jnp.eye(8, 8)
    r = route(x, w, 1, norm_topk=True)
    np.testing.assert_array_equal(np.asarray(r.expert_idx[:, 0]),
                                  np.arange(4))
    np.testing.assert_allclose(np.asarray(r.gates), 1.0)


def test_norm_topk_gates_sum_to_one():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 6))
    r = route(x, w, 3, norm_topk=True)
    np.testing.assert_allclose(np.asarray(r.gates.sum(-1)), 1.0, rtol=1e-5)


def test_softmax_after_topk_mixtral_style():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 6))
    r = route(x, w, 2, softmax_after_topk=True)
    np.testing.assert_allclose(np.asarray(r.gates.sum(-1)), 1.0, rtol=1e-5)
    # gates ordered with logits
    assert bool((r.gates[:, 0] >= r.gates[:, 1]).all())


def test_aux_loss_balanced_is_one():
    """Perfectly uniform router probs => aux = E * sum(1/E * 1/E) * E = 1."""
    n, e = 1024, 8
    x = jnp.zeros((n, 4))
    w = jnp.zeros((4, e))
    r = route(x, w, 1)
    # degenerate ties route everything to expert 0 -> f imbalanced; use
    # random instead and check aux ~ 1 for a weak router
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 4))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, e)) * 1e-3
    r = route(x, w, 1)
    assert 0.9 < float(r.aux_loss) < 1.2


def test_aux_loss_penalises_collapse():
    n, e = 256, 8
    x = jnp.ones((n, 4))
    w = jnp.zeros((4, e)).at[:, 0].set(10.0)  # all mass on expert 0
    r = route(x, w, 1)
    assert float(r.aux_loss) > 4.0  # >> 1 (balanced)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 64), e=st.integers(2, 16), k=st.integers(1, 4),
       seed=st.integers(0, 3))
def test_router_invariants(n, e, k, seed):
    k = min(k, e)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 8))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, e))
    r = route(x, w, k)
    idx = np.asarray(r.expert_idx)
    assert idx.shape == (n, k)
    assert (0 <= idx).all() and (idx < e).all()
    # no duplicate expert per token
    for row in idx:
        assert len(set(row.tolist())) == k
    assert np.isfinite(np.asarray(r.gates)).all()
    assert (np.asarray(r.gates) >= 0).all()
