"""Flash-attention Pallas kernel vs the XLA online-softmax reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import chunked_attention


@pytest.mark.parametrize("s,bq,bk", [(64, 16, 16), (128, 32, 64)])
@pytest.mark.parametrize("gqa", [1, 2])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_reference(s, bq, bk, gqa, dtype):
    b, hq, hd = 2, 4, 16
    hkv = hq // gqa
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, hd)).astype(dtype)
    out = flash_attention(q, k, v, bq=bq, bk=bk)
    want = chunked_attention(q, k, v, q_chunk=32, kv_block=32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_non_causal():
    b, s, h, hd = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (b, s, h, hd)) for kk in ks)
    out = flash_attention(q, k, v, causal=False, bq=32, bk=32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * hd ** -0.5
    w = jax.nn.softmax(logits, -1)
    want = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
