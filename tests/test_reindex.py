"""Re-index layout invariants (paper Algorithm 1), incl. property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.reindex import (
    build_reindex, combine_scatter, gather_sorted, padded_rows,
)
from repro.core.routing import route


def _routing(n, e, k, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, 16))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (16, e))
    return route(x, w, k)


def check_invariants(expert_idx, gates, e, blk):
    n, k = expert_idx.shape
    ri = build_reindex(jnp.asarray(expert_idx), jnp.asarray(gates), e, blk)
    row_id = np.asarray(ri.row_id)
    nk = n * k

    # 1. static shape
    assert ri.num_rows == padded_rows(n, k, e, blk)
    assert ri.num_rows % blk == 0
    # 2. every copy id appears exactly once
    real = row_id[row_id < nk]
    assert sorted(real.tolist()) == list(range(nk))
    # 3. every block is single-expert and matches block_expert
    be = np.asarray(ri.block_expert)
    ef = np.asarray(expert_idx).reshape(-1)
    for r, fid in enumerate(row_id):
        if fid < nk:
            assert ef[fid] == be[r // blk]
    # 4. counts
    assert np.asarray(ri.counts).sum() == nk
    np.testing.assert_array_equal(
        np.asarray(ri.counts),
        np.bincount(ef, minlength=e),
    )
    # 5. padded counts are blk multiples covering counts
    pc = np.asarray(ri.padded_counts)
    assert (pc % blk == 0).all()
    assert (pc >= np.asarray(ri.counts)).all()
    # 6. gates: real rows carry the right gate; sentinels zero
    g = np.asarray(gates).reshape(-1)
    rg = np.asarray(ri.row_gate)
    for r, fid in enumerate(row_id):
        if fid < nk:
            assert rg[r] == pytest.approx(g[fid], abs=1e-6)
        else:
            assert rg[r] == 0.0
    return ri


def test_basic_invariants():
    r = _routing(64, 4, 2)
    check_invariants(r.expert_idx, r.gates, 4, 16)


def test_empty_experts():
    # all tokens to expert 0: others empty
    ei = jnp.zeros((32, 1), jnp.int32)
    g = jnp.ones((32, 1), jnp.float32)
    ri = check_invariants(ei, g, 8, 8)
    assert int(ri.counts[0]) == 32
    assert int(ri.counts[1:].sum()) == 0


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 40),
    e=st.integers(1, 9),
    k=st.integers(1, 3),
    blk=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 5),
)
def test_property_invariants(n, e, k, blk, seed):
    k = min(k, e)
    rng = np.random.default_rng(seed)
    ei = rng.integers(0, e, size=(n, k)).astype(np.int32)
    g = rng.random(size=(n, k)).astype(np.float32)
    check_invariants(ei, g, e, blk)


def test_gather_combine_roundtrip():
    """combine(gather(x)) with gates summing to 1 == x (top-k identity)."""
    n, d, e, k, blk = 32, 8, 4, 2, 8
    r = _routing(n, e, k)
    gates = jnp.full((n, k), 0.5)
    ri = build_reindex(r.expert_idx, gates, e, blk)
    x = jax.random.normal(jax.random.PRNGKey(3), (n, d))
    xs = gather_sorted(x, ri)
    y = combine_scatter(xs, ri, n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)
