"""Property test for the fused expert FFN: random shapes, routings and gate
weights — fused (pallas-interpret AND blocked) must match the unfused
reference composition, forward and gradient. Guarded like the other
property modules: skips without hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import espec  # noqa: E402
from repro.core.reindex import build_reindex  # noqa: E402


@st.composite
def _case(draw):
    e = draw(st.sampled_from([2, 3, 4, 8]))
    k = draw(st.integers(1, min(e, 3)))
    n = draw(st.sampled_from([16, 24, 40]))
    d = draw(st.sampled_from([8, 16]))
    f = draw(st.sampled_from([8, 24]))
    blk = draw(st.sampled_from([8, 16]))
    glu = draw(st.booleans())
    # arbitrary routing incl. repeats/empties: every token picks freely
    ei = draw(st.lists(
        st.lists(st.integers(0, e - 1), min_size=k, max_size=k),
        min_size=n, max_size=n,
    ))
    seed = draw(st.integers(0, 2 ** 16))
    return e, k, n, d, f, blk, glu, np.asarray(ei, np.int32), seed


@given(_case())
@settings(max_examples=20, deadline=None)
def test_fused_matches_unfused_property(case):
    e, k, n, d, f, blk, glu, ei, seed = case
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    g = jax.random.uniform(ks[0], (n, k))
    ri = build_reindex(jnp.asarray(ei), g, e, blk)
    x = jax.random.normal(ks[1], (n, d))
    if glu:
        ws = (jax.random.normal(ks[2], (e, d, f)) * 0.3,
              jax.random.normal(ks[3], (e, d, f)) * 0.3,
              jax.random.normal(ks[4], (e, f, d)) * 0.3)
        run = lambda impl, fused: espec.moe_glu(
            x, ri, *ws, act="silu", impl=impl, fused=fused)
    else:
        ws = (jax.random.normal(ks[2], (e, d, f)) * 0.3,
              jax.random.normal(ks[3], (e, f)) * 0.3,
              jax.random.normal(ks[4], (e, f, d)) * 0.3,
              None)
        run = lambda impl, fused: espec.moe_mlp(
            x, ri, ws[0], ws[1], ws[2], ws[3], act="gelu",
            impl=impl, fused=fused)

    want = run("ref", False)
    for impl in ("pallas", "blocked"):
        got = run(impl, True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=5e-5, atol=5e-5,
            err_msg=f"forward {impl}",
        )

    def loss(ws_, impl, fused):
        if glu:
            y = espec.moe_glu(x, ri, *ws_, act="silu", impl=impl, fused=fused)
        else:
            y = espec.moe_mlp(x, ri, ws_[0], ws_[1], ws_[2], ws_[3],
                              act="gelu", impl=impl, fused=fused)
        return jnp.sum(y ** 2)

    diff = tuple(w for w in ws if w is not None)
    pack = (lambda t: t) if glu or len(diff) == 4 else (
        lambda t: (t[0], t[1], t[2], None))
    g_u = jax.grad(lambda t: loss(pack(t), "blocked", False))(diff)
    g_f = jax.grad(lambda t: loss(pack(t), "blocked", True))(diff)
    for a, b in zip(g_u, g_f):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4,
            err_msg="grad",
        )
