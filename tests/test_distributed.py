"""Distributed-semantics tests, run in subprocesses with 8 fake devices
(the main test process keeps the 1-device contract)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.multihost  # subprocess fake-device mesh tier

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, timeout: int = 900) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    # Force the CPU backend: with JAX_PLATFORMS unset, jax probes the TPU
    # plugin first, and off-TPU that means minutes of GCP-metadata retries
    # before the CPU fallback. Fake devices come from XLA_FLAGS regardless.
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT")]
    assert line, res.stdout[-2000:]
    return json.loads(line[-1][len("RESULT"):])


PREAMBLE = r"""
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.moe_parallel import MoEParams, MoEStatic, moe_layer
from repro.parallel.sharding import ParallelConfig
from repro.core import espec

from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
B, S, D, F, E, K = 8, 16, 32, 64, 4, 2
ks = jax.random.split(jax.random.PRNGKey(0), 6)
x = jax.random.normal(ks[0], (B, S, D), jnp.float32)
p = MoEParams(router=jax.random.normal(ks[1], (D, E)) * 0.1,
              w_gate=jax.random.normal(ks[2], (E, D, F)) * 0.1,
              w_up=jax.random.normal(ks[3], (E, D, F)) * 0.1,
              w_down=jax.random.normal(ks[4], (E, F, D)) * 0.1)
ms = MoEStatic(num_experts=E, top_k=K, act="silu", glu=True)
ref = espec.hexa_moe_ffn(
    x.reshape(B * S, D),
    {"router": p.router, "w_gate": p.w_gate, "w_up": p.w_up,
     "w_down": p.w_down},
    num_experts=E, top_k=K, act="silu", glu=True, blk=16).y.reshape(B, S, D)
"""


def test_all_modes_match_oracle():
    out = run_sub(PREAMBLE + r"""
errs = {}
for mode in ("hybrid", "model_centric", "data_centric", "ep"):
    for sched in ("ag_rs", "ag_ar"):
        cfg = ParallelConfig(mode=mode, collective_schedule=sched, blk=16,
                             capacity_factor=8.0)  # EP: no drops
        spec = P("data", "model", None)
        with mesh:
            y, aux, z = jax.jit(
                lambda x, p: moe_layer(x, p, ms, cfg, mesh, x_spec=spec)
            )(x, p)
        errs[f"{mode}/{sched}"] = float(jnp.abs(y - ref).max())
print("RESULT" + json.dumps(errs))
""")
    for key, err in out.items():
        assert err < 5e-5, (key, err)


def test_grads_match_across_modes():
    out = run_sub(PREAMBLE + r"""
def loss(p, mode):
    cfg = ParallelConfig(mode=mode, blk=16)
    spec = P("data", "model", None)
    y, aux, z = moe_layer(x, p, ms, cfg, mesh, x_spec=spec)
    return jnp.sum(y ** 2)

with mesh:
    g_h = jax.jit(jax.grad(lambda p: loss(p, "hybrid")))(p)
    g_m = jax.jit(jax.grad(lambda p: loss(p, "model_centric")))(p)
    g_d = jax.jit(jax.grad(lambda p: loss(p, "data_centric")))(p)
errs = {}
for name, g in (("model", g_m), ("data", g_d)):
    errs[name] = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(g_h), jax.tree.leaves(g))
    )
print("RESULT" + json.dumps(errs))
""")
    for key, err in out.items():
        assert err < 1e-3, (key, err)


def test_sharded_train_step_runs_and_matches_single_device():
    out = run_sub(r"""
import json, dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_smoke_config
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.optim import adamw
from repro.parallel.sharding import ParallelConfig, split_tree, tree_shardings

cfg = dataclasses.replace(get_smoke_config("mixtral-8x7b"), dtype="float32")
B, S = 8, 32
rng = np.random.default_rng(0)
toks = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
batch = {"tokens": jnp.asarray(toks),
         "labels": jnp.asarray(np.roll(toks, -1, 1)),
         "loss_mask": jnp.ones((B, S), jnp.float32)}
opt_cfg = adamw.OptimizerConfig(master_fp32=False)

def run(mesh):
    pcfg = ParallelConfig(blk=8)
    params, specs = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    if mesh is not None:
        params = jax.tree.map(jax.device_put, params,
                              tree_shardings(params, specs, pcfg, mesh))
    opt = adamw.init_opt_state(params, opt_cfg)
    step = jax.jit(steps_lib.make_train_step(cfg, pcfg, mesh, opt_cfg,
                                             (B, S, cfg.d_model)))
    losses = []
    for i in range(3):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    return losses

mesh = make_mesh((4, 2), ("data", "model"))
with mesh:
    dist = run(mesh)
single = run(None)
print("RESULT" + json.dumps({"dist": dist, "single": single}))
""")
    for a, b in zip(out["dist"], out["single"]):
        assert abs(a - b) < 2e-3, (out["dist"], out["single"])


def test_compressed_psum_matches_exact():
    out = run_sub(r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.optim.compression import compressed_psum
from repro.launch.mesh import make_mesh
from repro.parallel.moe_parallel import _shard_map

mesh = make_mesh((8,), ("pod",))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

def body(g_loc):
    out, res = compressed_psum(g_loc[0], "pod", jnp.zeros_like(g_loc[0]))
    return out[None], res[None]

with mesh:
    out, res = jax.jit(_shard_map(
        body, mesh, in_specs=(P("pod", None),),
        out_specs=(P("pod", None), P("pod", None)),
    ))(g)
exact = jnp.sum(g, axis=0)
rel = float(jnp.linalg.norm(out[0] - exact) / jnp.linalg.norm(exact))
resid_ok = bool(jnp.isfinite(res).all())
print("RESULT" + json.dumps({"rel": rel, "resid_ok": resid_ok}))
""")
    assert out["rel"] < 0.02, out
    assert out["resid_ok"]


def test_elastic_restore_onto_different_mesh(tmp_path):
    out = run_sub(r"""
import json, dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro.checkpoint import manager as ckpt
from repro.configs import get_smoke_config
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.parallel.sharding import ParallelConfig, split_tree, tree_shardings

cfg = dataclasses.replace(get_smoke_config("phi3-medium-14b"), dtype="float32")
pcfg = ParallelConfig(blk=8)
params, specs = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))

mesh_a = make_mesh((4, 2), ("data", "model"))
pa = jax.tree.map(jax.device_put, params,
                  tree_shardings(params, specs, pcfg, mesh_a))
import tempfile, os
d = tempfile.mkdtemp()
ckpt.save(d, 1, pa, meta={"step": 1})

# "job restarted with fewer devices": new 2x2 mesh over first 4 devices
from jax.sharding import Mesh
import numpy as onp
mesh_b = Mesh(onp.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
sh_b = tree_shardings(params, specs, pcfg, mesh_b)
pb, _ = ckpt.restore(d, 1, params, sh_b)
ok = all(
    bool(np.allclose(np.asarray(a), np.asarray(b)))
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb))
)
devs = {json.dumps(dict(x.sharding.mesh.shape)) for x in jax.tree.leaves(pb)}
print("RESULT" + json.dumps({"ok": ok, "meshes": sorted(devs)}))
""")
    assert out["ok"]
    assert json.loads(out["meshes"][0]) == {"data": 2, "model": 2}
