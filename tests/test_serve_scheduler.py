"""Scheduler property tests (ISSUE 4): no page leaks after arbitrary
admit/finish interleavings, FIFO admission without starvation, and batch
invariance of a request's output stream.

Each property body is a plain ``_check_*`` function: the hypothesis tests
(skipped without the package, like the other property modules) drive it
with drawn inputs, and the deterministic tests below drive it with pinned
samples so the invariants stay executed on minimal CI environments."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.launch import serve, steps as steps_lib
from repro.models import lm
from repro.parallel.cache import PagePool, page_shares
from repro.parallel.sharding import ParallelConfig, split_tree

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# --- tiny decode-capable model shared by every engine-level case ----------

CFG = ModelConfig(
    name="sched-smoke",
    family="dense",
    num_layers=1,
    d_model=16,
    num_heads=2,
    num_kv_heads=2,
    head_dim=8,
    d_ff=32,
    vocab_size=32,
    dtype="float32",
)
PCFG = ParallelConfig(blk=8)
NUM_SLOTS, PAGE, MAXP = 3, 4, 8
NUM_PAGES = 1 + NUM_SLOTS * MAXP

_STATE: dict = {}


def _shared():
    """Params + jitted steps built once: every server instance reuses the
    same compiled macro-step (identical shapes), so hypothesis examples
    don't pay a retrace each."""
    if not _STATE:
        params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), CFG))
        _STATE["params"] = params
        _STATE["serve_step"] = jax.jit(steps_lib.make_paged_serve_step(
            CFG, PCFG, None, (NUM_SLOTS, 1, CFG.d_model), PAGE))
        _STATE["prefill_step"] = jax.jit(steps_lib.make_paged_prefill_step(
            CFG, PCFG, None, PAGE))
        _STATE["ref_step"] = jax.jit(steps_lib.make_serve_step(
            CFG, PCFG, None, (1, 1, CFG.d_model)))
    return _STATE


def _server(prefill_chunk=4):
    s = _shared()
    srv = serve.PagedServer(
        CFG, PCFG, None, num_slots=NUM_SLOTS, page_size=PAGE,
        num_pages=NUM_PAGES, max_pages_per_slot=MAXP,
        params=s["params"], prefill_chunk=prefill_chunk,
    )
    srv.serve_step = s["serve_step"]
    srv.prefill_step = s["prefill_step"]
    return srv


def _mk_requests(spec):
    """spec: list of (prompt_len, max_new) with deterministic contents."""
    reqs = []
    for i, (plen, max_new) in enumerate(spec):
        prompt = (np.arange(plen) * 7 + i * 3) % CFG.vocab_size
        reqs.append(serve.Request(rid=i, prompt=prompt.astype(np.int32),
                                  max_new=max_new))
    return reqs


# ---------------------------------------------------------------------------
# property 1 — no page leaks under arbitrary admit/finish interleavings
# ---------------------------------------------------------------------------

def _check_pool_no_leak(num_pages, shares, ops):
    """Drive a PagePool through an arbitrary interleaving of admissions
    (reserve + partial alloc) and finishes; the pool must stay consistent
    THROUGHOUT and the free count must return to its initial value."""
    pool = PagePool(num_pages, page_bytes=128, shares=shares)
    initial_free = pool.free_pages
    live = []  # (group, need, pages)
    for kind, a, b in ops:
        if kind == "admit":
            g = a % len(pool.shares)
            need = 1 + b % 6
            if pool.try_reserve(need, g):
                n_alloc = b % (need + 1)
                pages = [pool.alloc(g) for _ in range(n_alloc)]
                live.append([g, need, pages])
        elif kind == "grow" and live:
            g, need, pages = live[a % len(live)]
            if len(pages) < need:
                pages.append(pool.alloc(g))
        elif kind == "finish" and live:
            g, need, pages = live.pop(a % len(live))
            pool.release(pages, g, unused_reserved=need - len(pages))
        pool.assert_consistent()
        assert pool.in_use_pages <= num_pages - 1
    while live:
        g, need, pages = live.pop()
        pool.release(pages, g, unused_reserved=need - len(pages))
    pool.assert_consistent()
    assert pool.free_pages == initial_free
    assert pool.in_use_pages == 0 and pool.reserved_pages == 0


OPS_SAMPLES = [
    [("admit", 0, 5), ("admit", 1, 3), ("grow", 0, 0), ("finish", 0, 0),
     ("admit", 0, 2), ("finish", 0, 0), ("finish", 0, 0)],
    [("admit", 0, 6)] * 10 + [("finish", 0, 0)] * 10,
    [("admit", 1, 4), ("grow", 0, 0), ("grow", 0, 0), ("grow", 0, 0),
     ("admit", 0, 1), ("finish", 1, 0), ("finish", 0, 0)],
]


@pytest.mark.parametrize("ops", OPS_SAMPLES)
@pytest.mark.parametrize("shares", [None, [10, 6], [15, 0, 1]])
def test_pool_no_leak_samples(ops, shares):
    _check_pool_no_leak(17, shares, ops)


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(st.lists(
        st.tuples(st.sampled_from(["admit", "grow", "finish"]),
                  st.integers(0, 7), st.integers(0, 7)),
        max_size=60,
    ), st.sampled_from([None, [10, 6], [4, 4, 4, 4]]))
    def test_pool_no_leak_property(ops, shares):
        _check_pool_no_leak(17, shares, ops)


def test_pool_rejects_bad_inputs():
    pool = PagePool(5, shares=[2, 2])
    assert not pool.try_reserve(3, 0)           # beyond the group share
    assert pool.try_reserve(2, 0)
    with pytest.raises(RuntimeError):
        [pool.alloc(1) for _ in range(1)]       # group 1 reserved nothing
    with pytest.raises(ValueError):
        PagePool(5, shares=[5])                 # shares exceed usable (4)
    with pytest.raises(ValueError):
        PagePool(1)
    with pytest.raises(ValueError):
        page_shares([0, 0], 4)
    assert sum(page_shares([2, 1], 7)) == 7


# ---------------------------------------------------------------------------
# property 2 — engine-level: no leak + FIFO no-starvation
# ---------------------------------------------------------------------------

def _check_engine_fifo_and_leakfree(spec, prefill_chunk=4):
    reqs = _mk_requests(spec)
    srv = _server(prefill_chunk)
    for r in reqs:
        srv.submit(dataclasses.replace(r, out=[]))
    done = srv.run()
    # no starvation: every submitted request completes with its max_new
    assert sorted(r.rid for r in done) == list(range(len(spec)))
    for r in done:
        assert len(r.out) == r.max_new
    # FIFO: admission order is exactly submission order (head-of-line)
    assert srv.admission_log == [r.rid for r in reqs]
    # no leaks: pool drained back to initial, page table cleared
    srv.pool.assert_consistent()
    assert srv.pool.free_pages == NUM_PAGES - 1
    assert srv.pool.in_use_pages == 0 and srv.pool.reserved_pages == 0
    assert (srv.table == 0).all()
    return {r.rid: r.out for r in done}


ENGINE_SPECS = [
    [(3, 2), (9, 4), (1, 1), (14, 3), (2, 5), (6, 1)],
    [(29, 4), (1, 1), (1, 1), (1, 1)],       # long prompt at the head
    [(4, 8)] * 7,                             # uniform churn > slots
]


@pytest.mark.parametrize("spec", ENGINE_SPECS)
def test_engine_fifo_and_leakfree_samples(spec):
    _check_engine_fifo_and_leakfree(spec)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 20), st.integers(1, 6)),
                    min_size=1, max_size=7),
           st.sampled_from([1, 4, 16]))
    def test_engine_fifo_and_leakfree_property(spec, chunk):
        # worst case must fit one slot's table and the pool share
        cap = MAXP * PAGE
        spec = [(p, min(m, cap - p + 1)) for p, m in spec]
        _check_engine_fifo_and_leakfree(spec, prefill_chunk=chunk)


# ---------------------------------------------------------------------------
# property 3 — a request's stream is invariant to its batch-mates
# ---------------------------------------------------------------------------

def _check_batch_invariance(spec, probe_idx):
    probe = _mk_requests(spec)[probe_idx]

    alone = _server()
    alone.submit(dataclasses.replace(probe, out=[]))
    solo_out = {r.rid: r.out for r in alone.run()}[probe.rid]

    crowd = _server()
    for r in _mk_requests(spec):
        crowd.submit(dataclasses.replace(r, out=[]))
    crowd_out = {r.rid: r.out for r in crowd.run()}[probe.rid]
    assert crowd_out == solo_out, (
        f"request {probe.rid} changed its stream when co-batched")


@pytest.mark.parametrize("probe_idx", [0, 2, 4])
def test_batch_invariance_samples(probe_idx):
    _check_batch_invariance(
        [(3, 3), (11, 2), (5, 4), (1, 5), (8, 2)], probe_idx)


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 12), st.integers(1, 4)),
                    min_size=2, max_size=6),
           st.integers(0, 5))
    def test_batch_invariance_property(spec, probe_idx):
        _check_batch_invariance(spec, probe_idx % len(spec))
