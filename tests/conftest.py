import os
import sys

# Tests and benches see ONE device (the dry-run sets its own XLA_FLAGS in a
# subprocess). Keep CPU compile fast.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
