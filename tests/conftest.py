import os
import sys

# Tests and benches see ONE device (the dry-run sets its own XLA_FLAGS in a
# subprocess). Keep CPU compile fast.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tier1: fast in-process tests (the default tier; every test "
        "without an explicit multihost marker)",
    )
    config.addinivalue_line(
        "markers",
        "multihost: subprocess tests driving an "
        "--xla_force_host_platform_device_count fake-device mesh (the "
        "slower distributed tier; `pytest -m multihost`)",
    )


def pytest_collection_modifyitems(config, items):
    # Every test is in exactly one tier: multihost where marked (module
    # pytestmark or per-test), tier1 otherwise — so
    # `-m "not multihost"` + `-m multihost` partition the suite.
    for item in items:
        if item.get_closest_marker("multihost") is None:
            item.add_marker(pytest.mark.tier1)
