"""Heterogeneous-aware allocation (paper Eq. 1/2, Table 3 logic)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.hetero import (
    DeviceProfile,
    plan_data_centric,
    plan_model_centric,
    proportional_split,
    replan_from_step_times,
    step_latency_model,
)


def test_eq1_proportions_match_paper_case1():
    # Paper Table 3 case 1: t = (4.58, 3.06) -> R = (0.40, 0.60)
    profiles = [DeviceProfile("D0", 4.58), DeviceProfile("D1", 3.06)]
    shares = plan_data_centric(profiles, 100)
    assert shares[0] + shares[1] == 100
    assert abs(shares[0] - 40) <= 1 and abs(shares[1] - 60) <= 1


def test_eq2_mxu_quantum():
    profiles = [DeviceProfile("a", 1.0), DeviceProfile("b", 3.0)]
    shares = plan_model_centric(profiles, 1024, quantum=128)
    assert sum(shares) == 1024
    assert all(s % 128 == 0 for s in shares)
    assert shares[0] > shares[1]


@settings(max_examples=50, deadline=None)
@given(
    lat=st.lists(st.floats(0.1, 100.0), min_size=1, max_size=16),
    total=st.integers(1, 4096),
)
def test_split_exact_total_property(lat, total):
    shares = proportional_split(lat, total)
    assert sum(shares) == total
    assert all(s >= 0 for s in shares)
    # monotone: faster device never gets less than a strictly slower one
    order = np.argsort(lat)
    s = np.array(shares)[order]
    assert all(s[i] >= s[i + 1] - 1 for i in range(len(s) - 1))


def test_optimal_split_minimises_latency_model():
    """Figure 11's claim: the Eq.1 split beats uniform on the latency model."""
    profiles = [DeviceProfile("fast", 1.0), DeviceProfile("slow", 3.0)]
    total = 120
    opt = plan_data_centric(profiles, total)
    uniform = [60, 60]
    t_opt = step_latency_model(profiles, opt, total)
    t_uni = step_latency_model(profiles, uniform, total)
    assert t_opt < t_uni
    # the paper reports double-digit % gains for a 3x skew
    assert (t_uni - t_opt) / t_uni > 0.2


def test_replan_shifts_load_away_from_straggler():
    shares = [50, 50]
    times = [1.0, 2.0]  # device 1 is degraded
    new = replan_from_step_times(times, shares, 100, smoothing=1.0)
    assert sum(new) == 100
    assert new[0] > new[1]


def test_replan_smoothing_damps():
    shares = [50, 50]
    times = [1.0, 2.0]
    aggressive = replan_from_step_times(times, shares, 100, smoothing=1.0)
    damped = replan_from_step_times(times, shares, 100, smoothing=0.2)
    assert aggressive[0] >= damped[0] >= 50


def test_quantum_divisibility_error():
    with pytest.raises(ValueError):
        proportional_split([1.0, 1.0], 101, quantum=2)
