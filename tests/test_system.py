"""End-to-end behaviour tests: tiny Hexa-MoE LM learns the synthetic
Markov stream; checkpoint-resume reproduces the exact trajectory;
prefill+decode agree with teacher-forced forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.data.pipeline import DataConfig, TokenSource
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.optim import adamw
from repro.parallel.sharding import ParallelConfig, split_tree

CFG = ModelConfig(
    name="sys-moe", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=0, vocab_size=64, dtype="float32",
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=128),
)
B, S = 8, 32


def _make_step(opt_cfg):
    return jax.jit(
        steps_lib.make_train_step(CFG, ParallelConfig(blk=16), None, opt_cfg,
                                  (B, S, CFG.d_model))
    )


def test_loss_decreases_on_markov_stream():
    opt_cfg = adamw.OptimizerConfig(peak_lr=3e-3, warmup_steps=5,
                                    decay_steps=100, master_fp32=False)
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), CFG))
    opt = adamw.init_opt_state(params, opt_cfg)
    step = _make_step(opt_cfg)
    data = TokenSource(DataConfig(seq_len=S, global_batch=B,
                                  vocab_size=CFG.vocab_size))
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::8]


def test_training_is_deterministic():
    opt_cfg = adamw.OptimizerConfig(master_fp32=False)
    data = TokenSource(DataConfig(seq_len=S, global_batch=B,
                                  vocab_size=CFG.vocab_size))
    step = _make_step(opt_cfg)

    def run(n):
        params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), CFG))
        opt = adamw.init_opt_state(params, opt_cfg)
        for i in range(n):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            params, opt, m = step(params, opt, batch)
        return float(m["loss"])

    assert run(5) == run(5)


def test_prefill_then_decode_matches_forward():
    """prefill(x[:t]) -> decode one-by-one must reproduce teacher-forced
    logits at every position (the KV-cache correctness contract)."""
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(1), CFG))
    pcfg = ParallelConfig(blk=16)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              CFG.vocab_size)
    # teacher-forced full forward
    full_logits, _, _, _ = lm.forward(
        params, {"tokens": toks}, CFG, pcfg, None, mode="train")
    # prefill on first 6, then decode the rest
    cache = lm.init_cache(CFG, 2, 12)
    pre_logits, cache, _, _ = lm.forward(
        params, {"tokens": toks[:, :6]}, CFG, pcfg, None,
        mode="prefill", cache=cache)
    np.testing.assert_allclose(np.asarray(pre_logits[:, 0]),
                               np.asarray(full_logits[:, 5]),
                               rtol=2e-3, atol=2e-3)
    for t in range(6, 12):
        dec_logits, cache, _, _ = lm.forward(
            params, {"tokens": toks[:, t:t + 1]}, CFG, pcfg, None,
            mode="decode", cache=cache)
        np.testing.assert_allclose(
            np.asarray(dec_logits[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3, err_msg=f"position {t}",
        )


def test_checkpoint_resume_exact(tmp_path):
    from repro.checkpoint import manager as ckpt

    opt_cfg = adamw.OptimizerConfig(master_fp32=False)
    data = TokenSource(DataConfig(seq_len=S, global_batch=B,
                                  vocab_size=CFG.vocab_size))
    step = _make_step(opt_cfg)

    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), CFG))
    opt = adamw.init_opt_state(params, opt_cfg)
    # run 6 steps straight
    ps, os_ = params, opt
    for i in range(6):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        ps, os_, m6 = step(ps, os_, batch)
    # run 3 steps, save, restore, 3 more
    pa, oa = params, opt
    for i in range(3):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        pa, oa, _ = step(pa, oa, batch)
    ckpt.save(str(tmp_path), 3, {"p": pa, "o": oa}, meta={"step": 3})
    restored, meta = ckpt.restore(
        str(tmp_path), 3, {"p": pa, "o": oa})
    pb, ob = restored["p"], restored["o"]
    for i in range(3, 6):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        pb, ob, mr = step(pb, ob, batch)
    assert float(mr["loss"]) == float(m6["loss"])
