"""Fused expert-FFN (kernels/esffn.py + ops.esffn_*, DESIGN.md §5) vs the
unfused gather/esmm/act/esmm/combine composition: forward and gradients,
across impls, expert-load shapes, both body types, with and without biases —
plus the cost-model claim that the (Np, F) hidden round-trip is gone."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import espec
from repro.core.reindex import build_reindex
from repro.kernels import ops
from repro.kernels.esffn import esffn_cost, esffn_glu_pallas, esffn_mlp_pallas

N, D, F, E, K, BLK = 48, 16, 24, 4, 2, 8
IMPLS = ["pallas", "blocked", "ref"]

#: Expert-load shapes: uniform routing, heavily skewed (uneven per-expert
#: counts), and everything-to-expert-0 (E-1 empty experts + tail blocks).
LOADS = ["uniform", "uneven", "empty"]


def _routing(load, n=N, k=K, e=E, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    if load == "uniform":
        ei = jax.random.randint(ks[0], (n, k), 0, e)
    elif load == "uneven":
        ei = jnp.minimum(
            jax.random.randint(ks[0], (n, k), 0, e),
            jax.random.randint(ks[1], (n, k), 0, e),
        )
    elif load == "empty":
        ei = jnp.zeros((n, k), jnp.int32)
    else:
        raise ValueError(load)
    g = jax.random.uniform(ks[2], (n, k))
    return build_reindex(ei, g, e, BLK)


def _weights(seed=0, e=E, d=D, f=F):
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    return {
        "w_gate": jax.random.normal(ks[0], (e, d, f)) * 0.2,
        "w_up": jax.random.normal(ks[1], (e, d, f)) * 0.2,
        "w_down": jax.random.normal(ks[2], (e, f, d)) * 0.2,
        "w1": jax.random.normal(ks[3], (e, d, f)) * 0.2,
        "b1": jax.random.normal(ks[4], (e, f)) * 0.2,
        "w2": jax.random.normal(ks[5], (e, f, d)) * 0.2,
        "b2": jax.random.normal(ks[6], (e, d)) * 0.2,
    }


def _x(seed=9, n=N, d=D):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d))


def _run(x, ri, w, glu, bias, impl, fused):
    if glu:
        return espec.moe_glu(
            x, ri, w["w_gate"], w["w_up"], w["w_down"], act="silu",
            impl=impl, fused=fused,
        )
    return espec.moe_mlp(
        x, ri,
        w["w1"], w["b1"] if bias else None,
        w["w2"], w["b2"] if bias else None,
        act="gelu", impl=impl, fused=fused,
    )


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("glu,bias", [(True, False), (False, True),
                                      (False, False)])
@pytest.mark.parametrize("load", LOADS)
def test_fused_forward_matches_unfused(impl, glu, bias, load):
    ri = _routing(load)
    x, w = _x(), _weights()
    want = _run(x, ri, w, glu, bias, "blocked", fused=False)
    got = _run(x, ri, w, glu, bias, impl, fused=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("glu,bias", [(True, False), (False, True),
                                      (False, False)])
@pytest.mark.parametrize("load", ["uniform", "empty"])
def test_fused_grads_match_unfused(impl, glu, bias, load):
    """Full-pipeline grads (x, every weight, and — via the in-kernel gate
    weighting — the routing gates/router) of fused == unfused."""
    ri = _routing(load)
    x, w = _x(), _weights()
    tgt = _x(seed=11)
    keys = (["w_gate", "w_up", "w_down"] if glu
            else (["w1", "b1", "w2", "b2"] if bias else ["w1", "w2"]))

    def loss(x, w, impl, fused):
        y = _run(x, ri, w, glu, bias, impl, fused)
        return jnp.sum((y - tgt) ** 2)

    gx_u, gw_u = jax.grad(loss, argnums=(0, 1))(x, w, "blocked", False)
    gx_f, gw_f = jax.grad(loss, argnums=(0, 1))(x, w, impl, True)
    np.testing.assert_allclose(
        np.asarray(gx_f), np.asarray(gx_u), rtol=5e-4, atol=5e-5
    )
    for key in keys:
        np.testing.assert_allclose(
            np.asarray(gw_f[key]), np.asarray(gw_u[key]),
            rtol=5e-4, atol=5e-5, err_msg=f"{impl} {key}",
        )


@pytest.mark.parametrize("glu", [True, False])
def test_fused_router_grads_match(glu):
    """Gate gradients flow through the fused op's custom_vjp (d_gate) back
    to the router weights — end-to-end through hexa_moe_ffn."""
    p = _weights()
    p["router"] = jax.random.normal(jax.random.PRNGKey(3), (D, E)) * 0.2
    x = _x()
    tgt = _x(seed=12)

    def loss(p, fused, impl):
        out = espec.hexa_moe_ffn(
            x, p, num_experts=E, top_k=K, act="silu" if glu else "gelu",
            glu=glu, blk=BLK, impl=impl, fused=fused,
        )
        return jnp.sum((out.y - tgt) ** 2)

    g_u = jax.grad(loss)(p, False, "blocked")
    for impl in IMPLS:
        g_f = jax.grad(loss)(p, True, impl)
        np.testing.assert_allclose(
            np.asarray(g_f["router"]), np.asarray(g_u["router"]),
            rtol=5e-4, atol=5e-5, err_msg=impl,
        )


def test_fused_empty_expert_weight_grads_zero():
    """Experts that received no tokens must get exactly-zero weight grads
    through the fused backward (recompute path included)."""
    ri = _routing("empty")
    x, w = _x(), _weights()

    def loss(w):
        y = espec.moe_glu(
            x, ri, w["w_gate"], w["w_up"], w["w_down"], act="silu",
            impl="blocked", fused=True,
        )
        return jnp.sum(y ** 2)

    g = jax.grad(loss)({k: w[k] for k in ("w_gate", "w_up", "w_down")})
    for key, val in g.items():
        arr = np.asarray(val)
        assert np.abs(arr[1:]).max() == 0.0, key   # empty experts
        assert np.abs(arr[0]).max() > 0.0, key     # the loaded expert


def test_pallas_kernel_direct_bf16():
    """The megakernel itself (not through espec), bf16 inputs."""
    ri = _routing("uniform")
    w = _weights()
    x = _x().astype(jnp.bfloat16)
    wg = w["w_gate"].astype(jnp.bfloat16)
    wu = w["w_up"].astype(jnp.bfloat16)
    wd = w["w_down"].astype(jnp.bfloat16)
    got = esffn_glu_pallas(
        x, ri.row_token, ri.row_gate, ri.block_expert, wg, wu, wd, act="silu"
    )
    assert got.dtype == jnp.bfloat16
    want = ops.esffn_glu(
        x, ri.row_token, ri.row_gate, ri.block_expert, ri.padded_counts,
        wg, wu, wd, act="silu", impl="ref",
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=4e-2, atol=4e-2,
    )


def test_pallas_kernel_hidden_blocking():
    """bf < F forces multi-step hidden accumulation in the kernel grid."""
    ri = _routing("uniform")
    w = _weights()
    x = _x()
    got = esffn_mlp_pallas(
        x, ri.row_token, ri.row_gate, ri.block_expert,
        w["w1"], w["b1"], w["w2"], w["b2"], act="gelu", bf=8,
    )
    want = _run(x, ri, w, glu=False, bias=True, impl="blocked", fused=False)
    # compare at the sorted level: scatter back first
    from repro.core.reindex import scatter_rows
    got_tok = scatter_rows(got, ri.row_token, N)
    np.testing.assert_allclose(
        np.asarray(got_tok), np.asarray(want), rtol=2e-5, atol=2e-5
    )


def test_cost_estimate_excludes_hidden_roundtrip():
    """The acceptance claim: bytes_accessed of the fused kernel has no
    (Np, F) hidden term — it is exactly rows+weights+gate+output, strictly
    below the unfused pipeline's traffic which round-trips the sorted copy
    and the hidden between stages."""
    np_rows, d, f, nm, isz = 2560, 256, 512, 20, 4
    c = esffn_cost(np_rows, d, f, nm, isz, glu=True)
    rows_io = 2 * np_rows * d * isz
    w_tiles = nm * 3 * d * f * isz
    gate = np_rows * 4
    assert c.bytes_accessed == rows_io + w_tiles + gate
    # doubling F must grow bytes only via the weight tiles, never via an
    # Np*F activation term
    c2 = esffn_cost(np_rows, d, 2 * f, nm, isz, glu=True)
    assert c2.bytes_accessed - c.bytes_accessed == w_tiles
    # and the unfused composition's extra inter-stage HBM traffic (hidden
    # g/u write+read + sorted-copy write+read) is strictly additional
    hidden_roundtrip = 2 * 2 * np_rows * f * isz
    sorted_roundtrip = 2 * np_rows * d * isz
    assert c.bytes_accessed < (
        rows_io + w_tiles + gate + hidden_roundtrip + sorted_roundtrip
    )
    # flops/transcendentals sanity: 3 matmuls + one activation sweep
    assert c.flops == 3 * 2 * np_rows * d * f
    assert c.transcendentals == np_rows * f


def test_default_fused_on_for_pallas_only():
    assert ops.default_fused_ffn("pallas") is True
    assert ops.default_fused_ffn("blocked") is False
    assert ops.default_fused_ffn("ragged") is False
    assert ops.default_fused_ffn("ref") is False


def test_autotune_unfused_bytes_shift_crossover():
    """The roofline's unfused activation round-trips inflate the token-
    proportional side: the data-centric crossover must move (weakly) later,
    and latencies never shrink."""
    from repro.parallel import autotune

    d, f, e, k = 1024, 4096, 8, 2
    for tokens in (2 ** i for i in range(4, 18)):
        for mode in ("model_centric", "data_centric"):
            fused = autotune.layer_latency(mode, tokens, d, f, e, k, 16)
            unfused = autotune.layer_latency(
                mode, tokens, d, f, e, k, 16, fused_ffn=False
            )
            assert unfused >= fused
    cf = autotune.crossover_tokens(d, f, e, k, n_dev=16)
    cu = autotune.crossover_tokens(d, f, e, k, n_dev=16, fused_ffn=False)
    assert cf is not None and cu is not None
    assert cu >= cf
