"""runtime/ft.py unit tests (ISSUE 7): sliding-window failure budget with
deterministic backoff, signal-handler restoration on every exit path,
GC-after-write ordering, fallback restore past corrupt checkpoints, and the
train.loss / train.preempt fault sites."""
import os
import signal

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.runtime import faults as faults_lib
from repro.runtime import ft as ft_lib


# ---------------------------------------------------------------------------
# FailureBudget
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_failure_budget_sliding_window():
    clk = _Clock()
    b = ft_lib.FailureBudget(2, window_s=100.0, clock=clk)
    b.record()
    clk.t = 10.0
    b.record()
    assert not b.exhausted          # 2 failures == budget, not over it
    clk.t = 20.0
    b.record()
    assert b.exhausted              # 3 in 20s > 2
    # the window slides: the first two age out, only one recent remains
    clk.t = 115.0
    assert not b.exhausted
    assert len(b.stamps) == 1


def test_failure_budget_lifetime_failures_do_not_accumulate():
    """The old lifetime counter killed any long job with sparse noise; the
    window must tolerate arbitrarily many failures if they are spread out."""
    clk = _Clock()
    b = ft_lib.FailureBudget(2, window_s=10.0, clock=clk)
    for i in range(50):
        clk.t = i * 100.0
        b.record()
        assert not b.exhausted


def test_failure_budget_backoff_exponential_and_deterministic():
    clk = _Clock()
    b1 = ft_lib.FailureBudget(10, 1e9, base_s=0.1, max_s=1.0, seed=7,
                              clock=clk)
    b2 = ft_lib.FailureBudget(10, 1e9, base_s=0.1, max_s=1.0, seed=7,
                              clock=clk)
    seq1 = [b1.record() for _ in range(6)]
    seq2 = [b2.record() for _ in range(6)]
    assert seq1 == seq2             # deterministic jitter: same seed, same run
    # exponential base under the jitter (jitter is in [0, 0.25) * backoff)
    for n, got in enumerate(seq1, start=1):
        base = min(0.1 * 2 ** (n - 1), 1.0)
        assert base <= got < base * 1.25
    assert seq1[-1] < 1.0 * 1.25    # capped at max_s


# ---------------------------------------------------------------------------
# run_with_recovery
# ---------------------------------------------------------------------------

def _step_fn(state, step):
    """Deterministic synthetic step: resume-from-checkpoint replays the
    exact same trajectory (x' = x + step + 1)."""
    return ({"x": state["x"] + jnp.float32(step + 1)},
            {"loss": float(step)})


def _run(tmp_path, *, step_fn=_step_fn, steps=6, save_every=2, keep=3,
         state=None, start=0, **kw):
    ft = ft_lib.FTConfig(ckpt_dir=str(tmp_path), save_every=save_every,
                         keep=keep, max_failures=3, backoff_base_s=0.0)
    return ft_lib.run_with_recovery(
        state=state if state is not None else {"x": jnp.float32(0.0)},
        step_fn=step_fn, start_step=start, num_steps=steps, ft=ft,
        sleep_fn=lambda s: None, **kw)


def test_run_to_completion_and_gc_after_write(tmp_path):
    state, last = _run(tmp_path, steps=10, save_every=1, keep=2)
    assert last == 10
    assert float(state["x"]) == sum(range(1, 11))
    # GC ordered after each write lands: exactly `keep` checkpoints
    # remain and they are the NEWEST two (the old pre-write GC raced the
    # async save and computed retention against a stale listing).
    left = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert left == ["step_00000009", "step_00000010"]


def test_signal_handlers_restored_on_every_exit_path(tmp_path):
    orig_term = signal.getsignal(signal.SIGTERM)
    orig_int = signal.getsignal(signal.SIGINT)
    _run(tmp_path)                                      # clean exit
    assert signal.getsignal(signal.SIGTERM) is orig_term

    def bad(state, step):
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError):                   # raise exit
        _run(tmp_path / "b", step_fn=bad)
    assert signal.getsignal(signal.SIGTERM) is orig_term
    assert signal.getsignal(signal.SIGINT) is orig_int


def test_restore_on_failure_resumes_bit_exact(tmp_path):
    ref_state, _ = _run(tmp_path / "ref", steps=8, save_every=2)

    fails = {"n": 0}

    def flaky(state, step):
        if step == 5 and fails["n"] < 2:
            fails["n"] += 1
            raise RuntimeError("injected device error")
        return _step_fn(state, step)

    state, last = _run(tmp_path / "chaos", step_fn=flaky, steps=8,
                       save_every=2)
    assert last == 8 and fails["n"] == 2
    np.testing.assert_array_equal(np.asarray(state["x"]),
                                  np.asarray(ref_state["x"]))


def test_fallback_restore_skips_corrupt_newest(tmp_path):
    """The acceptance scenario in miniature: the newest checkpoint is
    corrupt when a failure hits, so recovery must fall back to the older
    valid one and still converge to the unfaulted trajectory."""
    ref_state, _ = _run(tmp_path / "ref", steps=8, save_every=2)

    d = tmp_path / "chaos"
    corrupted = {"done": False}

    def flaky(state, step):
        if step == 5 and not corrupted["done"]:
            corrupted["done"] = True
            # newest checkpoint (step 4) is damaged at failure time
            p = d / "step_00000004" / "a_00000.npy"
            p.write_bytes(p.read_bytes()[: 8])
            raise RuntimeError("injected device error")
        return _step_fn(state, step)

    state, last = _run(d, step_fn=flaky, steps=8, save_every=2)
    assert last == 8
    np.testing.assert_array_equal(np.asarray(state["x"]),
                                  np.asarray(ref_state["x"]))
    # it really did restore from step 2, not the corrupt step 4
    assert ckpt.latest_valid_step(str(d)) is not None


def test_budget_exhaustion_reraises(tmp_path):
    def always_bad(state, step):
        raise RuntimeError("permafail")

    with pytest.raises(RuntimeError, match="first valid checkpoint"):
        _run(tmp_path, step_fn=always_bad)


def test_nan_loss_fault_site_triggers_restore(tmp_path):
    plan = faults_lib.FaultPlan([
        faults_lib.Fault(site="train.loss", kind="nan", at=5),
    ])
    with faults_lib.scope(plan):
        state, last = _run(tmp_path, steps=8, save_every=2)
    assert last == 8
    assert plan.fired == [("train.loss", 5, "nan")]
    ref_state, _ = _run(tmp_path / "ref", steps=8, save_every=2)
    np.testing.assert_array_equal(np.asarray(state["x"]),
                                  np.asarray(ref_state["x"]))


def test_preempt_fault_site_saves_and_exits(tmp_path):
    plan = faults_lib.FaultPlan([
        faults_lib.Fault(site="train.preempt", kind="preempt", at=3),
    ])
    with faults_lib.scope(plan):
        state, last = _run(tmp_path, steps=100, save_every=50)
    assert last == 4                # stopped right after the flagged step
    # the preemption checkpoint landed and is restorable
    s = ckpt.latest_valid_step(str(tmp_path))
    restored, meta = ckpt.restore(str(tmp_path), s, state)
    assert int(meta["step"]) == 4
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.asarray(state["x"]))


def test_device_loss_routes_to_elastic_handler(tmp_path):
    plan = faults_lib.FaultPlan([
        faults_lib.Fault(site="train.step", kind="device_drop", at=5,
                         payload={"survivors": 2}),
    ])
    calls = []

    def flaky(state, step):
        faults_lib.inject("train.step")
        return _step_fn(state, step)

    def on_loss(err):
        calls.append(err.survivors)
        return {"x": jnp.float32(0.0)}, None

    with faults_lib.scope(plan):
        state, last = _run(tmp_path, step_fn=flaky, steps=8, save_every=2,
                           on_device_loss=on_loss)
    assert calls == [2] and last == 8
    ref_state, _ = _run(tmp_path / "ref", steps=8, save_every=2)
    np.testing.assert_array_equal(np.asarray(state["x"]),
                                  np.asarray(ref_state["x"]))


def test_device_loss_without_handler_is_fatal(tmp_path):
    plan = faults_lib.FaultPlan([
        faults_lib.Fault(site="train.step", kind="device_drop", at=1),
    ])

    def flaky(state, step):
        faults_lib.inject("train.step")
        return _step_fn(state, step)

    with faults_lib.scope(plan), \
            pytest.raises(faults_lib.DeviceLostError):
        _run(tmp_path, step_fn=flaky)
