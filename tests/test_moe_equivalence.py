"""The central correctness claim: the expert-specific (Hexa) path computes
EXACTLY what per-token expert evaluation computes — forward and gradients —
for every impl, both expert body types, fused and unfused backward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, espec
from repro.core.reindex import build_reindex
from repro.core.routing import route
from repro.kernels import ops, ref


def _params(e, d, f, glu, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    p = {"router": jax.random.normal(ks[0], (d, e)) * 0.2}
    if glu:
        p["w_gate"] = jax.random.normal(ks[1], (e, d, f)) * 0.2
        p["w_up"] = jax.random.normal(ks[2], (e, d, f)) * 0.2
        p["w_down"] = jax.random.normal(ks[3], (e, f, d)) * 0.2
    else:
        p["w1"] = jax.random.normal(ks[1], (e, d, f)) * 0.2
        p["b1"] = jax.random.normal(ks[4], (e, f)) * 0.2
        p["w2"] = jax.random.normal(ks[2], (e, f, d)) * 0.2
        p["b2"] = jax.random.normal(ks[5], (e, d)) * 0.2
    return p


N, D, F, E, K, BLK = 48, 16, 24, 4, 2, 8


@pytest.mark.parametrize("impl", ["ragged", "blocked", "pallas", "ref"])
@pytest.mark.parametrize("glu", [True, False])
def test_forward_matches_per_token_oracle(impl, glu):
    p = _params(E, D, F, glu)
    x = jax.random.normal(jax.random.PRNGKey(9), (N, D))
    out = espec.hexa_moe_ffn(
        x, p, num_experts=E, top_k=K, act="gelu" if not glu else "silu",
        glu=glu, blk=BLK, impl=impl,
    )
    r = route(x, p["router"], K)
    if glu:
        oracle = ref.moe_ffn_per_token(
            x, r.expert_idx, r.gates,
            p["w_gate"], jnp.zeros((E, F)), p["w_down"], jnp.zeros((E, D)),
            lambda h: jax.nn.silu(h),
        )
        # glu oracle needs the up-projection too: compute directly
        def token_fn(xt, et, gt):
            def slot(e):
                return (jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
                        ) @ p["w_down"][e]
            ys = jax.vmap(slot)(et)
            return jnp.sum(ys * gt[:, None], axis=0)
        oracle = jax.vmap(token_fn)(x, r.expert_idx, r.gates)
    else:
        oracle = ref.moe_ffn_per_token(
            x, r.expert_idx, r.gates, p["w1"], p["b1"], p["w2"], p["b2"],
            jax.nn.gelu,
        )
    np.testing.assert_allclose(
        np.asarray(out.y), np.asarray(oracle), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("impl", ["ragged", "blocked", "pallas"])
@pytest.mark.parametrize("fused", [True, False])
def test_gradients_match_oracle(impl, fused):
    glu = False
    p = _params(E, D, F, glu)
    x = jax.random.normal(jax.random.PRNGKey(7), (N, D))
    tgt = jax.random.normal(jax.random.PRNGKey(8), (N, D))

    ops.set_fused_backward(fused)
    try:
        def loss_hexa(p):
            out = espec.hexa_moe_ffn(
                x, p, num_experts=E, top_k=K, act="gelu", glu=glu,
                blk=BLK, impl=impl,
            )
            return jnp.sum((out.y - tgt) ** 2)

        def loss_oracle(p):
            r = route(x, p["router"], K)
            y = ref.moe_ffn_per_token(
                x, r.expert_idx, r.gates, p["w1"], p["b1"], p["w2"], p["b2"],
                jax.nn.gelu,
            )
            return jnp.sum((y - tgt) ** 2)

        g1 = jax.grad(loss_hexa)(p)
        g2 = jax.grad(loss_oracle)(p)
        for k in g1:
            np.testing.assert_allclose(
                np.asarray(g1[k]), np.asarray(g2[k]),
                rtol=5e-4, atol=5e-4, err_msg=f"{impl} fused={fused} {k}",
            )
    finally:
        ops.set_fused_backward(True)


def test_hexa_equals_no_drop_dispatch():
    """dispatch/combine with infinite capacity == hexa exactly."""
    p = _params(E, D, F, glu=False, seed=3)
    x = jax.random.normal(jax.random.PRNGKey(4), (N, D))
    r = route(x, p["router"], K)
    out = espec.hexa_moe_ffn(
        x, p, num_experts=E, top_k=K, act="gelu", glu=False, blk=BLK,
        impl="ragged",
    )
    base = baselines.grouped_dense_moe(
        x, r, p["w1"], p["b1"], p["w2"], p["b2"], act=jax.nn.gelu
    )
    np.testing.assert_allclose(
        np.asarray(out.y), np.asarray(base), rtol=2e-5, atol=2e-5
    )


def test_dispatch_capacity_drops_tokens():
    """Tiny capacity must change (degrade) the result — the redundancy /
    quality trade the paper eliminates."""
    p = _params(E, D, F, glu=False, seed=5)
    x = jax.random.normal(jax.random.PRNGKey(6), (N, D))
    r = route(x, p["router"], K)
    full = baselines.grouped_dense_moe(
        x, r, p["w1"], p["b1"], p["w2"], p["b2"], act=jax.nn.gelu
    )
    tight = baselines.dispatch_combine_moe(
        x, r, p["w1"], p["b1"], p["w2"], p["b2"], act=jax.nn.gelu,
        capacity=2,
    )
    assert np.abs(np.asarray(full) - np.asarray(tight)).max() > 1e-3
