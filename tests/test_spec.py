"""Speculative-decoding unit suite (ISSUE 9, DESIGN.md §11): the n-gram
drafter's matching rules, the multi-token score step against sequential
scoring, rollback accounting (pool reservations + device length), the
greedy tie-breaking convention shared by every engine, and the autotune
verify-cost model. End-to-end stream parity lives in
tests/test_serve_parity.py; the PagePool rollback op is also driven by the
structural oracle in tests/test_page_refcount.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.launch import serve, spec as spec_lib, steps as steps_lib
from repro.models import lm
from repro.parallel import autotune
from repro.parallel.cache import PagePool
from repro.parallel.sharding import ParallelConfig, split_tree


# --- n-gram drafter ------------------------------------------------------

def test_ngram_drafts_most_recent_continuation():
    d = spec_lib.NGramDrafter(n=2)
    # trailing bigram (7, 8) occurred twice; the MOST RECENT prior
    # occurrence (index 4) is the one whose continuation is proposed
    h = np.array([7, 8, 1, 2, 7, 8, 3, 4, 7, 8])
    assert d.draft(h, 3) == [3, 4, 7]


def test_ngram_prefers_longest_suffix_match():
    d = spec_lib.NGramDrafter(n=3)
    # trigram (1, 2, 3) matches at the start -> continuation [9];
    # a unigram match of (3,) alone would have proposed [5]
    h = np.array([1, 2, 3, 9, 3, 5, 1, 2, 3])
    assert d.draft(h, 2) == [9, 3]


def test_ngram_falls_back_to_shorter_orders():
    d = spec_lib.NGramDrafter(n=3)
    # no trigram/bigram repeats, but token 4 recurs -> unigram fallback
    h = np.array([4, 1, 2, 4])
    assert d.draft(h, 2) == [1, 2]


def test_ngram_empty_without_repetition_and_caps_k():
    d = spec_lib.NGramDrafter(n=3)
    assert d.draft(np.array([1, 2, 3, 4, 5]), 4) == []
    # constant stream: the adjacent occurrence's continuation is cut off
    # by the end of history, so an earlier one supplies the full k
    assert d.draft(np.array([6, 6, 6, 6, 6]), 2) == [6, 6]
    assert d.draft(np.array([6, 6]), 3) == [6]      # longest available
    assert d.draft(np.array([1, 2]), 0) == []
    with pytest.raises(ValueError):
        spec_lib.NGramDrafter(n=0)


# --- multi-token score step vs sequential scoring ------------------------

def _paged_fixture(arch="gemma-2b"):
    cfg = dataclasses.replace(cfglib.get_smoke_config(arch),
                              dtype="float32")
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    return cfg, pcfg, params


def test_score_step_matches_sequential_rows():
    """Scoring k tokens in ONE chunk forward yields, at every position,
    the same logits as k one-token score steps — the property that makes
    exact-match verification equivalent to sequential decode."""
    cfg, pcfg, params = _paged_fixture()
    page = 4
    n_tok, n_pages = 8, 4
    tokens = np.arange(1, n_tok + 1, dtype=np.int32) % cfg.vocab_size

    def fresh():
        cache = lm.init_paged_cache(cfg, num_slots=1, num_pages=1 + n_pages,
                                    page_size=page)
        table = np.zeros((8,), np.int32)
        table[:n_pages] = np.arange(1, n_pages + 1)
        return cache, jnp.asarray(table)

    batched = jax.jit(steps_lib.make_paged_score_step(
        cfg, pcfg, None, page))
    cache, table = fresh()
    all_rows, cache = batched(params, jnp.asarray(tokens),
                              jnp.int32(n_tok), jnp.int32(0), table, cache)
    assert all_rows.shape == (n_tok, cfg.vocab_size)
    assert int(cache["len"][0]) == n_tok

    one = jax.jit(steps_lib.make_paged_score_step(cfg, pcfg, None, page))
    cache, table = fresh()
    seq_rows = []
    for t in tokens:
        row, cache = one(params, jnp.asarray([t], jnp.int32),
                         jnp.int32(1), jnp.int32(0), table, cache)
        seq_rows.append(np.asarray(row[0]))
    np.testing.assert_allclose(np.asarray(all_rows), np.stack(seq_rows),
                               rtol=2e-5, atol=2e-5)


def test_score_step_padded_tail_is_inert():
    """Rows at and past n_valid are sink-written padding: they advance
    nothing and leave the valid rows' logits untouched."""
    cfg, pcfg, params = _paged_fixture()
    page = 4

    def run(width, n_valid):
        cache = lm.init_paged_cache(cfg, num_slots=1, num_pages=5,
                                    page_size=page)
        table = jnp.asarray(np.array([1, 2, 3, 4, 0, 0, 0, 0], np.int32))
        toks = np.zeros((width,), np.int32)
        toks[:n_valid] = np.arange(1, n_valid + 1)
        step = jax.jit(steps_lib.make_paged_score_step(cfg, pcfg, None,
                                                       page))
        rows, cache = step(params, jnp.asarray(toks), jnp.int32(n_valid),
                           jnp.int32(0), table, cache)
        return np.asarray(rows[:n_valid]), int(cache["len"][0])

    exact, len_exact = run(3, 3)
    padded, len_padded = run(8, 3)
    assert len_exact == len_padded == 3
    np.testing.assert_allclose(exact, padded, rtol=2e-5, atol=2e-5)


def test_score_step_rejects_recurrent_stack():
    cfg = dataclasses.replace(
        cfglib.get_smoke_config("jamba-1.5-large-398b"), dtype="float32")
    with pytest.raises(ValueError, match="all-attention"):
        steps_lib.make_paged_score_step(cfg, ParallelConfig(blk=8), None, 4)


# --- greedy tie-breaking convention (the parity bugfix) ------------------

def test_greedy_tie_break_is_lowest_index_in_f32():
    """Regression for the next_token/_greedy divergence: a two-way-tied
    bf16 row must argmax to the LOWEST index under every entry point —
    the single f32-upcast device convention (DESIGN.md §11)."""
    row = np.full((16,), -3.0, np.float32)
    row[5] = 1.0
    row[11] = 1.0
    bf16_row = jnp.asarray(row).astype(jnp.bfloat16)
    assert float(bf16_row[5]) == float(bf16_row[11]), "tie not constructed"

    req = serve.Request(rid=0, prompt=np.array([1]), max_new=1)
    assert serve.argmax_token(bf16_row) == 5
    assert serve.next_token(bf16_row, req) == 5
    batch = serve._greedy(bf16_row[None, None, :])
    assert batch.tolist() == [5]


def test_greedy_convention_upcasts_before_comparing():
    """f32-first ordering: values that are DISTINCT in f32 but collapse to
    a tie in bf16 must still resolve to the lowest index consistently in
    both the scalar and batch helpers — comparing at different precisions
    between engines is exactly the bug the shared convention kills."""
    row = np.zeros((8,), np.float32)
    row[2] = 1.0
    row[6] = 1.0 + 1e-4          # > row[2] in f32 ...
    bf16_row = jnp.asarray(row).astype(jnp.bfloat16)
    assert float(bf16_row[2]) == float(bf16_row[6])   # ... tied in bf16
    # the convention operates on what the engine HAS (the bf16 row): both
    # entry points must agree on the same index
    assert serve.argmax_token(bf16_row) == int(
        serve._greedy(bf16_row[None, None, :])[0]) == 2
    # and on the original f32 row both pick the true max
    assert serve.argmax_token(row) == int(
        serve._greedy(jnp.asarray(row)[None, None, :])[0]) == 6


# --- rollback accounting -------------------------------------------------

def test_pool_rollback_returns_pages_to_reservation():
    pool = PagePool(num_pages=9, page_bytes=1)
    assert pool.try_reserve(4, 0)
    pages = [pool.alloc(0) for _ in range(3)]
    free0, res0, use0 = pool._free[0], pool._reserved[0], pool._in_use[0]
    pool.rollback(pages[-2:], 0)
    # in_use -> reserved; the FREE budget must NOT change (a live request
    # keeps its admission guarantee, other admissions can't steal it)
    assert pool._free[0] == free0
    assert pool._reserved[0] == res0 + 2
    assert pool._in_use[0] == use0 - 2
    assert pool.refcount(pages[-1]) == 0
    pool.assert_consistent()
    # the reservation is re-allocatable and drains cleanly
    again = [pool.alloc(0), pool.alloc(0)]
    pool.release([pages[0]] + again, 0, unused_reserved=1)
    pool.assert_consistent()
    assert pool.free_pages == sum(pool.shares)
    assert pool.stats()["total_rollbacks"] == 2


def test_pool_rollback_refuses_shared_and_foreign_pages():
    pool = PagePool(num_pages=9, page_bytes=1, shares=[4, 4])
    assert pool.try_reserve(2, 0) and pool.try_reserve(1, 1)
    mine = pool.alloc(0)
    shared = pool.alloc(0)
    pool.fork([shared])                      # refcount 2: prefix-shared
    theirs = pool.alloc(1)
    with pytest.raises(RuntimeError, match="refcount"):
        pool.rollback([shared], 0)
    with pytest.raises(RuntimeError, match="owned by group"):
        pool.rollback([theirs], 0)
    with pytest.raises(RuntimeError, match="refcount"):
        pool.rollback([8], 0)                # free page
    with pytest.raises(ValueError):
        pool.rollback([0], 0)                # the sink
    pool.assert_consistent()                 # guards fired BEFORE mutation
    pool.rollback([mine], 0)
    pool.release([shared], 0)
    pool.release([shared], 0)
    pool.release([theirs], 1)
    pool.release([], 0, unused_reserved=1)
    pool.assert_consistent()
    assert pool.free_pages == sum(pool.shares)


def test_rollback_slot_truncates_len_and_rejects_recurrent():
    cfg, _, _ = _paged_fixture()
    cache = lm.init_paged_cache(cfg, num_slots=2, num_pages=5, page_size=4)
    cache = {"layers": cache["layers"],
             "len": cache["len"].at[1].set(jnp.int32(9))}
    cache = lm.rollback_slot(cfg, cache, 1, 6)
    assert int(cache["len"][1]) == 6 and int(cache["len"][0]) == 0
    with pytest.raises(ValueError):
        lm.rollback_slot(cfg, cache, 1, -1)
    jcfg = dataclasses.replace(
        cfglib.get_smoke_config("jamba-1.5-large-398b"), dtype="float32")
    jcache = lm.init_paged_cache(jcfg, num_slots=2, num_pages=5,
                                 page_size=4)
    with pytest.raises(ValueError, match="all-attention"):
        lm.rollback_slot(jcfg, jcache, 0, 2)


def test_spec_decoder_validates_construction():
    cfg, pcfg, params = _paged_fixture()
    server = serve.PagedServer(
        cfg, pcfg, None, num_slots=2, page_size=4, num_pages=17,
        max_pages_per_slot=8, params=params)
    with pytest.raises(ValueError, match="k must be"):
        spec_lib.SpecDecoder(server, spec_lib.NGramDrafter(), k=0)
    assert server.spec is None
    dec = spec_lib.SpecDecoder(server, spec_lib.NGramDrafter(), k=3)
    assert server.spec is dec and dec.chunk == 4


def test_model_drafter_rejects_unsafe_configs():
    """Rolling-buffer windowed caches and recurrent stacks cannot truncate
    their draft rows away — the drafter must refuse them."""
    pcfg = ParallelConfig(blk=8)
    windowed = dataclasses.replace(cfglib.get_smoke_config("mixtral-8x7b"),
                                   dtype="float32")
    with pytest.raises(ValueError, match="non-windowed"):
        spec_lib.ModelDrafter(windowed, pcfg, None, {}, max_seq=32)
    hybrid = dataclasses.replace(
        cfglib.get_smoke_config("jamba-1.5-large-398b"), dtype="float32")
    with pytest.raises(ValueError, match="all-attention"):
        spec_lib.ModelDrafter(hybrid, pcfg, None, {}, max_seq=32)


def test_model_drafter_drafts_its_own_greedy_stream():
    """The drafter's k-token proposal equals the draft model's own
    sequential greedy continuation (same argmax convention), across
    rounds with intervening accepted tokens, and truncation keeps the
    cache consistent; drop() frees the per-request state."""
    cfg, pcfg, params = _paged_fixture()
    drafter = spec_lib.ModelDrafter(cfg, pcfg, None, params, max_seq=32)
    hist = np.array([3, 1, 4, 1, 5], np.int32)
    ref = serve.greedy_reference(cfg, pcfg, None, params, hist, 6,
                                 max_seq=32)
    assert drafter.draft(hist, 3, rid=7) == ref[:3]
    # target accepted 2 of them plus its own sample; catch-up must resume
    hist2 = np.concatenate([hist, np.asarray(ref[:3], np.int32)])
    assert drafter.draft(hist2, 3, rid=7) == ref[3:6]
    # capacity clamp: 1 row left -> 1-token draft; 0 left -> refuse
    assert len(drafter.draft(np.arange(31, dtype=np.int32), 4, rid=8)) == 1
    assert drafter.draft(np.arange(32, dtype=np.int32), 4, rid=9) == [], (
        "draft must refuse to overrun its cache capacity")
    drafter.drop(7)
    drafter.drop(7)   # idempotent
    assert 7 not in drafter._state


# --- autotune verify-cost model ------------------------------------------

def test_expected_verify_tokens_bounds_and_monotonicity():
    assert autotune.expected_verify_tokens(0.0, 5) == 1.0
    assert autotune.expected_verify_tokens(1.0, 5) == 6.0
    assert autotune.expected_verify_tokens(0.5, 0) == 1.0
    vals = [autotune.expected_verify_tokens(a, 4)
            for a in (0.0, 0.3, 0.6, 0.9, 1.0)]
    assert all(b > a for a, b in zip(vals, vals[1:]))
    ks = [autotune.expected_verify_tokens(0.8, k) for k in range(5)]
    assert all(b > a for a, b in zip(ks, ks[1:]))
    with pytest.raises(ValueError):
        autotune.expected_verify_tokens(1.5, 3)
    with pytest.raises(ValueError):
        autotune.expected_verify_tokens(0.5, -1)


def test_spec_verify_latency_sublinear_in_memory_bound_regime():
    """Decode is weight-bound: scoring k+1 rows must cost far less than
    k+1 decode steps (that gap IS the speculative win), and the verify
    latency is monotone in the token count."""
    shape = dict(d=4096, f=14336, e=8, k=2)
    dec = autotune.spec_verify_latency(1, **shape)
    ver8 = autotune.spec_verify_latency(8, **shape)
    assert ver8 < 8 * dec * 0.5, (ver8, dec)
    lats = [autotune.spec_verify_latency(n, **shape) for n in (1, 4, 16, 64)]
    assert all(b >= a for a, b in zip(lats, lats[1:]))


def test_spec_decode_speedup_behaviour():
    """>1 with a decent drafter in the memory-bound regime; degrades
    toward the no-draft floor at acceptance 0; improves with acceptance."""
    shape = dict(d=4096, f=14336, e=8, k=2)
    good = autotune.spec_decode_speedup(0.8, 4, **shape)
    none = autotune.spec_decode_speedup(0.0, 4, **shape)
    assert good > 1.5, good
    assert none <= 1.0 + 1e-9, none
    sweep = [autotune.spec_decode_speedup(a, 4, **shape)
             for a in (0.0, 0.4, 0.8, 1.0)]
    assert all(b > a for a, b in zip(sweep, sweep[1:]))


# --- engine-level rollback accounting (pages + reservation) --------------

def test_engine_rollback_restores_pages_and_reservation():
    """Drive one slot to a speculative length crossing a page boundary,
    roll back, and check: tail pages freed, reservation restored, table
    zeroed, device len truncated, audit oracle clean."""
    cfg, pcfg, params = _paged_fixture()
    server = serve.PagedServer(
        cfg, pcfg, None, num_slots=1, page_size=4, num_pages=17,
        max_pages_per_slot=8, params=params, prefill_chunk=4)
    req = serve.Request(rid=0, prompt=np.arange(6, dtype=np.int32),
                        max_new=12)
    server.submit(req)
    server._admit()
    done = []
    while server.slots[0].pos < len(req.prompt):
        server._prefill_tick(done)
    st = server.slots[0]
    base_len = st.length
    n_pages = len(st.pages)
    # speculative grant of 5 rows (crosses a page boundary), then reject 4
    step = jax.jit(steps_lib.make_paged_score_step(cfg, pcfg, None, 4))
    server._ensure_pages(0, st, st.length + 5)
    toks = np.asarray([req.out[-1], 1, 2, 3, 4], np.int32)
    _, server.cache = step(server.params, jnp.asarray(toks), jnp.int32(5),
                           jnp.int32(0), jnp.asarray(server.table[0]),
                           server.cache)
    st.length += 5
    assert len(st.pages) > n_pages
    grew = len(st.pages) - n_pages
    res_before = server.pool._reserved[st.group]
    server._rollback(0, 4)
    assert st.length == base_len + 1
    assert len(st.pages) == serve.cdiv(st.length, 4)
    assert int(server.cache["len"][0]) == st.length
    assert server.pool._reserved[st.group] == res_before + grew, (
        "rolled-back pages must return to the slot's reservation")
    assert (server.table[0, len(st.pages):] == 0).all()
    server.assert_page_invariants()
    # the request can still grow back to its admitted worst case
    server._ensure_pages(0, st, st.length + 4)
    server.assert_page_invariants()
    server._finish(0, st, done)
    server.pool.assert_consistent()
    assert server.pool.free_pages == sum(server.pool.shares)
