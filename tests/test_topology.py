"""Property/parity layer for the two-level interconnect cost model
(parallel.autotune.Topology, DESIGN.md §10).

Four pinned properties:

  (1) flat degeneracy — a topology whose node holds the whole group prices
      every collective with the SAME EXPRESSION as the topology-less
      roofline, so ``Topology(intra_bw=hw.link_bw, ...)`` is bitwise equal
      to today's ``layer_latency``/``choose_mode``/``crossover_tokens``;
  (2) latency monotone in the inter-node traffic: more tokens never
      cheapens a collective, and shrinking ``inter_bw`` never speeds one up;
  (3) the crossover moves the right way: as ``inter_bw/intra_bw`` shrinks,
      data-centric's per-node weight staging amortises the slow links and
      the model->data crossover moves to FEWER (never more) tokens;
  (4) hierarchical dispatch crosses nodes with <= the flat schedule's
      bytes for every (top_k, node_size) — the Bernoulli overlap factor
      ``(nn-1)(1-(1-1/nn)^k) <= k(nn-1)/nn``, and the staged hierarchical
      schedule's inter-node share of ``moe_coll_bytes`` <= the flat ring's.

Each property runs over a deterministic grid (so the module passes with or
without hypothesis installed); with hypothesis present the same checks are
additionally driven over sampled shapes.
"""
import dataclasses
import itertools

import pytest

from repro.parallel import autotune as at
from repro.parallel.autotune import (
    Topology,
    V5E,
    choose_mode,
    crossover_tokens,
    dispatch_inter_bytes,
    layer_latency,
    layer_latency_uneven,
    moe_coll_bytes,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic grid still runs
    HAVE_HYPOTHESIS = False

MODES = ("model_centric", "data_centric")
SHAPES = [  # (d, f, e, k)
    (64, 256, 4, 2),
    (1024, 4096, 16, 2),
    (2048, 768, 64, 8),
]


# ---------------------------------------------------------------- helpers
# (shared by the grid tests and the hypothesis drivers)

def check_flat_degenerate(d, f, e, k, n_dev, tokens):
    """Flat topology (single node) == topology-less pricing, bitwise."""
    flat = Topology(intra_bw=V5E.link_bw, inter_bw=1.0, node_size=n_dev)
    hw = dataclasses.replace(V5E, topology=flat)
    assert flat.is_flat(n_dev)
    for mode in MODES:
        a = layer_latency(mode, tokens, d, f, e, k, n_dev)
        b = layer_latency(mode, tokens, d, f, e, k, n_dev, hw)
        assert a == b, (mode, tokens, a, b)  # bitwise, not allclose
    assert (choose_mode(tokens, d, f, e, k, n_dev=n_dev)
            == choose_mode(tokens, d, f, e, k, n_dev=n_dev, hw=hw))
    assert (crossover_tokens(d, f, e, k, n_dev=n_dev)
            == crossover_tokens(d, f, e, k, n_dev=n_dev, hw=hw))


def check_monotone(d, f, e, k, n_dev, topo):
    """Coll cost non-decreasing in tokens; non-increasing in inter_bw."""
    hw = dataclasses.replace(V5E, topology=topo)
    for mode in MODES:
        lats = [layer_latency(mode, t, d, f, e, k, n_dev, hw)
                for t in (2 ** i for i in range(4, 16))]
        assert all(b >= a for a, b in zip(lats, lats[1:])), (mode, lats)
    slower = dataclasses.replace(
        V5E, topology=dataclasses.replace(topo, inter_bw=topo.inter_bw / 4))
    for mode in MODES:
        for t in (64, 4096, 65536):
            assert (layer_latency(mode, t, d, f, e, k, n_dev, slower)
                    >= layer_latency(mode, t, d, f, e, k, n_dev, hw)), mode


def check_crossover_shift(d, f, e, k, n_dev, node_size):
    """crossover(slow inter) <= crossover(fast inter): data-centric wins
    earlier as the cross-node fabric degrades."""
    prev = None
    for inter in (50e9, 12.5e9, 3e9, 1e9):
        topo = Topology(intra_bw=50e9, inter_bw=inter, node_size=node_size)
        hw = dataclasses.replace(V5E, topology=topo)
        co = crossover_tokens(d, f, e, k, n_dev=n_dev, hw=hw)
        if co is not None and prev is not None:
            assert co <= prev, (inter, co, prev)
        if co is not None:
            prev = co


def check_dispatch_bytes(tokens, d, k, n_dev, node_size):
    """Hierarchical dispatch's expected inter-node bytes <= flat's, and the
    staged schedule's inter share of moe_coll_bytes <= the flat ring's."""
    hier = dispatch_inter_bytes(tokens, d, k, n_dev=n_dev,
                                node_size=node_size, hierarchical=True)
    flat = dispatch_inter_bytes(tokens, d, k, n_dev=n_dev,
                                node_size=node_size, hierarchical=False)
    assert 0.0 <= hier <= flat + 1e-9, (hier, flat)
    topo = Topology(intra_bw=50e9, inter_bw=12.5e9, node_size=node_size)
    for mode in MODES:
        _, inter_h = moe_coll_bytes(mode, tokens, d, 4 * d, 8, k,
                                    n_dev=n_dev, topology=topo,
                                    hierarchical=True)
        _, inter_f = moe_coll_bytes(mode, tokens, d, 4 * d, 8, k,
                                    n_dev=n_dev, topology=topo,
                                    hierarchical=False)
        assert inter_h <= inter_f + 1e-9, (mode, inter_h, inter_f)


# ---------------------------------------------------------------- the grid

@pytest.mark.parametrize("d,f,e,k", SHAPES)
def test_flat_topology_bitwise_degenerate(d, f, e, k):
    for n_dev in (2, 4, 8, 16):
        for tokens in (16, 1024, 65536):
            check_flat_degenerate(d, f, e, k, n_dev, tokens)


def test_single_device_and_parse_and_validation():
    t = Topology.parse("50e9:12.5e9:4")
    assert t == Topology(intra_bw=50e9, inter_bw=12.5e9, node_size=4)
    assert t.n_nodes(8) == 2 and t.n_nodes(4) == 1
    assert t.is_flat(4) and not t.is_flat(5)
    with pytest.raises(ValueError):
        Topology.parse("50e9:12.5e9")
    with pytest.raises(ValueError):
        Topology(intra_bw=-1.0)
    with pytest.raises(ValueError):
        Topology(node_size=0)


@pytest.mark.parametrize("d,f,e,k", SHAPES)
def test_latency_monotone_in_inter_bytes(d, f, e, k):
    for n_dev, ns in ((8, 4), (16, 4), (16, 8)):
        check_monotone(d, f, e, k, n_dev,
                       Topology(intra_bw=50e9, inter_bw=12.5e9, node_size=ns))


@pytest.mark.parametrize("d,f,e,k", SHAPES)
def test_crossover_shifts_toward_data_centric(d, f, e, k):
    for n_dev, ns in ((8, 2), (16, 4)):
        check_crossover_shift(d, f, e, k, n_dev, ns)


def test_crossover_shift_reference_case():
    """The DESIGN.md §10 worked example, pinned numerically."""
    d, f, e, k, n = 1024, 4096, 16, 2, 16
    fast = dataclasses.replace(
        V5E, topology=Topology(intra_bw=50e9, inter_bw=50e9, node_size=4))
    slow = dataclasses.replace(
        V5E, topology=Topology(intra_bw=50e9, inter_bw=12.5e9, node_size=4))
    assert crossover_tokens(d, f, e, k, n_dev=n, hw=fast) == 65536
    assert crossover_tokens(d, f, e, k, n_dev=n, hw=slow) == 32768


def test_dispatch_bytes_hier_le_flat_grid():
    for tokens, d in ((64, 32), (4096, 1024)):
        for n_dev, ns, k in itertools.product(
                (4, 8, 16, 32), (1, 2, 4, 8), (1, 2, 4, 8)):
            check_dispatch_bytes(tokens, d, k, n_dev, ns)


def test_dispatch_single_node_moves_nothing_across():
    assert dispatch_inter_bytes(4096, 64, 2, n_dev=4, node_size=4) == 0.0
    topo = Topology(intra_bw=50e9, inter_bw=1e9, node_size=8)
    intra, inter = moe_coll_bytes("model_centric", 4096, 64, 256, 8, 2,
                                  n_dev=8, topology=topo)
    assert inter == 0.0 and intra > 0.0


def test_uneven_roofline_prices_topology():
    """layer_latency_uneven threads the same per-level collective costs:
    a slower inter fabric can only increase the uneven max-latency."""
    d, f, e, k = 1024, 4096, 16, 2
    lat = [1.0, 1.0, 1.5, 1.5]
    topo = Topology(intra_bw=50e9, inter_bw=12.5e9, node_size=2)
    hw = dataclasses.replace(V5E, topology=topo)
    slower = dataclasses.replace(
        V5E, topology=dataclasses.replace(topo, inter_bw=1e9))
    for mode in MODES:
        a = layer_latency_uneven(mode, 65536, d, f, e, k, lat, hw=hw)
        b = layer_latency_uneven(mode, 65536, d, f, e, k, lat, hw=slower)
        assert b >= a, mode
    flat = Topology(intra_bw=V5E.link_bw, inter_bw=1.0, node_size=4)
    hwf = dataclasses.replace(V5E, topology=flat)
    for mode in MODES:
        assert (layer_latency_uneven(mode, 65536, d, f, e, k, lat)
                == layer_latency_uneven(mode, 65536, d, f, e, k, lat, hw=hwf))


# ------------------------------------------------- hypothesis-driven sweep

if HAVE_HYPOTHESIS:

    @st.composite
    def _topo_case(draw):
        d = draw(st.sampled_from([32, 64, 512, 1024, 4096]))
        f = draw(st.sampled_from([128, 768, 4096, 14336]))
        e = draw(st.sampled_from([4, 8, 16, 64]))
        k = draw(st.integers(1, min(e, 8)))
        n_dev = draw(st.sampled_from([2, 4, 8, 16, 32]))
        node_size = draw(st.sampled_from([1, 2, 4, 8, 16]))
        tokens = draw(st.sampled_from([16, 256, 4096, 65536]))
        return d, f, e, k, n_dev, node_size, tokens

    @given(_topo_case())
    @settings(max_examples=40, deadline=None)
    def test_flat_degenerate_property(case):
        d, f, e, k, n_dev, _, tokens = case
        check_flat_degenerate(d, f, e, k, n_dev, tokens)

    @given(_topo_case())
    @settings(max_examples=40, deadline=None)
    def test_monotone_property(case):
        d, f, e, k, n_dev, node_size, _ = case
        check_monotone(
            d, f, e, k, n_dev,
            Topology(intra_bw=50e9, inter_bw=12.5e9, node_size=node_size))

    @given(_topo_case())
    @settings(max_examples=40, deadline=None)
    def test_crossover_shift_property(case):
        d, f, e, k, n_dev, node_size, _ = case
        check_crossover_shift(d, f, e, k, n_dev, node_size)

    @given(_topo_case())
    @settings(max_examples=60, deadline=None)
    def test_dispatch_bytes_property(case):
        d, _, _, k, n_dev, node_size, tokens = case
        check_dispatch_bytes(tokens, d, k, n_dev, node_size)
