"""Unified observability subsystem tests (ISSUE 10, DESIGN.md §12).

Four contracts pinned here:

  * registry semantics — counter/gauge/histogram families with labels,
    Prometheus text rendering, snapshot-object polling, and the
    disabled ⇒ shared-no-op-singleton fast path;
  * tracing — span nesting, Chrome trace-event JSON schema validity,
    span-union coverage, and the TTFT/TPOT derivation's bitwise
    agreement with the raw-float subtraction it formalises;
  * device-side router telemetry — per-expert token counts are
    integer-exact against a host numpy recount of the same routing
    decisions, and the flag-gated forward arity leaves the default
    path's logits bitwise untouched;
  * the serve loop — TTFT derived from the recorded spans equals the
    legacy ``PagedServer.ttft_s`` dict bitwise, because both subtract
    the same two clock reads.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs.base import ModelConfig, MoEConfig
from repro.core.routing import route
from repro.launch import serve
from repro.models import lm
from repro.obs import device as obs_device
from repro.obs.metrics import _NOOP_FAMILY, MetricsRegistry, log_buckets
from repro.obs.tracing import (
    Tracer,
    derive_request_latencies,
    span_coverage,
)
from repro.parallel.sharding import ParallelConfig, split_tree


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts from and returns to the disabled baseline so the
    process-wide instances never leak state across the suite."""
    obs.configure(metrics=False, tracing=False, event_log=False, reset=True)
    yield
    obs.configure(metrics=False, tracing=False, event_log=False, reset=True)


# -- registry ---------------------------------------------------------------


def test_counter_gauge_histogram_semantics():
    reg = MetricsRegistry(enabled=True)
    c = reg.counter("repro_test_ops_total", "ops", labels=("kind",))
    c.labels("read").inc()
    c.labels("read").inc(2)
    c.labels("write").inc()
    assert reg.value("repro_test_ops_total", "read") == 3
    assert reg.value("repro_test_ops_total", "write") == 1
    with pytest.raises(ValueError):
        c.labels("read").inc(-1)

    g = reg.gauge("repro_test_depth", "queue depth")
    g.set(7)
    g.set(3.5)
    assert reg.value("repro_test_depth") == 3.5

    h = reg.histogram("repro_test_latency_seconds", "lat",
                      buckets=[0.01, 0.1, 1.0])
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    text = reg.render_prometheus()
    assert '# TYPE repro_test_latency_seconds histogram' in text
    assert 'repro_test_latency_seconds_bucket{le="0.01"} 1' in text
    assert 'repro_test_latency_seconds_bucket{le="1"} 3' in text
    assert 'repro_test_latency_seconds_bucket{le="+Inf"} 4' in text
    assert 'repro_test_latency_seconds_count 4' in text
    assert '# TYPE repro_test_ops_total counter' in text
    assert 'repro_test_ops_total{kind="read"} 3' in text


def test_kind_and_label_arity_mismatch_raise():
    reg = MetricsRegistry(enabled=True)
    reg.counter("repro_test_x", "x")
    with pytest.raises(ValueError):
        reg.gauge("repro_test_x", "x")
    fam = reg.counter("repro_test_y", "y", labels=("a", "b"))
    with pytest.raises(ValueError):
        fam.labels("only-one")


def test_disabled_registry_is_noop_singleton():
    reg = MetricsRegistry(enabled=False)
    fam = reg.counter("repro_test_never", "never")
    assert fam is _NOOP_FAMILY
    fam.inc()
    fam.labels("x").inc(10)
    reg.gauge("repro_test_g").set(1)
    reg.histogram("repro_test_h").observe(1)
    assert reg.families == {}
    assert reg.render_prometheus() == ""


def test_collect_polls_registered_objects():
    class Pool:
        def obs_metrics(self):
            return {"repro_test_free": 12, "repro_test_used": 4}

    reg = MetricsRegistry(enabled=True)
    p = Pool()
    reg.register_object(p)
    reg.collect()
    assert reg.value("repro_test_free", "pool", "0") == 12
    assert reg.value("repro_test_used", "pool", "0") == 4
    # Dead weakrefs are pruned, not polled.
    del p
    reg.collect()


def test_log_buckets_are_sorted_decades():
    b = log_buckets(1e-3, 1.0, 3)
    assert list(b) == sorted(b)
    assert b[0] == pytest.approx(1e-3)
    assert b[-1] == pytest.approx(1.0)
    assert len(b) == 10


# -- tracing ----------------------------------------------------------------


def test_span_nesting_and_chrome_schema():
    clock_vals = iter([1.0, 1.1, 1.2, 1.6, 2.0])
    tr = Tracer(enabled=True, clock=lambda: next(clock_vals))
    with tr.span("outer", n=1):
        with tr.span("inner"):
            pass
        tr.instant("tick", rid=7)
    inner, outer = tr.events[0], tr.events[2]
    assert (inner["name"], inner["depth"]) == ("inner", 1)
    assert (outer["name"], outer["depth"]) == ("outer", 0)
    assert outer["t"] <= inner["t"]
    trace = tr.chrome_trace()
    json.dumps(trace)  # schema must be JSON-serialisable as-is
    evs = trace["traceEvents"]
    assert {e["ph"] for e in evs} == {"X", "i"}
    for e in evs:
        assert e["ts"] >= 0 and "pid" in e and "tid" in e
        if e["ph"] == "X":
            assert e["dur"] >= 0
        else:
            assert e["s"] == "p"
    tick = next(e for e in evs if e["ph"] == "i")
    assert tick["args"]["rid"] == 7


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("x"):
        tr.instant("y")
    assert tr.events == []
    assert tr.chrome_trace() == {"traceEvents": []}


def test_span_coverage_union():
    evs = [
        {"ph": "X", "t": 0.0, "dur": 1.0},
        {"ph": "X", "t": 0.5, "dur": 1.0},   # overlaps the first
        {"ph": "X", "t": 3.0, "dur": 1.0},   # gap [1.5, 3.0)
        {"ph": "i", "t": 9.0},               # instants don't count
    ]
    assert span_coverage(evs) == pytest.approx(2.5 / 4.0)
    assert span_coverage([]) == 1.0


def test_derive_request_latencies_bitwise():
    t0 = 100.0
    t_first = {1: 100.75, 2: 101.5}
    events = [{"name": "serve.run", "ph": "X", "t": t0, "dur": 10.0,
               "args": {}}]
    for rid, t in t_first.items():
        events.append({"name": "serve.first_token", "ph": "i", "t": t,
                       "args": {"rid": rid}})
    events.append({"name": "serve.token", "ph": "i", "t": 101.0,
                   "args": {"rid": 1}})
    events.append({"name": "serve.token", "ph": "i", "t": 101.5,
                   "args": {"rid": 1}})
    ttft, tpot = derive_request_latencies(events)
    assert ttft[1] == t_first[1] - t0   # same float subtraction: bitwise
    assert ttft[2] == t_first[2] - t0
    assert tpot == {1: pytest.approx((101.5 - 100.75) / 2)}


# -- device-side router telemetry -------------------------------------------


def test_expert_counts_bitwise_equal_host_recount():
    rng = np.random.default_rng(0)
    n, d, e, k = 64, 16, 8, 2
    x = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.normal(size=(d, e)).astype(np.float32)
    ro = route(jax.numpy.asarray(x), jax.numpy.asarray(w), k)
    stats = jax.jit(
        lambda i, p: obs_device.expert_stats(i, p, e)
    )(ro.expert_idx, ro.probs)
    idx = np.asarray(ro.expert_idx)
    recount = np.bincount(idx.reshape(-1), minlength=e).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(stats["expert_tokens"]), recount)
    assert int(stats["tokens"]) == n
    assert int(stats["dropped_tokens"]) == 0
    assert int(np.asarray(stats["expert_tokens"]).sum()) == n * k

    # Hetero tail masking: masked rows contribute no counts, no entropy.
    mask = np.zeros(n, bool)
    mask[: n // 2] = True
    ms = obs_device.expert_stats(
        ro.expert_idx, ro.probs, e,
        valid_mask=jax.numpy.asarray(mask))
    recount_m = np.bincount(idx[mask].reshape(-1), minlength=e)
    np.testing.assert_array_equal(np.asarray(ms["expert_tokens"]), recount_m)
    assert int(ms["tokens"]) == n // 2
    assert float(ms["entropy_sum"]) < float(stats["entropy_sum"])


def test_router_stats_drain_publishes_deltas():
    reg = MetricsRegistry(enabled=True)
    drain = obs.RouterStatsDrain(reg, num_experts=2, phase="t")
    mk = lambda c0, c1, tok: {
        "expert_tokens": np.array([c0, c1], np.int32),
        "dropped_tokens": np.int32(0),
        "entropy_sum": np.float32(0.5 * tok),
        "tokens": np.int32(tok),
    }
    drain.push(mk(3, 5, 4))
    drain.flush()
    assert reg.value("repro_router_expert_tokens_total", "t", "0") == 3
    assert reg.value("repro_router_routed_tokens_total", "t") == 4
    drain.push(mk(4, 6, 5))
    drain.flush()
    # Counters accumulate pushed totals monotonically across flushes.
    assert reg.value("repro_router_expert_tokens_total", "t", "0") == 7
    assert reg.value("repro_router_expert_tokens_total", "t", "1") == 11
    assert reg.value("repro_router_routed_tokens_total", "t") == 9
    assert reg.value("repro_router_gate_entropy", "t") == pytest.approx(0.5)


MOE_CFG = ModelConfig(
    name="obs-moe", family="moe",
    num_layers=2, d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
    d_ff=0, vocab_size=32, dtype="float32",
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=64),
)


def test_forward_arity_and_bitwise_default_path():
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), MOE_CFG))
    tokens = jax.numpy.asarray(
        np.random.default_rng(1).integers(0, 32, size=(2, 8)), np.int32)
    pcfg_off = ParallelConfig(blk=8)
    pcfg_on = dataclasses.replace(pcfg_off, collect_router_stats=True)
    out_off = lm.forward(params, {"tokens": tokens}, MOE_CFG, pcfg_off,
                         None, mode="train")
    out_on = lm.forward(params, {"tokens": tokens}, MOE_CFG, pcfg_on,
                        None, mode="train")
    assert len(out_off) == 4
    assert len(out_on) == 5
    np.testing.assert_array_equal(np.asarray(out_off[0]),
                                  np.asarray(out_on[0]))
    stats = out_on[4]
    n_moe = sum(1 for i in range(MOE_CFG.num_layers)
                if MOE_CFG.is_moe_layer(i))
    total = 2 * 8 * MOE_CFG.moe.top_k * n_moe
    assert int(np.asarray(stats["expert_tokens"]).sum()) == total
    assert int(stats["tokens"]) == 2 * 8 * n_moe


# -- event log --------------------------------------------------------------


def test_event_log_records_and_jsonl(tmp_path):
    clock_vals = iter([5.0, 6.0])
    log = obs.EventLog(enabled=True, clock=lambda: next(clock_vals))
    log.emit("train.replan", reason="straggler", shares=[3, 1])
    log.emit("serve.recover", reason="engine step failure")
    assert [r["kind"] for r in log.records] == ["train.replan",
                                                "serve.recover"]
    assert log.records[0]["t"] == 5.0
    assert log.records[0]["reason"] == "straggler"
    path = tmp_path / "events.jsonl"
    log.write_jsonl(str(path))
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert lines == log.records

    off = obs.EventLog(enabled=False)
    off.emit("x")
    assert off.records == []


# -- serve loop -------------------------------------------------------------


SERVE_CFG = ModelConfig(
    name="obs-serve", family="dense",
    num_layers=1, d_model=16, num_heads=2, num_kv_heads=2, head_dim=8,
    d_ff=32, vocab_size=32, dtype="float32",
)


def _serve_requests(n, seed=3):
    rng = np.random.default_rng(seed)
    return [serve.Request(
        rid=i,
        prompt=rng.integers(0, SERVE_CFG.vocab_size,
                            size=int(rng.integers(2, 10))).astype(np.int32),
        max_new=int(rng.integers(2, 5)), out=[])
        for i in range(n)]


def _run_paged(reqs):
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), SERVE_CFG))
    srv = serve.PagedServer(
        SERVE_CFG, ParallelConfig(blk=8), None, num_slots=2, page_size=4,
        num_pages=24, max_pages_per_slot=8, params=params, prefill_chunk=4)
    for r in reqs:
        srv.submit(r)
    done = srv.run()
    return srv, {r.rid: list(r.out) for r in done}


def test_serve_ttft_from_spans_matches_legacy():
    obs.configure(metrics=True, tracing=True, event_log=True, reset=True)
    srv, _ = _run_paged(_serve_requests(3))
    ttft, tpot = derive_request_latencies(obs.tracer.events)
    assert set(ttft) == set(srv.ttft_s)
    for rid, legacy in srv.ttft_s.items():
        assert ttft[rid] == legacy, "span-derived TTFT must be bitwise legacy"
    # The run span must dominate the trace window.
    assert span_coverage(obs.tracer.events) > 0.95
    # The legacy trace shim still reports tuple events.
    kinds = {e[0] for e in srv.trace}
    assert {"admit", "prefill_chunk", "decode", "finish"} <= kinds
    # Scheduler counters landed on the process registry.
    obs.registry.collect()
    text = obs.registry.render_prometheus()
    assert "repro_serve_admissions_total" in text
    assert "repro_serve_decode_step_seconds_count" in text
    assert "repro_cache_num_pages" in text  # PagePool snapshot polled


def test_serve_obs_enabled_changes_no_tokens():
    _, out_ref = _run_paged(_serve_requests(2, seed=9))
    obs.configure(metrics=True, tracing=True, event_log=True, reset=True)
    _, out_obs = _run_paged(_serve_requests(2, seed=9))
    assert out_obs == out_ref
