"""CoW refcount + radix-index property tests (ISSUE 6): the PagePool and
PrefixIndex survive arbitrary admit/fork/write/insert/finish/evict/rollback
interleavings with no leaked pages, no double-frees, and refcounts that
exactly mirror who holds each page.

The driver interprets a drawn op list against the real pool/index while
maintaining an independent shadow model (per-holder page lists + a trie
walk), so the oracle is structural: after EVERY op, each page's pool
refcount must equal the number of slot holders plus trie nodes that map
it, and ``assert_consistent`` must hold; at the end, draining every
holder and the index returns the pool to exactly its initial budget.

Like the other property modules, the hypothesis tests are skipped without
the package and the same ``_check_*`` bodies are driven by pinned samples
so minimal CI environments still execute every invariant.
"""
import pytest

from repro.parallel.cache import PagePool, PrefixIndex

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

PAGE = 2          # tokens per page (tiny: collisions are the point)
NUM_PAGES = 17    # 16 allocatable + sink
N_FAMILIES = 3    # distinct prompt prefixes -> forced sharing


def _prompt(family: int, n_pages: int) -> list:
    """Deterministic token stream per family: equal families share every
    leading chunk, so admissions collide in the trie by construction."""
    return [family * 100 + j for j in range(n_pages * PAGE)]


class _Driver:
    """Interprets (op, seed) tuples against a real pool + index, keeping a
    shadow model of every reference holder for the refcount oracle."""

    def __init__(self, shares=None):
        self.pool = PagePool(NUM_PAGES, shares=shares)
        self.index = PrefixIndex(PAGE)
        self.n_groups = len(self.pool.shares)
        self.slots = {}          # sid -> holder dict
        self._sid = 0

    # -- ops ----------------------------------------------------------------

    def admit(self, seed: int):
        family = seed % N_FAMILIES
        need = 1 + (seed // N_FAMILIES) % 4
        group = (seed // 16) % self.n_groups
        prompt = _prompt(family, need)
        matched = self.index.match(prompt, (len(prompt) - 1) // PAGE)
        if matched:
            self.pool.fork(matched)
        reserve_n = need - len(matched)
        while not self.pool.try_reserve(reserve_n, group):
            if not self.index.evict_lru(self.pool):
                if matched:
                    self.pool.release(matched)
                return
        self.slots[self._sid] = {
            "group": group, "prompt": prompt, "pages": list(matched),
            "need": need, "reserved": reserve_n, "allocated": 0,
            "matched_n": len(matched),
        }
        self._sid += 1

    def alloc(self, seed: int):
        st_ = self._pick(seed)
        if st_ is None or st_["allocated"] >= st_["reserved"]:
            return
        st_["pages"].append(self.pool.alloc(st_["group"]))
        st_["allocated"] += 1

    def write(self, seed: int):
        """CoW trigger: writing a shared page converts a reservation into
        a private copy; an exclusive page is written in place."""
        st_ = self._pick(seed)
        if st_ is None or not st_["pages"]:
            return
        j = seed % len(st_["pages"])
        page = st_["pages"][j]
        if self.pool.refcount(page) <= 1:
            assert self.pool.cow(page, st_["group"]) == page
            return
        while not self.pool.try_reserve(1, st_["group"]):
            if not self.index.evict_lru(self.pool):
                return
        st_["reserved"] += 1
        st_["pages"][j] = self.pool.cow(page, st_["group"])
        st_["allocated"] += 1

    def insert(self, seed: int):
        """Index the holder's fully-backed prompt pages (what the server
        does at prefill completion)."""
        st_ = self._pick(seed)
        if st_ is None or len(st_["pages"]) < st_["need"]:
            return
        self.index.insert(st_["prompt"], st_["pages"][:st_["need"]],
                          self.pool)

    def finish(self, seed: int):
        st_ = self._pick(seed)
        if st_ is None:
            return
        self.pool.release(st_["pages"], st_["group"],
                          unused_reserved=st_["reserved"] - st_["allocated"])
        del self.slots[[k for k, v in self.slots.items() if v is st_][0]]

    def abort(self, seed: int):
        """The ISSUE-7 abort path: a faulted/preempted/timed-out request
        releases EVERYTHING it holds mid-flight — partially-allocated
        reservation, matched forks, CoW copies — exactly like ``_finish``,
        then immediately re-admits through the prefix cache (the retry).
        The oracle must hold at both points."""
        st_ = self._pick(seed)
        if st_ is None:
            return
        self.pool.release(st_["pages"], st_["group"],
                          unused_reserved=st_["reserved"] - st_["allocated"])
        del self.slots[[k for k, v in self.slots.items() if v is st_][0]]
        self.check()
        self.admit(seed)         # retry re-enters via match+fork+reserve

    def rollback(self, seed: int):
        """The ISSUE-9 speculative-rollback path: drop the tail page back
        into the holder's RESERVATION (``PagePool.rollback``).  Only pages
        the slot allocated itself are candidates — never the forked prefix
        — and a tail page the index also holds (refcount > 1) is skipped,
        mirroring the engine's decode-region-only guarantee."""
        st_ = self._pick(seed)
        if st_ is None or len(st_["pages"]) <= st_["matched_n"]:
            return
        page = st_["pages"][-1]
        if self.pool.refcount(page) != 1:
            return
        self.pool.rollback([page], st_["group"])
        st_["pages"].pop()
        st_["allocated"] -= 1    # reservation restored by the pool

    def evict(self, seed: int):
        self.index.evict_lru(self.pool)

    def _pick(self, seed: int):
        if not self.slots:
            return None
        return self.slots[sorted(self.slots)[seed % len(self.slots)]]

    # -- oracle -------------------------------------------------------------

    def check(self):
        self.pool.assert_consistent()
        held = {}
        for st_ in self.slots.values():
            for p in st_["pages"]:
                held[p] = held.get(p, 0) + 1
        stack = [self.index.root]
        while stack:
            node = stack.pop()
            for child in node.children.values():
                held[child.page] = held.get(child.page, 0) + 1
                stack.append(child)
        for p in range(1, NUM_PAGES):
            assert self.pool.refcount(p) == held.get(p, 0), (
                f"page {p}: pool says {self.pool.refcount(p)}, "
                f"holders say {held.get(p, 0)}")

    def drain(self):
        for seed in range(len(self.slots)):
            self.finish(0)
        self.index.clear(self.pool)
        self.check()
        assert len(self.index) == 0
        assert self.pool.in_use_pages == 0
        assert self.pool.reserved_pages == 0
        assert self.pool.free_pages == sum(self.pool.shares)
        assert self.pool.total_allocs == self.pool.total_frees


OPS = ("admit", "alloc", "write", "insert", "finish", "evict", "abort",
       "rollback")


def _check_ops(ops, shares=None):
    d = _Driver(shares)
    for name, seed in ops:
        getattr(d, name)(seed)
        d.check()
    d.drain()


# Pinned samples: every op type, single-group and hetero-share pools,
# including the sequences that exercise CoW and LRU-eviction backpressure.
OPS_SAMPLES = [
    # admit -> fill -> index -> re-admit same family (match+fork) -> CoW
    [("admit", 0), ("alloc", 0), ("alloc", 0), ("insert", 0),
     ("admit", 0), ("write", 0), ("write", 1), ("finish", 0),
     ("finish", 0), ("evict", 0)],
    # eviction pressure: families churn through a pool smaller than the sum
    # of their worst cases, so admission must reclaim LRU trie pages
    [("admit", 9), ("alloc", 0), ("alloc", 0), ("insert", 0), ("finish", 0),
     ("admit", 10), ("alloc", 0), ("alloc", 0), ("insert", 0), ("finish", 0),
     ("admit", 11), ("alloc", 0), ("alloc", 0), ("insert", 0), ("finish", 0),
     ("admit", 9), ("admit", 10), ("admit", 11), ("finish", 0),
     ("finish", 0), ("finish", 0)],
    # interleaved: shared pages outlive their allocator
    [("admit", 3), ("alloc", 0), ("insert", 0), ("admit", 3),
     ("finish", 0), ("write", 0), ("alloc", 0), ("evict", 0),
     ("insert", 0), ("finish", 0)],
    # abort paths (ISSUE 7): mid-prefill abort (reservation partially
    # consumed), abort of a slot borrowing indexed pages, abort after a
    # CoW write, back-to-back abort/retry churn under share pressure
    [("admit", 0), ("alloc", 0), ("abort", 0), ("alloc", 0),
     ("insert", 0), ("admit", 0), ("abort", 1), ("abort", 0),
     ("finish", 0), ("evict", 0)],
    [("admit", 9), ("alloc", 0), ("alloc", 0), ("insert", 0),
     ("admit", 9), ("write", 0), ("abort", 1), ("abort", 0),
     ("admit", 10), ("abort", 0), ("evict", 0), ("finish", 0)],
    # rollback paths (ISSUE 9): rollback of a speculative tail page, re-use
    # of the restored reservation, a rollback refused because the tail page
    # is also held by the index (refcount > 1), rollback on the matched
    # prefix boundary (no-op), and rollback under hetero shares
    [("admit", 0), ("alloc", 0), ("alloc", 0), ("rollback", 0),
     ("alloc", 0), ("insert", 0), ("rollback", 0), ("admit", 0),
     ("rollback", 1), ("evict", 0), ("rollback", 0), ("finish", 0),
     ("finish", 0)],
    [("admit", 19), ("alloc", 0), ("rollback", 0), ("rollback", 0),
     ("alloc", 0), ("abort", 0), ("alloc", 0), ("rollback", 0),
     ("finish", 0)],
]
SHARES_SAMPLES = [None, [10, 6]]


@pytest.mark.parametrize("shares", SHARES_SAMPLES)
@pytest.mark.parametrize("ops_i", range(len(OPS_SAMPLES)))
def test_ops_pinned(ops_i, shares):
    _check_ops(OPS_SAMPLES[ops_i], shares)


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(
        ops=st.lists(
            st.tuples(st.sampled_from(OPS), st.integers(0, 127)),
            min_size=1, max_size=40),
        shares=st.sampled_from(SHARES_SAMPLES),
    )
    def test_ops_property(ops, shares):
        _check_ops(ops, shares)


def test_ops_fuzz_deterministic():
    """200 seeded pseudo-random interleavings — the property keeps its
    example count even on environments without hypothesis."""
    import numpy as np

    rng = np.random.default_rng(0)
    for case in range(200):
        n = int(rng.integers(1, 40))
        ops = [(OPS[int(rng.integers(len(OPS)))], int(rng.integers(128)))
               for _ in range(n)]
        _check_ops(ops, SHARES_SAMPLES[case % len(SHARES_SAMPLES)])


# --- explicit regression cases -------------------------------------------

def _pool_with_page():
    pool = PagePool(NUM_PAGES)
    assert pool.try_reserve(2)
    return pool, pool.alloc()


def test_double_release_raises():
    """The PR-4 pool silently corrupted its free list on a double release;
    the refcount layer turns it into a RuntimeError."""
    pool, page = _pool_with_page()
    pool.release([page])
    with pytest.raises(RuntimeError, match="double release"):
        pool.release([page])
    # the failed release must not have mutated anything
    pool.release([], unused_reserved=1)
    pool.assert_consistent()


def test_fork_free_page_raises():
    pool, page = _pool_with_page()
    pool.release([page])
    with pytest.raises(RuntimeError, match="fork of free page"):
        pool.fork([page])
    with pytest.raises(ValueError):
        pool.fork([0])          # the sink is never forkable


def test_cow_semantics():
    pool, page = _pool_with_page()
    # exclusive page: written in place, no new allocation
    assert pool.cow(page) == page
    pool.fork([page])
    new = pool.cow(page)        # shared: converts the reservation
    assert new != page
    assert pool.refcount(page) == 1 and pool.refcount(new) == 1
    assert pool.stats()["total_cow_copies"] == 1
    pool.assert_consistent()
    pool.release([page, new])
    with pytest.raises(RuntimeError, match="cow on free page"):
        pool.cow(page)


def test_release_frees_only_at_refcount_zero():
    pool, page = _pool_with_page()
    pool.fork([page])
    pool.release([page])
    assert pool.refcount(page) == 1 and pool.in_use_pages == 1
    pool.release([page], unused_reserved=1)
    assert pool.refcount(page) == 0 and pool.in_use_pages == 0
    assert pool.free_pages == NUM_PAGES - 1
    pool.assert_consistent()


def test_owner_group_credited_across_groups():
    """A page forked into another holder stays charged to its allocator
    group until the LAST reference dies — the documented budget pinning."""
    pool = PagePool(NUM_PAGES, shares=[10, 6])
    assert pool.try_reserve(1, 0)
    page = pool.alloc(0)
    pool.fork([page])           # e.g. group-1 slot borrows it
    pool.release([page], group=0)   # allocator's reference dies first
    assert pool.group_free(0) == 9  # still pinned to group 0
    pool.release([page], group=1)
    assert pool.group_free(0) == 10 and pool.group_free(1) == 6
    pool.assert_consistent()


def test_trie_match_fork_evict():
    pool = PagePool(NUM_PAGES)
    idx = PrefixIndex(PAGE)
    prompt = _prompt(0, 3)
    assert pool.try_reserve(3)
    pages = [pool.alloc() for _ in range(3)]
    assert idx.insert(prompt, pages, pool) == 3
    # racing insert of the same prefix adds nothing
    assert idx.insert(prompt, pages, pool) == 0
    # match caps at max_pages and bumps nothing beyond it
    assert idx.match(prompt, 2) == pages[:2]
    # releasing the slot's references leaves the trie holding every page
    pool.release(pages)
    assert pool.in_use_pages == 3
    # interior nodes never evict before their children
    assert idx.evict_lru(pool)
    assert len(idx) == 2 and pool.refcount(pages[2]) == 0
    # a borrowed (refcount>1) page is pinned against eviction
    pool.fork([pages[0]])
    pool.fork([pages[1]])
    assert idx.evict_lru(pool) is False
    pool.release([pages[0]])
    pool.release([pages[1]])
    assert idx.clear(pool) == 2
    assert pool.free_pages == NUM_PAGES - 1
    pool.assert_consistent()


def test_partial_page_never_indexed():
    """Prompts shorter than a page contribute nothing to the index, so a
    later write can never mutate cached content."""
    pool = PagePool(NUM_PAGES)
    idx = PrefixIndex(PAGE)
    assert pool.try_reserve(1)
    page = pool.alloc()
    assert idx.insert([7], [page][: 1 // PAGE], pool) == 0
    assert idx.match([7, 8], (2 - 1) // PAGE) == []
    assert len(idx) == 0
    pool.release([page])
