"""Substrate tests: optimizer, compression, data pipeline, checkpointing,
fault-tolerance loop, straggler monitor, elastic mesh choice."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, Prefetcher, TokenSource
from repro.optim import adamw, compression
from repro.runtime import elastic, ft as ft_lib
from repro.runtime.straggler import StragglerConfig, StragglerMonitor


# ---------------------------------------------------------------- optimizer

def test_adamw_optimises_quadratic():
    cfg = adamw.OptimizerConfig(peak_lr=0.1, warmup_steps=5, decay_steps=200,
                                weight_decay=0.0, master_fp32=False)
    params = {"w": jnp.ones((8,)) * 5.0}
    state = adamw.init_opt_state(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, state, m = adamw.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_bf16_states_and_master():
    cfg = adamw.OptimizerConfig(state_dtype="bfloat16", master_fp32=True)
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = adamw.init_opt_state(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    assert state["master"]["w"].dtype == jnp.float32
    grads = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    params2, state2, _ = adamw.apply_updates(params, grads, state, cfg)
    assert params2["w"].dtype == jnp.bfloat16
    assert state2["step"] == 1


def test_grad_clip():
    cfg = adamw.OptimizerConfig(grad_clip=1.0, peak_lr=1.0, warmup_steps=0,
                                decay_steps=10, weight_decay=0.0,
                                master_fp32=False)
    params = {"w": jnp.zeros((4,))}
    state = adamw.init_opt_state(params, cfg)
    _, _, m = adamw.apply_updates(params, {"w": jnp.full((4,), 100.0)},
                                  state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# -------------------------------------------------------------- compression

def test_int8_roundtrip_bounded_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,))
    rec, res = compression.compress_roundtrip(x)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.abs(res).max()) <= scale * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With error feedback, the accumulated applied signal converges to the
    accumulated true signal."""
    g = jax.random.normal(jax.random.PRNGKey(1), (64,)) * 0.01
    res = jnp.zeros_like(g)
    applied = jnp.zeros_like(g)
    for _ in range(50):
        rec, res = compression.compress_roundtrip(g + res)
        applied = applied + rec
    true = g * 50
    rel = float(jnp.linalg.norm(applied - true) / jnp.linalg.norm(true))
    assert rel < 0.05


# --------------------------------------------------------------------- data

def test_data_deterministic_and_resumable():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab_size=64, seed=7)
    a = TokenSource(cfg)
    b = TokenSource(cfg)
    for step in (0, 5, 1000):
        np.testing.assert_array_equal(a.batch(step)["tokens"],
                                      b.batch(step)["tokens"])
    assert not np.array_equal(a.batch(1)["tokens"], a.batch(2)["tokens"])


def test_data_labels_shifted():
    cfg = DataConfig(seq_len=16, global_batch=2, vocab_size=64, seed=1)
    batch = TokenSource(cfg).batch(0)
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["labels"][:, :-1])


def test_data_markov_structure_learnable():
    """Stream entropy must be below uniform (otherwise convergence examples
    cannot show learning)."""
    cfg = DataConfig(seq_len=512, global_batch=8, vocab_size=32, seed=2)
    toks = TokenSource(cfg).batch(0)["tokens"]
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), []).append(int(b))
    # conditional empirical entropy << log2(32)
    ents = []
    for a, nxt in pairs.items():
        if len(nxt) < 16:
            continue
        _, counts = np.unique(nxt, return_counts=True)
        prob = counts / counts.sum()
        ents.append(-(prob * np.log2(prob)).sum())
    assert np.mean(ents) < 4.0  # uniform would be 5 bits


def test_unequal_shares():
    cfg = DataConfig(seq_len=8, global_batch=10, vocab_size=16)
    s0 = TokenSource(cfg, num_shards=2, shard=0, shares=[7, 3])
    s1 = TokenSource(cfg, num_shards=2, shard=1, shares=[7, 3])
    assert s0.batch(0)["tokens"].shape[0] == 7
    assert s1.batch(0)["tokens"].shape[0] == 3


def test_prefetcher():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab_size=16)
    pf = Prefetcher(TokenSource(cfg), start_step=3)
    step, batch = next(pf)
    assert step == 3 and batch["tokens"].shape == (2, 8)
    step, _ = next(pf)
    assert step == 4
    pf.close()


# --------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 7, tree, meta={"step": 7})
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, meta = ckpt.restore(str(tmp_path), 7, like)
    assert meta["step"] == 7
    np.testing.assert_array_equal(restored["a"], tree["a"])
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async_and_gc(tmp_path):
    saver = ckpt.AsyncSaver()
    for step in (1, 2, 3, 4):
        saver.save(str(tmp_path), step, {"x": jnp.full((4,), step)})
    saver.wait()
    ckpt.gc_old(str(tmp_path), keep=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.ones(3)})
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


# ------------------------------------------------------------------ ft loop

def test_run_with_recovery_restores_after_failure(tmp_path):
    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        if step == 7 and calls["n"] < 12:  # fail once at step 7
            raise RuntimeError("injected device failure")
        return {"w": state["w"] + 1}, {"loss": 1.0}

    state, last = ft_lib.run_with_recovery(
        state={"w": jnp.zeros(())},
        step_fn=step_fn,
        start_step=0,
        num_steps=10,
        ft=ft_lib.FTConfig(ckpt_dir=str(tmp_path), save_every=2,
                           max_failures=2),
    )
    assert last == 10
    assert float(state["w"]) == 10.0  # deterministic replay after restore


def test_run_with_recovery_nan_watchdog(tmp_path):
    # transient data corruption: NaN appears once at step 5, the watchdog
    # restores and the retry succeeds (external cause, external counter).
    seen = {"nans": 0}

    def step_fn(state, step):
        loss = 1.0
        if step == 5 and seen["nans"] == 0:
            seen["nans"] += 1
            loss = float("nan")
        return {"w": state["w"] + 1}, {"loss": loss}

    state, last = ft_lib.run_with_recovery(
        state={"w": jnp.zeros(())},
        step_fn=step_fn, start_step=0, num_steps=8,
        ft=ft_lib.FTConfig(ckpt_dir=str(tmp_path), save_every=2,
                           max_failures=3),
    )
    assert last == 8
    assert seen["nans"] == 1


# ---------------------------------------------------------------- straggler

def test_straggler_monitor_replans():
    mon = StragglerMonitor(
        4, 64,
        StragglerConfig(window=4, trigger_ratio=1.2,
                        min_steps_between_replans=0),
    )
    new = None
    for _ in range(8):
        new = mon.report([1.0, 1.0, 1.0, 2.5]) or new
    assert new is not None
    assert new[3] < new[0]
    assert sum(new) == 64


def test_straggler_quiet_on_homogeneous():
    mon = StragglerMonitor(4, 64, StragglerConfig(window=4,
                                                  min_steps_between_replans=0))
    for _ in range(8):
        assert mon.report([1.0, 1.01, 0.99, 1.0]) is None


# ------------------------------------------------------------------ elastic

@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 4096))
def test_choose_mesh_shape_covers_devices(n):
    data, model = elastic.choose_mesh_shape(n)
    assert data * model <= n
    assert data * model >= n // 2  # never waste more than half


def test_choose_mesh_min_model_for_memory():
    # 100GB of params need TP >= 100e9 / (0.5 * 17.2e9) ~ 12 -> 16
    data, model = elastic.choose_mesh_shape(
        256, param_bytes=100e9, hbm_bytes=16 * 2**30
    )
    assert data * model == 256
    assert model >= 16
    # small model: pure DP is fine
    data2, model2 = elastic.choose_mesh_shape(256, param_bytes=1e9)
    assert model2 == 1
