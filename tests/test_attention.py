"""Chunked-causal attention vs naive reference; SWA; prefix-LM; decode
consistency with prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import chunked_attention, decode_attention, rope


def naive_attention(q, k, v, *, window=None, prefix_len=0):
    b, s, hq, hd = q.shape
    _, _, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd)
    logits = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k) * hd ** -0.5
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    allowed = kpos <= qpos
    if window is not None:
        allowed &= kpos > qpos - window
    if prefix_len:
        allowed |= (kpos < prefix_len) & (qpos < prefix_len)
    logits = jnp.where(allowed[None, :, None, None, :], logits, -2e38)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", w, v)
    return out.reshape(b, s, hq, hd)


def _qkv(b=2, s=64, hq=4, hkv=2, hd=8, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))
    return q, k, v


@pytest.mark.parametrize("q_chunk,kv_block", [(16, 16), (32, 8), (64, 64)])
def test_chunked_matches_naive_causal(q_chunk, kv_block):
    q, k, v = _qkv()
    out = chunked_attention(q, k, v, q_chunk=q_chunk, kv_block=kv_block)
    want = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [8, 16, 48])
def test_sliding_window(window):
    q, k, v = _qkv(s=64)
    out = chunked_attention(q, k, v, window=window, q_chunk=16, kv_block=8)
    want = naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_prefix_lm():
    q, k, v = _qkv(s=64)
    out = chunked_attention(q, k, v, prefix_len=10, q_chunk=16, kv_block=16)
    want = naive_attention(q, k, v, prefix_len=10)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_softcap():
    q, k, v = _qkv(s=32)
    out = chunked_attention(q, k, v, softcap=30.0, q_chunk=8, kv_block=8)
    assert not np.isnan(np.asarray(out)).any()


def test_decode_matches_full():
    """decode(q_t | cache of first t) == row t of full causal attention."""
    b, s, hq, hkv, hd = 2, 16, 4, 2, 8
    q, k, v = _qkv(b, s, hq, hkv, hd)
    full = naive_attention(q, k, v)
    for t in [0, 5, 15]:
        k_cache = jnp.where(
            (jnp.arange(s) <= t)[None, :, None, None], k, 0.0
        )
        v_cache = jnp.where(
            (jnp.arange(s) <= t)[None, :, None, None], v, 0.0
        )
        out = decode_attention(
            q[:, t:t + 1], k_cache, v_cache,
            jnp.full((b,), t + 1, jnp.int32),
        )
        np.testing.assert_allclose(
            np.asarray(out[:, 0]), np.asarray(full[:, t]),
            rtol=1e-5, atol=1e-5,
        )


def test_rope_relative_shift():
    """RoPE inner products depend only on relative positions."""
    b, s, h, hd = 1, 8, 1, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, hd))
    p0 = jnp.arange(s)
    p1 = jnp.arange(s) + 100
    dots0 = jnp.einsum(
        "bqhd,bkhd->bqk", rope(q, p0), rope(k, p0)
    )
    dots1 = jnp.einsum(
        "bqhd,bkhd->bqk", rope(q, p1), rope(k, p1)
    )
    np.testing.assert_allclose(np.asarray(dots0), np.asarray(dots1),
                               rtol=1e-4, atol=1e-4)


def test_gradients_flow():
    q, k, v = _qkv(s=32)
    def loss(q, k, v):
        return jnp.sum(chunked_attention(q, k, v, q_chunk=8, kv_block=8) ** 2)
    g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for t in g:
        assert np.isfinite(np.asarray(t)).all()
        assert np.abs(np.asarray(t)).max() > 0
