"""Cross-config decode parity matrix (ISSUE 4): batched continuous-batching
decode — dense baseline AND paged engine (pallas-interpret paged attention)
— must emit token-for-token the same streams as the one-request-at-a-time
dense-cache reference, including mid-run slot refill and with an attached
heterogeneous plan.

The matrix covers the paper-relevant families: mixtral (SWA windowed MoE,
softmax_after_topk), qwen3 (fine-grained MoE + qk-norm), gemma-2b (dense
GeGLU MQA), and the swin-moe expert configuration (expert-MLP, layernorm,
gelu — swin itself is a vision classifier with no decode path, so its MoE
block is grafted onto a tiny decode-capable LM)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.configs.base import ModelConfig, MoEConfig
from repro.core import hetero as hetero_lib
from repro.launch import serve, spec as spec_lib, steps as steps_lib
from repro.models import lm
from repro.parallel.sharding import ParallelConfig, split_tree

#: swin-moe-small's expert configuration (4 experts, top-2, expert-MLP with
#: gelu + layernorm, MoE on alternating blocks) on a decode-capable LM.
SWIN_MOE_LM = ModelConfig(
    name="swin-moe-lm-smoke",
    family="vision-moe",
    num_layers=2,
    d_model=32,
    num_heads=2,
    num_kv_heads=2,
    head_dim=16,
    d_ff=48,
    vocab_size=64,
    act="gelu",
    glu=False,
    norm="layernorm",
    tie_embeddings=True,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=48, period=2, offset=1),
)

ARCHS = ["mixtral-8x7b", "qwen3-moe-30b-a3b", "gemma-2b", "swin_moe_small"]


def _config(arch):
    if arch == "swin_moe_small":
        cfg = SWIN_MOE_LM
    else:
        cfg = cfglib.get_smoke_config(arch)
    # f32 keeps greedy argmax margins far above cross-batch reduction noise
    return dataclasses.replace(cfg, dtype="float32")


def _requests(cfg, n, seed):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(2, 14))
        reqs.append(serve.Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new=int(rng.integers(1, 6)),
        ))
    return reqs


def _reference_streams(cfg, pcfg, params, reqs, max_seq):
    step = jax.jit(steps_lib.make_serve_step(
        cfg, pcfg, None, (1, 1, cfg.d_model)))
    return {
        r.rid: serve.greedy_reference(
            cfg, pcfg, None, params, r.prompt, r.max_new,
            max_seq=max_seq, step=step)
        for r in reqs
    }


MAX_SEQ = 32
NUM_SLOTS = 3    # < num requests -> guaranteed mid-run slot refill
N_REQ = 6


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_decode_parity(arch):
    """Paged continuous batching (pallas-interpret paged attention, chunked
    prefill, slot refill) is token-identical to the batch-1 dense
    reference on every config in the matrix."""
    cfg = _config(arch)
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _requests(cfg, N_REQ, seed=11)
    refs = _reference_streams(cfg, pcfg, params, reqs, MAX_SEQ)

    maxp = MAX_SEQ // 4
    server = serve.PagedServer(
        cfg, pcfg, None, num_slots=NUM_SLOTS, page_size=4,
        num_pages=1 + NUM_SLOTS * maxp, max_pages_per_slot=maxp,
        params=params, prefill_chunk=5,
    )
    for r in reqs:
        server.submit(dataclasses.replace(r, out=[]))
    done = server.run()
    assert len(done) == N_REQ
    assert server.admissions > NUM_SLOTS, "no mid-run slot refill happened"
    for r in done:
        assert r.out == refs[r.rid], (
            f"{arch}: paged stream for rid={r.rid} diverged")
    # no page leaks, table fully cleared
    server.pool.assert_consistent()
    assert server.pool.free_pages == NUM_SLOTS * maxp
    assert (server.table == 0).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_dense_decode_parity(arch):
    """The dense continuous-batching baseline (masked macro-steps, slot
    refill) matches the same reference — the two servers differ only in
    cache layout, never in tokens."""
    cfg = _config(arch)
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _requests(cfg, N_REQ, seed=13)
    refs = _reference_streams(cfg, pcfg, params, reqs, MAX_SEQ)

    server = serve.BatchedServer(
        cfg, pcfg, None, num_slots=NUM_SLOTS, max_seq=MAX_SEQ, params=params)
    for r in reqs:
        server.submit(dataclasses.replace(r, out=[]))
    done = server.run()
    assert len(done) == N_REQ
    assert server.admissions > NUM_SLOTS
    for r in done:
        assert r.out == refs[r.rid], (
            f"{arch}: dense stream for rid={r.rid} diverged")


def test_paged_parity_with_hetero_plan():
    """An attached Eq. 1/2 plan (uneven page-pool shares + padded FFN
    hidden tiles) must not change a single token: the plan reshapes WHERE
    pages and hidden columns live, never what is computed."""
    cfg = _config("qwen3-moe-30b-a3b")
    plan = hetero_lib.make_hetero_plan(
        (1.0, 2.0), global_batch=4,
        hidden_size=cfg.moe.d_ff, tp_latencies=(1.0, 3.0))
    pcfg = ParallelConfig(blk=8, impl="pallas", hetero_plan=plan)
    params, _ = split_tree(
        lm.init_params(jax.random.PRNGKey(0), cfg, plan=plan))
    reqs = _requests(cfg, 5, seed=17)
    refs = _reference_streams(cfg, pcfg, params, reqs, MAX_SEQ)

    maxp = MAX_SEQ // 4
    server = serve.PagedServer(
        cfg, pcfg, None, num_slots=4, page_size=4,
        num_pages=1 + 4 * maxp, max_pages_per_slot=maxp,
        params=params, prefill_chunk=4, plan=plan,
    )
    # uneven shares actually materialised (t=1 vs t=2 -> 2:1 page budget)
    assert len(server.pool.shares) == 2
    assert server.pool.shares[0] > server.pool.shares[1]
    for r in reqs:
        server.submit(dataclasses.replace(r, out=[]))
    done = server.run()
    assert len(done) == 5
    for r in done:
        assert r.out == refs[r.rid], f"hetero rid={r.rid} diverged"
    server.pool.assert_consistent()
    assert server.pool.free_pages == sum(server.pool.shares)


def test_paged_cache_specs_mirror_cache_tree():
    """``paged_cache_logical_specs`` must stay structurally congruent with
    ``init_paged_cache`` (leaf-for-leaf), and each logical entry must have
    one axis per array dim — that is what lets ``tree_shardings`` place
    the pool (page dim over "dp") on a real mesh."""
    for arch in ("mixtral-8x7b", "jamba-1.5-large-398b"):
        cfg = _config(arch) if arch != "jamba-1.5-large-398b" else (
            dataclasses.replace(
                cfglib.get_smoke_config(arch), dtype="float32"))
        cache = lm.init_paged_cache(cfg, num_slots=3, num_pages=9,
                                    page_size=4)
        specs = lm.paged_cache_logical_specs(cfg, cache)
        flat_c, tree_c = jax.tree_util.tree_flatten(cache)
        # specs' leaves are tuples; flatten up to the cache structure
        flat_s = tree_c.flatten_up_to(specs)
        assert len(flat_s) == len(flat_c)
        for arr, spec in zip(flat_c, flat_s):
            assert isinstance(spec, tuple) and len(spec) == arr.ndim, (
                arch, arr.shape, spec)


def test_paged_parity_recurrent_scan_prefill():
    """Hybrid attn+mamba (jamba): recurrent state can't prefill a chunk in
    one forward, so the engine falls back to the in-jit scan of decode
    steps — per-slot state slicing, freezing, and reset must all still
    produce reference-identical streams through slot refill."""
    cfg = dataclasses.replace(
        cfglib.get_smoke_config("jamba-1.5-large-398b"), dtype="float32")
    assert any(cfg.layer_kind(i) != "attn" for i in range(cfg.period))
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _requests(cfg, 4, seed=23)
    refs = _reference_streams(cfg, pcfg, params, reqs, MAX_SEQ)
    maxp = MAX_SEQ // 4
    server = serve.PagedServer(
        cfg, pcfg, None, num_slots=2, page_size=4,
        num_pages=1 + 2 * maxp, max_pages_per_slot=maxp,
        params=params, prefill_chunk=4,
    )
    for r in reqs:
        server.submit(dataclasses.replace(r, out=[]))
    done = server.run()
    assert len(done) == 4 and server.admissions > 2
    for r in done:
        assert r.out == refs[r.rid], f"jamba rid={r.rid} diverged"
    server.pool.assert_consistent()


def test_window_page_reclamation():
    """On an all-SWA stack (mixtral) pages wholly behind the window return
    to the pool mid-request: live pages stay bounded by the window, and
    the reused pages never perturb the token stream."""
    cfg = _config("mixtral-8x7b")
    assert cfg.window == 16
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    prompt = np.arange(40, dtype=np.int32) % cfg.vocab_size
    req = serve.Request(rid=0, prompt=prompt, max_new=8)
    ref = serve.greedy_reference(
        cfg, pcfg, None, params, prompt, 8, max_seq=64)

    page, maxp = 4, 12  # 48 rows per slot -> covers 40 + 8 - 1
    server = serve.PagedServer(
        cfg, pcfg, None, num_slots=2, page_size=page,
        num_pages=1 + 2 * maxp, max_pages_per_slot=maxp,
        params=params, prefill_chunk=8,
    )
    assert server.reclaim_window == 16
    server.submit(dataclasses.replace(req, out=[]))
    done = server.run()
    assert done[0].out == ref
    # the request wrote 47 rows (12 pages) but never held more than the
    # window + one prefill chunk's worth of them at once
    window_pages = cfg.window // page + server.prefill_chunk // page + 1
    assert server.pool.peak_in_use_pages <= window_pages
    assert server.pool.total_allocs == 12
    server.pool.assert_consistent()
    assert server.pool.free_pages == 2 * maxp


def test_int8_kv_decode_parity_and_capacity():
    """int8 paged-KV (ISSUE 5, DESIGN.md §8): the quantized-cache server
    stays token-identical to its own full-precision run under greedy
    sampling (per-row scales keep the quantization error far inside the
    pinned greedy-argmax margin on this matrix), while the smaller page
    bytes make an equal-HBM PagePool admit measurably more concurrent
    requests."""
    from repro.parallel.cache import PagePool

    cfg = _config("qwen3-moe-30b-a3b")
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _requests(cfg, N_REQ, seed=11)
    maxp = MAX_SEQ // 4

    def run_server(kv_quant):
        server = serve.PagedServer(
            cfg, pcfg, None, num_slots=NUM_SLOTS, page_size=4,
            num_pages=1 + NUM_SLOTS * maxp, max_pages_per_slot=maxp,
            params=params, prefill_chunk=5, kv_quant=kv_quant,
        )
        for r in reqs:
            server.submit(dataclasses.replace(r, out=[]))
        done = server.run()
        server.pool.assert_consistent()
        assert len(done) == N_REQ
        return server, {r.rid: r.out for r in done}

    srv_fp, out_fp = run_server(None)
    srv_q, out_q = run_server("int8")
    assert out_q == out_fp, "int8 KV diverged from its own fp run"
    # the int8 cache really is int8 + scales
    attn_pos = next(i for i in range(cfg.period)
                    if cfg.layer_kind(i) == "attn")
    entry = srv_q.cache["layers"][attn_pos]
    assert entry["k"].dtype == jnp.int8 and "k_scale" in entry

    # equal-HBM admission capacity: same byte budget -> more int8 pages ->
    # more concurrently admissible requests
    pb_fp = lm.paged_kv_page_bytes(cfg, 4, None)
    pb_q = lm.paged_kv_page_bytes(cfg, 4, "int8")
    assert srv_fp.page_bytes == pb_fp and srv_q.page_bytes == pb_q
    budget = 24 * pb_fp
    pool_fp = PagePool(1 + budget // pb_fp, page_bytes=pb_fp)
    pool_q = PagePool(1 + budget // pb_q, page_bytes=pb_q)
    need = 4  # worst-case pages of a representative request

    def capacity(pool):
        n = 0
        while pool.try_reserve(need):
            n += 1
        return n

    cap_fp, cap_q = capacity(pool_fp), capacity(pool_q)
    assert cap_q > cap_fp, (cap_q, cap_fp)
    assert cap_q * pb_q * need <= budget + need * pb_q  # still within HBM


# --- prefix-sharing rows (ISSUE 6) ---------------------------------------

def _shared_prefix_requests(cfg, n, seed, *, shared_len=12):
    """High-duplicate chat-style workload: every request opens with the
    same ``shared_len``-token system prompt and appends a short unique
    tail — the later admissions' prefixes are fully cached."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, size=shared_len).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(
            0, cfg.vocab_size, size=int(rng.integers(1, 4))).astype(np.int32)
        reqs.append(serve.Request(
            rid=i, prompt=np.concatenate([shared, tail]),
            max_new=int(rng.integers(2, 6))))
    return reqs


def _run_paged(cfg, pcfg, params, reqs, *, num_slots=NUM_SLOTS,
               num_pages=None, spec=None, spec_k=3, **kw):
    maxp = MAX_SEQ // 4
    server = serve.PagedServer(
        cfg, pcfg, None, num_slots=num_slots, page_size=4,
        num_pages=num_pages or (1 + num_slots * maxp),
        max_pages_per_slot=maxp, params=params, prefill_chunk=5, **kw)
    if spec is not None:
        spec_lib.SpecDecoder(server, spec, k=spec_k)
    for r in reqs:
        server.submit(dataclasses.replace(r, out=[]))
    done = server.run()
    assert len(done) == len(reqs)
    return server, {r.rid: r.out for r in done}


def _assert_drained(server):
    """The pool returns to its full budget once the index is dropped."""
    server.drop_prefix_cache()
    server.pool.assert_consistent()
    assert server.pool.free_pages == sum(server.pool.shares)
    assert (server.table == 0).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_prefix_cache_parity(arch):
    """Prefix-cache ON is token-identical to OFF and to the batch-1 dense
    reference on every config in the matrix, while actually sharing pages
    (hits > 0, strictly fewer physical allocations)."""
    cfg = _config(arch)
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _shared_prefix_requests(cfg, N_REQ, seed=29)
    refs = _reference_streams(cfg, pcfg, params, reqs, MAX_SEQ)

    srv_on, out_on = _run_paged(cfg, pcfg, params, reqs, prefix_cache=True)
    srv_off, out_off = _run_paged(cfg, pcfg, params, reqs)
    assert out_on == out_off == refs, f"{arch}: prefix-cache changed tokens"
    pf = srv_on.stats()["prefix"]
    assert pf["hit_tokens"] > 0, f"{arch}: no prefix was ever shared"
    assert srv_on.pool.total_allocs < srv_off.pool.total_allocs
    assert srv_on.pool.total_forks > 0
    _assert_drained(srv_on)


def test_prefix_cache_int8_parity():
    """Shared int8 pages share their scale rows through the same physical
    index: int8 + prefix-cache stays token-identical to int8 alone."""
    cfg = _config("qwen3-moe-30b-a3b")
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _shared_prefix_requests(cfg, N_REQ, seed=31)

    srv_on, out_on = _run_paged(cfg, pcfg, params, reqs,
                                kv_quant="int8", prefix_cache=True)
    srv_off, out_off = _run_paged(cfg, pcfg, params, reqs, kv_quant="int8")
    assert out_on == out_off, "int8 prefix-cache diverged from int8 alone"
    assert srv_on.stats()["prefix"]["hit_tokens"] > 0
    entry = srv_on.cache["layers"][0]
    assert entry["k"].dtype == jnp.int8 and "k_scale" in entry
    _assert_drained(srv_on)


def test_prefix_cache_parity_under_eviction_pressure():
    """A pool too small to keep every family cached forces mid-run LRU
    evictions of trie pages during admission — the streams must not move
    and the drained pool must still balance."""
    cfg = _config("gemma-2b")
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    # three distinct 8-token prefix families, revisited out of order
    rng = np.random.default_rng(37)
    fams = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
            for _ in range(3)]
    reqs = []
    for i, f in enumerate([0, 1, 2, 0, 1, 2, 2, 0]):
        tail = rng.integers(0, cfg.vocab_size, size=2).astype(np.int32)
        reqs.append(serve.Request(
            rid=i, prompt=np.concatenate([fams[f], tail]), max_new=3))
    refs = _reference_streams(cfg, pcfg, params, reqs, MAX_SEQ)

    # worst case per request: ceil((10 + 3 - 1) / 4) = 3 pages; 2 slots
    # need 6 of the 7 usable pages, but the three families want 6 cached
    # pages between them -> admission must evict LRU trie pages
    srv, out = _run_paged(cfg, pcfg, params, reqs, num_slots=2,
                          num_pages=8, prefix_cache=True)
    assert out == refs, "eviction pressure changed tokens"
    pf = srv.stats()["prefix"]
    assert pf["evictions"] > 0, "pool was never actually under pressure"
    _assert_drained(srv)


def test_prefix_cache_rejects_recurrent_stack():
    """Recurrent layers keep per-slot state outside the pages, so a
    skipped prefix would decode from zeros — the server refuses."""
    cfg = dataclasses.replace(
        cfglib.get_smoke_config("jamba-1.5-large-398b"), dtype="float32")
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    with pytest.raises(ValueError, match="all-attention"):
        serve.PagedServer(
            cfg, pcfg, None, num_slots=2, page_size=4, num_pages=17,
            max_pages_per_slot=8, params=params, prefix_cache=True)


def test_sampled_stream_parity_across_engines():
    """RNG plumbing (ISSUE 6): a sampled request's stream is a pure
    function of (seed, step, logits) — dense server, paged server, and the
    batch-1 reference all draw identical tokens, and the temperature
    actually moves the stream off greedy."""
    cfg = _config("gemma-2b")
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _requests(cfg, N_REQ, seed=41)
    for r in reqs:
        r.temperature, r.seed = 0.8, 1000 + r.rid
    step = jax.jit(steps_lib.make_serve_step(
        cfg, pcfg, None, (1, 1, cfg.d_model)))
    refs = {r.rid: serve.reference_stream(
        cfg, pcfg, None, params, r, max_seq=MAX_SEQ, step=step)
        for r in reqs}
    greedy = {r.rid: serve.greedy_reference(
        cfg, pcfg, None, params, r.prompt, r.max_new,
        max_seq=MAX_SEQ, step=step) for r in reqs}
    assert any(refs[r.rid] != greedy[r.rid] for r in reqs), (
        "temperature 0.8 never moved any token off argmax — the sampled "
        "path is not exercised")

    srv_p, out_paged = _run_paged(cfg, pcfg, params, reqs)
    dense = serve.BatchedServer(
        cfg, pcfg, None, num_slots=NUM_SLOTS, max_seq=MAX_SEQ,
        params=params)
    for r in reqs:
        dense.submit(dataclasses.replace(r, out=[]))
    out_dense = {r.rid: r.out for r in dense.run()}
    assert out_paged == refs, "paged sampled stream diverged"
    assert out_dense == refs, "dense sampled stream diverged"


# --- hierarchical topology row (DESIGN.md §10) ---------------------------

@pytest.mark.multihost
def test_hier_topology_serving_token_identical():
    """Paged serving on a two-level mesh (2 nodes x 2 devices of a 4-wide TP
    group, node-local combine before the cross-node exchange) must stream
    token-for-token what the flat 4-wide mesh streams, both for the paged
    engine (slot refill, chunked prefill) and for the batch-1 greedy
    reference on the same meshes — the topology reshapes the collectives,
    never the tokens. The comparisons are mesh-to-mesh (same batch layout,
    same sharded reductions, only the schedule differs); a sharded run is
    not token-comparable to the single-device oracle, greedy argmax sits on
    reassociated f32 sums there. Subprocess: needs 8 fake devices."""
    import json as _json
    import os as _os
    import subprocess as _sp
    import sys as _sys

    code = r"""
import dataclasses, json
import jax
import numpy as np
from repro import configs as cfglib
from repro.launch import serve
from repro.launch.mesh import make_mesh, split_model_axis
from repro.models import lm
from repro.parallel.autotune import Topology
from repro.parallel.sharding import ParallelConfig, split_tree, tree_shardings

cfg = dataclasses.replace(
    cfglib.get_smoke_config("qwen3-moe-30b-a3b"), dtype="float32")
rng = np.random.default_rng(11)
reqs = []
for i in range(6):
    plen = int(rng.integers(2, 14))
    reqs.append(serve.Request(
        rid=i,
        prompt=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
        max_new=int(rng.integers(1, 6))))

def run(mesh, pcfg):
    params, specs = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    params = jax.tree.map(jax.device_put, params,
                          tree_shardings(params, specs, pcfg, mesh))
    maxp = 32 // 4
    server = serve.PagedServer(
        cfg, pcfg, mesh, num_slots=4, page_size=4,
        num_pages=1 + 4 * maxp, max_pages_per_slot=maxp,
        params=params, prefill_chunk=5)
    for r in reqs:
        server.submit(dataclasses.replace(r, out=[]))
    done = server.run()
    server.pool.assert_consistent()
    refs = {str(r.rid): serve.greedy_reference(
        cfg, pcfg, mesh, params, r.prompt, r.max_new, max_seq=32)
        for r in reqs}
    return {str(r.rid): r.out for r in done}, refs

flat, flat_ref = run(make_mesh((2, 4), ("data", "model")),
                     ParallelConfig(blk=8))
topo = Topology(intra_bw=50e9, inter_bw=12.5e9, node_size=2)
dims, axes = split_model_axis((2, 4), ("data", "model"), topo.node_size)
hier, hier_ref = run(make_mesh(dims, axes),
                     ParallelConfig(blk=8, topology=topo))
print("RESULT" + json.dumps({"flat": flat, "hier": hier,
                             "flat_ref": flat_ref, "hier_ref": hier_ref}))
"""
    root = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
    env = dict(_os.environ)
    env["PYTHONPATH"] = _os.path.join(root, "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    res = _sp.run([_sys.executable, "-c", code], capture_output=True,
                  text=True, env=env, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT")]
    assert line, res.stdout[-2000:]
    out = _json.loads(line[-1][len("RESULT"):])
    assert out["hier"] == out["flat"], (
        "hierarchical paged serving changed the token stream")
    assert out["hier_ref"] == out["flat_ref"], (
        "hierarchical batch-1 greedy reference changed the token stream")


def test_prefill_chunk_size_is_invisible():
    """Chunked prefill is a scheduling choice, not a numerical one: chunk
    sizes 1/3/16 produce identical streams."""
    cfg = _config("mixtral-8x7b")
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _requests(cfg, 4, seed=19)
    outs = []
    maxp = MAX_SEQ // 4
    for chunk in (1, 3, 16):
        server = serve.PagedServer(
            cfg, pcfg, None, num_slots=2, page_size=4,
            num_pages=1 + 2 * maxp, max_pages_per_slot=maxp,
            params=params, prefill_chunk=chunk,
        )
        for r in reqs:
            server.submit(dataclasses.replace(r, out=[]))
        done = server.run()
        outs.append({r.rid: r.out for r in done})
    assert outs[0] == outs[1] == outs[2]


# --- speculative decoding rows (ISSUE 9, DESIGN.md §11) ------------------

class _WrongDrafter:
    """Adversarial drafter proposing deliberately wrong tokens — every
    verify round hits a mid-verify rejection and the rollback path, yet
    the committed stream must be byte-identical (the sampled correction
    token IS the non-speculative token)."""

    def draft(self, history, k, rid=-1):
        return [(int(history[-1]) + 1 + j) % 7 for j in range(k)]


def _spec_requests(cfg, n, seed):
    """Greedy + seeded-temperature mix (odd rids sample at 0.8)."""
    reqs = _requests(cfg, n, seed)
    for r in reqs:
        if r.rid % 2:
            r.temperature, r.seed = 0.8, 1000 + r.rid
        r.max_new = max(r.max_new, 3)   # give speculation room to verify
    return reqs


@pytest.mark.parametrize("arch", ARCHS)
def test_spec_stream_parity(arch):
    """Speculative ON == speculative OFF == batch-1 dense reference on
    every all-attention config in the matrix, greedy AND seeded
    temperature, with the page pool drained and rollback exercised under
    --audit (the structural oracle runs every scheduler step)."""
    cfg = _config(arch)
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _spec_requests(cfg, N_REQ, seed=47)
    step = jax.jit(steps_lib.make_serve_step(
        cfg, pcfg, None, (1, 1, cfg.d_model)))
    refs = {r.rid: serve.reference_stream(
        cfg, pcfg, None, params, dataclasses.replace(r, out=[]),
        max_seq=MAX_SEQ, step=step) for r in reqs}

    srv_off, out_off = _run_paged(cfg, pcfg, params, reqs)
    srv_on, out_on = _run_paged(cfg, pcfg, params, reqs,
                                spec=spec_lib.NGramDrafter(), audit=True)
    assert out_on == out_off == refs, (
        f"{arch}: speculative stream diverged")
    assert srv_on.spec.rounds > 0
    _assert_drained(srv_on)


def test_spec_forced_midverify_rejection_parity():
    """An adversarial always-wrong drafter forces a rejection + rollback
    every round; tokens stay identical, rollback trace events fire, and
    the audit oracle holds through every truncation."""
    cfg = _config("mixtral-8x7b")   # windowed: rollback meets reclamation
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _spec_requests(cfg, N_REQ, seed=53)

    _, out_off = _run_paged(cfg, pcfg, params, reqs)
    srv_on, out_on = _run_paged(cfg, pcfg, params, reqs,
                                spec=_WrongDrafter(), audit=True)
    assert out_on == out_off
    sp = srv_on.spec.stats()
    assert sp["drafted"] > 0 and sp["accepted_drafts"] == 0
    assert sp["rollback_tokens"] == sp["drafted"]
    assert any(ev[0] == "rollback" for ev in srv_on.trace), (
        "forced rejection never exercised _rollback")
    _assert_drained(srv_on)


def test_spec_parity_int8_kv():
    """Speculative verify writes/reads int8-quantized pages row-wise like
    prefill; streams must match the non-speculative int8 engine."""
    cfg = _config("qwen3-moe-30b-a3b")
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _spec_requests(cfg, N_REQ, seed=59)

    _, out_off = _run_paged(cfg, pcfg, params, reqs, kv_quant="int8")
    srv_on, out_on = _run_paged(cfg, pcfg, params, reqs, kv_quant="int8",
                                spec=spec_lib.NGramDrafter(), audit=True)
    assert out_on == out_off
    _assert_drained(srv_on)


def test_spec_parity_under_prefix_cache_hits():
    """Speculation on top of prefix-cache hits: rollback must only ever
    pop decode-region pages, never a refcount>1 shared prompt page (the
    pool raises on any violation), and streams stay identical."""
    cfg = _config("gemma-2b")
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _shared_prefix_requests(cfg, N_REQ, seed=61)
    for r in reqs:
        r.max_new = max(r.max_new, 3)

    srv_off, out_off = _run_paged(cfg, pcfg, params, reqs,
                                  prefix_cache=True)
    srv_on, out_on = _run_paged(cfg, pcfg, params, reqs, prefix_cache=True,
                                spec=spec_lib.NGramDrafter(), audit=True)
    assert out_on == out_off
    assert srv_on.index.stats()["hit_tokens"] > 0, "no prefix hits"
    _assert_drained(srv_on)


def test_spec_model_drafter_self_draft_full_acceptance():
    """A ModelDrafter running the TARGET's own config+params drafts
    exactly what greedy verification will sample: every draft of every
    greedy request is accepted (acceptance == 1.0) and the stream still
    equals the non-speculative engine — the strongest equivalence check
    on the multi-token score step."""
    cfg = _config("gemma-2b")
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _requests(cfg, N_REQ, seed=67)
    for r in reqs:
        r.max_new = max(r.max_new, 4)   # greedy only

    drafter = spec_lib.ModelDrafter(cfg, pcfg, None, params,
                                    max_seq=MAX_SEQ)
    _, out_off = _run_paged(cfg, pcfg, params, reqs)
    srv_on, out_on = _run_paged(cfg, pcfg, params, reqs, spec=drafter,
                                audit=True)
    assert out_on == out_off
    sp = srv_on.spec.stats()
    assert sp["drafted"] > 0
    assert sp["acceptance_rate"] == 1.0, (
        f"self-drafting must be fully accepted under greedy: {sp}")
    assert not drafter._state, "finished requests leaked draft caches"
    _assert_drained(srv_on)


def test_spec_rejects_recurrent_stack():
    """Hybrid (recurrent) stacks cannot rewind token-wise state by page
    truncation: SpecDecoder must refuse loudly at construction."""
    cfg = dataclasses.replace(
        cfglib.get_smoke_config("jamba-1.5-large-398b"), dtype="float32")
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    maxp = MAX_SEQ // 4
    server = serve.PagedServer(
        cfg, pcfg, None, num_slots=2, page_size=4, num_pages=1 + 2 * maxp,
        max_pages_per_slot=maxp, params=params)
    with pytest.raises(ValueError, match="all-attention"):
        spec_lib.SpecDecoder(server, spec_lib.NGramDrafter(), k=3)
    assert server.spec is None, "failed construction must not attach"
