"""Per-architecture smoke tests (deliverable f): every assigned arch as a
REDUCED same-family config — one train step + one decode step on CPU,
asserting shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.launch import steps as steps_lib
from repro.models import lm, swin
from repro.optim import adamw
from repro.parallel.sharding import ParallelConfig, split_tree

LM_ARCHS = [
    "qwen3-moe-30b-a3b",
    "mixtral-8x7b",
    "jamba-1.5-large-398b",
    "phi3-medium-14b",
    "starcoder2-15b",
    "gemma3-12b",
    "gemma-2b",
    "musicgen-large",
    "xlstm-350m",
    "paligemma-3b",
]

B, S = 2, 32


def _batch(cfg):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
    batch = {
        "tokens": jnp.asarray(toks),
        "labels": jnp.asarray(np.roll(toks, -1, axis=1)),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.frontend == "encodec":
        batch = {
            "embeds": jnp.asarray(
                rng.normal(size=(B, S, cfg.frontend_dim)), jnp.float32),
            "cond": jnp.asarray(rng.normal(size=(B, 8, cfg.cross_d)),
                                jnp.float32),
            "labels": jnp.asarray(
                np.repeat(np.roll(toks, -1, 1)[..., None],
                          cfg.num_codebooks, -1) % cfg.vocab_size),
            "loss_mask": batch["loss_mask"],
        }
    elif cfg.frontend == "siglip":
        npatch = cfg.prefix_len
        batch = {
            "patches": jnp.asarray(
                rng.normal(size=(B, npatch, cfg.frontend_dim)), jnp.float32),
            "tokens": batch["tokens"][:, : S - npatch],
            "labels": batch["labels"],
            "loss_mask": batch["loss_mask"],
        }
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step(arch):
    cfg = cfglib.get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    pcfg = ParallelConfig(blk=8)
    opt_cfg = adamw.OptimizerConfig(master_fp32=False)
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    opt = adamw.init_opt_state(params, opt_cfg)
    step = steps_lib.make_train_step(cfg, pcfg, None, opt_cfg,
                                     (B, S, cfg.d_model))
    p2, opt2, m = jax.jit(step)(params, opt, _batch(cfg))
    assert np.isfinite(float(m["loss"])), arch
    assert float(m["loss"]) > 0
    assert int(opt2["step"]) == 1
    # params actually changed
    delta = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), params, p2
    )
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_step(arch):
    cfg = cfglib.get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    pcfg = ParallelConfig(blk=8)
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    cache = lm.init_cache(cfg, B, 16)
    serve = steps_lib.make_serve_step(cfg, pcfg, None, (B, 1, cfg.d_model))
    inputs = {"tokens": jnp.ones((B, 1), jnp.int32)}
    if cfg.frontend == "encodec":
        inputs = {
            "embeds": jnp.ones((B, 1, cfg.frontend_dim), jnp.float32),
            "cond": jnp.ones((B, 8, cfg.cross_d), jnp.float32),
        }
    logits, cache2 = jax.jit(serve)(params, inputs, cache)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits)).all(), arch
    assert int(cache2["len"][0]) == 1
    # a second step advances
    logits3, cache3 = jax.jit(serve)(params, inputs, cache2)
    assert int(cache3["len"][0]) == 2


@pytest.mark.parametrize("arch", ["swin-moe-small", "swin-moe-base"])
def test_swin_smoke(arch):
    cfg = cfglib.get_smoke_config(arch)
    params, _ = split_tree(swin.init_swin(jax.random.PRNGKey(0), cfg))
    pcfg = ParallelConfig(blk=8)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.img_size,
                                                  cfg.img_size, 3))
    logits, aux, z = swin.swin_forward(params, x, cfg, pcfg, None)
    assert logits.shape == (2, cfg.num_classes)
    assert np.isfinite(np.asarray(logits)).all()
    assert float(aux) > 0  # MoE layers actually routed


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_full_config_loads(arch):
    """Exact assigned configs instantiate and report sane counts (no
    allocation — abstract init only)."""
    cfg = cfglib.get_config(arch)
    values, specs = lm.abstract_params(cfg)
    from repro.common import tree_params
    n = tree_params(values)
    assert n > 1e8  # every assigned arch is at least 100M params
    if arch == "jamba-1.5-large-398b":
        assert 3.5e11 < n < 4.5e11, f"jamba param count {n:.3e}"
    if arch == "mixtral-8x7b":
        assert 4.2e10 < n < 5.2e10, f"mixtral param count {n:.3e}"
    if arch == "qwen3-moe-30b-a3b":
        assert 2.6e10 < n < 3.4e10, f"qwen3 param count {n:.3e}"
