"""Adaptive centric dispatch (paper §4.5 / Fig. 10) + pipeline-shared cache.

Covers the ISSUE-1 acceptance criteria:
  (a) the runtime chooser flips model->data centric at the workload the
      Fig. 10 roofline sweep predicts (same grid, same cost model object),
  (b) mode="auto" produces bitwise-identical outputs to the forced layer
      mode — single-process AND on an 8-device mesh (subprocess),
  (c) the pipeline-shared cache never holds more than its configured number
      of layers' gathered params, while prefetch keeps the next layer warm.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import lm
from repro.parallel import autotune
from repro.parallel.cache import (
    PipelineSharedCache,
    gather_ffn_params,
    gathered_layer_bytes,
    tree_bytes,
)
from repro.parallel.sharding import ParallelConfig, split_tree

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

D, F, E, K = 1024, 4096, 8, 2  # the Fig. 10 layer


# ------------------------------------------------------------- (a) roofline

def test_choose_mode_matches_roofline_crossover():
    """The chooser's flip point == the Fig. 10 sweep's flip point."""
    grid = [2 ** i for i in range(4, 18)]
    sweep_winner = [
        "model_centric"
        if autotune.layer_latency("model_centric", t, D, F, E, K, 16)
        < autotune.layer_latency("data_centric", t, D, F, E, K, 16)
        else "data_centric"
        for t in grid
    ]
    flips = [grid[i] for i in range(1, len(grid))
             if sweep_winner[i] != sweep_winner[i - 1]]
    assert len(flips) == 1, "roofline must cross exactly once on this grid"
    crossover = flips[0]
    assert autotune.crossover_tokens(D, F, E, K, n_dev=16) == crossover
    for t in grid:
        expect = "model_centric" if t < crossover else "data_centric"
        assert autotune.choose_mode(t, D, F, E, K, n_dev=16) == expect


def test_benchmark_uses_the_same_cost_model():
    """benchmarks/centric_crossover must import (not fork) the roofline."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks import centric_crossover
    finally:
        sys.path.remove(ROOT)
    assert centric_crossover.layer_latency is autotune.layer_latency


def test_small_workload_prefers_model_centric_large_prefers_data():
    assert autotune.choose_mode(64, D, F, E, K, n_dev=16) == "model_centric"
    assert autotune.choose_mode(2 ** 17, D, F, E, K, n_dev=16) == "data_centric"


def test_effective_devices_heterogeneity():
    # homogeneous group: full size; half-speed straggler: counts as 0.5
    assert autotune.effective_devices([1.0, 1.0, 1.0, 1.0]) == 4.0
    assert autotune.effective_devices([1.0, 2.0]) == pytest.approx(1.5)
    with pytest.raises(ValueError):
        autotune.effective_devices([1.0, -1.0])


class _StubMesh:
    """Static mesh stand-in (axes()/resolve_layer_mode only read names and
    extents, never devices)."""
    axis_names = ("data", "model")
    shape = {"data": 2, "model": 8}


def test_hetero_latencies_shift_the_decision():
    """Straggler-degraded TP group: the effective device count shrinks, the
    group turns compute-bound at the crossover workload, and the tie-break
    keeps model-centric (no weight movement) where the healthy group had
    already switched to data-centric."""
    t = autotune.crossover_tokens(D, F, E, K, n_dev=8)
    healthy = ParallelConfig(mode="auto")
    degraded = ParallelConfig(mode="auto",
                              device_latencies=tuple([1.0] + [7.0] * 7))
    n_eff = autotune.effective_devices(degraded.device_latencies)
    assert n_eff == pytest.approx(2.0)
    kw = dict(d=D, f=F, e=E, k=K, mesh=_StubMesh(), layer_idx=0)
    assert autotune.resolve_layer_mode(t, cfg=healthy, **kw) == "data_centric"
    assert autotune.resolve_layer_mode(t, cfg=degraded, **kw) == "model_centric"


def test_plan_layer_modes_per_period_position():
    cfg = ModelConfig(
        name="t", family="moe", num_layers=4, d_model=D, num_heads=8,
        num_kv_heads=8, d_ff=D * 4, vocab_size=64,
        moe=MoEConfig(num_experts=E, top_k=K, d_ff=F, period=2, offset=1),
    )
    pcfg = ParallelConfig(mode="auto")
    plan = autotune.plan_layer_modes(cfg, pcfg, None, tokens=64)
    assert len(plan) == cfg.period
    assert plan[0] is None                  # dense position
    assert plan[1] in ("model_centric", "data_centric")
    # pinning the plan into the config overrides the chooser
    pinned = ParallelConfig(mode="auto", layer_mode_plan=plan)
    got = autotune.resolve_layer_mode(
        10 ** 9, d=D, f=F, e=E, k=K, cfg=pinned, mesh=None, layer_idx=1)
    assert got == plan[1]


# ------------------------------------------------- (b) auto == forced, exact

def _tiny_cfg():
    return ModelConfig(
        name="tiny-moe", family="moe", num_layers=4, d_model=32,
        num_heads=4, num_kv_heads=2, head_dim=8, d_ff=0, vocab_size=64,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=48),
    )


def _fwd(cfg, params, toks, pcfg, mode="train"):
    logits, _, aux, z = lm.forward(
        params, {"tokens": toks}, cfg, pcfg, None, mode=mode)
    return np.asarray(logits)


def test_auto_bitwise_equals_forced_single_process():
    cfg = _tiny_cfg()
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    auto = _fwd(cfg, params, toks, ParallelConfig(mode="auto", blk=16))
    for forced in ("data_centric", "model_centric"):
        got = _fwd(cfg, params, toks, ParallelConfig(
            mode="auto", blk=16, forced_layer_mode=forced))
        assert np.array_equal(auto, got), forced


def test_unrolled_cache_path_bitwise_equals_uncached():
    """The prefetch cache is an inference-side mechanism (prefill/decode);
    under the remat'd train step the remat policy is the cache instead."""
    cfg = _tiny_cfg()
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    base = _fwd(cfg, params, toks, ParallelConfig(
        mode="auto", blk=16, scan_layers=False, cache_layers=0),
        mode="prefill")
    cached = _fwd(cfg, params, toks, ParallelConfig(
        mode="auto", blk=16, scan_layers=False, cache_layers=2),
        mode="prefill")
    assert np.array_equal(base, cached)
    st = lm.LAST_PIPELINE_CACHE_STATS
    assert st is not None
    assert st["peak_resident_layers"] <= 2
    assert st["hits"] > 0  # prefetch made every later fetch a hit
    # overlap_dispatch (DESIGN.md §10): prefetching the MoE positions'
    # expert collectives alongside the fsdp gathers must stay bitwise
    # equal with the same residency bound.
    overlap = _fwd(cfg, params, toks, ParallelConfig(
        mode="auto", blk=16, scan_layers=False, cache_layers=2,
        overlap_dispatch=True),
        mode="prefill")
    assert np.array_equal(base, overlap)
    st = lm.LAST_PIPELINE_CACHE_STATS
    assert st is not None and st["peak_resident_layers"] <= 2


def test_cache_skipped_under_remat_train_and_rejected_with_scan():
    """Train mode with remat active must NOT route gathered params through
    the checkpointed period_fn (they would be saved as residuals — Janus
    residency); and cache_layers>0 with scan_layers=True is a config error,
    not a silent no-op."""
    cfg = _tiny_cfg()
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    lm.LAST_PIPELINE_CACHE_STATS = None
    _fwd(cfg, params, toks, ParallelConfig(
        mode="auto", blk=16, scan_layers=False, cache_layers=2),
        mode="train")
    assert lm.LAST_PIPELINE_CACHE_STATS is None  # prefetcher skipped
    with pytest.raises(ValueError, match="scan_layers"):
        _fwd(cfg, params, toks, ParallelConfig(
            mode="auto", blk=16, scan_layers=True, cache_layers=2),
            mode="prefill")


@pytest.mark.multihost
def test_auto_mode_on_mesh_bitwise_equals_forced():
    """8 fake CPU devices (subprocess, same idiom as test_distributed):
    mode="auto" on a (4,2) mesh must equal the forced layer mode bitwise and
    the single-device oracle numerically — for a workload on each side of
    the crossover (decode-sized vs prefill-sized)."""
    code = r"""
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import espec
from repro.parallel import autotune
from repro.parallel.moe_parallel import MoEParams, MoEStatic, moe_layer
from repro.parallel.sharding import ParallelConfig

from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
D, F, E, K = 32, 64, 4, 2
out = {}
for B, S in ((8, 16), (8, 512)):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (B, S, D), jnp.float32)
    p = MoEParams(router=jax.random.normal(ks[1], (D, E)) * 0.1,
                  w_gate=jax.random.normal(ks[2], (E, D, F)) * 0.1,
                  w_up=jax.random.normal(ks[3], (E, D, F)) * 0.1,
                  w_down=jax.random.normal(ks[4], (E, F, D)) * 0.1)
    ms = MoEStatic(num_experts=E, top_k=K, act="silu", glu=True)
    ref = espec.hexa_moe_ffn(
        x.reshape(B * S, D),
        {"router": p.router, "w_gate": p.w_gate, "w_up": p.w_up,
         "w_down": p.w_down},
        num_experts=E, top_k=K, act="silu", glu=True, blk=16).y
    ref = ref.reshape(B, S, D)
    spec = P("data", "model", None)
    chosen = autotune.choose_mode(B * S // 4, D, F, E, K, n_dev=2)
    def run(cfg):
        with mesh:
            y, aux, z = jax.jit(
                lambda x, p: moe_layer(x, p, ms, cfg, mesh, x_spec=spec)
            )(x, p)
        return np.asarray(y)
    y_auto = run(ParallelConfig(mode="auto", blk=16))
    y_forced = run(ParallelConfig(mode="auto", blk=16,
                                  forced_layer_mode=chosen))
    y_other = run(ParallelConfig(
        mode="auto", blk=16,
        forced_layer_mode=("data_centric" if chosen == "model_centric"
                           else "model_centric")))
    out[f"{B}x{S}"] = {
        "chosen": chosen,
        "bitwise_forced": bool(np.array_equal(y_auto, y_forced)),
        "err_auto": float(np.abs(y_auto - ref).max()),
        "err_other": float(np.abs(y_other - ref).max()),
    }
print("RESULT" + json.dumps(out))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    # Force CPU: with JAX_PLATFORMS unset, jax probes the TPU plugin and
    # off-TPU that stalls for minutes in GCP-metadata retries (see
    # test_distributed.run_sub). Fake devices come from XLA_FLAGS.
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT")]
    assert line, res.stdout[-2000:]
    out = json.loads(line[-1][len("RESULT"):])
    modes = {cell["chosen"] for cell in out.values()}
    for key, cell in out.items():
        assert cell["bitwise_forced"], (key, cell)
        assert cell["err_auto"] < 5e-5, (key, cell)
        assert cell["err_other"] < 5e-5, (key, cell)
    # both dispatches exercised: small workload -> model, large -> data
    assert out["8x16"]["chosen"] == "model_centric"
    assert out["8x512"]["chosen"] == "data_centric"
    assert modes == {"model_centric", "data_centric"}


# --------------------------------------------------- (c) cache residency

def test_cache_never_exceeds_capacity():
    gathers = []
    layer = {"w": jnp.zeros((4, 8, 16), jnp.bfloat16)}

    def gather(l):
        gathers.append(l)
        return layer

    cache = PipelineSharedCache(2)
    for l in range(10):
        cache.fetch(l, lambda l=l: gather(l))
        assert cache.resident_layers <= 2
        if l + 1 < 10:
            cache.prefetch(l + 1, lambda l=l: gather(l + 1))
            assert cache.resident_layers <= 2
    st = cache.stats()
    assert st["peak_resident_layers"] == 2
    assert st["misses"] == 1                    # only layer 0 stalls...
    assert st["prefetches"] == 9                # ...the rest gather ahead
    assert st["hits"] == 9                      # and hit at fetch time
    assert st["evictions"] == 8
    assert gathers == list(range(10))
    assert st["peak_resident_bytes"] == 2 * tree_bytes(layer)


def test_cache_capacity_one_and_validation():
    cache = PipelineSharedCache(1)
    for l in range(5):
        cache.fetch(l, lambda: {"w": jnp.zeros((2, 2))})
        assert cache.resident_layers == 1
    assert cache.stats()["peak_resident_layers"] == 1
    with pytest.raises(ValueError):
        PipelineSharedCache(0)


def test_evicted_layer_regathers():
    calls = {"n": 0}

    def gather():
        calls["n"] += 1
        return {"w": jnp.zeros((2, 2))}

    cache = PipelineSharedCache(1)
    cache.fetch("a", gather)
    cache.fetch("b", gather)   # evicts a
    cache.fetch("a", gather)   # must re-gather
    assert calls["n"] == 3
    cache.fetch("a", gather)   # resident -> hit
    assert calls["n"] == 3


def test_gather_ffn_params_no_mesh_is_identity():
    ffn = {
        "router": jnp.zeros((8, 4)),
        "w_gate": jnp.zeros((4, 8, 16)),
        "w_up": jnp.zeros((4, 8, 16)),
        "w_down": jnp.zeros((4, 16, 8)),
    }
    out = gather_ffn_params(ffn, ParallelConfig(mode="auto"), None)
    assert set(out) == set(ffn)
    for key in ffn:
        assert out[key] is ffn[key]


def test_gathered_layer_bytes():
    assert gathered_layer_bytes(D, F, E, glu=True) == E * 3 * D * F * 2
    mlp = gathered_layer_bytes(D, F, E, glu=False)
    assert mlp == E * 2 * D * F * 2 + E * (F + D) * 4


def test_forward_cache_bound_two_moe_positions_per_period():
    """Regression: with >1 MoE layer per period, one cache entry is the
    whole period — the residency bound counts what is actually live."""
    cfg = ModelConfig(
        name="per2", family="moe", num_layers=4, d_model=32,
        num_heads=4, num_kv_heads=2, head_dim=8, d_ff=0, vocab_size=64,
        attn_pattern=("global", "local"), window=8,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=48),
    )
    assert cfg.period == 2
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    base = _fwd(cfg, params, toks, ParallelConfig(
        mode="auto", blk=16, scan_layers=False, cache_layers=0),
        mode="prefill")
    cached = _fwd(cfg, params, toks, ParallelConfig(
        mode="auto", blk=16, scan_layers=False, cache_layers=2),
        mode="prefill")
    assert np.array_equal(base, cached)
    st = lm.LAST_PIPELINE_CACHE_STATS
    assert st["peak_resident_layers"] <= 2   # 2 periods = all 4 MoE layers
    assert st["misses"] == 1                 # period 0 stalls
    assert st["prefetches"] == 1             # period 1 gathers ahead
    assert st["hits"] == 1                   # and hits at fetch time


def test_forward_cache_bound_deep_model():
    """Through the real forward: an 8-layer MoE LM, cache capacity 2 —
    peak gathered residency stays 2 while all 8 layers are gathered."""
    cfg = ModelConfig(
        name="deep", family="moe", num_layers=8, d_model=32,
        num_heads=4, num_kv_heads=2, head_dim=8, d_ff=0, vocab_size=64,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=48),
    )
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    _fwd(cfg, params, toks, ParallelConfig(
        mode="auto", blk=16, scan_layers=False, cache_layers=2),
        mode="prefill")
    st = lm.LAST_PIPELINE_CACHE_STATS
    assert st["peak_resident_layers"] == 2
    assert st["misses"] == 1      # only period 0 on the critical path
    assert st["prefetches"] == 7  # periods 1-7 gathered ahead of use
    assert st["evictions"] == 6
