"""Mamba / xLSTM correctness: chunked-parallel training form must match
step-by-step recurrence (the decode path) exactly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import MambaConfig, ModelConfig, XLSTMConfig
from repro.models import mamba, xlstm
from repro.models.transformer import Ctx
from repro.parallel.sharding import ParallelConfig, split_tree


def _ctx(cfg, mode):
    return Ctx(cfg=cfg, pcfg=ParallelConfig(), mesh=None, mode=mode,
               positions=jnp.zeros((2, 1), jnp.int32),
               cache_len=None, x_spec=P(None, None, None))


MCFG = ModelConfig(
    name="m", family="hybrid", num_layers=1, d_model=32, num_heads=4,
    num_kv_heads=2, d_ff=0, vocab_size=16, layer_pattern=("mamba",),
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2, dt_rank=4, chunk=8),
    dtype="float32",
)

XCFG = ModelConfig(
    name="x", family="ssm", num_layers=1, d_model=32, num_heads=4,
    num_kv_heads=4, d_ff=0, vocab_size=16, layer_pattern=("mlstm",),
    xlstm=XLSTMConfig(chunk=8), dtype="float32",
)


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_mamba_chunked_equals_stepwise(chunk):
    cfg = dataclasses.replace(MCFG, mamba=dataclasses.replace(MCFG.mamba, chunk=chunk))
    p, _ = split_tree(mamba.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32))
    b, s = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.5

    y_par, _ = mamba.apply_mamba(p, x, _ctx(cfg, "train"), None)

    spec = mamba.cache_spec_mamba(cfg, b, jnp.float32)
    cache = jax.tree.map(lambda s_: jnp.zeros(s_.shape, s_.dtype), spec)
    outs = []
    ctx_d = _ctx(cfg, "decode")
    for t in range(s):
        y_t, cache = mamba.apply_mamba(p, x[:, t:t + 1], ctx_d, cache)
        outs.append(y_t)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_par), np.asarray(y_seq), rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_mlstm_chunked_equals_stepwise(chunk):
    cfg = dataclasses.replace(XCFG, xlstm=dataclasses.replace(XCFG.xlstm, chunk=chunk))
    p, _ = split_tree(xlstm.init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32))
    b, s = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.5

    y_par, _ = xlstm.apply_mlstm(p, x, _ctx(cfg, "train"), None)

    spec = xlstm.cache_spec_mlstm(cfg, b, jnp.float32)
    cache = jax.tree.map(lambda s_: jnp.zeros(s_.shape, s_.dtype), spec)
    ctx_d = _ctx(cfg, "decode")
    outs = []
    for t in range(s):
        y_t, cache = xlstm.apply_mlstm(p, x[:, t:t + 1], ctx_d, cache)
        outs.append(y_t)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_par), np.asarray(y_seq), rtol=3e-3, atol=3e-3
    )


def test_slstm_decode_continues_train_state():
    cfg = dataclasses.replace(XCFG, layer_pattern=("slstm",))
    p, _ = split_tree(xlstm.init_slstm(jax.random.PRNGKey(0), cfg, jnp.float32))
    b, s = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.5

    # full sequence at once vs one-at-a-time must agree
    y_full, _ = xlstm.apply_slstm(p, x, _ctx(cfg, "train"), None)
    spec = xlstm.cache_spec_slstm(cfg, b)
    cache = jax.tree.map(lambda s_: jnp.zeros(s_.shape, s_.dtype), spec)
    ctx_d = _ctx(cfg, "decode")
    outs = []
    for t in range(s):
        y_t, cache = xlstm.apply_slstm(p, x[:, t:t + 1], ctx_d, cache)
        outs.append(y_t)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_full), np.asarray(y_seq), rtol=2e-4, atol=2e-4
    )


def test_mamba_gradients_finite():
    p, _ = split_tree(mamba.init_mamba(jax.random.PRNGKey(0), MCFG, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, MCFG.d_model))
    def loss(p):
        y, _ = mamba.apply_mamba(p, x, _ctx(MCFG, "train"), None)
        return jnp.sum(y ** 2)
    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
