"""Direct unit coverage for runtime/elastic.py (ISSUE 7 satellite):
``choose_mesh_shape`` divisibility fallback, HBM-driven min_model
doubling, degenerate pools, and ``make_mesh`` over a shrunken device
list — previously only reachable through the end-to-end elastic path."""
import jax
import numpy as np
import pytest

from repro.runtime import elastic


def test_choose_mesh_shape_basic_factorisations():
    assert elastic.choose_mesh_shape(8) == (8, 1)
    assert elastic.choose_mesh_shape(8, min_model=2) == (4, 2)
    assert elastic.choose_mesh_shape(8, min_model=8) == (1, 8)
    assert elastic.choose_mesh_shape(1) == (1, 1)


def test_choose_mesh_shape_divisibility_fallback():
    """When min_model does not divide the pool, the model axis walks up to
    the next divisor (data * model must cover every surviving device)."""
    data, model = elastic.choose_mesh_shape(6, min_model=4)
    assert (data, model) == (1, 6)        # 4,5 rejected; 6 divides
    data, model = elastic.choose_mesh_shape(12, min_model=5)
    assert (data, model) == (2, 6)
    for n in (2, 3, 5, 6, 7, 12):
        for mm in (1, 2, 3, 4, n):
            d, m = elastic.choose_mesh_shape(n, min_model=mm)
            assert d * m == n, (n, mm, d, m)


def test_choose_mesh_shape_prime_survivor_count():
    """A prime pool (the classic 'one host died' shape) still yields a
    full-cover mesh."""
    d, m = elastic.choose_mesh_shape(7, min_model=2)
    assert d * m == 7


def test_choose_mesh_shape_hbm_doubles_min_model():
    gib = 2**30
    # 24 GiB of params on 16 GiB chips: one TP shard must hold <= 8 GiB,
    # so min_model doubles 1 -> 2 -> 4 (24/2 = 12 > 8, 24/4 = 6 <= 8).
    d, m = elastic.choose_mesh_shape(8, param_bytes=24 * gib,
                                     hbm_bytes=16 * gib)
    assert (d, m) == (2, 4)
    # small model: HBM imposes nothing
    assert elastic.choose_mesh_shape(8, param_bytes=1 * gib,
                                     hbm_bytes=16 * gib) == (8, 1)


def test_choose_mesh_shape_max_model_caps():
    d, m = elastic.choose_mesh_shape(8, min_model=3, max_model=2)
    assert m <= 2


def test_choose_mesh_shape_degenerate_pool():
    """A pool too small for the HBM-driven min_model still returns a
    usable (possibly memory-oversubscribed) mesh rather than failing —
    min_model stops doubling at the pool size."""
    gib = 2**30
    d, m = elastic.choose_mesh_shape(2, param_bytes=1000 * gib,
                                     hbm_bytes=16 * gib)
    assert d * m == 2 and m == 2


def test_make_mesh_over_shrunken_device_list():
    """The elastic-shrink call pattern: re-mesh over an explicit survivor
    subset (devices= the ones that did not drop)."""
    devs = jax.devices()
    mesh = elastic.make_mesh((1, 1), ("data", "model"), devices=devs[:1])
    assert mesh.devices.shape == (1, 1)
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices[0, 0] == devs[0]
    # default path uses the global pool
    mesh2 = elastic.make_mesh((1,), ("data",))
    assert mesh2.devices.shape == (1,)


def test_choose_then_make_roundtrip():
    n = len(jax.devices())
    shape = elastic.choose_mesh_shape(n)
    mesh = elastic.make_mesh(shape, ("data", "model"))
    assert int(np.prod(mesh.devices.shape)) == n
