"""Per-kernel allclose sweeps: Pallas (interpret) and ragged vs the pure-jnp
oracle, across shapes and dtypes (the deliverable-(c) kernel contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.reindex import build_reindex, gather_sorted
from repro.kernels import ref
from repro.kernels.esfk import esfk_pallas
from repro.kernels.esmm import esmm_pallas
from repro.kernels.ess import ess_pallas
from repro.kernels.estmm import estmm_pallas

SHAPES = [
    # (n_tokens, k, E, D1, D2, blk)
    (32, 1, 2, 16, 32, 8),
    (64, 2, 4, 32, 16, 16),
    (48, 2, 3, 16, 16, 8),
    (16, 4, 8, 32, 64, 8),   # many empty experts likely
    (128, 1, 1, 64, 32, 32),  # single expert
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _setup(n, k, e, d1, d2, blk, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    ei = jax.random.randint(ks[0], (n, k), 0, e)
    g = jax.random.uniform(ks[1], (n, k))
    ri = build_reindex(ei, g, e, blk)
    x = jax.random.normal(ks[2], (n, d1)).astype(dtype)
    xs = gather_sorted(x, ri)
    w = (jax.random.normal(ks[3], (e, d1, d2)) * 0.3).astype(dtype)
    b = (jax.random.normal(ks[4], (e, d2)) * 0.3).astype(dtype)
    dy = jax.random.normal(ks[5], (ri.num_rows, d2)).astype(dtype)
    return ri, xs, w, b, dy


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_esmm_pallas(shape, dtype):
    n, k, e, d1, d2, blk = shape
    ri, xs, w, b, _ = _setup(*shape, dtype)
    out = esmm_pallas(xs, w, b, ri.block_expert, bm=blk, bn=min(128, d2),
                      bk=min(128, d1))
    want = ref.esmm(xs, w, b, ri.block_expert)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("dtype", DTYPES)
def test_esmm_pallas_transposed(shape, dtype):
    # transpose_rhs contracts dy (Np, D2) against w (E, D1, D2) on D2:
    # the backward-dX orientation reuses the forward weight array as-is.
    n, k, e, d1, d2, blk = shape
    ri, xs, w, b, dy = _setup(*shape, dtype)
    out = esmm_pallas(dy, w, None, ri.block_expert, transpose_rhs=True,
                      bm=blk)
    want = ref.esmm(dy, w, None, ri.block_expert, transpose_rhs=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_ess_pallas(shape, dtype):
    n, k, e, d1, d2, blk = shape
    ri, xs, w, b, dy = _setup(*shape, dtype)
    out = ess_pallas(dy, ri.block_expert, ri.padded_counts, bm=blk)
    want = ref.ess(dy.astype(jnp.float32), ri.block_expert, e)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_estmm_pallas(shape, dtype):
    n, k, e, d1, d2, blk = shape
    ri, xs, w, b, dy = _setup(*shape, dtype)
    out = estmm_pallas(xs, dy, ri.block_expert, ri.padded_counts, bm=blk)
    want = ref.estmm(
        xs.astype(jnp.float32), dy.astype(jnp.float32), ri.block_expert, e
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_esfk_fused_matches_unfused(shape, dtype):
    n, k, e, d1, d2, blk = shape
    ri, xs, w, b, dy = _setup(*shape, dtype)
    dw_f, db_f = esfk_pallas(xs, dy, ri.block_expert, ri.padded_counts, bm=blk)
    dw_u = estmm_pallas(xs, dy, ri.block_expert, ri.padded_counts, bm=blk)
    db_u = ess_pallas(dy, ri.block_expert, ri.padded_counts, bm=blk)
    np.testing.assert_allclose(np.asarray(dw_f), np.asarray(dw_u), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(db_f), np.asarray(db_u), rtol=1e-6)


def test_esfk_empty_expert_grads_zero():
    """Experts with zero routed tokens must get exactly-zero grads."""
    n, k, e, d1, d2, blk = 16, 1, 4, 16, 16, 8
    ei = jnp.zeros((n, k), jnp.int32)  # everything to expert 0
    g = jnp.ones((n, k))
    ri = build_reindex(ei, g, e, blk)
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d1))
    xs = gather_sorted(x, ri)
    dy = jax.random.normal(jax.random.PRNGKey(1), (ri.num_rows, d2))
    dw, db = esfk_pallas(xs, dy, ri.block_expert, ri.padded_counts, bm=blk)
    assert np.abs(np.asarray(dw[1:])).max() == 0.0
    assert np.abs(np.asarray(db[1:])).max() == 0.0
    assert np.abs(np.asarray(dw[0])).max() > 0.0
