"""Checkpoint manager: save -> wait -> restore round-trips, gc_old keep
boundaries, and AsyncSaver failure propagation — the guarantees a serving
warm-restart leans on (ISSUE 4 satellite)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (4, 8), jnp.float32),
        "moments": {
            "bf16": jax.random.normal(k, (3, 5)).astype(jnp.bfloat16),
            "step": jnp.asarray(7, jnp.int32),
        },
        "list": [jnp.arange(6), jnp.ones((2,), jnp.float32)],
    }


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert la.dtype == lb.dtype
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_sync_roundtrip(tmp_path):
    tree = _tree()
    path = manager.save(str(tmp_path), 3, tree, meta={"tag": "x"})
    assert path.endswith("step_00000003")
    assert manager.latest_step(str(tmp_path)) == 3
    restored, meta = manager.restore(str(tmp_path), 3, tree)
    assert meta == {"tag": "x"}
    _assert_trees_equal(tree, restored)


def test_async_save_wait_restore_roundtrip(tmp_path):
    """The serving warm-restart sequence: save_async -> wait -> restore."""
    saver = manager.AsyncSaver()
    tree = _tree(1)
    saver.save(str(tmp_path), 10, tree, meta={"k": 1})
    saver.wait()
    assert saver.last_path is not None and saver.last_path.endswith(
        "step_00000010")
    # a second save waits for the first and supersedes it
    tree2 = _tree(2)
    saver.save(str(tmp_path), 11, tree2)
    saver.wait()
    assert manager.latest_step(str(tmp_path)) == 11
    restored, _ = manager.restore(str(tmp_path), 11, tree2)
    _assert_trees_equal(tree2, restored)
    # the earlier checkpoint is still intact (no cross-step clobbering)
    restored10, meta10 = manager.restore(str(tmp_path), 10, tree)
    assert meta10 == {"k": 1}
    _assert_trees_equal(tree, restored10)


def test_async_failure_propagates_on_wait(tmp_path):
    """A failed background write must surface, not leave last_path stale
    while the trainer keeps gc'ing good checkpoints."""
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("occupied")
    saver = manager.AsyncSaver()
    # target "directory" is a regular file -> the background mkdir fails
    saver.save(str(blocker), 1, _tree())
    with pytest.raises(OSError):
        saver.wait()
    # the error is consumed: the saver is reusable afterwards
    saver.save(str(tmp_path), 2, _tree())
    saver.wait()
    assert manager.latest_step(str(tmp_path)) == 2


def test_latest_step_ignores_tmp(tmp_path):
    manager.save(str(tmp_path), 1, _tree())
    os.makedirs(tmp_path / "step_00000099.tmp")
    assert manager.latest_step(str(tmp_path)) == 1


def test_gc_old_keep_boundary(tmp_path):
    tree = {"x": jnp.arange(3)}
    for step in (1, 2, 5, 9):
        manager.save(str(tmp_path), step, tree)
    manager.gc_old(str(tmp_path), keep=2)
    left = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert left == ["step_00000005", "step_00000009"]
    # keep >= count: nothing deleted
    manager.gc_old(str(tmp_path), keep=10)
    assert len(os.listdir(tmp_path)) == 2
    # keep=0 deletes everything (the old [:-0] slice kept everything)
    manager.gc_old(str(tmp_path), keep=0)
    assert [d for d in os.listdir(tmp_path) if d.startswith("step_")] == []
    with pytest.raises(ValueError):
        manager.gc_old(str(tmp_path), keep=-1)


def test_gc_old_never_touches_tmp(tmp_path):
    manager.save(str(tmp_path), 1, _tree())
    manager.save(str(tmp_path), 2, _tree())
    os.makedirs(tmp_path / "step_00000000.tmp")
    manager.gc_old(str(tmp_path), keep=1)
    left = sorted(os.listdir(tmp_path))
    assert left == ["step_00000000.tmp", "step_00000002"]


def test_restore_applies_dtype_views(tmp_path):
    """bf16 leaves survive the uint16 npy view round-trip bit-exactly."""
    tree = {"b": jnp.asarray([1.5, -2.25, 3.0], jnp.bfloat16)}
    manager.save(str(tmp_path), 1, tree)
    on_disk = np.load(tmp_path / "step_00000001" / "a_00000.npy")
    assert on_disk.dtype == np.uint16  # stored as the view, not float
    restored, _ = manager.restore(str(tmp_path), 1, tree)
    _assert_trees_equal(tree, restored)


def test_mixed_dtype_tree_roundtrip(tmp_path):
    """int8 payload + f32 scale + fp8 leaves (a quantized-expert tree,
    ISSUE 5) round-trip bit-exactly with their dtypes intact."""
    rng = np.random.default_rng(0)
    tree = {
        "ffn": {
            "w_gate": jnp.asarray(
                rng.integers(-127, 128, size=(2, 4, 8)), jnp.int8),
            "w_gate_scale": jnp.asarray(
                rng.random((2, 1, 1)), jnp.float32),
            "w_up": jnp.asarray(rng.random((2, 4, 8)),
                                jnp.float8_e4m3fn),
            "router": jnp.asarray(rng.random((4, 2)), jnp.float32),
        },
        "step": jnp.asarray(3, jnp.int32),
    }
    manager.save(str(tmp_path), 7, tree)
    restored, _ = manager.restore(str(tmp_path), 7, tree)
    _assert_trees_equal(tree, restored)
    assert restored["ffn"]["w_gate"].dtype == jnp.int8
    assert restored["ffn"]["w_up"].dtype == jnp.float8_e4m3fn


def test_restore_rejects_dtype_mismatch(tmp_path):
    """A target structure whose leaf dtype disagrees with the checkpoint
    fails loudly instead of silently casting (the failure mode that would
    corrupt int8 payload / f32 scale pairs)."""
    tree = {"w": jnp.asarray([1, -2, 3], jnp.int8),
            "s": jnp.asarray([0.5], jnp.float32)}
    manager.save(str(tmp_path), 1, tree)
    wrong = {"w": jnp.asarray([1.0, -2.0, 3.0], jnp.float32),
             "s": jnp.asarray([0.5], jnp.float32)}
    with pytest.raises(ValueError, match="dtype mismatch"):
        manager.restore(str(tmp_path), 1, wrong)
    # manifest records the logical dtypes
    with open(tmp_path / "step_00000001" / "manifest.json") as f:
        m = json.load(f)
    assert m["dtypes"] == ["float32", "int8"] or m["dtypes"] == [
        "int8", "float32"]


def test_manifest_is_valid_json(tmp_path):
    manager.save(str(tmp_path), 4, _tree(), meta={"note": "hi"})
    with open(tmp_path / "step_00000004" / "manifest.json") as f:
        m = json.load(f)
    assert m["step"] == 4 and m["num_leaves"] == 5 and m["meta"] == {
        "note": "hi"}


# ---------------------------------------------------------------------------
# integrity: crc32/nbytes manifest record, verify, latest_valid_step (ISSUE 7)
# ---------------------------------------------------------------------------

from repro.runtime import faults as faults_lib  # noqa: E402


def _corrupt(path, leaf=0, mode="bitflip"):
    f = path / f"a_{leaf:05d}.npy"
    data = bytearray(f.read_bytes())
    if mode == "bitflip":
        data[len(data) // 2] ^= 0x40
    else:
        data = data[: len(data) // 2]
    f.write_bytes(bytes(data))


def test_manifest_records_crc_and_bytes(tmp_path):
    manager.save(str(tmp_path), 1, _tree())
    with open(tmp_path / "step_00000001" / "manifest.json") as f:
        m = json.load(f)
    assert len(m["crc32"]) == m["num_leaves"]
    assert len(m["nbytes"]) == m["num_leaves"]
    # the recorded counts are the exact on-disk file sizes
    for i, n in enumerate(m["nbytes"]):
        assert (tmp_path / "step_00000001" / f"a_{i:05d}.npy"
                ).stat().st_size == n
    manager.verify(str(tmp_path / "step_00000001"))  # clean -> no raise


def test_verify_catches_bitflip_and_truncation(tmp_path):
    manager.save(str(tmp_path), 1, _tree())
    p = tmp_path / "step_00000001"
    _corrupt(p, leaf=2, mode="bitflip")
    with pytest.raises(manager.CheckpointCorruptError, match="crc32"):
        manager.verify(str(p))
    manager.save(str(tmp_path), 2, _tree())
    p2 = tmp_path / "step_00000002"
    _corrupt(p2, leaf=0, mode="truncate")
    with pytest.raises(manager.CheckpointCorruptError, match="truncated"):
        manager.verify(str(p2))


def test_restore_refuses_corrupt_checkpoint(tmp_path):
    tree = _tree()
    manager.save(str(tmp_path), 1, tree)
    _corrupt(tmp_path / "step_00000001")
    with pytest.raises(manager.CheckpointCorruptError):
        manager.restore(str(tmp_path), 1, tree)


def test_latest_valid_step_skips_corrupt(tmp_path):
    """The fallback-restore contract: the newest checkpoint is damaged, so
    latest_valid_step must return the older intact one (latest_step still
    reports the damaged newest — that asymmetry IS the feature)."""
    tree = _tree()
    manager.save(str(tmp_path), 1, tree)
    manager.save(str(tmp_path), 2, tree)
    _corrupt(tmp_path / "step_00000002", mode="truncate")
    assert manager.latest_step(str(tmp_path)) == 2
    assert manager.latest_valid_step(str(tmp_path)) == 1
    assert manager.valid_steps(str(tmp_path)) == [1]
    # missing leaf file is also invalid
    manager.save(str(tmp_path), 3, tree)
    os.remove(tmp_path / "step_00000003" / "a_00000.npy")
    assert manager.latest_valid_step(str(tmp_path)) == 1
    # unreadable manifest is also invalid
    manager.save(str(tmp_path), 4, tree)
    (tmp_path / "step_00000004" / "manifest.json").write_text("{broken")
    assert manager.latest_valid_step(str(tmp_path)) == 1


def test_pre_integrity_checkpoints_still_verify(tmp_path):
    """Checkpoints written before the crc32 record existed must keep
    loading (manifest without crc32/nbytes passes verification)."""
    manager.save(str(tmp_path), 1, _tree())
    mpath = tmp_path / "step_00000001" / "manifest.json"
    m = json.loads(mpath.read_text())
    del m["crc32"], m["nbytes"]
    mpath.write_text(json.dumps(m))
    manager.verify(str(tmp_path / "step_00000001"))
    restored, _ = manager.restore(str(tmp_path), 1, _tree())
    _assert_trees_equal(_tree(), restored)


def test_async_post_hook_runs_after_commit(tmp_path):
    """The GC-ordering contract: post() sees the committed checkpoint."""
    seen = []
    saver = manager.AsyncSaver()
    saver.save(str(tmp_path), 5, _tree(),
               post=lambda p: seen.append(
                   (p, manager.latest_step(str(tmp_path)))))
    saver.wait()
    assert seen and seen[0][0].endswith("step_00000005")
    assert seen[0][1] == 5
    # a failing write never runs post
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("occupied")
    saver.save(str(blocker), 6, _tree(), post=lambda p: seen.append("bad"))
    with pytest.raises(OSError):
        saver.wait()
    assert "bad" not in seen


def test_ckpt_write_fault_site_corrupts_after_commit(tmp_path):
    """The chaos hook: a scripted truncate fault at ckpt.write damages the
    committed checkpoint exactly the way verify detects."""
    plan = faults_lib.FaultPlan([
        faults_lib.Fault(site="ckpt.write", kind="truncate", at=1,
                         payload={"leaf": 0}),
    ])
    with faults_lib.scope(plan):
        manager.save(str(tmp_path), 1, _tree())   # call 0: intact
        manager.save(str(tmp_path), 2, _tree())   # call 1: corrupted
    assert plan.fired == [("ckpt.write", 1, "truncate")]
    manager.verify(str(tmp_path / "step_00000001"))
    with pytest.raises(manager.CheckpointCorruptError):
        manager.verify(str(tmp_path / "step_00000002"))
    assert manager.latest_valid_step(str(tmp_path)) == 1
