"""Checkpoint manager: save -> wait -> restore round-trips, gc_old keep
boundaries, and AsyncSaver failure propagation — the guarantees a serving
warm-restart leans on (ISSUE 4 satellite)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (4, 8), jnp.float32),
        "moments": {
            "bf16": jax.random.normal(k, (3, 5)).astype(jnp.bfloat16),
            "step": jnp.asarray(7, jnp.int32),
        },
        "list": [jnp.arange(6), jnp.ones((2,), jnp.float32)],
    }


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert la.dtype == lb.dtype
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_sync_roundtrip(tmp_path):
    tree = _tree()
    path = manager.save(str(tmp_path), 3, tree, meta={"tag": "x"})
    assert path.endswith("step_00000003")
    assert manager.latest_step(str(tmp_path)) == 3
    restored, meta = manager.restore(str(tmp_path), 3, tree)
    assert meta == {"tag": "x"}
    _assert_trees_equal(tree, restored)


def test_async_save_wait_restore_roundtrip(tmp_path):
    """The serving warm-restart sequence: save_async -> wait -> restore."""
    saver = manager.AsyncSaver()
    tree = _tree(1)
    saver.save(str(tmp_path), 10, tree, meta={"k": 1})
    saver.wait()
    assert saver.last_path is not None and saver.last_path.endswith(
        "step_00000010")
    # a second save waits for the first and supersedes it
    tree2 = _tree(2)
    saver.save(str(tmp_path), 11, tree2)
    saver.wait()
    assert manager.latest_step(str(tmp_path)) == 11
    restored, _ = manager.restore(str(tmp_path), 11, tree2)
    _assert_trees_equal(tree2, restored)
    # the earlier checkpoint is still intact (no cross-step clobbering)
    restored10, meta10 = manager.restore(str(tmp_path), 10, tree)
    assert meta10 == {"k": 1}
    _assert_trees_equal(tree, restored10)


def test_async_failure_propagates_on_wait(tmp_path):
    """A failed background write must surface, not leave last_path stale
    while the trainer keeps gc'ing good checkpoints."""
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("occupied")
    saver = manager.AsyncSaver()
    # target "directory" is a regular file -> the background mkdir fails
    saver.save(str(blocker), 1, _tree())
    with pytest.raises(OSError):
        saver.wait()
    # the error is consumed: the saver is reusable afterwards
    saver.save(str(tmp_path), 2, _tree())
    saver.wait()
    assert manager.latest_step(str(tmp_path)) == 2


def test_latest_step_ignores_tmp(tmp_path):
    manager.save(str(tmp_path), 1, _tree())
    os.makedirs(tmp_path / "step_00000099.tmp")
    assert manager.latest_step(str(tmp_path)) == 1


def test_gc_old_keep_boundary(tmp_path):
    tree = {"x": jnp.arange(3)}
    for step in (1, 2, 5, 9):
        manager.save(str(tmp_path), step, tree)
    manager.gc_old(str(tmp_path), keep=2)
    left = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert left == ["step_00000005", "step_00000009"]
    # keep >= count: nothing deleted
    manager.gc_old(str(tmp_path), keep=10)
    assert len(os.listdir(tmp_path)) == 2
    # keep=0 deletes everything (the old [:-0] slice kept everything)
    manager.gc_old(str(tmp_path), keep=0)
    assert [d for d in os.listdir(tmp_path) if d.startswith("step_")] == []
    with pytest.raises(ValueError):
        manager.gc_old(str(tmp_path), keep=-1)


def test_gc_old_never_touches_tmp(tmp_path):
    manager.save(str(tmp_path), 1, _tree())
    manager.save(str(tmp_path), 2, _tree())
    os.makedirs(tmp_path / "step_00000000.tmp")
    manager.gc_old(str(tmp_path), keep=1)
    left = sorted(os.listdir(tmp_path))
    assert left == ["step_00000000.tmp", "step_00000002"]


def test_restore_applies_dtype_views(tmp_path):
    """bf16 leaves survive the uint16 npy view round-trip bit-exactly."""
    tree = {"b": jnp.asarray([1.5, -2.25, 3.0], jnp.bfloat16)}
    manager.save(str(tmp_path), 1, tree)
    on_disk = np.load(tmp_path / "step_00000001" / "a_00000.npy")
    assert on_disk.dtype == np.uint16  # stored as the view, not float
    restored, _ = manager.restore(str(tmp_path), 1, tree)
    _assert_trees_equal(tree, restored)


def test_mixed_dtype_tree_roundtrip(tmp_path):
    """int8 payload + f32 scale + fp8 leaves (a quantized-expert tree,
    ISSUE 5) round-trip bit-exactly with their dtypes intact."""
    rng = np.random.default_rng(0)
    tree = {
        "ffn": {
            "w_gate": jnp.asarray(
                rng.integers(-127, 128, size=(2, 4, 8)), jnp.int8),
            "w_gate_scale": jnp.asarray(
                rng.random((2, 1, 1)), jnp.float32),
            "w_up": jnp.asarray(rng.random((2, 4, 8)),
                                jnp.float8_e4m3fn),
            "router": jnp.asarray(rng.random((4, 2)), jnp.float32),
        },
        "step": jnp.asarray(3, jnp.int32),
    }
    manager.save(str(tmp_path), 7, tree)
    restored, _ = manager.restore(str(tmp_path), 7, tree)
    _assert_trees_equal(tree, restored)
    assert restored["ffn"]["w_gate"].dtype == jnp.int8
    assert restored["ffn"]["w_up"].dtype == jnp.float8_e4m3fn


def test_restore_rejects_dtype_mismatch(tmp_path):
    """A target structure whose leaf dtype disagrees with the checkpoint
    fails loudly instead of silently casting (the failure mode that would
    corrupt int8 payload / f32 scale pairs)."""
    tree = {"w": jnp.asarray([1, -2, 3], jnp.int8),
            "s": jnp.asarray([0.5], jnp.float32)}
    manager.save(str(tmp_path), 1, tree)
    wrong = {"w": jnp.asarray([1.0, -2.0, 3.0], jnp.float32),
             "s": jnp.asarray([0.5], jnp.float32)}
    with pytest.raises(ValueError, match="dtype mismatch"):
        manager.restore(str(tmp_path), 1, wrong)
    # manifest records the logical dtypes
    with open(tmp_path / "step_00000001" / "manifest.json") as f:
        m = json.load(f)
    assert m["dtypes"] == ["float32", "int8"] or m["dtypes"] == [
        "int8", "float32"]


def test_manifest_is_valid_json(tmp_path):
    manager.save(str(tmp_path), 4, _tree(), meta={"note": "hi"})
    with open(tmp_path / "step_00000004" / "manifest.json") as f:
        m = json.load(f)
    assert m["step"] == 4 and m["num_leaves"] == 5 and m["meta"] == {
        "note": "hi"}
