"""Hierarchical (two-level) MoE dispatch parity (DESIGN.md §10), on an
8-fake-device mesh arranged as 2 nodes x 4 devices per node.

The hierarchical schedule must change WHERE bytes move, never WHAT is
computed:

  * data-centric rows (phased gathers only) are BITWISE equal to the flat
    schedule — gathers concatenate in tuple-axis order, exactly;
  * model-centric rows (node-local combine before the cross-node exchange)
    reassociate one f32 reduction, so they are tight-allclose;
  * flat meshes with a topology attached, and uniform single-node
    topologies, short-circuit — the lowered HLO is IDENTICAL text to the
    pre-topology path;
  * the overlap schedule (``overlap_dispatch``: next layer's expert
    collectives prefetched during current-layer compute) is bitwise equal
    to the eager schedule with cache residency still bounded.

All subprocess tests (multihost tier): the main pytest process keeps the
1-device contract.
"""
import json
import math
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.multihost  # subprocess fake-device mesh tier

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, timeout: int = 900) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT")]
    assert line, res.stdout[-2000:]
    return json.loads(line[-1][len("RESULT"):])


ISLAND_PREAMBLE = r"""
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.moe_parallel import MoEParams, MoEStatic, moe_layer
from repro.parallel.sharding import ParallelConfig
from repro.parallel.autotune import Topology
from repro.launch.mesh import make_mesh, split_model_axis

B, S, D, F, E, K = 8, 16, 32, 64, 4, 2
ks = jax.random.split(jax.random.PRNGKey(0), 6)
x = jax.random.normal(ks[0], (B, S, D), jnp.float32)
p = MoEParams(router=jax.random.normal(ks[1], (D, E)) * 0.1,
              w_gate=jax.random.normal(ks[2], (E, D, F)) * 0.1,
              w_up=jax.random.normal(ks[3], (E, D, F)) * 0.1,
              w_down=jax.random.normal(ks[4], (E, F, D)) * 0.1)
ms = MoEStatic(num_experts=E, top_k=K, act="silu", glu=True)

# 2 nodes x 4 devices: TP group of 4 spans both nodes ((node, model) =
# (2, 2)); the equivalent flat mesh keeps TP as a single 4-wide axis.
topo = Topology(intra_bw=50e9, inter_bw=12.5e9, node_size=2)
dims, axes = split_model_axis((2, 4), ("data", "model"), topo.node_size)
assert dims == (2, 2, 2) and axes == ("data", "node", "model")
mesh_flat = make_mesh((2, 4), ("data", "model"))
mesh_node = make_mesh(dims, axes)
SPEC_FLAT = P("data", "model", None)
SPEC_NODE = P("data", ("node", "model"), None)

def run(cfg, mesh, spec):
    with mesh:
        y, aux, z = jax.jit(
            lambda x, p: moe_layer(x, p, ms, cfg, mesh, x_spec=spec)
        )(x, p)
    return np.asarray(y), float(aux)
"""


def test_hier_island_forward_and_grad_parity():
    out = run_sub(ISLAND_PREAMBLE + r"""
rows = {}
for mode in ("data_centric", "model_centric"):
    for sched in ("ag_rs", "ag_ar"):
        yf, af = run(ParallelConfig(mode="auto", blk=16,
                                    collective_schedule=sched,
                                    forced_layer_mode=mode),
                     mesh_flat, SPEC_FLAT)
        yh, ah = run(ParallelConfig(mode="auto", blk=16,
                                    collective_schedule=sched,
                                    forced_layer_mode=mode, topology=topo),
                     mesh_node, SPEC_NODE)
        rows[f"{mode}/{sched}"] = {
            "bitwise": bool(np.array_equal(yf, yh)),
            "maxdiff": float(np.abs(yf - yh).max()),
            "aux_diff": abs(af - ah),
        }

# auto chooser on both meshes (same TP group size, same token workload)
ya, _ = run(ParallelConfig(mode="auto", blk=16), mesh_flat, SPEC_FLAT)
yb, _ = run(ParallelConfig(mode="auto", blk=16, topology=topo),
            mesh_node, SPEC_NODE)
rows["auto"] = {"maxdiff": float(np.abs(ya - yb).max())}

# gradient parity through the hierarchical combine
def loss(p, cfg, mesh, spec):
    y, aux, z = moe_layer(x, p, ms, cfg, mesh, x_spec=spec)
    return jnp.sum(y ** 2) + aux
with mesh_flat:
    gf = jax.jit(jax.grad(lambda p: loss(
        p, ParallelConfig(mode="hybrid", blk=16), mesh_flat, SPEC_FLAT)))(p)
with mesh_node:
    gh = jax.jit(jax.grad(lambda p: loss(
        p, ParallelConfig(mode="hybrid", blk=16, topology=topo),
        mesh_node, SPEC_NODE)))(p)
rows["grad_maxdiff"] = max(
    float(jnp.abs(a - b).max())
    for a, b in zip(jax.tree.leaves(gf), jax.tree.leaves(gh)))
print("RESULT" + json.dumps(rows))
""")
    for sched in ("ag_rs", "ag_ar"):
        # phased gathers concatenate in tuple-axis order: exact
        assert out[f"data_centric/{sched}"]["bitwise"], out
        # node-local combine reassociates one f32 reduction: tight
        assert out[f"model_centric/{sched}"]["maxdiff"] < 1e-5, out
    for row in out.values():
        if isinstance(row, dict) and "aux_diff" in row:
            assert row["aux_diff"] < 1e-6, out
    assert out["auto"]["maxdiff"] < 1e-5, out
    assert out["grad_maxdiff"] < 1e-5, out


def test_flat_topology_identical_hlo():
    """The short-circuits pinned at the HLO level: a topology on a mesh
    without a "node" axis, and a single-node topology, must lower to
    IDENTICAL HLO text as the pre-topology path (not just equal outputs)."""
    out = run_sub(ISLAND_PREAMBLE + r"""
def hlo(cfg, mesh, spec):
    with mesh:
        return jax.jit(
            lambda x, p: moe_layer(x, p, ms, cfg, mesh, x_spec=spec)
        ).lower(x, p).as_text()

rows = {}
base = hlo(ParallelConfig(mode="auto", blk=16), mesh_flat, SPEC_FLAT)
# topology attached but the mesh carries no node axis -> flat schedule
rows["flat_mesh"] = hlo(
    ParallelConfig(mode="auto", blk=16, topology=topo),
    mesh_flat, SPEC_FLAT) == base
# node mesh, single-node topology (node axis extent 1 after split_model_axis
# refuses to split): degenerate — identical to the flat mesh program
d2, a2 = split_model_axis((2, 4), ("data", "model"), 4)
rows["no_split"] = (d2, a2) == ((2, 4), ("data", "model"))
# the hierarchical program must NOT be textually identical (it really does
# emit different collectives)
rows["hier_differs"] = hlo(
    ParallelConfig(mode="auto", blk=16, topology=topo,
                   forced_layer_mode="model_centric"),
    mesh_node, SPEC_NODE) != hlo(
    ParallelConfig(mode="auto", blk=16,
                   forced_layer_mode="model_centric"),
    mesh_flat, SPEC_FLAT)
print("RESULT" + json.dumps(rows))
""")
    assert out["flat_mesh"], "topology on a flat mesh must not change HLO"
    assert out["no_split"]
    assert out["hier_differs"]


def test_hier_train_step_hetero_and_quant_rows():
    """LM-level parity, flat (2,4) vs hierarchical (2,2,2): two train steps
    + a forward under (a) plain auto, (b) an uneven HeteroPlan (Eq. 1 tail
    masking), (c) a plan carrying hidden_splits + per-class int8
    ``expert_bits`` (DESIGN.md §8 pricing), (d) int8 QAT fake-quant."""
    out = run_sub(r"""
import json, dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs.base import ModelConfig, MoEConfig
from repro.core import hetero as hetero_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh, split_model_axis
from repro.models import lm
from repro.optim import adamw
from repro.parallel.autotune import Topology
from repro.parallel.sharding import ParallelConfig, split_tree, tree_shardings

cfg = ModelConfig(
    name="tiny-moe", family="moe", num_layers=4, d_model=32,
    num_heads=4, num_kv_heads=2, head_dim=8, d_ff=0, vocab_size=64,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=64),
)
topo = Topology(intra_bw=50e9, inter_bw=12.5e9, node_size=2)
dims, axes = split_model_axis((2, 4), ("data", "model"), topo.node_size)
mesh_flat = make_mesh((2, 4), ("data", "model"))
mesh_node = make_mesh(dims, axes)
B, S = 8, 32
rng = np.random.default_rng(0)
toks = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
batch = {"tokens": jnp.asarray(toks),
         "labels": jnp.asarray(np.roll(toks, -1, 1)),
         "loss_mask": jnp.ones((B, S), jnp.float32)}
opt_cfg = adamw.OptimizerConfig(master_fp32=False)

def run(mesh, pcfg, plan=None, batch=batch, eff_b=B):
    params, specs = split_tree(
        lm.init_params(jax.random.PRNGKey(0), cfg, plan=plan))
    params = jax.tree.map(jax.device_put, params,
                          tree_shardings(params, specs, pcfg, mesh))
    opt = adamw.init_opt_state(params, opt_cfg)
    step = jax.jit(steps_lib.make_train_step(cfg, pcfg, mesh, opt_cfg,
                                             (eff_b, S, cfg.d_model)))
    losses = []
    with mesh:
        # forward parity at the UNTRAINED params (tight); the optimizer
        # normalizes grads by sqrt(v), amplifying reassociation noise, so
        # post-step parity is asserted on the losses instead
        logits, _, _, _ = jax.jit(
            lambda p, t: lm.forward(p, {"tokens": t}, cfg, pcfg, mesh,
                                    mode="prefill",
                                    x_spec=P("data", None, None)))(
            params, batch["tokens"])
        for _ in range(2):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
    return losses, np.asarray(logits)

def pair(name, plan=None, quant="none", batch=batch, eff_b=B, forced=None):
    pf = ParallelConfig(mode="auto", blk=16, hetero_plan=plan, quant=quant,
                        forced_layer_mode=forced)
    ph = dataclasses.replace(pf, topology=topo)
    lf, of = run(mesh_flat, pf, plan, batch, eff_b)
    lh, oh = run(mesh_node, ph, plan, batch, eff_b)
    return {"loss_diff": max(abs(a - b) for a, b in zip(lf, lh)),
            "logit_diff": float(np.abs(of - oh).max()),
            "losses": lf}

rows = {}
rows["auto"] = pair("auto")
# phased gathers are exact -> the whole data-centric forward is bitwise
rows["forced_dc"] = pair("forced_dc", forced="data_centric")

# (b) uneven Eq. 1 plan over the 2-wide data group: 3:1 token shares,
# padded + masked tails — identical masking on both meshes.
plan_b = hetero_lib.make_hetero_plan((1.0, 3.0), global_batch=B)
eff_b = len(plan_b.token_counts) * plan_b.batch_capacity
pk = {k: jnp.asarray(v) for k, v in hetero_lib.pack_batch(
    {k: np.asarray(v) for k, v in batch.items()}, plan_b).items()}
rows["hetero"] = pair("hetero", plan=plan_b, batch=pk, eff_b=eff_b)

# (c) hidden_splits over the 4-wide TP group + per-class int8 expert_bits:
# prices the chooser's uneven roofline per device class (DESIGN.md §8)
# and pads the FFN tiles identically on both meshes.
plan_c = hetero_lib.make_hetero_plan(
    (1.0, 1.0, 1.5, 1.5), hidden_size=cfg.moe.d_ff, hidden_quantum=16,
    expert_bits=(8, 8, 16, 16))
rows["expert_bits"] = pair("expert_bits", plan=plan_c)

# (d) int8 QAT fake-quant of the gathered expert weights
rows["quant_int8"] = pair("quant_int8", quant="int8")
print("RESULT" + json.dumps(rows))
""", timeout=900)
    assert out["forced_dc"]["logit_diff"] == 0.0, out["forced_dc"]
    for name, row in out.items():
        assert row["loss_diff"] < 1e-4, (name, row)
        # model-centric positions reassociate one f32 reduction per MoE
        # layer; layernorm + the vocab projection amplify that to ~1e-3
        # max-abs over the logits (relative ~1e-4). The bitwise statement
        # lives in the forced_dc row and the island-level test.
        assert row["logit_diff"] < 5e-3, (name, row)
    # training actually produced finite losses (not NaN garbage)
    assert all(math.isfinite(l) for l in out["auto"]["losses"])


def test_overlap_dispatch_bitwise_and_residency():
    """The overlap schedule (next layer's expert collectives prefetched
    during current-layer compute) is bitwise == the eager schedule, keeps
    the residency bound, and composes with hierarchical dispatch."""
    out = run_sub(r"""
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.base import ModelConfig, MoEConfig
from repro.launch.mesh import make_mesh, split_model_axis
from repro.models import lm
from repro.parallel.autotune import Topology
from repro.parallel.sharding import ParallelConfig, split_tree, tree_shardings

cfg = ModelConfig(
    name="tiny-moe", family="moe", num_layers=4, d_model=32,
    num_heads=4, num_kv_heads=2, head_dim=8, d_ff=0, vocab_size=64,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=48),
)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, 64)

def fwd(pcfg, mesh):
    params, specs = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    params = jax.tree.map(jax.device_put, params,
                          tree_shardings(params, specs, pcfg, mesh))
    lm.LAST_PIPELINE_CACHE_STATS = None
    with mesh:
        logits, _, _, _ = jax.jit(
            lambda p, t: lm.forward(p, {"tokens": t}, cfg, pcfg, mesh,
                                    mode="prefill"))(params, toks)
    return np.asarray(logits), lm.LAST_PIPELINE_CACHE_STATS

mesh = make_mesh((4, 2), ("data", "model"))
base, st0 = fwd(ParallelConfig(mode="auto", blk=16, scan_layers=False,
                               cache_layers=2), mesh)
ovl, st1 = fwd(ParallelConfig(mode="auto", blk=16, scan_layers=False,
                              cache_layers=2, overlap_dispatch=True), mesh)

topo = Topology(intra_bw=50e9, inter_bw=12.5e9, node_size=2)
dims, axes = split_model_axis((2, 4), ("data", "model"), topo.node_size)
mesh_n = make_mesh(dims, axes)
mesh_f = make_mesh((2, 4), ("data", "model"))
bf, _ = fwd(ParallelConfig(mode="auto", blk=16, scan_layers=False,
                           cache_layers=2), mesh_f)
bh, sth = fwd(ParallelConfig(mode="auto", blk=16, scan_layers=False,
                             cache_layers=2, topology=topo,
                             overlap_dispatch=True), mesh_n)
print("RESULT" + json.dumps({
    "overlap_bitwise": bool(np.array_equal(base, ovl)),
    "hier_overlap_maxdiff": float(np.abs(bf - bh).max()),
    "stats_eager": st0, "stats_overlap": st1, "stats_hier": sth,
}))
""")
    assert out["overlap_bitwise"]
    assert out["hier_overlap_maxdiff"] < 1e-5
    for key in ("stats_eager", "stats_overlap", "stats_hier"):
        st = out[key]
        assert st is not None, key
        assert st["peak_resident_layers"] <= 2, (key, st)
        assert st["prefetches"] > 0 and st["hits"] > 0, (key, st)
