"""Paged decode-attention kernel tests (ISSUE 4): pallas-interpret vs
blocked vs the gather-dense reference vs the dense-cache
``models.attention.decode_attention`` — across page sizes, ragged lengths,
empty pages/slots, windows, GQA/MQA layouts, and bf16 — plus the
cost-model assertion that paged bytes carry no dense
``num_slots * max_seq`` term."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import paged_attention as pa
from repro.models import attention as attn_lib
from repro.parallel import autotune


def _case(seed, b, hq, hkv, hd, page, maxp, *, dtype=jnp.float32,
          lengths=None):
    """Random pools + a page table with DISTINCT pages per slot (what the
    scheduler guarantees), plus the dense (B, S) cache holding the same
    tokens for cross-layout comparison."""
    rng = np.random.default_rng(seed)
    npages = 1 + b * maxp
    q = jnp.asarray(rng.normal(size=(b, 1, hq, hd)), dtype)
    k_pool = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)), dtype)
    v_pool = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)), dtype)
    table = np.zeros((b, maxp), np.int32)
    for i in range(b):
        table[i] = 1 + i * maxp + np.arange(maxp)
    if lengths is None:
        lengths = rng.integers(0, maxp * page + 1, size=(b,))
    lengths = np.asarray(lengths, np.int32)
    # dense view: slot i's logical row j lives at pool[table[i, j//page]]
    k_dense = np.asarray(k_pool)[table].reshape(b, maxp * page, hkv, hd)
    v_dense = np.asarray(v_pool)[table].reshape(b, maxp * page, hkv, hd)
    return (q, k_pool, v_pool, jnp.asarray(table), jnp.asarray(lengths),
            jnp.asarray(k_dense), jnp.asarray(v_dense))


CASES = [
    # (b, hq, hkv, hd, page, maxp) — GQA, MQA, kv==q, tiny pages
    (4, 4, 2, 16, 8, 6),
    (3, 8, 1, 16, 4, 5),
    (2, 4, 4, 8, 16, 2),
    (5, 2, 2, 32, 2, 9),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("window", [None, 9])
def test_impl_equivalence(case, window):
    """pallas-interpret == blocked == gather-dense reference, across page
    sizes, ragged lengths (incl. an empty slot and a full slot)."""
    b, hq, hkv, hd, page, maxp = case
    lengths = [0, maxp * page] + [None] * (b - 2)
    rng = np.random.default_rng(hash(case) % 2**31)
    lengths = [l if l is not None else int(rng.integers(1, maxp * page))
               for l in lengths]
    q, kp, vp, pt, lens, _, _ = _case(1, *case, lengths=lengths)
    r = pa.paged_attention_ref(q, kp, vp, pt, lens, window=window)
    bl = pa.paged_attention_blocked(q, kp, vp, pt, lens, window=window)
    pl_ = pa.paged_attention_pallas(q, kp, vp, pt, lens, window=window,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(r), np.asarray(bl), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r), np.asarray(pl_), atol=1e-5)
    # empty slot emits exactly zero from every impl
    for out in (r, bl, pl_):
        assert float(jnp.abs(out[0]).max()) == 0.0


@pytest.mark.parametrize("case", CASES[:2])
def test_matches_dense_decode_attention(case):
    """The paged impls reproduce the dense-cache decode attention on the
    same tokens (no window: the dense op has none)."""
    q, kp, vp, pt, lens, kd, vd = _case(2, *case)
    dense = attn_lib.decode_attention(q, kd, vd, lens)
    # the dense op leaves empty rows at softmax-uniform garbage; compare
    # only slots with at least one live token
    live = np.asarray(lens) > 0
    for impl in (pa.paged_attention_ref, pa.paged_attention_blocked):
        out = impl(q, kp, vp, pt, lens)
        np.testing.assert_allclose(
            np.asarray(out)[live], np.asarray(dense)[live], atol=1e-5)
    out = pa.paged_attention_pallas(q, kp, vp, pt, lens, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out)[live], np.asarray(dense)[live], atol=1e-5)


def test_softcap():
    case = CASES[0]
    q, kp, vp, pt, lens, _, _ = _case(3, *case)
    r = pa.paged_attention_ref(q, kp, vp, pt, lens, softcap=5.0)
    bl = pa.paged_attention_blocked(q, kp, vp, pt, lens, softcap=5.0)
    pl_ = pa.paged_attention_pallas(q, kp, vp, pt, lens, softcap=5.0,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(r), np.asarray(bl), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r), np.asarray(pl_), atol=1e-5)


def test_bf16_kernel_direct():
    b, hq, hkv, hd, page, maxp = CASES[0]
    q, kp, vp, pt, lens, _, _ = _case(4, b, hq, hkv, hd, page, maxp,
                                      dtype=jnp.bfloat16)
    r = pa.paged_attention_ref(q, kp, vp, pt, lens)
    pl_ = pa.paged_attention_pallas(q, kp, vp, pt, lens, interpret=True)
    assert pl_.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(r, np.float32), np.asarray(pl_, np.float32), atol=3e-2)


def test_shared_pages_between_logical_slots():
    """Duplicate physical pages in a table (e.g. a shared prompt prefix)
    are read consistently by every impl."""
    b, hq, hkv, hd, page, maxp = 2, 4, 2, 16, 8, 4
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(b, 1, hq, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(6, page, hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(6, page, hkv, hd)), jnp.float32)
    pt = jnp.asarray([[1, 2, 3, 4], [1, 2, 5, 0]], jnp.int32)  # shared 1,2
    lens = jnp.asarray([30, 20], jnp.int32)
    r = pa.paged_attention_ref(q, kp, vp, pt, lens)
    bl = pa.paged_attention_blocked(q, kp, vp, pt, lens)
    pl_ = pa.paged_attention_pallas(q, kp, vp, pt, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(r), np.asarray(bl), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r), np.asarray(pl_), atol=1e-5)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_cost_has_no_dense_rectangle_term():
    """Paged bytes depend on live tokens only: growing max_seq (the dense
    rectangle's long side) with fixed lengths changes NOTHING, while the
    dense layout's bill scales with it."""
    kw = dict(num_slots=32, hq=8, hkv=2, hd=64, page=16, itemsize=2)
    lens = [5, 100, 0, 17] + [1] * 28
    paged_small = autotune.decode_attn_bytes(
        "paged", max_seq=256, lengths=lens, **kw)
    paged_large = autotune.decode_attn_bytes(
        "paged", max_seq=4096, lengths=lens, **kw)
    assert paged_small == paged_large
    dense_small = autotune.decode_attn_bytes("dense", max_seq=256, **kw)
    dense_large = autotune.decode_attn_bytes("dense", max_seq=4096, **kw)
    assert dense_large == pytest.approx(16 * dense_small, rel=0.05)
    # ragged real-world mix: paged far below dense
    assert paged_large < dense_large / 10


def test_cost_scales_with_pages_not_slots():
    """An idle slot costs a query row, not a max_seq stripe; page-granular
    rounding is visible (len 1 is billed one full page)."""
    c1 = pa.paged_attn_cost([1], 16, 8, 2, 64, 2)
    c0 = pa.paged_attn_cost([0], 16, 8, 2, 64, 2)
    cfull = pa.paged_attn_cost([16], 16, 8, 2, 64, 2)
    assert c0["bytes_accessed"] == 2 * 8 * 64 * 2          # q + out only
    assert c1["bytes_accessed"] == cfull["bytes_accessed"]  # same one page
    # additive over slots
    c_sum = pa.paged_attn_cost([1, 16, 0], 16, 8, 2, 64, 2)
    assert c_sum["bytes_accessed"] == (
        c1["bytes_accessed"] + cfull["bytes_accessed"]
        + c0["bytes_accessed"])


def test_latency_entry_prices_paged_below_dense():
    lat_dense = autotune.serve_decode_attn_latency(
        "dense", num_slots=16, max_seq=2048, hq=8, hkv=2, hd=64)
    lat_paged = autotune.serve_decode_attn_latency(
        "paged", num_slots=16, max_seq=2048, hq=8, hkv=2, hd=64,
        lengths=[32] * 16, page=16)
    assert lat_paged < lat_dense / 8
    with pytest.raises(ValueError):
        autotune.decode_attn_bytes("mmap", num_slots=1, max_seq=1,
                                   hq=1, hkv=1, hd=1)
