"""Disaggregated prefill/decode serving tests (ISSUE 6, DESIGN.md §7).

The split engine's contract has three parts, each pinned here against the
single-loop PR-4 engine on the same pinned workloads:

  * the page-table handoff is invisible in tokens — a sequence prefilled
    on a prefill-role slot and decoded on a decode-role slot emits exactly
    the single-loop stream (the KV never moves, only the table row and the
    jitted per-slot metadata);
  * role separation is strict — a decode-role slot never runs a prefill
    chunk, a prefill-role slot never decodes (checked on the scheduler
    trace, the observable schedule);
  * the degenerate case really is degenerate — a uniform one-role-class
    hetero plan derives "both" everywhere and replays the single-loop
    scheduler trace event for event.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs as cfglib
from repro.configs.base import ModelConfig
from repro.core import hetero as hetero_lib
from repro.launch import serve
from repro.models import lm
from repro.parallel.sharding import ParallelConfig, split_tree

CFG = ModelConfig(
    name="disagg-smoke",
    family="dense",
    num_layers=1,
    d_model=16,
    num_heads=2,
    num_kv_heads=2,
    head_dim=8,
    d_ff=32,
    vocab_size=32,
    dtype="float32",
)
PCFG = ParallelConfig(blk=8)
PAGE, MAXP = 4, 8

_PARAMS: dict = {}


def _params(cfg):
    key = cfg.name
    if key not in _PARAMS:
        _PARAMS[key], _ = split_tree(
            lm.init_params(jax.random.PRNGKey(0), cfg))
    return _PARAMS[key]


def _requests(cfg, n, seed=11):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(2, 12))
        reqs.append(serve.Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=plen).astype(np.int32),
            max_new=int(rng.integers(2, 6))))
    return reqs


def _run(cfg, reqs, *, num_slots=4, **kw):
    srv = serve.PagedServer(
        cfg, PCFG if cfg is CFG else kw.pop("pcfg"), None,
        num_slots=num_slots, page_size=PAGE,
        num_pages=1 + num_slots * MAXP, max_pages_per_slot=MAXP,
        params=_params(cfg), prefill_chunk=4, **kw)
    for r in reqs:
        srv.submit(dataclasses.replace(r, out=[]))
    done = srv.run()
    assert len(done) == len(reqs)
    srv.pool.assert_consistent()
    assert srv.pool.free_pages == sum(srv.pool.shares)
    return srv, {r.rid: r.out for r in done}


def test_handoff_preserves_tokens():
    """Half/half role split: every sequence crosses a page-table handoff
    and still emits the single-loop engine's exact stream."""
    reqs = _requests(CFG, 6)
    srv_single, out_single = _run(CFG, reqs)
    srv_disagg, out_disagg = _run(CFG, reqs, disagg=True)
    assert out_disagg == out_single, "handoff changed tokens"
    assert srv_disagg.transfers == len(reqs), (
        "every request must hand off exactly once in a strict split")
    assert srv_single.transfers == 0


def test_roles_are_strict():
    """Trace invariant: prefill chunks only on prefill-role slots, decode
    steps only over decode-role slots, and each transfer moves
    prefill -> decode."""
    reqs = _requests(CFG, 6, seed=13)
    srv, _ = _run(CFG, reqs, disagg=True)
    roles = srv.roles
    assert set(roles) == {"prefill", "decode"}
    transferred = set()
    for ev in srv.trace:
        if ev[0] == "prefill_chunk":
            assert roles[ev[2]] == "prefill", f"decode slot prefilled: {ev}"
        elif ev[0] == "decode":
            assert all(roles[s] == "decode" for s in ev[1]), (
                f"prefill slot decoded: {ev}")
        elif ev[0] == "transfer":
            _, rid, src, dst = ev
            assert roles[src] == "prefill" and roles[dst] == "decode"
            transferred.add(rid)
    assert transferred == {r.rid for r in reqs}


def test_uniform_plan_reduces_to_single_loop():
    """derive_roles on a uniform (or single-class) plan yields "both"
    everywhere, and the disaggregated server replays the single-loop
    scheduler trace event for event on a pinned workload."""
    assert serve.derive_roles((3, 3)) == ["both", "both"]
    assert serve.derive_roles((5,)) == ["both"]
    assert serve.derive_roles((4, 2)) == ["prefill", "decode"]
    assert serve.derive_roles((2, 4, 4)) == ["decode", "prefill", "prefill"]

    plan = hetero_lib.make_hetero_plan((1.0, 1.0), global_batch=4)
    reqs = _requests(CFG, 6, seed=17)
    srv_single, out_single = _run(CFG, reqs, plan=plan)
    srv_disagg, out_disagg = _run(CFG, reqs, plan=plan, disagg=True)
    assert srv_disagg.roles == ["both"] * 4
    assert out_disagg == out_single
    assert srv_disagg.trace == srv_single.trace, (
        "degenerate disagg scheduled differently from the PR-4 engine")
    assert srv_disagg.transfers == 0


def test_hetero_plan_assigns_roles():
    """A skewed plan maps the fast class to prefill and the slow class to
    decode, with the page budget still split per Eq. 1."""
    plan = hetero_lib.make_hetero_plan((1.0, 2.0), global_batch=4)
    reqs = _requests(CFG, 6, seed=19)
    srv, out = _run(CFG, reqs, plan=plan, disagg=True)
    # groups [0, 0, 1, 1]: class 0 (faster, larger token share) prefills
    assert srv.roles == ["prefill", "prefill", "decode", "decode"]
    assert srv.transfers == len(reqs)
    _, out_single = _run(CFG, reqs, plan=plan)
    assert out == out_single


def test_handoff_moves_recurrent_state():
    """Hybrid attn+mamba (jamba): the handoff step must move the per-slot
    recurrent state rows, not just the page table — otherwise the decode
    slot resumes from a zero conv/ssm state and the stream diverges."""
    cfg = dataclasses.replace(
        cfglib.get_smoke_config("jamba-1.5-large-398b"), dtype="float32")
    assert any(cfg.layer_kind(i) != "attn" for i in range(cfg.period))
    pcfg = ParallelConfig(blk=8, impl="pallas")
    reqs = _requests(cfg, 4, seed=23)
    srv_single, out_single = _run(cfg, reqs, pcfg=pcfg)
    srv_disagg, out_disagg = _run(cfg, reqs, pcfg=pcfg, disagg=True)
    assert srv_disagg.transfers == len(reqs)
    assert out_disagg == out_single, "recurrent state lost in handoff"


def test_disagg_validation():
    with pytest.raises(ValueError, match=">= 2 slots"):
        serve.PagedServer(
            CFG, PCFG, None, num_slots=1, page_size=PAGE,
            num_pages=1 + MAXP, max_pages_per_slot=MAXP,
            params=_params(CFG), disagg=True)
