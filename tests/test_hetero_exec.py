"""Heterogeneous EXECUTION (paper §4.4 Eq. 1/2 run for real; DESIGN.md §6).

Covers the invariants the design doc promises:
  * largest-remainder planning preserves exact global token/hidden totals;
  * a plan with equal latencies is bitwise-identical to the uniform path
    (SPMD, 8 fake devices — forward, train step, and serve decode);
  * a skewed plan's masked-tail rows produce zero output AND zero gradient,
    and valid rows match the dense reference;
  * zero-padded hidden tiles compute exactly the unpadded uneven split;
  * the per-device execution engine (parallel.hetero_exec) matches the
    single-program reference for both dispatches;
  * the replan loop's re-traces are bounded by the plan-keyed cache;
  * the autotune uneven-split latency term prefers proportional splits.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import espec
from repro.core.hetero import (
    HeteroPlan,
    clamp_shares,
    hidden_mask,
    make_hetero_plan,
    pack_batch,
    proportional_split,
    uniform_plan,
)
from repro.parallel import autotune
from repro.parallel.cache import PlanCache
from repro.parallel.hetero_exec import HeteroExecutor
from repro.runtime.straggler import StragglerConfig, StragglerMonitor


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# planner invariants (pure)
# ---------------------------------------------------------------------------

def test_plan_preserves_exact_totals():
    """Largest-remainder property: Eq. 1/2 shares sum to the exact global
    batch / hidden size for arbitrary skews."""
    from repro.core.hetero import fit_quantum

    for lat in ([1.0, 2.0], [1.0, 1.7, 9.4], [3.3, 0.2, 1.0, 1.0, 8.0]):
        plan = make_hetero_plan(lat, global_batch=96, hidden_size=1024,
                                hidden_quantum=128)
        assert sum(plan.token_counts) == 96
        assert sum(plan.hidden_splits) == 1024
        q = fit_quantum(1024, 128, len(lat))
        assert all(h % q == 0 for h in plan.hidden_splits)


def test_fitted_quanta_survive_replan_and_bound_padding():
    """The plan must carry the FITTED quanta, not the requested ones:
    (a) a replan re-splits on plan.token_quantum — an unfitted quantum
    crashes proportional_split when it does not divide the batch;
    (b) hidden_capacity rounds tiles to plan.hidden_quantum — an unfitted
    one silently pads small d_ff far past the real hidden size."""
    plan = make_hetero_plan([1.0, 2.0], global_batch=12, token_quantum=8)
    assert plan.token_quantum == 4  # fitted: 8 does not divide 12
    mon = StragglerMonitor(
        2, 12, StragglerConfig(window=2, min_steps_between_replans=0),
        plan=plan,
    )
    new = None
    for _ in range(4):
        new = mon.report([1.0, 3.0]) or new
    assert new is not None and sum(new) == 12
    assert mon.current_plan().token_counts == tuple(mon.shares)

    p2 = make_hetero_plan([1.0, 1.5], hidden_size=96, hidden_quantum=128)
    assert p2.hidden_quantum == 32 and sum(p2.hidden_splits) == 96
    # padding bounded by < one fitted quantum per rank, not blown up to 256
    assert p2.padded_hidden_size() <= 96 + 32


def test_uniform_counterpart_respects_groups_and_quantum():
    from repro.core.hetero import uniform_counterpart

    # token group (2) and hidden/TP group (4) have different sizes
    plan = make_hetero_plan([1.0, 2.0], global_batch=8, hidden_size=1024,
                            tp_latencies=[1.0, 1.0, 2.0, 2.0],
                            hidden_quantum=128)
    uni = uniform_counterpart(plan)
    assert uni.token_counts == (4, 4)
    assert uni.hidden_splits == (256,) * 4
    assert uni.token_capacity is None
    # an equal hidden share that is not a quantum multiple is rejected —
    # the baseline arm must execute the same MXU-aligned tile shapes
    p2 = make_hetero_plan([1.0, 2.0], hidden_size=384, hidden_quantum=128)
    assert p2.hidden_splits == (256, 128)
    with pytest.raises(ValueError):
        uniform_counterpart(p2)


def test_clamp_shares_redistributes_preserving_total():
    out = clamp_shares([10, 2, 0], capacity=6)
    assert sum(out) == 12
    assert max(out) <= 6
    with pytest.raises(ValueError):
        clamp_shares([10, 10], capacity=6)


def test_with_token_counts_clamps_to_capacity():
    plan = make_hetero_plan([1.0, 1.0], global_batch=8)
    plan = dataclasses.replace(plan, token_capacity=6)
    new = plan.with_token_counts([8, 0])
    assert sum(new.token_counts) == 8 and max(new.token_counts) <= 6


def test_pack_batch_layout_and_loss_mask():
    plan = make_hetero_plan([1.0, 3.0], global_batch=8)
    assert plan.token_counts == (6, 2)
    batch = {"tokens": np.arange(8, dtype=np.int32),
             "loss_mask": np.ones(8, np.float32)}
    packed = pack_batch(batch, plan)
    cap = plan.batch_capacity
    assert packed["tokens"].shape[0] == 2 * cap
    assert list(packed["tokens"][:6]) == [0, 1, 2, 3, 4, 5]
    assert list(packed["tokens"][cap:cap + 2]) == [6, 7]
    assert packed["loss_mask"].sum() == 8  # pad rows masked out of the loss


def test_hidden_mask_layout():
    plan = make_hetero_plan([1.0, 2.0], hidden_size=192, hidden_quantum=64)
    assert plan.hidden_splits == (128, 64)
    m = hidden_mask(plan)  # capacity 128 -> F' = 256
    assert m.shape == (256,)
    assert m[:128].all() and m[128:192].all() and not m[192:].any()


# ---------------------------------------------------------------------------
# masked-tail semantics (single process, island level)
# ---------------------------------------------------------------------------

def _tiny_layer(key, d=16, f=32, e=4):
    ks = jax.random.split(key, 5)
    return {"router": jax.random.normal(ks[0], (d, e)) * 0.1,
            "w_gate": jax.random.normal(ks[1], (e, d, f)) * 0.1,
            "w_up": jax.random.normal(ks[2], (e, d, f)) * 0.1,
            "w_down": jax.random.normal(ks[3], (e, f, d)) * 0.1}


def test_masked_tail_rows_zero_output_and_zero_grad():
    from repro.parallel.moe_parallel import (
        MoEParams, MoEStatic, _SINGLE_MESH, hexa_moe_island,
    )
    from repro.parallel.sharding import ParallelConfig

    d, f, e, k, n, nv = 16, 32, 4, 2, 24, 17
    params = _tiny_layer(jax.random.PRNGKey(0), d, f, e)
    p = MoEParams(router=params["router"], w_gate=params["w_gate"],
                  w_up=params["w_up"], w_down=params["w_down"])
    ms = MoEStatic(num_experts=e, top_k=k, act="silu", glu=True)
    cfg = ParallelConfig(blk=8)
    x = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    tv = jnp.arange(n) < nv

    def loss(x, p, masked):
        y, aux, z = hexa_moe_island(
            x, p, ms, cfg, _SINGLE_MESH, tokens_sharded_tp=False,
            token_valid=tv if masked else None,
        )
        return jnp.sum(y ** 2) + aux + z, y

    (l_m, y_m), g_m = jax.value_and_grad(loss, argnums=(0, 1),
                                         has_aux=True)(x, p, True)
    # tail outputs exactly zero
    assert bool(jnp.all(y_m[nv:] == 0))
    # tail rows contribute exactly zero gradient to x ...
    assert bool(jnp.all(g_m[0][nv:] == 0))
    # ... and the weight grads equal those of the dense valid-only program
    (l_v, y_v), g_v = jax.value_and_grad(
        lambda xv, pv: loss(xv, pv, False), argnums=(0, 1), has_aux=True
    )(x[:nv], p)
    np.testing.assert_allclose(np.asarray(y_m[:nv]), np.asarray(y_v),
                               rtol=0, atol=1e-6)
    for a, b in zip(jax.tree.leaves(g_m[1]), jax.tree.leaves(g_v[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g_m[0][:nv]), np.asarray(g_v[0]),
                               rtol=1e-5, atol=1e-5)


def test_padded_hidden_tiles_compute_exact_unpadded_result():
    """DESIGN.md §6 padding invariant: embedding the Eq. 2 slices into
    zero-padded per-rank tiles changes nothing about the output."""
    d, f, e, k, n = 16, 96, 4, 2, 32
    params = _tiny_layer(jax.random.PRNGKey(2), d, f, e)
    x = jax.random.normal(jax.random.PRNGKey(3), (n, d))
    ref = espec.hexa_moe_ffn(x, params, num_experts=e, top_k=k,
                             act="silu", glu=True, blk=8).y

    plan = make_hetero_plan([1.0, 2.0], hidden_size=f, hidden_quantum=32)
    assert plan.hidden_splits == (64, 32) and plan.hidden_padded()
    fp = plan.padded_hidden_size()
    cap = plan.hidden_capacity
    # place each rank's h_i real columns at the head of its padded tile
    pad = {"router": params["router"],
           "w_gate": jnp.zeros((e, d, fp)), "w_up": jnp.zeros((e, d, fp)),
           "w_down": jnp.zeros((e, fp, d))}
    off = 0
    for i, h in enumerate(plan.hidden_splits):
        sl_dst = slice(i * cap, i * cap + h)
        sl_src = slice(off, off + h)
        pad["w_gate"] = pad["w_gate"].at[:, :, sl_dst].set(
            params["w_gate"][:, :, sl_src])
        pad["w_up"] = pad["w_up"].at[:, :, sl_dst].set(
            params["w_up"][:, :, sl_src])
        pad["w_down"] = pad["w_down"].at[:, sl_dst, :].set(
            params["w_down"][:, sl_src, :])
        off += h
    got = espec.hexa_moe_ffn(x, pad, num_experts=e, top_k=k,
                             act="silu", glu=True, blk=8).y
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_init_moe_ffn_uniform_plan_bitwise_and_padded_zero_columns():
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm

    cfg = get_smoke_config("mixtral-8x7b")
    key = jax.random.PRNGKey(0)
    base = tfm.init_moe_ffn(key, cfg, jnp.float32)
    up = uniform_plan(2, hidden_size=cfg.moe.d_ff,
                      hidden_quantum=cfg.moe.d_ff // 2)
    same = tfm.init_moe_ffn(key, cfg, jnp.float32, plan=up)
    for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(same)):
        assert bool(jnp.all(a == b))

    skew = make_hetero_plan([1.0, 2.0], hidden_size=cfg.moe.d_ff,
                            hidden_quantum=cfg.moe.d_ff // 4)
    if skew.hidden_padded():
        padded = tfm.init_moe_ffn(key, cfg, jnp.float32, plan=skew)
        fp = skew.padded_hidden_size()
        assert padded["w_gate"].value.shape[-1] == fp
        m = hidden_mask(skew).astype(bool)
        assert bool(jnp.all(padded["w_gate"].value[:, :, ~m] == 0))
        assert bool(jnp.all(padded["w_down"].value[:, ~m, :] == 0))


# ---------------------------------------------------------------------------
# per-device execution engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["data_centric", "model_centric"])
@pytest.mark.parametrize("glu", [True, False])
def test_hetero_exec_matches_reference(mode, glu):
    d, f, e, k, n = 16, 64, 4, 2, 40
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    params = {"router": jax.random.normal(ks[0], (d, e)) * 0.1}
    if glu:
        params.update(
            w_gate=jax.random.normal(ks[1], (e, d, f)) * 0.1,
            w_up=jax.random.normal(ks[2], (e, d, f)) * 0.1,
            w_down=jax.random.normal(ks[3], (e, f, d)) * 0.1)
    else:
        params.update(
            w1=jax.random.normal(ks[1], (e, d, f)) * 0.1,
            b1=jnp.full((e, f), 0.1),
            w2=jax.random.normal(ks[2], (e, f, d)) * 0.1,
            b2=jnp.full((e, d), 0.05))
    x = jax.random.normal(ks[5], (n, d), jnp.float32)
    ref = espec.hexa_moe_ffn(x, params, num_experts=e, top_k=k,
                             act="silu", glu=glu, blk=8).y
    plan = make_hetero_plan([1.0, 3.0], global_batch=n, hidden_size=f,
                            token_quantum=8, hidden_quantum=16)
    ex = HeteroExecutor(params, num_experts=e, top_k=k, act="silu", glu=glu,
                        plan=plan, mode=mode, blk=8)
    y = ex(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    st = ex.timed_step(x, rounds=1)
    assert st.step_latency_s > 0 and len(st.device_times_s) == 2
    np.testing.assert_allclose(np.asarray(st.y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# replan loop: bounded plan-keyed retraces
# ---------------------------------------------------------------------------

def test_replan_retrace_reuses_plan_keyed_cache():
    plan = make_hetero_plan([1.0, 1.0, 1.0, 1.0], global_batch=32,
                            capacity_headroom=1.5)
    mon = StragglerMonitor(
        4, 32,
        StragglerConfig(window=4, min_steps_between_replans=0),
        plan=plan,
    )
    traces = []
    cache = PlanCache(4)

    def step_for(p):
        return cache.fetch(p.key(), lambda: traces.append(p.key()) or
                           (lambda: p.token_counts))

    step = step_for(plan)
    assert len(traces) == 1
    # straggler appears -> replan -> ONE new trace
    new = None
    for _ in range(6):
        out = mon.report([1.0, 1.0, 1.0, 2.4])
        new = out or new
    assert new is not None
    plan2 = mon.current_plan()
    assert plan2.token_counts != plan.token_counts
    assert sum(plan2.token_counts) == 32
    assert max(plan2.token_counts) <= plan.batch_capacity
    step_for(plan2)
    assert len(traces) == 2
    # same plan again: cache hit, no retrace
    step_for(plan2)
    step_for(plan)
    assert len(traces) == 2
    assert cache.stats()["hits"] >= 2
    del step


# ---------------------------------------------------------------------------
# autotune: uneven-split latency term
# ---------------------------------------------------------------------------

def test_uneven_latency_proportional_beats_uniform():
    lat = [1.0, 1.0, 2.0, 1.0]
    n = len(lat)
    tokens, d, f, e, k = 8192, 1024, 4096, 8, 2
    tok_prop = proportional_split(lat, tokens)
    hid_prop = proportional_split(lat, f, quantum=128)
    for mode in ("data_centric", "model_centric"):
        uneven = autotune.layer_latency_uneven(
            mode, tokens, d, f, e, k, lat,
            token_shares=tok_prop, hidden_shares=hid_prop)
        uniform = autotune.layer_latency_uneven(
            mode, tokens, d, f, e, k, lat,
            token_shares=[tokens // n] * n, hidden_shares=[f // n] * n)
        assert uneven <= uniform * (1 + 1e-9), mode
    # homogeneous group: uneven term == the classic roofline
    flat = [1.0] * n
    for mode in ("data_centric", "model_centric"):
        a = autotune.layer_latency_uneven(mode, tokens, d, f, e, k, flat)
        b = autotune.layer_latency(mode, tokens, d, f, e, k, n_dev=n)
        np.testing.assert_allclose(a, b, rtol=1e-9)


def test_resolve_layer_mode_uses_plan():
    from repro.parallel.sharding import ParallelConfig

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 4}

    plan = make_hetero_plan([1.0, 1.0, 1.0, 4.0], hidden_size=4096,
                            hidden_quantum=128)
    cfg = ParallelConfig(mode="auto", hetero_plan=plan)
    mode = autotune.resolve_layer_mode(
        32768, d=1024, f=4096, e=8, k=2, cfg=cfg, mesh=FakeMesh())
    assert mode in autotune.CHOOSABLE_MODES
    # tiny decode workload still resolves model-centric under a plan
    mode_small = autotune.resolve_layer_mode(
        8, d=1024, f=4096, e=8, k=2, cfg=cfg, mesh=FakeMesh())
    assert mode_small == "model_centric"


# ---------------------------------------------------------------------------
# SPMD end-to-end (8 fake devices, subprocess)
# ---------------------------------------------------------------------------

def run_sub(code: str, timeout: int = 900) -> dict:
    """Run ``code`` under 8 fake CPU devices; parse its RESULT json line."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT")]
    assert line, res.stdout[-2000:]
    return json.loads(line[-1][len("RESULT"):])


@pytest.mark.multihost
def test_spmd_uniform_plan_bitwise_and_skewed_plan_exact():
    out = run_sub(r"""
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.moe_parallel import MoEParams, MoEStatic, moe_layer
from repro.parallel.sharding import ParallelConfig
from repro.core import espec
from repro.core.hetero import make_hetero_plan, uniform_plan
from repro.launch.mesh import make_mesh
import dataclasses

mesh = make_mesh((4, 2), ("data", "model"))
B, S, D, F, E, K = 8, 16, 32, 64, 4, 2
ks = jax.random.split(jax.random.PRNGKey(0), 6)
x = jax.random.normal(ks[0], (B, S, D), jnp.float32)
p = MoEParams(router=jax.random.normal(ks[1], (D, E)) * 0.1,
              w_gate=jax.random.normal(ks[2], (E, D, F)) * 0.1,
              w_up=jax.random.normal(ks[3], (E, D, F)) * 0.1,
              w_down=jax.random.normal(ks[4], (E, F, D)) * 0.1)
ms = MoEStatic(num_experts=E, top_k=K, act="silu", glu=True)
spec = P("data", "model", None)
res = {}
for mode in ("hybrid", "auto"):
    cfg0 = ParallelConfig(mode=mode, blk=16)
    cfgu = ParallelConfig(mode=mode, blk=16,
                          hetero_plan=uniform_plan(4, global_batch=B))
    with mesh:
        y0, a0, z0 = jax.jit(lambda x, p: moe_layer(
            x, p, ms, cfg0, mesh, x_spec=spec))(x, p)
        y1, a1, z1 = jax.jit(lambda x, p: moe_layer(
            x, p, ms, cfgu, mesh, x_spec=spec))(x, p)
    res[f"bitwise/{mode}"] = bool(jnp.all(y0 == y1)) and float(a0) == float(a1)

# skewed: 7 valid batch rows over 4 data ranks (2,2,2,1), tail masked
plan = make_hetero_plan([1.0, 1.0, 1.0, 2.0], global_batch=7)
plan = dataclasses.replace(plan, token_counts=(2, 2, 2, 1), token_capacity=2)
ref = espec.hexa_moe_ffn(
    x[:7].reshape(7 * S, D),
    {"router": p.router, "w_gate": p.w_gate, "w_up": p.w_up,
     "w_down": p.w_down},
    num_experts=E, top_k=K, act="silu", glu=True, blk=16).y.reshape(7, S, D)
for mode in ("hybrid", "auto", "data_centric", "model_centric", "ep"):
    cfgs = ParallelConfig(mode=mode, blk=16, capacity_factor=8.0,
                          hetero_plan=plan)
    with mesh:
        ys, _, _ = jax.jit(lambda x, p: moe_layer(
            x, p, ms, cfgs, mesh, x_spec=spec))(x, p)
    res[f"skew_err/{mode}"] = float(jnp.abs(ys[:7] - ref).max())
    res[f"skew_tail0/{mode}"] = bool(jnp.all(ys[7] == 0))

# masked rows: zero gradient through the island (weights see only valid rows)
def loss(p, cfg):
    y, aux, z = moe_layer(x, p, ms, cfg, mesh, x_spec=spec)
    return jnp.sum(y ** 2) + aux

with mesh:
    gs = jax.jit(jax.grad(lambda p: loss(p, ParallelConfig(
        mode="hybrid", blk=16, hetero_plan=plan))))(p)
    gv = jax.jit(jax.grad(lambda p: loss(p, ParallelConfig(
        mode="hybrid", blk=16))))(p)
# grads must differ from the unmasked program (row 7 excluded) but be finite
res["grad_finite"] = all(bool(jnp.isfinite(g).all())
                         for g in jax.tree.leaves(gs))
res["grad_masks_row"] = any(
    float(jnp.abs(a - b).max()) > 1e-8
    for a, b in zip(jax.tree.leaves(gs), jax.tree.leaves(gv)))
print("RESULT" + json.dumps(res))
""")
    for mode in ("hybrid", "auto"):
        assert out[f"bitwise/{mode}"], out
    for key, val in out.items():
        if key.startswith("skew_err/"):
            assert val < 5e-5, (key, val)
        if key.startswith("skew_tail0/"):
            assert val, key
    assert out["grad_finite"] and out["grad_masks_row"]


@pytest.mark.multihost
def test_spmd_train_step_and_serve_decode_under_plan():
    out = run_sub(r"""
import json, dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_smoke_config
from repro.core.hetero import make_hetero_plan, pack_batch, uniform_plan
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.optim import adamw
from repro.parallel.sharding import ParallelConfig, split_tree, tree_shardings

cfg = dataclasses.replace(get_smoke_config("mixtral-8x7b"), dtype="float32")
B, S = 8, 32
mesh = make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(0)
toks = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
batch = {"tokens": toks, "labels": np.roll(toks, -1, 1).astype(np.int32),
         "loss_mask": np.ones((B, S), np.float32)}
opt_cfg = adamw.OptimizerConfig(master_fp32=False)

def losses(pcfg, host_batch, eff_b, steps=2):
    params, specs = split_tree(
        lm.init_params(jax.random.PRNGKey(0), cfg, plan=pcfg.hetero_plan))
    params = jax.tree.map(jax.device_put, params,
                          tree_shardings(params, specs, pcfg, mesh))
    opt = adamw.init_opt_state(params, opt_cfg)
    step = jax.jit(steps_lib.make_train_step(cfg, pcfg, mesh, opt_cfg,
                                             (eff_b, S, cfg.d_model)))
    out = []
    b = {k: jnp.asarray(v) for k, v in host_batch.items()}
    for _ in range(steps):
        params, opt, m = step(params, opt, b)
        out.append(float(m["loss"]))
    return out

res = {}
with mesh:
    base = losses(ParallelConfig(mode="auto", blk=8), batch, B)
    uni = losses(ParallelConfig(
        mode="auto", blk=8, hetero_plan=uniform_plan(4, global_batch=B)),
        batch, B)
    res["train_bitwise_uniform"] = base == uni

    # skewed: token shares (3,2,2,1) + uneven TP hidden tiles (quantum /4)
    plan = make_hetero_plan([1.0, 1.0, 1.0, 2.0], global_batch=B,
                            hidden_size=cfg.moe.d_ff,
                            tp_latencies=[1.0, 1.5],
                            hidden_quantum=max(cfg.moe.d_ff // 4, 8),
                            capacity_headroom=1.5)
    eff_b = len(plan.token_counts) * plan.batch_capacity
    skew_losses = losses(ParallelConfig(mode="auto", blk=8, hetero_plan=plan),
                         pack_batch(batch, plan), eff_b)
    res["train_skew_finite"] = all(np.isfinite(skew_losses))
    res["plan"] = [list(plan.token_counts), list(plan.hidden_splits)]

    # serve decode: uniform plan bitwise; skewed plan runs
    slots = 8
    slot_toks = rng.integers(0, cfg.vocab_size, size=(16, 1)).astype(np.int32)
    def decode_logits(pcfg, nslots):
        params, specs = split_tree(
            lm.init_params(jax.random.PRNGKey(0), cfg, plan=pcfg.hetero_plan))
        params = jax.tree.map(jax.device_put, params,
                              tree_shardings(params, specs, pcfg, mesh))
        cache = lm.init_cache(cfg, nslots, 16)
        step = jax.jit(steps_lib.make_serve_step(
            cfg, pcfg, mesh, (nslots, 1, cfg.d_model)))
        toks = jnp.asarray(slot_toks[:nslots])
        logits, cache = step(params, {"tokens": toks}, cache)
        return np.asarray(logits)

    l0 = decode_logits(ParallelConfig(mode="auto", blk=8), slots)
    l1 = decode_logits(ParallelConfig(
        mode="auto", blk=8, hetero_plan=uniform_plan(4, global_batch=slots)),
        slots)
    res["decode_bitwise_uniform"] = bool((l0 == l1).all())
    splan = make_hetero_plan([1.0, 1.0, 1.0, 2.0], global_batch=slots,
                             hidden_size=cfg.moe.d_ff,
                             tp_latencies=[1.0, 1.5],
                             hidden_quantum=max(cfg.moe.d_ff // 4, 8))
    eff_slots = len(splan.token_counts) * splan.batch_capacity
    l2 = decode_logits(ParallelConfig(mode="auto", blk=8, hetero_plan=splan),
                       eff_slots)
    res["decode_skew_finite"] = bool(np.isfinite(l2).all())
print("RESULT" + json.dumps(res))
""")
    assert out["train_bitwise_uniform"], out
    assert out["train_skew_finite"], out
    assert out["decode_bitwise_uniform"], out
    assert out["decode_skew_finite"], out
