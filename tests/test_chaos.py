"""End-to-end chaos scenarios (ISSUE 7 acceptance): scripted fault plans
drive every recovery path and the outcome is asserted BIT-EXACT against
an unfaulted reference, never just "it didn't crash".

  (a) training — injected step failure while the newest checkpoint is
      corrupt: fallback restore from the older valid one, bit-exact
      resume vs the unfaulted trajectory;
  (b) serving — injected mid-decode/mid-prefill failures, an engine-level
      step failure, NaN logits, deadline expiry, and a forced priority
      preemption all recover with greedy streams token-identical to the
      no-fault reference, with the page-pool structural oracle
      (refcounts == slot holders + trie) audited after every step;
  (c) elastic — injected device dropout re-meshes over the survivors
      (serving: ``_shrink``; training CLI: ``choose_mesh_shape`` in a
      subprocess with 8 fake devices) and the run completes.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.checkpoint import manager as ckpt
from repro.core import hetero as hetero_lib
from repro.launch import serve, steps as steps_lib
from repro.models import lm
from repro.parallel.sharding import ParallelConfig, split_tree
from repro.runtime import faults as faults_lib
from repro.runtime import ft as ft_lib

MAX_SEQ = 32



@pytest.fixture(scope="module")
def engine_setup():
    """One cheap all-attention config (prefix-cache capable) shared by
    every serving scenario; f32 keeps greedy margins wide."""
    cfg = dataclasses.replace(cfglib.get_smoke_config("gemma-2b"),
                              dtype="float32")
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    return cfg, pcfg, params


def _mk_requests(cfg, specs, seed=5):
    """Deterministic requests from (plen, max_new) specs — fixed shapes so
    the fault plans' call indices line up with known slots."""
    rng = np.random.default_rng(seed)
    return [
        serve.Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=plen).astype(
                np.int32),
            max_new=max_new,
        )
        for i, (plen, max_new) in enumerate(specs)
    ]


def _refs(cfg, pcfg, params, reqs):
    step = jax.jit(steps_lib.make_serve_step(
        cfg, pcfg, None, (1, 1, cfg.d_model)))
    return {
        r.rid: serve.greedy_reference(
            cfg, pcfg, None, params, r.prompt, r.max_new,
            max_seq=MAX_SEQ, step=step)
        for r in reqs
    }


def _server(cfg, pcfg, params, **kw):
    maxp = MAX_SEQ // 4
    base = dict(num_slots=3, page_size=4, num_pages=1 + 3 * maxp,
                max_pages_per_slot=maxp, params=params, prefill_chunk=5,
                audit=True)
    base.update(kw)
    return serve.PagedServer(cfg, pcfg, None, **base)


def _run_all(server, reqs):
    for r in reqs:
        server.submit(dataclasses.replace(r, out=[]))
    return server.run()


def _assert_drained(server):
    """Leak check: after flushing the prefix cache's retained pages, the
    pool must be exactly full again."""
    server.assert_page_invariants()
    server.drop_prefix_cache()
    server.pool.assert_consistent()
    assert server.pool.free_pages == sum(server.pool.shares)
    assert (server.table == 0).all()


# ---------------------------------------------------------------------------
# (b) serving recovery
# ---------------------------------------------------------------------------

def test_mid_decode_fault_retries_through_prefix_cache(engine_setup):
    """A mid-decode injected device error aborts only the poisoned slot;
    the retry re-admits through the prefix cache (only the uncached
    suffix re-prefills) and every stream ends token-identical to the
    no-fault reference. A second, mid-prefill fault rides along."""
    cfg, pcfg, params = engine_setup
    reqs = _mk_requests(cfg, [(6, 5), (9, 4), (7, 4), (11, 3), (6, 4)])
    refs = _refs(cfg, pcfg, params, reqs)

    plan = faults_lib.FaultPlan([
        # decode call 2: slot 0 (rid 0, FIFO-first admit) is mid-stream
        faults_lib.Fault(site="serve.decode", kind="error", at=2,
                         payload={"slot": 0}),
        faults_lib.Fault(site="serve.prefill", kind="error", at=4,
                         payload={"slot": 1}),
    ])
    srv = _server(cfg, pcfg, params, prefix_cache=True)
    with faults_lib.scope(plan):
        done = _run_all(srv, reqs)

    assert len(plan.fired) == 2
    assert srv.failed == []
    assert len(done) == len(reqs)
    for r in done:
        assert r.out == refs[r.rid], f"rid={r.rid} diverged after retry"
    # both faults turned into request-level aborts (slots were live)
    assert srv.aborts == 2
    assert [t for t in srv.trace if t[0] == "abort"]
    # rid 0 finished prefill before its abort, so its full prompt page was
    # indexed — the retry's admission match reused it (>= one page's worth)
    assert srv.index.hit_tokens >= srv.page_size
    _assert_drained(srv)


def test_engine_level_fault_rejits_and_streams_survive(engine_setup):
    """A step failure with no slot payload is engine-level: the step fns
    are rebuilt and the live page tables carry every request across —
    zero aborts, zero failed, reference-identical streams."""
    cfg, pcfg, params = engine_setup
    reqs = _mk_requests(cfg, [(6, 4), (9, 3), (7, 5), (5, 4)])
    refs = _refs(cfg, pcfg, params, reqs)

    plan = faults_lib.FaultPlan([
        faults_lib.Fault(site="serve.decode", kind="error", at=1),
    ])
    srv = _server(cfg, pcfg, params)
    with faults_lib.scope(plan):
        done = _run_all(srv, reqs)

    assert plan.fired == [("serve.decode", 1, "error")]
    assert srv.engine_recoveries == 1 and ("recover",) in srv.trace
    assert srv.aborts == 0 and srv.failed == []
    assert len(done) == len(reqs)
    for r in done:
        assert r.out == refs[r.rid], f"rid={r.rid} diverged across re-jit"
    _assert_drained(srv)


def test_nan_watchdog_fails_request_not_engine(engine_setup):
    """NaN logits in one slot's row fail THAT request only (satellite c):
    with the retry budget at zero it lands in ``failed``, while its
    same-macro-step batchmates' streams stay reference-identical and the
    engine keeps serving."""
    cfg, pcfg, params = engine_setup
    reqs = _mk_requests(cfg, [(6, 4), (9, 4), (7, 5), (5, 3)])
    refs = _refs(cfg, pcfg, params, reqs)

    plan = faults_lib.FaultPlan([
        faults_lib.Fault(site="serve.logits", kind="nan", at=1,
                         payload={"slot": 0}),
    ])
    srv = _server(cfg, pcfg, params, max_retries=0)
    with faults_lib.scope(plan):
        done = _run_all(srv, reqs)

    assert plan.fired == [("serve.logits", 1, "nan")]
    assert srv.engine_recoveries == 0          # the engine never flinched
    assert len(srv.failed) == 1
    assert srv.failed[0].rid == 0
    assert "non-finite decode logits" in srv.failed[0].error
    assert srv.failed[0].out == []             # no poisoned tokens leak out
    assert {r.rid for r in done} == {1, 2, 3}
    for r in done:
        assert r.out == refs[r.rid], f"batchmate rid={r.rid} was perturbed"
    _assert_drained(srv)


def test_priority_preemption_replays_token_identical(engine_setup):
    """Page exhaustion + a higher-priority head: the youngest decoding
    low-priority request is preempted (pages released, stream cleared),
    re-admits right behind the head, and replays token-identically —
    preemption never consumes its retry budget."""
    cfg, pcfg, params = engine_setup
    low1, low2 = _mk_requests(cfg, [(8, 8), (8, 8)], seed=3)
    (high,) = _mk_requests(cfg, [(4, 2)], seed=9)
    high = dataclasses.replace(high, rid=2, priority=5)
    reqs = [low1, low2, high]
    refs = _refs(cfg, pcfg, params, reqs)

    # a free slot but no pages: the two low-priority requests reserve the
    # whole pool (4 each), so the high-priority head has a slot to enter
    # yet can only reserve by preempting.
    srv = _server(cfg, pcfg, params, num_slots=3, num_pages=1 + 8,
                  max_pages_per_slot=4, prefix_cache=True)
    done = _run_all(srv, reqs)

    assert srv.preemptions == 1
    preempts = [t for t in srv.trace if t[0] == "preempt"]
    assert preempts == [("preempt", 0, 0)]     # rid 0 was the victim
    assert srv.failed == [] and srv.aborts == 0   # no retry budget spent
    assert len(done) == 3
    for r in done:
        assert r.out == refs[r.rid], f"rid={r.rid} diverged after preempt"
    victim = next(r for r in done if r.rid == 0)
    assert victim.preemptions == 1
    # its re-admission went through the radix index (prefix pages reused)
    assert srv.index.hit_tokens >= srv.page_size
    _assert_drained(srv)


class _TickClock:
    """Deterministic wall clock: +1 per reading."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


def test_deadline_expiry_queued_and_in_flight(engine_setup):
    cfg, pcfg, params = engine_setup
    # single slot: r0 occupies it, r1's tiny deadline expires in queue
    r0, r1 = _mk_requests(cfg, [(6, 3), (6, 3)])
    r1 = dataclasses.replace(r1, deadline_s=2.0)
    srv = _server(cfg, pcfg, params, num_slots=1, num_pages=1 + 8,
                  max_pages_per_slot=8, clock=_TickClock())
    done = _run_all(srv, [r0, r1])
    assert [r.rid for r in done] == [0]
    assert done[0].out == _refs(cfg, pcfg, params, [r0])[0]
    assert len(srv.failed) == 1
    assert srv.failed[0].error == "deadline exceeded in queue"
    _assert_drained(srv)

    # in-flight expiry: admitted and decoding, but max_new is far beyond
    # what the deadline allows — pages release like any abort
    (r2,) = _mk_requests(cfg, [(6, 20)], seed=7)
    r2 = dataclasses.replace(r2, deadline_s=10.0)
    srv2 = _server(cfg, pcfg, params, num_slots=1, num_pages=1 + 8,
                   max_pages_per_slot=8, clock=_TickClock())
    done2 = _run_all(srv2, [r2])
    assert done2 == []
    assert len(srv2.failed) == 1
    assert srv2.failed[0].error == "deadline exceeded"   # not "... in queue"
    assert any(t[:1] == ("abort",) and t[3] == "deadline"
               for t in srv2.trace)
    _assert_drained(srv2)


def test_device_dropout_shrinks_pool_and_carries_requests(engine_setup):
    """(c, serving half) An injected device dropout mid-run: live slots
    are aborted back to the queue (no retry charge), the prefix index is
    drained, the pool reshares over the surviving class's weight, and
    every request still ends reference-identical on the shrunken pool."""
    cfg, pcfg, params = engine_setup
    plan_h = hetero_lib.make_hetero_plan((1.0, 2.0), global_batch=4)
    reqs = _mk_requests(cfg, [(6, 4), (9, 3), (7, 4), (5, 5), (6, 3),
                              (10, 4)])
    refs = _refs(cfg, pcfg, params, reqs)

    fplan = faults_lib.FaultPlan([
        faults_lib.Fault(site="serve.decode", kind="device_drop", at=3,
                         payload={"survivors": [0]}),
    ])
    maxp = MAX_SEQ // 4
    srv = _server(cfg, pcfg, params, num_slots=4,
                  num_pages=1 + 4 * maxp, plan=plan_h, prefix_cache=True)
    assert len(srv.pool.shares) == 2           # two device classes pre-drop
    with faults_lib.scope(fplan):
        done = _run_all(srv, reqs)

    assert fplan.fired == [("serve.decode", 3, "device_drop")]
    assert ("shrink", (0,)) in srv.trace
    assert len(srv.pool.shares) == 1           # one surviving class
    assert set(srv.groups) == {0}
    assert srv.failed == []                    # everything fit + finished
    assert len(done) == len(reqs)
    for r in done:
        assert r.out == refs[r.rid], f"rid={r.rid} diverged across shrink"
    _assert_drained(srv)


# ---------------------------------------------------------------------------
# (a) training: step failure + corrupt newest checkpoint, fault-plan-driven
# ---------------------------------------------------------------------------

def _train_step(state, step):
    faults_lib.inject("train.step")
    return ({"x": state["x"] + jnp.float32(step + 1)},
            {"loss": float(step)})


def _train_run(tmp_path, steps=8):
    ft = ft_lib.FTConfig(ckpt_dir=str(tmp_path), save_every=2, keep=3,
                         max_failures=3, backoff_base_s=0.0)
    return ft_lib.run_with_recovery(
        state={"x": jnp.float32(0.0)}, step_fn=_train_step, start_step=0,
        num_steps=steps, ft=ft, sleep_fn=lambda s: None)


def test_training_chaos_corrupt_newest_plus_step_failure(tmp_path, capsys):
    """The full scenario (a) driven end-to-end by one fault plan: the
    step-4 checkpoint is bit-flipped as it commits (``ckpt.write``), then
    step 5 hits an injected device error — recovery must skip the corrupt
    newest checkpoint, restore step 2, and replay to a final state
    bit-exact with the unfaulted run."""
    ref_state, _ = _train_run(tmp_path / "ref")

    plan = faults_lib.FaultPlan([
        faults_lib.Fault(site="ckpt.write", kind="bitflip", at=1,
                         payload={"leaf": 0}),        # 2nd write = step 4
        faults_lib.Fault(site="train.step", kind="error", at=5),
    ])
    d = tmp_path / "chaos"
    with faults_lib.scope(plan):
        state, last = _train_run(d)

    assert last == 8
    assert set(plan.fired) == {("ckpt.write", 1, "bitflip"),
                               ("train.step", 5, "error")}
    np.testing.assert_array_equal(np.asarray(state["x"]),
                                  np.asarray(ref_state["x"]))
    # the fallback really happened: the corrupt step-4 checkpoint was
    # skipped by the verification walk and step 2 restored instead
    out = capsys.readouterr().out
    assert "restored step 2" in out
    # the replay re-saved step 4 over the damaged directory, so by the end
    # the newest retained checkpoints all verify
    assert ckpt.latest_valid_step(str(d)) == ckpt.latest_step(str(d)) == 8


# ---------------------------------------------------------------------------
# (c) training CLI: device dropout -> choose_mesh_shape re-mesh -> resume
# ---------------------------------------------------------------------------

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.multihost
def test_train_cli_elastic_device_dropout(tmp_path):
    """Subprocess with 8 fake devices: ``--elastic --fault-spec`` injects
    a device dropout at step 3 of a 2x2-mesh MoE run; the driver must
    re-mesh over the 2 survivors, restore the step-2 checkpoint onto the
    shrunken mesh, and finish all 6 steps."""
    spec = ('{"faults": [{"site": "train.step", "kind": "device_drop",'
            ' "at": 3, "payload": {"survivors": 2}}]}')
    code = f"""
from repro.launch import train
train.main([
    "--arch", "qwen3-moe-30b-a3b", "--smoke",
    "--steps", "6", "--global-batch", "4", "--seq-len", "16",
    "--mesh", "2,2", "--elastic", "--save-every", "2",
    "--ckpt-dir", {str(tmp_path / "ckpt")!r},
    "--fault-spec", {spec!r},
])
print("RESULT-OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "[elastic] device loss -> re-mesh (2, 1) over 2 survivors" \
        in res.stdout
    assert "[ft] resumed on shrunken mesh" in res.stdout
    assert "[train] finished at step 6" in res.stdout
    assert "RESULT-OK" in res.stdout
