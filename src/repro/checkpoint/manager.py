"""Mesh-agnostic checkpointing with atomic commits and async save.

Layout (one directory per step):

  <dir>/step_000123/
    manifest.json          tree structure, shapes, dtypes, metadata
    a_0000.npy ...         one file per leaf (full logical array)

Design choices for the 1000-node story:
  * Checkpoints record LOGICAL arrays, not device layouts: restore works on
    any mesh/device count (elastic scaling) — new shardings are applied at
    ``device_put`` time.
  * Atomic commit: write into ``step_N.tmp``, fsync, rename. A crash never
    leaves a half checkpoint as "latest".
  * Async: ``save_async`` snapshots to host RAM (device_get) synchronously
    — O(seconds) — then writes in a background thread so training resumes
    immediately; ``wait()`` joins before the next save or exit.
  * On a real multi-host pod each host writes only the shards it owns
    (``process_index`` naming is already threaded through); in this
    single-process environment that degenerates to one writer.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.runtime import faults as faults_lib


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity verification (missing/short/flipped
    leaf bytes, or no readable manifest). Raised by :func:`verify` and
    :func:`restore`; ``runtime.ft`` treats it as "fall back to the next
    older valid checkpoint", not as a training failure."""


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class _CRCWriter:
    """File wrapper that crc32s and counts every byte as it is written,
    so the manifest's integrity record is computed from the exact bytes
    on disk (npy header included) with no second read pass."""

    def __init__(self, f):
        self._f = f
        self.crc = 0
        self.nbytes = 0

    def write(self, data):
        self.crc = zlib.crc32(data, self.crc)
        self.nbytes += len(data)
        return self._f.write(data)

    def __getattr__(self, name):
        return getattr(self._f, name)


def save(directory: str, step: int, tree: Any, meta: Optional[dict] = None) -> str:
    """Synchronous atomic checkpoint save. Returns final path."""
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    return _write(directory, step, host_leaves, treedef, meta or {})


_VIEW_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _fsync_write(path: str, write_fn) -> None:
    """Write + flush one file to stable storage before the commit rename."""
    with open(path, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())


def _write(directory, step, host_leaves, treedef, meta) -> str:
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    names = []
    crcs = []
    nbytes = []
    for i, leaf in enumerate(host_leaves):
        name = f"a_{i:05d}.npy"
        arr = np.asarray(leaf)
        # npy has no ml_dtypes support: store as a same-width uint view.
        view = _VIEW_DTYPES.get(str(arr.dtype))
        if view is not None:
            arr = arr.view(view)

        def write_with_crc(f, a=arr):
            w = _CRCWriter(f)
            np.save(w, a)
            crcs.append(w.crc)
            nbytes.append(w.nbytes)

        _fsync_write(os.path.join(tmp, name), write_with_crc)
        names.append(name)
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(
            jax.tree_util.tree_unflatten(treedef, list(range(len(names))))
        ).__repr__(),
        "num_leaves": len(names),
        # LOGICAL dtypes (pre-view): restore cross-checks these against the
        # target structure so a mixed-dtype tree (int8 payloads + f32 scale
        # leaves, DESIGN.md §8) can never silently load into the wrong
        # leaf after a structural drift.
        "dtypes": [str(np.asarray(l).dtype) for l in host_leaves],
        # Per-leaf integrity record over the exact file bytes: restore and
        # ``verify`` recompute these, so a truncated or bit-flipped leaf is
        # detected *before* it is handed to the model, and
        # ``latest_valid_step`` can skip a damaged checkpoint entirely.
        "crc32": crcs,
        "nbytes": nbytes,
        "meta": meta,
        "process_index": jax.process_index(),
    }
    _fsync_write(os.path.join(tmp, "manifest.json"),
                 lambda f: f.write(json.dumps(manifest, indent=1).encode()))
    if os.path.exists(final):
        shutil.rmtree(final)
    # The rename is the commit point: data was fsynced above, and the parent
    # directory entry is fsynced after, so a crash can never order the
    # rename ahead of the checkpoint's bytes ("latest" is always complete).
    os.replace(tmp, final)
    dir_fd = os.open(directory, os.O_RDONLY)
    try:
        os.fsync(dir_fd)
    finally:
        os.close(dir_fd)
    # Chaos hook (DESIGN.md §9): "ckpt.write" faults model storage damage
    # *after* the atomic commit — exactly the failure the crc32 record
    # exists to catch — by corrupting a committed leaf file in place.
    for f in faults_lib.inject("ckpt.write", step=step, path=final):
        if f.kind in ("truncate", "bitflip"):
            faults_lib.corrupt_checkpoint(final, f)
    return final


class AsyncSaver:
    """Snapshot-to-host synchronously, write in a background thread.

    A failed background write re-raises at the next ``wait()`` (or the next
    ``save()``, which waits first) instead of vanishing with the thread —
    otherwise the trainer keeps running, ``gc_old`` prunes the older good
    checkpoints, and a later warm restart restores something stale while
    believing the newest save succeeded."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.last_path: Optional[str] = None

    def save(self, directory: str, step: int, tree: Any,
             meta: Optional[dict] = None,
             post: Optional[Callable[[str], None]] = None) -> None:
        """Queue an async write. ``post(final_path)`` runs in the worker
        thread only after the write commits — the ordering hook retention
        GC needs: pruning against a listing that already contains the new
        checkpoint, never racing the in-flight write."""
        self.wait()
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

        def work():
            try:
                self.last_path = _write(directory, step, host_leaves,
                                        treedef, meta or {})
                if post is not None:
                    post(self.last_path)
            except BaseException as exc:  # noqa: BLE001 — handed to wait()
                self._error = exc

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            exc, self._error = self._error, None
            raise exc


def latest_step(directory: str) -> Optional[int]:
    """Newest COMMITTED step on disk, integrity-unchecked — "what
    exists", not "what is safe to load" (that is
    :func:`latest_valid_step`)."""
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def verify(path: str) -> None:
    """Integrity-check one committed checkpoint directory against its
    manifest: every leaf file must exist with exactly the recorded byte
    count and crc32. Raises :class:`CheckpointCorruptError` on any
    mismatch; pre-integrity checkpoints (no ``crc32`` record) pass, so old
    on-disk trees stay restorable."""
    manifest_path = os.path.join(path, "manifest.json")
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as exc:
        raise CheckpointCorruptError(
            f"{path}: unreadable manifest ({exc})") from exc
    crcs = manifest.get("crc32")
    nbytes = manifest.get("nbytes")
    for i in range(manifest["num_leaves"]):
        leaf_path = os.path.join(path, f"a_{i:05d}.npy")
        try:
            with open(leaf_path, "rb") as f:
                data = f.read()
        except OSError as exc:
            raise CheckpointCorruptError(
                f"{path}: missing leaf {i} ({exc})") from exc
        if nbytes is not None and len(data) != nbytes[i]:
            raise CheckpointCorruptError(
                f"{path}: leaf {i} has {len(data)} bytes, "
                f"manifest records {nbytes[i]} (truncated/partial write)")
        if crcs is not None and zlib.crc32(data) != crcs[i]:
            raise CheckpointCorruptError(
                f"{path}: leaf {i} crc32 mismatch (corrupt bytes)")


def valid_steps(directory: str) -> list[int]:
    """Committed steps that pass :func:`verify`, newest first — the
    fallback-restore walk order for ``runtime.ft.run_with_recovery``."""
    if not os.path.isdir(directory):
        return []
    steps = sorted(
        (
            int(d.split("_")[1])
            for d in os.listdir(directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        ),
        reverse=True,
    )
    good = []
    for s in steps:
        try:
            verify(os.path.join(directory, f"step_{s:08d}"))
        except CheckpointCorruptError:
            continue
        good.append(s)
    return good


def latest_valid_step(directory: str) -> Optional[int]:
    """Newest committed step that passes integrity verification, skipping
    corrupt/partial checkpoints (None when no checkpoint loads)."""
    good = valid_steps(directory)
    return good[0] if good else None


def restore(
    directory: str,
    step: int,
    like: Any,
    shardings: Optional[Any] = None,
) -> tuple[Any, dict]:
    """Restore into the structure of ``like``; apply ``shardings`` (same
    tree) if given — this is where elastic re-sharding happens: the stored
    logical arrays are placed onto whatever mesh the new job runs."""
    path = os.path.join(directory, f"step_{step:08d}")
    verify(path)  # crc32 + byte counts — refuse to restore damaged bytes
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like)
    assert manifest["num_leaves"] == len(leaves), (
        f"checkpoint has {manifest['num_leaves']} leaves, "
        f"target structure has {len(leaves)}"
    )
    # Mixed-dtype round-trip guard: the manifest records every leaf's
    # logical dtype; a target structure whose leaf dtypes disagree fails
    # loudly BEFORE any array is materialised (a silent cast here would
    # corrupt int8 payload / f32 scale pairs, DESIGN.md §8).
    stored = manifest.get("dtypes")
    if stored is not None:
        # string compare: bfloat16/float8 dtype names are ml_dtypes
        # extensions plain np.dtype() cannot parse
        mismatched = [
            f"leaf {i}: checkpoint {s} vs target {l.dtype}"
            for i, (s, l) in enumerate(zip(stored, leaves))
            if hasattr(l, "dtype") and s != str(l.dtype)
        ]
        if mismatched:
            raise ValueError(
                "checkpoint/target dtype mismatch:\n  "
                + "\n  ".join(mismatched)
            )

    def load_one(i, like):
        h = np.load(os.path.join(path, f"a_{i:05d}.npy"))
        want = np.dtype(like.dtype) if hasattr(like, "dtype") else None
        if want is not None and str(want) in _VIEW_DTYPES:
            h = h.view(want)  # undo the uint storage view
        assert tuple(h.shape) == tuple(np.shape(like)), (h.shape, like)
        if want is not None and h.dtype != want:
            raise ValueError(
                f"leaf {i}: stored dtype {h.dtype} does not match target "
                f"{want} (refusing a silent cast)"
            )
        return h

    host = [load_one(i, l) for i, l in enumerate(leaves)]
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        new_leaves = [
            jax.device_put(h, s) for h, s in zip(host, flat_sh)
        ]
    else:
        new_leaves = [jax.numpy.asarray(h) for h in host]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["meta"]


def gc_old(directory: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` committed checkpoints.

    ``keep=0`` deletes everything (the old ``steps[:-keep]`` slice made it
    silently keep everything instead). ``.tmp`` dirs are never touched:
    one may belong to an in-flight ``AsyncSaver`` write, and ``_write``
    clears its own stale tmp before re-using the name."""
    if keep < 0:
        raise ValueError(f"keep must be >= 0, got {keep}")
    if not os.path.isdir(directory):
        return
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    cut = len(steps) - keep
    for d in steps[:max(cut, 0)]:
        shutil.rmtree(os.path.join(directory, d))
