"""Error-feedback int8 gradient compression for thin cross-pod links.

The inter-pod (DCN / optical) hop is the thinnest link in a multi-pod mesh;
compressing the data-parallel gradient reduction over the "pod" axis cuts
that traffic 2x (bf16) / 4x (f32). Error feedback keeps the compression
unbiased over time: the quantisation residual is carried to the next step
(Seide et al.; 1-bit Adam lineage).

``compressed_psum`` is collective-correct: the shared scale is agreed with a
(psum, max) of per-pod maxima, then int8 payloads are summed as int32 and
dequantised — associative, so the result is exact for the quantised values.

The int8 primitives themselves live in ``quant.core`` (one rounding/
clipping convention repo-wide, shared with the fused-kernel weight path,
DESIGN.md §8) and are re-exported here for the existing public API.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.quant.core import dequantize_int8, quantize_int8  # noqa: F401


def compress_roundtrip(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(reconstruction, residual) for a single tensor (local use/tests)."""
    scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0
    q = quantize_int8(x, scale)
    rec = dequantize_int8(q, scale).astype(x.dtype)
    return rec, x - rec


def compressed_psum(
    g: jax.Array,
    axis_name: str,
    residual: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """psum over ``axis_name`` with int8 payload + error feedback.

    Must be called inside a shard_map that is manual over ``axis_name``.
    Returns (summed_gradient, new_residual).
    """
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    scale = lax.pmax(jnp.max(jnp.abs(gf)), axis_name) / 127.0
    q = quantize_int8(gf, scale)
    local_rec = dequantize_int8(q, scale)
    new_residual = gf - local_rec
    total = lax.psum(q.astype(jnp.int32), axis_name)
    out = dequantize_int8(total, scale).astype(g.dtype)
    return out, new_residual.astype(g.dtype)


def tree_compressed_psum(
    grads: Any, axis_name: str, residuals: Optional[Any]
) -> tuple[Any, Any]:
    if residuals is None:
        residuals = jax.tree.map(lambda g: jnp.zeros_like(g), grads)
    pairs = jax.tree.map(
        lambda g, r: compressed_psum(g, axis_name, r), grads, residuals
    )
    out = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return out, res
