"""AdamW with configurable state dtypes and warmup-cosine schedule.

Pure JAX (no optax in this environment). Memory knobs that matter at the
512-chip scale (see EXPERIMENTS.md §Dry-run):

  * ``state_dtype`` — m/v moments in bf16 halve optimizer memory; the
    update math is always f32.
  * ``master_fp32`` — keep an f32 master copy when params are bf16
    (standard mixed-precision training); disable to save 4 bytes/param
    when the model checkpoint dtype is already f32.

Optimizer state inherits each parameter's sharding (same tree structure),
so FSDP-sharded params get FSDP-sharded moments — ZeRO-2/3 for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"     # moments dtype
    master_fp32: bool = True         # keep f32 master for low-prec params


def schedule(step: jax.Array, cfg: OptimizerConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any, cfg: OptimizerConfig) -> dict:
    sd = jnp.dtype(cfg.state_dtype)
    zeros_like = lambda p: jnp.zeros(p.shape, sd)
    state = {
        "m": jax.tree.map(zeros_like, params),
        "v": jax.tree.map(zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_fp32:
        needs_master = lambda p: (
            jnp.issubdtype(p.dtype, jnp.floating) and p.dtype != jnp.float32
        )
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32) if needs_master(p) else None,
            params,
        )
    return state


def global_norm(tree: Any) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(
    params: Any, grads: Any, state: dict, cfg: OptimizerConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    sd = jnp.dtype(cfg.state_dtype)
    masters = state.get("master")

    def upd(p, g, m, v, master):
        gf = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        update = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        base = master if master is not None else p.astype(jnp.float32)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_base = base - lr * (update + decay * base)
        new_p = new_base.astype(p.dtype)
        new_master = new_base if master is not None else None
        return new_p, mf.astype(sd), vf.astype(sd), new_master

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    if masters is not None:
        flat_ma = treedef.flatten_up_to(masters)
    else:
        flat_ma = [None] * len(flat_p)

    outs = [
        upd(p, g, m, v, ma)
        for p, g, m, v, ma in zip(flat_p, flat_g, flat_m, flat_v, flat_ma)
    ]
    new_params = treedef.unflatten([o[0] for o in outs])
    new_state = {
        "m": treedef.unflatten([o[1] for o in outs]),
        "v": treedef.unflatten([o[2] for o in outs]),
        "step": step,
    }
    if masters is not None:
        new_state["master"] = treedef.unflatten([o[3] for o in outs])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics
