"""xLSTM layers: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential) — for the xlstm-350m architecture.

mLSTM trains with the chunkwise form: within a chunk the exponential-gating
decay structure is an attention-like (C x C) matrix per head (stabilised by
a running max m); across chunks a (hd x hd) matrix memory is carried. This
is the TPU-friendly shape — per-chunk work is dense matmuls. Decode is the
O(hd^2) recurrent update, which is what makes xlstm a long_500k architecture.

sLSTM has a true sequential dependency through its block-diagonal recurrent
matrix, so it runs as a lax.scan over time (cheap: scalar memory per
channel).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import Param, normal_init

NEG = -1e30


def _dims(cfg: ModelConfig):
    x = cfg.xlstm
    d_in = int(x.proj_factor * cfg.d_model)
    nh = cfg.num_heads
    hd = d_in // nh
    return d_in, nh, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_in, nh, hd = _dims(cfg)
    kconv = cfg.xlstm.conv_kernel
    ks = jax.random.split(key, 8)
    return {
        "up": Param(normal_init(ks[0], (d, 2 * d_in), dtype), ("fsdp", "tp")),
        "conv_w": Param(normal_init(ks[1], (kconv, d_in), dtype, 0.2), (None, "tp")),
        "conv_b": Param(jnp.zeros((d_in,), jnp.float32), ("tp",)),
        "wq": Param(normal_init(ks[2], (d_in, d_in), dtype), ("tp", None)),
        "wk": Param(normal_init(ks[3], (d_in, d_in), dtype), ("tp", None)),
        "wv": Param(normal_init(ks[4], (d_in, d_in), dtype), ("tp", None)),
        "w_if": Param(normal_init(ks[5], (d_in, 2 * nh), dtype), ("tp", None)),
        "b_if": Param(
            jnp.concatenate(
                [jnp.zeros((nh,), jnp.float32), 3.0 * jnp.ones((nh,), jnp.float32)]
            ),
            (None,),
        ),
        "down": Param(normal_init(ks[6], (d_in, d), dtype), ("tp", "fsdp")),
    }


from repro.models.mamba import causal_depthwise_conv as _causal_conv  # noqa: E402


def _mlstm_chunked(q, k, v, log_i, log_f, chunk):
    """q/k/v: (B,S,NH,HD) any dtype; log_i/log_f: (B,S,NH) f32.
    Returns y (B,S,NH,HD) f32 and final (C, n, m) state."""
    bsz, s, nh, hd = q.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk

    # Keep scan inputs in the storage dtype (bf16); cast per chunk inside
    # the rematerialised step so f32 copies never exist at full seq length.
    out_dtype = v.dtype
    q = q.reshape(bsz, nc, chunk, nh, hd)
    k = k.reshape(bsz, nc, chunk, nh, hd)
    v = v.reshape(bsz, nc, chunk, nh, hd)
    log_i = log_i.reshape(bsz, nc, chunk, nh)
    fcum = jnp.cumsum(log_f.reshape(bsz, nc, chunk, nh), axis=2)
    fsum = fcum[:, :, -1]  # (B, nc, NH)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def step(carry, inp):
        c_mat, n_vec, m_run = carry  # (B,NH,HD,HD), (B,NH,HD), (B,NH)
        qc, kc, vc, li, fc, ft = inp  # per-chunk slices
        qc = qc.astype(jnp.float32)
        kc = kc.astype(jnp.float32) / (hd ** 0.5)
        vc = vc.astype(jnp.float32)
        # Intra-chunk pair log-weights D[t,s] = fcum_t - fcum_s + i_s, s<=t.
        dmat = fc[:, :, None, :] - fc[:, None, :, :] + li[:, None, :, :]
        dmat = jnp.where(tri[None, :, :, None], dmat, NEG)   # (B,C,C,NH)
        inter_log = fc + m_run[:, None, :]                   # (B,C,NH)
        m_t = jnp.maximum(jnp.max(dmat, axis=2), inter_log)  # (B,C,NH)
        w_pair = jnp.exp(dmat - m_t[:, :, None, :])          # (B,C,C,NH)
        w_inter = jnp.exp(inter_log - m_t)                   # (B,C,NH)

        logits = jnp.einsum("bthd,bshd->btsh", qc, kc)       # (B,C,C,NH)
        num = (
            jnp.einsum("btsh,bshd->bthd", logits * w_pair, vc)
            + jnp.einsum("bthd,bhde->bthe", qc, c_mat) * w_inter[..., None]
        )
        n_t = (
            jnp.einsum("btsh,bshd->bthd", w_pair, kc)
            + w_inter[..., None] * n_vec[:, None]
        )
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bthd,bthd->bth", qc, n_t)), jnp.exp(-m_t)
        )
        y = num / den[..., None]

        # Chunk-end state.
        end_log = ft[:, None, :] - fc + li                   # (B,C,NH)
        m_end = jnp.maximum(ft + m_run, jnp.max(end_log, axis=1))
        w_end = jnp.exp(end_log - m_end[:, None, :])         # (B,C,NH)
        decay = jnp.exp(ft + m_run - m_end)                  # (B,NH)
        c_new = decay[..., None, None] * c_mat + jnp.einsum(
            "bsh,bshd,bshe->bhde", w_end, kc, vc
        )
        n_new = decay[..., None] * n_vec + jnp.einsum(
            "bsh,bshd->bhd", w_end, kc
        )
        return (c_new, n_new, m_end), y.astype(out_dtype)

    c0 = jnp.zeros((bsz, nh, hd, hd), jnp.float32)
    n0 = jnp.zeros((bsz, nh, hd), jnp.float32)
    m0 = jnp.zeros((bsz, nh), jnp.float32)
    (cN, nN, mN), ys = jax.lax.scan(
        step,
        (c0, n0, m0),
        tuple(
            jnp.moveaxis(t, 1, 0)
            for t in (q, k, v, log_i, fcum, fsum)
        ),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, nh, hd)
    return y, (cN, nN, mN)


def _mlstm_step(q, k, v, log_i, log_f, state):
    """Single decode step. q/k/v: (B,NH,HD); gates: (B,NH)."""
    c_mat, n_vec, m_run = state
    hd = q.shape[-1]
    k = k / (hd ** 0.5)
    m_new = jnp.maximum(log_f + m_run, log_i)
    decay = jnp.exp(log_f + m_run - m_new)
    inw = jnp.exp(log_i - m_new)
    c_new = decay[..., None, None] * c_mat + inw[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n_new = decay[..., None] * n_vec + inw[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, c_new)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)), jnp.exp(-m_new)
    )
    y = num / den[..., None]
    return y, (c_new, n_new, m_new)


def apply_mlstm(p, x, ctx, cache: Optional[dict]):
    """x: (B,S,D) -> (y, cache'). cache: {"c","n","m","conv"}."""
    from repro.parallel.sharding import constrain

    cfg, mode = ctx.cfg, ctx.mode
    bsz, s, _ = x.shape
    d_in, nh, hd = _dims(cfg)
    xz = x @ p["up"].astype(x.dtype)
    # The recurrent head structure (nh=4) is too narrow for wide TP: the
    # mixer body runs replicated over "model" (xlstm-scale models are small;
    # see DESIGN.md §4 / the roofline table's honest verdict on this arch).
    xz = constrain(xz, (("dp",), None, None), ctx.pcfg, ctx.mesh)
    xm, z = jnp.split(xz, 2, axis=-1)

    kconv = cfg.xlstm.conv_kernel
    if mode == "decode":
        conv_in = jnp.concatenate(
            [cache["conv"], xm.astype(cache["conv"].dtype)], axis=1
        )
        xc = jnp.einsum(
            "bkd,kd->bd", conv_in.astype(jnp.float32),
            p["conv_w"].astype(jnp.float32),
        ) + p["conv_b"]
        xc = jax.nn.silu(xc)[:, None].astype(x.dtype)
        new_conv = conv_in[:, 1:]
    else:
        xc = jax.nn.silu(_causal_conv(xm, p["conv_w"], p["conv_b"]))
        xc = xc.astype(x.dtype)
        new_conv = (
            jnp.pad(xm, [(0, 0), (kconv - 1, 0), (0, 0)])[:, -(kconv - 1):]
            if cache is not None else None
        )

    q = (xc @ p["wq"].astype(x.dtype)).reshape(bsz, s, nh, hd)
    k = (xc @ p["wk"].astype(x.dtype)).reshape(bsz, s, nh, hd)
    v = (xm @ p["wv"].astype(x.dtype)).reshape(bsz, s, nh, hd)
    gif = (xc @ p["w_if"].astype(x.dtype)).astype(jnp.float32) + p["b_if"]
    log_i, f_pre = jnp.split(gif, 2, axis=-1)           # (B,S,NH) each
    log_f = jax.nn.log_sigmoid(f_pre)

    if mode == "decode":
        state = (cache["c"], cache["n"], cache["m"])
        y, new_state = _mlstm_step(
            q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
            v[:, 0].astype(jnp.float32), log_i[:, 0], log_f[:, 0], state,
        )
        y = y[:, None]
    else:
        y, new_state = _mlstm_chunked(q, k, v, log_i, log_f, cfg.xlstm.chunk)

    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ p["down"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {
            "c": new_state[0], "n": new_state[1], "m": new_state[2],
            "conv": new_conv,
        }
    return out, new_cache


def cache_spec_mlstm(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in, nh, hd = _dims(cfg)
    kconv = cfg.xlstm.conv_kernel
    return {
        "c": jax.ShapeDtypeStruct((batch, nh, hd, hd), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, nh, hd), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, nh), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, kconv - 1, d_in), dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    ks = jax.random.split(key, 4)
    return {
        # input projections for 4 gates (i, f, z, o)
        "w_in": Param(normal_init(ks[0], (d, 4 * d), dtype), ("fsdp", "tp")),
        # block-diagonal recurrent weights, per head
        "r": Param(normal_init(ks[1], (nh, hd, 4 * hd), dtype), (None, None, None)),
        "b": Param(
            jnp.concatenate(
                [jnp.zeros((d,)), 3.0 * jnp.ones((d,)), jnp.zeros((2 * d,))]
            ).astype(jnp.float32),
            (None,),
        ),
    }


def _slstm_scan(gates_in, r, b, nh, hd, state):
    """gates_in: (B,S,4D) precomputed input contributions."""
    bsz, s, _ = gates_in.shape

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def step(carry, g_t):
        c, n, h, m = carry  # (B,NH,HD) x3, (B,NH,HD)
        g_t = g_t.astype(jnp.float32)
        rec = jnp.einsum(
            "bhd,hdk->bhk", h, r.astype(jnp.float32)
        )  # (B,NH,4HD)
        g = g_t.reshape(bsz, nh, 4, hd) + rec.reshape(bsz, nh, 4, hd) \
            + b.reshape(nh, 4, hd)[None]
        i_pre, f_pre, z_pre, o_pre = (g[:, :, j] for j in range(4))
        log_f = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(log_f + m, i_pre)
        i_g = jnp.exp(i_pre - m_new)
        f_g = jnp.exp(log_f + m - m_new)
        z_g = jnp.tanh(z_pre)
        o_g = jax.nn.sigmoid(o_pre)
        c_new = f_g * c + i_g * z_g
        n_new = jnp.maximum(f_g * n + i_g, 1e-6)
        h_new = o_g * c_new / n_new
        return (c_new, n_new, h_new, m_new), h_new.astype(jnp.bfloat16)

    gseq = jnp.moveaxis(gates_in.reshape(bsz, s, 4 * nh * hd), 1, 0)
    (c, n, h, m), hs = jax.lax.scan(step, state, gseq)
    return jnp.moveaxis(hs, 0, 1), (c, n, h, m)


def apply_slstm(p, x, ctx, cache: Optional[dict]):
    from repro.parallel.sharding import constrain

    cfg, mode = ctx.cfg, ctx.mode
    bsz, s, d = x.shape
    nh = cfg.num_heads
    hd = d // nh
    gates_in = x @ p["w_in"].astype(x.dtype)  # (B,S,4D)
    gates_in = constrain(gates_in, (("dp",), None, None), ctx.pcfg, ctx.mesh)
    if cache is not None and mode == "decode":
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
    else:
        z = jnp.zeros((bsz, nh, hd), jnp.float32)
        state = (z, z, z, z)
    hs, new_state = _slstm_scan(gates_in, p["r"], p["b"], nh, hd, state)
    y = hs.reshape(bsz, s, d).astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = dict(zip(("c", "n", "h", "m"), new_state))
    return y, new_cache


def cache_spec_slstm(cfg: ModelConfig, batch: int) -> dict:
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    shp = (batch, nh, hd)
    return {
        k: jax.ShapeDtypeStruct(shp, jnp.float32) for k in ("c", "n", "h", "m")
    }
