"""Attention primitives: RoPE, chunked-causal GQA attention, decode attention.

Training/prefill attention is *chunked-causal*: an unrolled outer loop over
query chunks where chunk c only reads K/V[0 : (c+1)*chunk] (static slice), so
the compiled FLOPs are ~half of a masked full-S^2 implementation and sliding
windows become genuinely sub-quadratic (chunk c reads a static window slice).
Within a chunk an online-softmax scan over KV blocks bounds live memory to
(chunk x kv_block) logits — the pure-XLA shape of flash attention, chosen
over a Pallas kernel because the multi-pod dry-run must lower through XLA on
CPU (DESIGN.md §2); a Pallas flash kernel would unroll its grid in interpret
mode.

Decode attention is a plain einsum over the cache: O(S·d) memory-bound work
that GSPMD shards (sequence-sharded caches combine via partial-softmax
all-reduce — the flash-decode pattern, inserted by the partitioner).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common import cdiv

NEG_INF = -2.0e38


def rope(
    x: jax.Array, positions: jax.Array, theta: float = 1e4
) -> jax.Array:
    """Rotary embeddings. x: (B, S, H, hd); positions: (B, S) or (S,)."""
    b, s, h, hd = x.shape
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def _attn_block(q, k, qpos, kpos, *, causal, window, prefix_len, scale, softcap):
    """Masked logits for one (q-chunk, kv-block) pair."""
    # q: (B, cs, Hkv, G, hd); k: (B, bk, Hkv, hd)
    logits = jnp.einsum(
        "bqhgd,bkhd->bqhgk", q, k, preferred_element_type=jnp.float32
    ) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    if causal:
        allowed = kpos[None, :] <= qpos[:, None]
        if window is not None:
            allowed &= kpos[None, :] > (qpos[:, None] - window)
        if prefix_len:
            allowed |= (kpos[None, :] < prefix_len) & (qpos[:, None] < prefix_len)
    else:
        allowed = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    return jnp.where(allowed[None, :, None, None, :], logits, NEG_INF)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
    q_chunk: int = 2048,
    kv_block: int = 2048,
    scale: Optional[float] = None,
    softcap: float = 0.0,
) -> jax.Array:
    """GQA attention, sub-quadratic-aware. q: (B,S,Hq,hd); k/v: (B,S,Hkv,hd)."""
    b, s, hq, hd = q.shape
    _, skv, hkv, _ = k.shape
    assert s == skv, "prefill/train assumes aligned q and kv"
    g = hq // hkv
    scale = scale if scale is not None else hd ** -0.5
    q = q.reshape(b, s, hkv, g, hd)

    q_chunk = min(q_chunk, s)
    while s % q_chunk:
        q_chunk //= 2
    n_chunks = s // q_chunk

    @functools.partial(jax.checkpoint, prevent_cse=False, static_argnums=(3,))
    def run_chunk(q_c, k_c, v_c, meta):
        """One query chunk. Rematerialised in backward so per-chunk online-
        softmax residuals never accumulate across chunks (flash-attention
        memory structure, expressed as nested remat)."""
        c, start, span, bk = meta
        qpos = c * q_chunk + jnp.arange(q_chunk)
        kb = k_c.reshape(b, span // bk, bk, hkv, hd)
        vb = v_c.reshape(b, span // bk, bk, hkv, hd)
        kpos0 = start + jnp.arange(span).reshape(span // bk, bk)

        def step(carry, inp):
            m, l, acc = carry
            kblk, vblk, kpos = inp
            logits = _attn_block(
                q_c, kblk, qpos, kpos, causal=causal,
                window=window if causal else None,
                prefix_len=prefix_len, scale=scale, softcap=softcap,
            )
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p, vblk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, q_chunk, hkv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, hkv, g), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, hkv, g, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(step, prevent_cse=False),
            (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), kpos0),
        )
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out_chunks = []
    for c in range(n_chunks):
        q_c = jax.lax.slice_in_dim(q, c * q_chunk, (c + 1) * q_chunk, axis=1)
        # Static KV range this chunk can see.
        end = (c + 1) * q_chunk if causal else s
        start = 0
        if causal and window is not None and not prefix_len:
            start = max(0, (c + 1) * q_chunk - window - q_chunk)
        span = end - start
        bk = min(kv_block, span)
        while span % bk:
            bk //= 2
        k_c = jax.lax.slice_in_dim(k, start, end, axis=1)
        v_c = jax.lax.slice_in_dim(v, start, end, axis=1)
        out_chunks.append(run_chunk(q_c, k_c, v_c, (c, start, span, bk)))

    out = jnp.concatenate(out_chunks, axis=1)
    return out.reshape(b, s, hq, hd).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    scale: Optional[float] = None,
    softcap: float = 0.0,
) -> jax.Array:
    """Single-step decode. q: (B,1,Hq,hd); caches: (B,S,Hkv,hd).

    Positions >= cache_len are masked. Memory-bound: one pass over the
    cache; with a sequence-sharded cache GSPMD lowers the softmax into the
    flash-decode partial-reduction pattern.
    """
    b, one, hq, hd = q.shape
    _, s, hkv, _ = k_cache.shape
    g = hq // hkv
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(b, hkv, g, hd)
    logits = jnp.einsum(
        "bhgd,bshd->bhgs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    valid = jnp.arange(s)[None] < cache_len[:, None]  # (B, S)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, hq, hd).astype(q.dtype)
