"""Transformer building blocks: norms, dense FFN, GQA attention blocks,
and the generic block dispatcher used by every architecture.

Parameter trees use ``parallel.sharding.Param`` leaves (value + logical
spec). Apply functions consume plain value trees (specs are stripped at
model assembly time).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.parallel.moe_parallel import MoEParams, MoEStatic, moe_layer
from repro.parallel.sharding import (
    ParallelConfig,
    Param,
    constrain,
    normal_init,
    ones_init,
    zeros_init,
)


@dataclasses.dataclass
class Ctx:
    """Per-call context threaded through apply functions."""
    cfg: ModelConfig
    pcfg: ParallelConfig
    mesh: Optional[Mesh]
    mode: str                       # train | prefill | decode
    positions: jax.Array            # (B, S) absolute positions
    cache_len: Optional[jax.Array]  # (B,) filled length before this step
    x_spec: P                       # sharding of (B, S, D) activations
    rng: Optional[jax.Array] = None
    cond: Optional[jax.Array] = None  # cross-attention memory (B, T, Dc)
    layer_idx: Optional[int] = None   # period position (auto-mode plan key)
    paged: Optional[dict] = None      # paged-KV decode (DESIGN.md §7):
    #   {"table": (B, maxp) i32, "page_size": int}
    decode_active: Optional[jax.Array] = None  # (B,) continuous-batching
    #   mask: inactive slots write nothing, freeze state, don't advance

    @property
    def dtype(self):
        return jnp.dtype(self.cfg.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> dict:
    return {"scale": Param(jnp.ones((d,), jnp.float32), (None,))}


def rmsnorm(p: dict, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def init_layernorm(d: int) -> dict:
    return {
        "scale": Param(jnp.ones((d,), jnp.float32), (None,)),
        "bias": Param(jnp.zeros((d,), jnp.float32), (None,)),
    }


def layernorm(p: dict, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


def apply_norm(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "bias" in p:
        return layernorm(p, x, cfg.norm_eps)
    return rmsnorm(p, x, cfg.norm_eps)


def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> dict:
    d = d or cfg.d_model
    return init_layernorm(d) if cfg.norm == "layernorm" else init_rmsnorm(d)


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------

def init_dense_ffn(key, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    if cfg.glu:
        return {
            "w_gate": Param(normal_init(ks[0], (d, f), dtype), ("fsdp", "tp")),
            "w_up": Param(normal_init(ks[1], (d, f), dtype), ("fsdp", "tp")),
            "w_down": Param(normal_init(ks[2], (f, d), dtype), ("tp", "fsdp")),
        }
    return {
        "w1": Param(normal_init(ks[0], (d, f), dtype), ("fsdp", "tp")),
        "b1": Param(jnp.zeros((f,), jnp.float32), ("tp",)),
        "w2": Param(normal_init(ks[1], (f, d), dtype), ("tp", "fsdp")),
        "b2": Param(jnp.zeros((d,), jnp.float32), (None,)),
    }


def apply_dense_ffn(p: dict, x: jax.Array, ctx: Ctx) -> jax.Array:
    """Megatron FFN: AG activations over seq once, hidden sharded over TP,
    reduce-scatter the down-projection partials back to seq-sharded.
    Without the explicit hidden constraints GSPMD gathers the full FFN
    weights instead (EXPERIMENTS.md §Perf, jamba iteration 2)."""
    from repro.core.espec import ACTIVATIONS

    act = ACTIVATIONS[ctx.cfg.act]
    hid = (("dp",), None, "tp")
    out_spec = (("dp",), "sp", None)
    if ctx.mode == "decode":
        hid = (("dp",), None, "tp")
        out_spec = None
    if "w_gate" in p:
        g = constrain(x @ p["w_gate"].astype(x.dtype), hid, ctx.pcfg, ctx.mesh)
        u = constrain(x @ p["w_up"].astype(x.dtype), hid, ctx.pcfg, ctx.mesh)
        y = (act(g) * u) @ p["w_down"].astype(x.dtype)
    else:
        h = constrain(
            x @ p["w1"].astype(x.dtype) + p["b1"].astype(x.dtype),
            hid, ctx.pcfg, ctx.mesh,
        )
        y = act(h) @ p["w2"].astype(x.dtype) + p["b2"].astype(x.dtype)
    if out_spec is not None:
        y = constrain(y, out_spec, ctx.pcfg, ctx.mesh)
    return y


# ---------------------------------------------------------------------------
# MoE FFN (espec path through the distributed island)
# ---------------------------------------------------------------------------

def init_moe_ffn(key, cfg: ModelConfig, dtype, plan=None) -> dict:
    """Expert FFN parameters, optionally laid out for a heterogeneous plan.

    With a ``core.hetero.HeteroPlan`` carrying Eq. 2 ``hidden_splits``, the
    FFN hidden dim is padded to per-TP-rank MXU-aligned tiles
    (``plan.padded_hidden_size()``); the padded columns are initialised to
    exact zeros, contribute exactly zero to the forward, receive exactly
    zero gradient, and therefore stay zero under training (DESIGN.md §6
    padding invariant). An even, quantum-aligned split needs no padding and
    leaves the init bitwise identical to the plan-less path."""
    from repro.parallel.moe_parallel import MOE_PARAM_LOGICAL as L

    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff, m.num_experts
    col = None  # (F',) validity mask over padded hidden columns
    if plan is not None and getattr(plan, "hidden_splits", None) is not None:
        from repro.core.hetero import hidden_mask

        if sum(plan.hidden_splits) != f:
            raise ValueError(
                f"hetero_plan.hidden_splits sum to {sum(plan.hidden_splits)}"
                f" but d_ff is {f}"
            )
        if plan.hidden_padded():
            f = plan.padded_hidden_size()
            col = jnp.asarray(hidden_mask(plan))

    def masked(v, axis):
        if col is None:
            return v
        shape = [1] * v.ndim
        shape[axis] = f
        return v * col.reshape(shape).astype(v.dtype)

    ks = jax.random.split(key, 5)
    p = {"router": Param(normal_init(ks[0], (d, e), jnp.float32), L["router"])}
    if cfg.glu:
        p["w_gate"] = Param(
            masked(normal_init(ks[1], (e, d, f), dtype), 2), L["w_gate"])
        p["w_up"] = Param(
            masked(normal_init(ks[2], (e, d, f), dtype), 2), L["w_up"])
        p["w_down"] = Param(
            masked(normal_init(ks[3], (e, f, d), dtype), 1), L["w_down"])
    else:
        p["w1"] = Param(
            masked(normal_init(ks[1], (e, d, f), dtype), 2), L["w1"])
        p["b1"] = Param(masked(jnp.zeros((e, f), jnp.float32), 1), L["b1"])
        p["w2"] = Param(
            masked(normal_init(ks[2], (e, f, d), dtype), 1), L["w2"])
        p["b2"] = Param(jnp.zeros((e, d), jnp.float32), L["b2"])
    return p


def apply_moe_ffn(p: dict, x: jax.Array, ctx: Ctx,
                  gathered: Optional[dict] = None):
    """Returns (y, aux_loss, z_loss) — plus a trailing stats pytree when
    ``ctx.pcfg.collect_router_stats`` is set (passed through from
    parallel.moe_parallel.moe_layer unchanged). x: (B, S, D).

    ``gathered``: pregathered weight leaves from the pipeline-shared cache
    (parallel.cache); they replace the sharded ones and the island skips
    the matching in-island gathers. The reserved ``"__collectives__"`` key
    carries the gather level — "fsdp" (default) or "all" (the overlap
    schedule: fsdp AND the data-centric tp factor, DESIGN.md §10)."""
    m = ctx.cfg.moe
    ms = MoEStatic(
        num_experts=m.num_experts,
        top_k=m.top_k,
        act=ctx.cfg.act,
        glu=ctx.cfg.glu,
        norm_topk=m.norm_topk,
        softmax_after_topk=m.softmax_after_topk,
    )
    src = dict(p)
    pregathered: Any = False
    if gathered is not None:
        pregathered = gathered.get("__collectives__", "fsdp")
        src.update({k: v for k, v in gathered.items()
                    if v is not None and k != "__collectives__"})
    mp = MoEParams(
        router=src["router"],
        w_gate=src.get("w_gate"),
        w_up=src.get("w_up"),
        w_down=src.get("w_down"),
        w1=src.get("w1"),
        b1=src.get("b1"),
        w2=src.get("w2"),
        b2=src.get("b2"),
        w_gate_scale=src.get("w_gate_scale"),
        w_up_scale=src.get("w_up_scale"),
        w_down_scale=src.get("w_down_scale"),
        w1_scale=src.get("w1_scale"),
        w2_scale=src.get("w2_scale"),
    )
    return moe_layer(
        x, mp, ms, ctx.pcfg, ctx.mesh, x_spec=ctx.x_spec, noise_rng=ctx.rng,
        layer_idx=ctx.layer_idx, pregathered=pregathered,
    )


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": Param(normal_init(ks[0], (d, hq * hd), dtype), ("fsdp", "tp")),
        "wk": Param(normal_init(ks[1], (d, hkv * hd), dtype), ("fsdp", "tp")),
        "wv": Param(normal_init(ks[2], (d, hkv * hd), dtype), ("fsdp", "tp")),
        "wo": Param(normal_init(ks[3], (hq * hd, d), dtype), ("tp", "fsdp")),
    }
    if cfg.qk_norm:
        p["q_norm"] = Param(jnp.ones((hd,), jnp.float32), (None,))
        p["k_norm"] = Param(jnp.ones((hd,), jnp.float32), (None,))
    return p


def _head_rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def apply_attention(
    p: dict,
    x: jax.Array,
    ctx: Ctx,
    layer_idx: int,
    cache: Optional[dict],
):
    """Self-attention (train/prefill/decode). Returns (y, new_cache)."""
    cfg = ctx.cfg
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    local = cfg.attn_kind(layer_idx) == "local" and cfg.window > 0
    window = cfg.window if local else None

    # Attention sharding (perf iteration 1, EXPERIMENTS.md §Perf):
    #  * head-sharded path (heads divisible by TP): gather x ONCE before
    #    qkv (1 AG), compute with heads sharded, reduce-scatter after wo —
    #    replaces the baseline's per-tensor q/k/v gathers + all-reduce.
    #  * seq-sharded path (heads NOT divisible, e.g. phi3's 40, MQA's 8):
    #    queries stay sequence-sharded (one q chunk), K/V are gathered
    #    (small: kv heads only) — without this GSPMD silently REPLICATES
    #    attention over the model axis (26 TB/step for phi3).
    tp_size = 1
    if ctx.mesh is not None:
        tp_axis = ctx.pcfg.axes(ctx.mesh)["tp"]
        # Two-level meshes span TP over ("node", "model") (DESIGN.md §10).
        for a in ((tp_axis if isinstance(tp_axis, tuple) else (tp_axis,))
                  if tp_axis else ()):
            tp_size *= ctx.mesh.shape[a]
    heads_shardable = hq % tp_size == 0 and hkv % tp_size == 0
    seq_parallel_attn = ctx.mode != "decode" and not heads_shardable

    if ctx.mode != "decode" and heads_shardable:
        x = constrain(x, (("dp",), None, None), ctx.pcfg, ctx.mesh)

    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, hq, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, hkv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, hkv, hd)
    if ctx.mode != "decode":
        if heads_shardable:
            head_spec = (("dp",), None, "tp", None)
            q = constrain(q, head_spec, ctx.pcfg, ctx.mesh)
            k = constrain(k, head_spec, ctx.pcfg, ctx.mesh)
            v = constrain(v, head_spec, ctx.pcfg, ctx.mesh)
        else:
            q = constrain(q, (("dp",), "sp", None, None), ctx.pcfg, ctx.mesh)
    if cfg.qk_norm:
        q = _head_rms(q, p["q_norm"], cfg.norm_eps)
        k = _head_rms(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        q = attn_lib.rope(q, ctx.positions, cfg.rope_theta)
        k = attn_lib.rope(k, ctx.positions, cfg.rope_theta)

    new_cache = cache
    if ctx.mode == "prefill" and ctx.paged is not None:
        # Paged chunk-extension prefill (DESIGN.md §7): the chunk's s rows
        # are scattered into the slot's granted pages (invalid tail rows of
        # a short final chunk go to the sink), and the chunk attends
        # causally over the gathered logical view — prior chunks of the
        # same prompt plus the intra-chunk triangle. ctx.positions already
        # carries the absolute offsets (cache_len + arange), so RoPE and
        # the window mask line up with decode exactly. Prefix-sharing
        # admission reuses this path unchanged: cache_len starts at the
        # matched prefix length, so only the uncached suffix is written —
        # the shared (refcount>1) prefix pages are read through the table
        # but never scattered into. Speculative verification (DESIGN.md
        # §11) also reuses this path verbatim: row i attends over
        # positions <= cache_len + i, so its hidden state equals a
        # sequential decode having fed tokens[..i] — which is why the
        # score step can read per-position logits out of one chunk
        # forward, and why truncating `len` afterwards fully un-writes
        # rejected rows (every read past `len` is masked).
        from repro.kernels.paged_attention import NEG_INF
        from repro.quant.core import dequantize_rows, quantize_rows

        page = int(ctx.paged["page_size"])
        table = ctx.paged["table"]                 # (B, maxp)
        active = ctx.decode_active                 # (B, S) valid positions
        if active is None:
            active = jnp.ones((b, s), bool)
        pos_abs = ctx.cache_len[:, None] + jnp.arange(s)[None]   # (B, S)
        rows = jnp.broadcast_to(jnp.arange(b)[:, None], (b, s))
        phys = jnp.where(
            active, table[rows, (pos_abs // page).astype(jnp.int32)], 0
        ).astype(jnp.int32)
        off = (pos_abs % page).astype(jnp.int32)
        kv_q = "k_scale" in cache  # int8 paged-KV pool (DESIGN.md §8)
        k_rows = k.reshape(b * s, hkv, hd)
        v_rows = v.reshape(b * s, hkv, hd)
        idx = (phys.reshape(-1), off.reshape(-1))
        if kv_q:
            # Each written row quantizes with its own per-(row, head)
            # scale, so already-resident pages never re-scale.
            kq, ks = quantize_rows(k_rows)
            vq, vs = quantize_rows(v_rows)
            k_pool = cache["k"].at[idx].set(kq)
            v_pool = cache["v"].at[idx].set(vq)
            k_sc = cache["k_scale"].at[idx].set(ks)
            v_sc = cache["v_scale"].at[idx].set(vs)
            new_cache = {"k": k_pool, "v": v_pool,
                         "k_scale": k_sc, "v_scale": v_sc}
        else:
            k_pool = cache["k"].at[idx].set(k_rows.astype(cache["k"].dtype))
            v_pool = cache["v"].at[idx].set(v_rows.astype(cache["v"].dtype))
            new_cache = {"k": k_pool, "v": v_pool}

        maxp = table.shape[1]
        s_all = maxp * page
        if kv_q:
            kv_view = dequantize_rows(
                k_pool[table], k_sc[table], dtype=q.dtype
            ).reshape(b, s_all, hkv, hd)
            vv_view = dequantize_rows(
                v_pool[table], v_sc[table], dtype=q.dtype
            ).reshape(b, s_all, hkv, hd)
        else:
            kv_view = k_pool[table].reshape(b, s_all, hkv, hd)
            vv_view = v_pool[table].reshape(b, s_all, hkv, hd)
        g = hq // hkv
        qg = q.reshape(b, s, hkv, g, hd)
        logits = jnp.einsum(
            "bqhgd,bkhd->bqhgk", qg, kv_view,
            preferred_element_type=jnp.float32,
        ) * (hd ** -0.5)
        if cfg.logit_softcap:
            logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
        kpos = jnp.arange(s_all)[None, None]               # (1, 1, S_all)
        allowed = kpos <= pos_abs[:, :, None]              # causal, absolute
        if window is not None:
            allowed &= kpos > pos_abs[:, :, None] - window
        logits = jnp.where(allowed[:, :, None, None, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum(
            "bqhgk,bkhd->bqhgd", probs.astype(vv_view.dtype), vv_view,
            preferred_element_type=jnp.float32,
        ).reshape(b, s, hq, hd).astype(q.dtype)
    elif ctx.mode == "decode" and ctx.paged is not None:
        # Paged-KV decode (DESIGN.md §7): the new token's K/V row goes to
        # page ``table[slot, len // page]`` at offset ``len % page``;
        # inactive slots are redirected to the reserved sink page 0 and do
        # not advance. The read gathers K/V page-wise through the table
        # (kernels.paged_attention), window masked by absolute position —
        # paged storage never rolls, unlike the dense windowed buffer.
        # CoW invariant (prefix sharing): the scheduler guarantees the
        # write-target page has refcount 1 — decode must never write into
        # a refcount>1 page, so ``PagedServer._ensure_pages`` CoW-copies
        # (``PagePool.cow`` + ``make_page_copy_step``) BEFORE repointing
        # the table row this step reads. Shared prefix pages are therefore
        # read-only from this kernel's point of view.
        from repro.kernels.paged_attention import paged_attention
        from repro.quant.core import quantize_rows

        assert cache is not None and s == 1
        page = int(ctx.paged["page_size"])
        table = ctx.paged["table"]
        active = ctx.decode_active
        if active is None:
            active = jnp.ones((b,), bool)
        length = ctx.cache_len                     # (B,) before this token
        logical = (length // page).astype(jnp.int32)
        off = (length % page).astype(jnp.int32)
        phys = jnp.where(
            active, table[jnp.arange(b), logical], 0
        ).astype(jnp.int32)
        k_sc = v_sc = None
        if "k_scale" in cache:
            # int8 paged-KV (DESIGN.md §8): the new row quantizes with its
            # own per-(row, head) scale; the read dequantizes per gathered
            # page inside the paged-attention kernels.
            kq, ks = quantize_rows(k[:, 0])
            vq, vs = quantize_rows(v[:, 0])
            k_pool = cache["k"].at[phys, off].set(kq)
            v_pool = cache["v"].at[phys, off].set(vq)
            k_sc = cache["k_scale"].at[phys, off].set(ks)
            v_sc = cache["v_scale"].at[phys, off].set(vs)
            new_cache = {"k": k_pool, "v": v_pool,
                         "k_scale": k_sc, "v_scale": v_sc}
        else:
            k_pool = cache["k"].at[phys, off].set(
                k[:, 0].astype(cache["k"].dtype))
            v_pool = cache["v"].at[phys, off].set(
                v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": k_pool, "v": v_pool}
        lengths = length + active.astype(jnp.int32)
        out = paged_attention(
            q, k_pool, v_pool, table, lengths,
            k_scale=k_sc, v_scale=v_sc,
            window=window,
            softcap=cfg.logit_softcap,
            impl=ctx.pcfg.impl,
        )
    elif ctx.mode == "decode":
        assert cache is not None and s == 1
        s_cache = cache["k"].shape[1]
        slot = (ctx.cache_len % s_cache).astype(jnp.int32)  # rolling (window)
        adv = (jnp.ones((b,), jnp.int32) if ctx.decode_active is None
               else ctx.decode_active.astype(jnp.int32))

        def write(buf, new):
            if ctx.decode_active is not None:
                # Continuous batching: an inactive slot must not clobber its
                # rolling-buffer row (for a full window buffer, position
                # len % s_cache still holds the OLDEST readable token) —
                # write back the existing row instead.
                old = jax.vmap(
                    lambda bb, ss: jax.lax.dynamic_slice(
                        bb, (ss, 0, 0), (1,) + bb.shape[1:]
                    )
                )(buf, slot)
                new = jnp.where(
                    ctx.decode_active[:, None, None, None], new, old
                )
            return jax.vmap(
                lambda bb, nn, ss: jax.lax.dynamic_update_slice(
                    bb, nn, (ss, 0, 0)
                )
            )(buf, new, slot)

        k_cache = write(cache["k"], k.astype(cache["k"].dtype))
        v_cache = write(cache["v"], v.astype(cache["v"].dtype))
        new_cache = {"k": k_cache, "v": v_cache}
        valid = jnp.minimum(ctx.cache_len + adv, s_cache)
        out = attn_lib.decode_attention(
            q, k_cache, v_cache, valid, softcap=cfg.logit_softcap
        )
    else:
        k_attn, v_attn = k, v
        q_chunk = 2048
        if seq_parallel_attn:
            # gather (small) K/V over the seq axis; queries stay sharded;
            # a single full-length q chunk keeps the sharded dim unsliced.
            k_attn = constrain(k, (("dp",), None, None, None),
                               ctx.pcfg, ctx.mesh)
            v_attn = constrain(v, (("dp",), None, None, None),
                               ctx.pcfg, ctx.mesh)
            q_chunk = s
        out = attn_lib.chunked_attention(
            q, k_attn, v_attn,
            causal=True,
            window=window,
            prefix_len=cfg.prefix_len,
            softcap=cfg.logit_softcap,
            q_chunk=q_chunk,
        )
        if ctx.mode == "prefill" and cache is not None:
            s_cache = cache["k"].shape[1]
            if s_cache >= s:
                pad = [(0, 0), (0, s_cache - s), (0, 0), (0, 0)]
                new_cache = {
                    "k": jnp.pad(k, pad).astype(cache["k"].dtype),
                    "v": jnp.pad(v, pad).astype(cache["v"].dtype),
                }
            else:  # windowed layer: keep the tail, rotated so that absolute
                # position p lives at slot p % s_cache (decode writes there).
                new_cache = {
                    "k": jnp.roll(
                        k[:, s - s_cache:], s, axis=1
                    ).astype(cache["k"].dtype),
                    "v": jnp.roll(
                        v[:, s - s_cache:], s, axis=1
                    ).astype(cache["v"].dtype),
                }

    if ctx.mode != "decode" and heads_shardable:
        out = constrain(out, (("dp",), None, "tp", None), ctx.pcfg, ctx.mesh)
    y = out.reshape(b, s, hq * hd) @ p["wo"].astype(x.dtype)
    if ctx.mode != "decode":
        # reduce-scatter the TP partial sums straight back to seq-sharded
        y = constrain(y, (("dp",), "sp", None), ctx.pcfg, ctx.mesh)
    return y, new_cache


def cache_spec_attention(cfg: ModelConfig, layer_idx: int, batch: int,
                         seq_len: int, dtype) -> dict:
    """Abstract KV cache for one attention layer (window-bounded)."""
    local = cfg.attn_kind(layer_idx) == "local" and cfg.window > 0
    s_cache = min(seq_len, cfg.window) if local else seq_len
    shape = (batch, s_cache, cfg.num_kv_heads, cfg.hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


# ---------------------------------------------------------------------------
# cross attention (musicgen conditioning)
# ---------------------------------------------------------------------------

def init_cross_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, hq, hd = cfg.d_model, cfg.num_heads, cfg.hd
    dc = cfg.cross_d
    ks = jax.random.split(key, 4)
    return {
        "wq": Param(normal_init(ks[0], (d, hq * hd), dtype), ("fsdp", "tp")),
        "wk": Param(normal_init(ks[1], (dc, hq * hd), dtype), (None, "tp")),
        "wv": Param(normal_init(ks[2], (dc, hq * hd), dtype), (None, "tp")),
        "wo": Param(normal_init(ks[3], (hq * hd, d), dtype), ("tp", "fsdp")),
    }


def apply_cross_attention(p: dict, x: jax.Array, ctx: Ctx) -> jax.Array:
    cfg = ctx.cfg
    b, s, d = x.shape
    hq, hd = cfg.num_heads, cfg.hd
    cond = ctx.cond.astype(x.dtype)
    t = cond.shape[1]
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, hq, hd)
    k = (cond @ p["wk"].astype(x.dtype)).reshape(b, t, hq, hd)
    v = (cond @ p["wv"].astype(x.dtype)).reshape(b, t, hq, hd)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * hd ** -0.5
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    return out.reshape(b, s, hq * hd) @ p["wo"].astype(x.dtype)
