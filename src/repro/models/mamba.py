"""Mamba-1 selective-SSM layer (for the Jamba hybrid architecture).

Training/prefill uses a *chunked* parallel scan: within a chunk of length C
the recurrence h_t = a_t ⊙ h_{t-1} + b_t is evaluated with an associative
scan (log-depth, materialises (B, C, d_inner, d_state) transients only per
chunk); chunks are chained sequentially with a tiny carry. Decode is the
O(1)-per-step recurrent update.

TP mapping: everything between in_proj and out_proj is elementwise in
d_inner, so sharding d_inner over "model" (Megatron-style) keeps the SSM
entirely local — one psum at out_proj, inserted by GSPMD from the param
specs. This mirrors how the paper's model-centric TP splits the FFN hidden
dim (the SSM inner dim plays the same role).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import Param, normal_init


def _dims(cfg: ModelConfig):
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    dt_rank = m.dt_rank or -(-cfg.d_model // 16)
    return d_in, m.d_state, m.d_conv, dt_rank


def causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal 1D conv. x: (B,S,D); w: (K,D); b: (D,).

    Native grouped conv — never materialises the (B,S,K,D) stack.
    """
    k, d = w.shape
    out = jax.lax.conv_general_dilated(
        x,
        w[:, None, :].astype(x.dtype),        # (K, 1, D) WIO
        window_strides=(1,),
        padding=[(k - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=d,
    )
    return out.astype(jnp.float32) + b


def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_in, d_state, d_conv, dt_rank = _dims(cfg)
    ks = jax.random.split(key, 8)
    # S4D-real initialisation for A.
    a = jnp.broadcast_to(
        jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_in, d_state)
    )
    dt = jnp.exp(
        jax.random.uniform(ks[6], (d_in,), jnp.float32)
        * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    # inverse softplus so softplus(dt_bias) == dt at init
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": Param(normal_init(ks[0], (d, 2 * d_in), dtype), ("fsdp", "tp")),
        "conv_w": Param(normal_init(ks[1], (d_conv, d_in), dtype, 0.2), (None, "tp")),
        "conv_b": Param(jnp.zeros((d_in,), jnp.float32), ("tp",)),
        "x_proj": Param(
            normal_init(ks[2], (d_in, dt_rank + 2 * d_state), dtype), ("tp", None)
        ),
        "dt_proj": Param(normal_init(ks[3], (dt_rank, d_in), dtype), (None, "tp")),
        "dt_bias": Param(dt_bias, ("tp",)),
        "a_log": Param(jnp.log(a), ("tp", None)),
        "d_skip": Param(jnp.ones((d_in,), jnp.float32), ("tp",)),
        "out_proj": Param(normal_init(ks[4], (d_in, d), dtype), ("tp", "fsdp")),
    }


def _ssm_chunked(u, dt, b_in, c_in, a, chunk):
    """Selective scan. u: (B,S,Din) bf16; dt: (B,S,Din) f32;
    b_in/c_in: (B,S,Dst); a: (Din,Dst) f32.
    Returns y: (B, S, Din) f32 and final state (B, Din, Dst).

    Discretisation happens INSIDE the rematerialised chunk step: the
    (B, C, Din, Dst) transients never exist at full sequence length.
    """
    bsz, s, d_in = u.shape
    d_state = a.shape[1]
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk

    chunked = lambda t: jnp.moveaxis(
        t.reshape(bsz, nc, chunk, *t.shape[2:]), 1, 0
    )

    def combine(left, right):
        la, lb = left
        ra, rb = right
        return la + ra, jnp.exp(ra) * lb + rb

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_step(h, inp):
        u_c, dt_c, b_c, cc = inp   # (B,C,Din)x2, (B,C,Dst)x2
        dt_f = dt_c.astype(jnp.float32)
        log_a = dt_f[..., None] * a[None, None]               # (B,C,Din,Dst)
        bu = (dt_f * u_c.astype(jnp.float32))[..., None] * \
            b_c.astype(jnp.float32)[:, :, None, :]
        acc_a, acc_b = jax.lax.associative_scan(
            combine, (log_a, bu), axis=1
        )
        h_t = acc_b + jnp.exp(acc_a) * h[:, None]             # (B,C,Din,Dst)
        y = jnp.einsum("bcds,bcs->bcd", h_t, cc.astype(jnp.float32))
        return h_t[:, -1], y

    h0 = jnp.zeros((bsz, d_in, d_state), jnp.float32)
    hN, ys = jax.lax.scan(
        chunk_step, h0,
        (chunked(u), chunked(dt), chunked(b_in), chunked(c_in)),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, d_in)
    return y, hN


def apply_mamba(
    p: dict,
    x: jax.Array,
    ctx,
    cache: Optional[dict],
):
    """x: (B, S, D) -> (y, new_cache). Cache: {"conv": (B, K-1, Din),
    "ssm": (B, Din, Dst)} for decode."""
    from repro.parallel.sharding import constrain

    cfg, mode = ctx.cfg, ctx.mode
    bsz, s, _ = x.shape
    d_in, d_state, d_conv, dt_rank = _dims(cfg)
    xz = x @ p["in_proj"].astype(x.dtype)
    # Megatron-SP transition: the SSM time loop needs a LOCAL seq dim;
    # parallelism moves to the inner channel dim for the mixer body.
    # (A two-step seq-local-then-a2a variant was tried and REFUTED:
    # EXPERIMENTS.md §Perf jamba iteration 2a — GSPMD answered with more
    # all-reduce, not less.)
    xz = constrain(xz, (("dp",), None, "tp"), ctx.pcfg, ctx.mesh)
    xm, z = jnp.split(xz, 2, axis=-1)  # (B, S, Din) each

    new_cache = cache
    if mode == "decode":
        assert s == 1 and cache is not None
        conv_state = jnp.concatenate(
            [cache["conv"], xm.astype(cache["conv"].dtype)], axis=1
        )  # (B, K, Din)
        xm_c = jnp.einsum(
            "bkd,kd->bd", conv_state.astype(jnp.float32),
            p["conv_w"].astype(jnp.float32),
        ) + p["conv_b"]
        xm = jax.nn.silu(xm_c)[:, None].astype(x.dtype)
        new_conv = conv_state[:, 1:]
    else:
        xm_conv = causal_depthwise_conv(xm, p["conv_w"], p["conv_b"])
        new_conv = (
            jnp.pad(xm, [(0, 0), (d_conv - 1, 0), (0, 0)])[:, -(d_conv - 1):]
            if cache is not None else None
        )
        xm = jax.nn.silu(xm_conv).astype(x.dtype)

    proj = xm @ p["x_proj"].astype(x.dtype)
    proj = constrain(proj, (("dp",), None, None), ctx.pcfg, ctx.mesh)
    dt_lr, b_in, c_in = jnp.split(
        proj, [dt_rank, dt_rank + d_state], axis=-1
    )
    dt = jax.nn.softplus(
        dt_lr.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
        + p["dt_bias"]
    )  # (B, S, Din) f32
    dt = constrain(dt, (("dp",), None, "tp"), ctx.pcfg, ctx.mesh)
    a = -jnp.exp(p["a_log"])  # (Din, Dst)

    if mode == "decode":
        uf = xm.astype(jnp.float32)
        bf = b_in.astype(jnp.float32)
        cf = c_in.astype(jnp.float32)
        h = cache["ssm"]
        da = jnp.exp(dt[:, 0, :, None] * a[None])            # (B,Din,Dst)
        h = da * h + (dt[:, 0] * uf[:, 0])[..., None] * bf[:, 0][:, None, :]
        y = jnp.einsum("bds,bs->bd", h, cf[:, 0])[:, None]
        new_ssm = h
    else:
        y, hN = _ssm_chunked(xm, dt, b_in, c_in, a, cfg.mamba.chunk)
        new_ssm = hN if cache is not None else None

    y = y + p["d_skip"] * xm.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"].astype(x.dtype)
    # NOTE: constraining out to (dp, sp, -) here was tried and REFUTED
    # (EXPERIMENTS.md §Perf jamba iteration 2b: GSPMD turned it into MORE
    # all-reduce, +1.4s t_coll). The block-exit constraint in apply_block
    # handles the transition.

    if cache is not None:
        new_cache = {"conv": new_conv, "ssm": new_ssm}
    return out, new_cache


def cache_spec_mamba(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in, d_state, d_conv, _ = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, d_conv - 1, d_in), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, d_in, d_state), jnp.float32),
    }
