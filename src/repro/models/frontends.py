"""Modality frontends.

Per the assignment, [audio]/[vlm] entries specify the transformer BACKBONE
only — the modality frontend is a STUB: ``input_specs()`` provides
precomputed frame/patch embeddings. What remains real here is the learned
projection from the frontend embedding space into the backbone d_model
(which is part of the backbone checkpoint in both MusicGen and PaliGemma).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import Param, normal_init


def init_frontend(key, cfg: ModelConfig, dtype) -> dict:
    if cfg.frontend is None:
        return {}
    assert cfg.frontend_dim > 0, cfg.name
    return {
        "proj": Param(
            normal_init(key, (cfg.frontend_dim, cfg.d_model), dtype),
            (None, "fsdp"),
        )
    }


def project_frontend(p: dict, feats: jax.Array, dtype) -> jax.Array:
    """(B, S, frontend_dim) precomputed embeddings -> (B, S, D)."""
    return feats.astype(dtype) @ p["proj"].astype(dtype)
