"""LM assembly: parameter init, forward pass (train/prefill/decode),
KV/state cache management, and loss.

Layers are grouped into *periods* (the lcm of the layer/attention/MoE
patterns); parameters are stacked over periods and the forward runs a
``lax.scan`` over periods with the blocks of one period unrolled inside.
This keeps the HLO size O(period) regardless of depth — essential for the
72-layer Jamba dry-run — and is where the remat policy (the paper's
pipeline-shared cache, DESIGN.md §2) is applied.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import frontends, mamba, transformer as tfm, xlstm
from repro.obs import device as obs_device
from repro.models.transformer import Ctx
from repro.parallel.sharding import (
    ParallelConfig,
    Param,
    constrain,
    normal_init,
    split_tree,
)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _ffn_kind(cfg: ModelConfig, idx: int) -> Optional[str]:
    kind = cfg.layer_kind(idx)
    if kind == "slstm":
        return "slstm_ffn"
    if kind == "mlstm":
        return None
    if cfg.is_moe_layer(idx):
        return "moe"
    if cfg.d_ff > 0:
        return "dense"
    return None


def init_block(key, cfg: ModelConfig, idx: int, dtype, plan=None) -> dict:
    """One block's parameters; ``plan`` (core.hetero.HeteroPlan) pads MoE
    FFN hidden dims for heterogeneous TP tiles (DESIGN.md §6)."""
    kind = cfg.layer_kind(idx)
    ks = jax.random.split(key, 6)
    p: dict = {"ln1": tfm.init_norm(cfg)}
    if kind == "attn":
        p["mixer"] = tfm.init_attention(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mixer"] = mamba.init_mamba(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["mixer"] = xlstm.init_mlstm(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["mixer"] = xlstm.init_slstm(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if cfg.cross_attn and kind == "attn":
        p["ln_x"] = tfm.init_norm(cfg)
        p["xattn"] = tfm.init_cross_attention(ks[1], cfg, dtype)
    fk = _ffn_kind(cfg, idx)
    if fk is not None:
        p["ln2"] = tfm.init_norm(cfg)
        if fk == "moe":
            p["ffn"] = tfm.init_moe_ffn(ks[2], cfg, dtype, plan=plan)
        elif fk == "dense":
            p["ffn"] = tfm.init_dense_ffn(ks[2], cfg, dtype)
        else:  # slstm_ffn: small GLU
            f = int(cfg.xlstm.ffn_factor * cfg.d_model)
            f = (f + 63) // 64 * 64
            sub = dataclasses.replace(cfg, d_ff=f, glu=True)
            p["ffn"] = tfm.init_dense_ffn(ks[2], sub, dtype)
    return p


def init_params(key, cfg: ModelConfig, plan=None) -> dict:
    """Full parameter tree (Param leaves). eval_shape-safe.

    ``plan`` (core.hetero.HeteroPlan, DESIGN.md §6): Eq. 2 hidden splits pad
    every MoE FFN to per-TP-rank tiles; an even split changes nothing."""
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.num_layers + 4)
    period = cfg.period
    n_periods = cfg.num_layers // period

    layers = []
    for pos in range(period):
        per_period = [
            init_block(keys[pp * period + pos], cfg, pos, dtype, plan=plan)
            for pp in range(n_periods)
        ]
        stacked = jax.tree.map(
            lambda *xs: Param(
                jnp.stack([x.value for x in xs]),
                (None,) + xs[0].spec,
            ),
            *per_period,
            is_leaf=lambda x: isinstance(x, Param),
        )
        layers.append(stacked)

    p = {
        "embed": Param(
            normal_init(keys[-1], (cfg.vocab_size, cfg.d_model), dtype),
            ("tp", "fsdp"),
        ),
        "final_norm": tfm.init_norm(cfg),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        p["head"] = Param(
            normal_init(keys[-2], (cfg.d_model, cfg.vocab_size), dtype),
            ("fsdp", "tp"),
        )
    if cfg.num_codebooks > 1:
        p["cb_heads"] = Param(
            normal_init(
                keys[-2], (cfg.num_codebooks, cfg.d_model, cfg.vocab_size), dtype
            ),
            (None, "fsdp", "tp"),
        )
    if cfg.frontend:
        p["frontend"] = frontends.init_frontend(keys[-3], cfg, dtype)
    return p


def abstract_params(cfg: ModelConfig, plan=None) -> tuple[Any, Any]:
    """(ShapeDtypeStruct tree, logical spec tree) without allocating."""
    shapes = jax.eval_shape(
        lambda k: init_params(k, cfg, plan=plan), jax.random.PRNGKey(0)
    )
    # eval_shape maps over Param leaves; reconstruct specs from a concrete
    # tiny init of the STRUCTURE only: specs are static, rebuild via init on
    # the abstract tree (Param is a NamedTuple, eval_shape keeps it intact
    # with .spec as aux? no — spec is an array-free leaf). Simplest: call
    # init_params under eval_shape and read spec from the returned tree.
    values = jax.tree.map(
        lambda p: p.value, shapes, is_leaf=lambda x: isinstance(x, Param)
    )
    specs = jax.tree.map(
        lambda p: p.spec, shapes, is_leaf=lambda x: isinstance(x, Param)
    )
    return values, specs


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Abstract decode cache (stacked over periods per position)."""
    dtype = jnp.dtype(cfg.dtype)
    period = cfg.period
    n_periods = cfg.num_layers // period
    layers = []
    for pos in range(period):
        kind = cfg.layer_kind(pos)
        if kind == "attn":
            spec = tfm.cache_spec_attention(cfg, pos, batch, seq_len, dtype)
        elif kind == "mamba":
            spec = mamba.cache_spec_mamba(cfg, batch, dtype)
        elif kind == "mlstm":
            spec = xlstm.cache_spec_mlstm(cfg, batch, dtype)
        elif kind == "slstm":
            spec = xlstm.cache_spec_slstm(cfg, batch)
        layers.append(
            jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (n_periods,) + s.shape, s.dtype
                ),
                spec,
            )
        )
    return {
        "layers": layers,
        "len": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def cache_logical_specs(cfg: ModelConfig, cache: dict) -> dict:
    """Logical partition specs for the cache tree (batch -> dp; the cache
    sequence dim -> sp so long contexts shard; states shard inner dims)."""
    def leaf_spec(path_leaf):
        s = path_leaf.shape if hasattr(path_leaf, "shape") else None
        return s

    layers = []
    period = cfg.period
    for pos in range(period):
        kind = cfg.layer_kind(pos)
        if kind == "attn":
            spec = {"k": (None, "dp", "sp", None, None),
                    "v": (None, "dp", "sp", None, None)}
        elif kind == "mamba":
            spec = {"conv": (None, "dp", None, "tp"),
                    "ssm": (None, "dp", "tp", None)}
        elif kind == "mlstm":
            spec = {"c": (None, "dp", None, None, None),
                    "n": (None, "dp", None, None),
                    "m": (None, "dp", None),
                    "conv": (None, "dp", None, "tp")}
        else:  # slstm
            spec = {k: (None, "dp", None, None) for k in ("c", "n", "h", "m")}
        layers.append(spec)
    return {"layers": layers, "len": ("dp",)}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    spec = cache_spec(cfg, batch, seq_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


# ---------------------------------------------------------------------------
# paged serving cache (DESIGN.md §7)
# ---------------------------------------------------------------------------

def paged_cache_spec(
    cfg: ModelConfig,
    num_slots: int,
    num_pages: int,
    page_size: int,
    kv_quant: Optional[str] = None,
) -> dict:
    """Abstract paged decode cache for serving (DESIGN.md §7).

    Attention layers trade the dense per-slot ``(num_slots, max_seq)``
    rectangle for a SHARED pool of ``num_pages`` fixed-size pages per
    period position: ``(n_periods, num_pages, page_size, Hkv, hd)``.
    Which slot owns which page lives host-side in the scheduler's page
    table, passed to the decode step as an input each macro-step —
    physical page 0 is reserved as the write sink for inactive slots and
    is never allocated. Windowed layers store full positions too (the
    window is masked at read; a rolling buffer would break page identity).

    ``kv_quant="int8"`` (DESIGN.md §8): the pools hold int8 payloads plus
    float32 ``k_scale``/``v_scale`` pools of per-(row, kv-head) scales —
    ~(itemsize*hd)/(hd+4)x smaller pages, so the same HBM budget admits
    proportionally more pages (``paged_kv_page_bytes``/``PagePool``).

    Recurrent mixers (mamba/xlstm) keep their per-slot constant-size state
    exactly as in ``cache_spec`` — there is nothing to page.
    """
    if kv_quant not in (None, "none", "int8"):
        raise ValueError(f"unsupported kv_quant {kv_quant!r}")
    quant = kv_quant == "int8"
    dtype = jnp.int8 if quant else jnp.dtype(cfg.dtype)
    period = cfg.period
    n_periods = cfg.num_layers // period
    pool = jax.ShapeDtypeStruct(
        (n_periods, num_pages, page_size, cfg.num_kv_heads, cfg.hd), dtype
    )
    sc_pool = jax.ShapeDtypeStruct(
        (n_periods, num_pages, page_size, cfg.num_kv_heads), jnp.float32
    )
    layers = []
    for pos in range(period):
        kind = cfg.layer_kind(pos)
        if kind == "attn":
            entry = {"k": pool, "v": pool}
            if quant:
                entry["k_scale"] = sc_pool
                entry["v_scale"] = sc_pool
            layers.append(entry)
            continue
        if kind == "mamba":
            spec = mamba.cache_spec_mamba(cfg, num_slots, dtype)
        elif kind == "mlstm":
            spec = xlstm.cache_spec_mlstm(cfg, num_slots, dtype)
        else:  # slstm
            spec = xlstm.cache_spec_slstm(cfg, num_slots)
        layers.append(
            jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (n_periods,) + s.shape, s.dtype
                ),
                spec,
            )
        )
    return {
        "layers": layers,
        "len": jax.ShapeDtypeStruct((num_slots,), jnp.int32),
    }


def paged_cache_logical_specs(cfg: ModelConfig, cache: dict) -> dict:
    """Logical partition specs for the paged cache tree: the shared page
    pool shards its PAGE dim over "dp" so the pool's bytes spread across
    data ranks; per-slot recurrent state and lengths shard the slot dim.

    Note the allocator (``parallel.cache.PagePool``) treats physical pages
    as fungible — a hetero group's share is a COUNT, not a contiguous page
    range, so on a real mesh a slot's pages land on arbitrary ranks and
    the page-wise gather crosses devices. Rank-local (range-partitioned)
    allocation is the natural next step for multi-host serving."""
    layers = []
    for pos in range(cfg.period):
        kind = cfg.layer_kind(pos)
        if kind == "attn":
            spec = {"k": (None, "dp", None, None, None),
                    "v": (None, "dp", None, None, None)}
            if "k_scale" in cache["layers"][pos]:
                spec["k_scale"] = (None, "dp", None, None)
                spec["v_scale"] = (None, "dp", None, None)
        elif kind == "mamba":
            spec = {"conv": (None, "dp", None, "tp"),
                    "ssm": (None, "dp", "tp", None)}
        elif kind == "mlstm":
            spec = {"c": (None, "dp", None, None, None),
                    "n": (None, "dp", None, None),
                    "m": (None, "dp", None),
                    "conv": (None, "dp", None, "tp")}
        else:  # slstm
            spec = {k: (None, "dp", None, None) for k in ("c", "n", "h", "m")}
        layers.append(spec)
    return {"layers": layers, "len": ("dp",)}


def init_paged_cache(
    cfg: ModelConfig,
    num_slots: int,
    num_pages: int,
    page_size: int,
    kv_quant: Optional[str] = None,
) -> dict:
    spec = paged_cache_spec(cfg, num_slots, num_pages, page_size,
                            kv_quant=kv_quant)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def paged_kv_page_bytes(
    cfg: ModelConfig, page_size: int, kv_quant: Optional[str] = None
) -> int:
    """HBM bytes ONE physical page costs across every attention layer —
    the unit ``parallel.cache.PagePool`` budgets admission in.

    With ``kv_quant="int8"`` each K/V row stores hd int8 payload bytes
    plus one float32 per-(row, head) scale, so a page costs
    ``(hd + 4) / (hd * itemsize)`` of its full-precision size and the
    same HBM admits proportionally more concurrent requests
    (DESIGN.md §8)."""
    n_attn = sum(cfg.layer_kind(i) == "attn" for i in range(cfg.num_layers))
    row = cfg.num_kv_heads * cfg.hd * jnp.dtype(cfg.dtype).itemsize
    if kv_quant == "int8":
        row = cfg.num_kv_heads * (cfg.hd + 4)  # int8 payload + f32 scale
    return n_attn * 2 * page_size * row


def reset_slot(cfg: ModelConfig, cache: dict, slot: int,
               length: int = 0) -> dict:
    """Reset one slot's length and zero its recurrent state so a new
    request can reuse it (continuous-batching slot refill). K/V needs no
    scrub: the dense buffer and freshly-granted pages are both masked by
    ``len``.

    ``length > 0`` is the prefix-sharing admission path (DESIGN.md §7):
    the slot starts with ``length`` tokens already resident — whole pages
    matched by the radix index and forked into the slot's page table at
    refcount+1 — so the next chunk-prefill continues at absolute position
    ``length`` instead of re-prefilling the shared prefix. The shared
    pages themselves MUST NOT be scrubbed here: other slots and the index
    still read them. Only valid for all-attention stacks (recurrent state
    is per-slot and cannot be borrowed page-wise)."""
    if length and any(cfg.layer_kind(p) != "attn" for p in range(cfg.period)):
        raise ValueError(
            "prefix-sharing reset (length > 0) requires an all-attention "
            "stack: recurrent per-slot state has no paged representation")
    layers = []
    for pos in range(cfg.period):
        tree = cache["layers"][pos]
        if cfg.layer_kind(pos) == "attn":
            layers.append(tree)
        else:
            layers.append(jax.tree.map(
                lambda v: v.at[:, slot].set(jnp.zeros_like(v[:, slot])),
                tree,
            ))
    return {"layers": layers,
            "len": cache["len"].at[slot].set(jnp.int32(length))}


def rollback_slot(cfg: ModelConfig, cache: dict, slot: int,
                  length: int) -> dict:
    """Truncate one slot's resident length to ``length`` — the device half
    of speculative-decoding rollback (DESIGN.md §11). Rejected drafted
    rows need no scrub: paged attention masks every position at and past
    ``len``, so truncating the length (plus returning the now-unreferenced
    tail pages host-side, ``PagePool.rollback``) makes them unobservable,
    exactly like the masked tail of a fresh page. Only valid for
    all-attention stacks: recurrent mixers advance per-slot state
    token-wise, and that state cannot be rewound by truncation — callers
    must refuse speculation there (``launch.spec.SpecDecoder`` raises at
    construction)."""
    if any(cfg.layer_kind(p) != "attn" for p in range(cfg.period)):
        raise ValueError(
            "rollback (length truncation) requires an all-attention "
            "stack: recurrent per-slot state cannot be rewound")
    if length < 0:
        raise ValueError(f"negative rollback length {length}")
    return {"layers": cache["layers"],
            "len": cache["len"].at[slot].set(jnp.int32(length))}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def apply_block(p, x, ctx: Ctx, pos: int, cache, ffn_gathered=None):
    """One block: mixer + (optional) FFN. Returns
    (x, new_cache, aux_loss, z_loss, stats) — ``stats`` is the MoE
    layer's obs.device telemetry pytree when
    ``ctx.pcfg.collect_router_stats`` is set and this block holds an MoE
    FFN, else None (dense / telemetry disabled)."""
    kind = ctx.cfg.layer_kind(pos)
    h = tfm.apply_norm(p["ln1"], x, ctx.cfg)
    if kind == "attn":
        out, new_cache = tfm.apply_attention(p["mixer"], h, ctx, pos, cache)
    elif kind == "mamba":
        out, new_cache = mamba.apply_mamba(p["mixer"], h, ctx, cache)
    elif kind == "mlstm":
        out, new_cache = xlstm.apply_mlstm(p["mixer"], h, ctx, cache)
    else:
        out, new_cache = xlstm.apply_slstm(p["mixer"], h, ctx, cache)
    if (ctx.decode_active is not None and ctx.mode == "decode"
            and kind != "attn"
            and cache is not None and new_cache is not None):
        # Continuous-batching macro-step: inactive slots freeze their
        # recurrent state (attention handles itself via the sink page /
        # masked rolling-buffer write).
        act = ctx.decode_active
        new_cache = jax.tree.map(
            lambda n, o: jnp.where(
                act.reshape((-1,) + (1,) * (n.ndim - 1)), n, o
            ),
            new_cache, cache,
        )
    x = x + out
    if "xattn" in p:
        x = x + tfm.apply_cross_attention(
            p["xattn"], tfm.apply_norm(p["ln_x"], x, ctx.cfg), ctx
        )
    aux = jnp.zeros((), jnp.float32)
    z = jnp.zeros((), jnp.float32)
    stats = None
    if "ffn" in p:
        h2 = tfm.apply_norm(p["ln2"], x, ctx.cfg)
        if ctx.cfg.is_moe_layer(pos):
            out = tfm.apply_moe_ffn(
                p["ffn"], h2, dataclasses.replace(ctx, layer_idx=pos),
                gathered=ffn_gathered,
            )
            if ctx.pcfg.collect_router_stats:
                y, aux, z, stats = out
            else:
                y, aux, z = out
        else:
            y = tfm.apply_dense_ffn(p["ffn"], h2, ctx)
        x = x + y
    x = constrain(x, (("dp",), "sp", None), ctx.pcfg, ctx.mesh)
    return x, new_cache, aux, z, stats


def _remat_policy(pcfg: ParallelConfig):
    cp = jax.checkpoint_policies
    if pcfg.cache_policy == "janus":
        return cp.save_only_these_names("gathered_w")
    if pcfg.cache_policy == "dots":
        return cp.checkpoint_dots
    return cp.nothing_saveable


#: Residency/hit accounting of the last pipeline-shared cache built by
#: run_layers (trace-time stats; populated on the first trace of a jitted
#: forward). Benchmarks and tests read it after a call.
LAST_PIPELINE_CACHE_STATS: Optional[dict] = None


def run_layers(layers, x, ctx: Ctx, cache_layers):
    cfg, pcfg = ctx.cfg, ctx.pcfg
    period = cfg.period
    # Router telemetry (DESIGN.md §12): when enabled the scan carry grows
    # a stats pytree summed over every MoE layer. Gated statically so the
    # default path's carry structure — and compiled HLO — is unchanged.
    collect = pcfg.collect_router_stats and cfg.moe is not None

    def period_fn(carry, xs):
        if collect:
            x, aux, z, stats = carry
        else:
            x, aux, z = carry
            stats = None
        lp, lc, gf = xs
        new_caches = []
        for pos in range(period):
            c_in = None if lc is None else lc[pos]
            g = None if gf is None else gf.get(pos)
            x, nc, a, zz, st = apply_block(lp[pos], x, ctx, pos, c_in,
                                           ffn_gathered=g)
            new_caches.append(nc)
            aux = aux + a
            z = z + zz
            if collect and st is not None:
                stats = obs_device.add_stats(stats, st)
        return ((x, aux, z, stats) if collect else (x, aux, z)), new_caches

    if pcfg.remat != "none" and ctx.mode == "train":
        period_fn = jax.checkpoint(
            period_fn, policy=_remat_policy(pcfg), prevent_cse=False
        )

    zero = jnp.zeros((), jnp.float32)
    if pcfg.scan_layers:
        if pcfg.cache_layers > 0 and cfg.moe is not None:
            raise ValueError(
                "cache_layers > 0 requires scan_layers=False (the "
                "pipeline-shared prefetch cache lives in the unrolled "
                "layer loop)"
            )
        if collect:
            init = (x, zero, zero, obs_device.zero_stats(
                cfg.moe.num_experts))
            (x, aux, z, stats), new_cache = jax.lax.scan(
                period_fn, init, (layers, cache_layers, None)
            )
        else:
            (x, aux, z), new_cache = jax.lax.scan(
                period_fn, (x, zero, zero), (layers, cache_layers, None)
            )
            stats = None
    else:
        n_periods = cfg.num_layers // period
        moe_positions = [
            pos for pos in range(period)
            if cfg.is_moe_layer(pos) and _ffn_kind(cfg, pos) == "moe"
        ]
        # Pipeline-shared cache (DESIGN.md §2): gather each period's MoE fsdp
        # weight factors OUTSIDE the island, holding at most cache_layers
        # gathered periods and prefetching period pp+1 before period pp's
        # compute ops are emitted (the all-gather overlaps the MXU). One
        # cache entry = ONE period (all its MoE positions together), so the
        # residency bound counts what is actually live even when a period
        # holds several MoE layers.
        #
        # Inference-side mechanism only: under the remat'd training step the
        # gathered trees would become jax.checkpoint inputs and be SAVED as
        # residuals for every period — Janus residency with a cache sticker
        # on it. There the remat policy (cache_policy="shared_cache",
        # backward re-gathers per layer) is the paper's cache; skip the
        # prefetcher.
        remat_train = pcfg.remat != "none" and ctx.mode == "train"
        pcache = None
        if (pcfg.cache_layers > 0 and moe_positions and pcfg.mode != "ep"
                and not remat_train):
            from repro.parallel.cache import (
                PipelineSharedCache,
                gather_ffn_params,
            )
            pcache = PipelineSharedCache(pcfg.cache_layers)

            # Overlap schedule (DESIGN.md §10): with overlap_dispatch, the
            # prefetcher gathers the data-centric layers' FULL expert
            # weights (fsdp AND tp factor) one period ahead, so the next
            # layer's expert collectives — not just its fsdp gather —
            # overlap the current layer's compute. The per-position level
            # is resolved ONCE with the island's own chooser
            # (moe_parallel._auto_layer_mode), so prefetcher and island can
            # never disagree: an "all"-gathered layer is exactly a layer
            # the island would have run data-centric, and the gathered
            # values equal the in-island gather's — bit-identical schedule.
            levels = {pos: "fsdp" for pos in moe_positions}
            if pcfg.overlap_dispatch and pcfg.mode == "auto":
                import types

                from repro.parallel.moe_parallel import (
                    MoEStatic,
                    _auto_layer_mode,
                )

                def _sds(v):
                    return (None if v is None
                            else jax.ShapeDtypeStruct(v.shape[1:], v.dtype))

                tokens = x.shape[0] * x.shape[1]
                for pos in moe_positions:
                    ffn = layers[pos]["ffn"]
                    stub = types.SimpleNamespace(
                        w_gate=_sds(ffn.get("w_gate")),
                        w1=_sds(ffn.get("w1")),
                    )
                    ms = MoEStatic(
                        num_experts=cfg.moe.num_experts,
                        top_k=cfg.moe.top_k,
                    )
                    mode_pos = _auto_layer_mode(
                        stub, ms, pcfg, ctx.mesh, tokens, pos
                    )
                    if mode_pos == "data_centric":
                        levels[pos] = "all"

            def gather_period(pp):
                out = {}
                for pos in moe_positions:
                    g = gather_ffn_params(
                        jax.tree.map(lambda v: v[pp], layers[pos]["ffn"]),
                        pcfg, ctx.mesh, collectives=levels[pos],
                    )
                    if levels[pos] == "all":
                        g["__collectives__"] = "all"
                    out[pos] = g
                return out

        carry = ((x, zero, zero, obs_device.zero_stats(cfg.moe.num_experts))
                 if collect else (x, zero, zero))
        outs = []
        for pp in range(n_periods):
            lp = jax.tree.map(lambda v: v[pp], layers)
            lc = (
                None
                if cache_layers is None
                else jax.tree.map(lambda v: v[pp], cache_layers)
            )
            gf = None
            if pcache is not None:
                gf = pcache.fetch(pp, lambda: gather_period(pp))
                if pcache.capacity_layers >= 2 and pp + 1 < n_periods:
                    # double-buffer: issue pp+1's gathers before pp computes
                    pcache.prefetch(pp + 1, lambda: gather_period(pp + 1))
            carry, nc = period_fn(carry, (lp, lc, gf))
            outs.append(nc)
        if collect:
            x, aux, z, stats = carry
        else:
            x, aux, z = carry
            stats = None
        if pcache is not None:
            global LAST_PIPELINE_CACHE_STATS
            LAST_PIPELINE_CACHE_STATS = pcache.stats()
        new_cache = (
            None
            if cache_layers is None
            else jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        )
    return x, aux, z, new_cache, stats


def _embed_in(params, inputs, cfg: ModelConfig, dtype):
    emb = params["embed"]
    if cfg.frontend == "siglip" and "patches" in inputs:
        patches = frontends.project_frontend(
            params["frontend"], inputs["patches"], dtype
        )
        x_txt = emb[inputs["tokens"]].astype(dtype)
        x = jnp.concatenate([patches, x_txt], axis=1)
    elif cfg.frontend == "encodec":
        x = frontends.project_frontend(
            params["frontend"], inputs["embeds"], dtype
        )
    else:
        x = emb[inputs["tokens"]].astype(dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    return x


def _logits_out(params, x, cfg: ModelConfig):
    # bf16 operands, f32 accumulation (MXU-native mixed precision).
    if cfg.num_codebooks > 1:
        return jnp.einsum(
            "bsd,cdv->bscv", x, params["cb_heads"],
            preferred_element_type=jnp.float32,
        )
    if cfg.tie_embeddings:
        return jnp.einsum(
            "bsd,vd->bsv", x, params["embed"],
            preferred_element_type=jnp.float32,
        )
    return jnp.einsum(
        "bsd,dv->bsv", x, params["head"],
        preferred_element_type=jnp.float32,
    )


def score_logits(params, hidden, cfg: ModelConfig):
    """Project final-norm hidden states at EVERY position to vocabulary
    logits ``(B, S, V)`` — the multi-position output head of the
    speculative verify step (DESIGN.md §11). ``forward(...,
    return_hidden=True)`` deliberately stops before the head so the
    prefill path can project a single row; verification needs all ``S``
    drafted rows, which is exactly the per-position amortization the
    paged chunk forward already paid for. f32 accumulation, same einsum
    as the single-row head."""
    if cfg.num_codebooks > 1:
        raise ValueError("score_logits does not support codebook heads")
    return _logits_out(params, hidden, cfg)


def forward(
    params: dict,
    inputs: Dict[str, jax.Array],
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    mesh: Optional[Mesh],
    *,
    mode: str,
    cache: Optional[dict] = None,
    x_spec: P = P(None, None, None),
    rng: Optional[jax.Array] = None,
    return_hidden: bool = False,
    paged: Optional[dict] = None,
    active: Optional[jax.Array] = None,
):
    """Returns (logits, new_cache, aux_loss, z_loss). With
    ``return_hidden`` the first element is the final normed hidden states
    instead (callers compute chunked logits/loss themselves). When
    ``pcfg.collect_router_stats`` is set a fifth element is appended: the
    obs.device stats pytree summed over every MoE layer (per-expert token
    counts, capacity drops, entropy/token sums; DESIGN.md §12).

    ``paged`` (decode only, DESIGN.md §7): ``{"table": (B, maxp) int32,
    "page_size": int}`` switches the KV write/read to the shared page pool
    of ``init_paged_cache``.

    ``active`` (decode only): (B,) bool continuous-batching mask. Inactive
    slots write nothing (paged: redirected to the sink page; dense: the
    rolling-buffer row is restored), freeze their recurrent state, and do
    not advance their length — the shape-stable macro-step both serving
    drivers batch around.
    """
    dtype = jnp.dtype(cfg.dtype)
    x = _embed_in(params, inputs, cfg, dtype)
    b, s, _ = x.shape

    if mode == "decode":
        cache_len = cache["len"]
        positions = cache_len[:, None]
    elif mode == "prefill" and paged is not None:
        # chunk-extension prefill: this chunk continues from the tokens
        # already resident in the slot's pages
        cache_len = cache["len"]
        positions = cache_len[:, None] + jnp.arange(s)[None]
    else:
        cache_len = None
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if (paged is not None or active is not None) and mode not in (
            "decode", "prefill"):
        raise ValueError("paged cache / active mask are serving-side only")

    ctx = Ctx(
        cfg=cfg,
        pcfg=pcfg,
        mesh=mesh,
        mode=mode,
        positions=positions,
        cache_len=cache_len,
        x_spec=x_spec,
        rng=rng,
        cond=inputs.get("cond"),
        paged=paged,
        decode_active=active,
    )
    x = constrain(x, (("dp",), "sp", None), pcfg, mesh)
    cache_layers = None if cache is None else cache["layers"]
    x, aux, z, new_cache_layers, stats = run_layers(
        params["layers"], x, ctx, cache_layers
    )
    x = tfm.apply_norm(params["final_norm"], x, cfg)

    if return_hidden:
        logits = x
    elif mode == "prefill":
        logits = _logits_out(params, x[:, -1:], cfg)
    else:
        logits = _logits_out(params, x, cfg)

    new_cache = None
    if cache is not None:
        if mode == "decode" and active is not None:
            new_len = cache["len"] + active.astype(jnp.int32)
        elif mode == "decode":
            new_len = cache["len"] + s
        elif mode == "prefill" and paged is not None:
            adv = (jnp.full((b,), s, jnp.int32) if active is None
                   else active.astype(jnp.int32).sum(axis=1))
            new_len = cache["len"] + adv
        else:
            new_len = jnp.full((b,), s, jnp.int32)
        new_cache = {"layers": new_cache_layers, "len": new_len}
    n_moe = max(sum(cfg.is_moe_layer(i) for i in range(cfg.num_layers)), 1)
    if pcfg.collect_router_stats:
        if stats is None:
            stats = obs_device.zero_stats(
                cfg.moe.num_experts if cfg.moe is not None else 1)
        return logits, new_cache, aux / n_moe, z / n_moe, stats
    return logits, new_cache, aux / n_moe, z / n_moe
