"""Swin-Transformer-MoE — the paper's benchmark model (§5, Tutel setup).

Hierarchical windowed-attention vision transformer with the FFN of
alternating blocks in the last two stages replaced by an MoE FFN. The MoE
FFN uses the paper's 2-MLP expert form (GeLU between, with biases) — i.e.
exactly the formulation of Fig. 3 — through any of the execution paths:

  moe_impl="hexa"        expert-specific ops (the paper's method)
  moe_impl="tutel"       dispatch/combine with capacity factor (baseline)
  moe_impl="megablocks"  worst-case-capacity grouped dense GeMM (baseline)

Simplification vs. the reference Swin: shifted windows are implemented by
rolling without the cross-window attention mask (systems-benchmark fidelity:
identical FLOPs/memory/communication, slightly different masking semantics).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.core import baselines, espec
from repro.core.routing import route
from repro.parallel.moe_parallel import (
    MOE_PARAM_LOGICAL,
    MoEParams,
    MoEStatic,
    moe_layer,
)
from repro.parallel.sharding import ParallelConfig, Param, normal_init


@dataclasses.dataclass(frozen=True)
class SwinConfig:
    name: str
    family: str = "vision-moe"
    img_size: int = 224
    patch_size: int = 4
    in_chans: int = 3
    depths: Tuple[int, ...] = (2, 2, 18, 2)
    dims: Tuple[int, ...] = (96, 192, 384, 768)
    heads: Tuple[int, ...] = (3, 6, 12, 24)
    window: int = 7
    mlp_ratio: float = 4.0
    num_classes: int = 1000
    moe_stages: Tuple[int, ...] = (2, 3)
    moe: Optional[MoEConfig] = None
    norm_eps: float = 1e-5
    dtype: str = "float32"

    def is_moe_block(self, stage: int, blk: int) -> bool:
        return self.moe is not None and stage in self.moe_stages and blk % 2 == 1


SWIN_SMALL = dict(depths=(2, 2, 18, 2), dims=(96, 192, 384, 768),
                  heads=(3, 6, 12, 24))
SWIN_BASE = dict(depths=(2, 2, 18, 2), dims=(128, 256, 512, 1024),
                 heads=(4, 8, 16, 32))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_ln(d):
    return {
        "scale": Param(jnp.ones((d,), jnp.float32), (None,)),
        "bias": Param(jnp.zeros((d,), jnp.float32), (None,)),
    }


def _init_window_attn(key, dim, heads, window, dtype):
    ks = jax.random.split(key, 3)
    return {
        "qkv_w": Param(normal_init(ks[0], (dim, 3 * dim), dtype), ("fsdp", "tp")),
        "qkv_b": Param(jnp.zeros((3 * dim,), jnp.float32), ("tp",)),
        "proj_w": Param(normal_init(ks[1], (dim, dim), dtype), ("tp", "fsdp")),
        "proj_b": Param(jnp.zeros((dim,), jnp.float32), (None,)),
        "rel_bias": Param(
            normal_init(ks[2], ((2 * window - 1) ** 2, heads), jnp.float32),
            (None, None),
        ),
    }


def _init_mlp(key, dim, hidden, dtype):
    ks = jax.random.split(key, 2)
    return {
        "w1": Param(normal_init(ks[0], (dim, hidden), dtype), ("fsdp", "tp")),
        "b1": Param(jnp.zeros((hidden,), jnp.float32), ("tp",)),
        "w2": Param(normal_init(ks[1], (hidden, dim), dtype), ("tp", "fsdp")),
        "b2": Param(jnp.zeros((dim,), jnp.float32), (None,)),
    }


def _init_moe_mlp(key, dim, hidden, moe: MoEConfig, dtype):
    ks = jax.random.split(key, 3)
    e = moe.num_experts
    L = MOE_PARAM_LOGICAL
    return {
        "router": Param(normal_init(ks[0], (dim, e), jnp.float32), L["router"]),
        "w1": Param(normal_init(ks[1], (e, dim, hidden), dtype), L["w1"]),
        "b1": Param(jnp.zeros((e, hidden), jnp.float32), L["b1"]),
        "w2": Param(normal_init(ks[2], (e, hidden, dim), dtype), L["w2"]),
        "b2": Param(jnp.zeros((e, dim), jnp.float32), L["b2"]),
    }


def init_swin(key, cfg: SwinConfig):
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 128)
    ki = iter(range(128))
    p: dict = {
        "patch_w": Param(
            normal_init(
                keys[next(ki)],
                (cfg.patch_size, cfg.patch_size, cfg.in_chans, cfg.dims[0]),
                dtype,
            ),
            (None, None, None, None),
        ),
        "patch_b": Param(jnp.zeros((cfg.dims[0],), jnp.float32), (None,)),
        "patch_ln": _init_ln(cfg.dims[0]),
        "stages": [],
        "final_ln": _init_ln(cfg.dims[-1]),
        "head_w": Param(
            normal_init(keys[next(ki)], (cfg.dims[-1], cfg.num_classes), dtype),
            (None, None),
        ),
        "head_b": Param(jnp.zeros((cfg.num_classes,), jnp.float32), (None,)),
    }
    for s, depth in enumerate(cfg.depths):
        dim, heads = cfg.dims[s], cfg.heads[s]
        hidden = int(cfg.mlp_ratio * dim)
        blocks = []
        for b in range(depth):
            blk = {
                "ln1": _init_ln(dim),
                "attn": _init_window_attn(
                    keys[next(ki)], dim, heads, cfg.window, dtype
                ),
                "ln2": _init_ln(dim),
            }
            if cfg.is_moe_block(s, b):
                blk["moe"] = _init_moe_mlp(
                    keys[next(ki)], dim, hidden, cfg.moe, dtype
                )
            else:
                blk["mlp"] = _init_mlp(keys[next(ki)], dim, hidden, dtype)
            blocks.append(blk)
        stage = {"blocks": blocks}
        if s < len(cfg.depths) - 1:
            stage["merge_w"] = Param(
                normal_init(keys[next(ki)], (4 * dim, 2 * dim), dtype),
                ("fsdp", "tp"),
            )
            stage["merge_ln"] = _init_ln(4 * dim)
        p["stages"].append(stage)
    return p


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def _ln(p, x, eps):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(x.dtype)


def _rel_bias_index(window):
    coords = jnp.stack(
        jnp.meshgrid(jnp.arange(window), jnp.arange(window), indexing="ij"), -1
    ).reshape(-1, 2)
    rel = coords[:, None] - coords[None, :] + window - 1  # (w2, w2, 2)
    return rel[..., 0] * (2 * window - 1) + rel[..., 1]


def _window_attention(p, x, heads, window, eps):
    """x: (B, H, W, C) -> same, windowed MSA."""
    b, h, w, c = x.shape
    window = min(window, h, w)  # Swin clamps when window > feature map
    hd = c // heads
    nwh, nww = h // window, w // window
    xw = x.reshape(b, nwh, window, nww, window, c)
    xw = xw.transpose(0, 1, 3, 2, 4, 5).reshape(-1, window * window, c)

    qkv = xw @ p["qkv_w"].astype(xw.dtype) + p["qkv_b"].astype(xw.dtype)
    q, k, v = jnp.split(qkv.reshape(-1, window * window, 3, heads, hd), 3, 2)
    q, k, v = (t[:, :, 0] for t in (q, k, v))  # (nB, w2, heads, hd)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * hd ** -0.5
    bias = p["rel_bias"][_rel_bias_index(window)]  # (w2, w2, heads)
    logits = logits + bias.transpose(2, 0, 1)[None]
    attn = jax.nn.softmax(logits, -1).astype(xw.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", attn, v).reshape(-1, window * window, c)
    out = out @ p["proj_w"].astype(xw.dtype) + p["proj_b"].astype(xw.dtype)

    out = out.reshape(b, nwh, nww, window, window, c)
    return out.transpose(0, 1, 3, 2, 4, 5).reshape(b, h, w, c)


def _apply_moe_ffn(p, x_tokens, cfg: SwinConfig, pcfg, mesh, moe_impl, x_spec):
    """x_tokens: (B, L, C). Returns (y, aux, z)."""
    m = cfg.moe
    if moe_impl == "hexa":
        ms = MoEStatic(
            num_experts=m.num_experts, top_k=m.top_k, act="gelu", glu=False,
            norm_topk=m.norm_topk, softmax_after_topk=m.softmax_after_topk,
        )
        mp = MoEParams(router=p["router"], w1=p["w1"], b1=p["b1"],
                       w2=p["w2"], b2=p["b2"])
        if pcfg.collect_router_stats:
            # Router telemetry is an LM-stack feature; the vision tower
            # keeps the plain 3-tuple contract.
            pcfg = dataclasses.replace(pcfg, collect_router_stats=False)
        return moe_layer(x_tokens, mp, ms, pcfg, mesh, x_spec=x_spec)
    bsz, L, c = x_tokens.shape
    xf = x_tokens.reshape(bsz * L, c)
    r = route(xf, p["router"], m.top_k, norm_topk=m.norm_topk,
              softmax_after_topk=m.softmax_after_topk)
    if moe_impl == "tutel":
        y = baselines.dispatch_combine_moe(
            xf, r, p["w1"], p["b1"], p["w2"], p["b2"], act=jax.nn.gelu,
            capacity_factor=pcfg.capacity_factor,
        )
    elif moe_impl == "megablocks":
        y = baselines.grouped_dense_moe(
            xf, r, p["w1"], p["b1"], p["w2"], p["b2"], act=jax.nn.gelu,
        )
    else:
        raise ValueError(moe_impl)
    return y.reshape(bsz, L, c), r.aux_loss, r.z_loss


def swin_forward(
    params,
    images: jax.Array,
    cfg: SwinConfig,
    pcfg: ParallelConfig,
    mesh: Optional[Mesh] = None,
    *,
    moe_impl: str = "hexa",
):
    """images: (B, H, W, 3) -> (logits (B, classes), aux, z)."""
    dtype = jnp.dtype(cfg.dtype)
    x = jax.lax.conv_general_dilated(
        images.astype(dtype),
        params["patch_w"].astype(dtype),
        window_strides=(cfg.patch_size, cfg.patch_size),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + params["patch_b"].astype(dtype)
    x = _ln(params["patch_ln"], x, cfg.norm_eps)

    aux_total = jnp.zeros((), jnp.float32)
    z_total = jnp.zeros((), jnp.float32)
    n_moe = 0
    for s, stage in enumerate(params["stages"]):
        heads = cfg.heads[s]
        for bidx, blk in enumerate(stage["blocks"]):
            w_eff = min(cfg.window, x.shape[1], x.shape[2])
            shift = (w_eff // 2) if (bidx % 2 == 1 and w_eff < x.shape[1]) else 0
            h = _ln(blk["ln1"], x, cfg.norm_eps)
            if shift:
                h = jnp.roll(h, (-shift, -shift), axis=(1, 2))
            h = _window_attention(blk["attn"], h, heads, cfg.window, cfg.norm_eps)
            if shift:
                h = jnp.roll(h, (shift, shift), axis=(1, 2))
            x = x + h
            h = _ln(blk["ln2"], x, cfg.norm_eps)
            bb, hh, ww, cc = h.shape
            if "moe" in blk:
                y, aux, z = _apply_moe_ffn(
                    blk["moe"], h.reshape(bb, hh * ww, cc), cfg, pcfg, mesh,
                    moe_impl, P(("pod", "data") if mesh else None, None, None),
                )
                y = y.reshape(bb, hh, ww, cc)
                aux_total += aux
                z_total += z
                n_moe += 1
            else:
                m = blk["mlp"]
                y = jax.nn.gelu(
                    h @ m["w1"].astype(dtype) + m["b1"].astype(dtype)
                ) @ m["w2"].astype(dtype) + m["b2"].astype(dtype)
            x = x + y
        if "merge_w" in stage:
            bb, hh, ww, cc = x.shape
            x = x.reshape(bb, hh // 2, 2, ww // 2, 2, cc)
            x = x.transpose(0, 1, 3, 2, 4, 5).reshape(bb, hh // 2, ww // 2, 4 * cc)
            x = _ln(stage["merge_ln"], x, cfg.norm_eps)
            x = x @ stage["merge_w"].astype(dtype)

    x = _ln(params["final_ln"], x, cfg.norm_eps)
    pooled = x.mean(axis=(1, 2)).astype(jnp.float32)
    logits = pooled @ params["head_w"].astype(jnp.float32) + params["head_b"]
    denom = max(n_moe, 1)
    return logits, aux_total / denom, z_total / denom
