"""Quantization subsystem (DESIGN.md §8): block-wise int8/fp8 expert
weights fused into the ES kernels, int8 paged-KV payloads, and the STE
training path. ``quant.core`` is the single rounding/clipping convention
for the repo; ``optim.compression`` re-exports its int8 helpers."""
from repro.quant.core import (  # noqa: F401
    EXPERT_WEIGHT_KEYS,
    QUANT_FORMATS,
    dequant_tile,
    dequantize_blockwise,
    dequantize_int8,
    dequantize_rows,
    fake_quant,
    ffn_scales,
    quant_bits,
    quantize_blockwise,
    quantize_ffn,
    quantize_int8,
    quantize_lm_params,
    quantize_rows,
)
