"""Block-wise expert-weight and KV quantization (DESIGN.md §8).

One rounding/clipping convention for the whole repo:

  q = clip(round(x / scale), -Q, Q)        scale = amax(block) / Q

with symmetric ranges (int8: Q = 127; fp8-e4m3: Q = 448, the format's
finite max — the "round" is the cast's round-to-nearest). Scales are
float32, one per *block*:

  * expert weights — one scale per ``(expert, tile_row, tile_col)`` block of
    the trailing two dims (leading dims — period stacking, the expert dim —
    are batch). Blocks default to 128x128 (clamped to the dim), so a scale
    tile always nests inside the Pallas kernels' weight BlockSpecs and the
    in-VMEM dequant is a reshape-broadcast-multiply (DESIGN.md §8).
  * KV rows — one scale per written ``(token-row, kv-head)``: each decode
    step quantizes only the row it writes, so page contents never need
    re-scaling (``quantize_rows`` / ``dequantize_rows``).

Training uses the straight-through estimator: ``fake_quant`` runs the real
quantize→dequantize in forward and passes gradients through unchanged
(``custom_vjp`` identity), so routers/dense layers — which are never
quantized — and the expert master weights all keep full-precision grads.
``quantize_blockwise(..., rng=...)`` optionally applies stochastic rounding
(floor(x/scale + u), u ~ U[0,1)) so QAT rounding is unbiased in expectation.

The gradient-compression helpers ``quantize_int8``/``dequantize_int8``
(single shared scale, the ``optim.compression`` error-feedback path) live
here too and are re-exported by ``optim.compression`` — one convention,
one module.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

#: Supported quantized-weight formats -> (storage dtype, symmetric max).
QUANT_FORMATS = {
    "int8": (jnp.int8, 127.0),
    "fp8": (jnp.float8_e4m3fn, 448.0),
}

#: Expert-weight keys the param walkers quantize (routers/norms/biases
#: always stay full precision).
EXPERT_WEIGHT_KEYS = ("w_gate", "w_up", "w_down", "w1", "w2")


def quant_bits(mode: Optional[str]) -> int:
    """Storage bits per weight element for a quant mode (16 for none —
    the bf16 baseline the autotune byte model prices against)."""
    if mode in (None, "none"):
        return 16
    if mode not in QUANT_FORMATS:
        raise ValueError(f"unknown quant mode {mode!r}")
    return 8


# ---------------------------------------------------------------------------
# gradient-compression convention (moved from optim.compression)
# ---------------------------------------------------------------------------

def quantize_int8(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Symmetric int8 with a caller-supplied (shared) scale — the
    collective-safe form ``optim.compression.compressed_psum`` needs (the
    scale is agreed across the group before payloads move)."""
    q = jnp.round(x.astype(jnp.float32) / jnp.maximum(scale, 1e-30))
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of ``quantize_int8`` (float32 out)."""
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# block-wise weight quantization
# ---------------------------------------------------------------------------

def block_tiles(shape: Sequence[int], tile: int) -> tuple[int, int]:
    """Per-axis tile sizes over the trailing two dims: ``tile`` clamped to
    the dim (a dim smaller than the tile is one block). Dims larger than
    the tile must divide evenly — weight shapes here are MXU-aligned."""
    a, b = int(shape[-2]), int(shape[-1])
    ta, tb = min(tile, a), min(tile, b)
    if a % ta or b % tb:
        raise ValueError(f"dims {(a, b)} not divisible by tiles {(ta, tb)}")
    return ta, tb


def _upsample(scales: jax.Array, shape: Sequence[int]) -> jax.Array:
    """Broadcast per-block scales up to the full weight shape."""
    *batch, a, b = shape
    na, nb = scales.shape[-2:]
    s = scales.reshape(*scales.shape[:-2], na, 1, nb, 1)
    s = jnp.broadcast_to(
        s, tuple(scales.shape[:-2]) + (na, a // na, nb, b // nb)
    )
    return s.reshape(tuple(shape))


def quantize_blockwise(
    w: jax.Array,
    *,
    mode: str = "int8",
    tile: int = 128,
    rng: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Quantize ``w`` block-wise over its trailing two dims.

    Returns ``(q, scales)`` with ``q`` int8/fp8-e4m3 shaped like ``w`` and
    ``scales`` float32 shaped ``(*batch, A/tile_a, B/tile_b)``. ``rng``
    enables stochastic rounding (int8 only): ``floor(x/scale + u)`` with
    ``u ~ U[0,1)``, unbiased in expectation — the training-side option.
    """
    if mode not in QUANT_FORMATS:
        raise ValueError(f"unknown quant mode {mode!r}")
    dtype, qmax = QUANT_FORMATS[mode]
    ta, tb = block_tiles(w.shape, tile)
    *batch, a, b = w.shape
    wf = w.astype(jnp.float32)
    blocks = wf.reshape(*batch, a // ta, ta, b // tb, tb)
    amax = jnp.max(jnp.abs(blocks), axis=(-3, -1))
    scales = (jnp.maximum(amax, 1e-30) / qmax).astype(jnp.float32)
    x = wf / _upsample(scales, w.shape)
    if mode == "int8":
        if rng is not None:
            x = jnp.floor(x + jax.random.uniform(rng, x.shape))
        else:
            x = jnp.round(x)
        q = jnp.clip(x, -qmax, qmax).astype(dtype)
    else:
        if rng is not None:
            raise ValueError("stochastic rounding is int8-only")
        q = jnp.clip(x, -qmax, qmax).astype(dtype)
    return q, scales


def dequantize_blockwise(
    q: jax.Array, scales: jax.Array, dtype: Any = jnp.float32
) -> jax.Array:
    """Inverse of ``quantize_blockwise``; tile sizes are inferred from the
    q/scales shapes. Exact for values representable on the block's grid."""
    return (q.astype(jnp.float32) * _upsample(scales, q.shape)).astype(dtype)


def scale_block_dims(wdims, sdims, bdims) -> tuple:
    """Block dims of a scale operand congruent with its weight BlockSpec.

    For each trailing weight axis (full extent ``wdims``, ``sdims`` scale
    blocks, kernel block ``bdims``) the per-axis quant tile
    ``wdim // sdim`` must divide the kernel block; the scale tile then
    covers ``bdim // tile`` blocks. Shared by the esmm/esffn kernels so
    the scale-layout contract has one implementation (DESIGN.md §8)."""
    out = []
    for d, s, b in zip(wdims, sdims, bdims):
        t = d // s
        if b % t:
            raise ValueError(
                f"quant tile {t} does not divide kernel block {b} "
                f"(dim {d}, {s} scale blocks)"
            )
        out.append(b // t)
    return tuple(out)


def dequant_tile(w: jax.Array, s: jax.Array) -> jax.Array:
    """In-kernel VMEM dequant of one 2-D weight tile (DESIGN.md §8).

    ``w``: (A, B) int8/fp8 tile as loaded by the kernel's BlockSpec; ``s``:
    the congruent (na, nb) scale tile — each scale covers an
    (A/na, B/nb) sub-block. Returns float32 (A, B), fed straight to the
    MXU contraction; the quantized bytes are all that crossed HBM.
    """
    a, b = w.shape
    na, nb = s.shape
    wf = w.astype(jnp.float32).reshape(na, a // na, nb, b // nb)
    return (wf * s.astype(jnp.float32)[:, None, :, None]).reshape(a, b)


# ---------------------------------------------------------------------------
# straight-through estimator (training / QAT)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _fake_quant(w, mode, tile):
    q, s = quantize_blockwise(w, mode=mode, tile=tile)
    return dequantize_blockwise(q, s, dtype=w.dtype)


def _fake_quant_fwd(w, mode, tile):
    return _fake_quant(w, mode, tile), None


def _fake_quant_bwd(mode, tile, _, g):
    return (g,)  # straight-through: d(dequant∘quant)/dw := identity


_fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


def fake_quant(w: jax.Array, mode: str = "int8", tile: int = 128) -> jax.Array:
    """Quantize-dequantize with straight-through gradients (DESIGN.md §8).

    Forward runs the real block-wise round-trip (numerics match the
    deployed int8/fp8 weights); backward passes the cotangent through
    unchanged, so the full-precision master weights keep training while
    the loss sees quantized arithmetic. Routers and dense layers are
    simply never passed through this — their grads are untouched."""
    return _fake_quant(w, mode, tile)


# ---------------------------------------------------------------------------
# KV-row quantization (paged cache payloads, DESIGN.md §8)
# ---------------------------------------------------------------------------

def quantize_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 over the trailing (head_dim) axis.

    ``x``: (..., hd) K or V rows about to be written to the paged pool.
    Returns (int8 rows, float32 scales shaped (...,)) — one scale per
    written (token-row, kv-head), so a decode step quantizes only its own
    row and already-resident pages never re-scale."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = (jnp.maximum(amax, 1e-30) / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_rows(
    q: jax.Array, scale: jax.Array, dtype: Any = jnp.float32
) -> jax.Array:
    """Inverse of ``quantize_rows`` (scale broadcasts over the row)."""
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


# ---------------------------------------------------------------------------
# parameter-tree walkers
# ---------------------------------------------------------------------------

def quantize_ffn(ffn: dict, *, mode: str = "int8", tile: int = 128) -> dict:
    """Quantize one MoE FFN param dict's expert weights in place-style.

    Each ``EXPERT_WEIGHT_KEYS`` leaf becomes its int8/fp8 payload plus a
    ``<name>_scale`` float32 entry; router and biases pass through. Leading
    dims (period stacking, the expert dim) are batch — scales are
    per-(expert, tile)."""
    out = dict(ffn)
    for name in EXPERT_WEIGHT_KEYS:
        w = ffn.get(name)
        if w is None or f"{name}_scale" in ffn:
            continue
        q, s = quantize_blockwise(w, mode=mode, tile=tile)
        out[name] = q
        out[f"{name}_scale"] = s
    return out


def ffn_scales(ffn: dict) -> Optional[dict]:
    """The ``<name>_scale`` entries of a (possibly) quantized FFN dict, or
    None when the dict holds plain full-precision weights."""
    s = {k: v for k, v in ffn.items() if k.endswith("_scale")}
    return s or None


def quantize_lm_params(
    params: dict, cfg, *, mode: str = "int8", tile: int = 128
) -> dict:
    """Quantize every MoE layer's expert weights in a full LM value tree
    (post-``split_tree``). Dense FFNs, attention, norms, embeddings and
    routers stay full precision — this is the serving-side true-quant
    entry (``launch/serve.py --quant``); training QAT goes through
    ``fake_quant`` inside the island instead."""
    from repro.models.lm import _ffn_kind  # lazy: avoid kernels<->models cycle

    out = dict(params)
    layers = []
    for pos, layer in enumerate(params["layers"]):
        if _ffn_kind(cfg, pos) == "moe" and "ffn" in layer:
            layer = dict(layer)
            layer["ffn"] = quantize_ffn(layer["ffn"], mode=mode, tile=tile)
        layers.append(layer)
    out["layers"] = layers
    return out
