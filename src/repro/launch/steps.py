"""Step builders: train_step (loss+grads+optimizer), prefill_step,
serve_step — the functions the dry-run lowers and the drivers execute.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.inputs import activation_spec
from repro.models import lm
from repro.obs import device as obs_device
from repro.optim import adamw
from repro.parallel.sharding import (
    ParallelConfig,
    divisible_spec,
    resolve_spec,
    tree_shardings,
)


def _fwd5(out):
    """Normalise ``lm.forward``'s flag-dependent arity to a 5-tuple
    ``(x, cache, aux, z, stats)`` — ``stats`` is None when router
    telemetry (``pcfg.collect_router_stats``) is off."""
    return out if len(out) == 5 else (*out, None)


def xent_loss(logits, labels, mask):
    """Vocab-parallel-safe cross entropy. logits (B,S,[C,]V) f32."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    per_tok = lse - ll
    if per_tok.ndim == 3:  # (B, S, num_codebooks)
        per_tok = per_tok.mean(-1)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_tok * mask) / denom


def chunked_xent(x, params, cfg: ModelConfig, labels, mask,
                 n_chunks: int = 16, pcfg=None, mesh=None):
    """Cross entropy over sequence chunks: the (B, S_c, V) logits block is
    materialised (vocab-sharded) one chunk at a time and rematerialised in
    backward — peak memory drops by n_chunks vs. full-sequence logits,
    which otherwise dominate activation memory for 150k-260k vocabularies.
    x: (B, S, D) final hidden states."""
    from repro.parallel.sharding import constrain

    b, s, _ = x.shape
    while s % n_chunks:
        n_chunks //= 2
    cs = s // n_chunks

    # Gather the (small, bf16) hidden states over the seq-parallel axis so
    # chunk slicing is local and every rank computes every chunk with its
    # vocab shard (balanced vocab-parallel loss).
    if pcfg is not None and mesh is not None:
        x = constrain(x, (("dp",), None, None), pcfg, mesh)

    # Localise the D contraction: the embedding's fsdp (D) shard would
    # otherwise make every logits chunk a full (B,S_c,V) all-reduce over
    # "data". Gathering the table's D once (a few 10s of MB) instead keeps
    # logits purely vocab-sharded.
    if pcfg is not None and mesh is not None:
        params = dict(params)
        if cfg.num_codebooks > 1 and "cb_heads" in params:
            params["cb_heads"] = constrain(
                params["cb_heads"], (None, None, "tp"), pcfg, mesh)
        elif cfg.tie_embeddings:
            params["embed"] = constrain(
                params["embed"], ("tp", None), pcfg, mesh)
        elif "head" in params:
            params["head"] = constrain(
                params["head"], (None, "tp"), pcfg, mesh)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_loss(x_c, lbl_c, m_c):
        logits = lm._logits_out(params, x_c, cfg)
        lg = logits.astype(jnp.float32)
        mx = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(lg - mx), axis=-1)) + mx[..., 0]
        ll = jnp.take_along_axis(lg, lbl_c[..., None], axis=-1)[..., 0]
        per_tok = lse - ll
        if per_tok.ndim == 3:
            per_tok = per_tok.mean(-1)
        return jnp.sum(per_tok * m_c)

    total = jnp.zeros((), jnp.float32)
    for c in range(n_chunks):
        sl = slice(c * cs, (c + 1) * cs)
        total = total + chunk_loss(x[:, sl], labels[:, sl], mask[:, sl])
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def make_loss_fn(cfg: ModelConfig, pcfg: ParallelConfig, mesh: Optional[Mesh],
                 batch_shape3):
    """Build the training loss closure: LM forward (hidden-state output) +
    chunked cross-entropy + MoE aux/z losses, weighted per ``cfg.moe``."""
    x_spec = activation_spec(batch_shape3, pcfg, mesh)
    aw = cfg.moe.aux_weight if cfg.moe else 0.0
    zw = cfg.moe.z_weight if cfg.moe else 0.0

    def loss_fn(params, batch):
        hidden, _, aux, z, stats = _fwd5(lm.forward(
            params, batch, cfg, pcfg, mesh, mode="train", x_spec=x_spec,
            return_hidden=True,
        ))
        labels = batch["labels"]
        mask = batch["loss_mask"]
        if cfg.frontend == "siglip":
            # no loss on the image prefix
            n_img = hidden.shape[1] - (labels.shape[1])
            if n_img > 0:
                hidden = hidden[:, n_img:]
        loss = chunked_xent(hidden, params, cfg, labels, mask,
                            pcfg=pcfg, mesh=mesh)
        total = loss + aw * aux + zw * z
        metrics = {"loss": loss, "aux_loss": aux, "z_loss": z}
        if stats is not None:
            # Device telemetry rides the has_aux channel (not
            # differentiated); train drivers pop this non-scalar entry
            # before float()-ing the metrics dict.
            metrics["router_stats"] = stats
        return total, metrics

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    mesh: Optional[Mesh],
    opt_cfg: adamw.OptimizerConfig,
    batch_shape3,
):
    """Build the jittable train step: value_and_grad of ``make_loss_fn``
    followed by the AdamW update, returning (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(cfg, pcfg, mesh, batch_shape3)

    def train_step(params, opt_state, batch):
        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state, om = adamw.apply_updates(
            params, grads, opt_state, opt_cfg
        )
        return params, opt_state, {**metrics, **om, "total_loss": total}

    return train_step


def make_prefill_step(cfg: ModelConfig, pcfg: ParallelConfig,
                      mesh: Optional[Mesh], batch_shape3):
    """Build the dense-cache prefill step: one forward over the whole
    prompt batch, writing K/V rows into the ``(slots, max_seq)`` cache."""
    x_spec = activation_spec(batch_shape3, pcfg, mesh)

    def prefill_step(params, inputs, cache):
        logits, new_cache, _, _, stats = _fwd5(lm.forward(
            params, inputs, cfg, pcfg, mesh, mode="prefill",
            cache=cache, x_spec=x_spec,
        ))
        if pcfg.collect_router_stats:
            return logits, new_cache, stats
        return logits, new_cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, pcfg: ParallelConfig,
                    mesh: Optional[Mesh], batch_shape3):
    """Build the dense-cache decode macro-step: one token per occupied
    slot (``active`` masks the rest), returning last-position logits.
    With ``pcfg.collect_router_stats`` the return grows a third element,
    the obs.device stats pytree (DESIGN.md §12)."""
    # decode tokens are replicated over TP (S=1 can't shard).
    x_spec = activation_spec(batch_shape3, pcfg, mesh)

    def serve_step(params, inputs, cache):
        logits, new_cache, _, _, stats = _fwd5(lm.forward(
            params, inputs, cfg, pcfg, mesh, mode="decode",
            cache=cache, x_spec=x_spec,
            active=inputs.get("active"),
        ))
        if pcfg.collect_router_stats:
            return logits, new_cache, stats
        return logits, new_cache

    return serve_step


def make_paged_serve_step(cfg: ModelConfig, pcfg: ParallelConfig,
                          mesh: Optional[Mesh], batch_shape3,
                          page_size: int):
    """Continuous-batching decode macro-step over the paged KV cache
    (DESIGN.md §7). ``inputs`` carries the scheduler's per-step view:
    tokens (B, 1), page_table (B, maxp) int32, active (B,) bool. With
    ``pcfg.collect_router_stats`` the return grows a third element, the
    obs.device stats pytree (DESIGN.md §12)."""
    x_spec = activation_spec(batch_shape3, pcfg, mesh)

    def serve_step(params, inputs, cache):
        logits, new_cache, _, _, stats = _fwd5(lm.forward(
            params, {"tokens": inputs["tokens"]}, cfg, pcfg, mesh,
            mode="decode", cache=cache, x_spec=x_spec,
            paged={"table": inputs["page_table"], "page_size": page_size},
            active=inputs["active"],
        ))
        if pcfg.collect_router_stats:
            return logits, new_cache, stats
        return logits, new_cache

    return serve_step


def make_paged_prefill_step(cfg: ModelConfig, pcfg: ParallelConfig,
                            mesh: Optional[Mesh], page_size: int):
    """Chunked prefill into the paged cache (DESIGN.md §7): one request's
    next ``chunk`` prompt tokens advance between decode macro-steps.
    Returns the logits at the last valid token — after the final chunk
    these are the request's first-generated-token logits, exactly what a
    batch-1 dense prefill would have produced.

    Two implementations behind one signature
    ``(params, tokens (chunk,), n_valid (), slot (), table_row (maxp,),
    cache) -> (last_logits (V,), cache)``; short final chunks pad and mask:

      * all-attention stacks: ONE batch-1 forward over the whole chunk
        (``mode="prefill"`` + ``paged`` — the chunk-extension attention in
        ``models.transformer``), the production chunked-prefill shape;
      * stacks with recurrent mixers (mamba/xlstm): a ``lax.scan`` of
        single-token decode forwards — those states only advance
        token-wise mid-stream, so the chunk is a scheduling unit, not a
        compute one.
    """
    if all(cfg.layer_kind(p) == "attn" for p in range(cfg.period)):
        return _make_paged_prefill_chunk(cfg, pcfg, mesh, page_size)
    return _make_paged_prefill_scan(cfg, pcfg, mesh, page_size)


def _paged_chunk_forward(cfg: ModelConfig, pcfg: ParallelConfig,
                         mesh: Optional[Mesh], page_size: int):
    """Shared body of the chunk-extension paged forward: one batch-1
    ``mode="prefill"`` forward over ``chunk`` tokens continuing at the
    slot's resident length, against the shared page pools through
    ``table_row``. Returns the final-norm hidden states at EVERY chunk
    position, the cache with the slot's length advanced by ``n_valid``,
    and the obs.device stats pytree (None when telemetry is off) — the
    prefill step projects only the last valid row to logits, the
    speculative score step projects them all (DESIGN.md §11).
    All-attention stacks only: recurrent mixers advance per-slot state
    token-wise and take the scan path instead."""
    if any(cfg.layer_kind(p) != "attn" for p in range(cfg.period)):
        raise ValueError(
            "chunk-extension paged forward requires an all-attention "
            "stack (recurrent mixers advance token-wise)")
    x_spec = activation_spec((1, 1, cfg.d_model), pcfg, mesh)

    def fwd(params, tokens, n_valid, slot, table_row, cache):
        chunk = tokens.shape[0]
        # every layer is attention, so the whole layer cache is the shared
        # (batch-free) page pools — only the length is per-slot
        sub = {
            "layers": cache["layers"],
            "len": jax.lax.dynamic_slice(cache["len"], (slot,), (1,)),
        }
        active = (jnp.arange(chunk) < n_valid)[None]       # (1, chunk)
        hidden, sub, _, _, stats = _fwd5(lm.forward(
            params, {"tokens": tokens[None]}, cfg, pcfg, mesh,
            mode="prefill", cache=sub, x_spec=x_spec,
            paged={"table": table_row[None], "page_size": page_size},
            active=active, return_hidden=True,
        ))
        new_len = jax.lax.dynamic_update_slice(
            cache["len"], sub["len"], (slot,))
        return hidden, {"layers": sub["layers"], "len": new_len}, stats

    return fwd


def _make_paged_prefill_chunk(cfg: ModelConfig, pcfg: ParallelConfig,
                              mesh: Optional[Mesh], page_size: int):
    fwd = _paged_chunk_forward(cfg, pcfg, mesh, page_size)

    def prefill_step(params, tokens, n_valid, slot, table_row, cache):
        hidden, new_cache, stats = fwd(params, tokens, n_valid, slot,
                                       table_row, cache)
        # last valid row only: prefill wants the first-generated-token
        # logits, and projecting one row keeps the vocab matmul off the
        # chunk's other positions
        last_h = jax.lax.dynamic_slice_in_dim(hidden, n_valid - 1, 1, axis=1)
        logits = lm._logits_out(params, last_h, cfg)
        out = logits.reshape(-1).astype(jnp.float32)
        if pcfg.collect_router_stats:
            return out, new_cache, stats
        return out, new_cache

    return prefill_step


def make_paged_score_step(cfg: ModelConfig, pcfg: ParallelConfig,
                          mesh: Optional[Mesh], page_size: int):
    """Multi-token scoring step for speculative verification (DESIGN.md
    §11): the chunk-extension paged forward of ``make_paged_prefill_step``
    with logits at **every** chunk position instead of only the last.

    Signature ``(params, tokens (k,), n_valid (), slot (), table_row
    (maxp,), cache) -> (logits (k, V) f32, cache)``: row ``i`` is the
    next-token distribution AFTER ``tokens[:i+1]``, i.e. exactly what a
    sequential decode would have produced having fed ``tokens[i]`` — so
    one forward verifies a whole drafted continuation against the same
    paged pools. The slot's cache length advances by ``n_valid``; rows at
    and past ``n_valid`` are sink-written padding and must be ignored (the
    caller rolls back rejected rows by page-table truncation,
    ``PagedServer._rollback``). All-attention stacks only — raises
    ``ValueError`` otherwise (see ``launch.spec.SpecDecoder``)."""
    if cfg.num_codebooks > 1:
        raise ValueError("score step does not support codebook heads")
    fwd = _paged_chunk_forward(cfg, pcfg, mesh, page_size)

    def score_step(params, tokens, n_valid, slot, table_row, cache):
        hidden, new_cache, stats = fwd(params, tokens, n_valid, slot,
                                       table_row, cache)
        logits = lm.score_logits(params, hidden, cfg)   # (1, chunk, V)
        out = logits[0].astype(jnp.float32)
        if pcfg.collect_router_stats:
            return out, new_cache, stats
        return out, new_cache

    return score_step


def make_paged_handoff_step(cfg: ModelConfig):
    """Prefill→decode role handoff (DESIGN.md §7 disaggregation): move one
    finished-prefill sequence from slot ``src`` to slot ``dst`` as a PURE
    page-table/metadata transfer — the KV pages live in the shared pool and
    are reached through the (host-side) table row the scheduler copies, so
    NO K/V bytes move. On-device state that IS per-slot moves here: the
    ``len`` entry and, for hybrid stacks, the recurrent mixer state rows
    (mamba conv/ssm, xlstm c/n/h/m) — constant-size, orders of magnitude
    below the KV it avoids copying. ``src``/``dst`` are traced int32
    scalars so slot refill never retraces."""
    is_attn = [cfg.layer_kind(p) == "attn" for p in range(cfg.period)]

    def handoff(cache, src, dst):
        def move_rows(v):
            row = jax.lax.dynamic_index_in_dim(v, src, axis=1, keepdims=True)
            v = jax.lax.dynamic_update_slice_in_dim(v, row, dst, axis=1)
            return jax.lax.dynamic_update_slice_in_dim(
                v, jnp.zeros_like(row), src, axis=1)

        layers = [
            cache["layers"][p] if is_attn[p]
            else jax.tree.map(move_rows, cache["layers"][p])
            for p in range(cfg.period)
        ]
        ln = cache["len"]
        val = jax.lax.dynamic_index_in_dim(ln, src, axis=0, keepdims=True)
        ln = jax.lax.dynamic_update_slice(ln, val, (dst,))
        ln = jax.lax.dynamic_update_slice(
            ln, jnp.zeros((1,), ln.dtype), (src,))
        return {"layers": layers, "len": ln}

    return handoff


def make_page_copy_step(cfg: ModelConfig):
    """Copy one physical page's K/V rows (and int8 scale rows) from ``src``
    to ``dst`` across every attention pool — the device half of
    ``parallel.cache.PagePool.cow``: the scheduler allocates ``dst`` from
    the writer's reservation, copies the shared payload here, and repoints
    the writer's table entry so the refcount>1 original is never written
    (DESIGN.md §7)."""
    is_attn = [cfg.layer_kind(p) == "attn" for p in range(cfg.period)]

    def copy_page(cache, src, dst):
        def cp(v):
            row = jax.lax.dynamic_index_in_dim(v, src, axis=1, keepdims=True)
            return jax.lax.dynamic_update_slice_in_dim(v, row, dst, axis=1)

        layers = [
            jax.tree.map(cp, cache["layers"][p]) if is_attn[p]
            else cache["layers"][p]
            for p in range(cfg.period)
        ]
        return {"layers": layers, "len": cache["len"]}

    return copy_page


def _make_paged_prefill_scan(cfg: ModelConfig, pcfg: ParallelConfig,
                             mesh: Optional[Mesh], page_size: int):
    x_spec = activation_spec((1, 1, cfg.d_model), pcfg, mesh)
    period = cfg.period
    is_attn = [cfg.layer_kind(p) == "attn" for p in range(period)]
    collect = pcfg.collect_router_stats
    n_experts = cfg.moe.num_experts if cfg.moe is not None else 1

    def prefill_step(params, tokens, n_valid, slot, table_row, cache):
        def take_slot(tree):
            return jax.tree.map(
                lambda v: jax.lax.dynamic_slice_in_dim(v, slot, 1, axis=1),
                tree,
            )

        sub_layers = [
            cache["layers"][p] if is_attn[p]
            else take_slot(cache["layers"][p])
            for p in range(period)
        ]
        sub = {
            "layers": sub_layers,
            "len": jax.lax.dynamic_slice(cache["len"], (slot,), (1,)),
        }

        def body(carry, xs):
            if collect:
                sc, last, stacc = carry
            else:
                sc, last = carry
            tok, t = xs
            act = (t < n_valid)[None]
            logits, sc, _, _, st = _fwd5(lm.forward(
                params, {"tokens": tok.reshape(1, 1)}, cfg, pcfg, mesh,
                mode="decode", cache=sc, x_spec=x_spec,
                paged={"table": table_row[None], "page_size": page_size},
                active=act,
            ))
            last = jnp.where(act[0], logits.reshape(-1), last)
            if collect:
                return (sc, last, obs_device.add_stats(stacc, st)), None
            return (sc, last), None

        chunk = tokens.shape[0]
        last0 = jnp.zeros((cfg.vocab_size,), jnp.float32)
        if collect:
            init = (sub, last0, obs_device.zero_stats(n_experts))
            (sub, last, stats), _ = jax.lax.scan(
                body, init, (tokens, jnp.arange(chunk))
            )
        else:
            (sub, last), _ = jax.lax.scan(
                body, (sub, last0), (tokens, jnp.arange(chunk))
            )
            stats = None

        new_layers = []
        for p in range(period):
            if is_attn[p]:
                new_layers.append(sub["layers"][p])
            else:
                new_layers.append(jax.tree.map(
                    lambda full, part: jax.lax.dynamic_update_slice_in_dim(
                        full, part, slot, axis=1
                    ),
                    cache["layers"][p], sub["layers"][p],
                ))
        new_len = jax.lax.dynamic_update_slice(
            cache["len"], sub["len"], (slot,)
        )
        new_cache = {"layers": new_layers, "len": new_len}
        if collect:
            return last, new_cache, stats
        return last, new_cache

    return prefill_step


def sharded_params(cfg: ModelConfig, pcfg: ParallelConfig, mesh: Mesh):
    """(abstract_params_with_shardings, shardings_tree, logical_specs)."""
    values, specs = lm.abstract_params(cfg, plan=pcfg.hetero_plan)
    sh = tree_shardings(values, specs, pcfg, mesh)
    abstract = jax.tree.map(
        lambda v, s: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=s),
        values, sh,
    )
    return abstract, sh, specs


def sharded_opt_state(abstract_params, opt_cfg: adamw.OptimizerConfig,
                      mesh: Mesh):
    """Abstract optimizer state whose moments inherit param shardings."""
    def like(p, dtype):
        return jax.ShapeDtypeStruct(p.shape, dtype, sharding=p.sharding)

    sd = jnp.dtype(opt_cfg.state_dtype)
    state = {
        "m": jax.tree.map(lambda p: like(p, sd), abstract_params),
        "v": jax.tree.map(lambda p: like(p, sd), abstract_params),
        "step": jax.ShapeDtypeStruct(
            (), jnp.int32, sharding=NamedSharding(mesh, P())
        ),
    }
    if opt_cfg.master_fp32:
        state["master"] = jax.tree.map(
            lambda p: (
                like(p, jnp.float32)
                if jnp.issubdtype(p.dtype, jnp.floating) and p.dtype != jnp.float32
                else None
            ),
            abstract_params,
        )
    return state


def wrap_step_with_faults(step_fn, site: str):
    """Host-level chaos wrapper for a jitted step callable (DESIGN.md §9).

    Fault injection cannot live *inside* a jitted function — the hook
    would fire once at trace time and never again — so the drivers wrap
    their compiled steps here: ``inject(site)`` runs before every call
    (raising for ``error``/``device_drop`` kinds, sleeping for ``delay``)
    and the wrapped fn is only entered if no fault fires. With no
    installed plan the wrapper adds one attribute read per step."""
    from repro.runtime import faults as faults_lib

    @functools.wraps(step_fn)
    def wrapped(*args, **kwargs):
        faults_lib.inject(site)
        return step_fn(*args, **kwargs)

    return wrapped
