"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation — the dry-run lowers every
(arch x shape x mesh) cell against these. The same functions build real
arrays for smoke tests (``concrete=True`` path in tests).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.parallel.sharding import ParallelConfig, divisible_spec, resolve_spec


def _sharded(sds: jax.ShapeDtypeStruct, logical, cfg: ParallelConfig,
             mesh: Optional[Mesh]):
    if mesh is None:
        return sds
    spec = divisible_spec(sds.shape, resolve_spec(logical, cfg, mesh), mesh)
    return jax.ShapeDtypeStruct(
        sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
    )


def activation_spec(shape3, pcfg: ParallelConfig, mesh: Optional[Mesh]) -> P:
    """Physical spec for (B, S, D) activations (dp, sp, -)."""
    if mesh is None:
        return P(None, None, None)
    return divisible_spec(
        shape3, resolve_spec((("dp",), "sp", None), pcfg, mesh), mesh
    )


def input_specs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    pcfg: ParallelConfig,
    mesh: Optional[Mesh],
) -> dict:
    """Abstract model inputs for one (arch x shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    toks = lambda *shp: jax.ShapeDtypeStruct(shp, jnp.int32)
    f32 = lambda *shp: jax.ShapeDtypeStruct(shp, jnp.float32)
    out = {}

    if shape.kind == "decode":
        s_in = 1
    else:
        s_in = s

    if cfg.frontend == "siglip" and shape.kind != "decode":
        n_patch = cfg.prefix_len
        out["patches"] = _sharded(
            f32(b, n_patch, cfg.frontend_dim), (("dp",), None, None), pcfg, mesh
        )
        out["tokens"] = _sharded(
            toks(b, s_in - n_patch), (("dp",), "sp"), pcfg, mesh
        )
    elif cfg.frontend == "encodec":
        out["embeds"] = _sharded(
            f32(b, s_in, cfg.frontend_dim), (("dp",), "sp", None), pcfg, mesh
        )
        out["cond"] = _sharded(
            f32(b, 64, cfg.cross_d), (("dp",), None, None), pcfg, mesh
        )
    else:
        out["tokens"] = _sharded(toks(b, s_in), (("dp",), "sp"), pcfg, mesh)

    if shape.kind == "train":
        if cfg.num_codebooks > 1:
            out["labels"] = _sharded(
                toks(b, s, cfg.num_codebooks), (("dp",), "sp", None), pcfg, mesh
            )
        else:
            lbl_s = s - cfg.prefix_len if cfg.frontend == "siglip" else s
            out["labels"] = _sharded(toks(b, s), (("dp",), "sp"), pcfg, mesh)
        out["loss_mask"] = _sharded(f32(b, s), (("dp",), "sp"), pcfg, mesh)
    return out


def cache_specs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    pcfg: ParallelConfig,
    mesh: Optional[Mesh],
):
    """Abstract (sharded) decode cache for one cell."""
    spec_tree = lm.cache_spec(cfg, shape.global_batch, shape.seq_len)
    logical = lm.cache_logical_specs(cfg, spec_tree)
    if mesh is None:
        return spec_tree

    def apply(sds, logical_spec):
        phys = divisible_spec(
            sds.shape, resolve_spec(logical_spec, pcfg, mesh), mesh
        )
        return jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, phys)
        )

    layers = [
        {
            k: apply(spec_tree["layers"][pos][k], logical["layers"][pos][k])
            for k in spec_tree["layers"][pos]
        }
        for pos in range(len(spec_tree["layers"]))
    ]
    return {
        "layers": layers,
        "len": apply(spec_tree["len"], logical["len"]),
    }


def concrete_inputs(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Real (small!) arrays matching input_specs — for smoke tests."""
    spec = input_specs(cfg, shape, ParallelConfig(), None)
    rng = np.random.default_rng(seed)

    def make(s):
        if np.issubdtype(s.dtype, np.integer):
            return jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=s.shape), s.dtype
            )
        return jnp.asarray(rng.normal(size=s.shape), s.dtype)

    return {k: make(v) for k, v in spec.items()}
