"""Training driver: data pipeline -> jitted train step -> checkpoints,
with fault tolerance (restore-on-failure, preemption save), straggler
monitoring feeding the heterogeneous planner, and deterministic resume.

CPU-scale example (examples/ use this):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \
      --smoke --steps 50 --global-batch 8 --seq-len 256

On a real slice the same driver runs the full config across the production
mesh (--mesh data,model) — everything else is identical.

Heterogeneous execution (paper §4.4, DESIGN.md §6): ``--hetero-latencies``
builds an Eq. 1/2 ``HeteroPlan`` that the MoE islands execute (uneven
per-device token shares, padded + masked; uneven TP hidden tiles via
``--hetero-tp-latencies``). ``--hetero-replan`` closes the straggler loop:
observed step times re-plan the token shares online, each new plan being a
bounded re-trace through ``parallel.cache.PlanCache``. ``--simulate-skew``
synthesises the per-device telemetry on a single host so the loop can be
demonstrated (and tested) off-cluster.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro import obs
from repro.checkpoint import manager as ckpt
from repro.configs.base import ShapeConfig
from repro.core import hetero as hetero_lib
from repro.data.pipeline import DataConfig, Prefetcher, TokenSource
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh, split_model_axis
from repro.models import lm
from repro.parallel.cache import PlanCache
from repro.optim import adamw
from repro.parallel.sharding import ParallelConfig, split_tree, tree_shardings
from repro.runtime import elastic as elastic_lib
from repro.runtime import faults as faults_lib
from repro.runtime import ft as ft_lib
from repro.runtime.straggler import StragglerConfig, StragglerMonitor


def build_state(cfg, pcfg, mesh, opt_cfg, seed):
    """Init sharded params (hetero-plan-padded when attached) + AdamW
    optimizer state."""
    params_p = lm.init_params(
        jax.random.PRNGKey(seed), cfg, plan=pcfg.hetero_plan
    )
    params, specs = split_tree(params_p)
    if mesh is not None:
        sh = tree_shardings(params, specs, pcfg, mesh)
        params = jax.tree.map(jax.device_put, params, sh)
    opt_state = adamw.init_opt_state(params, opt_cfg)
    return params, opt_state


def main(argv=None):
    """CLI training driver: synthetic-data train loop with optional mesh,
    hetero plan, straggler monitor, and QAT fake-quant."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--mesh", default=None, help="e.g. '2,4' => data,model")
    ap.add_argument("--mode", default="hybrid",
                    choices=["hybrid", "model_centric", "data_centric",
                             "auto", "ep"],
                    help="'auto' picks data/model-centric per MoE layer "
                         "from the roofline (parallel.autotune)")
    ap.add_argument("--schedule", default="ag_rs")
    ap.add_argument("--cache-policy", default="shared_cache")
    ap.add_argument("--cache-layers", type=int, default=0,
                    help="pipeline-shared prefetch cache residency bound "
                         "(gathered MoE periods); >0 implies --no-scan. "
                         "Inference-side mechanism: the remat'd train step "
                         "itself keeps using the remat-policy cache "
                         "(gathered params re-gathered in backward), so "
                         "this mainly affects eval/serve-style forwards")
    ap.add_argument("--no-scan", action="store_true",
                    help="unroll the period loop instead of lax.scan")
    ap.add_argument("--proxy-latencies", default=None,
                    help="comma-separated per-device proxy latencies t_i "
                         "(core.hetero); makes the auto chooser "
                         "heterogeneity-aware")
    ap.add_argument("--hetero-latencies", default=None,
                    help="comma-separated t_i per BATCH-group member: build "
                         "and EXECUTE an Eq. 1 uneven token split "
                         "(core.hetero.HeteroPlan; DESIGN.md §6). Requires "
                         "--mesh")
    ap.add_argument("--hetero-tp-latencies", default=None,
                    help="comma-separated t_i per TP-group member: adds the "
                         "Eq. 2 uneven hidden split (padded MXU tiles) to "
                         "the plan")
    ap.add_argument("--hetero-replan", action="store_true",
                    help="close the straggler loop: observed step times "
                         "re-plan the Eq. 1 shares online; each distinct "
                         "plan is one bounded re-trace (PlanCache)")
    ap.add_argument("--simulate-skew", default=None,
                    help="comma-separated per-worker slowdown factors used "
                         "to synthesise per-device telemetry on a single "
                         "host (demo/test of the replan loop)")
    ap.add_argument("--quant", default="none",
                    choices=["none", "int8", "fp8"],
                    help="QAT: expert weights pass through block-wise "
                         "int8/fp8 fake-quant inside the MoE islands "
                         "(straight-through grads; routers/dense layers "
                         "stay full precision — DESIGN.md §8)")
    ap.add_argument("--topology", default=None,
                    help="intra_bw:inter_bw:node_size (e.g. 50e9:12.5e9:4) "
                         "— two-level interconnect (DESIGN.md §10). Prices "
                         "the auto chooser's collectives per level, and "
                         "when the mesh's model extent spans multiple "
                         "nodes, splits it into ('node','model') and runs "
                         "the MoE islands' hierarchical dispatch "
                         "(node-local combine before the cross-node "
                         "exchange)")
    ap.add_argument("--overlap-dispatch", action="store_true",
                    help="overlap the NEXT MoE layer's expert collectives "
                         "with the current layer's compute: the "
                         "pipeline-shared prefetcher gathers data-centric "
                         "layers' full expert weights (fsdp AND tp factor) "
                         "a period ahead (DESIGN.md §10). Requires "
                         "--cache-layers > 0 and --mode auto")
    ap.add_argument("--impl", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable the metrics registry and dump a "
                         "Prometheus text snapshot to PATH at exit "
                         "(DESIGN.md §12); also turns on per-expert "
                         "router telemetry as extra train-step outputs")
    ap.add_argument("--metrics-interval", type=int, default=0, metavar="N",
                    help="also dump the Prometheus snapshot every N steps "
                         "(0 = exit-only)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record train-loop spans and write a Chrome "
                         "trace-event JSON (Perfetto-loadable) to PATH")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="write the structured event log (replans, "
                         "recoveries; JSONL) to PATH")
    ap.add_argument("--fault-spec", default=None,
                    help="chaos fault plan: inline JSON or a JSON file "
                         "(runtime.faults; sites train.step / train.loss / "
                         "train.preempt / ckpt.write, DESIGN.md §9)")
    ap.add_argument("--elastic", action="store_true",
                    help="on device dropout, re-mesh over the survivors "
                         "(runtime.elastic.choose_mesh_shape), re-derive "
                         "the hetero plan's token shares, and resume from "
                         "the newest valid checkpoint (requires --mesh)")
    args = ap.parse_args(argv)
    if args.elastic and not args.mesh:
        ap.error("--elastic requires --mesh (nothing to re-mesh)")
    if args.fault_spec:
        faults_lib.install(faults_lib.load_plan(args.fault_spec))

    obs_on = bool(args.metrics or args.trace_out or args.events_out)
    if obs_on:
        obs.configure(metrics=bool(args.metrics),
                      tracing=bool(args.trace_out),
                      event_log=bool(args.events_out), reset=True)

    cfg = (cfglib.get_smoke_config(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    if args.smoke:
        # widen the smoke vocab if the tokenizer stream needs it
        pass

    topo = None
    if args.topology:
        from repro.parallel.autotune import Topology
        try:
            topo = Topology.parse(args.topology)
        except (ValueError, TypeError) as e:
            ap.error(f"--topology: {e}")
    if args.overlap_dispatch and args.cache_layers <= 0:
        ap.error("--overlap-dispatch requires --cache-layers > 0 (the "
                 "prefetcher lives in the pipeline-shared cache)")

    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        axes = ("pod", "data", "model")[-len(dims):]
        if topo is not None:
            dims, axes = split_model_axis(dims, axes, topo.node_size)
        mesh = make_mesh(dims, axes)

    latencies = None
    if args.proxy_latencies:
        try:
            latencies = tuple(
                float(t) for t in args.proxy_latencies.split(",")
            )
        except ValueError:
            ap.error("--proxy-latencies must be comma-separated numbers")
        if any(t <= 0 for t in latencies):
            ap.error("--proxy-latencies must all be positive (seconds)")
    pcfg = ParallelConfig(
        mode=args.mode,
        collective_schedule=args.schedule,
        cache_policy=args.cache_policy,
        cache_layers=args.cache_layers,
        scan_layers=not (args.no_scan or args.cache_layers > 0),
        device_latencies=latencies,
        impl=args.impl,
        blk=min(128, max(16, args.seq_len // 4)),
        quant=args.quant,
        topology=topo,
        overlap_dispatch=args.overlap_dispatch,
        # --metrics adds per-expert router telemetry to the step outputs
        collect_router_stats=bool(args.metrics) and cfg.moe is not None,
    )

    def parse_lat(s, flag):
        try:
            vals = tuple(float(t) for t in s.split(","))
        except ValueError:
            ap.error(f"{flag} must be comma-separated numbers")
        if any(t <= 0 for t in vals):
            ap.error(f"{flag} must all be positive (seconds)")
        return vals

    hetero_plan = None
    if args.hetero_latencies:
        if mesh is None:
            ap.error("--hetero-latencies requires --mesh")
        tok_lat = parse_lat(args.hetero_latencies, "--hetero-latencies")
        tp_lat = (parse_lat(args.hetero_tp_latencies, "--hetero-tp-latencies")
                  if args.hetero_tp_latencies else None)
        hetero_plan = hetero_lib.make_hetero_plan(
            tok_lat,
            global_batch=args.global_batch,
            hidden_size=(cfg.moe.d_ff
                         if tp_lat is not None and cfg.moe is not None
                         else None),
            tp_latencies=tp_lat,
            capacity_headroom=1.5 if args.hetero_replan else 1.0,
        )
        pcfg = dataclasses.replace(pcfg, hetero_plan=hetero_plan)
        print(f"[hetero] plan: token_counts={hetero_plan.token_counts} "
              f"(capacity {hetero_plan.batch_capacity}/device) "
              f"hidden_splits={hetero_plan.hidden_splits}")
    opt_cfg = adamw.OptimizerConfig(
        peak_lr=args.lr, warmup_steps=args.warmup,
        decay_steps=max(args.steps, 2 * args.warmup),
        master_fp32=True,
    )
    data_cfg = DataConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        vocab_size=cfg.vocab_size, seed=args.seed,
    )
    source = TokenSource(data_cfg)

    params, opt_state = build_state(cfg, pcfg, mesh, opt_cfg, args.seed)
    # Uneven plans pad the SPMD batch: n_devices * capacity rows, of which
    # each device's Eq. 1 share is real (DESIGN.md §6). Shapes are FIXED
    # across replans — only the (plan-keyed) trace changes.
    eff_batch = args.global_batch
    if hetero_plan is not None and hetero_plan.token_counts is not None:
        eff_batch = (len(hetero_plan.token_counts)
                     * hetero_plan.batch_capacity)
    shape3 = (eff_batch, args.seq_len, cfg.d_model)
    plan_cache = PlanCache(4)
    # Single-element boxes: the replan loop and the elastic device-loss
    # handler both swap the live mesh/shape/step without re-entering main.
    mesh_box = [mesh]
    shape_box = [shape3]
    mesh_gen = [0]   # bumped per re-mesh so PlanCache keys can't collide

    def jit_step_for(plan):
        def build():
            pc = dataclasses.replace(pcfg, hetero_plan=plan)
            return jax.jit(
                steps_lib.make_train_step(
                    cfg, pc, mesh_box[0], opt_cfg, shape_box[0]),
                donate_argnums=(0, 1),
            )
        key = (mesh_gen[0], None if plan is None else plan.key())
        # The compiled step gets the chaos wrapper OUTSIDE the cache:
        # injection is host-level (inside jit it would fire at trace time
        # only) and must not be memoized away with the trace.
        return steps_lib.wrap_step_with_faults(
            plan_cache.fetch(key, build), "train.step")

    cur_plan = [hetero_plan]
    jit_step_box = [jit_step_for(hetero_plan)]

    start_step = 0
    state = {"params": params, "opt": opt_state}
    if args.resume:
        last = ckpt.latest_valid_step(args.ckpt_dir)
        if last is not None:
            state, meta = ckpt.restore(args.ckpt_dir, last, state)
            start_step = int(meta["step"])
            print(f"[train] resumed from step {start_step}")

    n_workers = 1
    if hetero_plan is not None and hetero_plan.token_counts is not None:
        n_workers = len(hetero_plan.token_counts)
    monitor = StragglerMonitor(
        num_workers=n_workers, global_batch=args.global_batch,
        cfg=StragglerConfig(window=8, min_steps_between_replans=8),
        plan=hetero_plan,
    )
    sim_skew = None
    if args.simulate_skew:
        sim_skew = np.asarray(
            parse_lat(args.simulate_skew, "--simulate-skew"))
        if len(sim_skew) != n_workers:
            ap.error(f"--simulate-skew needs {n_workers} factors")
    metrics_log = []
    t_last = [time.time()]
    router_drain = None
    if pcfg.collect_router_stats:
        router_drain = obs.RouterStatsDrain(
            obs.registry, cfg.moe.num_experts, phase="train")

    def dump_obs_metrics():
        if not args.metrics:
            return
        if router_drain is not None:
            router_drain.flush()
        obs.registry.collect()
        obs.dump_prometheus(obs.registry, args.metrics)

    def step_fn(state, step):
        t_data0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in source.batch(step).items()}
        if cfg.frontend == "encodec":
            rngb = np.random.default_rng(step)
            b, s = batch["tokens"].shape
            batch = {
                "embeds": jnp.asarray(
                    rngb.normal(size=(b, s, cfg.frontend_dim)), jnp.float32),
                "cond": jnp.asarray(
                    rngb.normal(size=(b, 64, cfg.cross_d)), jnp.float32),
                "labels": jnp.repeat(
                    batch["labels"][..., None], cfg.num_codebooks, -1),
                "loss_mask": batch["loss_mask"],
            }
        elif cfg.frontend == "siglip":
            rngb = np.random.default_rng(step)
            b, s = batch["tokens"].shape
            npatch = cfg.prefix_len
            batch = {
                "patches": jnp.asarray(
                    rngb.normal(size=(b, npatch, cfg.frontend_dim)), jnp.float32),
                "tokens": batch["tokens"][:, : s - npatch],
                "labels": batch["labels"],
                "loss_mask": batch["loss_mask"],
            }
        plan = cur_plan[0]
        if plan is not None and plan.token_counts is not None:
            # Re-pack the host batch into the plan's padded layout (each
            # device's Eq. 1 share followed by masked tail rows).
            batch = {
                k: jnp.asarray(v) for k, v in hetero_lib.pack_batch(
                    {k: np.asarray(v) for k, v in batch.items()}, plan
                ).items()
            }
        obs.tracer.complete("train.data", t_data0, time.perf_counter(),
                            step=step)
        with obs.tracer.span("train.step", step=step):
            params, opt, m = jit_step_box[0](
                state["params"], state["opt"], batch)
            # The device-side router accumulators ride the metrics pytree as
            # a non-scalar entry; hand them to the async drain before the
            # scalar float() conversion below.
            rstats = m.pop("router_stats", None)
            if rstats is not None and router_drain is not None:
                router_drain.push(rstats)
            m = {k: float(v) for k, v in m.items()}
        now = time.time()
        m["step_time_s"] = now - t_last[0]
        t_last[0] = now
        obs.registry.histogram(
            "repro_train_step_seconds",
            "Wall time per optimiser step").observe(m["step_time_s"])
        # Per-worker telemetry: real deployments feed host timings here; a
        # single-host demo synthesises them from the wall time, the plan
        # shares, and the simulated skew (time_i ∝ share_i * skew_i).
        times = [m["step_time_s"]] * n_workers
        if sim_skew is not None:
            shares = np.asarray(
                plan.token_counts if plan is not None
                and plan.token_counts is not None else [1] * n_workers,
                np.float64,
            )
            w = np.maximum(shares, 1e-9) * sim_skew
            times = list(m["step_time_s"] * w / w.mean())
        new_shares = monitor.report(times)
        if new_shares is not None and args.hetero_replan and plan is not None:
            cur_plan[0] = monitor.current_plan()
            jit_step_box[0] = jit_step_for(cur_plan[0])
            st = plan_cache.stats()
            obs.events.emit("train.replan", reason="straggler",
                            step=step, shares=list(new_shares))
            print(f"[hetero] replan -> shares {new_shares} "
                  f"(traces: {st['misses']}, reused: {st['hits']})")
        return {"params": params, "opt": opt}, m

    def on_metrics(step, m):
        metrics_log.append({"step": step, **m})
        if args.metrics_interval and step % args.metrics_interval == 0:
            dump_obs_metrics()
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {m['loss']:.4f} "
                  f"aux {m.get('aux_loss', 0):.4f} lr {m['lr']:.2e} "
                  f"({m['step_time_s']:.2f}s)")

    on_device_loss = None
    if args.elastic:
        def on_device_loss(err):
            """Elastic shrink (DESIGN.md §9): re-mesh over the survivors,
            re-derive the plan's token shares (hidden_splits stay fixed —
            they pad param shapes, and the checkpoint must still load),
            swap in a freshly-jitted step, and hand ``run_with_recovery``
            the template to restore the newest valid checkpoint into."""
            nonlocal n_workers, monitor, sim_skew
            survivors = err.survivors
            devs = (list(jax.devices())[:int(survivors)]
                    if isinstance(survivors, int)
                    else [jax.devices()[int(i)] for i in survivors])
            if not devs:
                raise RuntimeError("device dropout left no devices") \
                    from err
            new_shape = elastic_lib.choose_mesh_shape(len(devs))
            mesh_box[0] = elastic_lib.make_mesh(
                new_shape, ("data", "model"), devices=devs)
            mesh_gen[0] += 1
            new_plan = cur_plan[0]
            if (new_plan is not None and new_plan.token_counts is not None
                    and not isinstance(survivors, int)):
                # Re-derive ONLY the Eq. 1 token shares over the surviving
                # classes; hidden_splits/expert_bits pad the param shapes
                # and must stay fixed or the checkpoint could not load.
                surv_lat = tuple(new_plan.proxy_latencies[int(i)]
                                 for i in survivors)
                tmp = hetero_lib.make_hetero_plan(
                    surv_lat, global_batch=args.global_batch)
                new_plan = dataclasses.replace(
                    new_plan, proxy_latencies=tmp.proxy_latencies,
                    token_counts=tmp.token_counts,
                    token_capacity=tmp.token_capacity)
                shape_box[0] = (
                    len(new_plan.token_counts) * new_plan.batch_capacity,
                    args.seq_len, cfg.d_model)
            cur_plan[0] = new_plan
            if new_plan is not None and new_plan.token_counts is not None:
                n_workers = len(new_plan.token_counts)
                monitor = StragglerMonitor(
                    num_workers=n_workers, global_batch=args.global_batch,
                    cfg=StragglerConfig(window=8,
                                        min_steps_between_replans=8),
                    plan=new_plan)
                if sim_skew is not None and not isinstance(survivors, int):
                    sim_skew = sim_skew[[int(i) for i in survivors]]
            jit_step_box[0] = jit_step_for(new_plan)
            obs.events.emit("train.shrink", reason="device dropout",
                            mesh_shape=list(new_shape),
                            survivors=len(devs))
            print(f"[elastic] device loss -> re-mesh {new_shape} over "
                  f"{len(devs)} survivors")
            return state, None

    ft_cfg = ft_lib.FTConfig(
        ckpt_dir=args.ckpt_dir, save_every=args.save_every
    )
    with obs.tracer.span("train.run", steps=args.steps):
        state, last = ft_lib.run_with_recovery(
            state=state, step_fn=step_fn, start_step=start_step,
            num_steps=args.steps, ft=ft_cfg, on_metrics=on_metrics,
            on_device_loss=on_device_loss,
        )
    faults_lib.install(None)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(metrics_log, f, indent=1)
    if args.metrics:
        dump_obs_metrics()
        print(f"[obs] prometheus metrics -> {args.metrics}")
    if args.trace_out:
        obs.tracer.write(args.trace_out)
        cov = obs.span_coverage(obs.tracer.events)
        print(f"[obs] chrome trace -> {args.trace_out} "
              f"({len(obs.tracer.events)} events, "
              f"span coverage {cov:.1%})")
    if args.events_out:
        obs.events.write_jsonl(args.events_out)
        print(f"[obs] event log -> {args.events_out} "
              f"({len(obs.events.records)} records)")
    print(f"[train] finished at step {last}; "
          f"final loss {metrics_log[-1]['loss']:.4f}"
          if metrics_log else "[train] no steps run")
    return metrics_log


if __name__ == "__main__":
    main()
