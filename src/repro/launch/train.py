"""Training driver: data pipeline -> jitted train step -> checkpoints,
with fault tolerance (restore-on-failure, preemption save), straggler
monitoring feeding the heterogeneous planner, and deterministic resume.

CPU-scale example (examples/ use this):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \
      --smoke --steps 50 --global-batch 8 --seq-len 256

On a real slice the same driver runs the full config across the production
mesh (--mesh data,model) — everything else is identical.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.checkpoint import manager as ckpt
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, Prefetcher, TokenSource
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.optim import adamw
from repro.parallel.sharding import ParallelConfig, split_tree, tree_shardings
from repro.runtime import ft as ft_lib
from repro.runtime.straggler import StragglerMonitor


def build_state(cfg, pcfg, mesh, opt_cfg, seed):
    params_p = lm.init_params(jax.random.PRNGKey(seed), cfg)
    params, specs = split_tree(params_p)
    if mesh is not None:
        sh = tree_shardings(params, specs, pcfg, mesh)
        params = jax.tree.map(jax.device_put, params, sh)
    opt_state = adamw.init_opt_state(params, opt_cfg)
    return params, opt_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--mesh", default=None, help="e.g. '2,4' => data,model")
    ap.add_argument("--mode", default="hybrid",
                    choices=["hybrid", "model_centric", "data_centric",
                             "auto", "ep"],
                    help="'auto' picks data/model-centric per MoE layer "
                         "from the roofline (parallel.autotune)")
    ap.add_argument("--schedule", default="ag_rs")
    ap.add_argument("--cache-policy", default="shared_cache")
    ap.add_argument("--cache-layers", type=int, default=0,
                    help="pipeline-shared prefetch cache residency bound "
                         "(gathered MoE periods); >0 implies --no-scan. "
                         "Inference-side mechanism: the remat'd train step "
                         "itself keeps using the remat-policy cache "
                         "(gathered params re-gathered in backward), so "
                         "this mainly affects eval/serve-style forwards")
    ap.add_argument("--no-scan", action="store_true",
                    help="unroll the period loop instead of lax.scan")
    ap.add_argument("--proxy-latencies", default=None,
                    help="comma-separated per-device proxy latencies t_i "
                         "(core.hetero); makes the auto chooser "
                         "heterogeneity-aware")
    ap.add_argument("--impl", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = (cfglib.get_smoke_config(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    if args.smoke:
        # widen the smoke vocab if the tokenizer stream needs it
        pass

    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = make_mesh(dims, axes)

    latencies = None
    if args.proxy_latencies:
        try:
            latencies = tuple(
                float(t) for t in args.proxy_latencies.split(",")
            )
        except ValueError:
            ap.error("--proxy-latencies must be comma-separated numbers")
        if any(t <= 0 for t in latencies):
            ap.error("--proxy-latencies must all be positive (seconds)")
    pcfg = ParallelConfig(
        mode=args.mode,
        collective_schedule=args.schedule,
        cache_policy=args.cache_policy,
        cache_layers=args.cache_layers,
        scan_layers=not (args.no_scan or args.cache_layers > 0),
        device_latencies=latencies,
        impl=args.impl,
        blk=min(128, max(16, args.seq_len // 4)),
    )
    opt_cfg = adamw.OptimizerConfig(
        peak_lr=args.lr, warmup_steps=args.warmup,
        decay_steps=max(args.steps, 2 * args.warmup),
        master_fp32=True,
    )
    data_cfg = DataConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        vocab_size=cfg.vocab_size, seed=args.seed,
    )
    source = TokenSource(data_cfg)

    params, opt_state = build_state(cfg, pcfg, mesh, opt_cfg, args.seed)
    shape3 = (args.global_batch, args.seq_len, cfg.d_model)
    train_step = steps_lib.make_train_step(cfg, pcfg, mesh, opt_cfg, shape3)
    jit_step = jax.jit(train_step, donate_argnums=(0, 1))

    start_step = 0
    state = {"params": params, "opt": opt_state}
    if args.resume:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            state, meta = ckpt.restore(args.ckpt_dir, last, state)
            start_step = int(meta["step"])
            print(f"[train] resumed from step {start_step}")

    monitor = StragglerMonitor(num_workers=1, global_batch=args.global_batch)
    metrics_log = []
    t_last = [time.time()]

    def step_fn(state, step):
        batch = {k: jnp.asarray(v) for k, v in source.batch(step).items()}
        if cfg.frontend == "encodec":
            rngb = np.random.default_rng(step)
            b, s = batch["tokens"].shape
            batch = {
                "embeds": jnp.asarray(
                    rngb.normal(size=(b, s, cfg.frontend_dim)), jnp.float32),
                "cond": jnp.asarray(
                    rngb.normal(size=(b, 64, cfg.cross_d)), jnp.float32),
                "labels": jnp.repeat(
                    batch["labels"][..., None], cfg.num_codebooks, -1),
                "loss_mask": batch["loss_mask"],
            }
        elif cfg.frontend == "siglip":
            rngb = np.random.default_rng(step)
            b, s = batch["tokens"].shape
            npatch = cfg.prefix_len
            batch = {
                "patches": jnp.asarray(
                    rngb.normal(size=(b, npatch, cfg.frontend_dim)), jnp.float32),
                "tokens": batch["tokens"][:, : s - npatch],
                "labels": batch["labels"],
                "loss_mask": batch["loss_mask"],
            }
        params, opt, m = jit_step(state["params"], state["opt"], batch)
        m = {k: float(v) for k, v in m.items()}
        now = time.time()
        m["step_time_s"] = now - t_last[0]
        t_last[0] = now
        monitor.report([m["step_time_s"]])
        return {"params": params, "opt": opt}, m

    def on_metrics(step, m):
        metrics_log.append({"step": step, **m})
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {m['loss']:.4f} "
                  f"aux {m.get('aux_loss', 0):.4f} lr {m['lr']:.2e} "
                  f"({m['step_time_s']:.2f}s)")

    ft_cfg = ft_lib.FTConfig(
        ckpt_dir=args.ckpt_dir, save_every=args.save_every
    )
    state, last = ft_lib.run_with_recovery(
        state=state, step_fn=step_fn, start_step=start_step,
        num_steps=args.steps, ft=ft_cfg, on_metrics=on_metrics,
    )
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(metrics_log, f, indent=1)
    print(f"[train] finished at step {last}; "
          f"final loss {metrics_log[-1]['loss']:.4f}"
          if metrics_log else "[train] no steps run")
    return metrics_log


if __name__ == "__main__":
    main()
