"""Serving drivers: continuous batching over a dense KV cache (baseline)
and over the paged KV cache (DESIGN.md §7).

Both servers batch around the same shape-stable decode macro-step — every
occupied slot advances one token per step (a prompt token while prefilling,
the fed-back greedy token while decoding), inactive slots are masked — so
finished slots refill between steps without retracing.

``BatchedServer`` is the dense baseline: a ``(num_slots, max_seq)`` KV
rectangle allocated up front, every prompt token paying a full-batch step.
``PagedServer`` is the production engine: fixed-size pages in a shared pool
(``parallel.cache.PagePool``), per-slot page tables, admission by free-page
budget (worst-case pages reserved up front, so preemption-free FIFO decode
never starves the pool mid-request), chunked batch-1 prefill
(``launch.steps.make_paged_prefill_step``) interleaved with decode
macro-steps — pages granted a chunk's worth at a time from the
reservation — and on-demand page allocation at decode page boundaries.

Heterogeneous serving (paper §4.4, DESIGN.md §6/§7): for the dense baseline
``--hetero-latencies`` builds an Eq. 1 plan over the slot dim (tail slots
masked); for the paged engine the same plan becomes per-device PAGE-POOL
shares (``parallel.cache.page_shares``) — all slots stay schedulable, each
device group's admissions are budgeted against its share of pages.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro import obs
from repro.common import cdiv, tree_bytes
from repro.core import hetero as hetero_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh, split_model_axis
from repro.models import lm
from repro.parallel.cache import PagePool, PrefixIndex, page_shares
from repro.parallel.sharding import ParallelConfig, split_tree, tree_shardings
from repro.runtime import faults as faults_lib


@dataclass
class Request:
    """One serving request: prompt tokens in, up to ``max_new`` generated
    tokens out, sampled greedily at ``temperature`` 0 (the default) or
    categorically under the request's own ``seed``.

    Robustness fields (DESIGN.md §9): ``priority`` orders preemption under
    page exhaustion (lower preempts first); ``deadline_s`` bounds wall time
    from submission; a faulted request retries up to the engine's
    ``max_retries`` with ``aborts``/``preemptions`` counting the restarts.
    A retry clears ``out`` and replays from the prompt — sampling keys
    derive only from ``(seed, len(out))``, so the replayed stream is
    token-identical to an unfaulted run. A permanently failed request
    carries the reason in ``error``."""
    rid: int
    prompt: np.ndarray           # (S_prompt,)
    max_new: int
    out: list = field(default_factory=list)
    temperature: float = 0.0     # 0 = greedy argmax
    seed: int = 0                # per-request sampling seed
    priority: int = 0            # higher admits over lower under pressure
    deadline_s: Optional[float] = None   # wall-clock budget from submit
    submit_t: float = 0.0        # stamped by submit() (engine clock)
    aborts: int = 0              # fault/NaN retries consumed
    preemptions: int = 0         # page-pressure evictions (not retries)
    error: Optional[str] = None  # permanent failure reason


def argmax_token(logits_row) -> int:
    """THE engine-wide greedy convention (DESIGN.md §11): upcast the row
    to f32 FIRST, then argmax; ties resolve to the LOWEST index (first
    occurrence — ``np.argmax`` and ``jnp.argmax`` both guarantee this, so
    the host-side argmax here and the batched device argmax in
    ``_greedy`` agree on every row). Every greedy selection —
    ``next_token``, the batch ``_greedy`` helper, and the draft-side
    greedy in ``launch.spec`` — routes through this one convention, so
    draft-vs-target acceptance and reference replay can never diverge on
    a row where bf16 downcasting manufactures a tie the f32 original
    breaks (regression-pinned in tests/test_spec.py). Host-side on
    purpose: the speculative verify loop calls this per accepted row, and
    a device dispatch per row would eat the speedup it exists to measure."""
    row = np.asarray(logits_row, np.float32).reshape(-1)
    return int(np.argmax(row))


def _greedy(logits) -> np.ndarray:
    # batch form of argmax_token: f32 upcast BEFORE the device argmax,
    # lowest index on ties — one convention across all engines.
    rows = jnp.asarray(logits)[..., -1, :].astype(jnp.float32)
    return np.asarray(jnp.argmax(rows, axis=-1)).reshape(-1)


def next_token(logits_row, req: Request) -> int:
    """Engine-independent next-token selection: greedy argmax at
    ``temperature <= 0`` (``argmax_token`` — the shared f32-upcast device
    convention), else categorical sampling at a key derived ONLY from
    ``(req.seed, len(req.out))`` — the same seed threading in
    ``BatchedServer``, ``PagedServer``, the batch-1 reference, and the
    speculative verify loop (which appends each accepted token before
    sampling the next, so its keys advance identically), so a request's
    sampled stream is a pure function of its own logits and seed, never
    of its batch-mates, slot id, or engine
    (tests/test_serve_parity.py pins this)."""
    if req.temperature <= 0.0:
        return argmax_token(logits_row)
    row = np.asarray(logits_row, np.float32).reshape(-1)
    key = jax.random.fold_in(
        jax.random.PRNGKey(req.seed), len(req.out))
    return int(jax.random.categorical(
        key, jnp.asarray(row) / req.temperature))


def reference_stream(cfg, pcfg, mesh, params, req: Request, *,
                     max_seq: int, step=None) -> list[int]:
    """One-request-at-a-time dense-cache reference stream: batch-1 prefill
    (token by token) then decode through ``next_token`` — the ground truth
    the parity matrix pins both batched servers against, for greedy AND
    sampled requests."""
    if step is None:
        step = jax.jit(steps_lib.make_serve_step(
            cfg, pcfg, mesh, (1, 1, cfg.d_model)))
    ref = dataclasses.replace(req, out=[])
    cache = lm.init_cache(cfg, 1, max_seq)
    logits = None
    for tok in ref.prompt:
        logits, cache = step(
            params, {"tokens": jnp.asarray([[tok]], jnp.int32)}, cache)
    ref.out.append(next_token(logits[0, -1], ref))
    while len(ref.out) < ref.max_new:
        logits, cache = step(
            params, {"tokens": jnp.asarray([[ref.out[-1]]], jnp.int32)},
            cache)
        ref.out.append(next_token(logits[0, -1], ref))
    return ref.out


def greedy_reference(cfg, pcfg, mesh, params, prompt, max_new, *,
                     max_seq: int, step=None) -> list[int]:
    """Greedy ``reference_stream`` under the pre-sampling signature."""
    return reference_stream(
        cfg, pcfg, mesh, params,
        Request(rid=-1, prompt=np.asarray(prompt), max_new=max_new),
        max_seq=max_seq, step=step)


# ---------------------------------------------------------------------------
# dense baseline
# ---------------------------------------------------------------------------

@dataclass
class _Slot:
    req: Request
    pos: int = 0        # prompt tokens consumed


class BatchedServer:
    """Dense-cache continuous batching: the KV rectangle
    ``(num_slots, max_seq)`` is allocated up front (the memory
    over-allocation the paged engine exists to kill) and every prompt token
    of every request costs one full-batch macro-step."""

    def __init__(self, cfg, pcfg, mesh, *, num_slots: int, max_seq: int,
                 params, valid_slots: Optional[list] = None):
        self.cfg, self.pcfg, self.mesh = cfg, pcfg, mesh
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.params = params
        self.cache = lm.init_cache(cfg, num_slots, max_seq)
        self.serve_step = jax.jit(steps_lib.make_serve_step(
            cfg, pcfg, mesh, (num_slots, 1, cfg.d_model)))
        self.slots: list[Optional[_Slot]] = [None] * num_slots
        self.queue: deque[Request] = deque()
        # Heterogeneous plan over the slot dim (DESIGN.md §6): only each
        # device's Eq. 1 share of slots is schedulable.
        self.free = sorted(valid_slots if valid_slots is not None
                           else range(num_slots), reverse=True)
        self.decode_times_s: list = []
        self.admissions = 0

    def submit(self, req: Request):
        if len(req.prompt) + req.max_new - 1 > self.max_seq:
            raise ValueError(
                f"request {req.rid} needs {len(req.prompt) + req.max_new - 1}"
                f" cache rows > max_seq {self.max_seq}")
        self.queue.append(req)

    def _admit(self):
        while self.free and self.queue:
            slot = self.free.pop()
            req = self.queue.popleft()
            self.cache = lm.reset_slot(self.cfg, self.cache, slot)
            self.slots[slot] = _Slot(req)
            self.admissions += 1

    def _macro_step(self) -> list[Request]:
        tokens = np.zeros((self.num_slots, 1), np.int32)
        active = np.zeros((self.num_slots,), bool)
        for slot, st in enumerate(self.slots):
            if st is None:
                continue
            active[slot] = True
            tokens[slot, 0] = (st.req.prompt[st.pos]
                               if st.pos < len(st.req.prompt)
                               else st.req.out[-1])
        t0 = time.perf_counter()
        logits, self.cache = self.serve_step(
            self.params,
            {"tokens": jnp.asarray(tokens), "active": jnp.asarray(active)},
            self.cache,
        )
        nxt = np.asarray(logits)
        self.decode_times_s.append(time.perf_counter() - t0)
        done = []
        for slot, st in enumerate(self.slots):
            if st is None:
                continue
            st.pos += 1
            if st.pos >= len(st.req.prompt):
                st.req.out.append(next_token(nxt[slot, -1], st.req))
                if len(st.req.out) >= st.req.max_new:
                    done.append(st.req)
                    self.slots[slot] = None
                    self.free.append(slot)
        return done

    def run(self, max_steps: int = 100000) -> list[Request]:
        done = []
        steps = 0
        while (self.queue or any(self.slots)) and steps < max_steps:
            self._admit()
            done.extend(self._macro_step())
            steps += 1
        return done


# ---------------------------------------------------------------------------
# paged engine
# ---------------------------------------------------------------------------

@dataclass
class _PagedSlot:
    req: Request
    group: int
    order: int           # admission sequence (FIFO prefill priority)
    need: int            # worst-case pages for the request
    reserved: int        # pages reserved from the pool at admission
    pages: list = field(default_factory=list)  # phys page per logical (0 =
    pos: int = 0         # prompt tokens consumed       # reclaimed)
    length: int = 0      # tokens resident in the paged cache
    reclaimed: int = 0   # leading logical pages released behind the window
    allocated: int = 0   # pool.alloc calls (reservations consumed)
    matched: int = 0     # prefix-cache pages mapped in at refcount+1


def derive_roles(token_counts) -> list[str]:
    """Disaggregated prefill/decode role per device class (DESIGN.md §7):
    the fastest class(es) — largest Eq. 1 token share — take the
    compute-bound prefill role, the rest take the bandwidth-bound decode
    role. Uniform (or single-class) plans collapse to ``"both"`` for every
    class, which reduces the server to the single-loop engine."""
    counts = list(token_counts)
    if len(set(counts)) < 2:
        return ["both"] * len(counts)
    top = max(counts)
    return ["prefill" if c == top else "decode" for c in counts]


class PagedServer:
    """Continuous batching over the paged KV cache (DESIGN.md §7).

    Admission is by free-page budget: a request is admitted only when its
    worst-case page count ``ceil((prompt + max_new - 1) / page_size)`` can
    be reserved (per device group under a hetero plan), which makes the
    preemption-free FIFO safe — every physical ``alloc`` draws from the
    reservation and cannot fail. Prefill grants a chunk's worth of pages
    before each ``prefill_chunk``-token batch-1 chunk (interleaved with
    the decode macro-steps of the already-running slots); decode grants
    one page per boundary crossing; on all-windowed stacks pages wholly
    behind the window return to the pool mid-request.
    """

    def __init__(self, cfg, pcfg, mesh, *, num_slots: int, page_size: int,
                 num_pages: int, max_pages_per_slot: int, params,
                 prefill_chunk: int = 16, plan=None, kv_quant=None,
                 prefix_cache: bool = False, disagg: bool = False,
                 max_retries: int = 2, audit: bool = False,
                 clock=time.perf_counter):
        self.cfg, self.mesh = cfg, mesh
        self.max_retries = max_retries
        self.audit = audit
        self.clock = clock
        self.kv_quant = None if kv_quant in (None, "none") else kv_quant
        # The plan's Eq. 1 shares are honored as page budgets (below), not
        # as masked tail rows — every slot is schedulable, so only the
        # token_counts half is stripped from the step config. The Eq. 2
        # half (tp_latencies / hidden_splits) stays: the auto-mode roofline
        # keeps pricing layers with the uneven-tile term, matching the
        # plan-padded weights the caller initialised.
        self.pcfg = pcfg
        if pcfg.hetero_plan is not None:
            self.pcfg = dataclasses.replace(
                pcfg,
                hetero_plan=dataclasses.replace(
                    pcfg.hetero_plan, token_counts=None, token_capacity=None),
            )
        self.num_slots = num_slots
        self.page_size = page_size
        self.max_pages_per_slot = max_pages_per_slot
        self.prefill_chunk = prefill_chunk
        self.params = params
        self.cache = lm.init_paged_cache(cfg, num_slots, num_pages, page_size,
                                         kv_quant=self.kv_quant)

        # int8 paged-KV (DESIGN.md §8): admission budgets in the SMALLER
        # page bytes, so an equal-HBM pool holds proportionally more pages
        # and admits more concurrent requests.
        self.page_bytes = lm.paged_kv_page_bytes(cfg, page_size,
                                                 kv_quant=self.kv_quant)
        shares = None
        self.groups = [0] * num_slots
        # Per-class Eq. 1 weights, kept for elastic shrink: after a device
        # dropout the surviving classes' weights re-derive the pool shares
        # and roles (DESIGN.md §9).
        self.class_weights = (list(plan.token_counts)
                              if plan is not None else [1])
        if plan is not None:
            shares = page_shares(plan.token_counts, num_pages - 1)
            n_g = len(shares)
            if num_slots < n_g:
                raise ValueError(
                    f"{num_slots} slots cannot cover {n_g} device groups "
                    f"(a group without slots could never admit)")
            self.groups = [s * n_g // num_slots for s in range(num_slots)]
        self.pool = PagePool(num_pages, page_bytes=self.page_bytes,
                             shares=shares)

        # Window page reclamation: when EVERY attention layer is windowed
        # (e.g. mixtral's all-SWA stack), a page wholly behind the window
        # is dead — no layer will ever read it — and goes back to the pool
        # mid-request. Mixed local/global stacks keep everything (the
        # global layers read the full history through the shared table).
        attn_idx = [i for i in range(cfg.num_layers)
                    if cfg.layer_kind(i) == "attn"]
        self.reclaim_window = (
            cfg.window
            if cfg.window > 0 and attn_idx
            and all(cfg.attn_kind(i) == "local" for i in attn_idx)
            else None
        )

        # Prefix sharing (DESIGN.md §7): a radix index over FULL prompt
        # pages, each node holding one pool refcount. Only valid when every
        # period layer is attention — recurrent layers carry per-slot state
        # that pages do not capture, so a skipped prefix would silently
        # decode from a zero recurrent state.
        self.index = None
        if prefix_cache:
            if any(cfg.layer_kind(i) != "attn"
                   for i in range(cfg.num_layers)):
                raise ValueError(
                    "prefix_cache requires an all-attention stack: "
                    "recurrent layers keep per-slot state outside the KV "
                    "pages, so a shared prefix cannot be skipped")
            self.index = PrefixIndex(page_size)

        # Disaggregated prefill/decode roles (DESIGN.md §7): each slot is
        # tagged "prefill", "decode", or "both". Under a hetero plan the
        # tag comes from the slot's device class via derive_roles; without
        # one, an even half/half split. Single-role-class plans collapse to
        # "both" everywhere == the single-loop engine (pinned by
        # tests/test_disagg.py).
        self.disagg = disagg
        self.roles = ["both"] * num_slots
        if disagg:
            if plan is not None:
                group_roles = derive_roles(plan.token_counts)
                self.roles = [group_roles[self.groups[s]]
                              for s in range(num_slots)]
            else:
                if num_slots < 2:
                    raise ValueError("disagg needs >= 2 slots")
                self.roles = ["prefill" if s < num_slots // 2 else "decode"
                              for s in range(num_slots)]
            if "prefill" in self.roles and "decode" not in self.roles:
                raise ValueError(
                    "disaggregated plan has prefill-only slots but no "
                    "decode-capable slot — finished prefills could never "
                    "hand off")

        # Speculative decoding (DESIGN.md §11): constructing a
        # launch.spec.SpecDecoder over this server attaches itself here;
        # when set, _decode_tick delegates whole verify rounds to it.
        self.spec = None

        self.table = np.zeros((num_slots, max_pages_per_slot), np.int32)
        self._build_steps()
        self.slots: list[Optional[_PagedSlot]] = [None] * num_slots
        self.queue: deque[Request] = deque()
        self.free = sorted(range(num_slots), reverse=True)
        self.decode_times_s: list = []
        self.admissions = 0
        self.admission_log: list[int] = []   # rids, in admission order
        self._order = 0
        # Scheduler events (DESIGN.md §12): each entry holds the legacy
        # positional tuple — exposed unchanged through the ``trace``
        # property, the observable schedule the disagg invariants,
        # degenerate-reduction, and chaos tests pin — plus a monotonic
        # timestamp and a ``reason`` field, mirrored into the process-wide
        # obs event log when enabled. Tuple kinds: ("admit", rid, slot),
        # ("prefill_chunk", rid, slot, n), ("decode", (slots...)),
        # ("transfer", rid, src, dst), ("finish", rid, slot), ("abort",
        # rid, slot, reason), ("preempt", rid, slot), ("recover",),
        # ("shrink", survivors), ("rollback", rid, slot, n),
        # ("spec_verify", rid, slot, n_valid, accepted), ("fail", rid,
        # reason).
        self.events: list[dict] = []
        self.ttft_s: dict[int, float] = {}   # rid -> first-token latency
        # Router telemetry drain (collect_router_stats): step outputs
        # grow a stats pytree, pushed here and flushed at dump time.
        self.router_drain = None
        if self.pcfg.collect_router_stats and cfg.moe is not None:
            self.router_drain = obs.RouterStatsDrain(
                obs.registry, cfg.moe.num_experts, phase="serve")
        # Periodic Prometheus dumps from inside run() (0 = final only,
        # driven by the CLI's --metrics/--metrics-interval).
        self.obs_dump_every = 0
        self.obs_dump_path: Optional[str] = None
        obs.maybe_register(self)
        obs.maybe_register(self.pool)
        if self.index is not None:
            obs.maybe_register(self.index)
        self.transfers = 0
        self.failed: list[Request] = []      # permanently failed requests
        self.aborts = 0                      # fault/NaN slot aborts
        self.preemptions = 0                 # page-pressure evictions
        self.engine_recoveries = 0           # step-fn rebuilds
        self._run_t0 = 0.0

    def _build_steps(self):
        """(Re)build the jitted decode/prefill steps. Called at
        construction and by engine-level recovery (``_recover_engine``),
        which re-jits after an injected step failure — the page tables,
        pool, and cache are host/functional state that survives the
        rebuild, so live requests resume where they were."""
        self.serve_step = jax.jit(steps_lib.make_paged_serve_step(
            self.cfg, self.pcfg, self.mesh,
            (self.num_slots, 1, self.cfg.d_model), self.page_size))
        self.prefill_step = jax.jit(steps_lib.make_paged_prefill_step(
            self.cfg, self.pcfg, self.mesh, self.page_size))
        # Handoff/CoW-copy steps are built lazily on first use: most runs
        # never transfer a slot or copy a page, and tests monkeypatch the
        # two eager steps above.
        self._handoff_step = None
        self._copy_step = None
        # the speculative score step lives on the SpecDecoder; drop it so
        # engine recovery re-jits it too
        spec = getattr(self, "spec", None)
        if spec is not None:
            spec.reset_steps()

    def _event(self, name: str, *args, reason: Optional[str] = None):
        """Record one scheduler event: the legacy positional tuple (the
        ``trace`` view), a monotonic-clock stamp, and an optional reason —
        mirrored into the process-wide obs event log when enabled."""
        t = self.clock()
        self.events.append({"name": name, "t": t,
                            "legacy": (name, *args), "reason": reason})
        obs.events.emit(f"serve.{name}", reason=reason, t=t,
                        detail=list(args))

    @property
    def trace(self) -> list[tuple]:
        """The legacy timestamp-free event tuples, in order (the schedule
        view the invariant tests compare across engine configurations)."""
        return [e["legacy"] for e in self.events]

    def obs_metrics(self) -> dict:
        """Scheduler counters for registry snapshot polling."""
        return {
            "repro_serve_admissions_total": self.admissions,
            "repro_serve_transfers_total": self.transfers,
            "repro_serve_aborts_total": self.aborts,
            "repro_serve_preemptions_total": self.preemptions,
            "repro_serve_engine_recoveries_total": self.engine_recoveries,
            "repro_serve_failed_requests_total": len(self.failed),
            "repro_serve_queue_depth": len(self.queue),
            "repro_serve_live_slots": sum(
                s is not None for s in self.slots),
        }

    def _unpack_step(self, out):
        """Normalise a step's flag-dependent arity: with
        ``collect_router_stats`` every jitted step returns a trailing
        stats pytree, pushed (asynchronously) onto the router drain."""
        if self.pcfg.collect_router_stats:
            a, cache, rstats = out
            if self.router_drain is not None:
                self.router_drain.push(rstats)
            return a, cache
        return out

    def _dump_metrics(self):
        """Flush the router drain and write a Prometheus snapshot (used
        for the periodic in-run dumps; the CLI also dumps at exit)."""
        if self.router_drain is not None:
            self.router_drain.flush()
        if self.obs_dump_path:
            obs.dump_prometheus(obs.registry, self.obs_dump_path)

    def _need_pages(self, req: Request) -> int:
        # cache rows written = prompt + fed-back outputs (the last
        # generated token is never fed back).
        return cdiv(len(req.prompt) + req.max_new - 1, self.page_size)

    def submit(self, req: Request):
        if len(req.prompt) < 1 or req.max_new < 1:
            raise ValueError(f"request {req.rid}: empty prompt or max_new")
        if self._need_pages(req) > self.max_pages_per_slot:
            raise ValueError(
                f"request {req.rid} needs {self._need_pages(req)} pages "
                f"> max_pages_per_slot {self.max_pages_per_slot}")
        if self._need_pages(req) > max(self.pool.shares):
            raise ValueError(
                f"request {req.rid} needs {self._need_pages(req)} pages "
                f"> largest group share {max(self.pool.shares)} — it could "
                f"never admit (FIFO would deadlock behind it)")
        req.submit_t = self.clock()
        self.queue.append(req)

    # -- scheduling ticks -----------------------------------------------------

    def _try_reserve_evicting(self, n: int, group: int) -> bool:
        """``try_reserve`` with prefix-cache backpressure: when the free
        budget is short, evict LRU refcount-1 trie nodes — pages only the
        index still holds — back into the pool until the reservation fits
        or the index runs dry."""
        while not self.pool.try_reserve(n, group):
            if self.index is None or not self.index.evict_lru(self.pool):
                return False
        return True

    def _admit(self):
        """Strict FIFO: the queue head admits as soon as ANY free
        prefill-capable slot's group can reserve its worst-case pages;
        nothing overtakes it (head-of-line blocking is what makes FIFO
        starvation-free).

        With the prefix cache on, admission first matches the prompt
        against the radix index (capped at ``(plen - 1) // page_size``
        pages so at least one suffix token always prefills and produces
        the first-token logits), forks the matched pages — refcount+1,
        zero budget cost, and eviction-proof from that moment — and only
        reserves pages for the uncached remainder."""
        while self.queue and self.free:
            req = self.queue[0]
            need = self._need_pages(req)
            matched: list[int] = []
            if self.index is not None:
                plen = len(req.prompt)
                matched = self.index.match(
                    req.prompt, (plen - 1) // self.page_size)
                if matched:
                    self.pool.fork(matched)
            reserve_n = need - len(matched)
            slot = None
            for s in reversed(self.free):        # lowest slot id first
                if self.roles[s] == "decode":
                    continue
                if self._try_reserve_evicting(reserve_n, self.groups[s]):
                    slot = s
                    break
            if slot is None:
                if matched:
                    self.pool.release(matched)   # undo the admission forks
                # Graceful degradation under page exhaustion (DESIGN.md
                # §9): rather than stalling admission behind a full pool,
                # evict the lowest-priority decoding request (strictly
                # below the head's priority) back into the queue and retry
                # the head. A preemption is not a fault: it does not
                # consume the victim's retry budget, and the victim
                # re-admits right behind the head.
                if self._preempt_for(req):
                    continue
                return
            self.queue.popleft()
            self.free.remove(slot)
            m = len(matched) * self.page_size
            self.cache = lm.reset_slot(self.cfg, self.cache, slot, length=m)
            st = _PagedSlot(req, self.groups[slot], self._order, need,
                            reserved=reserve_n, pages=list(matched),
                            pos=m, length=m, matched=len(matched))
            self._order += 1
            self.admissions += 1
            self.admission_log.append(req.rid)
            self.table[slot, :] = 0
            self.table[slot, :len(matched)] = matched
            self.slots[slot] = st
            self._event("admit", req.rid, slot)

    def _cow_page(self, slot: int, st: _PagedSlot, j: int):
        """Copy-on-write guard: logical page ``j`` is about to be written
        but its physical page is shared (refcount > 1). Reserve+alloc a
        private replacement, copy the payload, repoint table and slot, and
        surrender the shared reference. The scheduler's own write pattern
        never triggers this — decode writes land strictly past the full
        prompt pages the index shares — so this is the defensive pool-level
        guarantee (exercised directly by tests/test_page_refcount.py)."""
        if not self._try_reserve_evicting(1, st.group):
            raise RuntimeError(
                f"slot {slot}: cannot reserve a CoW page for logical "
                f"page {j}")
        st.reserved += 1
        src = st.pages[j]
        dst = self.pool.cow(src, st.group)
        st.allocated += 1
        if self._copy_step is None:
            self._copy_step = jax.jit(
                steps_lib.make_page_copy_step(self.cfg))
        self.cache = self._copy_step(
            self.cache, jnp.int32(src), jnp.int32(dst))
        st.pages[j] = dst
        self.table[slot, j] = dst

    def _ensure_pages(self, slot: int, st: _PagedSlot, length: int):
        """Back every position below ``length`` with a physical page,
        drawing from the request's admission reservation: a chunk's worth
        at once before a prefill tick (the bulk grant), one page at a
        decode boundary. Granting at use (not all at admission) is what
        lets window reclamation bound an SWA request's live pages below
        its total page count. The first page the coming write touches is
        CoW-resolved if shared."""
        j = st.length // self.page_size
        if j < len(st.pages) and st.pages[j] != 0 \
                and self.pool.refcount(st.pages[j]) > 1:
            self._cow_page(slot, st, j)
        while (length - 1) // self.page_size >= len(st.pages):
            st.pages.append(self.pool.alloc(st.group))
            st.allocated += 1
            self.table[slot, len(st.pages) - 1] = st.pages[-1]

    def _reclaim(self, slot: int, st: _PagedSlot):
        """Release pages wholly behind the attention window: logical page
        ``j`` is dead once ``(j+1) * page_size <= length - window`` (the
        next read starts at ``length + 1 - window``, so this is
        conservative). The table entry drops to the sink; attention masks
        the positions regardless, so a reused page's new contents are
        never observable."""
        if self.reclaim_window is None:
            return
        dead = (st.length - self.reclaim_window) // self.page_size
        while st.reclaimed < dead:
            j = st.reclaimed
            self.pool.release([st.pages[j]], st.group)
            st.pages[j] = 0
            self.table[slot, j] = 0
            st.reclaimed += 1

    def _rollback(self, slot: int, n: int):
        """Un-write the last ``n`` speculative cache rows by truncation
        only (DESIGN.md §11): shrink the slot's device ``len`` (paged
        attention masks every row at and past it — ``lm.rollback_slot``),
        pop now-unbacked tail pages back to the request's own admission
        RESERVATION (``PagePool.rollback``, never the free budget: the
        request is still live and must re-grow grant-by-grant), and zero
        their table entries. The popped pages are strictly decode-region
        — past any prefix-matched prompt page — so they are always
        refcount-1; a shared page here trips the pool's hard error rather
        than corrupting a CoW sibling. The sampling key needs no explicit
        re-derivation: keys are a pure function of ``(seed, len(out))``
        and rejected tokens were never appended to ``out``."""
        st = self.slots[slot]
        if n <= 0:
            return
        new_len = st.length - n
        assert new_len >= len(st.req.prompt), (new_len, len(st.req.prompt))
        if self.reclaim_window is not None and st.reclaimed:
            # reclamation must only ever have run at committed lengths
            # (the spec tick reclaims AFTER rollback), so no reclaimed
            # page can re-enter the rolled-back window
            assert st.reclaimed * self.page_size <= max(
                new_len - self.reclaim_window, 0), \
                "rollback would rewind into window-reclaimed pages"
        keep = cdiv(new_len, self.page_size)
        dropped = []
        while len(st.pages) > keep:
            p = st.pages.pop()
            self.table[slot, len(st.pages)] = 0
            if p != 0:
                dropped.append(p)
        if dropped:
            self.pool.rollback(dropped, st.group)
            st.allocated -= len(dropped)
        self.cache = lm.rollback_slot(self.cfg, self.cache, slot, new_len)
        st.length = new_len
        self._event("rollback", st.req.rid, slot, n, reason="speculative rows rejected")

    def _finish(self, slot: int, st: _PagedSlot, done: list):
        done.append(st.req)
        self.pool.release([p for p in st.pages if p != 0], st.group,
                          unused_reserved=st.reserved - st.allocated)
        self.table[slot, :] = 0
        self.slots[slot] = None
        self.free.append(slot)
        if self.spec is not None:
            self.spec.forget(st.req.rid)
        self._event("finish", st.req.rid, slot)

    # -- failure handling (DESIGN.md §9) --------------------------------------

    def _release_slot(self, slot: int, st: _PagedSlot):
        """Return EVERYTHING a live slot holds to the pool: one reference
        per non-reclaimed page (window-reclaimed entries are already 0)
        plus the unconsumed tail of its admission reservation — the same
        accounting as ``_finish``, so refcounts, owner-group budgets, and
        the prefix trie stay consistent on every abort path (the
        structural oracle in tests/test_page_refcount.py pins this)."""
        self.pool.release([p for p in st.pages if p != 0], st.group,
                          unused_reserved=st.reserved - st.allocated)
        self.table[slot, :] = 0
        self.slots[slot] = None
        self.free.append(slot)
        if self.spec is not None:
            self.spec.forget(st.req.rid)

    def _fail_request(self, req: Request, reason: str):
        req.error = reason
        req.out.clear()
        self.failed.append(req)
        self._event("fail", req.rid, reason, reason=reason)

    def _abort_slot(self, slot: int, *, reason: str, requeue_at: int = 0,
                    count_retry: bool = True):
        """Tear a live request out of its slot: release all pages +
        reservations, clear the generated stream (sampling keys depend
        only on ``(seed, len(out))``, so the replay is token-identical),
        and either re-enqueue at ``requeue_at`` or fail permanently once
        the retry budget is spent. Re-admission goes through the prefix
        cache, so a retry re-prefills only the uncached suffix."""
        st = self.slots[slot]
        req = st.req
        self._release_slot(slot, st)
        req.out.clear()
        self._event("abort", req.rid, slot, reason, reason=reason)
        if count_retry:
            req.aborts += 1
            self.aborts += 1
            if req.aborts > self.max_retries:
                self._fail_request(
                    req, f"retries exhausted ({self.max_retries}) "
                         f"after {reason}")
                return
        self.queue.insert(min(requeue_at, len(self.queue)), req)

    def _preempt_for(self, head: Request) -> bool:
        """Evict the lowest-priority (ties: youngest) decoding request
        strictly below ``head.priority``, re-enqueueing it directly behind
        the head — bounded by the strict-inequality rule, so equal-priority
        traffic can never livelock-thrash. False when no victim exists."""
        victims = [(st.req.priority, -st.order, slot, st)
                   for slot, st in enumerate(self.slots)
                   if st is not None and st.pos >= len(st.req.prompt)
                   and st.req.priority < head.priority]
        if not victims:
            return False
        _, _, slot, st = min(victims)
        st.req.preemptions += 1
        self.preemptions += 1
        self._event("preempt", st.req.rid, slot, reason="page pressure")
        self._abort_slot(slot, reason="preempted", requeue_at=1,
                         count_retry=False)
        return True

    def _expire_deadlines(self):
        """Permanently fail requests past their wall-clock deadline, both
        queued and in-flight (their pages release like any abort)."""
        now = self.clock()

        def expired(req):
            return (req.deadline_s is not None
                    and now - req.submit_t > req.deadline_s)

        for req in [r for r in self.queue if expired(r)]:
            self.queue.remove(req)
            self._fail_request(req, "deadline exceeded in queue")
        for slot, st in enumerate(self.slots):
            if st is not None and expired(st.req):
                req = st.req
                self._release_slot(slot, st)
                self._event("abort", req.rid, slot, "deadline", reason="deadline")
                self._fail_request(req, "deadline exceeded")

    def _recover_engine(self):
        """Engine-level recovery after an injected step failure: re-jit
        the step fns and resume from the surviving page tables. Step fns
        are functional (inputs are never donated), so a step that raised
        left ``self.cache``/``self.table`` at the pre-step state, and
        every tick is idempotent on retry."""
        self.engine_recoveries += 1
        self._build_steps()
        self._event("recover", reason="engine step failure")

    def _on_fault(self, err: faults_lib.FaultError):
        """Route an injected fault: a ``{"slot": k}`` payload is a
        request-level failure (abort + bounded retry of that request,
        front of queue); anything else is engine-level
        (``_recover_engine``)."""
        payload = err.fault.payload if err.fault is not None else {}
        slot = payload.get("slot")
        if slot is not None and self.slots[slot] is not None:
            self._abort_slot(slot, reason=f"injected fault: {err}")
        else:
            self._recover_engine()

    def _shrink(self, survivors):
        """Elastic shrink after device dropout (DESIGN.md §9): abort every
        live slot back to the queue in admission order (no retry charge —
        the device died, not the request), drain the prefix index, rebind
        the pool's group shares to the surviving classes' Eq. 1 weights,
        and re-derive slot groups + disagg roles. Live requests carry
        across: their cleared streams replay token-identically on the
        shrunken engine. Queued requests whose worst case no longer fits
        any surviving share fail permanently (FIFO would deadlock behind
        them)."""
        survivors = sorted(survivors if survivors is not None
                           else range(len(self.pool.shares) - 1))
        if not survivors:
            raise RuntimeError("device dropout left no survivors")
        live = sorted(
            (st.order, slot) for slot, st in enumerate(self.slots)
            if st is not None)
        for i, (_, slot) in enumerate(live):
            self._abort_slot(slot, reason="device dropout",
                             requeue_at=i, count_retry=False)
        if self.index is not None:
            self.index.clear(self.pool)
        weights = [self.class_weights[g] for g in survivors]
        self.class_weights = weights
        n_g = len(weights)
        shares = (page_shares(weights, self.pool.num_pages - 1)
                  if n_g > 1 else None)
        self.pool.reshare(shares if shares is not None
                          else [self.pool.num_pages - 1])
        self.groups = [s * n_g // self.num_slots
                       for s in range(self.num_slots)]
        if self.disagg:
            group_roles = derive_roles(weights)
            self.roles = [group_roles[self.groups[s]]
                          for s in range(self.num_slots)]
        self._event("shrink", tuple(survivors), reason="device dropout")
        for req in [r for r in self.queue
                    if self._need_pages(r) > max(self.pool.shares)]:
            self.queue.remove(req)
            self._fail_request(
                req, f"needs {self._need_pages(req)} pages > largest "
                     f"surviving share {max(self.pool.shares)}")

    def assert_page_invariants(self):
        """Structural oracle (DESIGN.md §9): on top of the pool's own
        conservation checks, every live page's refcount must equal its
        holder count — slot page-table entries + prefix-trie nodes — and
        every group's reserved balance must equal the unconsumed
        reservations of its live slots. Run after every abort path when
        ``audit=True`` (the chaos tests) and cheap enough to leave on."""
        self.pool.assert_consistent()
        holders: dict[int, int] = {}
        for st in self.slots:
            if st is None:
                continue
            for p in st.pages:
                if p != 0:
                    holders[p] = holders.get(p, 0) + 1
        if self.index is not None:
            for p in self.index.pages():
                holders[p] = holders.get(p, 0) + 1
        refs = {p: self.pool.refcount(p)
                for p in range(1, self.pool.num_pages)
                if self.pool.refcount(p) > 0}
        assert refs == holders, (
            f"refcount/holder mismatch (leak or dangler): "
            f"{refs} vs {holders}")
        per_group = [0] * len(self.pool.shares)
        for st in self.slots:
            if st is not None:
                per_group[st.group] += st.reserved - st.allocated
        for g, want in enumerate(per_group):
            assert self.pool._reserved[g] == want, (
                g, self.pool._reserved[g], want)

    def _index_prompt(self, st: _PagedSlot):
        """Insert the request's FULL prompt pages into the radix index at
        prefill completion. Only whole pages go in (a partial page would
        later be written by decode), and a window-reclaimed slot skips
        insertion entirely — its leading pages are gone, so the chain from
        the root would dangle. Decode writes land strictly past
        ``plen // page_size`` pages, so indexed pages are immutable."""
        if self.index is None or st.reclaimed > 0:
            return
        full = len(st.req.prompt) // self.page_size
        if full > 0:
            self.index.insert(st.req.prompt, st.pages[:full], self.pool)

    def _prefill_tick(self, done: list) -> bool:
        """One chunk of the FIFO-oldest prefilling request, restricted to
        prefill-capable slots (all slots unless disaggregated)."""
        cand = [(st.order, slot, st) for slot, st in enumerate(self.slots)
                if st is not None and st.pos < len(st.req.prompt)
                and self.roles[slot] != "decode"]
        if not cand:
            return False
        faults_lib.inject("serve.prefill")
        _, slot, st = min(cand)
        n = min(self.prefill_chunk, len(st.req.prompt) - st.pos)
        self._ensure_pages(slot, st, st.length + n)
        toks = np.zeros((self.prefill_chunk,), np.int32)
        toks[:n] = st.req.prompt[st.pos: st.pos + n]
        # .copy(): self.table is a persistent host buffer the scheduler
        # mutates (reclaim, grants) while steps are still in flight — CPU
        # jax aliases numpy inputs zero-copy, so an async read of the live
        # buffer could observe a FUTURE table state (a real, hash-seed-
        # timing-dependent token corruption caught by the parity tests).
        with obs.tracer.span("serve.prefill_chunk", rid=st.req.rid,
                             slot=slot, n=n):
            last, self.cache = self._unpack_step(self.prefill_step(
                self.params, jnp.asarray(toks), jnp.int32(n),
                jnp.int32(slot),
                jnp.asarray(self.table[slot].copy()), self.cache,
            ))
        st.pos += n
        st.length += n
        self._reclaim(slot, st)
        self._event("prefill_chunk", st.req.rid, slot, n)
        if st.pos == len(st.req.prompt):
            self._index_prompt(st)
            last = np.asarray(last, np.float32)
            for f in faults_lib.inject("serve.prefill_logits"):
                if f.kind == "nan":
                    last = np.full_like(last, np.nan)
            # NaN watchdog: non-finite first-token logits fail THIS
            # request (bounded retry), never the engine.
            if not np.all(np.isfinite(last)):
                self._abort_slot(slot, reason="non-finite prefill logits")
                return True
            st.req.out.append(next_token(last, st.req))
            # one clock read for BOTH the legacy dict and the trace
            # instant, so the span-derived TTFT is bitwise the legacy
            # value (tests/test_obs.py pins the equality)
            now = time.perf_counter()
            self.ttft_s[st.req.rid] = now - self._run_t0
            obs.tracer.instant("serve.first_token", t=now, rid=st.req.rid)
            if len(st.req.out) >= st.req.max_new:
                self._finish(slot, st, done)
        return True

    def _handoff(self, src: int, dst: int):
        if self._handoff_step is None:
            self._handoff_step = jax.jit(
                steps_lib.make_paged_handoff_step(self.cfg))
        with obs.tracer.span("serve.handoff", src=src, dst=dst):
            self.cache = self._handoff_step(
                self.cache, jnp.int32(src), jnp.int32(dst))

    def _transfer_tick(self) -> bool:
        """Disaggregated handoff: move every prefill-role slot that has
        finished its prompt into a free decode-capable slot. The KV pages
        never move — the transfer is the page-table row plus the jitted
        per-slot metadata (``len`` and any recurrent state), so its cost
        is independent of context length."""
        if not self.disagg:
            return False
        ready = sorted(
            (st.order, src, st) for src, st in enumerate(self.slots)
            if st is not None and self.roles[src] == "prefill"
            and st.pos >= len(st.req.prompt))
        moved = False
        for _, src, st in ready:
            dst = None
            for s in sorted(self.free):
                if self.roles[s] != "prefill":
                    dst = s
                    break
            if dst is None:
                break
            self.free.remove(dst)
            self._handoff(src, dst)
            self.table[dst, :] = self.table[src]
            self.table[src, :] = 0
            self.slots[dst] = st
            self.slots[src] = None
            self.free.append(src)
            self.transfers += 1
            self._event("transfer", st.req.rid, src, dst)
            moved = True
        return moved

    def _decode_tick(self, done: list) -> bool:
        """One decode macro-step over every decode-capable slot past
        prefill (a strict prefill-role slot waits for _transfer_tick).
        With a SpecDecoder attached the whole tick is a speculative
        draft/verify round instead (DESIGN.md §11)."""
        if self.spec is not None:
            return self.spec.decode_tick(done)
        dec = [(slot, st) for slot, st in enumerate(self.slots)
               if st is not None and st.pos >= len(st.req.prompt)
               and self.roles[slot] != "prefill"]
        if not dec:
            return False
        faults_lib.inject("serve.decode")
        tokens = np.zeros((self.num_slots, 1), np.int32)
        active = np.zeros((self.num_slots,), bool)
        for slot, st in dec:
            self._ensure_pages(slot, st, st.length + 1)
            tokens[slot, 0] = st.req.out[-1]
            active[slot] = True
        t0 = time.perf_counter()
        with obs.tracer.span("serve.decode", t0=t0, slots=len(dec)):
            logits, self.cache = self._unpack_step(self.serve_step(
                self.params,
                {"tokens": jnp.asarray(tokens),
                 # .copy() — see _prefill_tick: the live table buffer must
                 # not be aliased by an asynchronously-executing step
                 "page_table": jnp.asarray(self.table.copy()),
                 "active": jnp.asarray(active)},
                self.cache,
            ))
            nxt = np.array(logits, np.float32)  # owned: faults may poison
        dt = time.perf_counter() - t0
        self.decode_times_s.append(dt)
        obs.registry.histogram(
            "repro_serve_decode_step_seconds",
            "paged decode macro-step latency").observe(dt)
        self._event("decode", tuple(slot for slot, _ in dec))
        for f in faults_lib.inject("serve.logits"):
            if f.kind == "nan":
                nxt[int(f.payload.get("slot", dec[0][0]))] = np.nan
        for slot, st in dec:
            # NaN watchdog: a non-finite logits row fails (and retries)
            # the offending request only — the batch-mates' rows are
            # independent outputs of the same macro-step and their
            # streams proceed untouched (pinned by tests/test_chaos.py).
            if not np.all(np.isfinite(nxt[slot, -1])):
                self._abort_slot(slot, reason="non-finite decode logits")
                continue
            st.length += 1
            st.req.out.append(next_token(nxt[slot, -1], st.req))
            obs.tracer.instant("serve.token", rid=st.req.rid)
            self._reclaim(slot, st)
            if len(st.req.out) >= st.req.max_new:
                self._finish(slot, st, done)
        return True

    def run(self, max_steps: int = 100000) -> list[Request]:
        """Drive admission + ticks to completion. Injected faults route
        through ``_on_fault`` (request abort/retry or engine re-jit) and
        ``_shrink`` (device dropout); permanently failed requests land in
        ``self.failed`` with ``error`` set, never in the return value."""
        done: list[Request] = []
        steps = 0
        self._run_t0 = time.perf_counter()
        # span start pinned to _run_t0 so TTFT derived from the trace
        # subtracts the exact stamp the legacy ttft_s dict subtracts
        with obs.tracer.span("serve.run", t0=self._run_t0):
            while (self.queue or any(s is not None for s in self.slots)) \
                    and steps < max_steps:
                try:
                    self._expire_deadlines()
                    self._admit()
                    advanced = self._transfer_tick()
                    advanced |= self._prefill_tick(done)
                    advanced |= self._decode_tick(done)
                except faults_lib.DeviceLostError as e:
                    self._shrink(e.survivors)
                    advanced = True
                except faults_lib.FaultError as e:
                    self._on_fault(e)
                    advanced = True
                if self.audit:
                    self.assert_page_invariants()
                if not advanced and not self.queue:
                    break
                steps += 1
                if self.obs_dump_every and steps % self.obs_dump_every == 0:
                    self._dump_metrics()
        self._dump_metrics()
        return done

    def drop_prefix_cache(self) -> int:
        """Release every page the radix index holds back to the pool
        (leak-check draining; also the operator's cache-flush)."""
        if self.index is None:
            return 0
        return self.index.clear(self.pool)

    def stats(self) -> dict:
        out = {**self.pool.stats(), "admissions": self.admissions,
               "transfers": self.transfers, "aborts": self.aborts,
               "preemptions": self.preemptions,
               "engine_recoveries": self.engine_recoveries,
               "failed": len(self.failed)}
        if self.index is not None:
            out["prefix"] = self.index.stats()
        return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None):
    """CLI serving driver: dense or paged continuous batching with
    optional hetero plan, weight/KV quantization, prefix cache, and
    disaggregated roles."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged KV pool (DESIGN.md §7) "
                         "instead of the dense (slots, max_seq) rectangle")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages", type=int, default=0,
                    help="shared pool size incl. the sink page "
                         "(0 -> slots * ceil(max_seq/page)/2 + 1: half the "
                         "dense rectangle, the paged engine's whole point)")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--topology", default=None,
                    help="intra_bw:inter_bw:node_size — two-level "
                         "interconnect (DESIGN.md §10): prices the auto "
                         "chooser per level and, on a mesh whose model "
                         "extent spans multiple nodes, serves with the "
                         "hierarchical dispatch schedule")
    ap.add_argument("--mode", default="auto",
                    choices=["hybrid", "model_centric", "data_centric",
                             "auto", "ep"],
                    help="parallel mode; 'auto' (default) lets each MoE "
                         "layer pick data-/model-centric dispatch from the "
                         "roofline — decode steps (few tokens) resolve "
                         "model-centric, large prefills data-centric")
    ap.add_argument("--cache-layers", type=int, default=0,
                    help="pipeline-shared prefetch cache residency bound "
                         "(gathered MoE periods) for the decode forward; "
                         ">0 unrolls the layer loop")
    ap.add_argument("--hetero-latencies", default=None,
                    help="comma-separated t_i per batch-group member: an "
                         "Eq. 1 plan — uneven SLOT shares for the dense "
                         "server, uneven PAGE-POOL shares for --paged "
                         "(DESIGN.md §6/§7). Requires --mesh for dense")
    ap.add_argument("--hetero-tp-latencies", default=None,
                    help="comma-separated t_i per TP-group member: Eq. 2 "
                         "uneven hidden tiles")
    ap.add_argument("--quant", default="none",
                    choices=["none", "int8", "fp8"],
                    help="quantize expert weights to block-wise int8/fp8 "
                         "payloads served through the fused-dequant ES "
                         "kernels (DESIGN.md §8)")
    ap.add_argument("--kv-quant", default="none", choices=["none", "int8"],
                    help="store paged-KV pages as int8 + per-row scales — "
                         "smaller pages, more admitted requests per HBM "
                         "byte (--paged only, DESIGN.md §8)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share full prompt pages across requests through "
                         "a CoW radix index — repeated prefixes admit at "
                         "refcount+1 and only prefill their uncached "
                         "suffix (--paged only, DESIGN.md §7)")
    ap.add_argument("--fault-spec", default=None,
                    help="chaos fault plan: inline JSON or a JSON file "
                         "({'seed': 0, 'faults': [{'site', 'kind', ...}]},"
                         " runtime.faults) — deterministic injection into "
                         "the serving ticks (DESIGN.md §9)")
    ap.add_argument("--audit", action="store_true",
                    help="run the page-pool structural oracle "
                         "(refcounts == slot holders + prefix-trie nodes) "
                         "after every scheduler step")
    ap.add_argument("--disagg", action="store_true",
                    help="split slots into prefill and decode roles; "
                         "finished prefills hand off by page-table "
                         "transfer, no KV copy (--paged only, DESIGN.md "
                         "§7). Role shares follow --hetero-latencies "
                         "classes, else half/half")
    ap.add_argument("--spec-ngram", action="store_true",
                    help="speculative decoding with self-speculative "
                         "n-gram drafting from each request's own token "
                         "history — no draft model (--paged only, "
                         "all-attention stacks, DESIGN.md §11)")
    ap.add_argument("--spec-draft", default=None, metavar="ARCH",
                    help="speculative decoding with a small draft model "
                         "(any all-attention non-windowed config, e.g. "
                         "gemma_2b drafting for a MoE target); resolved "
                         "with the same --smoke switch as --arch "
                         "(--paged only, DESIGN.md §11)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft length per verify round: up to k drafted "
                         "tokens + 1 correction commit per forward")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable the metrics registry and dump a "
                         "Prometheus text snapshot to PATH at exit "
                         "(DESIGN.md §12); with --paged the step outputs "
                         "also carry per-expert router telemetry")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    metavar="N",
                    help="also dump the Prometheus snapshot every N "
                         "scheduler steps (0 = exit-only)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record spans/instants and write a Chrome "
                         "trace-event JSON (Perfetto-loadable) to PATH")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="write the structured scheduler event log "
                         "(JSONL, monotonic timestamps + reasons) to PATH")
    args = ap.parse_args(argv)
    if (args.spec_ngram or args.spec_draft) and not args.paged:
        ap.error("--spec-ngram/--spec-draft require --paged")
    if args.spec_ngram and args.spec_draft:
        ap.error("--spec-ngram and --spec-draft are mutually exclusive")
    if args.kv_quant != "none" and not args.paged:
        ap.error("--kv-quant requires --paged")
    if (args.prefix_cache or args.disagg) and not args.paged:
        ap.error("--prefix-cache/--disagg require --paged")
    if (args.fault_spec or args.audit) and not args.paged:
        ap.error("--fault-spec/--audit require --paged (the recovery "
                 "machinery lives in the paged engine)")
    if args.fault_spec:
        faults_lib.install(faults_lib.load_plan(args.fault_spec))

    obs_on = bool(args.metrics or args.trace_out or args.events_out)
    if obs_on:
        obs.configure(metrics=bool(args.metrics),
                      tracing=bool(args.trace_out),
                      event_log=bool(args.events_out), reset=True)

    cfg = (cfglib.get_smoke_config(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    topo = None
    if args.topology:
        from repro.parallel.autotune import Topology
        try:
            topo = Topology.parse(args.topology)
        except (ValueError, TypeError) as e:
            ap.error(f"--topology: {e}")
    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        axes = ("pod", "data", "model")[-len(dims):]
        if topo is not None:
            dims, axes = split_model_axis(dims, axes, topo.node_size)
        mesh = make_mesh(dims, axes)

    plan = None
    num_slots, valid_slots = args.slots, None
    if args.hetero_latencies:
        if mesh is None and not args.paged:
            ap.error("--hetero-latencies requires --mesh (dense server)")
        tok_lat = tuple(float(t) for t in args.hetero_latencies.split(","))
        tp_lat = (tuple(float(t) for t in args.hetero_tp_latencies.split(","))
                  if args.hetero_tp_latencies else None)
        plan = hetero_lib.make_hetero_plan(
            tok_lat,
            global_batch=args.slots,
            hidden_size=(cfg.moe.d_ff
                         if tp_lat is not None and cfg.moe is not None
                         else None),
            tp_latencies=tp_lat,
        )
        if args.paged:
            print(f"[serve] hetero plan: page-pool shares proportional to "
                  f"{plan.token_counts} (all {num_slots} slots schedulable)")
        else:
            # Dense: padded slot layout, device i's Eq. 1 share schedulable.
            cap = plan.batch_capacity
            num_slots = len(plan.token_counts) * cap
            valid_slots = [i * cap + j
                           for i, c in enumerate(plan.token_counts)
                           for j in range(c)]
            print(f"[serve] hetero plan: slot shares {plan.token_counts} "
                  f"({num_slots} padded slots), hidden {plan.hidden_splits}")

    pcfg = ParallelConfig(
        mode=args.mode, blk=16,
        cache_layers=args.cache_layers,
        scan_layers=args.cache_layers <= 0,
        hetero_plan=plan,
        # auto-mode roofline prices the served weight width (the island
        # itself skips QAT fake-quant when the params carry true payloads)
        quant=args.quant,
        topology=topo,
        # --metrics adds router telemetry outputs to the paged engine's
        # jitted steps (the dense baseline keeps its 2-tuple contract)
        collect_router_stats=(bool(args.metrics) and args.paged
                              and cfg.moe is not None),
    )

    params, specs = split_tree(
        lm.init_params(jax.random.PRNGKey(0), cfg, plan=plan))
    if args.quant != "none":
        if mesh is not None:
            ap.error("--quant serves whole-expert int8/fp8 payloads; "
                     "combine with --mesh is not supported (the scales "
                     "do not shard congruently)")
        from repro.quant import quantize_lm_params

        before = tree_bytes(params)
        params = quantize_lm_params(params, cfg, mode=args.quant)
        print(f"[serve] expert weights -> {args.quant}: params "
              f"{before / 1e6:.1f}MB -> {tree_bytes(params) / 1e6:.1f}MB")
    if mesh is not None:
        params = jax.tree.map(
            jax.device_put, params, tree_shardings(params, specs, pcfg, mesh)
        )
    if args.paged:
        pages = args.pages or (
            num_slots * cdiv(args.max_seq, args.page_size) // 2 + 1)
        server = PagedServer(
            cfg, pcfg, mesh, num_slots=num_slots,
            page_size=args.page_size, num_pages=pages,
            max_pages_per_slot=cdiv(args.max_seq, args.page_size),
            params=params, prefill_chunk=args.prefill_chunk, plan=plan,
            kv_quant=args.kv_quant, prefix_cache=args.prefix_cache,
            disagg=args.disagg, audit=args.audit,
        )
        if args.metrics:
            server.obs_dump_path = args.metrics
            server.obs_dump_every = args.metrics_interval
        if args.spec_ngram or args.spec_draft:
            # lazy import: spec imports serve (the shared sampling
            # helpers), so serve must never import spec at module level
            from repro.launch import spec as spec_lib
            if args.spec_draft:
                dcfg = (cfglib.get_smoke_config(args.spec_draft)
                        if args.smoke else cfglib.get_config(args.spec_draft))
                dparams, _ = split_tree(
                    lm.init_params(jax.random.PRNGKey(1), dcfg))
                drafter = spec_lib.ModelDrafter(
                    dcfg, ParallelConfig(blk=16), None, dparams,
                    max_seq=args.max_seq)
            else:
                drafter = spec_lib.NGramDrafter()
            spec_lib.SpecDecoder(server, drafter, k=args.spec_k)
    else:
        server = BatchedServer(cfg, pcfg, mesh, num_slots=num_slots,
                               max_seq=args.max_seq, params=params,
                               valid_slots=valid_slots)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        server.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
            max_new=args.max_new,
        ))
    t0 = time.time()
    done = server.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / max(dt, 1e-9):.1f} tok/s)")
    if server.decode_times_s:
        ts = np.asarray(server.decode_times_s[1:] or server.decode_times_s)
        print(f"[serve] measured decode step: median "
              f"{np.median(ts) * 1e3:.1f}ms p90 "
              f"{np.percentile(ts, 90) * 1e3:.1f}ms over {len(ts)} steps")
    if args.paged:
        st = server.stats()
        server.drop_prefix_cache()
        print(f"[serve] page pool: {st['peak_in_use_pages']} peak pages "
              f"({st['peak_in_use_bytes'] / 1024:.1f} KiB KV resident) of "
              f"{st['num_pages'] - 1} allocatable; "
              f"{st['total_allocs']} allocs, leak-free="
              f"{server.pool.stats()['free_pages'] == st['num_pages'] - 1}")
        if st["aborts"] or st["preemptions"] or st["engine_recoveries"] \
                or st["failed"]:
            print(f"[serve] recovery: {st['aborts']} aborts, "
                  f"{st['preemptions']} preemptions, "
                  f"{st['engine_recoveries']} engine recoveries, "
                  f"{st['failed']} failed")
        if "prefix" in st:
            pf = st["prefix"]
            hit = pf["hit_tokens"] / max(pf["lookup_tokens"], 1)
            print(f"[serve] prefix cache: {hit:.0%} token hit-rate over "
                  f"{pf['lookups']} lookups, {pf['cached_pages']} pages "
                  f"held at peak, {pf['evictions']} LRU evictions")
        if args.disagg:
            print(f"[serve] disagg: roles {server.roles}, "
                  f"{server.transfers} page-table handoffs")
        if server.spec is not None:
            sp = server.spec.stats()
            print(f"[serve] speculative: {sp['rounds']} verify rounds, "
                  f"{sp['accepted_drafts']}/{sp['drafted']} drafts "
                  f"accepted ({sp['acceptance_rate']:.0%}), "
                  f"{sp['rollback_tokens']} rows rolled back")
    if obs_on:
        if args.metrics:
            if getattr(server, "router_drain", None) is not None:
                server.router_drain.flush()
            obs.registry.collect()
            obs.dump_prometheus(obs.registry, args.metrics)
            print(f"[serve] metrics -> {args.metrics}")
        if args.trace_out:
            obs.tracer.write(args.trace_out)
            cov = obs.span_coverage(obs.tracer.events)
            ttft, tpot = obs.derive_request_latencies(obs.tracer.events)
            print(f"[serve] trace -> {args.trace_out} "
                  f"({len(obs.tracer.events)} events, "
                  f"{cov:.0%} span coverage)")
            if ttft:
                ms = sorted(v * 1e3 for v in ttft.values())
                line = (f"[serve] TTFT from spans: median "
                        f"{ms[len(ms) // 2]:.1f}ms over {len(ms)} requests")
                if tpot:
                    tp = sorted(v * 1e3 for v in tpot.values())
                    line += f"; TPOT median {tp[len(tp) // 2]:.2f}ms"
                print(line)
        if args.events_out:
            obs.events.write_jsonl(args.events_out)
            print(f"[serve] events -> {args.events_out} "
                  f"({len(obs.events.records)} records)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")
    faults_lib.install(None)
    return done


if __name__ == "__main__":
    main()
