"""Serving driver: prefill + batched decode with a static-shape request
queue (continuous-batching lite: finished slots are refilled between decode
macro-steps so the jitted step shape never changes).
"""
from __future__ import annotations

import argparse
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.parallel.sharding import ParallelConfig, split_tree, tree_shardings


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S_prompt,)
    max_new: int
    out: list = field(default_factory=list)


class BatchedServer:
    """Fixed-slot decode server. Slots hold independent sequences; the
    cache is one pytree with a batch dim == num_slots."""

    def __init__(self, cfg, pcfg, mesh, *, num_slots: int, max_seq: int,
                 params, seed: int = 0):
        self.cfg, self.pcfg, self.mesh = cfg, pcfg, mesh
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.params = params
        self.cache = lm.init_cache(cfg, num_slots, max_seq)
        shape3 = (num_slots, 1, cfg.d_model)
        self.serve_step = jax.jit(
            steps_lib.make_serve_step(cfg, pcfg, mesh, shape3)
        )
        self.active: dict[int, Request] = {}
        self.queue: deque[Request] = deque()
        self.slot_tokens = np.zeros((num_slots, 1), np.int32)
        self.free = list(range(num_slots))

    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_one(self, slot: int, req: Request):
        """Prefill a single slot by decoding its prompt token by token
        (simple and shape-stable; a production server would use a bucketed
        prefill step — launch.steps.make_prefill_step — per length)."""
        # reset the slot: stale cache beyond len is masked by decode attn
        self.cache["len"] = self.cache["len"].at[slot].set(0)
        for tok in req.prompt:
            self.slot_tokens[slot, 0] = tok
            self._decode_step()
        self.active[slot] = req

    def _decode_step(self):
        logits, self.cache = self.serve_step(
            self.params, {"tokens": jnp.asarray(self.slot_tokens)}, self.cache
        )
        return np.asarray(jnp.argmax(logits[..., -1, :], axis=-1)).reshape(-1)

    def run(self, max_steps: int = 1000) -> list[Request]:
        done = []
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            # fill free slots
            while self.free and self.queue:
                slot = self.free.pop()
                req = self.queue.popleft()
                self._prefill_one(slot, req)
            nxt = self._decode_step()
            steps += 1
            for slot, req in list(self.active.items()):
                req.out.append(int(nxt[slot]))
                if len(req.out) >= req.max_new:
                    done.append(req)
                    del self.active[slot]
                    self.free.append(slot)
        return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--mode", default="auto",
                    choices=["hybrid", "model_centric", "data_centric",
                             "auto", "ep"],
                    help="parallel mode; 'auto' (default) lets each MoE "
                         "layer pick data-/model-centric dispatch from the "
                         "roofline — decode steps (few tokens) resolve "
                         "model-centric, large prefills data-centric")
    ap.add_argument("--cache-layers", type=int, default=0,
                    help="pipeline-shared prefetch cache residency bound "
                         "(gathered MoE periods) for the decode forward; "
                         ">0 unrolls the layer loop")
    args = ap.parse_args(argv)

    cfg = (cfglib.get_smoke_config(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(dims, ("pod", "data", "model")[-len(dims):])
    pcfg = ParallelConfig(
        mode=args.mode, blk=16,
        cache_layers=args.cache_layers,
        scan_layers=args.cache_layers <= 0,
    )

    params, specs = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    if mesh is not None:
        params = jax.tree.map(
            jax.device_put, params, tree_shardings(params, specs, pcfg, mesh)
        )
    server = BatchedServer(cfg, pcfg, mesh, num_slots=args.slots,
                           max_seq=args.max_seq, params=params)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        server.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
            max_new=args.max_new,
        ))
    t0 = time.time()
    done = server.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / max(dt, 1e-9):.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")
    return done


if __name__ == "__main__":
    main()
