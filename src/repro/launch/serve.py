"""Serving drivers: continuous batching over a dense KV cache (baseline)
and over the paged KV cache (DESIGN.md §7).

Both servers batch around the same shape-stable decode macro-step — every
occupied slot advances one token per step (a prompt token while prefilling,
the fed-back greedy token while decoding), inactive slots are masked — so
finished slots refill between steps without retracing.

``BatchedServer`` is the dense baseline: a ``(num_slots, max_seq)`` KV
rectangle allocated up front, every prompt token paying a full-batch step.
``PagedServer`` is the production engine: fixed-size pages in a shared pool
(``parallel.cache.PagePool``), per-slot page tables, admission by free-page
budget (worst-case pages reserved up front, so preemption-free FIFO decode
never starves the pool mid-request), chunked batch-1 prefill
(``launch.steps.make_paged_prefill_step``) interleaved with decode
macro-steps — pages granted a chunk's worth at a time from the
reservation — and on-demand page allocation at decode page boundaries.

Heterogeneous serving (paper §4.4, DESIGN.md §6/§7): for the dense baseline
``--hetero-latencies`` builds an Eq. 1 plan over the slot dim (tail slots
masked); for the paged engine the same plan becomes per-device PAGE-POOL
shares (``parallel.cache.page_shares``) — all slots stay schedulable, each
device group's admissions are budgeted against its share of pages.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.common import cdiv, tree_bytes
from repro.core import hetero as hetero_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.parallel.cache import PagePool, page_shares
from repro.parallel.sharding import ParallelConfig, split_tree, tree_shardings


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S_prompt,)
    max_new: int
    out: list = field(default_factory=list)


def _greedy(logits) -> np.ndarray:
    return np.asarray(jnp.argmax(logits[..., -1, :], axis=-1)).reshape(-1)


def greedy_reference(cfg, pcfg, mesh, params, prompt, max_new, *,
                     max_seq: int, step=None) -> list[int]:
    """One-request-at-a-time dense-cache reference stream: batch-1 prefill
    (token by token) then greedy decode — the ground truth the parity
    matrix pins both batched servers against."""
    if step is None:
        step = jax.jit(steps_lib.make_serve_step(
            cfg, pcfg, mesh, (1, 1, cfg.d_model)))
    cache = lm.init_cache(cfg, 1, max_seq)
    logits = None
    for tok in prompt:
        logits, cache = step(
            params, {"tokens": jnp.asarray([[tok]], jnp.int32)}, cache)
    out = [int(_greedy(logits)[0])]
    while len(out) < max_new:
        logits, cache = step(
            params, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)}, cache)
        out.append(int(_greedy(logits)[0]))
    return out


# ---------------------------------------------------------------------------
# dense baseline
# ---------------------------------------------------------------------------

@dataclass
class _Slot:
    req: Request
    pos: int = 0        # prompt tokens consumed


class BatchedServer:
    """Dense-cache continuous batching: the KV rectangle
    ``(num_slots, max_seq)`` is allocated up front (the memory
    over-allocation the paged engine exists to kill) and every prompt token
    of every request costs one full-batch macro-step."""

    def __init__(self, cfg, pcfg, mesh, *, num_slots: int, max_seq: int,
                 params, valid_slots: Optional[list] = None):
        self.cfg, self.pcfg, self.mesh = cfg, pcfg, mesh
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.params = params
        self.cache = lm.init_cache(cfg, num_slots, max_seq)
        self.serve_step = jax.jit(steps_lib.make_serve_step(
            cfg, pcfg, mesh, (num_slots, 1, cfg.d_model)))
        self.slots: list[Optional[_Slot]] = [None] * num_slots
        self.queue: deque[Request] = deque()
        # Heterogeneous plan over the slot dim (DESIGN.md §6): only each
        # device's Eq. 1 share of slots is schedulable.
        self.free = sorted(valid_slots if valid_slots is not None
                           else range(num_slots), reverse=True)
        self.decode_times_s: list = []
        self.admissions = 0

    def submit(self, req: Request):
        if len(req.prompt) + req.max_new - 1 > self.max_seq:
            raise ValueError(
                f"request {req.rid} needs {len(req.prompt) + req.max_new - 1}"
                f" cache rows > max_seq {self.max_seq}")
        self.queue.append(req)

    def _admit(self):
        while self.free and self.queue:
            slot = self.free.pop()
            req = self.queue.popleft()
            self.cache = lm.reset_slot(self.cfg, self.cache, slot)
            self.slots[slot] = _Slot(req)
            self.admissions += 1

    def _macro_step(self) -> list[Request]:
        tokens = np.zeros((self.num_slots, 1), np.int32)
        active = np.zeros((self.num_slots,), bool)
        for slot, st in enumerate(self.slots):
            if st is None:
                continue
            active[slot] = True
            tokens[slot, 0] = (st.req.prompt[st.pos]
                               if st.pos < len(st.req.prompt)
                               else st.req.out[-1])
        t0 = time.perf_counter()
        logits, self.cache = self.serve_step(
            self.params,
            {"tokens": jnp.asarray(tokens), "active": jnp.asarray(active)},
            self.cache,
        )
        nxt = _greedy(logits)
        self.decode_times_s.append(time.perf_counter() - t0)
        done = []
        for slot, st in enumerate(self.slots):
            if st is None:
                continue
            st.pos += 1
            if st.pos >= len(st.req.prompt):
                st.req.out.append(int(nxt[slot]))
                if len(st.req.out) >= st.req.max_new:
                    done.append(st.req)
                    self.slots[slot] = None
                    self.free.append(slot)
        return done

    def run(self, max_steps: int = 100000) -> list[Request]:
        done = []
        steps = 0
        while (self.queue or any(self.slots)) and steps < max_steps:
            self._admit()
            done.extend(self._macro_step())
            steps += 1
        return done


# ---------------------------------------------------------------------------
# paged engine
# ---------------------------------------------------------------------------

@dataclass
class _PagedSlot:
    req: Request
    group: int
    order: int           # admission sequence (FIFO prefill priority)
    need: int            # worst-case pages reserved at admission
    pages: list = field(default_factory=list)  # phys page per logical (0 =
    pos: int = 0         # prompt tokens consumed       # reclaimed)
    length: int = 0      # tokens written to the paged cache
    reclaimed: int = 0   # leading logical pages released behind the window


class PagedServer:
    """Continuous batching over the paged KV cache (DESIGN.md §7).

    Admission is by free-page budget: a request is admitted only when its
    worst-case page count ``ceil((prompt + max_new - 1) / page_size)`` can
    be reserved (per device group under a hetero plan), which makes the
    preemption-free FIFO safe — every physical ``alloc`` draws from the
    reservation and cannot fail. Prefill grants a chunk's worth of pages
    before each ``prefill_chunk``-token batch-1 chunk (interleaved with
    the decode macro-steps of the already-running slots); decode grants
    one page per boundary crossing; on all-windowed stacks pages wholly
    behind the window return to the pool mid-request.
    """

    def __init__(self, cfg, pcfg, mesh, *, num_slots: int, page_size: int,
                 num_pages: int, max_pages_per_slot: int, params,
                 prefill_chunk: int = 16, plan=None, kv_quant=None):
        self.cfg, self.mesh = cfg, mesh
        self.kv_quant = None if kv_quant in (None, "none") else kv_quant
        # The plan's Eq. 1 shares are honored as page budgets (below), not
        # as masked tail rows — every slot is schedulable, so only the
        # token_counts half is stripped from the step config. The Eq. 2
        # half (tp_latencies / hidden_splits) stays: the auto-mode roofline
        # keeps pricing layers with the uneven-tile term, matching the
        # plan-padded weights the caller initialised.
        self.pcfg = pcfg
        if pcfg.hetero_plan is not None:
            self.pcfg = dataclasses.replace(
                pcfg,
                hetero_plan=dataclasses.replace(
                    pcfg.hetero_plan, token_counts=None, token_capacity=None),
            )
        self.num_slots = num_slots
        self.page_size = page_size
        self.max_pages_per_slot = max_pages_per_slot
        self.prefill_chunk = prefill_chunk
        self.params = params
        self.cache = lm.init_paged_cache(cfg, num_slots, num_pages, page_size,
                                         kv_quant=self.kv_quant)

        # int8 paged-KV (DESIGN.md §8): admission budgets in the SMALLER
        # page bytes, so an equal-HBM pool holds proportionally more pages
        # and admits more concurrent requests.
        self.page_bytes = lm.paged_kv_page_bytes(cfg, page_size,
                                                 kv_quant=self.kv_quant)
        shares = None
        self.groups = [0] * num_slots
        if plan is not None:
            shares = page_shares(plan.token_counts, num_pages - 1)
            n_g = len(shares)
            if num_slots < n_g:
                raise ValueError(
                    f"{num_slots} slots cannot cover {n_g} device groups "
                    f"(a group without slots could never admit)")
            self.groups = [s * n_g // num_slots for s in range(num_slots)]
        self.pool = PagePool(num_pages, page_bytes=self.page_bytes,
                             shares=shares)

        # Window page reclamation: when EVERY attention layer is windowed
        # (e.g. mixtral's all-SWA stack), a page wholly behind the window
        # is dead — no layer will ever read it — and goes back to the pool
        # mid-request. Mixed local/global stacks keep everything (the
        # global layers read the full history through the shared table).
        attn_idx = [i for i in range(cfg.num_layers)
                    if cfg.layer_kind(i) == "attn"]
        self.reclaim_window = (
            cfg.window
            if cfg.window > 0 and attn_idx
            and all(cfg.attn_kind(i) == "local" for i in attn_idx)
            else None
        )

        self.table = np.zeros((num_slots, max_pages_per_slot), np.int32)
        self.serve_step = jax.jit(steps_lib.make_paged_serve_step(
            cfg, self.pcfg, mesh, (num_slots, 1, cfg.d_model), page_size))
        self.prefill_step = jax.jit(steps_lib.make_paged_prefill_step(
            cfg, self.pcfg, mesh, page_size))
        self.slots: list[Optional[_PagedSlot]] = [None] * num_slots
        self.queue: deque[Request] = deque()
        self.free = sorted(range(num_slots), reverse=True)
        self.decode_times_s: list = []
        self.admissions = 0
        self.admission_log: list[int] = []   # rids, in admission order
        self._order = 0

    def _need_pages(self, req: Request) -> int:
        # cache rows written = prompt + fed-back outputs (the last
        # generated token is never fed back).
        return cdiv(len(req.prompt) + req.max_new - 1, self.page_size)

    def submit(self, req: Request):
        if len(req.prompt) < 1 or req.max_new < 1:
            raise ValueError(f"request {req.rid}: empty prompt or max_new")
        if self._need_pages(req) > self.max_pages_per_slot:
            raise ValueError(
                f"request {req.rid} needs {self._need_pages(req)} pages "
                f"> max_pages_per_slot {self.max_pages_per_slot}")
        if self._need_pages(req) > max(self.pool.shares):
            raise ValueError(
                f"request {req.rid} needs {self._need_pages(req)} pages "
                f"> largest group share {max(self.pool.shares)} — it could "
                f"never admit (FIFO would deadlock behind it)")
        self.queue.append(req)

    # -- scheduling ticks -----------------------------------------------------

    def _admit(self):
        """Strict FIFO: the queue head admits as soon as ANY free slot's
        group can reserve its worst-case pages; nothing overtakes it
        (head-of-line blocking is what makes FIFO starvation-free)."""
        while self.queue and self.free:
            req = self.queue[0]
            need = self._need_pages(req)
            slot = None
            for s in reversed(self.free):        # lowest slot id first
                if self.pool.try_reserve(need, self.groups[s]):
                    slot = s
                    break
            if slot is None:
                return
            self.queue.popleft()
            self.free.remove(slot)
            self.cache = lm.reset_slot(self.cfg, self.cache, slot)
            st = _PagedSlot(req, self.groups[slot], self._order, need)
            self._order += 1
            self.admissions += 1
            self.admission_log.append(req.rid)
            self.table[slot, :] = 0
            self.slots[slot] = st

    def _ensure_pages(self, slot: int, st: _PagedSlot, length: int):
        """Back every position below ``length`` with a physical page,
        drawing from the request's admission reservation: a chunk's worth
        at once before a prefill tick (the bulk grant), one page at a
        decode boundary. Granting at use (not all at admission) is what
        lets window reclamation bound an SWA request's live pages below
        its total page count."""
        while (length - 1) // self.page_size >= len(st.pages):
            st.pages.append(self.pool.alloc(st.group))
            self.table[slot, len(st.pages) - 1] = st.pages[-1]

    def _reclaim(self, slot: int, st: _PagedSlot):
        """Release pages wholly behind the attention window: logical page
        ``j`` is dead once ``(j+1) * page_size <= length - window`` (the
        next read starts at ``length + 1 - window``, so this is
        conservative). The table entry drops to the sink; attention masks
        the positions regardless, so a reused page's new contents are
        never observable."""
        if self.reclaim_window is None:
            return
        dead = (st.length - self.reclaim_window) // self.page_size
        while st.reclaimed < dead:
            j = st.reclaimed
            self.pool.release([st.pages[j]], st.group)
            st.pages[j] = 0
            self.table[slot, j] = 0
            st.reclaimed += 1

    def _finish(self, slot: int, st: _PagedSlot, done: list):
        done.append(st.req)
        self.pool.release([p for p in st.pages if p != 0], st.group,
                          unused_reserved=st.need - len(st.pages))
        self.table[slot, :] = 0
        self.slots[slot] = None
        self.free.append(slot)

    def _prefill_tick(self, done: list) -> bool:
        """One chunk of the FIFO-oldest prefilling request."""
        cand = [(st.order, slot, st) for slot, st in enumerate(self.slots)
                if st is not None and st.pos < len(st.req.prompt)]
        if not cand:
            return False
        _, slot, st = min(cand)
        n = min(self.prefill_chunk, len(st.req.prompt) - st.pos)
        self._ensure_pages(slot, st, st.length + n)
        toks = np.zeros((self.prefill_chunk,), np.int32)
        toks[:n] = st.req.prompt[st.pos: st.pos + n]
        # .copy(): self.table is a persistent host buffer the scheduler
        # mutates (reclaim, grants) while steps are still in flight — CPU
        # jax aliases numpy inputs zero-copy, so an async read of the live
        # buffer could observe a FUTURE table state (a real, hash-seed-
        # timing-dependent token corruption caught by the parity tests).
        last, self.cache = self.prefill_step(
            self.params, jnp.asarray(toks), jnp.int32(n), jnp.int32(slot),
            jnp.asarray(self.table[slot].copy()), self.cache,
        )
        st.pos += n
        st.length += n
        self._reclaim(slot, st)
        if st.pos == len(st.req.prompt):
            st.req.out.append(int(np.argmax(np.asarray(last))))
            if len(st.req.out) >= st.req.max_new:
                self._finish(slot, st, done)
        return True

    def _decode_tick(self, done: list) -> bool:
        """One decode macro-step over every slot past prefill."""
        dec = [(slot, st) for slot, st in enumerate(self.slots)
               if st is not None and st.pos >= len(st.req.prompt)]
        if not dec:
            return False
        tokens = np.zeros((self.num_slots, 1), np.int32)
        active = np.zeros((self.num_slots,), bool)
        for slot, st in dec:
            self._ensure_pages(slot, st, st.length + 1)
            tokens[slot, 0] = st.req.out[-1]
            active[slot] = True
        t0 = time.perf_counter()
        logits, self.cache = self.serve_step(
            self.params,
            {"tokens": jnp.asarray(tokens),
             # .copy() — see _prefill_tick: the live table buffer must not
             # be aliased by an asynchronously-executing step
             "page_table": jnp.asarray(self.table.copy()),
             "active": jnp.asarray(active)},
            self.cache,
        )
        nxt = _greedy(logits)
        self.decode_times_s.append(time.perf_counter() - t0)
        for slot, st in dec:
            st.length += 1
            st.req.out.append(int(nxt[slot]))
            self._reclaim(slot, st)
            if len(st.req.out) >= st.req.max_new:
                self._finish(slot, st, done)
        return True

    def run(self, max_steps: int = 100000) -> list[Request]:
        done: list[Request] = []
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self._admit()
            advanced = self._prefill_tick(done)
            advanced |= self._decode_tick(done)
            if not advanced and not self.queue:
                break
            steps += 1
        return done

    def stats(self) -> dict:
        return {**self.pool.stats(), "admissions": self.admissions}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged KV pool (DESIGN.md §7) "
                         "instead of the dense (slots, max_seq) rectangle")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages", type=int, default=0,
                    help="shared pool size incl. the sink page "
                         "(0 -> slots * ceil(max_seq/page)/2 + 1: half the "
                         "dense rectangle, the paged engine's whole point)")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--mode", default="auto",
                    choices=["hybrid", "model_centric", "data_centric",
                             "auto", "ep"],
                    help="parallel mode; 'auto' (default) lets each MoE "
                         "layer pick data-/model-centric dispatch from the "
                         "roofline — decode steps (few tokens) resolve "
                         "model-centric, large prefills data-centric")
    ap.add_argument("--cache-layers", type=int, default=0,
                    help="pipeline-shared prefetch cache residency bound "
                         "(gathered MoE periods) for the decode forward; "
                         ">0 unrolls the layer loop")
    ap.add_argument("--hetero-latencies", default=None,
                    help="comma-separated t_i per batch-group member: an "
                         "Eq. 1 plan — uneven SLOT shares for the dense "
                         "server, uneven PAGE-POOL shares for --paged "
                         "(DESIGN.md §6/§7). Requires --mesh for dense")
    ap.add_argument("--hetero-tp-latencies", default=None,
                    help="comma-separated t_i per TP-group member: Eq. 2 "
                         "uneven hidden tiles")
    ap.add_argument("--quant", default="none",
                    choices=["none", "int8", "fp8"],
                    help="quantize expert weights to block-wise int8/fp8 "
                         "payloads served through the fused-dequant ES "
                         "kernels (DESIGN.md §8)")
    ap.add_argument("--kv-quant", default="none", choices=["none", "int8"],
                    help="store paged-KV pages as int8 + per-row scales — "
                         "smaller pages, more admitted requests per HBM "
                         "byte (--paged only, DESIGN.md §8)")
    args = ap.parse_args(argv)
    if args.kv_quant != "none" and not args.paged:
        ap.error("--kv-quant requires --paged")

    cfg = (cfglib.get_smoke_config(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(dims, ("pod", "data", "model")[-len(dims):])

    plan = None
    num_slots, valid_slots = args.slots, None
    if args.hetero_latencies:
        if mesh is None and not args.paged:
            ap.error("--hetero-latencies requires --mesh (dense server)")
        tok_lat = tuple(float(t) for t in args.hetero_latencies.split(","))
        tp_lat = (tuple(float(t) for t in args.hetero_tp_latencies.split(","))
                  if args.hetero_tp_latencies else None)
        plan = hetero_lib.make_hetero_plan(
            tok_lat,
            global_batch=args.slots,
            hidden_size=(cfg.moe.d_ff
                         if tp_lat is not None and cfg.moe is not None
                         else None),
            tp_latencies=tp_lat,
        )
        if args.paged:
            print(f"[serve] hetero plan: page-pool shares proportional to "
                  f"{plan.token_counts} (all {num_slots} slots schedulable)")
        else:
            # Dense: padded slot layout, device i's Eq. 1 share schedulable.
            cap = plan.batch_capacity
            num_slots = len(plan.token_counts) * cap
            valid_slots = [i * cap + j
                           for i, c in enumerate(plan.token_counts)
                           for j in range(c)]
            print(f"[serve] hetero plan: slot shares {plan.token_counts} "
                  f"({num_slots} padded slots), hidden {plan.hidden_splits}")

    pcfg = ParallelConfig(
        mode=args.mode, blk=16,
        cache_layers=args.cache_layers,
        scan_layers=args.cache_layers <= 0,
        hetero_plan=plan,
        # auto-mode roofline prices the served weight width (the island
        # itself skips QAT fake-quant when the params carry true payloads)
        quant=args.quant,
    )

    params, specs = split_tree(
        lm.init_params(jax.random.PRNGKey(0), cfg, plan=plan))
    if args.quant != "none":
        if mesh is not None:
            ap.error("--quant serves whole-expert int8/fp8 payloads; "
                     "combine with --mesh is not supported (the scales "
                     "do not shard congruently)")
        from repro.quant import quantize_lm_params

        before = tree_bytes(params)
        params = quantize_lm_params(params, cfg, mode=args.quant)
        print(f"[serve] expert weights -> {args.quant}: params "
              f"{before / 1e6:.1f}MB -> {tree_bytes(params) / 1e6:.1f}MB")
    if mesh is not None:
        params = jax.tree.map(
            jax.device_put, params, tree_shardings(params, specs, pcfg, mesh)
        )
    if args.paged:
        pages = args.pages or (
            num_slots * cdiv(args.max_seq, args.page_size) // 2 + 1)
        server = PagedServer(
            cfg, pcfg, mesh, num_slots=num_slots,
            page_size=args.page_size, num_pages=pages,
            max_pages_per_slot=cdiv(args.max_seq, args.page_size),
            params=params, prefill_chunk=args.prefill_chunk, plan=plan,
            kv_quant=args.kv_quant,
        )
    else:
        server = BatchedServer(cfg, pcfg, mesh, num_slots=num_slots,
                               max_seq=args.max_seq, params=params,
                               valid_slots=valid_slots)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        server.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
            max_new=args.max_new,
        ))
    t0 = time.time()
    done = server.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / max(dt, 1e-9):.1f} tok/s)")
    if server.decode_times_s:
        ts = np.asarray(server.decode_times_s[1:] or server.decode_times_s)
        print(f"[serve] measured decode step: median "
              f"{np.median(ts) * 1e3:.1f}ms p90 "
              f"{np.percentile(ts, 90) * 1e3:.1f}ms over {len(ts)} steps")
    if args.paged:
        st = server.stats()
        print(f"[serve] page pool: {st['peak_in_use_pages']} peak pages "
              f"({st['peak_in_use_bytes'] / 1024:.1f} KiB KV resident) of "
              f"{st['num_pages'] - 1} allocatable; "
              f"{st['total_allocs']} allocs, leak-free={st['free_pages'] == st['num_pages'] - 1}")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")
    return done


if __name__ == "__main__":
    main()
