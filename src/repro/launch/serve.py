"""Serving driver: prefill + batched decode with a static-shape request
queue (continuous-batching lite: finished slots are refilled between decode
macro-steps so the jitted step shape never changes).

Heterogeneous serving (paper §4.4, DESIGN.md §6): ``--hetero-latencies``
builds an Eq. 1 plan over the decode slot dim — each data-group member
serves its proportional share of slots, the padded tail slots are masked in
the MoE islands and never scheduled; ``--hetero-tp-latencies`` adds the
Eq. 2 uneven hidden tiles.
"""
from __future__ import annotations

import argparse
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.core import hetero as hetero_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.parallel.sharding import ParallelConfig, split_tree, tree_shardings


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S_prompt,)
    max_new: int
    out: list = field(default_factory=list)


class BatchedServer:
    """Fixed-slot decode server. Slots hold independent sequences; the
    cache is one pytree with a batch dim == num_slots."""

    def __init__(self, cfg, pcfg, mesh, *, num_slots: int, max_seq: int,
                 params, seed: int = 0,
                 valid_slots: Optional[list] = None):
        self.cfg, self.pcfg, self.mesh = cfg, pcfg, mesh
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.params = params
        self.cache = lm.init_cache(cfg, num_slots, max_seq)
        shape3 = (num_slots, 1, cfg.d_model)
        self.serve_step = jax.jit(
            steps_lib.make_serve_step(cfg, pcfg, mesh, shape3)
        )
        self.active: dict[int, Request] = {}
        self.queue: deque[Request] = deque()
        self.slot_tokens = np.zeros((num_slots, 1), np.int32)
        # Heterogeneous plan (DESIGN.md §6): only each device's Eq. 1 share
        # of slots is schedulable; padded tail slots stay permanently free
        # and are masked inside the MoE islands.
        self.free = (list(valid_slots) if valid_slots is not None
                     else list(range(num_slots)))
        self.decode_times_s: list = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_one(self, slot: int, req: Request):
        """Prefill a single slot by decoding its prompt token by token
        (simple and shape-stable; a production server would use a bucketed
        prefill step — launch.steps.make_prefill_step — per length)."""
        # reset the slot: stale cache beyond len is masked by decode attn
        self.cache["len"] = self.cache["len"].at[slot].set(0)
        for tok in req.prompt:
            self.slot_tokens[slot, 0] = tok
            self._decode_step()
        self.active[slot] = req

    def _decode_step(self):
        t0 = time.perf_counter()
        logits, self.cache = self.serve_step(
            self.params, {"tokens": jnp.asarray(self.slot_tokens)}, self.cache
        )
        out = np.asarray(jnp.argmax(logits[..., -1, :], axis=-1)).reshape(-1)
        self.decode_times_s.append(time.perf_counter() - t0)
        return out

    def run(self, max_steps: int = 1000) -> list[Request]:
        done = []
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            # fill free slots
            while self.free and self.queue:
                slot = self.free.pop()
                req = self.queue.popleft()
                self._prefill_one(slot, req)
            nxt = self._decode_step()
            steps += 1
            for slot, req in list(self.active.items()):
                req.out.append(int(nxt[slot]))
                if len(req.out) >= req.max_new:
                    done.append(req)
                    del self.active[slot]
                    self.free.append(slot)
        return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--mode", default="auto",
                    choices=["hybrid", "model_centric", "data_centric",
                             "auto", "ep"],
                    help="parallel mode; 'auto' (default) lets each MoE "
                         "layer pick data-/model-centric dispatch from the "
                         "roofline — decode steps (few tokens) resolve "
                         "model-centric, large prefills data-centric")
    ap.add_argument("--cache-layers", type=int, default=0,
                    help="pipeline-shared prefetch cache residency bound "
                         "(gathered MoE periods) for the decode forward; "
                         ">0 unrolls the layer loop")
    ap.add_argument("--hetero-latencies", default=None,
                    help="comma-separated t_i per batch-group member: serve "
                         "an Eq. 1 uneven slot split (DESIGN.md §6). "
                         "Requires --mesh")
    ap.add_argument("--hetero-tp-latencies", default=None,
                    help="comma-separated t_i per TP-group member: Eq. 2 "
                         "uneven hidden tiles")
    args = ap.parse_args(argv)

    cfg = (cfglib.get_smoke_config(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(dims, ("pod", "data", "model")[-len(dims):])

    plan = None
    num_slots, valid_slots = args.slots, None
    if args.hetero_latencies:
        if mesh is None:
            ap.error("--hetero-latencies requires --mesh")
        tok_lat = tuple(float(t) for t in args.hetero_latencies.split(","))
        tp_lat = (tuple(float(t) for t in args.hetero_tp_latencies.split(","))
                  if args.hetero_tp_latencies else None)
        plan = hetero_lib.make_hetero_plan(
            tok_lat,
            global_batch=args.slots,
            hidden_size=(cfg.moe.d_ff
                         if tp_lat is not None and cfg.moe is not None
                         else None),
            tp_latencies=tp_lat,
        )
        # Padded slot layout: device i's chunk holds capacity slots, only
        # its Eq. 1 share schedulable (tail slots masked in the islands).
        cap = plan.batch_capacity
        num_slots = len(plan.token_counts) * cap
        valid_slots = [i * cap + j for i, c in enumerate(plan.token_counts)
                       for j in range(c)]
        print(f"[serve] hetero plan: slot shares {plan.token_counts} "
              f"({num_slots} padded slots), hidden {plan.hidden_splits}")

    pcfg = ParallelConfig(
        mode=args.mode, blk=16,
        cache_layers=args.cache_layers,
        scan_layers=args.cache_layers <= 0,
        hetero_plan=plan,
    )

    params, specs = split_tree(
        lm.init_params(jax.random.PRNGKey(0), cfg, plan=plan))
    if mesh is not None:
        params = jax.tree.map(
            jax.device_put, params, tree_shardings(params, specs, pcfg, mesh)
        )
    server = BatchedServer(cfg, pcfg, mesh, num_slots=num_slots,
                           max_seq=args.max_seq, params=params,
                           valid_slots=valid_slots)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        server.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
            max_new=args.max_new,
        ))
    t0 = time.time()
    done = server.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / max(dt, 1e-9):.1f} tok/s)")
    if server.decode_times_s:
        ts = np.asarray(server.decode_times_s[1:] or server.decode_times_s)
        print(f"[serve] measured decode step: median "
              f"{np.median(ts) * 1e3:.1f}ms p90 "
              f"{np.percentile(ts, 90) * 1e3:.1f}ms over {len(ts)} steps")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")
    return done


if __name__ == "__main__":
    main()
