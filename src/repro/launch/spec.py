"""Speculative decoding on the paged engine (DESIGN.md §11).

Decode is memory-bound: a macro-step moves the whole weight set (and the
slot's KV pages) to emit ONE token per slot. Verifying ``k`` drafted
tokens in a single chunk-extension paged forward
(``launch.steps.make_paged_score_step``) amortizes that same traffic over
up to ``k + 1`` committed tokens — the classic speculative-decoding win,
priced by ``parallel.autotune.spec_decode_speedup``.

The acceptance rule here is **exact-match replay**, not
distribution-preserving rejection sampling: each verify row ``i`` is the
logits a sequential decode would have produced at that position, the
engine samples from it with the standard ``launch.serve.next_token``
(keys derive only from ``(seed, len(out))``, and accepted tokens are
appended before the next row is sampled, so the keys advance exactly as
in the non-speculative engine), and drafting continues only while the
sampled token equals the drafted one. Accepted streams are therefore
**token-identical** to the non-speculative paged engine — and to the
batch-1 dense reference — for greedy AND seeded-temperature requests
(tests/test_serve_parity.py pins the matrix); the draft only ever decides
how many sequential steps collapse into one forward, never which tokens
come out.

Rejection rolls back by truncation only: ``PagedServer._rollback``
shrinks the slot's device ``len`` (paged attention masks every row past
it), returns now-unbacked tail pages to the request's own admission
reservation (``PagePool.rollback`` — never to the free budget, and never
a refcount>1 prefix-shared page), and the sampling key re-derives itself
because rejected tokens were never appended to ``out``.

Recurrent stacks (mamba/xlstm hybrids) cannot rewind: their per-slot
state advances token-wise through ``_make_paged_prefill_scan`` and
truncation would silently decode from a poisoned state — ``SpecDecoder``
refuses them loudly at construction.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.launch import steps as steps_lib
from repro.launch.serve import argmax_token, next_token
from repro.models import lm
from repro.runtime import faults as faults_lib


class NGramDrafter:
    """Self-speculative n-gram drafting from the request's own history
    (DESIGN.md §11): find the most recent PRIOR occurrence of the
    trailing ``n``-gram in ``prompt + out`` and propose the tokens that
    followed it. No draft model, no extra memory traffic — it exploits
    the repetitiveness of real decode streams (templated boilerplate,
    code, retrieval-stuffed contexts, greedy cycles). An empty draft
    degrades the verify round to a plain one-token decode through the
    same score step."""

    def __init__(self, n: int = 3):
        if n < 1:
            raise ValueError(f"n-gram order must be >= 1, got {n}")
        self.n = n

    def draft(self, history: np.ndarray, k: int, rid: int = -1) -> list:
        """Propose up to ``k`` continuation tokens after ``history``,
        longest-matching-suffix first (order ``n`` down to 1); ``[]`` when
        no prior occurrence exists. Among occurrences of the same order
        the MOST RECENT one with a full ``k``-token continuation wins;
        near the end of history (where recent occurrences' continuations
        are cut short) the longest available continuation is proposed
        instead — on a cyclic stream that is the difference between
        drafting 1 token and drafting ``k``."""
        h = np.asarray(history)
        if k <= 0:
            return []
        for n in range(min(self.n, len(h) - 1), 0, -1):
            pat = h[-n:]
            best: list = []
            # scan most-recent-first; a full-k continuation returns
            # immediately, otherwise remember the longest seen
            for i in range(len(h) - n - 1, -1, -1):
                if np.array_equal(h[i:i + n], pat):
                    cont = h[i + n:i + n + k]
                    if len(cont) == k:
                        return [int(t) for t in cont]
                    if len(cont) > len(best):
                        best = [int(t) for t in cont]
            if best:
                return best
        return []


class ModelDrafter:
    """Draft-model drafting: a small dense-cache model (reusing the
    existing configs, e.g. ``gemma_2b`` drafting for a MoE target) greedily
    proposes ``k`` tokens per verify round (DESIGN.md §11).

    Per request it keeps a batch-1 dense cache: each ``draft`` call first
    catches the cache up on the tokens the target accepted since the last
    round, then decodes ``k`` greedy tokens (``argmax_token`` — the same
    convention as the target, so a deterministic draft of the same config
    reaches 100% acceptance under greedy), and finally truncates its
    ``len`` back to the committed history so rejected draft rows vanish
    exactly like the target's rollback. That truncation is why only
    all-attention, non-windowed draft configs are accepted: rolling-buffer
    local-attention caches and recurrent states cannot rewind."""

    def __init__(self, cfg, pcfg, mesh, params, *, max_seq: int):
        if any(cfg.layer_kind(i) != "attn" for i in range(cfg.num_layers)):
            raise ValueError(
                "ModelDrafter requires an all-attention draft config: "
                "recurrent draft state cannot rewind past rejected drafts")
        if cfg.window > 0 and any(cfg.attn_kind(i) == "local"
                                  for i in range(cfg.num_layers)):
            raise ValueError(
                "ModelDrafter requires a non-windowed draft config: the "
                "rolling local-attention cache cannot truncate safely")
        if cfg.num_codebooks > 1:
            raise ValueError("ModelDrafter does not support codebook heads")
        self.cfg, self.pcfg, self.mesh = cfg, pcfg, mesh
        self.params = params
        self.max_seq = max_seq
        self.step = jax.jit(steps_lib.make_serve_step(
            cfg, pcfg, mesh, (1, 1, cfg.d_model)))
        self._state: dict = {}   # rid -> [cache, resident_len]

    def _feed(self, cache, tok: int):
        logits, cache = self.step(
            self.params, {"tokens": jnp.asarray([[tok]], jnp.int32)}, cache)
        return logits, cache

    def draft(self, history: np.ndarray, k: int, rid: int = -1) -> list:
        """Catch the request's draft cache up on ``history`` and greedily
        decode up to ``k`` proposal tokens (empty when the draft cache
        cannot hold them)."""
        hist = np.asarray(history)
        k = min(k, self.max_seq - len(hist))
        if k <= 0:
            return []
        if rid not in self._state:
            self._state[rid] = [lm.init_cache(self.cfg, 1, self.max_seq), 0]
        cache, resident = self._state[rid]
        logits = None
        for tok in hist[resident:]:
            logits, cache = self._feed(cache, int(tok))
        draft = [argmax_token(logits[0, -1])]
        for _ in range(k - 1):
            logits, cache = self._feed(cache, draft[-1])
            draft.append(argmax_token(logits[0, -1]))
        # truncate the draft rows: next round's catch-up re-feeds from the
        # committed history, whatever the target accepted
        cache = {"layers": cache["layers"],
                 "len": cache["len"].at[0].set(jnp.int32(len(hist)))}
        self._state[rid] = [cache, len(hist)]
        return draft

    def drop(self, rid: int) -> None:
        """Free the per-request draft cache (finish/abort/preempt)."""
        self._state.pop(rid, None)


class SpecDecoder:
    """Drive speculative draft/verify rounds on a ``PagedServer``
    (DESIGN.md §11). Constructing one attaches it to the server
    (``server.spec``); ``PagedServer._decode_tick`` then delegates whole
    decode ticks here. Each round, per decode-capable slot:

    1. ask the drafter for up to ``k`` tokens after ``prompt + out``
       (capped so the round can never write past the admitted worst-case
       length);
    2. score ``[out[-1]] + draft`` in ONE chunk-extension paged forward
       (``make_paged_score_step``) — pages granted from the slot's
       reservation exactly like a decode boundary;
    3. sample each row with ``next_token`` (appending as it goes, so keys
       advance exactly like sequential decode) while the sample equals
       the draft;
    4. roll rejected rows back by truncation (``PagedServer._rollback``)
       and only then window-reclaim at the committed length.

    Refuses hybrid (recurrent) stacks at construction: their token-wise
    state advance cannot be rewound by page-table truncation, and a
    silent wrong-state decode is the failure mode this guard kills."""

    def __init__(self, server, drafter, k: int = 4):
        cfg = server.cfg
        if any(cfg.layer_kind(i) != "attn" for i in range(cfg.num_layers)):
            raise ValueError(
                "speculative decoding requires an all-attention stack: "
                "recurrent layers advance per-slot state token-wise "
                "(the scan prefill path), which page-table truncation "
                "cannot rewind — rollback would silently decode from a "
                "wrong state")
        if cfg.num_codebooks > 1:
            raise ValueError(
                "speculative decoding does not support codebook heads")
        if k < 1:
            raise ValueError(f"draft length k must be >= 1, got {k}")
        self.server = server
        self.drafter = drafter
        self.k = k
        self.chunk = k + 1
        self.rounds = 0
        self.drafted = 0            # draft tokens scored
        self.accepted_drafts = 0    # draft tokens that matched the sample
        self.rollback_tokens = 0    # speculative rows truncated away
        self._score_step = None
        server.spec = self
        obs.maybe_register(self)

    def obs_metrics(self) -> dict:
        """Speculative counters for registry snapshot polling."""
        return {
            "repro_spec_rounds_total": self.rounds,
            "repro_spec_drafted_tokens_total": self.drafted,
            "repro_spec_accepted_drafts_total": self.accepted_drafts,
            "repro_spec_rollback_tokens_total": self.rollback_tokens,
            "repro_spec_acceptance_rate": self.acceptance_rate(),
        }

    def reset_steps(self) -> None:
        """Drop the jitted score step (engine re-jit recovery path)."""
        self._score_step = None

    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verify sampler accepted."""
        return self.accepted_drafts / max(self.drafted, 1)

    def stats(self) -> dict:
        """Counters for benches/CLI: rounds, drafted, accepted, rate."""
        return {
            "rounds": self.rounds,
            "drafted": self.drafted,
            "accepted_drafts": self.accepted_drafts,
            "rollback_tokens": self.rollback_tokens,
            "acceptance_rate": self.acceptance_rate(),
        }

    def _step(self):
        if self._score_step is None:
            srv = self.server
            self._score_step = jax.jit(steps_lib.make_paged_score_step(
                srv.cfg, srv.pcfg, srv.mesh, srv.page_size))
        return self._score_step

    def decode_tick(self, done: list) -> bool:
        """One speculative round over every decode-capable slot — the
        drop-in replacement for ``PagedServer._decode_tick``'s macro-step
        (same fault sites, same NaN watchdog, same trace/timing hooks)."""
        srv = self.server
        dec = [(slot, st) for slot, st in enumerate(srv.slots)
               if st is not None and st.pos >= len(st.req.prompt)
               and srv.roles[slot] != "prefill"]
        if not dec:
            return False
        faults_lib.inject("serve.decode")
        step = self._step()
        t0 = time.perf_counter()
        for slot, st in dec:
            self._verify_round(slot, st, step, done)
        srv.decode_times_s.append(time.perf_counter() - t0)
        return True

    def _verify_round(self, slot, st, step, done) -> int:
        srv = self.server
        req = st.req
        # cap the draft so the round's rows stay inside the admitted
        # worst-case length (prompt + max_new - 1 cache rows): budget-1
        # drafts at most, since row 0 is always the pending fed-back token
        budget = req.max_new - len(req.out)
        draft: list = []
        if budget > 1:
            history = np.concatenate(
                [np.asarray(req.prompt, np.int64),
                 np.asarray(req.out, np.int64)])
            draft = [int(t) for t in
                     self.drafter.draft(history, min(self.k, budget - 1),
                                        req.rid)][:budget - 1]
        n_valid = 1 + len(draft)
        self.drafted += len(draft)
        srv._ensure_pages(slot, st, st.length + n_valid)
        toks = np.zeros((self.chunk,), np.int32)
        toks[0] = req.out[-1]
        toks[1:n_valid] = draft
        with obs.tracer.span("serve.spec_verify", rid=req.rid, slot=slot,
                             n_valid=n_valid):
            logits, srv.cache = srv._unpack_step(step(
                srv.params, jnp.asarray(toks), jnp.int32(n_valid),
                jnp.int32(slot),
                # .copy() — see _prefill_tick: the live table buffer must
                # not be aliased by an asynchronously-executing step
                jnp.asarray(srv.table[slot].copy()), srv.cache))
        st.length += n_valid
        rows = np.array(logits, np.float32)   # owned: faults may poison
        for f in faults_lib.inject("serve.logits"):
            if f.kind == "nan" and int(f.payload.get("slot", slot)) == slot:
                rows[:] = np.nan
        if not np.all(np.isfinite(rows[:n_valid])):
            srv._abort_slot(slot, reason="non-finite verify logits")
            return 0
        accepted = 0
        finished = False
        for i in range(n_valid):
            tok = next_token(rows[i], req)
            req.out.append(tok)
            obs.tracer.instant("serve.token", rid=req.rid)
            accepted = i + 1
            if len(req.out) >= req.max_new:
                finished = True
                break
            if i < len(draft) and tok != draft[i]:
                break   # first mismatch: the sampled token is the
                        # correction, everything past it is speculation
        self.rounds += 1
        self.accepted_drafts += accepted - 1
        srv._event("spec_verify", req.rid, slot, n_valid, accepted)
        if finished:
            srv._finish(slot, st, done)
            return accepted
        n_reject = n_valid - accepted
        self.rollback_tokens += n_reject
        srv._rollback(slot, n_reject)
        # window reclamation only ever sees COMMITTED lengths: reclaiming
        # at the speculative length could free pages the rolled-back
        # window still reads (the _rollback assert pins the ordering)
        srv._reclaim(slot, st)
        return accepted

    def forget(self, rid: int) -> None:
        """Drop per-request drafter state (finish/abort/preempt paths —
        the server calls this from ``_finish``/``_release_slot``)."""
        drop = getattr(self.drafter, "drop", None)
        if drop is not None:
            drop(rid)
