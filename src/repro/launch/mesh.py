"""Production mesh construction.

All functions (never module-level constants) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def split_model_axis(shape, axes, node_size: int):
    """Split the "model" extent into ("node", "model") for a two-level
    interconnect (DESIGN.md §10).

    A TP group spanning multiple nodes becomes node-major: the "node" axis
    extent is the number of nodes (model_extent / node_size) and the inner
    "model" extent is node_size, so the flattened rank order — and every
    gather's concatenation order — matches the flat mesh exactly. A TP
    group that fits inside one node (node_size >= extent), or whose extent
    node_size does not divide, is left flat (the single-level schedule)."""
    shape, axes = tuple(shape), tuple(axes)
    if "model" not in axes or node_size < 1:
        return shape, axes
    i = axes.index("model")
    m = shape[i]
    if node_size >= m or m % node_size != 0:
        return shape, axes
    return (
        shape[:i] + (m // node_size, node_size) + shape[i + 1:],
        axes[:i] + ("node", "model") + axes[i + 1:],
    )


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (1, 1) on one CPU).

    axis_types= only exists on newer jax; older releases (0.4.3x) default to
    Auto axes anyway, so fall back to the plain call there."""
    try:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(tuple(shape), tuple(axes))
