"""Production mesh construction.

All functions (never module-level constants) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (1, 1) on one CPU).

    axis_types= only exists on newer jax; older releases (0.4.3x) default to
    Auto axes anyway, so fall back to the plain call there."""
    try:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(tuple(shape), tuple(axes))
