"""Multi-pod dry-run driver: compile (not execute) the paper-scale
training/decode cells on a host-platform device farm, reporting per-cell
parallel-config choices, HLO collective counts, and memory estimates."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# (The two lines above are required verbatim by the multi-pod dry-run spec.)

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402

if __name__ == "__main__" and "--devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro import configs as cfglib                         # noqa: E402
from repro.configs.base import LONG_CONTEXT_ARCHS, SHAPES   # noqa: E402
from repro.launch import inputs as inputs_lib               # noqa: E402
from repro.launch import steps as steps_lib                 # noqa: E402
from repro.launch.mesh import make_mesh, make_production_mesh  # noqa: E402
from repro.optim import adamw                               # noqa: E402
from repro.parallel.sharding import ParallelConfig          # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

No arrays are ever allocated: parameters, optimizer state, batches and
caches are ShapeDtypeStructs with NamedShardings. ``compile()`` proving the
sharding is coherent (no mismatch, no unsupported collective) and
``memory_analysis()`` proving it fits are the deliverable; cost/collective
numbers feed EXPERIMENTS.md §Roofline.
"""

# v5e hardware model (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s/link ICI

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\("
)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2, "f8e4m3": 1,
    "f8e5m2": 1,
}


def parse_collectives(hlo_text: str, loop_multipliers: dict) -> dict:
    """Sum shard-local collective bytes from the partitioned HLO.

    Collectives inside while-loop bodies are multiplied by the loop's trip
    count (the layer scan); outside they count once. Wire model: all-reduce
    2x (reduce + broadcast phases), others 1x the result bytes.
    """
    per_op: dict = {}
    current_comp = "<main>"
    mult = 1
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("%" in stripped or stripped.startswith("ENTRY")):
            header = stripped.split("(")[0]
            current_comp = header.replace("%", "").strip()
            mult = 1
            for key, m in loop_multipliers.items():
                if key in current_comp:
                    mult = m
                    break
        m = COLLECTIVE_RE.search(stripped)
        if not m or "=" not in stripped:
            continue
        kind = m.group(1)
        lhs = stripped.split("=", 1)[1]
        sm = SHAPE_RE.search(lhs)
        if sm is None:
            continue
        dtype, dims = sm.group(1), sm.group(2)
        if dtype == "tuple" or dtype not in DTYPE_BYTES:
            # tuple results: sum every shape in the tuple
            total = 0
            for dt, ds in SHAPE_RE.findall(lhs.split(kind)[0]):
                if dt in DTYPE_BYTES:
                    n = int(np.prod([int(x) for x in ds.split(",") if x])) if ds else 1
                    total += n * DTYPE_BYTES[dt]
            size = total
        else:
            n = int(np.prod([int(x) for x in dims.split(",") if x])) if dims else 1
            size = n * DTYPE_BYTES[dtype]
        factor = 2 if kind == "all-reduce" else 1
        rec = per_op.setdefault(kind, {"bytes": 0, "count": 0})
        rec["bytes"] += size * factor * mult
        rec["count"] += mult
    return per_op


def find_loop_multipliers(hlo_text: str, n_periods: int) -> dict:
    """Map while-body computation names -> trip count. The layer scan (and
    its transpose in backward) dominates; inner scans carry no collectives,
    so attributing every while body the scan trip count is exact for our
    programs (verified against unrolled small configs in tests)."""
    mults = {}
    for m in re.finditer(r"%?(body[\w.\-]*|while_body[\w.\-]*)\s*\(", hlo_text):
        mults[m.group(1)] = n_periods
    return mults


def default_pcfg(cfg, shape, args) -> ParallelConfig:
    """Pick the per-cell parallel mode/layout the way the paper's runtime
    would: decode prefers model-centric when one TP shard fits HBM."""
    blk = 128  # MXU-aligned; padding <= E*(blk-1) stays <5% for all cells
    mode = args.mode
    if mode == "auto":
        if shape.kind == "decode":
            # replicate over data only if one TP shard of params fits HBM
            pbytes = cfg.param_count() * 2
            mode = "model_centric" if pbytes / 16 <= 8e9 else "hybrid"
        else:
            mode = "hybrid"
    # Layers are UNROLLED by default for the roofline cells: XLA's
    # cost_analysis does not multiply while-body FLOPs by trip count, so a
    # scanned program under-reports compute ~n_periods-fold. Unrolling makes
    # flops/bytes/collectives exact; --scan keeps the HLO small (multi-pod
    # pass/fail cells, big compile jobs).
    unroll = not args.scan
    return ParallelConfig(
        mode=mode,
        collective_schedule=args.schedule,
        cache_policy=args.cache_policy,
        remat="block",
        blk=blk if shape.kind != "decode" else 8,
        impl="blocked",
        scan_layers=not unroll,
    )


def default_opt_cfg(cfg, n_chips) -> adamw.OptimizerConfig:
    """Optimizer precision by memory pressure: bf16 state once fp32
    master + moments would exceed the per-chip HBM budget."""
    pbytes14 = cfg.param_count() * 14
    if pbytes14 / n_chips > 12e9:
        return adamw.OptimizerConfig(state_dtype="bfloat16", master_fp32=False)
    return adamw.OptimizerConfig(state_dtype="float32", master_fp32=True)


def _lower_one(cfg, shape, pcfg, opt_cfg, mesh):
    """Lower + compile one step program; returns (compiled, t_lower, t_comp)."""
    t0 = time.time()
    abstract_params, _, _ = steps_lib.sharded_params(cfg, pcfg, mesh)
    batch = inputs_lib.input_specs(cfg, shape, pcfg, mesh)
    if shape.kind == "train":
        shape3 = (shape.global_batch, shape.seq_len, cfg.d_model)
        opt_state = steps_lib.sharded_opt_state(abstract_params, opt_cfg, mesh)
        step_fn = steps_lib.make_train_step(cfg, pcfg, mesh, opt_cfg, shape3)
        with mesh:
            lowered = jax.jit(step_fn).lower(abstract_params, opt_state, batch)
    elif shape.kind == "prefill":
        shape3 = (shape.global_batch, shape.seq_len, cfg.d_model)
        cache = inputs_lib.cache_specs(cfg, shape, pcfg, mesh)
        step_fn = steps_lib.make_prefill_step(cfg, pcfg, mesh, shape3)
        with mesh:
            lowered = jax.jit(step_fn).lower(abstract_params, batch, cache)
    else:
        shape3 = (shape.global_batch, 1, cfg.d_model)
        cache = inputs_lib.cache_specs(cfg, shape, pcfg, mesh)
        step_fn = steps_lib.make_serve_step(cfg, pcfg, mesh, shape3)
        with mesh:
            lowered = jax.jit(step_fn).lower(abstract_params, batch, cache)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    return compiled, t_lower, time.time() - t0


def _extract(compiled):
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    colls = parse_collectives(hlo, {})
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "colls": colls,
        "hlo": hlo,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, args) -> dict:
    """Compile one (arch, shape) cell on the virtual mesh and return its
    report row (mode, collectives, padding, memory estimates)."""
    import dataclasses

    cfg = cfglib.get_config(arch)
    shape = SHAPES[shape_name]
    canon = cfglib.canonical(arch)
    if args.layers_override:
        n = args.layers_override
        n = max(cfg.period, n - n % cfg.period)
        cfg = dataclasses.replace(cfg, num_layers=n)

    if shape_name == "long_500k" and canon not in LONG_CONTEXT_ARCHS:
        return {"status": "skipped",
                "reason": "pure full-attention arch; see DESIGN.md §4"}

    n_dev = len(jax.devices())
    if args.mesh_shape:
        dims = tuple(int(x) for x in args.mesh_shape.split(","))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = make_mesh(dims, axes)
    elif n_dev == 512:
        mesh = make_production_mesh(multi_pod=multi_pod)
        if not multi_pod:
            mesh = make_mesh((16, 16), ("data", "model"))
    else:  # debug pools
        if multi_pod:
            mesh = make_mesh((2, n_dev // 4, 2), ("pod", "data", "model"))
        else:
            mesh = make_mesh((n_dev // 2, 2), ("data", "model"))
    n_chips = int(np.prod(list(mesh.shape.values())))

    pcfg = default_pcfg(cfg, shape, args)
    opt_cfg = default_opt_cfg(cfg, n_chips)

    # Exact parameter counts from the abstract tree (not the config
    # heuristic): N and N_active for the §Roofline MODEL_FLOPS convention.
    from repro.common import tree_params
    abstract_params, _, _ = steps_lib.sharded_params(cfg, pcfg, mesh)
    n_total = tree_params(abstract_params)
    if cfg.moe is not None:
        n_moe_layers = sum(
            cfg.is_moe_layer(i) for i in range(cfg.num_layers)
        )
        n_mats = 3 if cfg.glu else 2
        inactive = (
            n_moe_layers
            * (cfg.moe.num_experts - cfg.moe.top_k)
            * n_mats * cfg.d_model * cfg.moe.d_ff
        )
        n_active = n_total - inactive
    else:
        n_active = n_total

    # COMPOSITE dry-run (see EXPERIMENTS.md §Dry-run methodology):
    #  (1) scan-over-layers compile -> memory_analysis. The while loop
    #      forces per-period buffer reuse, which is what a real memory-
    #      aware (TPU) schedule does; an unrolled CPU schedule hoists
    #      remat buffers and wildly overstates peak.
    #  (2) unrolled 1-period and 2-period compiles -> exact per-period
    #      FLOPs/bytes/collectives deltas, extrapolated linearly to full
    #      depth (layers are structurally identical across periods;
    #      XLA's cost_analysis does not multiply while-body costs).
    n_periods = cfg.num_layers // cfg.period
    pcfg_scan = dataclasses.replace(pcfg, scan_layers=True)
    pcfg_unroll = dataclasses.replace(pcfg, scan_layers=False)

    compiled_scan, t_lower, t_compile = _lower_one(
        cfg, shape, pcfg_scan, opt_cfg, mesh
    )
    ma = compiled_scan.memory_analysis()
    if args.save_hlo:
        import gzip
        os.makedirs(os.path.dirname(args.save_hlo) or ".", exist_ok=True)
        with gzip.open(args.save_hlo, "wt") as f:
            f.write(compiled_scan.as_text())

    if multi_pod or args.scan:
        # pass/fail + memory cell: collectives from the scanned program
        # with loop multipliers; flops likewise (approximate, flagged).
        hlo = compiled_scan.as_text()
        ca = compiled_scan.cost_analysis()
        mults = find_loop_multipliers(hlo, n_periods)
        colls = parse_collectives(hlo, mults)
        flops = float(ca.get("flops", 0.0))
        bytes_acc = float(ca.get("bytes accessed", 0.0))
        accounting = "scan+loop-multipliers (approximate)"
    else:
        cfg1 = dataclasses.replace(cfg, num_layers=cfg.period)
        cfg2 = dataclasses.replace(cfg, num_layers=2 * cfg.period)
        c1, _, t1 = _lower_one(cfg1, shape, pcfg_unroll, opt_cfg, mesh)
        e1 = _extract(c1)
        del c1
        c2, _, t2 = _lower_one(cfg2, shape, pcfg_unroll, opt_cfg, mesh)
        e2 = _extract(c2)
        if args.save_hlo:
            import gzip
            with gzip.open(args.save_hlo + ".2p.gz", "wt") as f:
                f.write(e2["hlo"])
        del c2
        t_compile += t1 + t2
        flops = e1["flops"] + (n_periods - 1) * (e2["flops"] - e1["flops"])
        bytes_acc = e1["bytes"] + (n_periods - 1) * (e2["bytes"] - e1["bytes"])
        colls = {}
        kinds = set(e1["colls"]) | set(e2["colls"])
        for kind in kinds:
            b1 = e1["colls"].get(kind, {"bytes": 0, "count": 0})
            b2 = e2["colls"].get(kind, {"bytes": 0, "count": 0})
            colls[kind] = {
                "bytes": b1["bytes"] + (n_periods - 1) * (b2["bytes"] - b1["bytes"]),
                "count": b1["count"] + (n_periods - 1) * (b2["count"] - b1["count"]),
            }
        accounting = "unrolled 1p/2p extrapolation (exact)"

    coll_bytes = sum(v["bytes"] for v in colls.values())

    # XLA:CPU float-normalises bf16 to f32 (no native bf16 kernels), so
    # byte-denominated terms are ~2x a TPU compile for bf16 programs. We
    # report raw AND bf16-corrected (x0.5) terms; flops are dtype-exact.
    bf16_corr = 0.5 if cfg.dtype == "bfloat16" else 1.0

    # Kernel-true HBM correction: the XLA 'blocked' stand-in materialises a
    # (nblk, D, F_loc) weight-tile array per expert-specific matmul; the
    # Pallas ESMM/ESFK kernels stream each expert's slab through VMEM once
    # (sorted layout => revisit-cached). Subtract the stand-in's extra tile
    # traffic so t_memory reflects the kernel the system actually ships.
    moe_tile_extra = 0.0
    if cfg.moe is not None and pcfg.mode != "ep":
        axes_map = pcfg.axes(mesh)
        tp_size = mesh.shape.get("model", 1) if axes_map["tp"] else 1
        dp_size = n_chips // max(tp_size, 1)
        tok_island = shape.global_batch * (
            shape.seq_len if shape.kind != "decode" else 1
        ) // max(dp_size, 1)
        rows = tok_island * cfg.moe.top_k + cfg.moe.num_experts * (pcfg.blk - 1)
        nblk = max(rows // pcfg.blk, 1)
        f_loc = cfg.moe.d_ff // (
            tp_size if pcfg.mode in ("hybrid", "model_centric") else 1
        )
        n_moe = sum(cfg.is_moe_layer(i) for i in range(cfg.num_layers))
        n_mats = 3 if cfg.glu else 2
        tile = cfg.d_model * max(f_loc, 1) * 4  # f32 on the CPU backend
        per_esmm = (nblk - cfg.moe.num_experts) * tile
        # fwd(+remat refwd) esmm tile gathers + dW per-block outputs (rw)
        fwd_passes = n_mats * (2 if shape.kind == "train" else 1)
        dw_passes = 2 * n_mats if shape.kind == "train" else 0
        moe_tile_extra = n_moe * per_esmm * (fwd_passes + dw_passes)

    # Attention-transient correction: the pure-XLA online-softmax stand-in
    # materialises the (q_chunk x kv_block) logits/probability tensors in
    # HBM between the two dots of every (chunk, block) pair — a flash
    # kernel keeps them in VMEM. Estimated at 14 logits-sized tensor
    # traversals per pair (fwd 4: logits w+r, p w+r; remat re-fwd 4;
    # bwd 6: p r, dp w+r, dlogits w+r, read for dq/dk), f32 on this
    # backend. Subtracted for kernel-true t_memory.
    attn_extra = 0.0
    if shape.kind != "decode":
        axes_map = pcfg.axes(mesh)
        tp_size = mesh.shape.get("model", 1) if axes_map["tp"] else 1
        dp_size = n_chips // max(tp_size, 1)
        b_loc = max(shape.global_batch // max(dp_size, 1), 1)
        s = shape.seq_len
        n_attn = sum(
            cfg.layer_kind(i) == "attn" for i in range(cfg.num_layers)
        )
        heads_ok = (cfg.num_heads % tp_size == 0
                    and cfg.num_kv_heads % tp_size == 0)
        bk = min(2048, s)
        if heads_ok:
            cs = min(2048, s)
            nch = s // cs
            hq_loc = max(cfg.num_heads // tp_size, 1)
            pairs = sum(
                -(-((c + 1) * cs) // bk) for c in range(nch)
            )  # triangular (chunk, kv-block) pair count
            logits_unit = b_loc * cs * hq_loc * bk * 4
            per_layer = pairs * logits_unit
        else:
            cs_loc = s // tp_size  # queries stay seq-sharded
            pairs_rows = s // bk
            logits_unit = b_loc * cs_loc * cfg.num_heads * bk * 4
            per_layer = pairs_rows * logits_unit
        passes = 14 if shape.kind == "train" else 4
        attn_extra = n_attn * per_layer * passes

    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_acc * bf16_corr / HBM_BW
    t_mem_kernel = (
        max(bytes_acc - moe_tile_extra - attn_extra, 0.0) * bf16_corr / HBM_BW
    )
    t_coll = coll_bytes * bf16_corr / LINK_BW

    # MODEL_FLOPS convention from the assignment (per-chip share).
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf_mult = 6 if shape.kind == "train" else 2
    model_flops = mf_mult * n_active * tokens / n_chips

    dom = max(
        (("compute", t_comp), ("memory", t_mem_kernel),
         ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]

    return {
        "status": "ok",
        "arch": canon,
        "shape": shape_name,
        "mesh": dict(mesh.shape),
        "chips": n_chips,
        "mode": pcfg.mode,
        "schedule": pcfg.collective_schedule,
        "blk": pcfg.blk,
        "params_total": int(n_total),
        "params_active": int(n_active),
        "opt_state_dtype": opt_cfg.state_dtype,
        "master_fp32": opt_cfg.master_fp32,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
            "peak_per_device": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
        },
        "accounting": accounting,
        "bf16_byte_correction": bf16_corr,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll_bytes,
        "collectives": colls,
        "roofline": {
            "t_compute_s": t_comp,
            "t_memory_s": t_mem,
            "t_memory_kernel_s": t_mem_kernel,
            "t_collective_s": t_coll,
            "dominant": dom,
            "model_flops_per_device": model_flops,
            "useful_flops_fraction": model_flops / flops if flops else None,
        },
    }


def main():
    """CLI: dry-run one cell and print/append its JSON report row."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--devices", type=int, default=None,
                    help="debug: smaller fake-device pool")
    ap.add_argument("--mesh-shape", default=None,
                    help="debug: e.g. '4,2' or '2,2,2'")
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "hybrid", "model_centric",
                             "data_centric", "ep"])
    ap.add_argument("--schedule", default="ag_rs", choices=["ag_rs", "ag_ar"])
    ap.add_argument("--cache-policy", default="shared_cache",
                    choices=["shared_cache", "janus", "dots"])
    ap.add_argument("--scan", action="store_true",
                    help="scan layers instead of unrolling (smaller HLO, "
                         "approximate flop accounting)")
    ap.add_argument("--layers-override", type=int, default=None,
                    help="debug: truncate depth (rounded to a period)")
    ap.add_argument("--save-hlo", default=None,
                    help="gzip the optimized HLO here (perf analysis)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()

    result = run_cell(args.arch, args.shape, args.multi_pod, args)
    blob = json.dumps(result, indent=1, default=str)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(blob)
    print(blob)
    if result["status"] == "ok":
        print(f"\nDRYRUN OK {args.arch} {args.shape} "
              f"mesh={result['mesh']} dominant={result['roofline']['dominant']}")


if __name__ == "__main__":
    main()
