"""Straggler detection feeding the heterogeneous-aware planner.

The paper (§4.4) measures device capacity once, offline, with a proxy task.
At 1000-node scale capacity is *dynamic*: thermal throttling, ECC retries
and preemption-neighbour noise degrade individual workers. This module
closes the loop the paper leaves manual (DESIGN.md §6): observed per-worker
step times -> implied capacities -> ``core.hetero.replan_from_step_times``
-> new batch shares -> a new ``HeteroPlan`` whose Eq. 1 split the execution
layer runs (``parallel.moe_parallel``), re-traced at most once per distinct
plan through ``parallel.cache.PlanCache``.

In a single-controller SPMD run the per-worker timings arrive through the
``report()`` interface (e.g. from host telemetry); the logic is pure and
unit-tested with synthetic timelines.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Sequence

import numpy as np

from repro import obs
from repro.core.hetero import (
    HeteroPlan,
    clamp_shares,
    proportional_split,
    replan_from_step_times,
)


@dataclasses.dataclass
class StragglerConfig:
    """Replan-trigger policy (DESIGN.md §6 feedback loop).

    ``capacity`` caps any single worker's share: the SPMD layout allocates a
    fixed padded shard per device (``HeteroPlan.batch_capacity``), so a
    replan must never assign more rows than the shard holds — overflow is
    redistributed to workers with slack (``core.hetero.clamp_shares``)."""
    window: int = 16              # steps of history per worker
    trigger_ratio: float = 1.3    # worker slower than ratio*median -> replan
    min_steps_between_replans: int = 32
    quantum: int = 1              # batch-share granularity
    capacity: Optional[int] = None  # max share per worker (padded shard rows)


class StragglerMonitor:
    """Sliding-window step-time monitor that emits new Eq. 1 shares.

    Seed it with a ``HeteroPlan`` to start from the offline proxy-task split
    (paper Table 3) instead of uniform; ``current_plan()`` then returns the
    plan the execution layer should run now (DESIGN.md §6)."""

    def __init__(self, num_workers: int, global_batch: int,
                 cfg: StragglerConfig = StragglerConfig(),
                 plan: Optional[HeteroPlan] = None):
        self.cfg = cfg
        self.num_workers = num_workers
        self.global_batch = global_batch
        self._base_plan = plan
        if plan is not None and plan.token_counts is not None:
            if len(plan.token_counts) != num_workers:
                raise ValueError(
                    f"plan has {len(plan.token_counts)} shares for "
                    f"{num_workers} workers"
                )
            self.shares = list(plan.token_counts)
            if cfg.capacity is None and plan.token_capacity is not None:
                self.cfg = dataclasses.replace(
                    cfg, capacity=plan.token_capacity,
                    quantum=plan.token_quantum,
                )
        else:
            self.shares = proportional_split(
                [1.0] * num_workers, global_batch, quantum=cfg.quantum
            )
        self._hist = [deque(maxlen=cfg.window) for _ in range(num_workers)]
        self._last_replan = -10**9
        self._step = 0
        self.replans = 0

    def report(self, step_times_s: Sequence[float]) -> Optional[list[int]]:
        """Record one step's per-worker times; return new shares if a
        replan triggered, else None. New shares respect the capacity cap
        (``core.hetero.clamp_shares``) so the SPMD shard shapes never
        change — only the trace does (plan-keyed, see ``PlanCache``)."""
        self._step += 1
        for h, t in zip(self._hist, step_times_s):
            h.append(t)
        if obs.registry.enabled:
            g = obs.registry.gauge(
                "repro_straggler_worker_step_seconds",
                "windowed mean step time per worker", labels=("worker",))
            for i, h in enumerate(self._hist):
                if h:
                    g.labels(str(i)).set(float(np.mean(h)))
        if self._step - self._last_replan < self.cfg.min_steps_between_replans:
            return None
        if min(len(h) for h in self._hist) < self.cfg.window // 2:
            return None
        means = np.array([np.mean(h) for h in self._hist])
        med = np.median(means)
        if np.max(means) < self.cfg.trigger_ratio * med:
            return None
        new = replan_from_step_times(
            means, self.shares, self.global_batch,
            quantum=self.cfg.quantum, smoothing=0.7,
        )
        if self.cfg.capacity is not None:
            new = clamp_shares(
                new, self.cfg.capacity, quantum=self.cfg.quantum
            )
        self._last_replan = self._step
        self.replans += 1
        obs.registry.counter(
            "repro_straggler_replans_total",
            "replans triggered by the straggler monitor").inc()
        self.shares = new
        return new

    def current_plan(self) -> Optional[HeteroPlan]:
        """The HeteroPlan to execute now: the seed plan with the latest
        shares (None when the monitor was not seeded with a plan)."""
        if self._base_plan is None:
            return None
        return self._base_plan.with_token_counts(self.shares)
