"""Straggler detection feeding the heterogeneous-aware planner.

The paper (§4.4) measures device capacity once, offline, with a proxy task.
At 1000-node scale capacity is *dynamic*: thermal throttling, ECC retries
and preemption-neighbour noise degrade individual workers. This module
closes the loop: observed per-worker step times -> implied capacities ->
``core.hetero.replan_from_step_times`` -> new batch shares for the data
pipeline (Eq. 1 applied online).

In a single-controller SPMD run the per-worker timings arrive through the
``report()`` interface (e.g. from host telemetry); the logic is pure and
unit-tested with synthetic timelines.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Sequence

import numpy as np

from repro.core.hetero import proportional_split, replan_from_step_times


@dataclasses.dataclass
class StragglerConfig:
    window: int = 16              # steps of history per worker
    trigger_ratio: float = 1.3    # worker slower than ratio*median -> replan
    min_steps_between_replans: int = 32
    quantum: int = 1              # batch-share granularity


class StragglerMonitor:
    def __init__(self, num_workers: int, global_batch: int,
                 cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.num_workers = num_workers
        self.global_batch = global_batch
        self.shares = proportional_split([1.0] * num_workers, global_batch,
                                         quantum=cfg.quantum)
        self._hist = [deque(maxlen=cfg.window) for _ in range(num_workers)]
        self._last_replan = -10**9
        self._step = 0

    def report(self, step_times_s: Sequence[float]) -> Optional[list[int]]:
        """Record one step's per-worker times; return new shares if a
        replan triggered, else None."""
        self._step += 1
        for h, t in zip(self._hist, step_times_s):
            h.append(t)
        if self._step - self._last_replan < self.cfg.min_steps_between_replans:
            return None
        if min(len(h) for h in self._hist) < self.cfg.window // 2:
            return None
        means = np.array([np.mean(h) for h in self._hist])
        med = np.median(means)
        if np.max(means) < self.cfg.trigger_ratio * med:
            return None
        new = replan_from_step_times(
            means, self.shares, self.global_batch,
            quantum=self.cfg.quantum, smoothing=0.7,
        )
        self._last_replan = self._step
        self.shares = new
        return new
