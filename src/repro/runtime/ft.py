"""Fault tolerance: checkpoint policy, preemption handling, retry loop.

The training driver wraps its step loop in ``run_with_recovery``:

  * periodic async checkpoints (every ``save_every`` steps), with
    retention GC ordered *after* each write lands (the GC callback runs
    in the saver's worker thread post-commit, so retention is computed
    against a listing that contains the new checkpoint and can never
    race the in-flight write),
  * a SIGTERM/SIGINT handler that requests an immediate checkpoint and a
    clean exit (TPU preemption notice) — installed for exactly the
    lifetime of the loop (try/finally), so no raise path leaves the
    process's signal handlers hijacked,
  * on step failure (device error, NaN-loss watchdog): restore the newest
    checkpoint **that passes integrity verification** (corrupt/partial
    checkpoints are skipped — ``checkpoint.manager.valid_steps``) and
    continue, governed by a sliding-window failure budget with
    exponential backoff + deterministic jitter,
  * on ``faults.DeviceLostError`` (device dropout): hand the error to the
    caller's ``on_device_loss`` hook, which re-meshes via
    ``runtime.elastic``, re-derives the plan, and returns the new state
    template + shardings to restore under (DESIGN.md §9),
  * deterministic data resume: the data pipeline is a pure function of the
    step counter, so restore(step) replays the exact remaining stream.
"""
from __future__ import annotations

import collections
import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro import obs
from repro.checkpoint import manager as ckpt
from repro.runtime import faults as faults_lib


@dataclasses.dataclass
class FTConfig:
    """Fault-tolerance policy: checkpoint cadence/retention and the
    failure budget of the retry loop (``run_with_recovery``).

    The budget is a **sliding window**: at most ``max_failures`` failures
    within the trailing ``failure_window_s`` seconds — a lifetime counter
    would eventually kill any long job with a nonzero background failure
    rate, while a window distinguishes a crash loop from sparse noise.
    Each failure inside the window backs off ``backoff_base_s * 2**(n-1)``
    seconds (capped at ``backoff_max_s``) plus deterministic jitter from
    ``seed``."""
    ckpt_dir: str = "checkpoints"
    save_every: int = 100
    keep: int = 3
    max_failures: int = 3
    failure_window_s: float = 300.0
    backoff_base_s: float = 0.05
    backoff_max_s: float = 5.0
    seed: int = 0
    nan_is_failure: bool = True


class FailureBudget:
    """Sliding-window failure accounting with exponential backoff.

    ``record()`` stamps a failure and returns the backoff to sleep before
    retrying; ``exhausted`` is True once more than ``max_failures``
    failures landed within the trailing window. ``clock`` is injectable so
    tests can drive the window without real time passing; jitter comes
    from a generator seeded by ``seed`` — two runs of the same scenario
    back off identically (the chaos harness asserts on it)."""

    def __init__(self, max_failures: int, window_s: float, *,
                 base_s: float = 0.05, max_s: float = 5.0, seed: int = 0,
                 clock: Callable[[], float] = time.monotonic):
        self.max_failures = max_failures
        self.window_s = window_s
        self.base_s = base_s
        self.max_s = max_s
        self.clock = clock
        self.rng = np.random.default_rng(seed)
        self.stamps: collections.deque = collections.deque()

    def _prune(self, now: float) -> None:
        while self.stamps and now - self.stamps[0] > self.window_s:
            self.stamps.popleft()

    def record(self) -> float:
        """Stamp a failure; return the backoff sleep (seconds)."""
        now = self.clock()
        self._prune(now)
        self.stamps.append(now)
        n = len(self.stamps)
        backoff = min(self.base_s * 2 ** (n - 1), self.max_s)
        jitter = float(self.rng.uniform(0.0, 0.25)) * backoff
        return backoff + jitter

    @property
    def exhausted(self) -> bool:
        """More than ``max_failures`` failures inside the window?"""
        self._prune(self.clock())
        return len(self.stamps) > self.max_failures


class PreemptionFlag:
    """Set by SIGTERM/SIGINT; polled by the step loop."""

    def __init__(self):
        self.flag = False
        self._orig = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._orig[sig] = signal.signal(sig, self._handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self.flag = True

    def restore_handlers(self):
        for sig, h in self._orig.items():
            signal.signal(sig, h)


def _restore_latest_valid(ft, template, shardings):
    """Walk committed checkpoints newest→oldest, returning the first that
    verifies AND loads into ``template`` — the fallback path for a newest
    checkpoint that is corrupt, partial, or shape-incompatible."""
    for s in ckpt.valid_steps(ft.ckpt_dir):
        try:
            state, meta = ckpt.restore(ft.ckpt_dir, s, template, shardings)
        except (ckpt.CheckpointCorruptError, AssertionError,
                ValueError, OSError) as exc:
            print(f"[ft] checkpoint step {s} unusable ({exc!r}); "
                  f"trying older")
            continue
        return state, meta, s
    return None


def run_with_recovery(
    *,
    state: Any,
    step_fn: Callable[[Any, int], tuple[Any, dict]],
    start_step: int,
    num_steps: int,
    ft: FTConfig,
    shardings: Optional[Any] = None,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
    on_device_loss: Optional[
        Callable[[faults_lib.DeviceLostError], tuple[Any, Any]]
    ] = None,
    sleep_fn: Callable[[float], None] = time.sleep,
    clock: Callable[[], float] = time.monotonic,
) -> tuple[Any, int]:
    """Run ``step_fn(state, step) -> (state, metrics)`` with checkpointing
    and restore-on-failure. Returns (final_state, last_step).

    ``on_device_loss(err) -> (state_template, shardings)`` is the elastic
    hook: on an injected/real device dropout it must rebuild the mesh and
    step fn (mutating whatever closure ``step_fn`` reads) and return the
    new state template + shardings to restore the checkpoint under; with
    no hook, device loss is fatal. ``sleep_fn``/``clock`` are injectable
    for deterministic tests."""
    saver = ckpt.AsyncSaver()
    preempt = PreemptionFlag()
    budget = FailureBudget(
        ft.max_failures, ft.failure_window_s, base_s=ft.backoff_base_s,
        max_s=ft.backoff_max_s, seed=ft.seed, clock=clock)
    step = start_step

    def save(sync=False):
        # GC runs in the saver thread after the write commits: retention
        # sees the new checkpoint and never prunes under an in-flight one.
        saver.save(ft.ckpt_dir, step, state, meta={"step": step},
                   post=lambda _path: ckpt.gc_old(ft.ckpt_dir, ft.keep))
        if sync:
            saver.wait()

    def recover(err) -> None:
        nonlocal state, step
        backoff = budget.record()
        obs.registry.counter(
            "repro_ft_recoveries_total",
            "step failures recovered via checkpoint restore").inc()
        obs.registry.gauge(
            "repro_ft_backoff_seconds",
            "backoff slept before the most recent restore").set(backoff)
        if budget.exhausted:
            obs.events.emit("train.recover", reason="failure budget exhausted",
                            failures=len(budget.stamps))
            raise err
        try:
            saver.wait()  # settle the in-flight write before reading
        except Exception as werr:  # noqa: BLE001 — a failed save is
            # logged, not fatal: the restore walk below only trusts
            # checkpoints that verify.
            print(f"[ft] async save failed during recovery: {werr!r}")
        got = _restore_latest_valid(ft, state, shardings)
        if got is None:
            raise RuntimeError("failure before first valid checkpoint") \
                from err
        state, meta, restored = got
        step = int(meta["step"])
        sleep_fn(backoff)
        obs.events.emit("train.recover", reason=repr(err),
                        restored_step=step, backoff_s=backoff,
                        failures_in_window=len(budget.stamps))
        print(f"[ft] step failure ({err!r}); restored step {step} "
              f"(ckpt {restored}), {len(budget.stamps)} failures in "
              f"window, backoff {backoff:.3f}s")

    try:
        while step < num_steps:
            try:
                for f in faults_lib.inject("train.preempt", step=step):
                    if f.kind == "preempt":
                        preempt.flag = True
                new_state, metrics = step_fn(state, step)
                for f in faults_lib.inject("train.loss", step=step):
                    if f.kind == "nan" and "loss" in metrics:
                        metrics = dict(metrics, loss=float("nan"))
                if ft.nan_is_failure and "loss" in metrics:
                    if not np.isfinite(float(metrics["loss"])):
                        raise FloatingPointError(
                            f"non-finite loss at {step}")
                state = new_state
                step += 1
                if on_metrics:
                    on_metrics(step, metrics)
                if step % ft.save_every == 0:
                    save()
                if preempt.flag:
                    save(sync=True)
                    break
            except faults_lib.DeviceLostError as e:
                if on_device_loss is None:
                    raise
                # Elastic shrink: the hook re-meshes over the survivors
                # and hands back the template/shardings for the new
                # topology; the checkpoint's LOGICAL arrays then restore
                # onto the smaller mesh (DESIGN.md §9).
                template, shardings = on_device_loss(e)
                state = template
                recover(e)
                print(f"[ft] resumed on shrunken mesh at step {step}")
            except Exception as e:  # noqa: BLE001 — any step failure
                recover(e)
    finally:
        preempt.restore_handlers()
    saver.wait()
    return state, step
