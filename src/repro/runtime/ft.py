"""Fault tolerance: checkpoint policy, preemption handling, retry loop.

The training driver wraps its step loop in ``run_with_recovery``:

  * periodic async checkpoints (every ``save_every`` steps),
  * a SIGTERM/SIGINT handler that requests an immediate checkpoint and a
    clean exit (TPU preemption notice),
  * on step failure (device error, NaN-loss watchdog): restore the latest
    checkpoint and continue, up to ``max_failures`` times — the
    single-controller analogue of a coordinated multi-host restart,
  * deterministic data resume: the data pipeline is a pure function of the
    step counter, so restore(step) replays the exact remaining stream.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import manager as ckpt


@dataclasses.dataclass
class FTConfig:
    """Fault-tolerance policy: checkpoint cadence/retention and the
    failure budget of the retry loop (``run_with_recovery``)."""
    ckpt_dir: str = "checkpoints"
    save_every: int = 100
    keep: int = 3
    max_failures: int = 3
    nan_is_failure: bool = True


class PreemptionFlag:
    """Set by SIGTERM/SIGINT; polled by the step loop."""

    def __init__(self):
        self.flag = False
        self._orig = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._orig[sig] = signal.signal(sig, self._handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self.flag = True

    def restore_handlers(self):
        for sig, h in self._orig.items():
            signal.signal(sig, h)


def run_with_recovery(
    *,
    state: Any,
    step_fn: Callable[[Any, int], tuple[Any, dict]],
    start_step: int,
    num_steps: int,
    ft: FTConfig,
    shardings: Optional[Any] = None,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
) -> tuple[Any, int]:
    """Run ``step_fn(state, step) -> (state, metrics)`` with checkpointing
    and restore-on-failure. Returns (final_state, last_step)."""
    saver = ckpt.AsyncSaver()
    preempt = PreemptionFlag()
    failures = 0
    step = start_step

    def save(sync=False):
        saver.save(ft.ckpt_dir, step, state, meta={"step": step})
        if sync:
            saver.wait()
        ckpt.gc_old(ft.ckpt_dir, ft.keep)

    while step < num_steps:
        try:
            new_state, metrics = step_fn(state, step)
            if ft.nan_is_failure and "loss" in metrics:
                if not np.isfinite(float(metrics["loss"])):
                    raise FloatingPointError(f"non-finite loss at {step}")
            state = new_state
            step += 1
            if on_metrics:
                on_metrics(step, metrics)
            if step % ft.save_every == 0:
                save()
            if preempt.flag:
                save(sync=True)
                break
        except Exception as e:  # noqa: BLE001 — any step failure
            failures += 1
            if failures > ft.max_failures:
                raise
            last = ckpt.latest_step(ft.ckpt_dir)
            if last is None:
                raise RuntimeError("failure before first checkpoint") from e
            saver.wait()
            state, meta = ckpt.restore(ft.ckpt_dir, last, state, shardings)
            step = int(meta["step"])
            print(f"[ft] step failure ({e!r}); restored step {step}, "
                  f"failure {failures}/{ft.max_failures}")

    saver.wait()
    preempt.restore_handlers()
    return state, step
