"""Deterministic fault injection: the chaos substrate (DESIGN.md §9).

Every recovery mechanism in this repo — checkpoint fallback
(``runtime.ft``), elastic re-mesh (``runtime.elastic``), and the paged
serving engine's retry/preemption paths (``launch.serve``) — is driven by
failures that production makes plentiful and a test environment makes
rare. This module makes them plentiful *and* reproducible: a
``FaultPlan`` is a seedable script of faults keyed to named injection
**sites** threaded through the drivers behind no-op-when-disabled hooks
(``inject(site)`` is a dict lookup + counter bump when no plan is
installed — nothing else).

Sites (the convention, not a closed set):

  ``train.step``      before a training step executes (via
                      ``launch.steps.wrap_step_with_faults``)
  ``train.preempt``   polled once per step by ``ft.run_with_recovery``
  ``train.loss``      after a step — ``nan`` poisons the reported loss
  ``serve.decode``    before a paged decode macro-step
  ``serve.prefill``   before a paged prefill chunk
  ``serve.logits``    after a decode step — ``nan`` poisons one slot's row
  ``serve.prefill_logits``  after a prefill chunk — same, for the
                      first-token logits
  ``ckpt.write``      after a checkpoint directory commits — ``truncate``
                      / ``bitflip`` corrupt a committed leaf file, the
                      storage failure ``checkpoint.manager.verify`` and
                      ``latest_valid_step`` exist to catch

Fault kinds and how sites interpret them:

  ``error``        raise ``FaultError`` (device-error analogue). With a
                   ``{"slot": k}`` payload the serving engine treats it as
                   a request-level failure (abort + retry slot ``k``);
                   without one it is engine-level (rebuild step fns,
                   resume from the surviving page tables).
  ``device_drop``  raise ``DeviceLostError`` carrying
                   ``payload["survivors"]`` — the elastic-shrink trigger.
  ``delay``        sleep ``payload["delay_s"]`` (straggler spike).
  ``nan``          returned to the site, which poisons the named value.
  ``preempt``      returned to the site (``ft`` sets the SIGTERM flag).
  ``truncate`` / ``bitflip``  returned to the ``ckpt.write`` site, which
                   applies :func:`corrupt_checkpoint`.

Determinism: matching is by per-site call counters (``at`` = 0-based call
index, ``every`` = periodic) with an optional seeded ``prob``; the plan's
RNG is the only randomness and is owned by the plan, so the same plan
against the same driver fires identically every run — which is what lets
the chaos scenarios (`make chaos`, tests/test_chaos.py) assert bit-exact
recovery instead of "it didn't crash".
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro import obs


class FaultError(RuntimeError):
    """An injected device-error-style step failure. ``fault`` carries the
    spec that fired so recovery code can read its payload (e.g. which
    slot a serving failure poisons)."""

    def __init__(self, message: str, fault: Optional["Fault"] = None):
        super().__init__(message)
        self.fault = fault


class DeviceLostError(FaultError):
    """An injected device dropout. ``survivors`` names what is left —
    an int count (training device pool) or a sequence of surviving
    device-class/group ids (serving page-pool groups)."""

    def __init__(self, message: str, fault: Optional["Fault"] = None,
                 survivors: Any = None):
        super().__init__(message, fault)
        self.survivors = survivors


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scripted fault: fire at ``site`` when the site's call counter
    matches ``at`` (0-based), or every ``every`` calls, or with
    probability ``prob`` under the plan's seeded RNG; at most ``times``
    firings. ``payload`` is interpreted per (site, kind) — see module
    docstring."""

    site: str
    kind: str
    at: Optional[int] = None
    every: Optional[int] = None
    prob: float = 0.0
    times: int = 1
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def matches(self, call: int, rng: np.random.Generator) -> bool:
        """Does this fault fire on the site's ``call``-th invocation?"""
        if self.at is not None:
            return call == self.at
        if self.every is not None:
            return call % self.every == 0 and call > 0
        if self.prob > 0.0:
            return bool(rng.random() < self.prob)
        return False


class FaultPlan:
    """A seeded, scriptable set of :class:`Fault` specs plus the per-site
    call counters that make firing deterministic. ``fired`` logs every
    firing as ``(site, call_index, kind)`` so tests can assert exactly
    which faults a scenario exercised."""

    def __init__(self, faults: Sequence[Fault] = (), seed: int = 0):
        self.faults: List[List[Any]] = [[f, f.times] for f in faults]
        self.rng = np.random.default_rng(seed)
        self.calls: Dict[str, int] = {}
        self.fired: List[tuple] = []

    @classmethod
    def from_spec(cls, spec: Dict[str, Any]) -> "FaultPlan":
        """Build from a JSON-able dict:
        ``{"seed": 0, "faults": [{"site": ..., "kind": ..., ...}, ...]}``.
        """
        faults = [Fault(**f) for f in spec.get("faults", ())]
        return cls(faults, seed=int(spec.get("seed", 0)))

    def fire(self, site: str, **ctx) -> List[Fault]:
        """Advance ``site``'s call counter and return the faults that fire
        on this call (decrementing their remaining ``times``)."""
        call = self.calls.get(site, 0)
        self.calls[site] = call + 1
        out = []
        for entry in self.faults:
            f, remaining = entry
            if f.site != site or remaining <= 0:
                continue
            if f.matches(call, self.rng):
                entry[1] -= 1
                self.fired.append((site, call, f.kind))
                obs.registry.counter(
                    "repro_faults_fired_total",
                    "injected faults that fired, by site and kind",
                    labels=("site", "kind")).labels(site, f.kind).inc()
                out.append(f)
        return out


def load_plan(spec: str) -> FaultPlan:
    """Parse a fault plan from inline JSON (leading ``{``) or a JSON file
    path — the ``--fault-spec`` CLI contract."""
    text = spec
    if not spec.lstrip().startswith("{"):
        with open(spec) as fh:
            text = fh.read()
    return FaultPlan.from_spec(json.loads(text))


_ACTIVE: Optional[FaultPlan] = None


def install(plan: Optional[FaultPlan]) -> None:
    """Install ``plan`` as the process-wide active plan (None disables)."""
    global _ACTIVE
    _ACTIVE = plan


def active() -> Optional[FaultPlan]:
    """The currently installed plan, or None."""
    return _ACTIVE


@contextlib.contextmanager
def scope(plan: Optional[FaultPlan]):
    """Install ``plan`` for the duration of a with-block (tests)."""
    prev = _ACTIVE
    install(plan)
    try:
        yield plan
    finally:
        install(prev)


def inject(site: str, **ctx) -> List[Fault]:
    """The no-op-when-disabled hook every instrumented site calls.

    Raises for ``error``/``device_drop`` kinds, sleeps for ``delay``, and
    returns the remaining fired faults (``nan``/``preempt``/``truncate``/
    ``bitflip``) for the site to interpret. With no installed plan this is
    a single attribute read."""
    plan = _ACTIVE
    if plan is None:
        return []
    fired = plan.fire(site, **ctx)
    passthrough = []
    for f in fired:
        if f.kind == "device_drop":
            raise DeviceLostError(
                f"injected device loss at {site} "
                f"(call {plan.calls[site] - 1})",
                fault=f, survivors=f.payload.get("survivors"))
        if f.kind == "error":
            raise FaultError(
                f"injected fault at {site} (call {plan.calls[site] - 1})",
                fault=f)
        if f.kind == "delay":
            time.sleep(float(f.payload.get("delay_s", 0.01)))
        else:
            passthrough.append(f)
    return passthrough


# ---------------------------------------------------------------------------
# checkpoint corruption (the ``ckpt.write`` site's payload interpreter)
# ---------------------------------------------------------------------------

def corrupt_checkpoint(path: str, fault: Fault) -> str:
    """Damage one committed leaf file under checkpoint directory ``path``:
    ``truncate`` drops the trailing half of its bytes (a partial write the
    rename ordering can no longer protect against once injected *after*
    the commit), ``bitflip`` flips one bit mid-file (silent media
    corruption). Returns the damaged file's path. Both are exactly what
    ``checkpoint.manager.verify``'s byte counts and crc32 exist to catch.
    """
    leaf = int(fault.payload.get("leaf", 0))
    target = os.path.join(path, f"a_{leaf:05d}.npy")
    with open(target, "rb") as fh:
        data = bytearray(fh.read())
    if fault.kind == "truncate":
        data = data[: max(1, len(data) // 2)]
    elif fault.kind == "bitflip":
        pos = int(fault.payload.get("offset", len(data) // 2))
        data[pos] ^= 0x40
    else:
        raise ValueError(f"unknown corruption kind {fault.kind!r}")
    with open(target, "wb") as fh:
        fh.write(bytes(data))
    return target
