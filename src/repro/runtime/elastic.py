"""Elastic scaling: choose a mesh for whatever devices survive.

Checkpoints are mesh-agnostic (logical arrays), so elasticity reduces to:
(1) pick a new (data, model) factorisation for the surviving device count,
(2) re-apply shardings at restore. ``choose_mesh_shape`` prefers keeping the
model axis at the architecture's minimum TP degree (enough HBM per shard)
and gives the rest to data parallelism; the batch is re-split by the
heterogeneous planner if the surviving pool is uneven.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

from repro.common import tree_bytes


def choose_mesh_shape(
    num_devices: int,
    *,
    min_model: int = 1,
    max_model: Optional[int] = None,
    param_bytes: Optional[int] = None,
    hbm_bytes: int = 16 * 2**30,
) -> tuple[int, int]:
    """Largest (data, model) grid with model >= minimum TP for memory.

    If ``param_bytes`` is given, min_model is raised until params (+2x for
    optimizer) fit per device under pure TP+FSDP sharding heuristics.
    """
    if param_bytes is not None:
        # model axis must be wide enough that one TP-sharded copy of the
        # (bf16) parameters occupies at most half a chip's HBM — the
        # residency a decode/serving replica needs.
        while (
            min_model < num_devices
            and param_bytes / min_model > hbm_bytes * 0.5
        ):
            min_model *= 2
    model = min_model
    max_model = max_model or num_devices
    while num_devices % model != 0 and model <= max_model:
        model += 1
    model = min(model, max_model, num_devices)
    data = num_devices // model
    return data, model


def make_mesh(shape: Sequence[int], names: Sequence[str],
              devices=None) -> Mesh:
    """Mesh over the first prod(shape) devices (surviving-pool re-mesh)."""
    devices = devices if devices is not None else jax.devices()
    import numpy as np

    arr = np.array(devices[: int(np.prod(shape))]).reshape(tuple(shape))
    return Mesh(arr, tuple(names))
