"""Swin-MoE-Base — the paper's benchmark (larger scale)."""
from repro.configs.base import MoEConfig
from repro.configs.swin_moe_small import with_experts  # re-export helper
from repro.models.swin import SWIN_BASE, SwinConfig

CONFIG = SwinConfig(
    name="swin-moe-base",
    moe=MoEConfig(num_experts=8, top_k=1, d_ff=0, norm_topk=True),
    **SWIN_BASE,
)

SMOKE_CONFIG = SwinConfig(
    name="swin-moe-base-smoke",
    img_size=32,
    patch_size=4,
    depths=(1, 1, 2, 1),
    dims=(32, 64, 128, 256),
    heads=(2, 4, 4, 8),
    window=2,
    num_classes=10,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=0),
)
