"""Swin-MoE-Small — the paper's benchmark (Tutel configuration).

Swin-S backbone (depths 2/2/18/2, dims 96..768, window 7) with MoE FFN on
alternating blocks of stages 3-4. Expert count / top-k are overridden per
benchmark table (8 experts for Table 7, 4 for Table 8).
"""
import dataclasses

from repro.configs.base import MoEConfig
from repro.models.swin import SWIN_SMALL, SwinConfig

CONFIG = SwinConfig(
    name="swin-moe-small",
    moe=MoEConfig(num_experts=8, top_k=1, d_ff=0, norm_topk=True),
    **SWIN_SMALL,
)

SMOKE_CONFIG = SwinConfig(
    name="swin-moe-small-smoke",
    img_size=32,
    patch_size=4,
    depths=(1, 1, 2, 1),
    dims=(16, 32, 64, 128),
    heads=(2, 2, 4, 4),
    window=2,
    num_classes=10,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=0),
)


def with_experts(cfg: SwinConfig, num_experts: int, top_k: int) -> SwinConfig:
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=num_experts, top_k=top_k)
    )
