"""Gemma-2B — dense, GeGLU, MQA (kv=1), head_dim=256. [arXiv:2403.08295; hf]

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    act="gelu",
    glu=True,          # GeGLU
    embed_scale=True,
    rope_theta=1e4,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma2b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    act="gelu",
    glu=True,
    embed_scale=True,
    tie_embeddings=True,
)
