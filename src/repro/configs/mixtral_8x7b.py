"""Mixtral-8x7B — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]

32L d_model=4096 32H (GQA kv=8) expert d_ff=14336 vocab=32000, SWA 4096.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=32000,
    act="silu",
    glu=True,
    rope_theta=1e6,
    attn_pattern=("local",),   # SWA on every layer
    window=4096,
    tie_embeddings=False,
    moe=MoEConfig(
        num_experts=8, top_k=2, d_ff=14336,
        norm_topk=False, softmax_after_topk=True,
    ),
)

SMOKE_CONFIG = ModelConfig(
    name="mixtral-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=0,
    vocab_size=128,
    attn_pattern=("local",),
    window=16,
    tie_embeddings=False,
    moe=MoEConfig(
        num_experts=4, top_k=2, d_ff=96,
        norm_topk=False, softmax_after_topk=True,
    ),
)
