"""Gemma-3-12B — dense, 5:1 local:global attention, 128k context.
[hf:google/gemma-3 family]

48L d_model=3840 16H (GQA kv=8, head_dim=256) d_ff=15360 vocab=262144.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    act="gelu",
    glu=True,          # GeGLU
    qk_norm=True,
    embed_scale=True,
    rope_theta=1e6,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    num_layers=6,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    act="gelu",
    glu=True,
    qk_norm=True,
    embed_scale=True,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    window=16,
    tie_embeddings=True,
)
