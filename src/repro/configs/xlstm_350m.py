"""xLSTM-350M — sLSTM + mLSTM blocks (7:1). [arXiv:2405.04517]

24L d_model=1024 4H vocab=50304, no separate FFN (mLSTM blocks are
pre-up-projection; sLSTM blocks carry a small post FFN).
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    tie_embeddings=True,
    layer_pattern=(
        "mlstm", "mlstm", "slstm", "mlstm",
        "mlstm", "mlstm", "mlstm", "mlstm",
    ),
    # chunk=512: the (B,NH,HD,HD) matrix-memory carry is snapshotted per
    # chunk by scan AD; big chunks bound that memory (see EXPERIMENTS §Perf).
    xlstm=XLSTMConfig(proj_factor=2.0, conv_kernel=4, chunk=512),
)

SMOKE_CONFIG = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=128,
    tie_embeddings=True,
    layer_pattern=(
        "mlstm", "mlstm", "slstm", "mlstm",
        "mlstm", "mlstm", "mlstm", "mlstm",
    ),
    xlstm=XLSTMConfig(proj_factor=2.0, conv_kernel=4, chunk=8),
)
