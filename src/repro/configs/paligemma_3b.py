"""PaliGemma-3B — SigLIP vision encoder + Gemma-2B decoder.
[arXiv:2407.07726; hf]

Backbone: 18L d_model=2048 8H (MQA kv=1, head_dim=256) d_ff=16384
vocab=257216. The SigLIP frontend is a STUB: input_specs provides 256
precomputed patch embeddings (frontend_dim=1152); the image prefix is
bidirectional (prefix-LM mask, prefix_len=256).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    act="gelu",
    glu=True,
    embed_scale=True,
    rope_theta=1e4,
    frontend="siglip",
    frontend_dim=1152,
    prefix_len=256,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="paligemma-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    act="gelu",
    glu=True,
    embed_scale=True,
    frontend="siglip",
    frontend_dim=48,
    prefix_len=8,
    tie_embeddings=True,
)
