"""Qwen3-30B-A3B — fine-grained MoE. [hf:Qwen/Qwen3-30B-A3B; hf]

48L d_model=2048 32H (GQA kv=4, head_dim=128) expert d_ff=768,
vocab=151936, MoE 128 experts top-8, norm_topk, qk-norm.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    act="silu",
    glu=True,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=False,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff=768, norm_topk=True),
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=0,
    vocab_size=128,
    qk_norm=True,
    tie_embeddings=False,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=32),
)
