"""MusicGen-Large — decoder-only over EnCodec tokens with T5 cross-attention.
[arXiv:2306.05284; hf]

48L d_model=2048 32H (MHA kv=32, head_dim=64) d_ff=8192 vocab=2048 (EnCodec
codebook size), 4 codebooks. The EnCodec frontend is a STUB: input_specs
provides precomputed frame embeddings (frontend_dim=128, the EnCodec latent
dim); the T5 conditioning sequence is likewise precomputed (cross_d=1024).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    act="gelu",
    glu=False,
    norm="layernorm",
    rope_theta=1e4,
    frontend="encodec",
    frontend_dim=128,
    cross_attn=True,
    cross_d=1024,
    num_codebooks=4,
    tie_embeddings=False,
)

SMOKE_CONFIG = ModelConfig(
    name="musicgen-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=64,
    act="gelu",
    glu=False,
    norm="layernorm",
    frontend="encodec",
    frontend_dim=32,
    cross_attn=True,
    cross_d=48,
    num_codebooks=4,
    tie_embeddings=False,
)
