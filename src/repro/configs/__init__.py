"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the exact published configuration;
``get_smoke_config(name)`` returns a reduced same-family config for CPU
smoke tests. ``SHAPES`` defines the assigned input-shape set.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen3_moe_30b_a3b",
    "mixtral_8x7b",
    "jamba_1_5_large_398b",
    "phi3_medium_14b",
    "starcoder2_15b",
    "gemma3_12b",
    "gemma_2b",
    "musicgen_large",
    "xlstm_350m",
    "paligemma_3b",
    "swin_moe_small",
    "swin_moe_base",
]

ALIASES = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "phi3-medium-14b": "phi3_medium_14b",
    "starcoder2-15b": "starcoder2_15b",
    "gemma3-12b": "gemma3_12b",
    "gemma-2b": "gemma_2b",
    "musicgen-large": "musicgen_large",
    "xlstm-350m": "xlstm_350m",
    "paligemma-3b": "paligemma_3b",
    "swin-moe-small": "swin_moe_small",
    "swin-moe-base": "swin_moe_base",
}


def canonical(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE_CONFIG
