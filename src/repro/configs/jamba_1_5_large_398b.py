"""Jamba-1.5-Large (398B) — Mamba+attention 7:1 hybrid with MoE.
[arXiv:2403.19887; hf]

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2 on
every other layer; attention once per 8-layer period (no RoPE in Jamba).
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    act="silu",
    glu=True,
    use_rope=False,
    tie_embeddings=False,
    layer_pattern=(
        "mamba", "mamba", "mamba", "mamba",
        "attn", "mamba", "mamba", "mamba",
    ),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576, period=2, offset=1),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, dt_rank=512, chunk=128),
)

SMOKE_CONFIG = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    use_rope=False,
    tie_embeddings=False,
    layer_pattern=(
        "mamba", "mamba", "mamba", "mamba",
        "attn", "mamba", "mamba", "mamba",
    ),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=96, period=2, offset=1),
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2, dt_rank=8, chunk=8),
)
