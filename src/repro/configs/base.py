"""Model / shape configuration dataclasses."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                     # per-expert hidden size
    period: int = 1               # layer_idx % period == offset -> MoE FFN
    offset: int = 0
    norm_topk: bool = True
    softmax_after_topk: bool = False
    aux_weight: float = 0.01
    z_weight: float = 1e-3


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    proj_factor: float = 2.0
    conv_kernel: int = 4
    chunk: int = 64
    ffn_factor: float = 4.0 / 3.0  # sLSTM block FFN


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense|moe|hybrid|ssm|audio|vlm|vision-moe
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                     # dense FFN hidden (0 -> none / MoE only)
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    act: str = "silu"
    glu: bool = True
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    norm_eps: float = 1e-6
    rope_theta: float = 1e4
    use_rope: bool = True
    qk_norm: bool = False
    logit_softcap: float = 0.0
    embed_scale: bool = False     # gemma-style sqrt(d) embedding scale
    attn_pattern: Tuple[str, ...] = ("global",)   # cycled: global|local
    window: int = 0               # local/SWA window (0 -> none)
    layer_pattern: Tuple[str, ...] = ("attn",)    # cycled: attn|mamba|mlstm|slstm
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    frontend: Optional[str] = None    # encodec|siglip|None
    frontend_dim: int = 0
    cross_attn: bool = False
    cross_d: int = 0
    num_codebooks: int = 1
    prefix_len: int = 0               # bidirectional prefix (vlm)
    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def period(self) -> int:
        p = math.lcm(len(self.layer_pattern), len(self.attn_pattern))
        if self.moe is not None:
            p = math.lcm(p, self.moe.period)
        assert self.num_layers % p == 0, (self.name, p, self.num_layers)
        return p

    def layer_kind(self, idx: int) -> str:
        return self.layer_pattern[idx % len(self.layer_pattern)]

    def attn_kind(self, idx: int) -> str:
        return self.attn_pattern[idx % len(self.attn_pattern)]

    def is_moe_layer(self, idx: int) -> bool:
        return (
            self.moe is not None
            and idx % self.moe.period == self.moe.offset
            and self.layer_kind(idx) in ("attn", "mamba")
        )

    def param_count(self) -> int:
        """Approximate total parameter count (for roofline MODEL_FLOPS)."""
        d, l = self.d_model, self.num_layers
        hd = self.hd
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i in range(l):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += attn
            elif kind == "mamba":
                di = (self.mamba.expand if self.mamba else 2) * d
                ds = self.mamba.d_state if self.mamba else 16
                dtr = (self.mamba.dt_rank or -(-d // 16)) if self.mamba else d // 16
                total += d * 2 * di + di * (dtr + 2 * ds) + dtr * di + di * ds + di * d
            elif kind in ("mlstm", "slstm"):
                pf = self.xlstm.proj_factor if self.xlstm else 2.0
                di = int(pf * d)
                total += 2 * d * di + 3 * di * di // 4 + di * d  # rough
            if self.is_moe_layer(i):
                m = self.moe
                n_mats = 3 if self.glu else 2
                total += m.num_experts * n_mats * d * m.d_ff + d * m.num_experts
            elif self.d_ff:
                n_mats = 3 if self.glu else 2
                total += n_mats * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        n_mats = 3 if self.glu else 2
        n_moe_layers = sum(self.is_moe_layer(i) for i in range(self.num_layers))
        inactive = (
            n_moe_layers * (m.num_experts - m.top_k) * n_mats * self.d_model * m.d_ff
        )
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

# long_500k applicability (DESIGN.md §4): sub-quadratic archs only.
LONG_CONTEXT_ARCHS = {
    "jamba_1_5_large_398b",  # hybrid SSM
    "xlstm_350m",            # SSM
    "mixtral_8x7b",          # SWA: KV bounded by window
    "gemma3_12b",            # 5:1 local:global
}
