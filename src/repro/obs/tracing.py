"""Span tracing with Chrome trace-event export (DESIGN.md §12).

``Tracer.span`` is a context manager recording a complete ("X"-phase)
event; ``Tracer.instant`` records a point event. Raw timestamps are kept
as the tracer clock's float **seconds** (``time.perf_counter`` by
default) — latency derivations (TTFT, TPOT) subtract raw floats so they
are bitwise-identical to the legacy ad-hoc timers they replace — and are
converted to the Chrome format's microseconds only at export.
``chrome_trace()`` emits the ``{"traceEvents": [...]}`` JSON object that
Perfetto / chrome://tracing load directly.

Like the metrics registry, a disabled tracer records nothing and costs a
single flag check per call.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Dict, Iterator, List, Optional, Tuple


class Tracer:
    """Append-only span/instant recorder with a Chrome-JSON exporter.

    Thread-safe appends; ``events`` entries are dicts with raw-seconds
    ``t`` (start) and, for spans, ``dur`` (seconds). ``enabled`` may be
    flipped at runtime (a span open across the flip still records)."""

    def __init__(self, enabled: bool = True, clock=time.perf_counter):
        self.enabled = enabled
        self.clock = clock
        self.events: List[dict] = []
        self._lock = threading.Lock()
        self._depth = threading.local()

    def _tid(self) -> int:
        return threading.get_ident() & 0x7FFFFFFF

    @contextlib.contextmanager
    def span(self, name: str, t0: Optional[float] = None,
             **args) -> Iterator[None]:
        """Record a complete event covering the with-block.

        ``t0`` overrides the recorded start time (raw clock seconds) so a
        caller that already stamped the moment — e.g. the serve loop's
        ``_run_t0`` — gets a span whose start is bitwise that stamp."""
        if not self.enabled:
            yield
            return
        start = self.clock() if t0 is None else t0
        d = getattr(self._depth, "v", 0)
        self._depth.v = d + 1
        try:
            yield
        finally:
            self._depth.v = d
            end = self.clock()
            with self._lock:
                self.events.append({
                    "name": name, "ph": "X", "t": start,
                    "dur": max(end - start, 0.0), "tid": self._tid(),
                    "depth": d, "args": args,
                })

    def complete(self, name: str, t0: float, t1: float, **args) -> None:
        """Record a complete span from two already-captured raw stamps —
        for callers that time a phase with their own clock reads and only
        afterwards know it is worth recording."""
        if not self.enabled:
            return
        with self._lock:
            self.events.append({
                "name": name, "ph": "X", "t": t0,
                "dur": max(t1 - t0, 0.0), "tid": self._tid(),
                "depth": getattr(self._depth, "v", 0), "args": args,
            })

    def instant(self, name: str, t: Optional[float] = None, **args) -> None:
        """Record a point event at ``t`` (raw clock seconds; now when
        omitted). ``args`` land in the Chrome event's ``args`` object."""
        if not self.enabled:
            return
        stamp = self.clock() if t is None else t
        with self._lock:
            self.events.append({
                "name": name, "ph": "i", "t": stamp, "tid": self._tid(),
                "args": args,
            })

    def clear(self) -> None:
        """Drop all recorded events."""
        with self._lock:
            self.events.clear()

    def chrome_trace(self) -> dict:
        """The Chrome trace-event JSON object: timestamps rebased to the
        earliest event and scaled to microseconds; spans are "X" events
        with ``dur``, instants are "i" events with process scope."""
        with self._lock:
            events = list(self.events)
        if not events:
            return {"traceEvents": []}
        base = min(e["t"] for e in events)
        out = []
        for e in events:
            ce = {
                "name": e["name"], "ph": e["ph"], "pid": 0,
                "tid": e["tid"], "ts": (e["t"] - base) * 1e6,
                "args": {k: _jsonable(v) for k, v in e["args"].items()},
            }
            if e["ph"] == "X":
                ce["dur"] = e["dur"] * 1e6
            else:
                ce["s"] = "p"
            out.append(ce)
        return {"traceEvents": out}

    def write(self, path: str) -> None:
        """Write ``chrome_trace()`` as JSON to ``path``."""
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh, indent=1)


def span_coverage(events: List[dict]) -> float:
    """Fraction of the trace's wall window covered by the union of its
    spans (raw-event form, i.e. ``Tracer.events``). 1.0 for an empty or
    span-free trace — nothing claimed, nothing missing."""
    spans = [(e["t"], e["t"] + e["dur"]) for e in events if e["ph"] == "X"]
    if not spans:
        return 1.0
    t_lo = min(s for s, _ in spans)
    t_hi = max(e for _, e in spans)
    if t_hi <= t_lo:
        return 1.0
    covered = 0.0
    cur_s, cur_e = None, None
    for s, e in sorted(spans):
        if cur_s is None:
            cur_s, cur_e = s, e
        elif s <= cur_e:
            cur_e = max(cur_e, e)
        else:
            covered += cur_e - cur_s
            cur_s, cur_e = s, e
    covered += cur_e - cur_s
    return covered / (t_hi - t_lo)


def chrome_span_coverage(trace: dict) -> float:
    """``span_coverage`` over an exported ``{"traceEvents": ...}`` object
    (microsecond timestamps) — what scripts/obs_check.py validates."""
    raw = [{"ph": e["ph"], "t": e.get("ts", 0.0),
            "dur": e.get("dur", 0.0)}
           for e in trace.get("traceEvents", [])]
    return span_coverage(raw)


def derive_request_latencies(
        events: List[dict], *,
        run_span: str = "serve.run",
        first_token: str = "serve.first_token",
        token: str = "serve.token",
) -> Tuple[Dict[int, float], Dict[int, float]]:
    """Per-request (TTFT, TPOT) derived from raw tracer events.

    TTFT for request ``rid`` is the ``first_token`` instant's raw stamp
    minus the enclosing ``run_span`` start — the same float subtraction
    the legacy ``PagedServer.ttft_s`` dict performed, so the two agree
    bitwise. TPOT is the mean gap between that request's successive
    ``token`` instants (empty dict entries for single-token requests)."""
    run_t0 = None
    for e in events:
        if e["name"] == run_span and e["ph"] == "X":
            run_t0 = e["t"]
            break
    ttft: Dict[int, float] = {}
    stamps: Dict[int, List[float]] = {}
    for e in events:
        rid = e["args"].get("rid") if e.get("args") else None
        if rid is None:
            continue
        if e["name"] == first_token and run_t0 is not None:
            ttft[rid] = e["t"] - run_t0
        if e["name"] in (first_token, token):
            stamps.setdefault(rid, []).append(e["t"])
    tpot = {
        rid: (ts[-1] - ts[0]) / (len(ts) - 1)
        for rid, ts in stamps.items() if len(ts) > 1
    }
    return ttft, tpot


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return str(v)
