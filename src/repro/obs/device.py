"""On-device router/expert telemetry accumulators (DESIGN.md §12).

``expert_stats`` computes, *inside* the jitted MoE islands, the per-expert
token-slot counts, capacity-overflow drops, and gate-entropy sums the
observability layer publishes — as plain extra jit outputs, so enabling
them (``ParallelConfig.collect_router_stats``) changes the step's output
pytree but adds no host synchronisation. The counts are exact integers:
a host-side recount of the same routing decisions (``np.bincount`` over
``expert_idx``) matches bitwise (pinned by tests/test_obs.py).

``RouterStatsDrain`` is the asynchronous host side: ``push()`` only keeps
references to the device arrays (on an async backend those are futures —
no block), and ``flush()`` — called at metrics-dump boundaries, never in
the tick/step hot path — materialises them with ``np.asarray`` and folds
them into the metrics registry, preserving push order.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

STAT_KEYS = ("expert_tokens", "dropped_tokens", "entropy_sum", "tokens")


def zero_stats(num_experts: int) -> Dict[str, jax.Array]:
    """The all-zero stats pytree (scan-carry init / dense-layer filler)."""
    return {
        "expert_tokens": jnp.zeros((num_experts,), jnp.int32),
        "dropped_tokens": jnp.zeros((), jnp.int32),
        "entropy_sum": jnp.zeros((), jnp.float32),
        "tokens": jnp.zeros((), jnp.int32),
    }


def add_stats(a: Dict[str, jax.Array],
              b: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Elementwise sum of two stats pytrees (layer/period accumulation)."""
    return {k: a[k] + b[k] for k in STAT_KEYS}


def expert_stats(
    expert_idx: jax.Array,          # (N, k) int32 routed expert ids
    probs: jax.Array,               # (N, E) f32 full router distribution
    num_experts: int,
    valid_mask: Optional[jax.Array] = None,   # (N,) bool hetero tail mask
    dropped: Optional[jax.Array] = None,      # () int32 capacity drops
) -> Dict[str, jax.Array]:
    """Device-side router telemetry for one MoE layer's routing decisions.

    ``expert_tokens[e]`` counts valid token-slot assignments to expert
    ``e`` (a token routed to k experts contributes k assignments) —
    integer-exact, so the host recount comparison is bitwise. The entropy
    sum is over each valid token's full router distribution (natural log,
    gradient-stopped — telemetry must not grow the backward graph);
    ``tokens`` is the valid-token count the host divides by for the mean.
    """
    n, k = expert_idx.shape
    idx = jax.lax.stop_gradient(expert_idx)
    p = jax.lax.stop_gradient(probs).astype(jnp.float32)
    if valid_mask is None:
        vtok = jnp.ones((n,), jnp.int32)
    else:
        vtok = valid_mask.astype(jnp.int32)
    w = jnp.broadcast_to(vtok[:, None], (n, k)).reshape(-1)
    counts = jnp.zeros((num_experts,), jnp.int32).at[idx.reshape(-1)].add(
        w, mode="drop")
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log(jnp.maximum(p, 1e-30)), 0.0),
                   axis=-1)
    return {
        "expert_tokens": counts,
        "dropped_tokens": (jnp.zeros((), jnp.int32) if dropped is None
                           else dropped.astype(jnp.int32)),
        "entropy_sum": jnp.sum(ent * vtok.astype(jnp.float32)),
        "tokens": jnp.sum(vtok),
    }


def load_imbalance(expert_tokens: np.ndarray) -> float:
    """Host-side load-imbalance factor: max over experts / mean over
    experts of the token counts (1.0 = perfectly balanced; 0 when no
    tokens were routed)."""
    counts = np.asarray(expert_tokens, np.float64)
    mean = counts.mean()
    return float(counts.max() / mean) if mean > 0 else 0.0


class RouterStatsDrain:
    """Asynchronous device→host drain of ``expert_stats`` outputs.

    ``push`` is O(1) and never synchronises — it appends the device
    arrays (futures on async backends) to a bounded pending list.
    ``flush`` materialises and aggregates everything pending into the
    registry, in push order (DESIGN.md §12 drain-ordering guarantee:
    within one drain, step ``i``'s contribution lands before step
    ``i+1``'s; flush never runs concurrently with push — both belong to
    the driver thread)."""

    def __init__(self, registry, num_experts: int, phase: str,
                 max_pending: int = 4096):
        self.registry = registry
        self.num_experts = num_experts
        self.phase = phase
        self.max_pending = max_pending
        self._pending: List[dict] = []
        self.total = np.zeros((num_experts,), np.int64)
        self.total_dropped = 0
        self.total_tokens = 0
        self.entropy_sum = 0.0

    def push(self, stats: Optional[dict]) -> None:
        """Queue one step's device stats (no device→host copy happens
        here). Auto-flushes only if the pending list hits its bound."""
        if stats is None:
            return
        self._pending.append(stats)
        if len(self._pending) >= self.max_pending:
            self.flush()

    def flush(self) -> None:
        """Materialise all pending device stats and publish: per-expert
        token counters, drop counters, the routed-token counter, and the
        derived gate-entropy / load-imbalance gauges."""
        if not self._pending:
            self._publish_gauges()
            return
        pending, self._pending = self._pending, []
        for st in pending:
            self.total += np.asarray(st["expert_tokens"], np.int64)
            self.total_dropped += int(np.asarray(st["dropped_tokens"]))
            self.total_tokens += int(np.asarray(st["tokens"]))
            self.entropy_sum += float(np.asarray(st["entropy_sum"]))
        reg = self.registry
        c = reg.counter("repro_router_expert_tokens_total",
                        "per-expert routed token-slot assignments",
                        labels=("phase", "expert"))
        # counters are monotonic: re-publish by setting the delta between
        # the running total and what the series already holds
        for e in range(self.num_experts):
            cur = _series_value(c, (self.phase, str(e)))
            c.labels(self.phase, str(e)).inc(float(self.total[e]) - cur)
        d = reg.counter("repro_router_dropped_tokens_total",
                        "capacity-overflow dropped token slots",
                        labels=("phase",))
        d.labels(self.phase).inc(
            float(self.total_dropped) - _series_value(d, (self.phase,)))
        t = reg.counter("repro_router_routed_tokens_total",
                        "valid tokens routed through MoE layers",
                        labels=("phase",))
        t.labels(self.phase).inc(
            float(self.total_tokens) - _series_value(t, (self.phase,)))
        self._publish_gauges()

    def _publish_gauges(self) -> None:
        reg = self.registry
        if self.total_tokens > 0:
            reg.gauge("repro_router_gate_entropy",
                      "mean router-distribution entropy (nats)",
                      labels=("phase",)).labels(self.phase).set(
                self.entropy_sum / self.total_tokens)
        if self.total.sum() > 0:
            reg.gauge("repro_router_load_imbalance",
                      "max/mean per-expert token load",
                      labels=("phase",)).labels(self.phase).set(
                load_imbalance(self.total))


def _series_value(family, key: tuple) -> float:
    child = getattr(family, "children", {}).get(key)
    return float(child.value) if child is not None else 0.0
