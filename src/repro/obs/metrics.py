"""Process-local metrics registry (DESIGN.md §12).

Counters, gauges, and histograms with fixed log-spaced buckets, organised
as *families* of labeled series (one family per metric name, one child per
label-value tuple). The registry is thread-safe (one lock around every
mutation) and **no-op when disabled**: a disabled registry hands every
caller the same shared no-op family/child singletons, so an instrumented
hot path costs one attribute read and one dict hit — nothing is allocated
and nothing is recorded.

Naming convention (enforced by style, validated by scripts/obs_check.py):
``repro_<subsystem>_<what>[_<unit>]`` with the Prometheus ``_total``
suffix on counters, e.g. ``repro_router_expert_tokens_total``,
``repro_serve_decode_step_seconds`` (a histogram), or
``repro_pagepool_free_pages`` (a gauge). Label values are always strings.

Library code never holds child handles across enable/disable flips — the
idiom is ``registry.counter(name).labels(v).inc()`` at the event site, so
a registry enabled mid-process picks the site up on its next event.
"""
from __future__ import annotations

import math
import threading
import weakref
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


def log_buckets(lo: float, hi: float, per_decade: int = 3) -> Tuple[float, ...]:
    """Fixed log-spaced histogram bucket upper bounds covering [lo, hi].

    ``per_decade`` bounds per power of ten; the list always ends at a
    bound >= hi so every finite observation lands in a real bucket (the
    rendered text still appends the Prometheus ``+Inf`` bucket)."""
    if lo <= 0 or hi <= lo:
        raise ValueError(f"need 0 < lo < hi, got ({lo}, {hi})")
    n = int(math.ceil(per_decade * math.log10(hi / lo)))
    return tuple(lo * 10 ** (i / per_decade) for i in range(n + 1))


#: Default latency buckets: 10µs .. 100s, 3 per decade (DESIGN.md §12).
DEFAULT_SECONDS_BUCKETS = log_buckets(1e-5, 100.0, per_decade=3)


class _NoopChild:
    """Shared do-nothing series: every mutator is a pass."""

    __slots__ = ()

    def inc(self, n: float = 1.0) -> None:
        """No-op."""

    def set(self, v: float) -> None:
        """No-op."""

    def observe(self, v: float) -> None:
        """No-op."""


class _NoopFamily(_NoopChild):
    """Shared do-nothing family: ``labels()`` returns the no-op child."""

    __slots__ = ()

    def labels(self, *values: str) -> "_NoopChild":
        """Return the shared no-op child regardless of label values."""
        return _NOOP_CHILD


_NOOP_CHILD = _NoopChild()
_NOOP_FAMILY = _NoopFamily()


class _Counter:
    """Monotonically-increasing series."""

    __slots__ = ("value", "_lock")

    def __init__(self, lock: threading.Lock):
        self.value = 0.0
        self._lock = lock

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (must be >= 0) to the counter."""
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        with self._lock:
            self.value += n


class _Gauge:
    """Last-write-wins series."""

    __slots__ = ("value", "_lock")

    def __init__(self, lock: threading.Lock):
        self.value = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        """Set the gauge to ``v``."""
        with self._lock:
            self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        """Adjust the gauge by ``n`` (may be negative)."""
        with self._lock:
            self.value += n


class _Histogram:
    """Cumulative-bucket histogram over fixed upper bounds."""

    __slots__ = ("buckets", "counts", "sum", "count", "_lock")

    def __init__(self, lock: threading.Lock, buckets: Sequence[float]):
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)  # +1 = +Inf
        self.sum = 0.0
        self.count = 0
        self._lock = lock

    def observe(self, v: float) -> None:
        """Record one observation."""
        with self._lock:
            i = 0
            for i, b in enumerate(self.buckets):  # noqa: B007 — small, fixed
                if v <= b:
                    break
            else:
                i = len(self.buckets)
            self.counts[i] += 1
            self.sum += v
            self.count += 1


class Family:
    """One metric name holding one child series per label-value tuple."""

    def __init__(self, registry: "MetricsRegistry", name: str, kind: str,
                 help_text: str, label_names: Tuple[str, ...],
                 buckets: Optional[Sequence[float]] = None):
        self.registry = registry
        self.name = name
        self.kind = kind
        self.help = help_text
        self.label_names = label_names
        self.buckets = buckets
        self.children: Dict[Tuple[str, ...], object] = {}

    def labels(self, *values) -> object:
        """The child series for the given label values (created on first
        use). Call with no arguments on an unlabeled family."""
        key = tuple(str(v) for v in values)
        if len(key) != len(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {key}")
        child = self.children.get(key)
        if child is None:
            with self.registry._lock:
                child = self.children.get(key)
                if child is None:
                    lock = self.registry._series_lock
                    if self.kind == "counter":
                        child = _Counter(lock)
                    elif self.kind == "gauge":
                        child = _Gauge(lock)
                    else:
                        child = _Histogram(
                            lock, self.buckets or DEFAULT_SECONDS_BUCKETS)
                    self.children[key] = child
        return child

    # Unlabeled convenience: family acts as its own default child.
    def inc(self, n: float = 1.0) -> None:
        """Increment the unlabeled series."""
        self.labels().inc(n)

    def set(self, v: float) -> None:
        """Set the unlabeled series."""
        self.labels().set(v)

    def observe(self, v: float) -> None:
        """Observe into the unlabeled series."""
        self.labels().observe(v)


class MetricsRegistry:
    """Process-local registry of metric families (DESIGN.md §12).

    ``enabled`` may be flipped at runtime: while False every accessor
    returns the shared no-op singletons (zero allocation, nothing
    recorded); flipping to True makes the *next* accessor call at each
    instrumented site record for real. ``register_object`` keeps a
    weakref to any object exposing ``obs_metrics() -> dict`` — those are
    polled (as gauges) at collection time, so counters that live on hot
    host paths (page pool, prefix index, pipeline cache) publish snapshots
    with zero per-increment overhead."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()        # family/registration mutations
        self._series_lock = threading.Lock()  # series value mutations
        self.families: Dict[str, Family] = {}
        self._objects: List[weakref.ref] = []
        self._object_seq = 0

    def _family(self, name: str, kind: str, help_text: str,
                labels: Tuple[str, ...],
                buckets: Optional[Sequence[float]] = None):
        if not self.enabled:
            return _NOOP_FAMILY
        fam = self.families.get(name)
        if fam is None:
            with self._lock:
                fam = self.families.get(name)
                if fam is None:
                    fam = Family(self, name, kind, help_text, labels, buckets)
                    self.families[name] = fam
        if fam.kind != kind:
            raise ValueError(
                f"metric {name} already registered as {fam.kind}")
        return fam

    def counter(self, name: str, help: str = "",
                labels: Tuple[str, ...] = ()):
        """The counter family ``name`` (no-op family when disabled)."""
        return self._family(name, "counter", help, tuple(labels))

    def gauge(self, name: str, help: str = "",
              labels: Tuple[str, ...] = ()):
        """The gauge family ``name`` (no-op family when disabled)."""
        return self._family(name, "gauge", help, tuple(labels))

    def histogram(self, name: str, help: str = "",
                  labels: Tuple[str, ...] = (),
                  buckets: Optional[Sequence[float]] = None):
        """The histogram family ``name`` with fixed log-spaced ``buckets``
        (``DEFAULT_SECONDS_BUCKETS`` when omitted)."""
        return self._family(name, "histogram", help, tuple(labels), buckets)

    def register_object(self, obj) -> None:
        """Keep a weakref to ``obj`` (which must expose ``obs_metrics()``)
        for snapshot polling at collection time. Safe to call while the
        registry is disabled — the object is polled once enabled."""
        with self._lock:
            self._objects.append(weakref.ref(obj))
            self._object_seq += 1

    def collect(self) -> None:
        """Poll every live registered object's ``obs_metrics()`` snapshot
        into gauges labeled by ``kind`` (the object's class name) and
        ``instance`` (its registration order). Dead weakrefs are pruned."""
        if not self.enabled:
            return
        with self._lock:
            refs = list(self._objects)
        live = []
        for i, ref in enumerate(refs):
            obj = ref()
            if obj is None:
                continue
            live.append(ref)
            kind = type(obj).__name__.lower()
            for name, val in obj.obs_metrics().items():
                self.gauge(name, labels=("kind", "instance")).labels(
                    kind, str(i)).set(float(val))
        with self._lock:
            self._objects = live if len(live) != len(refs) else refs

    def value(self, name: str, *label_values) -> float:
        """Current value of a counter/gauge series (tests/driver reads);
        raises KeyError when the series does not exist."""
        fam = self.families[name]
        child = fam.children[tuple(str(v) for v in label_values)]
        return child.value

    def render_prometheus(self) -> str:
        """Render every family in the Prometheus text exposition format
        (``# HELP`` / ``# TYPE`` headers, one line per series, histograms
        as cumulative ``_bucket{le=...}`` + ``_sum`` + ``_count``).
        Polls registered snapshot objects first."""
        self.collect()
        out: List[str] = []
        with self._lock:
            fams = sorted(self.families.items())
        for name, fam in fams:
            if fam.help:
                out.append(f"# HELP {name} {fam.help}")
            out.append(f"# TYPE {name} {fam.kind}")
            for key in sorted(fam.children):
                child = fam.children[key]
                lbl = _labels_text(fam.label_names, key)
                if fam.kind == "histogram":
                    cum = 0
                    for b, c in zip(child.buckets, child.counts):
                        cum += c
                        le = _labels_text(
                            fam.label_names + ("le",), key + (_fmt(b),))
                        out.append(f"{name}_bucket{le} {cum}")
                    cum += child.counts[-1]
                    le = _labels_text(
                        fam.label_names + ("le",), key + ("+Inf",))
                    out.append(f"{name}_bucket{le} {cum}")
                    out.append(f"{name}_sum{lbl} {_fmt(child.sum)}")
                    out.append(f"{name}_count{lbl} {child.count}")
                else:
                    out.append(f"{name}{lbl} {_fmt(child.value)}")
        return "\n".join(out) + "\n" if out else ""


def _fmt(v: float) -> str:
    """Prometheus number formatting: integral floats render bare."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _labels_text(names: Iterable[str], values: Tuple[str, ...]) -> str:
    """Render a ``{k="v",...}`` label block ('' when unlabeled)."""
    pairs = [f'{k}="{_escape(v)}"' for k, v in zip(names, values)]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")
