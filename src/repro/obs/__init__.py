"""Unified observability subsystem (DESIGN.md §12).

Three pillars behind one module-level handle:

* **metrics** — a process-local :class:`MetricsRegistry` (counters,
  gauges, log-bucket histograms; labeled series; thread-safe; no-op when
  disabled) plus the on-device router accumulators in ``obs.device``.
* **tracing** — :class:`Tracer` span/instant recording with Chrome
  trace-event JSON export (Perfetto-loadable).
* **exporters** — Prometheus text dumps and the JSONL event log.

The process-wide instances are created **disabled** at import, so
instrumented library code (`runtime.ft`, `runtime.straggler`,
`parallel.cache`, `parallel.autotune`, the serve scheduler) pays one
flag check per event until a driver calls :func:`configure`. The
instances are persistent — ``configure`` flips their ``enabled`` flags
rather than swapping objects, so snapshot objects registered before
enablement still publish afterwards.
"""
from __future__ import annotations

from repro.obs.device import (  # noqa: F401 — re-exports
    RouterStatsDrain,
    add_stats,
    expert_stats,
    load_imbalance,
    zero_stats,
)
from repro.obs.exporters import EventLog, dump_prometheus  # noqa: F401
from repro.obs.metrics import (  # noqa: F401
    DEFAULT_SECONDS_BUCKETS,
    MetricsRegistry,
    log_buckets,
)
from repro.obs.tracing import (  # noqa: F401
    Tracer,
    chrome_span_coverage,
    derive_request_latencies,
    span_coverage,
)

#: Process-wide instances, disabled until a driver calls configure().
registry = MetricsRegistry(enabled=False)
tracer = Tracer(enabled=False)
events = EventLog(enabled=False)


def configure(metrics: bool = True, tracing: bool = True,
              event_log: bool = True, reset: bool = False) -> None:
    """Enable (or disable) the process-wide observability instances.

    ``reset`` clears previously recorded spans/events — drivers use it so
    back-to-back runs in one process (tests, benchmarks) start clean."""
    registry.enabled = metrics
    tracer.enabled = tracing
    events.enabled = event_log
    if reset:
        tracer.clear()
        events.records.clear()
        registry.families.clear()


def enabled() -> bool:
    """True when any pillar is currently recording."""
    return registry.enabled or tracer.enabled or events.enabled


def maybe_register(obj) -> None:
    """Register ``obj`` (exposing ``obs_metrics()``) for snapshot polling
    on the process-wide registry — always safe, weakref-held, and cheap,
    so constructors call it unconditionally."""
    registry.register_object(obj)
