"""Exporters: Prometheus text dumps and the structured JSONL event log
(DESIGN.md §12).

``dump_prometheus`` writes the registry's text exposition atomically
(write-temp-then-rename) so a scraper tailing the file never reads a
torn dump. ``EventLog`` is the machine-readable sibling of the human
trace: every scheduler/recovery/replan event lands as one JSON object
per line with a monotonic timestamp and a ``reason`` field — the
post-mortem ordering record the chaos tests lacked.
"""
from __future__ import annotations

import json
import os
import time
from typing import List, Optional


def dump_prometheus(registry, path: str) -> None:
    """Atomically write ``registry.render_prometheus()`` to ``path``."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as fh:
        fh.write(registry.render_prometheus())
    os.replace(tmp, path)


class EventLog:
    """Structured event recorder with JSONL export.

    Each ``emit`` stamps the event with the injectable monotonic clock
    (``time.perf_counter`` default — the same clock the serve scheduler
    uses, so event times interleave correctly with spans) plus a
    ``reason`` field (may be None) and arbitrary JSON-able context.
    Disabled logs record nothing."""

    def __init__(self, enabled: bool = True, clock=time.perf_counter):
        self.enabled = enabled
        self.clock = clock
        self.records: List[dict] = []

    def emit(self, kind: str, reason: Optional[str] = None,
             t: Optional[float] = None, **fields) -> None:
        """Record one event (no-op when disabled). ``t`` overrides the
        stamp for call sites that already captured the moment."""
        if not self.enabled:
            return
        rec = {"t": self.clock() if t is None else t, "kind": kind,
               "reason": reason}
        rec.update(fields)
        self.records.append(rec)

    def write_jsonl(self, path: str) -> None:
        """Write one JSON object per line (atomic rename, like the
        Prometheus dump)."""
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as fh:
            for rec in self.records:
                fh.write(json.dumps(rec, default=str) + "\n")
        os.replace(tmp, path)
