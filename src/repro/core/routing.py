"""Top-k expert routing.

The router is shared by every MoE execution path (expert-specific ops,
dispatch/combine baseline, grouped-GeMM baseline) so that correctness
comparisons are apples-to-apples: identical logits -> identical assignment.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class RouterOutput(NamedTuple):
    expert_idx: jax.Array  # (N, k) int32 — chosen expert per slot
    gates: jax.Array       # (N, k) float32 — combine weights
    aux_loss: jax.Array    # scalar — load-balancing auxiliary loss
    z_loss: jax.Array      # scalar — router z-loss
    probs: jax.Array       # (N, E) float32 — full router probabilities


def route(
    x: jax.Array,
    router_w: jax.Array,
    k: int,
    *,
    norm_topk: bool = True,
    softmax_after_topk: bool = False,
    noise_rng: Optional[jax.Array] = None,
    noise_eps: float = 1e-2,
    valid_mask: Optional[jax.Array] = None,
) -> RouterOutput:
    """Compute top-k routing for a flat token batch.

    Args:
      x: (N, D) tokens.
      router_w: (D, E) router weights.
      k: number of experts per token.
      norm_topk: renormalise top-k probabilities to sum to 1 (Qwen-style).
      softmax_after_topk: softmax over the selected top-k logits only
        (Mixtral-style) instead of selecting from the full softmax.
      noise_rng: optional PRNG key for multiplicative jitter (training).
      valid_mask: optional (N,) bool — heterogeneous-plan tail masking
        (DESIGN.md §6): invalid rows get gate 0 (⇒ exactly-zero combine
        output and exactly-zero weight gradients through them) and are
        excluded from the aux/z losses. ``None`` keeps the original op
        sequence bit-for-bit.
    """
    n, _ = x.shape
    e = router_w.shape[-1]
    logits = jnp.dot(x.astype(jnp.float32), router_w.astype(jnp.float32))
    if noise_rng is not None:
        jitter = jax.random.uniform(
            noise_rng, logits.shape, jnp.float32, 1.0 - noise_eps, 1.0 + noise_eps
        )
        logits = logits * jitter

    probs = jax.nn.softmax(logits, axis=-1)
    if softmax_after_topk:
        top_logits, expert_idx = jax.lax.top_k(logits, k)
        gates = jax.nn.softmax(top_logits, axis=-1)
    else:
        gates, expert_idx = jax.lax.top_k(probs, k)
        if norm_topk:
            gates = gates / jnp.maximum(
                jnp.sum(gates, axis=-1, keepdims=True), 1e-9
            )

    # Switch-Transformer style load-balance loss: E * sum_e f_e * P_e where
    # f_e is the fraction of token-slots routed to e, P_e the mean prob.
    one_hot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (N, k, E)
    if valid_mask is None:
        f_e = jnp.mean(jnp.sum(one_hot, axis=1), axis=0) / k    # (E,)
        p_e = jnp.mean(probs, axis=0)                            # (E,)
        aux_loss = e * jnp.sum(f_e * p_e)
        z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    else:
        vm = valid_mask.astype(jnp.float32)                      # (N,)
        gates = gates * vm[:, None].astype(gates.dtype)
        denom = jnp.maximum(jnp.sum(vm), 1.0)
        f_e = jnp.sum(jnp.sum(one_hot, axis=1) * vm[:, None], 0) / denom / k
        p_e = jnp.sum(probs * vm[:, None], axis=0) / denom
        aux_loss = e * jnp.sum(f_e * p_e)
        z_loss = (
            jnp.sum(jax.nn.logsumexp(logits, axis=-1) ** 2 * vm) / denom
        )

    return RouterOutput(
        expert_idx=expert_idx.astype(jnp.int32),
        gates=gates.astype(jnp.float32),
        aux_loss=aux_loss,
        z_loss=z_loss,
        probs=probs,
    )
