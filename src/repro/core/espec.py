"""Layer-level expert-specific MoE computation (paper Fig. 3, in-place form).

The full pipeline for one MoE FFN:

  route -> build_reindex -> gather_sorted -> ESMM -> act -> ESMM -> combine

with zero computation redundancy: no capacity factor, no token drop, at most
BLK-1 pad rows per expert. Autodiff flows through the custom-vjp'd ``esmm``
(dX via ESMM, dW/db via the fused ESFK), i.e. exactly the paper's Table 5.

With ``fused`` on (default for the TPU ``pallas`` impl) the gather/ESMM/act/
ESMM/gate stages collapse into ONE fused-FFN op (``kernels.ops.esffn_*``,
the Pallas megakernel of DESIGN.md §5): token rows are gathered straight
from the unsorted activations, the (Np, F) hidden never touches HBM, and
only the final scatter-add combine remains outside.

Two expert body types are supported:
  * ``moe_mlp`` — the paper's 2-MLP expert (Swin-MoE, classic GShard FFN).
  * ``moe_glu`` — gate/up/down GLU experts (Mixtral / Qwen3 / Jamba).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax

from repro.common import ACTIVATIONS
from repro.core.reindex import (
    ReIndex,
    build_reindex,
    combine_scatter,
    gather_sorted,
    scatter_rows,
)
from repro.core.routing import RouterOutput, route
from repro.kernels import ops

__all__ = [
    "ACTIVATIONS", "MoEOutput", "hexa_moe_ffn", "moe_glu", "moe_mlp",
]


def moe_mlp(
    x: jax.Array,
    ri: ReIndex,
    w1: jax.Array,
    b1: Optional[jax.Array],
    w2: jax.Array,
    b2: Optional[jax.Array],
    *,
    act: str = "gelu",
    impl: Optional[str] = None,
    fused: Optional[bool] = None,
) -> jax.Array:
    """Paper-form 2-MLP expert FFN over a flat token batch x: (N, D)."""
    impl = impl or ops.get_default_impl()
    if fused is None:
        fused = ops.default_fused_ffn(impl)
    if fused:
        ys = ops.esffn_mlp(
            x, ri.row_token, ri.row_gate, ri.block_expert, ri.padded_counts,
            w1, b1, w2, b2, act=act, impl=impl,
        )
        return scatter_rows(ys, ri.row_token, x.shape[0])
    f = ACTIVATIONS[act]
    xs = gather_sorted(x, ri)
    h = ops.esmm(xs, w1, b1, ri.block_expert, ri.padded_counts, impl=impl)
    h = f(h)
    ys = ops.esmm(h, w2, b2, ri.block_expert, ri.padded_counts, impl=impl)
    return combine_scatter(ys, ri, x.shape[0])


def moe_glu(
    x: jax.Array,
    ri: ReIndex,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    act: str = "silu",
    impl: Optional[str] = None,
    fused: Optional[bool] = None,
) -> jax.Array:
    """GLU expert FFN: y = (act(x Wg) * (x Wu)) Wd, routed per token."""
    impl = impl or ops.get_default_impl()
    if fused is None:
        fused = ops.default_fused_ffn(impl)
    if fused:
        ys = ops.esffn_glu(
            x, ri.row_token, ri.row_gate, ri.block_expert, ri.padded_counts,
            w_gate, w_up, w_down, act=act, impl=impl,
        )
        return scatter_rows(ys, ri.row_token, x.shape[0])
    f = ACTIVATIONS[act]
    xs = gather_sorted(x, ri)
    g = ops.esmm(xs, w_gate, None, ri.block_expert, ri.padded_counts, impl=impl)
    u = ops.esmm(xs, w_up, None, ri.block_expert, ri.padded_counts, impl=impl)
    h = f(g) * u
    ys = ops.esmm(h, w_down, None, ri.block_expert, ri.padded_counts, impl=impl)
    return combine_scatter(ys, ri, x.shape[0])


class MoEOutput(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array
    z_loss: jax.Array
    router: RouterOutput


def hexa_moe_ffn(
    x: jax.Array,
    params: dict,
    *,
    num_experts: int,
    top_k: int,
    act: str,
    glu: bool,
    blk: int = 128,
    norm_topk: bool = True,
    softmax_after_topk: bool = False,
    noise_rng: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    fused: Optional[bool] = None,
) -> MoEOutput:
    """Complete Hexa-MoE FFN: routing + expert-specific computation.

    x: (N, D) flat tokens. params holds 'router' (D, E) plus either
    {'w1','b1','w2','b2'} (mlp) or {'w_gate','w_up','w_down'} (glu).
    ``fused``: collapse the FFN stages into the single fused op (None =
    impl default: on for pallas).
    """
    r = route(
        x,
        params["router"],
        top_k,
        norm_topk=norm_topk,
        softmax_after_topk=softmax_after_topk,
        noise_rng=noise_rng,
    )
    ri = build_reindex(r.expert_idx, r.gates, num_experts, blk)
    if glu:
        y = moe_glu(
            x,
            ri,
            params["w_gate"],
            params["w_up"],
            params["w_down"],
            act=act,
            impl=impl,
            fused=fused,
        )
    else:
        y = moe_mlp(
            x,
            ri,
            params["w1"],
            params.get("b1"),
            params["w2"],
            params.get("b2"),
            act=act,
            impl=impl,
            fused=fused,
        )
    return MoEOutput(y=y, aux_loss=r.aux_loss, z_loss=r.z_loss, router=r)
