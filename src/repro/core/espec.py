"""Layer-level expert-specific MoE computation (paper Fig. 3, in-place form).

The full pipeline for one MoE FFN:

  route -> build_reindex -> gather_sorted -> ESMM -> act -> ESMM -> combine

with zero computation redundancy: no capacity factor, no token drop, at most
BLK-1 pad rows per expert. Autodiff flows through the custom-vjp'd ``esmm``
(dX via ESMM, dW/db via the fused ESFK), i.e. exactly the paper's Table 5.

With ``fused`` on (default for the TPU ``pallas`` impl) the gather/ESMM/act/
ESMM/gate stages collapse into ONE fused-FFN op (``kernels.ops.esffn_*``,
the Pallas megakernel of DESIGN.md §5): token rows are gathered straight
from the unsorted activations, the (Np, F) hidden never touches HBM, and
only the final scatter-add combine remains outside.

Two expert body types are supported:
  * ``moe_mlp`` — the paper's 2-MLP expert (Swin-MoE, classic GShard FFN).
  * ``moe_glu`` — gate/up/down GLU experts (Mixtral / Qwen3 / Jamba).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax

from repro.common import ACTIVATIONS
from repro.core.reindex import (
    ReIndex,
    build_reindex,
    combine_scatter,
    gather_sorted,
    scatter_rows,
)
from repro.core.routing import RouterOutput, route
from repro.kernels import ops

__all__ = [
    "ACTIVATIONS", "MoEOutput", "hexa_moe_ffn", "moe_glu", "moe_mlp",
]


def moe_mlp(
    x: jax.Array,
    ri: ReIndex,
    w1: jax.Array,
    b1: Optional[jax.Array],
    w2: jax.Array,
    b2: Optional[jax.Array],
    *,
    scales=None,
    act: str = "gelu",
    impl: Optional[str] = None,
    fused: Optional[bool] = None,
) -> jax.Array:
    """Paper-form 2-MLP expert FFN over a flat token batch x: (N, D).

    ``scales``: (s1, s2) block-wise scales when w1/w2 are int8/fp8
    payloads (DESIGN.md §8) — dequant fuses into the ES kernels."""
    impl = impl or ops.get_default_impl()
    if fused is None:
        fused = ops.default_fused_ffn(impl)
    if fused:
        ys = ops.esffn_mlp(
            x, ri.row_token, ri.row_gate, ri.block_expert, ri.padded_counts,
            w1, b1, w2, b2, scales=scales, act=act, impl=impl,
        )
        return scatter_rows(ys, ri.row_token, x.shape[0])
    f = ACTIVATIONS[act]
    s1, s2 = scales if scales is not None else (None, None)
    xs = gather_sorted(x, ri)
    h = ops.esmm(xs, w1, b1, ri.block_expert, ri.padded_counts, impl=impl,
                 w_scales=s1)
    h = f(h)
    ys = ops.esmm(h, w2, b2, ri.block_expert, ri.padded_counts, impl=impl,
                  w_scales=s2)
    return combine_scatter(ys, ri, x.shape[0])


def moe_glu(
    x: jax.Array,
    ri: ReIndex,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    scales=None,
    act: str = "silu",
    impl: Optional[str] = None,
    fused: Optional[bool] = None,
) -> jax.Array:
    """GLU expert FFN: y = (act(x Wg) * (x Wu)) Wd, routed per token.

    ``scales``: (sg, su, sd) block-wise scales when the weights are
    int8/fp8 payloads (DESIGN.md §8)."""
    impl = impl or ops.get_default_impl()
    if fused is None:
        fused = ops.default_fused_ffn(impl)
    if fused:
        ys = ops.esffn_glu(
            x, ri.row_token, ri.row_gate, ri.block_expert, ri.padded_counts,
            w_gate, w_up, w_down, scales=scales, act=act, impl=impl,
        )
        return scatter_rows(ys, ri.row_token, x.shape[0])
    f = ACTIVATIONS[act]
    sg, su, sd = scales if scales is not None else (None, None, None)
    xs = gather_sorted(x, ri)
    g = ops.esmm(xs, w_gate, None, ri.block_expert, ri.padded_counts,
                 impl=impl, w_scales=sg)
    u = ops.esmm(xs, w_up, None, ri.block_expert, ri.padded_counts,
                 impl=impl, w_scales=su)
    h = f(g) * u
    ys = ops.esmm(h, w_down, None, ri.block_expert, ri.padded_counts,
                  impl=impl, w_scales=sd)
    return combine_scatter(ys, ri, x.shape[0])


class MoEOutput(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array
    z_loss: jax.Array
    router: RouterOutput


def hexa_moe_ffn(
    x: jax.Array,
    params: dict,
    *,
    num_experts: int,
    top_k: int,
    act: str,
    glu: bool,
    blk: int = 128,
    norm_topk: bool = True,
    softmax_after_topk: bool = False,
    noise_rng: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    fused: Optional[bool] = None,
) -> MoEOutput:
    """Complete Hexa-MoE FFN: routing + expert-specific computation.

    x: (N, D) flat tokens. params holds 'router' (D, E) plus either
    {'w1','b1','w2','b2'} (mlp) or {'w_gate','w_up','w_down'} (glu);
    quantized expert weights carry their block scales as '<name>_scale'
    entries (quant.core.quantize_ffn, DESIGN.md §8) and are detected here.
    """
    r = route(
        x,
        params["router"],
        top_k,
        norm_topk=norm_topk,
        softmax_after_topk=softmax_after_topk,
        noise_rng=noise_rng,
    )
    ri = build_reindex(r.expert_idx, r.gates, num_experts, blk)
    if glu:
        scales = None
        if "w_gate_scale" in params:
            scales = (params["w_gate_scale"], params["w_up_scale"],
                      params["w_down_scale"])
        y = moe_glu(
            x,
            ri,
            params["w_gate"],
            params["w_up"],
            params["w_down"],
            scales=scales,
            act=act,
            impl=impl,
            fused=fused,
        )
    else:
        scales = None
        if "w1_scale" in params:
            scales = (params["w1_scale"], params["w2_scale"])
        y = moe_mlp(
            x,
            ri,
            params["w1"],
            params.get("b1"),
            params["w2"],
            params.get("b2"),
            scales=scales,
            act=act,
            impl=impl,
            fused=fused,
        )
    return MoEOutput(y=y, aux_loss=r.aux_loss, z_loss=r.z_loss, router=r)
