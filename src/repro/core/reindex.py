"""Re-index vector construction (paper §4.2, Algorithm 1) — TPU adaptation.

The CUDA implementation builds a re-index vector with atomicAdd and gathers
rows inside each kernel. On TPU we build the same logical object with one
stable sort, and *materialise* the expert-sorted layout with a single gather
so the Pallas kernels see contiguous, VMEM-tileable blocks:

  - every BLK-row block of the sorted layout belongs to exactly one expert
    (groups are padded to BLK boundaries with sentinel rows, value -1 in the
    paper; here the sentinel gathers an all-zero row),
  - ``block_expert`` is the scalar-prefetch map block -> expert,
  - the inverse mapping (``row_token``/``row_gate``) drives the gate-weighted
    scatter-add combine, which is the TPU analogue of the paper's atomicAdd
    top-k memory optimisation (no (k, N, D) materialisation).

Zero computation redundancy is preserved: padding is at most BLK-1 rows per
expert, versus capacity-factor padding of dispatch/combine implementations.

All shapes are static: Np = round_up(N*k + E*(BLK-1), BLK).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common import round_up

# Default block size: MXU-aligned.
DEFAULT_BLK = 128


class ReIndex(NamedTuple):
    """Static-shape expert-sorted layout descriptor.

    Attributes:
      row_id:       (Np,) int32 — flat copy id (token*k + slot) or sentinel N*k.
      row_token:    (Np,) int32 — source token id, or sentinel N for padding.
      row_gate:     (Np,) f32   — combine gate, 0 for padding rows.
      block_expert: (Np//BLK,) int32 — expert owning each BLK-row block.
      counts:       (E,) int32  — true token-copies per expert.
      padded_counts:(E,) int32  — counts rounded up to BLK (group extents).
    """
    row_id: jax.Array
    row_token: jax.Array
    row_gate: jax.Array
    block_expert: jax.Array
    counts: jax.Array
    padded_counts: jax.Array

    @property
    def num_rows(self) -> int:
        return self.row_id.shape[0]

    @property
    def num_blocks(self) -> int:
        return self.block_expert.shape[0]


def padded_rows(n: int, k: int, num_experts: int, blk: int = DEFAULT_BLK) -> int:
    """Static worst-case number of rows in the sorted layout."""
    return round_up(n * k + num_experts * (blk - 1), blk)


def build_reindex(
    expert_idx: jax.Array,
    gates: jax.Array,
    num_experts: int,
    blk: int = DEFAULT_BLK,
) -> ReIndex:
    """Build the expert-sorted block-padded layout from routing decisions.

    Args:
      expert_idx: (N, k) int32 routing choices.
      gates: (N, k) float combine weights.
      num_experts: E.
      blk: block size (rows per single-expert block).
    """
    n, k = expert_idx.shape
    nk = n * k
    np_rows = padded_rows(n, k, num_experts, blk)

    e_flat = expert_idx.reshape(nk)
    g_flat = gates.reshape(nk).astype(jnp.float32)

    counts = jnp.bincount(e_flat, length=num_experts).astype(jnp.int32)
    padded_counts = ((counts + blk - 1) // blk * blk).astype(jnp.int32)
    # Exclusive cumsum of padded group extents: group e spans
    # [p_offset[e], p_offset[e] + padded_counts[e]).
    p_offset = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(padded_counts)]
    )
    u_offset = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)]
    )

    # Stable sort by expert: order[r] = flat copy id of the r-th sorted row.
    order = jnp.argsort(e_flat, stable=True).astype(jnp.int32)
    e_sorted = e_flat[order]
    # Rank within the expert group, then destination in the padded layout.
    rank = jnp.arange(nk, dtype=jnp.int32) - u_offset[e_sorted]
    dest = p_offset[e_sorted] + rank

    row_id = jnp.full((np_rows,), nk, jnp.int32).at[dest].set(order)
    row_token = jnp.where(row_id == nk, n, row_id // k).astype(jnp.int32)
    gp = jnp.concatenate([g_flat, jnp.zeros((1,), jnp.float32)])
    row_gate = gp[jnp.minimum(row_id, nk)]

    # block -> expert: block b (start s = b*blk) belongs to expert e with
    # p_offset[e] <= s < p_offset[e+1]. Tail blocks past the last group get
    # clamped to E-1; their rows are all sentinels so they compute on zeros.
    starts = jnp.arange(np_rows // blk, dtype=jnp.int32) * blk
    block_expert = (
        jnp.searchsorted(p_offset, starts, side="right").astype(jnp.int32) - 1
    )
    block_expert = jnp.clip(block_expert, 0, num_experts - 1)

    return ReIndex(
        row_id=row_id,
        row_token=row_token,
        row_gate=row_gate,
        block_expert=block_expert,
        counts=counts,
        padded_counts=padded_counts,
    )


def gather_rows(x: jax.Array, row_token: jax.Array) -> jax.Array:
    """Materialise the expert-sorted layout: (Np, D) from (N, D) tokens.

    Sentinel rows (token id == N) gather an appended all-zero row, so padded
    blocks compute on zeros and never contaminate gradients. Single source
    of the sentinel-row convention: the unfused path (``gather_sorted``)
    and the fused op's recompute (``kernels.ops``) both route through here.
    """
    xp = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])
    return xp[row_token]


def gather_sorted(x: jax.Array, ri: ReIndex) -> jax.Array:
    """``gather_rows`` driven by a full ReIndex descriptor."""
    return gather_rows(x, ri.row_token)


def combine_scatter(ys: jax.Array, ri: ReIndex, num_tokens: int) -> jax.Array:
    """Gate-weighted scatter-add combine: (Np, D) sorted rows -> (N, D).

    The TPU analogue of the paper's atomicAdd top-k accumulation: all k
    routed copies of a token are summed in one scatter, never materialising
    per-choice output copies.
    """
    vals = ys * ri.row_gate[:, None].astype(ys.dtype)
    return scatter_rows(vals, ri.row_token, num_tokens)


def scatter_rows(ys: jax.Array, row_token: jax.Array, num_tokens: int) -> jax.Array:
    """Scatter-add ALREADY gate-weighted sorted rows back to token order.

    The combine step for the fused FFN (``kernels.ops.esffn_*``), whose
    kernel applies the gate before writing; sentinel rows (== num_tokens)
    land out of range and are dropped.
    """
    out = jnp.zeros((num_tokens, ys.shape[1]), ys.dtype)
    return out.at[row_token].add(ys, mode="drop")
