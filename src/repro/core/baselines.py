"""Baseline MoE execution paths the paper compares against.

* ``dispatch_combine_moe`` — Tutel-style: tokens are dispatched into a dense
  (E, C, D) capacity buffer (padding + dropping!), experts run as batched
  dense GeMMs, outputs are combined back. This carries the computation
  redundancy Hexa-MoE eliminates: capacity padding is computed like real
  tokens and overflow is dropped (a model-quality compromise).

* ``grouped_dense_moe`` — MegaBlocks(MoE)-style: the same capacity buffer
  with capacity set to the max group size each step (no dropping, all
  padding), which is what grouped GeMM without block-sparsity must do.

* ``ep_all_to_all`` helpers — classic expert parallelism: tokens travel via
  all-to-all to the expert-owning device and back. Used only inside
  ``parallel.strategies`` to build the distributed EP baseline for the
  roofline comparison (the paper's motivation: Hexa-MoE needs NO all-to-all).

All paths consume the same ``RouterOutput`` so numerical comparisons are
exact where no token is dropped.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common import cdiv
from repro.core.routing import RouterOutput


def _dispatch_ranks(expert_idx: jax.Array, num_experts: int):
    """Position of each token-copy within its expert's queue (stable)."""
    n, k = expert_idx.shape
    e_flat = expert_idx.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)
    counts = jnp.bincount(e_flat, length=num_experts)
    offset = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])
    rank_sorted = jnp.arange(n * k) - offset[e_flat[order]]
    rank = jnp.zeros((n * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    return rank.reshape(n, k), counts


def dispatch_combine_moe(
    x: jax.Array,
    r: RouterOutput,
    w1: jax.Array,
    b1: Optional[jax.Array],
    w2: jax.Array,
    b2: Optional[jax.Array],
    *,
    act,
    capacity_factor: float = 1.25,
    capacity: Optional[int] = None,
    glu_up: Optional[jax.Array] = None,
) -> jax.Array:
    """Tutel-like dense dispatch/combine MoE FFN.

    Capacity C = ceil(N*k/E * capacity_factor); copies ranked past C are
    DROPPED (their contribution is zero), copies below C are padded into a
    dense (E, C, D) buffer — the redundancy source.
    """
    n, d = x.shape
    e = w1.shape[0]
    k = r.expert_idx.shape[1]
    if capacity is None:
        capacity = int(cdiv(n * k, e) * capacity_factor)
        capacity = max(capacity, 1)

    rank, _ = _dispatch_ranks(r.expert_idx, e)
    keep = rank < capacity  # (N, k)

    # Dispatch: scatter token copies into the (E, C, D) buffer.
    flat_slot = r.expert_idx * capacity + rank  # (N, k)
    flat_slot = jnp.where(keep, flat_slot, e * capacity)  # drop -> OOB
    buf = jnp.zeros((e * capacity, d), x.dtype)
    src = jnp.broadcast_to(x[:, None, :], (n, k, d)).reshape(n * k, d)
    buf = buf.at[flat_slot.reshape(-1)].set(src, mode="drop")
    buf = buf.reshape(e, capacity, d)

    # Expert computation as dense batched GeMM — pads are computed too.
    h = jnp.einsum("ecd,edf->ecf", buf, w1.astype(x.dtype))
    if b1 is not None:
        h = h + b1[:, None].astype(x.dtype)
    if glu_up is not None:
        u = jnp.einsum("ecd,edf->ecf", buf, glu_up.astype(x.dtype))
        h = act(h) * u
    else:
        h = act(h)
    y = jnp.einsum("ecf,efd->ecd", h, w2.astype(x.dtype))
    if b2 is not None:
        y = y + b2[:, None].astype(x.dtype)

    # Combine: gather each kept copy back, weight by gate, sum over k.
    y_flat = y.reshape(e * capacity, d)
    got = y_flat[jnp.minimum(flat_slot, e * capacity - 1).reshape(-1)]
    got = got.reshape(n, k, d)
    gates = (r.gates * keep.astype(r.gates.dtype))[..., None].astype(x.dtype)
    return jnp.sum(got * gates, axis=1)


def grouped_dense_moe(
    x: jax.Array,
    r: RouterOutput,
    w1: jax.Array,
    b1: Optional[jax.Array],
    w2: jax.Array,
    b2: Optional[jax.Array],
    *,
    act,
    glu_up: Optional[jax.Array] = None,
) -> jax.Array:
    """MegaBlocks(MoE)-like: capacity = worst-case N*k (no drops, all pad).

    Exact (never drops) but computes on a buffer padded to the max possible
    group size — the static-shape analogue of per-step max-group capacity.
    """
    n, _ = x.shape
    e = w1.shape[0]
    k = r.expert_idx.shape[1]
    return dispatch_combine_moe(
        x, r, w1, b1, w2, b2, act=act,
        capacity=int(cdiv(n * k, 1)),  # worst case: all copies to one expert
        glu_up=glu_up,
    )
