"""Heterogeneous-aware workload allocation (paper §4.4, Eq. 1/2).

The paper measures per-device capacity t_i on a proxy task and assigns

  data-centric :  B_i = (1/t_i) / sum_j(1/t_j) * B_global        (Eq. 1)
  model-centric:  h_i = (1/t_i) / sum_j(1/t_j) * H               (Eq. 2)

with integer rounding that preserves the exact global total. On TPU,
heterogeneity arises across pod generations / slices and — dynamically — from
degraded chips (stragglers). The runtime's straggler detector feeds observed
per-device step latencies back into this planner (see ``runtime.straggler``),
closing the loop the paper leaves manual.

Also includes the latency model used by ``benchmarks/hetero_alloc.py`` to
reproduce Table 3 / Figure 11's "optimal split minimises latency" result.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Per-device capacity measurement (paper Table 3)."""
    name: str
    proxy_latency_s: float  # t_i from the proxy task

    @property
    def capacity(self) -> float:
        return 1.0 / self.proxy_latency_s


def proportional_split(
    latencies: Sequence[float], total: int, *, quantum: int = 1
) -> list[int]:
    """Split ``total`` units proportional to 1/t_i (Eq. 1/2), rounded to
    multiples of ``quantum`` while preserving the exact total.

    ``quantum`` lets model-centric splits stay MXU-aligned (e.g. 128-multiple
    hidden sub-dimensions) — a TPU adaptation: unaligned tiles waste MXU.
    """
    t = np.asarray(latencies, dtype=np.float64)
    if np.any(t <= 0):
        raise ValueError("latencies must be positive")
    if total % quantum != 0:
        raise ValueError(f"total {total} not a multiple of quantum {quantum}")
    weights = (1.0 / t) / np.sum(1.0 / t)
    units = total // quantum
    raw = weights * units
    base = np.floor(raw).astype(np.int64)
    # Largest-remainder method to distribute the leftover units.
    leftover = units - int(base.sum())
    order = np.argsort(-(raw - base))
    base[order[:leftover]] += 1
    out = (base * quantum).astype(np.int64)
    assert out.sum() == total
    return [int(v) for v in out]


def plan_data_centric(
    profiles: Sequence[DeviceProfile], global_batch: int
) -> list[int]:
    """Eq. 1: per-device local batch sizes."""
    return proportional_split(
        [p.proxy_latency_s for p in profiles], global_batch
    )


def fit_quantum(total: int, quantum: int, num_devices: int) -> int:
    """Largest power-of-two divisor of ``quantum`` that lets ``total`` be
    split into >= ``num_devices`` quantum-multiples (DESIGN.md §6)."""
    q = quantum
    while total % q != 0 or total // q < num_devices:
        q //= 2
        if q == 0:
            raise ValueError("total too small for the device count")
    return q


def plan_model_centric(
    profiles: Sequence[DeviceProfile], hidden_size: int, *, quantum: int = 128
) -> list[int]:
    """Eq. 2: per-device FFN hidden sub-dimensions (MXU-aligned)."""
    q = fit_quantum(hidden_size, quantum, len(profiles))
    return proportional_split(
        [p.proxy_latency_s for p in profiles], hidden_size, quantum=q
    )


def step_latency_model(
    profiles: Sequence[DeviceProfile],
    shares: Sequence[int],
    total_work: int,
    *,
    fixed_overhead_s: float = 0.0,
) -> float:
    """Synchronous-step latency: max over devices of (work share) * t_i /
    (work unit). A device's time is proportional to its share and its
    measured per-unit latency; the step completes when the slowest finishes
    (the all-reduce barrier)."""
    per_unit = np.array([p.proxy_latency_s for p in profiles]) / total_work
    times = np.asarray(shares) * per_unit * len(profiles)
    return float(np.max(times) + fixed_overhead_s)


def replan_from_step_times(
    step_times_s: Sequence[float],
    current_shares: Sequence[int],
    total: int,
    *,
    quantum: int = 1,
    smoothing: float = 0.5,
) -> list[int]:
    """Runtime straggler mitigation: observed per-device step times imply new
    capacities (time / share = per-unit latency); re-split proportionally.
    ``smoothing`` blends old and new implied latencies (EMA) so transient
    noise does not thrash the allocation."""
    shares = np.asarray(current_shares, dtype=np.float64)
    times = np.asarray(step_times_s, dtype=np.float64)
    per_unit = times / np.maximum(shares, 1)
    uniform = np.full_like(per_unit, per_unit.mean())
    blended = smoothing * per_unit + (1 - smoothing) * uniform
    return proportional_split(blended, total, quantum=quantum)


def clamp_shares(
    shares: Sequence[int], capacity: int, *, quantum: int = 1
) -> list[int]:
    """Cap each share at ``capacity`` and redistribute the overflow to
    devices with slack (largest-slack first), preserving the exact total.

    The runtime replan loop (DESIGN.md §6) needs this: the SPMD layout's
    per-device shard is a *fixed* padded shape, so no replan may assign a
    device more rows than its allocated capacity. Raises if the total
    exceeds ``capacity * num_devices`` (nowhere to put the overflow).
    """
    if capacity % quantum != 0:
        raise ValueError(f"capacity {capacity} not a multiple of {quantum}")
    s = np.asarray(shares, dtype=np.int64)
    total = int(s.sum())
    if total > capacity * len(s):
        raise ValueError(
            f"total {total} exceeds aggregate capacity {capacity * len(s)}"
        )
    out = np.minimum(s, capacity)
    overflow = total - int(out.sum())
    # Hand overflow out in quantum units, biggest slack first.
    while overflow > 0:
        order = np.argsort(-(capacity - out))
        for i in order:
            if overflow <= 0:
                break
            take = min(overflow, capacity - int(out[i]), quantum)
            if take > 0:
                out[i] += take
                overflow -= take
    assert out.sum() == total
    return [int(v) for v in out]


# ---------------------------------------------------------------------------
# execution plan (DESIGN.md §6) — the object the runtime actually executes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HeteroPlan:
    """A concrete per-device allocation the execution layer runs (§4.4 made
    executable; DESIGN.md §6).

    ``token_counts``  — Eq. 1: valid batch rows per data-split group member.
                        The SPMD shard keeps a *uniform* padded shape
                        (``batch_capacity`` rows per device); rows past a
                        device's count are masked in routing and contribute
                        zero output and zero gradient.
    ``hidden_splits`` — Eq. 2: real FFN hidden columns per TP group member.
                        Realised as a zero-padded MXU-aligned tile per
                        device (``hidden_capacity`` columns each); padded
                        columns hold exact zeros, so the computation equals
                        the unpadded uneven split bitwise per device.
    ``proxy_latencies`` — the t_i that produced the splits; kept on the plan
                        so the autotune roofline can evaluate the uneven-
                        split latency term and so replans can EMA against
                        the original measurement.
    ``expert_bits``   — per-device-class expert-weight storage bits
                        (DESIGN.md §8): 8 ⇒ that class holds block-wise
                        int8 expert payloads (smaller HBM footprint AND a
                        smaller weight-byte roofline term), 16 ⇒ bf16.
                        Low-HBM classes go 8 while big devices stay 16;
                        ``parallel.hetero_exec`` quantizes each class's
                        weight slice accordingly and the autotune chooser
                        prices the uneven split with per-device bits.

    The plan is hashable/static: every distinct plan compiles its own trace
    (the replan loop bounds retraces with a plan-keyed cache,
    ``parallel.cache.PlanCache``).
    """
    proxy_latencies: tuple    # per-device t_i (seconds on the proxy task)
    token_counts: Optional[tuple] = None   # Eq. 1 B_i (batch rows/device)
    hidden_splits: Optional[tuple] = None  # Eq. 2 h_i (FFN cols/device)
    token_quantum: int = 1
    hidden_quantum: int = 128
    token_capacity: Optional[int] = None   # fixed SPMD rows/device (headroom)
    #: When the data group and the TP group are different device sets (a 2-D
    #: mesh), these are the TP group's t_i; ``hidden_splits`` derive from
    #: them. None ⇒ ``proxy_latencies`` covers both groups.
    tp_latencies: Optional[tuple] = None
    expert_bits: Optional[tuple] = None    # per-class weight bits (8 | 16)

    def __post_init__(self):
        if self.token_counts is not None and self.token_capacity is not None:
            if max(self.token_counts) > self.token_capacity:
                raise ValueError(
                    f"token_counts {self.token_counts} exceed capacity "
                    f"{self.token_capacity}"
                )
        if self.expert_bits is not None:
            if any(b not in (8, 16) for b in self.expert_bits):
                raise ValueError(
                    f"expert_bits must be 8 or 16, got {self.expert_bits}"
                )
            if len(self.expert_bits) != len(self.proxy_latencies):
                raise ValueError(
                    f"expert_bits has {len(self.expert_bits)} entries for "
                    f"{len(self.proxy_latencies)} device classes"
                )

    @property
    def batch_capacity(self) -> int:
        """Padded batch rows per device in the SPMD layout."""
        if self.token_capacity is not None:
            return self.token_capacity
        from repro.common import round_up
        return round_up(max(self.token_counts), self.token_quantum)

    @property
    def hidden_capacity(self) -> int:
        """Padded FFN columns per TP rank (MXU-aligned tile width)."""
        from repro.common import round_up
        return round_up(max(self.hidden_splits), self.hidden_quantum)

    def padded_hidden_size(self) -> int:
        """Global FFN hidden size after per-device tile padding (= d_ff when
        the split is even and quantum-aligned: no padding needed)."""
        return self.hidden_capacity * len(self.hidden_splits)

    def hidden_padded(self) -> bool:
        return (self.hidden_splits is not None
                and self.padded_hidden_size() != sum(self.hidden_splits))

    def key(self) -> tuple:
        """Hashable retrace key: what the compiled program depends on."""
        return (self.token_counts, self.hidden_splits,
                self.token_capacity, self.token_quantum,
                self.hidden_quantum, self.expert_bits)

    def with_token_counts(self, counts: Sequence[int]) -> "HeteroPlan":
        """Replan step: same plan, new Eq. 1 shares (capacity-clamped)."""
        counts = tuple(int(c) for c in counts)
        if self.token_capacity is not None:
            counts = tuple(clamp_shares(
                counts, self.token_capacity, quantum=self.token_quantum
            ))
        return dataclasses.replace(self, token_counts=counts)


def make_hetero_plan(
    latencies: Sequence[float],
    *,
    global_batch: Optional[int] = None,
    hidden_size: Optional[int] = None,
    tp_latencies: Optional[Sequence[float]] = None,
    token_quantum: int = 1,
    hidden_quantum: int = 128,
    capacity_headroom: float = 1.0,
    expert_bits: Optional[Sequence[int]] = None,
) -> HeteroPlan:
    """Build the executable plan from measured proxy latencies (Eq. 1/2).

    ``global_batch`` enables the data split (token_counts over the data
    group, one entry per latency), ``hidden_size`` the model split
    (hidden_splits over the TP group — ``tp_latencies`` when that group is
    a different device set, else ``latencies``). ``capacity_headroom > 1``
    reserves extra padded rows per device so later replans can shift MORE
    load onto a device than the initial plan gave it without changing the
    SPMD shapes. ``expert_bits`` (DESIGN.md §8): per-class expert-weight
    storage bits — low-HBM classes hold int8 payloads (8), big devices
    stay bf16 (16).
    """
    lat = tuple(float(t) for t in latencies)
    tp_lat = (tuple(float(t) for t in tp_latencies)
              if tp_latencies is not None else None)
    if any(t <= 0 for t in lat + (tp_lat or ())):
        raise ValueError("latencies must be positive")
    token_counts = hidden_splits = None
    capacity = None
    if global_batch is not None:
        # The FITTED quantum is the one the plan lives by from here on:
        # replans re-split the same total on plan.token_quantum, so storing
        # the requested (unfitted) value would crash the replan path.
        token_quantum = fit_quantum(global_batch, token_quantum, len(lat))
        token_counts = tuple(
            proportional_split(lat, global_batch, quantum=token_quantum)
        )
        from repro.common import round_up
        capacity = round_up(
            min(int(max(token_counts) * capacity_headroom), global_batch),
            token_quantum,
        )
    if hidden_size is not None:
        hl = tp_lat if tp_lat is not None else lat
        # Same fitting for the hidden side: hidden_capacity (tile width)
        # must round to the quantum the split actually used, or small d_ff
        # would silently pad far past the real hidden size.
        hidden_quantum = fit_quantum(hidden_size, hidden_quantum, len(hl))
        hidden_splits = tuple(
            proportional_split(hl, hidden_size, quantum=hidden_quantum)
        )
    return HeteroPlan(
        proxy_latencies=lat,
        token_counts=token_counts,
        hidden_splits=hidden_splits,
        token_quantum=token_quantum,
        hidden_quantum=hidden_quantum,
        token_capacity=capacity,
        tp_latencies=tp_lat,
        expert_bits=(tuple(int(b) for b in expert_bits)
                     if expert_bits is not None else None),
    )


def uniform_plan(num_devices: int, **kwargs) -> HeteroPlan:
    """Equal-latency plan: splits degenerate to the uniform path (and the
    execution layer short-circuits all masking — bitwise-identical HLO)."""
    return make_hetero_plan([1.0] * num_devices, **kwargs)


def uniform_counterpart(plan: HeteroPlan) -> HeteroPlan:
    """The uniform-split baseline arm of an A/B comparison: same totals,
    same latencies (so the same simulated skew), equal shares per split
    group (each split keeps ITS group size — token and hidden groups can
    differ on a 2-D mesh).

    ``token_capacity`` is reset — uniform counts can exceed the skewed
    plan's kept capacity and ``HeteroPlan.__post_init__`` would reject
    them. Rejects totals whose equal shares would be uneven or (hidden
    side) quantum-misaligned: a baseline arm must execute the same
    MXU-aligned tile shapes the proportional arm does."""
    counts = splits = None
    if plan.token_counts is not None:
        n = len(plan.token_counts)
        total = sum(plan.token_counts)
        if total % n:
            raise ValueError(f"token total {total} not divisible by {n}")
        counts = (total // n,) * n
    if plan.hidden_splits is not None:
        n = len(plan.hidden_splits)
        total = sum(plan.hidden_splits)
        if total % n:
            raise ValueError(f"hidden total {total} not divisible by {n}")
        if (total // n) % plan.hidden_quantum:
            raise ValueError(
                f"uniform hidden share {total // n} is not a multiple of "
                f"the plan's hidden_quantum {plan.hidden_quantum}"
            )
        splits = (total // n,) * n
    return dataclasses.replace(
        plan, token_counts=counts, hidden_splits=splits, token_capacity=None
    )


def hidden_mask(plan: HeteroPlan, dtype=np.float32) -> np.ndarray:
    """(padded_hidden_size,) column-validity mask for the model split.

    Global padded column c belongs to TP rank ``c // hidden_capacity``;
    it is real iff its offset within the rank's tile is < h_i. Multiplying
    the initialised expert weights by this mask zeroes the padded columns,
    and they stay zero under training: the forward contribution of a zero
    column is exactly zero, so its gradient is exactly zero (DESIGN.md §6
    padding invariant)."""
    cap = plan.hidden_capacity
    mask = np.zeros((plan.padded_hidden_size(),), dtype=dtype)
    for i, h in enumerate(plan.hidden_splits):
        mask[i * cap: i * cap + h] = 1
    return mask


def pack_batch(batch: dict, plan: HeteroPlan) -> dict:
    """Re-pack a (B_total, ...) host batch into the plan's padded SPMD
    layout: device i's shard holds its Eq. 1 share ``token_counts[i]`` in
    rows [i*C, i*C + B_i) of a (n_dev * C, ...) array (C = batch_capacity);
    tail rows are zero ('loss_mask' zero ⇒ no loss; the MoE island masks
    them out of routing and the aux losses)."""
    counts = plan.token_counts
    cap = plan.batch_capacity
    n = len(counts)
    assert sum(counts) <= batch_size_of(batch), (
        "plan assigns more rows than the batch holds")
    offsets = np.concatenate([[0], np.cumsum(counts)])
    out = {}
    for name, arr in batch.items():
        a = np.asarray(arr)
        dst = np.zeros((n * cap,) + a.shape[1:], a.dtype)
        for i, b_i in enumerate(counts):
            dst[i * cap: i * cap + b_i] = a[offsets[i]: offsets[i] + b_i]
        out[name] = dst
    return out


def batch_size_of(batch: dict) -> int:
    """Leading-dim size of a host batch dict (all leaves agree)."""
    return int(next(iter(batch.values())).shape[0])
