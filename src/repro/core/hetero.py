"""Heterogeneous-aware workload allocation (paper §4.4, Eq. 1/2).

The paper measures per-device capacity t_i on a proxy task and assigns

  data-centric :  B_i = (1/t_i) / sum_j(1/t_j) * B_global        (Eq. 1)
  model-centric:  h_i = (1/t_i) / sum_j(1/t_j) * H               (Eq. 2)

with integer rounding that preserves the exact global total. On TPU,
heterogeneity arises across pod generations / slices and — dynamically — from
degraded chips (stragglers). The runtime's straggler detector feeds observed
per-device step latencies back into this planner (see ``runtime.straggler``),
closing the loop the paper leaves manual.

Also includes the latency model used by ``benchmarks/hetero_alloc.py`` to
reproduce Table 3 / Figure 11's "optimal split minimises latency" result.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Per-device capacity measurement (paper Table 3)."""
    name: str
    proxy_latency_s: float  # t_i from the proxy task

    @property
    def capacity(self) -> float:
        return 1.0 / self.proxy_latency_s


def proportional_split(
    latencies: Sequence[float], total: int, *, quantum: int = 1
) -> list[int]:
    """Split ``total`` units proportional to 1/t_i (Eq. 1/2), rounded to
    multiples of ``quantum`` while preserving the exact total.

    ``quantum`` lets model-centric splits stay MXU-aligned (e.g. 128-multiple
    hidden sub-dimensions) — a TPU adaptation: unaligned tiles waste MXU.
    """
    t = np.asarray(latencies, dtype=np.float64)
    if np.any(t <= 0):
        raise ValueError("latencies must be positive")
    if total % quantum != 0:
        raise ValueError(f"total {total} not a multiple of quantum {quantum}")
    weights = (1.0 / t) / np.sum(1.0 / t)
    units = total // quantum
    raw = weights * units
    base = np.floor(raw).astype(np.int64)
    # Largest-remainder method to distribute the leftover units.
    leftover = units - int(base.sum())
    order = np.argsort(-(raw - base))
    base[order[:leftover]] += 1
    out = (base * quantum).astype(np.int64)
    assert out.sum() == total
    return [int(v) for v in out]


def plan_data_centric(
    profiles: Sequence[DeviceProfile], global_batch: int
) -> list[int]:
    """Eq. 1: per-device local batch sizes."""
    return proportional_split(
        [p.proxy_latency_s for p in profiles], global_batch
    )


def plan_model_centric(
    profiles: Sequence[DeviceProfile], hidden_size: int, *, quantum: int = 128
) -> list[int]:
    """Eq. 2: per-device FFN hidden sub-dimensions (MXU-aligned)."""
    q = quantum
    while hidden_size % q != 0 or hidden_size // q < len(profiles):
        q //= 2
        if q == 0:
            raise ValueError("hidden_size too small for the device count")
    return proportional_split(
        [p.proxy_latency_s for p in profiles], hidden_size, quantum=q
    )


def step_latency_model(
    profiles: Sequence[DeviceProfile],
    shares: Sequence[int],
    total_work: int,
    *,
    fixed_overhead_s: float = 0.0,
) -> float:
    """Synchronous-step latency: max over devices of (work share) * t_i /
    (work unit). A device's time is proportional to its share and its
    measured per-unit latency; the step completes when the slowest finishes
    (the all-reduce barrier)."""
    per_unit = np.array([p.proxy_latency_s for p in profiles]) / total_work
    times = np.asarray(shares) * per_unit * len(profiles)
    return float(np.max(times) + fixed_overhead_s)


def replan_from_step_times(
    step_times_s: Sequence[float],
    current_shares: Sequence[int],
    total: int,
    *,
    quantum: int = 1,
    smoothing: float = 0.5,
) -> list[int]:
    """Runtime straggler mitigation: observed per-device step times imply new
    capacities (time / share = per-unit latency); re-split proportionally.
    ``smoothing`` blends old and new implied latencies (EMA) so transient
    noise does not thrash the allocation."""
    shares = np.asarray(current_shares, dtype=np.float64)
    times = np.asarray(step_times_s, dtype=np.float64)
    per_unit = times / np.maximum(shares, 1)
    uniform = np.full_like(per_unit, per_unit.mean())
    blended = smoothing * per_unit + (1 - smoothing) * uniform
    return proportional_split(blended, total, quantum=quantum)
