"""Adaptive data-/model-centric dispatch (paper §4.5, Fig. 10) at runtime.

The paper's observation: for one MoE FFN layer the collective bill is
token-proportional under model-centric execution (all-gather tokens over TP,
reduce partial outputs; weights stationary) but constant under data-centric
execution (all-gather expert weights; tokens stationary). Model-centric wins
small workloads, data-centric wins large ones, and the crossover sits where
moved token bytes ≈ moved weight bytes.

This module promotes the offline roofline (``benchmarks/centric_crossover.py``
now imports it from here) into a per-layer *runtime* decision:

  * ``layer_latency`` — the roofline itself: max(compute, HBM, link) for one
    MoE FFN layer under a given mode. Byte/FLOP terms only; no device state.
  * ``choose_mode`` / ``crossover_tokens`` — argmin over modes for a given
    token workload, and the workload where the winner flips.
  * ``resolve_layer_mode`` — the hook ``moe_parallel.moe_layer`` calls when
    ``ParallelConfig.mode == "auto"``: derives (d, f, e, k) from the param
    shapes, the TP group size from the mesh, and an effective device count
    from heterogeneous ``core.hetero.DeviceProfile`` measurements.
  * ``plan_layer_modes`` — a whole-model per-layer plan (one entry per
    period position) that can be pinned into ``ParallelConfig.layer_mode_plan``.

Because the decision is a pure function of static shapes, prefill and decode
traces naturally land on different sides of the crossover: a 32k-token
prefill picks data-centric while a batch-of-slots decode step (tokens = a few
dozen) picks model-centric — the serving scenario the paper's Fig. 10 implies
but never wires up.

All decisions are made OUTSIDE shard_map/jit tracing of collectives (shapes
are static), so ``mode="auto"`` compiles to exactly the same HLO as the
equivalent forced mode — bitwise-identical outputs, which the tier-1 suite
asserts.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np

from repro import obs


@dataclasses.dataclass(frozen=True)
class Topology:
    """Two-level interconnect (DESIGN.md §10): fast links inside a node of
    ``node_size`` devices (``intra_bw`` bytes/s per link), slow links across
    nodes (``inter_bw``). ``n_nodes(n) <= 1`` degenerates to a flat fabric:
    every price is computed with the *same expression* as the topology-less
    roofline, so a flat ``Topology(intra_bw=hw.link_bw, ...)`` is
    bitwise-identical to today's model (pinned by tests/test_topology.py)."""
    intra_bw: float = 50e9
    inter_bw: float = 12.5e9
    node_size: int = 4

    def __post_init__(self):
        if self.intra_bw <= 0 or self.inter_bw <= 0:
            raise ValueError("topology bandwidths must be positive")
        if self.node_size < 1:
            raise ValueError("node_size must be >= 1")

    def n_nodes(self, n_dev: float) -> int:
        """Number of nodes an ``n_dev``-wide group spans (ceil division)."""
        return int(math.ceil(float(n_dev) / self.node_size))

    def is_flat(self, n_dev: float) -> bool:
        """True when the group fits inside one node (single-level fabric)."""
        return self.n_nodes(n_dev) <= 1

    @staticmethod
    def parse(spec: str) -> "Topology":
        """Parse the CLI form ``intra:inter:node_size`` (bytes/s, e.g.
        ``50e9:12.5e9:4``)."""
        parts = spec.split(":")
        if len(parts) != 3:
            raise ValueError(
                f"--topology expects intra_bw:inter_bw:node_size, got {spec!r}")
        return Topology(intra_bw=float(parts[0]), inter_bw=float(parts[1]),
                        node_size=int(parts[2]))


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """Per-device roofline constants (bytes/s, FLOP/s).

    ``topology`` (DESIGN.md §10): optional two-level interconnect; ``None``
    keeps the flat single-bandwidth fabric priced at ``link_bw``."""
    peak_flops: float = 197e12   # bf16 MXU peak (v5e)
    hbm_bw: float = 819e9        # HBM bytes/s (v5e)
    link_bw: float = 50e9        # ICI per-link bytes/s (v5e)
    topology: Optional[Topology] = None


V5E = HardwareProfile()


def _token_coll_cost(tok_bytes: float, n_dev: float, hw: HardwareProfile) -> float:
    """Link time of model-centric's token collectives (AG in + RS out).

    Token shards and the node-combined partial sums are *distinct* bytes per
    device, so the inter-node term stays per-device: the hierarchical credit
    is exactly the node-local combine collapsing ``node_size`` partial-sum
    copies into one before the cross-node exchange — ``(nn-1)/nn`` instead of
    ``(n-1)/n`` per direction (DESIGN.md §10)."""
    topo = hw.topology
    if topo is None:
        return (tok_bytes + tok_bytes) / hw.link_bw
    if topo.is_flat(n_dev):
        return (tok_bytes + tok_bytes) / topo.intra_bw
    ns = min(topo.node_size, max(int(n_dev), 1))
    nn = topo.n_nodes(n_dev)
    intra = 2 * tok_bytes * (ns - 1) / ns / topo.intra_bw
    inter = 2 * tok_bytes * (nn - 1) / nn / topo.inter_bw
    return intra + inter


def _weight_coll_cost(w_bytes: float, n_dev: float, hw: HardwareProfile) -> float:
    """Link time of data-centric's expert-weight all-gather.

    Weights are *identical* bytes for every consumer, so the hierarchical
    gather lands each byte on a node exactly once over the slow fabric
    (per-NIC share ``1/node_size``) and fans out over the fast intra links —
    the staging that makes data-centric relatively cheaper as
    ``inter_bw/intra_bw`` shrinks (DESIGN.md §10 worked example)."""
    topo = hw.topology
    if topo is None:
        return w_bytes * (n_dev - 1) / n_dev / hw.link_bw
    if topo.is_flat(n_dev):
        return w_bytes * (n_dev - 1) / n_dev / topo.intra_bw
    ns = min(topo.node_size, max(int(n_dev), 1))
    nn = topo.n_nodes(n_dev)
    intra = w_bytes * (ns - 1) / ns / topo.intra_bw
    inter = w_bytes * (nn - 1) / nn / (ns * topo.inter_bw)
    return intra + inter


def dispatch_inter_bytes(
    tokens: int,
    d: int,
    k: int,
    *,
    n_dev: int,
    node_size: int,
    itemsize: int = 2,
    hierarchical: bool = True,
) -> float:
    """Expected inter-node bytes of a top-k expert all-to-all dispatch.

    Flat dispatch sends each of a token's ``k`` expert copies to its owner
    device: ``k * (nn-1)/nn`` copies cross nodes in expectation (uniform
    routing). Hierarchical dispatch (DESIGN.md §10) sends a token to a remote
    node ONCE if >= 1 of its k experts lives there and replicates over the
    fast intra links: ``(nn-1) * (1 - (1 - 1/nn)**k)`` expected crossings —
    the local top-k overlap factor. Bernoulli gives hierarchical <= flat for
    every (k, node_size), which tests/test_topology.py samples."""
    nn = int(math.ceil(n_dev / max(node_size, 1)))
    if nn <= 1:
        return 0.0
    per_tok = float(tokens) * d * itemsize
    if not hierarchical:
        return per_tok * k * (nn - 1) / nn
    return per_tok * (nn - 1) * (1.0 - (1.0 - 1.0 / nn) ** k)


def moe_coll_bytes(
    mode: str,
    tokens: int,
    d: int,
    f: int,
    e: int,
    k: int,
    *,
    n_dev: int,
    topology: Topology,
    hierarchical: bool = True,
    weight_bits: int = 16,
) -> Tuple[float, float]:
    """(intra_bytes, inter_bytes) one MoE layer's collectives move per device
    on a two-level fabric, under the flat vs hierarchical schedule.

    The flat schedule's ring spans nodes, so its whole per-device volume is
    paced by (and billed to) the inter level; the hierarchical schedule
    splits per DESIGN.md §10 — this is what ``benchmarks/hetero_alloc.py``
    feeds the simulated per-link latencies to pin hier <= flat."""
    tok_bytes = float(tokens) * d * 2
    w_bytes = float(e) * 2 * d * f * (weight_bits / 8)
    n = max(int(n_dev), 1)
    ns = min(topology.node_size, n)
    nn = topology.n_nodes(n)
    if mode == "model_centric":
        vol = 2 * tok_bytes * (n - 1) / n
        if nn <= 1:
            return (vol, 0.0)
        if not hierarchical:
            return (0.0, vol)
        return (2 * tok_bytes * (ns - 1) / ns,
                2 * tok_bytes * (nn - 1) / nn)
    if mode == "data_centric":
        vol = w_bytes * (n - 1) / n
        if nn <= 1:
            return (vol, 0.0)
        if not hierarchical:
            return (0.0, vol)
        return (w_bytes * (ns - 1) / ns,
                w_bytes * (nn - 1) / nn / ns)
    raise ValueError(mode)

#: Modes the runtime chooser may return, in tie-break preference order:
#: when the roofline says equal (usually both compute-bound), prefer
#: model-centric — it moves no weights, so it never inflates HBM residency.
CHOOSABLE_MODES = ("model_centric", "data_centric")


def layer_latency(
    mode: str,
    tokens: int,
    d: int,
    f: int,
    e: int,
    k: int,
    n_dev: float = 16,
    hw: HardwareProfile = V5E,
    *,
    fused_ffn: bool = True,
    weight_bits: int = 16,
) -> float:
    """One MoE FFN layer (fwd), bf16, on an ``n_dev`` TP/DP group.

    model_centric: tokens all-gathered over TP + partial-output reduction;
                   weights stationary.
    data_centric : weights all-gathered over the group (pipeline-shared
                   cache re-fill per layer); tokens stationary.
    ``n_dev`` may be fractional: heterogeneous groups report an *effective*
    device count (see ``effective_devices``).
    ``fused_ffn``: with the fused expert FFN (kernels.esffn, DESIGN.md §5,
    the TPU default) inter-stage activations stay in VMEM. Unfused, the
    HBM term additionally pays the (Np, D) sorted-copy and (Np, F) hidden
    round-trips between the 3-4 separate kernels — which inflates the
    token-proportional side of the roofline and moves the data-/model-
    centric crossover.
    ``weight_bits`` (DESIGN.md §8): expert-weight storage bits. Quantized
    experts (8) shrink the weight term of BOTH the HBM and the all-gather
    bills while the token bytes stay bf16 — data-centric's constant
    weight-movement cost halves, so the crossover shifts toward FEWER
    tokens (data-centric wins earlier).
    """
    active_rows = tokens * k
    flops = 2 * active_rows * d * f * 2  # two MLPs
    w_bytes = e * 2 * d * f * (weight_bits / 8)  # full expert params
    tok_bytes = tokens * d * 2
    # Unfused inter-stage HBM round-trips (1 write + 1 read each), bf16:
    # the expert-sorted (Np, D) copy and the (Np, F) hidden activations.
    srt_bytes = 2 * active_rows * d * 2
    hid_bytes = 2 * active_rows * f * 2
    if mode == "model_centric":
        compute = flops / n_dev / hw.peak_flops   # rows x F/n per device
        mem = (w_bytes / n_dev + tok_bytes) / hw.hbm_bw
        if not fused_ffn:
            # every device holds the whole group's gathered tokens; the
            # hidden is TP-sharded over F.
            mem += (srt_bytes + hid_bytes / n_dev) / hw.hbm_bw
        coll = _token_coll_cost(tok_bytes, n_dev, hw)  # AG tokens + RS outputs
    elif mode == "data_centric":
        compute = flops / n_dev / hw.peak_flops   # tokens/n per device
        mem = (w_bytes + tok_bytes / n_dev) / hw.hbm_bw
        if not fused_ffn:
            # tokens (and therefore both round-trips) are split over devices.
            mem += (srt_bytes + hid_bytes) / n_dev / hw.hbm_bw
        coll = _weight_coll_cost(w_bytes, n_dev, hw)  # AG weights
    else:
        raise ValueError(mode)
    return max(compute, mem, coll)


def layer_latency_uneven(
    mode: str,
    tokens: int,
    d: int,
    f: int,
    e: int,
    k: int,
    latencies: Sequence[float],
    *,
    token_shares: Optional[Sequence[int]] = None,
    hidden_shares: Optional[Sequence[int]] = None,
    hw: HardwareProfile = V5E,
    fused_ffn: bool = True,
    weight_bits=16,
) -> float:
    """Uneven-split roofline: max over devices of each device's latency
    under its Eq. 1/2 share (paper §4.4 executed; DESIGN.md §6).

    ``weight_bits`` may be a scalar or a per-device sequence (a plan's
    ``expert_bits``, DESIGN.md §8): device i's weight-byte terms use its
    own class's storage width, so an int8 low-HBM class sees a smaller
    HBM bill than its bf16 peers.

    Replaces the ``effective_devices`` scalar approximation when an actual
    per-device allocation is known: device ``i`` runs at ``t_min/t_i`` of
    the fastest chip's roofline (compute AND HBM scaled; link bandwidth is
    topology, not silicon, and stays flat) and carries
    ``token_shares[i]/Σ`` of the tokens (data-centric) or
    ``hidden_shares[i]/Σ`` of the hidden columns (model-centric). With the
    proportional split the per-device latencies equalise and the max
    coincides with the effective-devices approximation; any other split is
    strictly worse — which is the Fig. 11 claim this term lets the chooser
    see.
    """
    t = np.asarray(latencies, dtype=np.float64)
    if np.any(t <= 0):
        raise ValueError("latencies must be positive")
    n = len(t)
    speed = np.min(t) / t  # relative per-device speed, fastest = 1
    if token_shares is None:
        token_shares = [tokens // n] * n
    if hidden_shares is None:
        hidden_shares = [f // n] * n
    tok_frac = np.asarray(token_shares, np.float64) / max(sum(token_shares), 1)
    hid_frac = np.asarray(hidden_shares, np.float64) / max(sum(hidden_shares), 1)

    bits = (list(weight_bits) if not isinstance(weight_bits, (int, float))
            else [weight_bits] * n)
    if len(bits) != n:
        raise ValueError(
            f"weight_bits has {len(bits)} entries for {n} devices")

    active_rows = tokens * k
    flops = 2 * active_rows * d * f * 2
    tok_bytes = tokens * d * 2
    srt_bytes = 2 * active_rows * d * 2
    hid_bytes = 2 * active_rows * f * 2

    worst = 0.0
    for i in range(n):
        peak = hw.peak_flops * speed[i]
        hbm = hw.hbm_bw * speed[i]
        w_bytes = e * 2 * d * f * (bits[i] / 8)
        if mode == "model_centric":
            compute = flops * hid_frac[i] / peak
            mem = (w_bytes * hid_frac[i] + tok_bytes) / hbm
            if not fused_ffn:
                mem += (srt_bytes + hid_bytes * hid_frac[i]) / hbm
            coll = _token_coll_cost(tok_bytes, n, hw)
        elif mode == "data_centric":
            compute = flops * tok_frac[i] / peak
            mem = (w_bytes + tok_bytes * tok_frac[i]) / hbm
            if not fused_ffn:
                mem += (srt_bytes + hid_bytes) * tok_frac[i] / hbm
            coll = _weight_coll_cost(w_bytes, n, hw)
        else:
            raise ValueError(mode)
        worst = max(worst, max(compute, mem, coll))
    return worst


def effective_devices(proxy_latencies: Sequence[float]) -> float:
    """Heterogeneity-aware effective group size (paper §4.4 planner view).

    With the proportional split of Eq. 1/2 every device finishes together,
    so the group behaves like ``sum(t_min / t_i)`` devices rated at the
    fastest chip's roofline: a (1x fast + 1x half-speed) pair is worth 1.5
    fast devices, not 2.
    """
    t = np.asarray(proxy_latencies, dtype=np.float64)
    if t.size == 0:
        return 1.0
    if np.any(t <= 0):
        raise ValueError("proxy latencies must be positive")
    return float(np.sum(np.min(t) / t))


def choose_mode(
    tokens: int,
    d: int,
    f: int,
    e: int,
    k: int,
    *,
    n_dev: float = 16,
    hw: HardwareProfile = V5E,
    fused_ffn: bool = True,
    weight_bits: int = 16,
) -> str:
    """argmin-latency mode for one MoE layer's token workload (ties resolve
    in CHOOSABLE_MODES order: model-centric first)."""
    if n_dev <= 1:
        # No group to move tokens or weights across: the modes coincide;
        # report data_centric (weights-stationary == weights-local).
        return "data_centric"
    costs = {
        m: layer_latency(m, tokens, d, f, e, k, n_dev, hw,
                         fused_ffn=fused_ffn, weight_bits=weight_bits)
        for m in CHOOSABLE_MODES
    }
    return min(costs, key=costs.get)


def crossover_tokens(
    d: int,
    f: int,
    e: int,
    k: int,
    *,
    n_dev: float = 16,
    hw: HardwareProfile = V5E,
    fused_ffn: bool = True,
    weight_bits: int = 16,
    lo_exp: int = 4,
    hi_exp: int = 18,
) -> Optional[int]:
    """First power-of-two token count where the winner flips model->data.

    Scans the same 2**lo_exp .. 2**(hi_exp-1) grid as the Fig. 10 benchmark
    so the runtime chooser and the offline roofline agree exactly.
    Quantized experts (``weight_bits=8``, DESIGN.md §8) cheapen the
    data-centric weight movement and pull the crossover to fewer tokens.
    """
    prev = None
    for tokens in (2 ** i for i in range(lo_exp, hi_exp)):
        winner = choose_mode(
            tokens, d, f, e, k, n_dev=n_dev, hw=hw, fused_ffn=fused_ffn,
            weight_bits=weight_bits,
        )
        if prev is not None and prev != winner:
            return tokens
        prev = winner
    return None


# ---------------------------------------------------------------------------
# serving decode-attention pricing (paged vs dense KV, DESIGN.md §7)
# ---------------------------------------------------------------------------

def decode_attn_bytes(
    kind: str,
    *,
    num_slots: int,
    max_seq: int,
    hq: int,
    hkv: int,
    hd: int,
    lengths: Optional[Sequence[int]] = None,
    page: int = 16,
    itemsize: int = 2,
) -> int:
    """HBM bytes of ONE decode-attention macro-step under each cache layout.

    "dense": the kernel reads the whole up-front ``(num_slots, max_seq)``
    K/V rectangle every step — the workload-independent term the paged
    engine exists to kill.
    "paged": only the pages holding live tokens move
    (``kernels.paged_attention.paged_attn_cost``); an idle slot costs its
    query row, a short sequence its own pages. There is NO
    ``num_slots * max_seq`` term, which ``tests/test_paged_attention.py``
    pins.
    """
    from repro.kernels.paged_attention import paged_attn_cost

    if kind == "dense":
        q_bytes = 2 * num_slots * hq * hd * itemsize        # q in + out
        kv_bytes = 2 * num_slots * max_seq * hkv * hd * itemsize
        return int(q_bytes + kv_bytes)
    if kind == "paged":
        lens = ([max_seq] * num_slots if lengths is None
                else [min(int(l), max_seq) for l in lengths])
        return int(paged_attn_cost(lens, page, hq, hkv, hd, itemsize)
                   ["bytes_accessed"])
    raise ValueError(kind)


def serve_decode_attn_latency(
    kind: str,
    *,
    num_slots: int,
    max_seq: int,
    hq: int,
    hkv: int,
    hd: int,
    lengths: Optional[Sequence[int]] = None,
    page: int = 16,
    itemsize: int = 2,
    hw: HardwareProfile = V5E,
) -> float:
    """Roofline latency of one decode-attention macro-step: decode
    attention does O(1) FLOPs per byte, so the HBM term is the whole bill.
    This is the cost-model entry that lets the serving driver (and
    ``benchmarks/serve_bench.py``) price the paged kernel against the
    dense layout for an actual mix of sequence lengths."""
    return decode_attn_bytes(
        kind, num_slots=num_slots, max_seq=max_seq, hq=hq, hkv=hkv, hd=hd,
        lengths=lengths, page=page, itemsize=itemsize,
    ) / hw.hbm_bw


def expected_verify_tokens(accept_rate: float, spec_k: int) -> float:
    """Expected tokens committed per speculative verify round (DESIGN.md
    §11) when each drafted token independently matches the sampler with
    probability ``accept_rate``: the round commits ``i + 1`` tokens when
    the first mismatch lands on draft ``i``, so the expectation telescopes
    to ``1 + a + a^2 + ... + a^spec_k = (1 - a^(k+1)) / (1 - a)``. Bounds:
    1 at ``a = 0`` (the correction token alone) and ``spec_k + 1`` at
    ``a = 1`` (every draft and the bonus sample commit)."""
    if not 0.0 <= accept_rate <= 1.0:
        raise ValueError(f"accept_rate must be in [0, 1]: {accept_rate}")
    if spec_k < 0:
        raise ValueError(f"spec_k must be >= 0: {spec_k}")
    if accept_rate >= 1.0:
        return float(spec_k + 1)
    return float((1.0 - accept_rate ** (spec_k + 1)) / (1.0 - accept_rate))


def spec_verify_latency(
    n_tokens: int,
    d: int,
    f: int,
    e: int,
    k: int,
    *,
    n_dev: float = 16,
    hw: HardwareProfile = V5E,
    fused_ffn: bool = True,
    weight_bits: int = 16,
) -> float:
    """Roofline latency of ONE MoE layer scoring ``n_tokens`` drafted
    positions in a single paged forward — verification priced as a
    ``k``-row data-centric micro-batch (DESIGN.md §11). Decode
    (``n_tokens = 1``) is weight-bound: the whole expert weight movement
    is billed to one token, so a verify step's cost grows far slower than
    linearly in ``n_tokens`` until the token-proportional terms catch up.
    ``choose_mode`` picks the same argmin mode the serving forward's auto
    chooser will resolve for that token count, so the model prices what
    actually runs."""
    mode = choose_mode(n_tokens, d, f, e, k, n_dev=n_dev, hw=hw,
                       fused_ffn=fused_ffn, weight_bits=weight_bits)
    return layer_latency(mode, n_tokens, d, f, e, k, n_dev, hw,
                         fused_ffn=fused_ffn, weight_bits=weight_bits)


def spec_decode_speedup(
    accept_rate: float,
    spec_k: int,
    d: int,
    f: int,
    e: int,
    k: int,
    *,
    n_dev: float = 16,
    hw: HardwareProfile = V5E,
    fused_ffn: bool = True,
    weight_bits: int = 16,
) -> float:
    """Expected decode-throughput ratio of speculative verify over
    one-token-at-a-time decode on the MoE-layer roofline: committed
    tokens per round (``expected_verify_tokens``) divided by the verify
    round's cost relative to a single decode step. >1 exactly when the
    per-round token gain outruns the (sub-linear, memory-bound) cost of
    scoring ``spec_k + 1`` rows at once — the model-side version of the
    measured ``serve/spec/{on,off}`` rows in ``BENCH_serve.json``. Draft
    cost is not included (the n-gram drafter is host-side and free; a
    draft model adds its own, much smaller, roofline)."""
    dec = spec_verify_latency(1, d, f, e, k, n_dev=n_dev, hw=hw,
                              fused_ffn=fused_ffn, weight_bits=weight_bits)
    ver = spec_verify_latency(spec_k + 1, d, f, e, k, n_dev=n_dev, hw=hw,
                              fused_ffn=fused_ffn, weight_bits=weight_bits)
    return expected_verify_tokens(accept_rate, spec_k) * dec / ver


# ---------------------------------------------------------------------------
# runtime hooks (called from moe_parallel / lm with static shapes)
# ---------------------------------------------------------------------------

def _tp_group_size(cfg, mesh) -> int:
    """TP group extent under the given config/mesh (1 without a mesh).

    A two-level mesh (DESIGN.md §10) spreads the TP group over a
    ("node", "model") axis tuple; the group size is the product."""
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return 1
    tp = cfg.axes(mesh)["tp"]
    if not tp:
        return 1
    size = 1
    for ax in (tp if isinstance(tp, tuple) else (tp,)):
        size *= int(mesh.shape[ax])
    return size


def _record_mode(mode: str, source: str, cost_s=None) -> str:
    """Publish a layer-mode decision (trace-time only) and pass it through."""
    obs.registry.counter(
        "repro_autotune_mode_total",
        "per-layer parallel-mode decisions, by mode and decision source",
        labels=("mode", "source")).labels(mode, source).inc()
    if cost_s is not None:
        obs.registry.gauge(
            "repro_autotune_predicted_latency_seconds",
            "roofline-predicted layer latency for the chosen mode",
            labels=("mode",)).labels(mode).set(float(cost_s))
    return mode


def resolve_layer_mode(
    tokens: int,
    *,
    d: int,
    f: int,
    e: int,
    k: int,
    cfg,
    mesh,
    layer_idx: Optional[int] = None,
) -> str:
    """Per-layer mode decision for ``ParallelConfig.mode == "auto"``.

    Precedence: ``cfg.forced_layer_mode`` > ``cfg.layer_mode_plan`` (indexed
    by ``layer_idx`` modulo plan length) > the roofline chooser. With a
    ``cfg.hetero_plan`` whose latencies cover the TP group, the chooser
    evaluates the *uneven-split* roofline (``layer_latency_uneven``,
    DESIGN.md §6) — the max over devices under their actual Eq. 1/2 shares —
    instead of the ``effective_devices`` scalar approximation used for bare
    ``cfg.device_latencies``. Fused-FFN HBM cost is modelled unless the
    config forces the unfused composition (``cfg.fused_ffn is False``) — the
    roofline describes the TPU execution, where fused is the default.
    Weight bytes are priced at the quantized width (DESIGN.md §8): the
    plan's per-class ``expert_bits`` when it carries them, else 8 bits
    under ``cfg.quant`` int8/fp8, else 16.
    With a ``cfg.topology`` (DESIGN.md §10) both rooflines price the token
    and weight collectives per interconnect level (intra-node vs
    inter-node), so a slow cross-node fabric pulls the crossover toward
    data-centric — the per-node weight staging amortises the slow links.
    """
    if cfg.forced_layer_mode is not None:
        return _record_mode(cfg.forced_layer_mode, "forced")
    if cfg.layer_mode_plan and layer_idx is not None:
        planned = cfg.layer_mode_plan[layer_idx % len(cfg.layer_mode_plan)]
        if planned is not None:
            return _record_mode(planned, "plan")
    from repro.quant.core import quant_bits

    topo = getattr(cfg, "topology", None)
    hw = V5E if topo is None else dataclasses.replace(V5E, topology=topo)
    n_dev = float(_tp_group_size(cfg, mesh))
    fused = getattr(cfg, "fused_ffn", None)
    bits = quant_bits(getattr(cfg, "quant", "none"))
    plan = getattr(cfg, "hetero_plan", None)
    plan_lat = (None if plan is None
                else (plan.tp_latencies or plan.proxy_latencies))
    if plan_lat is not None and n_dev > 1 and len(plan_lat) == int(n_dev):
        lat = list(plan_lat)
        # Eq. 1 token weights; Eq. 2 hidden columns if the plan carries them.
        inv = [1.0 / t for t in lat]
        hs = (list(plan.hidden_splits)
              if plan.hidden_splits is not None else inv)
        wb = (list(plan.expert_bits)
              if plan.expert_bits is not None
              and len(plan.expert_bits) == len(lat) else bits)
        costs = {
            m: layer_latency_uneven(
                m, tokens, d, f, e, k, lat,
                token_shares=inv, hidden_shares=hs, hw=hw,
                fused_ffn=fused is not False, weight_bits=wb,
            )
            for m in CHOOSABLE_MODES
        }
        best = min(costs, key=costs.get)
        return _record_mode(best, "roofline_uneven", cost_s=costs[best])
    if cfg.device_latencies:
        lat = list(cfg.device_latencies)
        # Exactly one latency per group member: use them directly. A shorter
        # (or longer) list is a representative sample of the fleet mix —
        # scale its effective fraction to the group size rather than
        # silently modelling an n_dev-wide group as len(lat) devices.
        if len(lat) == int(n_dev):
            n_dev = effective_devices(lat)
        else:
            n_dev = n_dev * effective_devices(lat) / len(lat)
    return _record_mode(choose_mode(
        tokens, d, f, e, k, n_dev=n_dev, hw=hw, fused_ffn=fused is not False,
        weight_bits=bits,
    ), "roofline")


def plan_layer_modes(model_cfg, cfg, mesh, tokens: int) -> Tuple[Optional[str], ...]:
    """Whole-model plan: one entry per period position (None = not MoE).

    Pin the result into ``ParallelConfig.layer_mode_plan`` to freeze the
    decision (e.g. for the dry-run, or to ship a serving config that never
    re-derives it).
    """
    if model_cfg.moe is None:
        return ()
    m = model_cfg.moe
    out = []
    for pos in range(model_cfg.period):
        if not model_cfg.is_moe_layer(pos):
            out.append(None)
            continue
        out.append(resolve_layer_mode(
            tokens,
            d=model_cfg.d_model, f=m.d_ff, e=m.num_experts, k=m.top_k,
            cfg=cfg, mesh=mesh, layer_idx=pos,
        ))
    return tuple(out)
