"""Pipeline-shared cache: bounded gathered-expert-weight residency with
double-buffered prefetch (paper §4.5; DESIGN.md §2).

Data-centric execution all-gathers each MoE layer's expert weights at use.
Done naively, either (a) every layer re-gathers on the critical path (gather
latency exposed), or (b) all gathered copies stay live (residency = L layers
— the Janus baseline). The paper's pipeline-shared cache is the middle
point: at most C layers' gathered params are resident, and layer l+1's
gather is issued while layer l computes so the interconnect overlaps the
MXU.

``PipelineSharedCache`` realises this as a *trace-time* structure: the LM
forward's unrolled layer loop (``models.lm.run_layers`` with
``scan_layers=False`` and ``cache_layers > 0``) fetches layer l's gathered
tree (a hit — it was prefetched) and then prefetches layer l+1 BEFORE
emitting layer l's compute ops. In the lowered program the layer-(l+1)
all-gather therefore precedes, and is data-independent of, layer-l compute —
exactly the overlap XLA's latency-hiding scheduler needs — while eviction
drops the last reference to layer l-C+1's gathered buffers, bounding their
liveness. Residency accounting (resident/peak layers and bytes, hit/miss
counters) is exposed so ``benchmarks/memory_table.py`` can report it.

The gather itself is ``gather_ffn_params``: a GSPMD-level all-gather
expressed as a sharding constraint that drops the "fsdp" factor from each
weight's logical spec. ``moe_parallel.moe_layer(..., pregathered=True)``
then skips the island-internal fsdp gather and adjusts its in_specs.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Optional, Sequence

from repro.common import tree_bytes  # noqa: F401  (re-exported: cache API)
from repro import obs


class PipelineSharedCache:
    """Bounded FIFO cache of gathered parameter trees.

    capacity_layers: maximum simultaneously-resident gathered layers. 2 is
    the double-buffer (current + prefetched next); the Janus baseline is
    effectively capacity = num_layers.
    """

    def __init__(self, capacity_layers: int = 2):
        if capacity_layers < 1:
            raise ValueError("capacity_layers must be >= 1")
        self.capacity_layers = capacity_layers
        self._resident: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0      # fetch-path gathers (critical-path stalls)
        self.prefetches = 0  # gathers issued ahead of use (overlapped)
        self.evictions = 0
        self.peak_resident_layers = 0
        self.peak_resident_bytes = 0
        obs.maybe_register(self)

    # -- core ---------------------------------------------------------------

    def fetch(self, key: Hashable, gather_fn: Callable[[], Any]) -> Any:
        """Return the gathered tree for ``key``, gathering on a miss."""
        if key in self._resident:
            self.hits += 1
            return self._resident[key]
        self.misses += 1
        value = gather_fn()
        self._insert(key, value)
        return value

    def prefetch(self, key: Hashable, gather_fn: Callable[[], Any]) -> None:
        """Issue the gather for ``key`` now (no-op if already resident).

        Call AFTER fetching the current layer and BEFORE emitting its
        compute: the prefetched gather then has no data dependence on the
        current layer's ops and can overlap them. Counted separately from
        misses — a prefetched gather is off the critical path.
        """
        if key not in self._resident:
            self.prefetches += 1
            self._insert(key, gather_fn())

    def _insert(self, key: Hashable, value: Any) -> None:
        self._resident[key] = value
        while len(self._resident) > self.capacity_layers:
            self._resident.popitem(last=False)
            self.evictions += 1
        self.peak_resident_layers = max(
            self.peak_resident_layers, len(self._resident)
        )
        self.peak_resident_bytes = max(
            self.peak_resident_bytes, self.resident_bytes()
        )

    # -- accounting ---------------------------------------------------------

    @property
    def resident_layers(self) -> int:
        return len(self._resident)

    def resident_bytes(self) -> int:
        return sum(tree_bytes(v) for v in self._resident.values())

    def stats(self) -> Dict[str, int]:
        return {
            "capacity_layers": self.capacity_layers,
            "resident_layers": self.resident_layers,
            "resident_bytes": self.resident_bytes(),
            "peak_resident_layers": self.peak_resident_layers,
            "peak_resident_bytes": self.peak_resident_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "prefetches": self.prefetches,
            "evictions": self.evictions,
        }

    def obs_metrics(self) -> Dict[str, float]:
        """Snapshot for the observability registry (DESIGN.md §12): one
        ``repro_cache_<stat>`` gauge per ``stats()`` entry, disambiguated
        across cache kinds by the registry's ``kind`` label."""
        return {f"repro_cache_{k}": float(v) for k, v in self.stats().items()}


class PlanCache(PipelineSharedCache):
    """Bounded FIFO cache of compiled step functions keyed by hetero-plan
    tuples (``core.hetero.HeteroPlan.key()``) — the re-trace bound of the
    straggler→replan loop (DESIGN.md §6).

    Every distinct plan is a distinct trace (the Eq. 1 shares are baked in
    as constants), so an unbounded replanner would accumulate compiled
    executables without limit; this reuses the pipeline-shared cache's FIFO
    residency + hit/miss accounting, with ``capacity_layers`` re-read as
    "simultaneously-retained plans". A replan that oscillates between two
    plans therefore re-traces exactly twice and then only hits. Values are
    callables, not arrays, so byte accounting is disabled.
    """

    def resident_bytes(self) -> int:
        return 0


# ---------------------------------------------------------------------------
# the gather the cache holds
# ---------------------------------------------------------------------------

def _drop_axes(logical: tuple, which=("fsdp",)) -> tuple:
    out = []
    for entry in logical:
        if entry in which:
            out.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a not in which)
            out.append(kept if kept else None)
        else:
            out.append(entry)
    return tuple(out)


def _drop_fsdp(logical: tuple) -> tuple:
    return _drop_axes(logical, ("fsdp",))


def gather_ffn_params(ffn: dict, cfg, mesh, *, collectives: str = "fsdp") -> dict:
    """All-gather the fsdp factor of every MoE FFN weight leaf.

    Expressed as a sharding constraint (GSPMD inserts the all-gather), so it
    composes with jit/scan and is a no-op without a mesh. The router stays
    replicated; TP factors stay sharded — per-layer data-centric dispatch
    gathers those inside the island (see moe_parallel).

    ``collectives="all"`` (the overlap schedule, DESIGN.md §10) gathers the
    tp factor too: the unrolled layer loop prefetches the NEXT data-centric
    layer's full expert weights while the current layer computes —
    generalizing this cache's double buffering from fsdp gathers to the MoE
    expert collectives themselves. The gathered values are exactly the ones
    the in-island gather would produce, so the overlap schedule is
    bit-identical to the eager one.
    """
    from repro.parallel.moe_parallel import MOE_PARAM_LOGICAL
    from repro.parallel.sharding import constrain

    drop = ("fsdp", "tp") if collectives == "all" else ("fsdp",)
    out = {}
    for name, v in ffn.items():
        logical = MOE_PARAM_LOGICAL.get(name)
        if v is None or logical is None or name == "router":
            out[name] = v
            continue
        out[name] = constrain(v, _drop_axes(logical, drop), cfg, mesh)
    return out


# ---------------------------------------------------------------------------
# serving page pool (paged KV cache residency, DESIGN.md §7)
# ---------------------------------------------------------------------------

class PagePool:
    """Host-side free-list allocator + copy-on-write refcounts + residency
    accounting over the shared KV page pool of
    ``models.lm.init_paged_cache`` (DESIGN.md §7).

    Physical page 0 is the write sink for inactive slots and is never
    allocated; ``num_pages - 1`` pages are allocatable. The scheduler's
    admission invariant is two-phase:

      * ``try_reserve(n, group)`` at admission — the request's WORST-CASE
        page count is debited from the (group's) free budget up front, so
        preemption-free decode can never hit an empty pool mid-request;
      * ``alloc(group)`` converts one reserved page into a physical page id
        (a chunk's worth at prefill, on demand at decode page boundaries);
      * ``release(pages, group, unused_reserved)`` drops one reference per
        page at completion.

    Copy-on-write prefix sharing (DESIGN.md §7) layers refcounts on top:
    ``alloc`` hands out a page at refcount 1 owned by its group; ``fork``
    maps the same physical page into another holder at refcount+1 (the
    radix prefix index and every borrowing slot hold one reference each —
    a fork consumes NO page budget, which is the whole capacity win);
    ``release`` decrements and only a 0-refcount page returns to the free
    list (credited to its OWNER group); ``cow`` is the write trigger —
    writing a refcount>1 page surrenders the shared reference and converts
    one reservation into a private copy's page id. Releasing a page below
    refcount 0 (the classic double-free) raises instead of corrupting the
    free list.

    Heterogeneous plans (DESIGN.md §6) express per-device capacity as
    per-group page-pool ``shares`` instead of masked tail slots: physical
    pages stay fungible in one free list, but each group's
    reserve/alloc/release is budgeted against its own share. A forked page
    stays charged to the group that allocated it until its LAST reference
    dies, so cross-group sharing can pin another group's budget — the
    documented cost of keeping pages fungible.

    Per-group invariant, checked by ``assert_consistent``:
    ``free + reserved_unallocated + in_use == share``.
    """

    def __init__(self, num_pages: int, *, page_bytes: int = 0,
                 shares: Optional[Sequence[int]] = None):
        if num_pages < 2:
            raise ValueError("need at least one allocatable page + the sink")
        usable = num_pages - 1
        self.num_pages = num_pages
        self.page_bytes = page_bytes
        self.shares = list(shares) if shares is not None else [usable]
        if any(s < 0 for s in self.shares):
            raise ValueError(f"negative page share: {self.shares}")
        if sum(self.shares) > usable:
            raise ValueError(
                f"shares {self.shares} exceed {usable} allocatable pages"
            )
        self._free_list = list(range(num_pages - 1, 0, -1))
        g = len(self.shares)
        self._free = list(self.shares)
        self._reserved = [0] * g
        self._in_use = [0] * g
        self._ref: Dict[int, int] = {}    # page -> live references
        self._owner: Dict[int, int] = {}  # page -> group charged for it
        self.total_allocs = 0
        self.total_frees = 0
        self.total_forks = 0
        self.total_cow_copies = 0
        self.total_rollbacks = 0
        self.peak_in_use_pages = 0

    # -- admission / allocation ---------------------------------------------

    def try_reserve(self, n: int, group: int = 0) -> bool:
        """Debit ``n`` worst-case pages from ``group``'s budget (admission
        by free-page budget). False leaves the pool untouched."""
        if n < 0:
            raise ValueError(n)
        if self._free[group] < n:
            return False
        self._free[group] -= n
        self._reserved[group] += n
        return True

    def alloc(self, group: int = 0) -> int:
        """Turn one reserved page into a physical page id (>= 1) at
        refcount 1, owned by (charged to) ``group``."""
        if self._reserved[group] <= 0:
            raise RuntimeError(
                f"group {group} allocating beyond its reservation"
            )
        self._reserved[group] -= 1
        self._in_use[group] += 1
        self.total_allocs += 1
        page = self._free_list.pop()
        self._ref[page] = 1
        self._owner[page] = group
        self.peak_in_use_pages = max(self.peak_in_use_pages,
                                     self.in_use_pages)
        return page

    def fork(self, pages: Sequence[int]) -> None:
        """Add one reference to each live page (prefix sharing: a borrowing
        slot or the radix index maps the page without copying it). Costs no
        group budget — that is the capacity win. Forking a free page or the
        sink is a scheduler bug and raises."""
        for p in pages:
            if not 1 <= p < self.num_pages:
                raise ValueError(f"bad page id {p}")
            if self._ref.get(p, 0) <= 0:
                raise RuntimeError(f"fork of free page {p}")
        for p in pages:
            self._ref[p] += 1
            self.total_forks += 1

    def refcount(self, page: int) -> int:
        """Live references on ``page`` (0 = free)."""
        return self._ref.get(page, 0)

    def cow(self, page: int, group: int = 0) -> int:
        """Write trigger for a shared page: at refcount 1 the caller owns
        the page exclusively and may write in place (returned unchanged);
        at refcount>1 the caller's reference is surrendered and one of its
        ``group`` reservations converts into a fresh private page id. The
        caller must copy the page's payload (``launch.steps.
        make_page_copy_step``) and repoint its table entry."""
        if self._ref.get(page, 0) <= 0:
            raise RuntimeError(f"cow on free page {page}")
        if self._ref[page] == 1:
            return page
        self._ref[page] -= 1
        self.total_cow_copies += 1
        return self.alloc(group)

    def release(self, pages: Sequence[int], group: int = 0,
                unused_reserved: int = 0) -> None:
        """Drop one reference per page (returning 0-refcount pages to the
        free list, credited to their owner group) plus any reservation the
        caller never converted. Releasing a free page raises — the
        double-free guard the refcount layer exists for."""
        for p in pages:
            if not 1 <= p < self.num_pages:
                raise ValueError(f"bad page id {p}")
            if self._ref.get(p, 0) <= 0:
                raise RuntimeError(
                    f"double release of page {p} (refcount already 0)"
                )
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                owner = self._owner.pop(p)
                self._free_list.append(p)
                self._in_use[owner] -= 1
                self._free[owner] += 1
                self.total_frees += 1
        self._reserved[group] -= unused_reserved
        self._free[group] += unused_reserved
        if self._reserved[group] < 0:
            raise RuntimeError(f"group {group} over-released")

    def rollback(self, pages: Sequence[int], group: int = 0) -> None:
        """Return decode-granted pages to the caller's **reservation** —
        the speculative-decoding rollback path (DESIGN.md §11).

        ``release`` credits a freed page to its owner's FREE budget, where
        the next admission can immediately claim it; a rolled-back request
        is still live and must be able to re-grow to its admitted
        worst-case length, so its truncated pages convert ``in_use`` back
        into ``reserved`` instead (the alloc-cannot-fail invariant of
        decode-boundary grants survives mid-request truncation).

        Only exclusively-held (refcount-1) pages owned by ``group`` may
        roll back: a refcount>1 page is prefix-shared content whose other
        holders must survive (CoW semantics, DESIGN.md §7). The engine
        never truncates into one — rollback pops strictly decode-region
        tail pages, past any matched prompt prefix — so hitting a shared
        or foreign page here is a scheduler bug and raises before any
        state changes."""
        for p in pages:
            if not 1 <= p < self.num_pages:
                raise ValueError(f"bad page id {p}")
            r = self._ref.get(p, 0)
            if r != 1:
                raise RuntimeError(
                    f"rollback of page {p} at refcount {r} (only "
                    f"exclusively-held decode pages may roll back)")
            if self._owner[p] != group:
                raise RuntimeError(
                    f"rollback of page {p} owned by group "
                    f"{self._owner[p]}, not caller group {group}")
        for p in pages:
            del self._ref[p]
            del self._owner[p]
            self._free_list.append(p)
            self._in_use[group] -= 1
            self._reserved[group] += 1
            self.total_frees += 1
            self.total_rollbacks += 1

    # -- accounting -----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return sum(self._free)

    @property
    def in_use_pages(self) -> int:
        return sum(self._in_use)

    @property
    def reserved_pages(self) -> int:
        return sum(self._reserved)

    def group_free(self, group: int) -> int:
        return self._free[group]

    def reset_peak(self) -> None:
        """Restart peak tracking from the current residency (benchmarks
        call this after their warm-up workload)."""
        self.peak_in_use_pages = self.in_use_pages

    def reshare(self, shares: Sequence[int]) -> None:
        """Rebind the per-group share budgets — the elastic-shrink path
        (DESIGN.md §9): after a device class drops out, the serving engine
        re-derives ``page_shares`` over the survivors and rebinds the pool
        to the new group set. Only legal on a fully **drained** pool (no
        live or reserved pages): live pages are charged to their owner
        group, and re-binning them across a changed group set would break
        the per-group conservation invariant — the engine aborts live
        slots back to the queue first, which is also what carries their
        requests across the shrink."""
        if self.in_use_pages or self.reserved_pages:
            raise RuntimeError(
                f"reshare on a non-drained pool ({self.in_use_pages} live, "
                f"{self.reserved_pages} reserved pages)")
        usable = self.num_pages - 1
        shares = list(shares)
        if any(s < 0 for s in shares):
            raise ValueError(f"negative page share: {shares}")
        if sum(shares) > usable:
            raise ValueError(
                f"shares {shares} exceed {usable} allocatable pages")
        self.shares = shares
        g = len(shares)
        self._free = list(shares)
        self._reserved = [0] * g
        self._in_use = [0] * g
        self._free_list = list(range(self.num_pages - 1, 0, -1))
        self.assert_consistent()

    def assert_consistent(self) -> None:
        for g, share in enumerate(self.shares):
            total = self._free[g] + self._reserved[g] + self._in_use[g]
            assert total == share, (g, self._free[g], self._reserved[g],
                                    self._in_use[g], share)
        assert len(self._free_list) == (self.num_pages - 1
                                        - self.in_use_pages)
        assert len(set(self._free_list)) == len(self._free_list)
        # refcount layer: live pages and the free list partition the pool
        assert all(r > 0 for r in self._ref.values()), self._ref
        assert set(self._ref) == set(self._owner)
        assert not (set(self._ref) & set(self._free_list)), (
            "page both live and free")
        assert len(self._ref) == self.in_use_pages
        for g in range(len(self.shares)):
            assert self._in_use[g] == sum(
                1 for o in self._owner.values() if o == g)

    def stats(self) -> Dict[str, int]:
        return {
            "num_pages": self.num_pages,
            "page_bytes": self.page_bytes,
            "free_pages": self.free_pages,
            "in_use_pages": self.in_use_pages,
            "reserved_pages": self.reserved_pages,
            "shared_pages": sum(1 for r in self._ref.values() if r > 1),
            "peak_in_use_pages": self.peak_in_use_pages,
            "peak_in_use_bytes": self.peak_in_use_pages * self.page_bytes,
            "total_allocs": self.total_allocs,
            "total_frees": self.total_frees,
            "total_forks": self.total_forks,
            "total_cow_copies": self.total_cow_copies,
            "total_rollbacks": self.total_rollbacks,
        }

    def obs_metrics(self) -> Dict[str, float]:
        """Snapshot for the observability registry (DESIGN.md §12)."""
        return {f"repro_cache_{k}": float(v) for k, v in self.stats().items()}


def page_shares(weights: Sequence[float], usable_pages: int) -> list[int]:
    """Largest-remainder split of the allocatable pages proportional to
    ``weights`` (a hetero plan's Eq. 1 ``token_counts``): the per-device
    page-pool shares that replace masked tail slots (DESIGN.md §7)."""
    import numpy as np

    w = np.asarray(weights, dtype=np.float64)
    if np.any(w < 0) or w.sum() <= 0:
        raise ValueError(f"bad share weights {weights}")
    raw = w / w.sum() * usable_pages
    base = np.floor(raw).astype(np.int64)
    order = np.argsort(-(raw - base))
    base[order[: usable_pages - int(base.sum())]] += 1
    assert base.sum() == usable_pages
    return [int(v) for v in base]


# ---------------------------------------------------------------------------
# radix prefix index (CoW prefix sharing, DESIGN.md §7)
# ---------------------------------------------------------------------------

class _TrieNode:
    """One page-granular edge of the prefix trie: ``key`` is the tuple of
    ``page_size`` token ids this node's page holds, ``page`` the physical
    page id the index keeps one ``PagePool`` reference on."""

    __slots__ = ("key", "page", "children", "parent", "last_used")

    def __init__(self, key, page, parent):
        self.key = key
        self.page = page
        self.children: Dict[tuple, "_TrieNode"] = {}
        self.parent = parent
        self.last_used = 0


class PrefixIndex:
    """Radix/trie prefix index keyed on token ids at page granularity
    (DESIGN.md §7): admission matches the longest cached prefix of a
    prompt against whole pages already resident in the paged-KV pool and
    maps them into the new slot's page table at refcount+1 instead of
    re-prefilling them.

    Only FULL pages are indexed — a page is inserted when the prompt that
    wrote it finishes prefill and covers the page end-to-end, so cached
    content is immutable by construction (decode writes land strictly past
    the prompt, never inside an indexed page) and CoW copies stay a
    defensive guard rather than a steady-state cost. K/V rows depend only
    on the token prefix and the absolute position (RoPE/window masks are
    position-absolute), so identical token chunks at identical depths are
    bitwise-shareable across slots; int8 pools share their scale pages
    through the same physical index (DESIGN.md §8).

    Every node holds exactly ONE pool reference on its page. ``evict_lru``
    frees the least-recently-used leaf whose page has refcount 1 (cached
    but borrowed by no live slot — interior nodes and borrowed pages are
    pinned), feeding pages back to the admission budget.
    """

    def __init__(self, page_size: int):
        if page_size < 1:
            raise ValueError(page_size)
        self.page_size = page_size
        self.root = _TrieNode(None, 0, None)
        self._clock = 0
        self.lookups = 0
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.evictions = 0

    def __len__(self) -> int:
        """Number of cached pages (trie nodes below the root)."""
        n, stack = 0, [self.root]
        while stack:
            node = stack.pop()
            n += len(node.children)
            stack.extend(node.children.values())
        return n

    def _chunks(self, tokens, max_pages: int):
        P = self.page_size
        limit = min(max_pages, len(tokens) // P)
        return [tuple(int(t) for t in tokens[i * P:(i + 1) * P])
                for i in range(limit)]

    def match(self, tokens, max_pages: int) -> list:
        """Longest cached prefix of ``tokens``: physical page ids of the
        leading whole-page chunks present in the trie (at most
        ``max_pages`` — the scheduler caps at ``(prompt_len - 1) // P`` so
        at least one suffix token is always left to prefill, which is what
        produces the first generated token's logits). Bumps LRU clocks
        along the matched path; the caller must ``PagePool.fork`` the
        returned pages before anything else can evict them."""
        self.lookups += 1
        self.lookup_tokens += len(tokens)
        node, pages = self.root, []
        self._clock += 1
        for key in self._chunks(tokens, max_pages):
            child = node.children.get(key)
            if child is None:
                break
            child.last_used = self._clock
            pages.append(child.page)
            node = child
        self.hit_tokens += len(pages) * self.page_size
        return pages

    def insert(self, tokens, pages: Sequence[int], pool: PagePool) -> int:
        """Index the leading ``len(pages)`` whole-page chunks of ``tokens``
        under their physical ``pages``, forking one pool reference per
        NEWLY-created node (chunks already cached keep their existing page
        — two requests racing the same prefix do not double-index). Returns
        the number of pages newly indexed."""
        node = self.root
        self._clock += 1
        added = 0
        for key, page in zip(self._chunks(tokens, len(pages)), pages):
            child = node.children.get(key)
            if child is None:
                pool.fork([page])
                child = _TrieNode(key, page, node)
                child.last_used = self._clock
                node.children[key] = child
                added += 1
            node = child
        return added

    def evict_lru(self, pool: PagePool) -> bool:
        """Release the least-recently-used evictable page back to the pool
        (refcount-1 leaf: cached but borrowed by no slot and shadowing no
        longer chain). False when nothing is evictable — the admission
        loop's stop condition."""
        best = None
        stack = [self.root]
        while stack:
            node = stack.pop()
            for child in node.children.values():
                if not child.children and pool.refcount(child.page) == 1:
                    if best is None or child.last_used < best.last_used:
                        best = child
                stack.append(child)
        if best is None:
            return False
        pool.release([best.page])
        del best.parent.children[best.key]
        self.evictions += 1
        return True

    def pages(self):
        """Yield the physical page id of every trie node — one pool
        reference each. The serving engine's structural audit
        (``PagedServer.assert_page_invariants``, DESIGN.md §9) recomputes
        refcounts as slot holders + these."""
        stack = [self.root]
        while stack:
            node = stack.pop()
            for child in node.children.values():
                yield child.page
                stack.append(child)

    def clear(self, pool: PagePool) -> int:
        """Drop every cached reference (leaf-first). Servers call this to
        drain the cache so end-of-run leak checks see the whole pool."""
        dropped = 0
        while self.evict_lru(pool):
            dropped += 1
        # anything left is pinned by live borrowers; detach the index's
        # references anyway only when unpinned — a non-empty remainder
        # means slots still hold forks, which is not a leak.
        return dropped

    def stats(self) -> Dict[str, int]:
        """Hit-rate counters the serving benchmark reports."""
        return {
            "cached_pages": len(self),
            "lookups": self.lookups,
            "hit_tokens": self.hit_tokens,
            "lookup_tokens": self.lookup_tokens,
            "evictions": self.evictions,
        }

    def obs_metrics(self) -> Dict[str, float]:
        """Snapshot for the observability registry (DESIGN.md §12)."""
        return {f"repro_cache_{k}": float(v) for k, v in self.stats().items()}


def gathered_layer_bytes(d: int, f: int, e: int, *, glu: bool = True,
                         bytes_per_el: int = 2) -> int:
    """Bytes of ONE layer's fully-gathered expert weights (the unit the
    residency bound multiplies)."""
    n_mats = 3 if glu else 2
    total = e * n_mats * d * f * bytes_per_el
    if not glu:
        total += e * (f + d) * 4  # f32 biases
    return total
