"""Pipeline-shared cache: bounded gathered-expert-weight residency with
double-buffered prefetch (paper §4.5; DESIGN.md §2).

Data-centric execution all-gathers each MoE layer's expert weights at use.
Done naively, either (a) every layer re-gathers on the critical path (gather
latency exposed), or (b) all gathered copies stay live (residency = L layers
— the Janus baseline). The paper's pipeline-shared cache is the middle
point: at most C layers' gathered params are resident, and layer l+1's
gather is issued while layer l computes so the interconnect overlaps the
MXU.

``PipelineSharedCache`` realises this as a *trace-time* structure: the LM
forward's unrolled layer loop (``models.lm.run_layers`` with
``scan_layers=False`` and ``cache_layers > 0``) fetches layer l's gathered
tree (a hit — it was prefetched) and then prefetches layer l+1 BEFORE
emitting layer l's compute ops. In the lowered program the layer-(l+1)
all-gather therefore precedes, and is data-independent of, layer-l compute —
exactly the overlap XLA's latency-hiding scheduler needs — while eviction
drops the last reference to layer l-C+1's gathered buffers, bounding their
liveness. Residency accounting (resident/peak layers and bytes, hit/miss
counters) is exposed so ``benchmarks/memory_table.py`` can report it.

The gather itself is ``gather_ffn_params``: a GSPMD-level all-gather
expressed as a sharding constraint that drops the "fsdp" factor from each
weight's logical spec. ``moe_parallel.moe_layer(..., pregathered=True)``
then skips the island-internal fsdp gather and adjusts its in_specs.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable

from repro.common import tree_bytes  # noqa: F401  (re-exported: cache API)


class PipelineSharedCache:
    """Bounded FIFO cache of gathered parameter trees.

    capacity_layers: maximum simultaneously-resident gathered layers. 2 is
    the double-buffer (current + prefetched next); the Janus baseline is
    effectively capacity = num_layers.
    """

    def __init__(self, capacity_layers: int = 2):
        if capacity_layers < 1:
            raise ValueError("capacity_layers must be >= 1")
        self.capacity_layers = capacity_layers
        self._resident: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0      # fetch-path gathers (critical-path stalls)
        self.prefetches = 0  # gathers issued ahead of use (overlapped)
        self.evictions = 0
        self.peak_resident_layers = 0
        self.peak_resident_bytes = 0

    # -- core ---------------------------------------------------------------

    def fetch(self, key: Hashable, gather_fn: Callable[[], Any]) -> Any:
        """Return the gathered tree for ``key``, gathering on a miss."""
        if key in self._resident:
            self.hits += 1
            return self._resident[key]
        self.misses += 1
        value = gather_fn()
        self._insert(key, value)
        return value

    def prefetch(self, key: Hashable, gather_fn: Callable[[], Any]) -> None:
        """Issue the gather for ``key`` now (no-op if already resident).

        Call AFTER fetching the current layer and BEFORE emitting its
        compute: the prefetched gather then has no data dependence on the
        current layer's ops and can overlap them. Counted separately from
        misses — a prefetched gather is off the critical path.
        """
        if key not in self._resident:
            self.prefetches += 1
            self._insert(key, gather_fn())

    def _insert(self, key: Hashable, value: Any) -> None:
        self._resident[key] = value
        while len(self._resident) > self.capacity_layers:
            self._resident.popitem(last=False)
            self.evictions += 1
        self.peak_resident_layers = max(
            self.peak_resident_layers, len(self._resident)
        )
        self.peak_resident_bytes = max(
            self.peak_resident_bytes, self.resident_bytes()
        )

    # -- accounting ---------------------------------------------------------

    @property
    def resident_layers(self) -> int:
        return len(self._resident)

    def resident_bytes(self) -> int:
        return sum(tree_bytes(v) for v in self._resident.values())

    def stats(self) -> Dict[str, int]:
        return {
            "capacity_layers": self.capacity_layers,
            "resident_layers": self.resident_layers,
            "resident_bytes": self.resident_bytes(),
            "peak_resident_layers": self.peak_resident_layers,
            "peak_resident_bytes": self.peak_resident_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "prefetches": self.prefetches,
            "evictions": self.evictions,
        }


class PlanCache(PipelineSharedCache):
    """Bounded FIFO cache of compiled step functions keyed by hetero-plan
    tuples (``core.hetero.HeteroPlan.key()``) — the re-trace bound of the
    straggler→replan loop (DESIGN.md §6).

    Every distinct plan is a distinct trace (the Eq. 1 shares are baked in
    as constants), so an unbounded replanner would accumulate compiled
    executables without limit; this reuses the pipeline-shared cache's FIFO
    residency + hit/miss accounting, with ``capacity_layers`` re-read as
    "simultaneously-retained plans". A replan that oscillates between two
    plans therefore re-traces exactly twice and then only hits. Values are
    callables, not arrays, so byte accounting is disabled.
    """

    def resident_bytes(self) -> int:
        return 0


# ---------------------------------------------------------------------------
# the gather the cache holds
# ---------------------------------------------------------------------------

def _drop_fsdp(logical: tuple) -> tuple:
    out = []
    for entry in logical:
        if entry == "fsdp":
            out.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a != "fsdp")
            out.append(kept if kept else None)
        else:
            out.append(entry)
    return tuple(out)


def gather_ffn_params(ffn: dict, cfg, mesh) -> dict:
    """All-gather the fsdp factor of every MoE FFN weight leaf.

    Expressed as a sharding constraint (GSPMD inserts the all-gather), so it
    composes with jit/scan and is a no-op without a mesh. The router stays
    replicated; TP factors stay sharded — per-layer data-centric dispatch
    gathers those inside the island (see moe_parallel).
    """
    from repro.parallel.moe_parallel import MOE_PARAM_LOGICAL
    from repro.parallel.sharding import constrain

    out = {}
    for name, v in ffn.items():
        logical = MOE_PARAM_LOGICAL.get(name)
        if v is None or logical is None or name == "router":
            out[name] = v
            continue
        out[name] = constrain(v, _drop_fsdp(logical), cfg, mesh)
    return out


def gathered_layer_bytes(d: int, f: int, e: int, *, glu: bool = True,
                         bytes_per_el: int = 2) -> int:
    """Bytes of ONE layer's fully-gathered expert weights (the unit the
    residency bound multiplies)."""
    n_mats = 3 if glu else 2
    total = e * n_mats * d * f * bytes_per_el
    if not glu:
        total += e * (f + d) * 4  # f32 biases
    return total
