"""Distributed MoE layer execution — the paper's §4.3 on a TPU mesh.

One shard_map island per MoE layer gives exact control of the collective
schedule (auditable in the dry-run HLO):

  model-centric (paper TP): expert hidden dim sharded over "model"; tokens
    all-gathered over "model"; partial outputs reduced. NO weight movement.
  data-centric (paper Janus-style): expert params sharded over every mesh
    axis; all-gathered to each device at use; tokens never move. The
    pipeline-shared cache (bounded gathered-param residency) is realised two
    ways: the surrounding remat policy (gathered params are not saved as
    backward residuals, the backward re-gathers layer by layer) and, in the
    unrolled layer loop, parallel.cache.PipelineSharedCache's double-buffered
    prefetch (DESIGN.md §2).
  hybrid (beyond paper): fsdp gather over ("pod","data") + TP over "model".
  auto (paper §4.5 / Fig. 10, runtime form): hybrid physical layout; each
    MoE layer picks data- or model-centric dispatch at trace time via
    parallel.autotune's roofline — "move tokens over TP" vs "gather the
    weights' TP factor" is a per-layer ``layer_mode`` choice inside the
    island, so prefill and decode land on opposite sides of the crossover.
  ep (baseline): classic expert parallelism with all-to-all + capacity
    buffer — exists to quantify the paper's motivation in the roofline.

Collective schedule options (DESIGN.md §2):
  "ag_ar" — paper-faithful: tokens replicated over TP, outputs all-reduced.
  "ag_rs" — bandwidth-optimal sequence-parallel form: all-gather tokens in,
            reduce-scatter outputs; 2x less collective traffic at scale.

Everything here is a *token-level* API: x is (N_local, D) inside the island.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import baselines, espec
from repro.core.reindex import build_reindex
from repro.core.routing import route
from repro.obs import device as obs_device
from repro.parallel.sharding import ParallelConfig


class MoEParams(NamedTuple):
    """Expert parameter shards as seen inside the island (local views).

    The ``*_scale`` leaves are present only for true-quantized expert
    weights (int8/fp8 payloads from ``quant.core.quantize_ffn``,
    DESIGN.md §8): block-wise per-(expert, tile) scales the fused-dequant
    kernels consume. Routers and biases are never quantized."""
    router: jax.Array                  # (D, E) replicated
    w_gate: Optional[jax.Array] = None  # (E, D_l, F_l) glu
    w_up: Optional[jax.Array] = None    # (E, D_l, F_l) glu
    w_down: Optional[jax.Array] = None  # (E, F_l, D_l) glu
    w1: Optional[jax.Array] = None      # (E, D_l, F_l) mlp
    b1: Optional[jax.Array] = None      # (E, F_l) mlp
    w2: Optional[jax.Array] = None      # (E, F_l, D_l) mlp
    b2: Optional[jax.Array] = None      # (E, D_l) mlp
    w_gate_scale: Optional[jax.Array] = None  # (E, nD, nF)
    w_up_scale: Optional[jax.Array] = None
    w_down_scale: Optional[jax.Array] = None  # (E, nF, nD)
    w1_scale: Optional[jax.Array] = None
    w2_scale: Optional[jax.Array] = None


class MoEStatic(NamedTuple):
    """Static (trace-time) MoE layer hyperparameters shared by every
    island implementation (paper §4.2 routing + §4.3 execution)."""
    num_experts: int
    top_k: int
    act: str = "silu"
    glu: bool = True
    norm_topk: bool = True
    softmax_after_topk: bool = False


def _resolve_shard_map():
    """jax.shard_map moved out of jax.experimental in 2025-era jax; the
    replication check was renamed check_rep -> check_vma along the way (some
    releases expose jax.shard_map but still spell it check_rep). Resolve
    both once at import so the mesh path runs across the 0.4.x-0.6.x span."""
    import inspect

    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    try:
        kw = ("check_vma" if "check_vma" in inspect.signature(sm).parameters
              else "check_rep")
    except (TypeError, ValueError):  # C-level signature: assume modern name
        kw = "check_vma"
    return sm, kw


_SHARD_MAP, _SHARD_MAP_CHECK_KW = _resolve_shard_map()


def _shard_map(body, mesh, in_specs, out_specs):
    return _SHARD_MAP(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_SHARD_MAP_CHECK_KW: False},
    )


def _ag(x, axes, dim):
    """all_gather over possibly-multiple mesh axes (tiled)."""
    if not axes:
        return x
    return lax.all_gather(x, axes, axis=dim, tiled=True)


def _ag_hier(x, axes, dim, hier: bool):
    """Tiled all-gather, optionally phased per interconnect level.

    Hierarchical (DESIGN.md §10): gather the minor (intra-node, fast) axis
    first, then the major (inter-node, slow) one — after the intra phase the
    cross-node exchange moves each node's already-assembled shard once. The
    phased gather concatenates in exactly the tuple axis order, so its
    result is bitwise-identical to the single flat gather; only the
    collective decomposition (and therefore the per-link traffic) differs."""
    if not axes:
        return x
    if hier and isinstance(axes, tuple) and len(axes) > 1:
        for ax in reversed(axes):
            x = lax.all_gather(x, ax, axis=dim, tiled=True)
        return x
    return lax.all_gather(x, axes, axis=dim, tiled=True)


def _axis_size(mesh, axes) -> int:
    """Extent of a (possibly tuple) mesh axis group."""
    if not axes:
        return 1
    size = 1
    for ax in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[ax]
    return int(size)


def _hier_schedule(cfg: ParallelConfig, mesh, tp) -> bool:
    """True when the island should run the two-level collective schedule:
    a topology is attached AND the TP group spans a real "node" axis
    (DESIGN.md §10). Everything else — no topology, no node axis, or a
    single-node mesh — short-circuits to the flat single-level collectives,
    so those configs compile to HLO bitwise-identical to the pre-topology
    path."""
    return (
        getattr(cfg, "topology", None) is not None
        and isinstance(tp, tuple)
        and len(tp) == 2
        and int(mesh.shape[tp[0]]) > 1
    )


def _psum_hier(y, axes, hier: bool):
    """All-reduce, phased per level when hierarchical: the intra-node psum
    (node-local combine) runs first so only node-combined partial sums cross
    the slow inter-node fabric (DESIGN.md §10)."""
    if hier and isinstance(axes, tuple) and len(axes) > 1:
        for ax in reversed(axes):
            y = lax.psum(y, ax)
        return y
    return lax.psum(y, axes)


def _mask_rank0(b, tp_axis):
    """Keep a partial-sum bias on TP rank 0 only (avoids k_tp-fold bias)."""
    if b is None or tp_axis is None:
        return b
    rank = lax.axis_index(tp_axis)
    return jnp.where(rank == 0, b, jnp.zeros_like(b))


def hexa_moe_island(
    x: jax.Array,
    p: MoEParams,
    ms: MoEStatic,
    cfg: ParallelConfig,
    mesh: Mesh,
    *,
    tokens_sharded_tp: bool,
    noise_rng: Optional[jax.Array] = None,
    layer_mode: Optional[str] = None,
    pregathered=False,
    token_valid: Optional[jax.Array] = None,
):
    """Body of the shard_map island: local tokens x (N_l, D) -> (y, aux, z).

    ``tokens_sharded_tp``: whether the incoming token dim is sharded over the
    TP axis (training/prefill with SP) or replicated (decode).
    ``layer_mode``: per-layer dispatch under ``cfg.mode == "auto"`` —
    "data_centric" gathers the weights' TP factor and keeps tokens (and the
    output) local; "model_centric"/None keeps the TP compute split and moves
    tokens. ``pregathered``: which weight collectives already ran outside
    the island (pipeline-shared cache): False = none, True/"fsdp" = the
    fsdp factor, "all" = fsdp AND the data-centric tp factor (the overlap
    schedule, DESIGN.md §10) — skip the corresponding in-island gathers.
    ``token_valid``: optional (N_l,) bool — heterogeneous-plan (Eq. 1) tail
    mask (DESIGN.md §6): invalid rows route with gate 0, produce exactly-zero
    output rows and exactly-zero weight gradients, and are excluded from the
    aux losses. Travels through the same TP gather as the tokens.

    With ``cfg.topology`` on a two-level ("node", "model") mesh the
    collectives run the hierarchical schedule (DESIGN.md §10): token and
    weight gathers are phased intra-node -> inter-node (bitwise-identical
    values), and the output combine reduces node-locally BEFORE the
    cross-node exchange, shrinking inter-node partial-sum traffic by the
    node size. Flat/uniform meshes short-circuit to the single-level path.
    """
    axes = cfg.axes(mesh)
    fsdp, tp = axes["fsdp"], axes["tp"]
    if pregathered:
        fsdp = ()
    dc = layer_mode == "data_centric" and tp is not None
    gather_tokens = tp is not None and tokens_sharded_tp and not dc
    hier = _hier_schedule(cfg, mesh, tp)

    if gather_tokens:
        x = _ag_hier(x, tp, 0, hier)
        if token_valid is not None:
            token_valid = _ag_hier(token_valid, tp, 0, hier)

    r = route(
        x, p.router, ms.top_k,
        norm_topk=ms.norm_topk,
        softmax_after_topk=ms.softmax_after_topk,
        noise_rng=noise_rng,
        valid_mask=token_valid,
    )
    # Router telemetry (DESIGN.md §12): device-side accumulators over the
    # rows this device routed; the caller de-duplicates TP-replicated
    # counts before they leave the shard_map.
    stats = (obs_device.expert_stats(r.expert_idx, r.probs, ms.num_experts,
                                     valid_mask=token_valid)
             if cfg.collect_router_stats else None)
    ri = build_reindex(r.expert_idx, r.gates, ms.num_experts, cfg.blk)

    # True-quantized expert weights (int8/fp8 payloads + block scales,
    # DESIGN.md §8): the scales are NOT sharded congruently with a
    # sliced weight, so the path requires whole expert weights per device
    # (serving without TP over experts, or the per-device hetero_exec
    # programs). QAT (cfg.quant) fake-quants the gathered weights instead
    # and composes with any sharding.
    quantized = p.w_gate_scale is not None or p.w1_scale is not None
    if quantized and (fsdp or tp is not None):
        raise NotImplementedError(
            "true-quantized expert weights require ungathered whole-expert "
            "layouts (no fsdp/tp over expert weights); use cfg.quant (QAT "
            "fake-quant) on sharded meshes"
        )

    def maybe_fq(w):
        if w is None or quantized or cfg.quant == "none":
            return w
        from repro.quant.core import fake_quant
        return fake_quant(w, cfg.quant, cfg.quant_tile)

    # data-centric: gather the weights' TP factor (unless the overlap
    # schedule already gathered it outside the island, pregathered="all").
    tp_w = tp if dc and pregathered != "all" else None
    name = checkpoint_name  # pipeline-shared cache tagging

    def ag_w(w, dim):
        return _ag_hier(w, tp_w, dim, hier)

    if ms.glu:
        wg = name(maybe_fq(ag_w(_ag(p.w_gate, fsdp, 1), 2)), "gathered_w")
        wu = name(maybe_fq(ag_w(_ag(p.w_up, fsdp, 1), 2)), "gathered_w")
        wd = name(maybe_fq(ag_w(_ag(p.w_down, fsdp, 2), 1)), "gathered_w")
        scales = ((p.w_gate_scale, p.w_up_scale, p.w_down_scale)
                  if quantized else None)
        y = espec.moe_glu(
            x, ri, wg, wu, wd, scales=scales, act=ms.act, impl=cfg.impl,
            fused=cfg.fused_ffn,
        )
    else:
        w1 = name(maybe_fq(ag_w(_ag(p.w1, fsdp, 1), 2)), "gathered_w")
        w2 = name(maybe_fq(ag_w(_ag(p.w2, fsdp, 2), 1)), "gathered_w")
        # (E, F_l) bias: local TP slice adds locally; dc gathers it full.
        b1 = ag_w(p.b1, 1)
        b2 = _ag(p.b2, fsdp, 1)
        if not dc:
            b2 = _mask_rank0(b2, tp)
        scales = (p.w1_scale, p.w2_scale) if quantized else None
        y = espec.moe_mlp(
            x, ri, w1, b1, w2, b2, scales=scales, act=ms.act, impl=cfg.impl,
            fused=cfg.fused_ffn,
        )

    if tp is not None and not dc:
        # Partial products over the TP-sharded contraction dim.
        if gather_tokens and cfg.collective_schedule == "ag_rs":
            if hier:
                # Node-local combine BEFORE the cross-node exchange
                # (DESIGN.md §10): the intra-node reduce collapses node_size
                # partial sums into one, so only the combined rows cross the
                # slow fabric; the final slice keeps this rank's chunk of
                # its node's scatter share — same row ownership as the flat
                # reduce-scatter over the ("node", "model") tuple.
                node_ax, model_ax = tp
                y = lax.psum(y, model_ax)
                y = lax.psum_scatter(
                    y, node_ax, scatter_dimension=0, tiled=True)
                nl = y.shape[0] // mesh.shape[model_ax]
                y = lax.dynamic_slice_in_dim(
                    y, lax.axis_index(model_ax) * nl, nl, 0)
            else:
                y = lax.psum_scatter(y, tp, scatter_dimension=0, tiled=True)
        elif gather_tokens:
            # Paper-faithful ag_ar: all-reduce, then keep own token chunk.
            y = _psum_hier(y, tp, hier)
            nl = y.shape[0] // _axis_size(mesh, tp)
            y = lax.dynamic_slice_in_dim(y, lax.axis_index(tp) * nl, nl, 0)
        else:
            y = _psum_hier(y, tp, hier)

    # Per-device aux losses; mean over the data axes happens in the caller
    # after the island returns (values are replicated within TP).
    if stats is not None:
        return y, r.aux_loss, r.z_loss, stats
    return y, r.aux_loss, r.z_loss


def ep_moe_island(
    x: jax.Array,
    p: MoEParams,
    ms: MoEStatic,
    cfg: ParallelConfig,
    mesh: Mesh,
    *,
    tokens_sharded_tp: bool,
    noise_rng: Optional[jax.Array] = None,
    token_valid: Optional[jax.Array] = None,
):
    """Expert-parallel baseline: experts sharded over "model", tokens travel
    by all-to-all with a capacity buffer (padding + drops) — the classic
    GShard/Tutel execution the paper replaces.

    ``token_valid``: heterogeneous-plan (Eq. 1, DESIGN.md §6) tail mask.
    Masked rows get gate 0 so their combine output and weight gradients are
    exactly zero; they may still occupy capacity slots (the EP baseline's
    capacity buffer is exactly the redundancy the paper removes, so the
    masked path is not optimised further here)."""
    if p.w_gate_scale is not None or p.w1_scale is not None:
        raise NotImplementedError(
            "the EP baseline does not support quantized expert weights"
        )
    tp = cfg.axes(mesh)["tp"]
    if isinstance(tp, tuple):
        raise NotImplementedError(
            "the EP baseline does not support two-level (node) meshes; use "
            "the hexa modes for hierarchical dispatch (DESIGN.md §10)"
        )
    ep = mesh.shape[tp] if tp else 1
    e, k = ms.num_experts, ms.top_k
    assert e % max(ep, 1) == 0, "EP baseline needs num_experts % ep == 0"

    r = route(
        x, p.router, k,
        norm_topk=ms.norm_topk,
        softmax_after_topk=ms.softmax_after_topk,
        noise_rng=noise_rng,
        valid_mask=token_valid,
    )
    n, d = x.shape
    capacity = max(int((n * k / e) * cfg.capacity_factor), 1)

    rank, _ = baselines._dispatch_ranks(r.expert_idx, e)
    keep = rank < capacity
    stats = None
    if cfg.collect_router_stats:
        # Capacity-overflow drops (DESIGN.md §12): valid token slots whose
        # dispatch rank exceeded the buffer — the redundancy the paper's
        # modes remove, now measurable against them.
        vt = (jnp.ones((n,), jnp.int32) if token_valid is None
              else token_valid.astype(jnp.int32))
        dropped = jnp.sum((~keep).astype(jnp.int32) * vt[:, None])
        stats = obs_device.expert_stats(
            r.expert_idx, r.probs, e, valid_mask=token_valid,
            dropped=dropped)
    slot = r.expert_idx * capacity + rank
    slot = jnp.where(keep, slot, e * capacity)
    buf = jnp.zeros((e * capacity, d), x.dtype)
    src = jnp.broadcast_to(x[:, None, :], (n, k, d)).reshape(n * k, d)
    buf = buf.at[slot.reshape(-1)].set(src, mode="drop").reshape(e, capacity, d)

    if tp is not None and ep > 1:
        # (E, C, D) -> exchange expert groups: device m ends up with its
        # E/ep experts' tokens from every peer. all_to_all with
        # split=concat=0 is an involution, so the return path mirrors it.
        buf = buf.reshape(ep, e // ep, capacity, d)
        buf = lax.all_to_all(buf, tp, split_axis=0, concat_axis=0)
        # (src=ep, my_experts, C, D) -> expert-major rows
        buf = buf.transpose(1, 0, 2, 3).reshape(e // ep, ep * capacity, d)

    wg, wu, wd = p.w_gate, p.w_up, p.w_down  # local (E/ep, D, F) dense
    if ms.glu:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(buf.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wu.astype(buf.dtype))
        out = jnp.einsum("ecf,efd->ecd", h, wd.astype(buf.dtype))
    else:
        h = espec.ACTIVATIONS[ms.act](
            jnp.einsum("ecd,edf->ecf", buf, p.w1.astype(buf.dtype))
            + (p.b1[:, None].astype(buf.dtype) if p.b1 is not None else 0)
        )
        out = jnp.einsum("ecf,efd->ecd", h, p.w2.astype(buf.dtype))
        if p.b2 is not None:
            out = out + p.b2[:, None].astype(buf.dtype)

    if tp is not None and ep > 1:
        out = out.reshape(e // ep, ep, capacity, d).transpose(1, 0, 2, 3)
        out = lax.all_to_all(out, tp, split_axis=0, concat_axis=0)
        out = out.reshape(e, capacity, d)

    y_flat = out.reshape(e * capacity, d)
    got = y_flat[jnp.minimum(slot, e * capacity - 1).reshape(-1)].reshape(n, k, d)
    gates = (r.gates * keep.astype(r.gates.dtype))[..., None].astype(x.dtype)
    y = jnp.sum(got * gates, axis=1)
    if stats is not None:
        return y, r.aux_loss, r.z_loss, stats
    return y, r.aux_loss, r.z_loss


def _auto_layer_mode(
    p: MoEParams,
    ms: MoEStatic,
    cfg: ParallelConfig,
    mesh: Optional[Mesh],
    tokens: int,
    layer_idx: Optional[int],
) -> str:
    """Resolve the per-layer dispatch for cfg.mode == "auto" from static
    shapes (paper Fig. 10 roofline; see parallel.autotune)."""
    from repro.parallel import autotune

    w = p.w_gate if p.w_gate is not None else p.w1
    e, d, f = w.shape
    if mesh is not None and getattr(mesh, "axis_names", ()):
        dp_axes = cfg.axes(mesh)["dp"]
        dp = 1
        for a in dp_axes:
            dp *= mesh.shape[a]
        tokens = max(tokens // max(dp, 1), 1)  # workload per TP group
    return autotune.resolve_layer_mode(
        tokens, d=d, f=f, e=ms.num_experts, k=ms.top_k,
        cfg=cfg, mesh=mesh, layer_idx=layer_idx,
    )


def _hetero_mask_counts(plan, x_spec: P, mesh: Optional[Mesh], b: int):
    """Static resolution of the Eq. 1 token mask (DESIGN.md §6).

    Returns ``(token_counts, batch_axes)`` when the plan's data split is
    uneven at this sharding — the island then builds the per-device validity
    mask — or ``None`` when no masking is needed: no plan, no mesh, or a
    uniform split that exactly fills every shard (the short-circuit that
    keeps the uniform path's HLO bitwise unchanged)."""
    if plan is None or getattr(plan, "token_counts", None) is None:
        return None
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return None
    entry = x_spec[0]
    baxes = (() if entry is None
             else entry if isinstance(entry, tuple) else (entry,))
    extent = 1
    for a in baxes:
        extent *= mesh.shape[a]
    counts = tuple(int(c) for c in plan.token_counts)
    if len(counts) != extent:
        raise ValueError(
            f"hetero_plan.token_counts has {len(counts)} entries but the "
            f"batch dim is sharded over {extent} devices"
        )
    local_b = b // extent
    if max(counts) > local_b:
        raise ValueError(
            f"hetero_plan assigns {max(counts)} batch rows to a device but "
            f"the padded shard holds only {local_b} (global batch {b})"
        )
    if all(c == local_b for c in counts):
        return None  # uniform plan: no masking, identical trace
    return counts, baxes


def moe_layer(
    x: jax.Array,                    # (B, S, D) global
    p: MoEParams,                    # sharded per resolve_spec
    ms: MoEStatic,
    cfg: ParallelConfig,
    mesh: Optional[Mesh],
    *,
    x_spec: P,                       # how (B, S, D) is sharded
    noise_rng: Optional[jax.Array] = None,
    layer_idx: Optional[int] = None,
    pregathered=False,
):
    """Distributed MoE FFN over a (B, S, D) activation. Returns
    (y, aux_loss, z_loss) with y sharded like x — or, when
    ``cfg.collect_router_stats`` is set, (y, aux_loss, z_loss, stats)
    where ``stats`` is the replicated obs.device telemetry pytree
    (globally-exact per-expert token counts; DESIGN.md §12).

    ``layer_idx`` feeds the auto-mode plan lookup; ``pregathered`` marks
    which weight collectives already ran outside (pipeline-shared cache
    path): True/"fsdp" = the fsdp factor, "all" = fsdp AND the tp factor
    (the overlap schedule, DESIGN.md §10 — the layer then necessarily runs
    data-centric dispatch, which is what the overlap prefetcher resolved).

    ``cfg.hetero_plan`` (DESIGN.md §6): when the plan's Eq. 1 ``token_counts``
    are uneven, each batch-group member masks its shard's tail batch rows
    inside the island (the SPMD shapes stay uniform). A uniform plan
    short-circuits entirely — same trace as no plan."""
    b, s, d = x.shape

    island = ep_moe_island if cfg.mode == "ep" else hexa_moe_island
    layer_mode = None
    if island is hexa_moe_island:
        if pregathered == "all":
            # The overlap prefetcher already gathered the weights' tp
            # factor for this layer — it necessarily runs data-centric.
            layer_mode = "data_centric"
        elif cfg.mode == "auto":
            layer_mode = _auto_layer_mode(p, ms, cfg, mesh, b * s, layer_idx)
        island = functools.partial(
            island, layer_mode=layer_mode, pregathered=pregathered
        )

    mask_counts = _hetero_mask_counts(cfg.hetero_plan, x_spec, mesh, b)

    collect = cfg.collect_router_stats

    if mesh is None:
        # Single-process path (unit tests): plain local computation.
        local_cfg = cfg
        xf = x.reshape(b * s, d)
        out = island(
            xf, p, ms, local_cfg, _SINGLE_MESH, tokens_sharded_tp=False,
            noise_rng=noise_rng,
        )
        if collect:
            y, aux, z, stats = out
            return y.reshape(b, s, d), aux, z, stats
        y, aux, z = out
        return y.reshape(b, s, d), aux, z

    tokens_tp = x_spec[1] is not None  # seq dim sharded over "model"?

    # Telemetry de-duplication factor (DESIGN.md §12): when tokens are
    # gathered over TP (model-centric training/prefill) or replicated over
    # TP (decode), every TP rank routes — and counts — the same tokens, so
    # the psum'd totals are exact multiples of the true counts. Static per
    # layer, so the integer floor-division below is exact.
    stat_dup = 1
    if collect:
        tp = cfg.axes(mesh)["tp"]
        if tp is not None:
            tp_size = 1
            for a in (tp if isinstance(tp, tuple) else (tp,)):
                tp_size *= int(mesh.shape[a])
            if cfg.mode == "ep":
                stat_dup = 1 if tokens_tp else tp_size
            else:
                dc = layer_mode == "data_centric"
                stat_dup = 1 if (tokens_tp and dc) else tp_size

    def body(xl, pl, rngl):
        bl, sl, _ = xl.shape
        tv = None
        bv = None
        if mask_counts is not None:
            counts, baxes = mask_counts
            # This device's position in the batch-sharding group, then its
            # Eq. 1 share: row r of the flat (bl*sl) shard belongs to batch
            # element r // sl; elements past the share are masked tail.
            rank = jnp.zeros((), jnp.int32)
            for a in baxes:
                rank = rank * mesh.shape[a] + lax.axis_index(a)
            bv = jnp.asarray(counts, jnp.int32)[rank]
            tv = (jnp.arange(bl * sl, dtype=jnp.int32) // sl) < bv
        out = island(
            xl.reshape(bl * sl, d), pl, ms, cfg, mesh,
            tokens_sharded_tp=tokens_tp,
            noise_rng=None if rngl is None else rngl[0],
            token_valid=tv,
        )
        if collect:
            y, aux, z, stats = out
        else:
            (y, aux, z), stats = out, None
        if bv is None:
            # Mean aux over all devices (aux is per-local-batch).
            aux = lax.pmean(aux, mesh.axis_names)
            z = lax.pmean(z, mesh.axis_names)
        else:
            # Uneven plan: each device's aux is a mean over ITS valid rows,
            # so average them weighted by valid-token count — the result is
            # the masked mean over all valid tokens, independent of how a
            # replan shuffles the shares (DESIGN.md §6).
            w = (bv * sl).astype(jnp.float32)
            wsum = lax.psum(w, mesh.axis_names)
            aux = lax.psum(aux * w, mesh.axis_names) / wsum
            z = lax.psum(z * w, mesh.axis_names) / wsum
        if collect:
            # Global totals: sum every device's local counts, then divide
            # out the TP replication factor (exact — see stat_dup above).
            stats = {k: lax.psum(v, mesh.axis_names)
                     for k, v in stats.items()}
            if stat_dup > 1:
                stats = {
                    "expert_tokens": stats["expert_tokens"] // stat_dup,
                    "dropped_tokens": stats["dropped_tokens"] // stat_dup,
                    "entropy_sum": stats["entropy_sum"] / stat_dup,
                    "tokens": stats["tokens"] // stat_dup,
                }
            return y.reshape(bl, sl, d), aux, z, stats
        return y.reshape(bl, sl, d), aux, z

    p_specs = _param_specs(p, ms, cfg, mesh, pregathered=pregathered)
    rng_arg = None if noise_rng is None else noise_rng[None]
    rng_spec = None if noise_rng is None else P()
    if collect:
        stat_specs = {k: P() for k in obs_device.STAT_KEYS}
        y, aux, z, stats = _shard_map(
            body,
            mesh,
            in_specs=(x_spec, p_specs, rng_spec),
            out_specs=(x_spec, P(), P(), stat_specs),
        )(x, p, rng_arg)
        return y, aux, z, stats
    y, aux, z = _shard_map(
        body,
        mesh,
        in_specs=(x_spec, p_specs, rng_spec),
        out_specs=(x_spec, P(), P()),
    )(x, p, rng_arg)
    return y, aux, z


def _param_specs(p: MoEParams, ms: MoEStatic, cfg: ParallelConfig, mesh: Mesh,
                 *, pregathered=False):
    """Physical specs for MoEParams matching parallel.sharding's resolution.

    ``pregathered``: weight leaves arrive with their fsdp factor already
    gathered (parallel.cache.gather_ffn_params) — drop "fsdp" from their
    logical specs before resolving; ``"all"`` (the overlap schedule,
    DESIGN.md §10) additionally drops "tp" (the expert collectives were
    prefetched too). Logical specs come from the same MOE_PARAM_LOGICAL /
    EP_PARAM_LOGICAL tables the init/gather paths use, so the three can
    never drift apart."""
    from repro.parallel.cache import _drop_axes
    from repro.parallel.sharding import divisible_spec, resolve_spec

    table = EP_PARAM_LOGICAL if cfg.mode == "ep" else MOE_PARAM_LOGICAL
    drop = ()
    if pregathered:
        drop = ("fsdp", "tp") if pregathered == "all" else ("fsdp",)

    def spec_of(name):
        v = getattr(p, name)
        if v is None:
            return None
        logical = table[name]
        if drop and name != "router":
            logical = _drop_axes(logical, drop)
        phys = resolve_spec(logical, cfg, mesh)
        return divisible_spec(v.shape, phys, mesh)

    return MoEParams(**{name: spec_of(name) for name in MoEParams._fields})


#: Block-wise scale leaves of quantized expert weights stay replicated —
#: their (E, n1, n2) blocks do not tile congruently under arbitrary
#: weight sharding, and the quantized path requires whole-expert layouts
#: anyway (see hexa_moe_island's guard).
_SCALE_LOGICAL = {
    "w_gate_scale": (None, None, None),
    "w_up_scale": (None, None, None),
    "w_down_scale": (None, None, None),
    "w1_scale": (None, None, None),
    "w2_scale": (None, None, None),
}

MOE_PARAM_LOGICAL = {
    "router": (None, None),
    "w_gate": (None, "fsdp", "tp"),
    "w_up": (None, "fsdp", "tp"),
    "w_down": (None, "tp", "fsdp"),
    "w1": (None, "fsdp", "tp"),
    "b1": (None, "tp"),
    "w2": (None, "tp", "fsdp"),
    "b2": (None, "fsdp"),
    **_SCALE_LOGICAL,
}

EP_PARAM_LOGICAL = {
    "router": (None, None),
    "w_gate": ("tp", None, None),
    "w_up": ("tp", None, None),
    "w_down": ("tp", None, None),
    "w1": ("tp", None, None),
    "b1": ("tp", None),
    "w2": ("tp", None, None),
    "b2": ("tp", None),
    **_SCALE_LOGICAL,
}


class _FakeMesh:
    """Stands in for a mesh in the single-process path."""
    axis_names = ()
    shape = {}


_SINGLE_MESH = _FakeMesh()
