"""Sharding substrate: logical axes, parameter trees, parallel config.

Logical axis names used by every model definition:

  "fsdp"  — ZeRO-3 style parameter sharding, gathered at use. Maps to the
            ("pod", "data") mesh axes (the paper's *data-centric* gathering).
  "tp"    — tensor parallelism, kept sharded through compute. Maps to
            "model" (the paper's *model-centric* hidden-dim split).
  "dp"    — batch data parallelism: ("pod", "data").
  "sp"    — sequence parallelism for activations: "model".

The paper's two configurations are corners of this family (DESIGN.md §3):
model-centric disables "fsdp" (params replicated over data, TP compute);
data-centric folds "tp" into the gather (params fully gathered at use, no
TP compute). ``ParallelConfig.mode`` selects the mapping; mode="auto" keeps
the hybrid layout and lets each MoE layer pick its dispatch at trace time
from the parallel.autotune roofline (paper §4.5 / Fig. 10).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class Param:
    """A parameter leaf: value (or ShapeDtypeStruct) + logical spec.

    Registered as a pytree node with ``spec`` as static aux data, so Param
    trees pass through jax.eval_shape (abstract init for the dry-run).
    """
    __slots__ = ("value", "spec")

    def __init__(self, value: Any, spec: tuple):
        self.value = value
        self.spec = tuple(spec)

    def __repr__(self):
        return f"Param({self.value!r}, spec={self.spec})"


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.spec),
    lambda spec, children: Param(children[0], spec),
)


def is_param(x) -> bool:
    """True for Param leaves (the is_leaf predicate for Param trees)."""
    return isinstance(x, Param)


def split_tree(tree):
    """Split a tree of Param into (values, logical_specs)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    specs = jax.tree.map(lambda p: p.spec, tree, is_leaf=is_param)
    return values, specs


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How logical axes map onto the physical mesh.

    mode:
      "hybrid"        — fsdp -> (pod, data), tp -> model  (default at scale)
      "model_centric" — fsdp -> (),          tp -> model  (paper §4.3 TP)
      "data_centric"  — fsdp -> ALL axes,    tp -> ()     (paper §4.3 gather)
      "auto"          — hybrid physical layout; each MoE layer picks its
                        data-/model-centric collective schedule at trace time
                        from the roofline (parallel.autotune, paper Fig. 10)
      "ep"            — expert parallelism baseline (all-to-all)
    collective_schedule:
      "ag_ar" — paper-faithful: tokens replicated in TP, outputs all-reduced.
      "ag_rs" — bandwidth-optimal: all-gather in, reduce-scatter out (SP).
    cache_policy:
      "shared_cache" — paper's pipeline-shared cache: gathered params are NOT
                       saved for backward (remat re-gathers per layer).
      "janus"        — retain gathered params for backward (memory baseline).
      "none"         — no remat at all.
    fused_ffn:
      None (default) — fused forward expert FFN (kernels.ops.esffn_*,
      DESIGN.md §5) follows the impl default: ON for the TPU "pallas"
      path, OFF for the XLA impls. True/False force it either way.
    Auto-mode knobs (ignored for other modes):
      forced_layer_mode — pin every MoE layer's dispatch ("data_centric" /
                          "model_centric"); bypasses the chooser entirely.
      layer_mode_plan   — per-period-position plan from
                          autotune.plan_layer_modes (None entries defer to
                          the chooser).
      device_latencies  — heterogeneous proxy latencies (core.hetero t_i);
                          shrink the chooser's effective TP group size.
    Quantization (DESIGN.md §8):
      quant      — "none" | "int8" | "fp8": QAT fake-quant of the expert
                   weights inside the MoE islands (quant.core.fake_quant,
                   straight-through grads; routers/dense layers untouched).
                   Ignored when the params already carry true int8/fp8
                   payloads + '<name>_scale' leaves (serving-side
                   quant.core.quantize_lm_params) — those dispatch the
                   fused-dequant kernels directly.
      quant_tile — block size of the per-(expert, tile) scales.
    Pipeline-shared cache realisation (models.lm unrolled layer loop):
      cache_layers — gathered-period residency bound for the prefetching
                     cache (one entry = one period's MoE layers; 2 = double
                     buffer); 0 disables it. Requires scan_layers=False.
                     Inference-side: the remat'd train step skips it (the
                     remat policy is training's cache) so gathered trees
                     never become checkpoint residuals.
    Heterogeneous execution (paper §4.4 Eq. 1/2, DESIGN.md §6):
      hetero_plan — a ``core.hetero.HeteroPlan``. Its ``token_counts``
                    (Eq. 1) make the MoE islands mask each data-group
                    member's tail rows (the SPMD shard stays a uniform
                    padded shape; rows past the device's share contribute
                    zero output, zero gradient, and are excluded from the
                    aux losses). Its ``hidden_splits`` (Eq. 2) pad the FFN
                    hidden dim to per-TP-rank MXU-aligned tiles at init
                    (``models.transformer.init_moe_ffn``) with exact zeros
                    in the padded columns. A plan whose splits are uniform
                    short-circuits both mechanisms — the compiled HLO is
                    the uniform path's, bitwise. The plan is static: a
                    replan (runtime.straggler) produces a new plan and a
                    bounded re-trace (parallel.cache.PlanCache).
    """
    mode: str = "hybrid"
    collective_schedule: str = "ag_rs"
    cache_policy: str = "shared_cache"
    remat: str = "block"          # none | block
    blk: int = 128                # expert-sorted layout block size
    impl: Optional[str] = None    # kernel impl override
    fused_ffn: Optional[bool] = None  # fused forward FFN (None = impl default)
    capacity_factor: float = 1.25 # EP baseline only
    scan_layers: bool = True
    forced_layer_mode: Optional[str] = None
    layer_mode_plan: Optional[Tuple[Optional[str], ...]] = None
    device_latencies: Optional[Tuple[float, ...]] = None
    cache_layers: int = 0
    hetero_plan: Optional[Any] = None  # core.hetero.HeteroPlan
    quant: str = "none"           # expert-weight QAT: none | int8 | fp8
    quant_tile: int = 128         # block size of the per-(expert,tile) scales
    # Two-level interconnect (DESIGN.md §10): an ``autotune.Topology``
    # prices the chooser's collectives per level, and on a mesh carrying a
    # "node" axis switches the MoE islands to the hierarchical schedule
    # (two-phase gathers; node-local combine before the cross-node
    # exchange). None, or a mesh without a "node" axis, keeps the flat
    # single-level collectives — bitwise-identical HLO to the pre-topology
    # path.
    topology: Optional[Any] = None  # autotune.Topology
    # Overlap the NEXT layer's expert collectives with the current layer's
    # compute: extends the pipeline-shared cache's double buffering
    # (cache_layers) from fsdp gathers to the data-centric weights' tp
    # factor as well (DESIGN.md §10). Requires cache_layers > 0 and the
    # unrolled layer loop; values are bit-identical to the eager schedule.
    overlap_dispatch: bool = False
    # Router/expert telemetry (DESIGN.md §12): when True the MoE islands
    # return per-expert token counts, capacity drops, and gate-entropy
    # sums as extra jit outputs (obs.device.expert_stats) and
    # models.lm.forward grows a fifth, stats, return element. Default
    # False keeps every return arity — and the compiled HLO — bitwise
    # identical to the uninstrumented path.
    collect_router_stats: bool = False

    def axes(self, mesh: Mesh) -> dict:
        names = list(mesh.axis_names)
        dp = tuple(n for n in ("pod", "data") if n in names)
        # A two-level mesh (DESIGN.md §10) carries a "node" axis: the TP
        # group spans ("node", "model") — node-major, so the flattened rank
        # order (and therefore every gather's concat order) matches the
        # equivalent flat mesh exactly.
        tp: Any = "model" if "model" in names else None
        if tp is not None and "node" in names:
            tp = ("node", "model")
        if self.mode == "model_centric":
            return {"fsdp": (), "tp": tp, "dp": dp, "sp": tp}
        if self.mode == "data_centric":
            # paper §4.3: PURE data parallelism — every device computes its
            # own batch shard; params are sharded over the whole mesh and
            # gathered at use (pipeline-shared cache bounds residency).
            tp_axes = tp if isinstance(tp, tuple) else ((tp,) if tp else ())
            all_axes = dp + tp_axes
            return {"fsdp": all_axes, "tp": None, "dp": all_axes, "sp": None}
        if self.mode in ("hybrid", "ep", "auto"):
            # "auto" uses the hybrid physical layout — the superset both
            # per-layer behaviours execute from: model-centric dispatch moves
            # tokens over "tp", data-centric dispatch gathers the weights'
            # tp factor inside the island instead (DESIGN.md §3).
            return {"fsdp": dp, "tp": tp, "dp": dp, "sp": tp}
        raise ValueError(self.mode)


def resolve_spec(logical: Sequence, cfg: ParallelConfig, mesh: Mesh) -> P:
    """Translate a logical spec tuple into a physical PartitionSpec."""
    table = cfg.axes(mesh)
    out = []
    for entry in logical:
        if entry is None:
            out.append(None)
            continue
        parts = entry if isinstance(entry, tuple) else (entry,)
        phys: list = []
        for p in parts:
            m = table.get(p, p)
            if m is None or m == ():
                continue
            phys.extend(m if isinstance(m, tuple) else (m,))
        # Drop axes whose mesh extent doesn't divide... left to callers; XLA
        # requires divisibility, configs are chosen to satisfy it.
        if not phys:
            out.append(None)
        elif len(phys) == 1:
            out.append(phys[0])
        else:
            out.append(tuple(phys))
    return P(*out)


def _dim_sizes(mesh: Mesh, spec: P) -> list[int]:
    sizes = []
    for entry in spec:
        if entry is None:
            sizes.append(1)
        elif isinstance(entry, tuple):
            sizes.append(int(np.prod([mesh.shape[a] for a in entry])))
        else:
            sizes.append(mesh.shape[entry])
    return sizes


def divisible_spec(shape, spec: P, mesh: Mesh) -> P:
    """Drop spec entries that do not divide the corresponding dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep: list = []
        extent = 1
        for a in axes:
            if dim % (extent * mesh.shape[a]) == 0:
                keep.append(a)
                extent *= mesh.shape[a]
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def tree_shardings(values, logical_specs, cfg: ParallelConfig, mesh: Mesh):
    """NamedShardings for a whole (already split) value tree."""
    def one(v, spec):
        phys = resolve_spec(spec, cfg, mesh)
        phys = divisible_spec(v.shape, phys, mesh)
        return NamedSharding(mesh, phys)
    return jax.tree.map(one, values, logical_specs)


def constrain(x, spec: Sequence, cfg: ParallelConfig, mesh: Optional[Mesh]):
    """with_sharding_constraint via logical names (no-op without a mesh)."""
    if mesh is None:
        return x
    phys = divisible_spec(x.shape, resolve_spec(spec, cfg, mesh), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, phys))


# ---------------------------------------------------------------------------
# initializers (pure, eval_shape friendly)
# ---------------------------------------------------------------------------

def normal_init(key, shape, dtype, scale: float = 0.02):
    """Truncated-free scaled normal init (f32 draw, cast to dtype)."""
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def zeros_init(key, shape, dtype, scale: float = 0.0):
    """All-zeros init (key/scale ignored; kept initializer-signature)."""
    del key, scale
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype, scale: float = 0.0):
    """All-ones init (key/scale ignored; kept initializer-signature)."""
    del key, scale
    return jnp.ones(shape, dtype)
