"""Per-device-program heterogeneous MoE execution (paper §4.4 run for real;
DESIGN.md §6).

Real heterogeneous fleets (the paper's 2080Ti+TITAN cases, HeterMoE's
asymmetric GPU pools) cannot run one SPMD program: different device classes
compile different code. The execution model is therefore one *program per
device*, each with shapes cut from the plan:

  data-centric  — device i's program takes its Eq. 1 token shard
                  (``token_counts[i]`` rows, padded up to ``token_quantum``
                  with a masked tail) against the full expert weights;
                  shard outputs concatenate back to the global batch.
  model-centric — device i's program takes all tokens against its Eq. 2
                  hidden slice (``hidden_splits[i]`` columns — a quantum
                  multiple by construction, so every tile is MXU-aligned and
                  the esffn/esmm grids are sized from the *local* h_i: no
                  device does redundant FLOPs); partial outputs sum.

This is the physical realisation of the uneven split; the SPMD islands
(``parallel.moe_parallel``) realise the same plan *logically* on a
homogeneous mesh via masking, which is what the replan loop retraces. The
two agree numerically (tier-1 asserts it).

``timed_step`` measures each device program's wall time and scales it by
the plan's relative latencies — a *simulated-skew mesh*: the kernels run
for real at the uneven shapes on this host, and device i's clock runs
``t_i/t_min`` slower. The synchronous step latency is the max (the
all-reduce barrier), which is how ``benchmarks/hetero_alloc.py`` shows the
proportional split beating uniform with measured, not modelled, numbers.
"""
from __future__ import annotations

import functools
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import round_up
from repro.core import espec
from repro.core.hetero import HeteroPlan
from repro.core.reindex import build_reindex
from repro.core.routing import route


class HeteroStep(NamedTuple):
    """One executed uneven step: output + measured per-device seconds +
    the simulated-skew synchronous latency (max over devices)."""
    y: jax.Array
    device_times_s: tuple
    step_latency_s: float




def _ffn(x, ri, params, *, act, glu, impl):
    if glu:
        scales = None
        if "w_gate_scale" in params:
            scales = (params["w_gate_scale"], params["w_up_scale"],
                      params["w_down_scale"])
        return espec.moe_glu(
            x, ri, params["w_gate"], params["w_up"], params["w_down"],
            scales=scales, act=act, impl=impl,
        )
    scales = None
    if "w1_scale" in params:
        scales = (params["w1_scale"], params["w2_scale"])
    return espec.moe_mlp(
        x, ri, params["w1"], params.get("b1"), params["w2"],
        params.get("b2"), scales=scales, act=act, impl=impl,
    )


class HeteroExecutor:
    """Per-device jitted programs for one MoE FFN layer under a HeteroPlan.

    ``params`` is the espec-style dict ('router' + GLU or MLP weights with
    the FULL d_ff hidden — slicing happens here). ``mode`` picks which Eq.
    the devices execute: "data_centric" needs ``plan.token_counts``,
    "model_centric" needs ``plan.hidden_splits``.

    Precision-aware planning (``plan.expert_bits``, DESIGN.md §8): a
    device class marked 8 holds its expert-weight slice as block-wise
    int8 payloads + scales (``quant.core.quantize_ffn``) and runs the
    fused-dequant kernels — per-device-program execution is exactly where
    mixed per-class precision is expressible, since each class compiles
    its own program. Classes marked 16 keep the full-precision weights.
    """

    def __init__(
        self,
        params: dict,
        *,
        num_experts: int,
        top_k: int,
        act: str,
        glu: bool,
        plan: HeteroPlan,
        mode: str,
        blk: int = 128,
        impl: Optional[str] = None,
        quant_mode: str = "int8",
        quant_tile: int = 128,
    ):
        from repro.quant.core import quantize_ffn

        self.plan = plan
        self.mode = mode
        self.glu = glu
        t = np.asarray(plan.proxy_latencies, np.float64)
        self.skews = tuple(float(v) for v in t / t.min())
        splits = (plan.token_counts if mode == "data_centric"
                  else plan.hidden_splits)
        bits = plan.expert_bits or (16,) * len(splits or ())
        if splits is not None and len(bits) != len(splits):
            # expert_bits is validated against proxy_latencies at plan
            # construction, but the executed split may follow tp_latencies
            # (model-centric on a 2-D mesh) — refuse a silent mis-mapping.
            raise ValueError(
                f"expert_bits has {len(bits)} entries but the executed "
                f"{mode} split has {len(splits)} device programs"
            )
        # data-centric programs share the UNSLICED weights, so all 8-bit
        # classes can share one quantized copy (model-centric slices
        # differ per class and must quantize per slice).
        shared_q = (quantize_ffn(params, mode=quant_mode, tile=quant_tile)
                    if mode == "data_centric" and 8 in bits else None)

        def class_params(i, p_i):
            if bits[i] != 8:
                return p_i
            if shared_q is not None:
                return shared_q
            return quantize_ffn(p_i, mode=quant_mode, tile=quant_tile)

        def layer_fn(x, p, n_valid, n_rows):
            vm = None
            if n_valid != n_rows:
                vm = jnp.arange(n_rows, dtype=jnp.int32) < n_valid
            r = route(x, params["router"], top_k, valid_mask=vm)
            ri = build_reindex(r.expert_idx, r.gates, num_experts, blk)
            return _ffn(x, ri, p, act=act, glu=glu, impl=impl)

        # ONE jitted callable shared by every device program: devices whose
        # shapes coincide (the whole uniform arm, or any equal shares) hit
        # the same trace cache instead of compiling n identical programs.
        jit_fn = jax.jit(layer_fn, static_argnames=("n_valid", "n_rows"))

        self._programs = []  # [(jitted_fn, device_params, shard_meta)]
        if mode == "data_centric":
            if plan.token_counts is None:
                raise ValueError("data_centric needs plan.token_counts")
            q = plan.token_quantum
            off = 0
            for i, b_i in enumerate(plan.token_counts):
                rows = max(round_up(b_i, q), q)
                fn = functools.partial(jit_fn, n_valid=b_i, n_rows=rows)
                self._programs.append(
                    (fn, class_params(i, params), (off, b_i, rows)))
                off += b_i
        elif mode == "model_centric":
            if plan.hidden_splits is None:
                raise ValueError("model_centric needs plan.hidden_splits")
            off = 0
            for i, h_i in enumerate(plan.hidden_splits):
                sl = slice(off, off + h_i)
                if glu:
                    p_i = {
                        "w_gate": params["w_gate"][:, :, sl],
                        "w_up": params["w_up"][:, :, sl],
                        "w_down": params["w_down"][:, sl, :],
                    }
                else:
                    p_i = {
                        "w1": params["w1"][:, :, sl],
                        "b1": (params["b1"][:, sl]
                               if params.get("b1") is not None else None),
                        "w2": params["w2"][:, sl, :],
                        # partial-sum bias: device 0 only, like the island's
                        # _mask_rank0 (avoids an n_dev-fold bias).
                        "b2": (params.get("b2") if off == 0 else
                               (jnp.zeros_like(params["b2"])
                                if params.get("b2") is not None else None)),
                    }
                fn = functools.partial(jit_fn, n_valid=-1, n_rows=-1)
                self._programs.append(
                    (fn, class_params(i, p_i), (off, h_i, None)))
                off += h_i
        else:
            raise ValueError(mode)

    def device_param_bytes(self) -> tuple:
        """Per-device expert-weight HBM bytes (router excluded) — the
        memory claim of per-class precision (DESIGN.md §8): an int8 class
        holds ~half the bf16 bytes (~quarter of f32) plus its scales."""
        from repro.common import tree_bytes

        return tuple(
            tree_bytes({k: v for k, v in p.items() if k != "router"})
            for _, p, _ in self._programs
        )

    # -- execution ----------------------------------------------------------

    def _run_device(self, i: int, x: jax.Array):
        fn, p, meta = self._programs[i]
        if self.mode == "data_centric":
            off, b_i, rows = meta
            shard = x[off: off + b_i]
            if rows != b_i:
                shard = jnp.concatenate(
                    [shard, jnp.zeros((rows - b_i, x.shape[1]), x.dtype)]
                )
            return fn(shard, p)
        return fn(x, p)

    def _combine(self, outs) -> jax.Array:
        """Merge per-device outputs: Eq. 1 shards concatenate (dropping each
        shard's quantum-pad tail), Eq. 2 partials sum over the hidden."""
        if self.mode == "data_centric":
            outs = [o[: meta[1]]
                    for o, (_, _, meta) in zip(outs, self._programs)]
            return jnp.concatenate(outs, axis=0)
        y = outs[0]
        for o in outs[1:]:
            y = y + o
        return y

    def __call__(self, x: jax.Array) -> jax.Array:
        """Execute the uneven step (no timing): x (N, D) -> y (N, D)."""
        return self._combine(
            [self._run_device(i, x) for i in range(len(self._programs))]
        )

    def timed_step(self, x: jax.Array, *, rounds: int = 5,
                   warmup: bool = True) -> HeteroStep:
        """Run + measure each device program; apply the simulated skew.

        Device i's best (min-over-rounds) wall time is scaled by
        ``t_i/t_min`` (that device is that much slower than this host's
        silicon); the synchronous step completes at the slowest device (the
        barrier). Min, not median: every program here runs on the SAME host
        serially, so load spikes are one-sided noise — the minimum is the
        faithful per-shape estimate the skew model should scale.

        ``warmup=False`` skips the untimed compile pass — for callers that
        interleave several timed_step calls (e.g. the A/B benchmark) and
        have already warmed every program.
        """
        n = len(self._programs)
        # warmup/compile every program first so rounds measure steady state
        outs = [None] * n
        if warmup:
            outs = [jax.block_until_ready(self._run_device(i, x))
                    for i in range(n)]
        times = [[] for _ in range(n)]
        for _ in range(rounds):
            for i in range(n):
                t0 = time.perf_counter()
                outs[i] = self._run_device(i, x)
                jax.block_until_ready(outs[i])
                times[i].append(time.perf_counter() - t0)
        best = tuple(float(np.min(t)) for t in times)
        step = max(m * s for m, s in zip(best, self.skews))
        return HeteroStep(y=self._combine(outs), device_times_s=best,
                          step_latency_s=step)
