"""Causal flash attention (Pallas TPU) — beyond-paper optimization.

The paper's kernels cover the MoE FFN; the roofline analysis
(EXPERIMENTS.md §Perf) shows the remaining HBM-bytes hot-spot is the
attention softmax transients that the pure-XLA stand-in materialises
between its two dots. This kernel keeps the (q_block x kv_block) logits
and probabilities in VMEM — HBM traffic collapses to q/k/v in + out once.

Layout: q (B, Hq, S, hd), k/v (B, Hkv, S, hd) — batch*head on the grid's
outer (parallel) axes, kv blocks innermost with a running (m, l, acc)
scratch. Causal masking at block granularity; fully-masked blocks are
skipped with pl.when (their DMA still runs; compute does not).

Validated in interpret mode against models.attention.chunked_attention
(tests/test_flash_kernel.py); ops-level wrapper handles GQA by folding the
group into the query head dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common import pallas_interpret_default, tpu_compiler_params

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale, bq, bk, causal):
    i = pl.program_id(1)   # q block
    j = pl.program_id(2)   # kv block
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # blocks strictly above the diagonal contribute nothing
    run = (not causal) or (j * bk <= i * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)       # (bq, hd)
        k = k_ref[0].astype(jnp.float32)       # (bk, hd)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                               # (bq, bk)
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            logits = jnp.where(kpos <= qpos, logits, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(j == nj - 1)
    def _done():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,       # (BH, S, hd)
    k: jax.Array,       # (BH, S, hd)
    v: jax.Array,       # (BH, S, hd)
    *,
    causal: bool = True,
    bq: int = 512,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = pallas_interpret_default()
    bh, s, hd = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0
    scale = hd ** -0.5
    grid = (bh, s // bq, s // bk)

    flops = 4 * bh * s * s * hd * (0.5 if causal else 1.0)
    bytes_accessed = (
        q.size * q.dtype.itemsize * (s // bk)  # q re-read per kv block col?
        + 2 * k.size * k.dtype.itemsize
        + q.size * q.dtype.itemsize
    )

    return pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, bq=bq, bk=bk, causal=causal
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        cost_estimate=pl.CostEstimate(
            flops=int(flops), bytes_accessed=int(bytes_accessed),
            transcendentals=int(bh * s * s * (0.5 if causal else 1.0)),
        ),
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal: bool = True, **kw):
    """GQA wrapper: q (B,S,Hq,hd), k/v (B,S,Hkv,hd) -> (B,S,Hq,hd)."""
    b, s, hq, hd = q.shape
    _, _, hkv, _ = k.shape
    g = hq // hkv
    kr = jnp.repeat(k, g, axis=2) if g > 1 else k
    vr = jnp.repeat(v, g, axis=2) if g > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, hd)
    kf = kr.transpose(0, 2, 1, 3).reshape(b * hq, s, hd)
    vf = vr.transpose(0, 2, 1, 3).reshape(b * hq, s, hd)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, **kw)
    return out.reshape(b, hq, s, hd).transpose(0, 2, 1, 3)
