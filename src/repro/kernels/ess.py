"""ESS — expert-specific summation (standalone Pallas TPU kernel).

db[e] = sum of rows routed to expert e (paper Fig. 4(c)). The fused ESFK
kernel subsumes this in production; the standalone kernel exists for the
paper's unfused ablation (Fig. 12) and for kernel-level testing.

Grid (d_blocks, m_blocks), m innermost: revisits of the (per-expert) output
block are consecutive because the layout is expert-sorted.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common import pallas_interpret_default, tpu_compiler_params


def _ess_kernel(block_expert, x_ref, o_ref, acc_ref):
    m = pl.program_id(1)
    nm = pl.num_programs(1)
    cur = block_expert[m]
    prev = jnp.where(m == 0, -1, block_expert[jnp.maximum(m - 1, 0)])
    nxt = jnp.where(m == nm - 1, -1, block_expert[jnp.minimum(m + 1, nm - 1)])

    @pl.when(cur != prev)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.sum(
        x_ref[...].astype(jnp.float32), axis=0, keepdims=True
    )

    @pl.when(cur != nxt)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bd", "interpret"))
def ess_pallas(
    x: jax.Array,
    block_expert: jax.Array,
    counts: jax.Array,
    *,
    bm: int = 128,
    bd: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """x: (Np, D) sorted rows -> (E, D) per-expert sums (f32)."""
    if interpret is None:
        interpret = pallas_interpret_default()
    np_rows, d = x.shape
    e = counts.shape[0]
    bm = min(bm, np_rows)
    bd = min(bd, d)
    assert np_rows % bm == 0 and d % bd == 0
    assert block_expert.shape[0] * bm == np_rows
    grid = (d // bd, np_rows // bm)

    out = pl.pallas_call(
        _ess_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((bm, bd), lambda j, m, be: (m, j))],
            out_specs=pl.BlockSpec((1, bd), lambda j, m, be: (be[m], j)),
            scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((e, d), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        cost_estimate=pl.CostEstimate(
            flops=np_rows * d,
            bytes_accessed=x.size * x.dtype.itemsize + e * d * 4,
            transcendentals=0,
        ),
        interpret=interpret,
    )(block_expert, x)
    return jnp.where((counts > 0)[:, None], out, 0.0)
