"""Pure-jnp oracles for the expert-specific operators.

These are the ground truth every other implementation (Pallas, ragged) is
tested against. They operate on the *sorted layout* produced by
``core.reindex`` and are deliberately simple (one-hot einsums); never use
them on real workloads.

Paper mapping (Fig. 3 / Table 5):
  esmm  — expert-specific matrix multiplication.
  ess   — expert-specific summation (bias grads).
  estmm — expert-specific transposed matmul (weight grads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _row_expert(block_expert: jax.Array, blk: int) -> jax.Array:
    """Expand block->expert map to a per-row expert id."""
    return jnp.repeat(block_expert, blk)


def esmm(
    xs: jax.Array,
    w: jax.Array,
    b: jax.Array | None,
    block_expert: jax.Array,
    *,
    transpose_rhs: bool = False,
) -> jax.Array:
    """ys[i] = xs[i] @ W[e(i)] (+ b[e(i)]).

    Args:
      xs: (Np, D1) sorted tokens.
      w:  (E, D1, D2) expert weights; (E, D2, D1) when transpose_rhs.
      b:  (E, D2) or None.
      block_expert: (Np//BLK,) block->expert map.
    """
    np_rows = xs.shape[0]
    blk = np_rows // block_expert.shape[0]
    e = w.shape[0]
    re = _row_expert(block_expert, blk)
    onehot = jax.nn.one_hot(re, e, dtype=xs.dtype)  # (Np, E)
    wx = w.astype(xs.dtype)
    if transpose_rhs:
        y = jnp.einsum("nd,ne,efd->nf", xs, onehot, wx)
    else:
        y = jnp.einsum("nd,ne,edf->nf", xs, onehot, wx)
    if b is not None:
        y = y + onehot @ b.astype(xs.dtype)
    return y


def ess(dy: jax.Array, block_expert: jax.Array, num_experts: int) -> jax.Array:
    """db[e] = sum of dy rows routed to e.  dy: (Np, D) -> (E, D)."""
    np_rows = dy.shape[0]
    blk = np_rows // block_expert.shape[0]
    re = _row_expert(block_expert, blk)
    onehot = jax.nn.one_hot(re, num_experts, dtype=dy.dtype)
    return jnp.einsum("ne,nd->ed", onehot, dy)


def estmm(
    x1: jax.Array, x2: jax.Array, block_expert: jax.Array, num_experts: int
) -> jax.Array:
    """dW[e] = sum_{i in e} x1[i]^T x2[i].  (Np,D1),(Np,D2) -> (E,D1,D2)."""
    np_rows = x1.shape[0]
    blk = np_rows // block_expert.shape[0]
    re = _row_expert(block_expert, blk)
    onehot = jax.nn.one_hot(re, num_experts, dtype=x1.dtype)
    return jnp.einsum("ne,nd,nf->edf", onehot, x1, x2)


def esfk(
    x1: jax.Array, x2: jax.Array, block_expert: jax.Array, num_experts: int
) -> tuple[jax.Array, jax.Array]:
    """Fused backward: (dW, db) from one pass over x2 (= upstream grads)."""
    return (
        estmm(x1, x2, block_expert, num_experts),
        ess(x2, block_expert, num_experts),
    )


def moe_ffn_per_token(
    x: jax.Array,
    expert_idx: jax.Array,
    gates: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    act,
) -> jax.Array:
    """End-to-end per-token MoE FFN oracle (no sorted layout at all).

    y[t] = sum_s gates[t,s] * (act(x[t] @ W1[e] + b1[e]) @ W2[e] + b2[e]),
    e = expert_idx[t, s].  Used to validate the whole hexa pipeline.
    """
    def token_fn(xt, et, gt):
        def slot(e):
            h = act(xt @ w1[e] + b1[e])
            return h @ w2[e] + b2[e]
        ys = jax.vmap(slot)(et)  # (k, D2)
        return jnp.sum(ys * gt[:, None].astype(ys.dtype), axis=0)

    return jax.vmap(token_fn)(x, expert_idx, gates)
