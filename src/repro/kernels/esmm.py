"""ESMM — expert-specific matrix multiplication (Pallas TPU kernel).

Grouped matmul over the expert-sorted layout: every BLK_M-row block of ``xs``
belongs to one expert (``block_expert``, scalar-prefetched so Mosaic can
schedule the weight DMA for block i+1 while block i is on the MXU).

  ys[i] = xs[i] @ W[e(i)] (+ b[e(i)])          (paper Fig. 4(b))

Adaptation from the paper's CUDA kernel: the per-thread-block gather through
the re-index vector becomes a single ahead-of-time sort-permute (see
``core.reindex``); the kernel itself then streams contiguous VMEM tiles into
the MXU with a float32 accumulator, which is the TPU-native shape of the same
zero-redundancy computation.

Quantized weights (DESIGN.md §8): with ``w_scales`` the weight operand is an
int8/fp8 payload whose block-wise scales (``quant.core.quantize_blockwise``)
ride along as a congruent BlockSpec — each weight tile is dequantized in
VMEM right before the MXU contraction, so only the quantized bytes cross
HBM (the cost estimate reflects the smaller itemsize automatically).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common import cdiv, pallas_interpret_default, tpu_compiler_params
from repro.quant.core import dequant_tile, scale_block_dims


def _esmm_kernel(
    block_expert,  # scalar-prefetch (num_blocks,)
    x_ref,         # (BLK_M, BLK_K)
    w_ref,         # (1, BLK_K, BLK_N) or (1, BLK_N, BLK_K) if transposed
    *rest,
    transpose: bool,
    has_scale: bool,
    has_bias: bool,
):
    rest = list(rest)
    s_ref = rest.pop(0) if has_scale else None
    b_ref = rest.pop(0) if has_bias else None
    o_ref, acc_ref = rest
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        if b_ref is None:
            acc_ref[...] = jnp.zeros_like(acc_ref)
        else:
            acc_ref[...] = jnp.broadcast_to(
                b_ref[0].astype(jnp.float32), acc_ref.shape
            )

    w = w_ref[0]
    if has_scale:
        # VMEM dequant right before the contraction (DESIGN.md §8).
        w = dequant_tile(w, s_ref[0])
    # transposed: w block is (BLK_N, BLK_K); contract x dim 1 with w dim 1.
    dims = (((1,), (1,)), ((), ())) if transpose else (((1,), (0,)), ((), ()))
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w, dimension_numbers=dims,
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("transpose_rhs", "bm", "bn", "bk", "interpret"),
)
def esmm_pallas(
    xs: jax.Array,
    w: jax.Array,
    b: jax.Array | None,
    block_expert: jax.Array,
    *,
    w_scales: Optional[jax.Array] = None,
    transpose_rhs: bool = False,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Grouped matmul ys = xs @ W[e] (+ b[e]) on the sorted layout.

    xs: (Np, D1); w: (E, D1, D2) ((E, D2, D1) when transpose_rhs);
    b: (E, D2) or None; block_expert: (Np // bm,). ``w_scales``
    (E, n1, n2): block-wise scales of a quantized ``w`` (same axis order
    as w) — dequantized tile-wise in VMEM before the MXU contraction.
    """
    if interpret is None:
        interpret = pallas_interpret_default()
    np_rows, d1 = xs.shape
    if transpose_rhs:
        e, d2, d1w = w.shape
    else:
        e, d1w, d2 = w.shape
    assert d1w == d1, (w.shape, xs.shape)
    bm = min(bm, np_rows)
    bn = min(bn, d2)
    bk = min(bk, d1)
    assert np_rows % bm == 0 and d2 % bn == 0 and d1 % bk == 0, (
        f"shapes ({np_rows},{d1})x({d2}) not divisible by blocks {bm, bn, bk}"
    )
    assert block_expert.shape[0] * bm == np_rows, (
        "block_expert must be built with blk == bm"
    )
    grid = (np_rows // bm, d2 // bn, d1 // bk)

    if transpose_rhs:
        w_spec = pl.BlockSpec((1, bn, bk), lambda i, j, k, be: (be[i], j, k))
    else:
        w_spec = pl.BlockSpec((1, bk, bn), lambda i, j, k, be: (be[i], k, j))

    kernel = functools.partial(
        _esmm_kernel, transpose=transpose_rhs,
        has_scale=w_scales is not None, has_bias=b is not None,
    )
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k, be: (i, k)),
        w_spec,
    ]
    args = [block_expert, xs, w]
    if w_scales is not None:
        assert w_scales.shape[0] == e, (w_scales.shape, w.shape)
        if transpose_rhs:
            sb = scale_block_dims((d2, d1), w_scales.shape[1:], (bn, bk))
            in_specs.append(pl.BlockSpec(
                (1,) + sb, lambda i, j, k, be: (be[i], j, k)))
        else:
            sb = scale_block_dims((d1, d2), w_scales.shape[1:], (bk, bn))
            in_specs.append(pl.BlockSpec(
                (1,) + sb, lambda i, j, k, be: (be[i], k, j)))
        args.append(w_scales)
    if b is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k, be: (be[i], j)))
        args.append(b)

    flops = 2 * np_rows * d1 * d2
    bytes_accessed = (
        xs.size * xs.dtype.itemsize
        + grid[0] * d1 * d2 * w.dtype.itemsize  # one expert tile per m-block
        + np_rows * d2 * xs.dtype.itemsize
    )
    if w_scales is not None:
        bytes_accessed += grid[0] * int(
            w_scales.shape[1] * w_scales.shape[2]) * 4

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, be: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((np_rows, d2), xs.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        cost_estimate=pl.CostEstimate(
            flops=flops, bytes_accessed=bytes_accessed, transcendentals=0
        ),
        interpret=interpret,
    )(*args)
