"""ESFFN — fused expert-FFN megakernel (Pallas TPU). DESIGN.md §5.

ONE ``pallas_call`` runs the whole expert FFN over the expert-sorted layout:

  gather -> up/gate ESMM -> activation -> down ESMM -> gate-weighted output

Per BLK_M block the kernel

  1. DMA-gathers its token rows straight out of the *unsorted* ``(N, D)``
     activations via the scalar-prefetched ``row_token`` map — the
     ``(Np, D)`` ``gather_sorted`` copy is never materialised in HBM,
  2. computes the up/gate projections against the scalar-prefetched expert
     weight tiles, sharing the single VMEM-resident x tile between gate and
     up in the GLU case,
  3. applies the activation on the VPU,
  4. accumulates the down projection in a float32 VMEM accumulator across
     hidden-dim tiles, and
  5. writes the gate-weighted sorted output (combine-ready: the caller's
     scatter-add needs no further gate multiply).

The ``(Np, F)`` hidden activations exist only tile-wise in VMEM, so the
kernel's HBM traffic is the token rows, one expert weight tile per block,
and the output — the forward analogue of the ESFK backward fusion
(DESIGN.md §2), and the dominant inter-stage traffic the unfused
gather/esmm/act/esmm/combine composition round-trips through HBM.

Padding rows (``row_token == N``) clamp their gather to row ``N-1``; the
garbage they compute is annihilated by their zero combine gate, which is
applied in-kernel before the write.

Backward is flash-style recompute, wired in ``kernels.ops``: only xs-level
residuals are saved and the hidden is rebuilt tile-wise from the existing
ESMM/ESFK ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common import ACTIVATIONS, pallas_interpret_default, tpu_compiler_params
from repro.quant.core import dequant_tile, scale_block_dims

_CONTRACT_K = (((1,), (0,)), ((), ()))  # row-major matmul: (m,k)x(k,n)


def esffn_cost(
    np_rows: int,
    d: int,
    f: int,
    num_blocks: int,
    itemsize: int,
    *,
    glu: bool,
    has_b1: bool = False,
    has_b2: bool = False,
    weight_bits: int | None = None,
) -> pl.CostEstimate:
    """Cost model of the fused FFN pass.

    ``bytes_accessed`` counts the gathered token rows, one expert weight
    tile per M-block, the gate vector and the sorted output — and, by
    construction, EXCLUDES the (Np, F) hidden round-trip the unfused
    composition pays (2 HBM writes + 2..3 reads of g/u/h between stages)
    plus the (Np, D) sorted-copy round-trip of ``gather_sorted``.

    ``weight_bits`` (DESIGN.md §8) overrides the weight itemsize for
    quantized experts: int8/fp8 payloads move 8 bits per element across
    HBM regardless of the activation dtype, which is what shifts the
    autotune data-/model-centric crossover (block-wise scales add
    ~``(128*128)``-fold fewer bytes and are excluded).
    """
    n_mm = 3 if glu else 2
    flops = n_mm * 2 * np_rows * d * f
    w_itemsize = itemsize if weight_bits is None else weight_bits // 8
    w_bytes = num_blocks * n_mm * d * f * w_itemsize
    b_bytes = num_blocks * ((f if has_b1 else 0) + (d if has_b2 else 0)) * itemsize
    bytes_accessed = (
        np_rows * d * itemsize      # token rows gathered in
        + w_bytes + b_bytes         # one expert tile per m-block
        + np_rows * 4               # row_gate
        + np_rows * d * itemsize    # gate-weighted sorted output
    )
    return pl.CostEstimate(
        flops=flops, bytes_accessed=int(bytes_accessed),
        transcendentals=np_rows * f,
    )


def _gather_block(x_any, rt_ref, x_s, sem, m, bm, n_tokens):
    """DMA rows ``row_token[m*bm : (m+1)*bm]`` of the unsorted x into VMEM.

    Sentinel rows (token id == n_tokens) clamp to the last real row: their
    values are annihilated by the zero combine gate at write-out, so any
    finite row serves. All row copies are started before any is awaited so
    Mosaic can keep the full gather in flight.
    """
    base = m * bm

    def start(i, _):
        tok = jnp.minimum(rt_ref[base + i], n_tokens - 1)
        pltpu.make_async_copy(x_any.at[tok], x_s.at[i], sem).start()
        return _

    jax.lax.fori_loop(0, bm, start, None)

    def wait(i, _):
        # Waits are by byte count: any same-shaped descriptor drains one row.
        pltpu.make_async_copy(x_any.at[0], x_s.at[0], sem).wait()
        return _

    jax.lax.fori_loop(0, bm, wait, None)


def _wtile(w_ref, s_ref):
    """One expert weight tile, dequantized in VMEM when quantized
    (DESIGN.md §8) — only the int8/fp8 bytes crossed HBM."""
    if s_ref is None:
        return w_ref[0]
    return dequant_tile(w_ref[0], s_ref[0])


def _esffn_glu_kernel(
    block_expert,  # scalar prefetch (num_blocks,)
    row_token,     # scalar prefetch (Np,)
    x_any,         # (N, D) unsorted tokens, ANY/HBM
    *rest,         # wg [sg] wu [su] wd [sd] gate o x_s acc sem
    act_fn,
    bm: int,
    n_tokens: int,
    quantized: bool,
):
    rest = list(rest)
    wg_ref = rest.pop(0)
    sg_ref = rest.pop(0) if quantized else None
    wu_ref = rest.pop(0)
    su_ref = rest.pop(0) if quantized else None
    wd_ref = rest.pop(0)
    sd_ref = rest.pop(0) if quantized else None
    gate_ref, o_ref, x_s, acc, sem = rest

    m = pl.program_id(0)
    fb = pl.program_id(1)
    nf = pl.num_programs(1)

    @pl.when(fb == 0)
    def _load():
        _gather_block(x_any, row_token, x_s, sem, m, bm, n_tokens)
        acc[...] = jnp.zeros_like(acc)

    x = x_s[...]
    # One read of the x tile feeds BOTH projections (the GLU sharing).
    g = jax.lax.dot_general(
        x, _wtile(wg_ref, sg_ref), _CONTRACT_K,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    u = jax.lax.dot_general(
        x, _wtile(wu_ref, su_ref), _CONTRACT_K,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    h = act_fn(g) * u  # (BLK_M, BLK_F), VMEM only — never written to HBM
    acc[...] += jax.lax.dot_general(
        h, _wtile(wd_ref, sd_ref), _CONTRACT_K,
        preferred_element_type=jnp.float32,
    )

    @pl.when(fb == nf - 1)
    def _flush():
        o_ref[...] = (
            acc[...] * gate_ref[...].astype(jnp.float32)
        ).astype(o_ref.dtype)


def _esffn_mlp_kernel(
    block_expert,
    row_token,
    x_any,
    w1_ref,        # (1, D, BLK_F)
    *rest,         # [s1], [b1 (1, BLK_F)], w2 (1, BLK_F, D), [s2],
                   # [b2 (1, D)], gate, o, x_s, acc, sem
    act_fn,
    bm: int,
    n_tokens: int,
    has_b1: bool,
    has_b2: bool,
    quantized: bool,
):
    rest = list(rest)
    s1_ref = rest.pop(0) if quantized else None
    b1_ref = rest.pop(0) if has_b1 else None
    w2_ref = rest.pop(0)
    s2_ref = rest.pop(0) if quantized else None
    b2_ref = rest.pop(0) if has_b2 else None
    gate_ref, o_ref, x_s, acc, sem = rest

    m = pl.program_id(0)
    fb = pl.program_id(1)
    nf = pl.num_programs(1)

    @pl.when(fb == 0)
    def _load():
        _gather_block(x_any, row_token, x_s, sem, m, bm, n_tokens)
        if has_b2:
            # b2 is added once per row, not once per hidden tile.
            acc[...] = jnp.broadcast_to(
                b2_ref[0].astype(jnp.float32), acc.shape
            )
        else:
            acc[...] = jnp.zeros_like(acc)

    x = x_s[...]
    z = jax.lax.dot_general(
        x, _wtile(w1_ref, s1_ref), _CONTRACT_K,
        preferred_element_type=jnp.float32,
    )
    if has_b1:
        z = z + b1_ref[0].astype(jnp.float32)
    h = act_fn(z.astype(x.dtype))
    acc[...] += jax.lax.dot_general(
        h, _wtile(w2_ref, s2_ref), _CONTRACT_K,
        preferred_element_type=jnp.float32,
    )

    @pl.when(fb == nf - 1)
    def _flush():
        o_ref[...] = (
            acc[...] * gate_ref[...].astype(jnp.float32)
        ).astype(o_ref.dtype)


def _call(kernel, x, row_token, row_gate, block_expert, tensor_args,
          tensor_specs, f_dim, bf, cost, interpret):
    n, d = x.shape
    np_rows = row_token.shape[0]
    nm = block_expert.shape[0]
    assert np_rows % nm == 0, (np_rows, nm)
    bm = np_rows // nm
    bf = min(bf, f_dim)
    assert f_dim % bf == 0, (f_dim, bf)
    grid = (nm, f_dim // bf)

    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY)] + tensor_specs + [
        pl.BlockSpec((bm, 1), lambda m, fb, be, rt: (m, 0)),
    ]
    return pl.pallas_call(
        functools.partial(kernel, bm=bm, n_tokens=n),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, d), lambda m, fb, be, rt: (m, 0)),
            scratch_shapes=[
                pltpu.VMEM((bm, d), x.dtype),
                pltpu.VMEM((bm, d), jnp.float32),
                pltpu.SemaphoreType.DMA,
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((np_rows, d), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
    )(block_expert, row_token, x, *tensor_args,
      row_gate.reshape(np_rows, 1).astype(jnp.float32))


def _scale_spec(wdims, sdims, bdims, index_map):
    """BlockSpec of a weight's scale operand, congruent with its weight
    BlockSpec (each per-axis quant tile must divide the kernel block)."""
    return pl.BlockSpec(
        (1,) + scale_block_dims(wdims, sdims, bdims), index_map
    )


@functools.partial(jax.jit, static_argnames=("act", "bf", "interpret"))
def esffn_glu_pallas(
    x: jax.Array,
    row_token: jax.Array,
    row_gate: jax.Array,
    block_expert: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    w_scales=None,
    act: str = "silu",
    bf: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused GLU expert FFN: (N, D) unsorted tokens -> (Np, D) gate-weighted
    sorted output, in one Pallas pass.

    x: (N, D); row_token/row_gate: (Np,) from ``core.reindex``; block_expert:
    (Np // blk,); w_gate/w_up: (E, D, F); w_down: (E, F, D). ``w_scales``
    (DESIGN.md §8): (sg, su, sd) block-wise scales of int8/fp8 weights —
    each weight tile is dequantized in VMEM right before its MXU
    contraction, so the quantized bytes are what cross HBM.
    """
    if interpret is None:
        interpret = pallas_interpret_default()
    n, d = x.shape
    e, dw, f = w_gate.shape
    assert dw == d and w_up.shape == (e, d, f) and w_down.shape == (e, f, d)
    nm = block_expert.shape[0]
    bf_r = min(bf, f)
    quantized = w_scales is not None
    kernel = functools.partial(
        _esffn_glu_kernel, act_fn=ACTIVATIONS[act], quantized=quantized
    )
    up_map = lambda m, fb, be, rt: (be[m], 0, fb)    # noqa: E731
    down_map = lambda m, fb, be, rt: (be[m], fb, 0)  # noqa: E731
    args, specs = [], []
    for wt, sc, wdims, bdims, imap in (
        (w_gate, None if not quantized else w_scales[0], (d, f),
         (d, bf_r), up_map),
        (w_up, None if not quantized else w_scales[1], (d, f),
         (d, bf_r), up_map),
        (w_down, None if not quantized else w_scales[2], (f, d),
         (bf_r, d), down_map),
    ):
        args.append(wt)
        specs.append(pl.BlockSpec((1,) + bdims, imap))
        if quantized:
            args.append(sc)
            specs.append(_scale_spec(wdims, sc.shape[1:], bdims, imap))
    cost = esffn_cost(
        row_token.shape[0], d, f, nm, x.dtype.itemsize, glu=True,
        weight_bits=8 * w_gate.dtype.itemsize,
    )
    return _call(kernel, x, row_token, row_gate, block_expert,
                 args, specs, f, bf, cost, interpret)


@functools.partial(jax.jit, static_argnames=("act", "bf", "interpret"))
def esffn_mlp_pallas(
    x: jax.Array,
    row_token: jax.Array,
    row_gate: jax.Array,
    block_expert: jax.Array,
    w1: jax.Array,
    b1: jax.Array | None,
    w2: jax.Array,
    b2: jax.Array | None,
    *,
    w_scales=None,
    act: str = "gelu",
    bf: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused 2-MLP expert FFN (optionally biased); see ``esffn_glu_pallas``.

    w1: (E, D, F); b1: (E, F) or None; w2: (E, F, D); b2: (E, D) or None.
    ``w_scales``: (s1, s2) block-wise scales of quantized w1/w2 (biases
    stay full precision).
    """
    if interpret is None:
        interpret = pallas_interpret_default()
    n, d = x.shape
    e, dw, f = w1.shape
    assert dw == d and w2.shape == (e, f, d)
    nm = block_expert.shape[0]
    bf_r = min(bf, f)
    quantized = w_scales is not None
    kernel = functools.partial(
        _esffn_mlp_kernel, act_fn=ACTIVATIONS[act],
        has_b1=b1 is not None, has_b2=b2 is not None, quantized=quantized,
    )
    up_map = lambda m, fb, be, rt: (be[m], 0, fb)    # noqa: E731
    down_map = lambda m, fb, be, rt: (be[m], fb, 0)  # noqa: E731
    args = [w1]
    specs = [pl.BlockSpec((1, d, bf_r), up_map)]
    if quantized:
        args.append(w_scales[0])
        specs.append(_scale_spec((d, f), w_scales[0].shape[1:],
                                 (d, bf_r), up_map))
    if b1 is not None:
        assert b1.shape == (e, f)
        args.append(b1)
        specs.append(pl.BlockSpec((1, bf_r), lambda m, fb, be, rt: (be[m], fb)))
    args.append(w2)
    specs.append(pl.BlockSpec((1, bf_r, d), down_map))
    if quantized:
        args.append(w_scales[1])
        specs.append(_scale_spec((f, d), w_scales[1].shape[1:],
                                 (bf_r, d), down_map))
    if b2 is not None:
        assert b2.shape == (e, d)
        args.append(b2)
        specs.append(pl.BlockSpec((1, d), lambda m, fb, be, rt: (be[m], 0)))
    cost = esffn_cost(
        row_token.shape[0], d, f, nm, x.dtype.itemsize, glu=False,
        has_b1=b1 is not None, has_b2=b2 is not None,
        weight_bits=8 * w1.dtype.itemsize,
    )
    return _call(kernel, x, row_token, row_gate, block_expert,
                 args, specs, f, bf, cost, interpret)
