"""Paged decode attention (Pallas TPU) over a shared KV page pool. DESIGN.md §7.

Serving keeps one query row per slot and its keys/values scattered across
fixed-size pages of a shared pool (``models.lm.init_paged_cache``). This
kernel gathers them page-wise: the grid is (slot, kv-head, logical-page) and
the K/V BlockSpec index maps read the *scalar-prefetched* page table — the
same prefetch-driven DMA-gather idiom as the esffn megakernel — so each
program pulls exactly one physical page into VMEM and folds it into a
running online softmax. Pages past the slot's length (and, for windowed
layers, pages wholly behind the window) never run; HBM traffic is therefore
proportional to the tokens actually resident, not to the dense
``num_slots x max_seq`` rectangle the old cache allocated up front.

Three implementations share the signature:

  * ``paged_attention_pallas``  — the kernel (interpret-mode off-TPU).
  * ``paged_attention_blocked`` — XLA fallback: a ``lax.scan`` over logical
    pages with the same online-softmax accumulator; one (B, page) block of
    K/V is gathered per step, so live memory stays page-bounded.
  * ``paged_attention_ref``     — gather the page table to a dense
    (B, maxp*page) view and run plain masked softmax attention; the
    numerical reference (same reduction structure as
    ``models.attention.decode_attention``) and the serving default on CPU.

``paged_attn_cost`` is the pricing entry ``parallel.autotune`` uses: its
bytes-accessed term sums ``ceil(len_i / page) * page`` over slots — by
construction there is no dense ``num_slots * max_seq`` term.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common import cdiv, pallas_interpret_default, tpu_compiler_params

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# cost model (autotune pricing entry)
# ---------------------------------------------------------------------------

def paged_attn_cost(
    lengths: Sequence[int],
    page: int,
    hq: int,
    hkv: int,
    hd: int,
    itemsize: int = 2,
) -> dict:
    """Bytes/FLOPs of one paged decode-attention step for actual ``lengths``.

    bytes_accessed = q + out + the K/V pages that hold live tokens
    (``ceil(len/page) * page`` rows per slot). The dense layout's
    ``num_slots * max_seq`` rectangle never appears: an empty slot costs one
    query row, a short sequence costs its own pages only.
    """
    b = len(lengths)
    kv_rows = sum(cdiv(int(l), page) * page for l in lengths)
    tokens = sum(int(l) for l in lengths)
    q_bytes = b * hq * hd * itemsize
    kv_bytes = 2 * kv_rows * hkv * hd * itemsize
    flops = 4 * tokens * hq * hd  # qk^T + pv per live token, per q head
    return {
        "flops": int(flops),
        "bytes_accessed": int(q_bytes * 2 + kv_bytes),
        "transcendentals": int(tokens * hq),
    }


# ---------------------------------------------------------------------------
# reference: gather pages dense, plain masked softmax
# ---------------------------------------------------------------------------

def _mask_from(lengths, s, window):
    kpos = jnp.arange(s)[None, :]                        # (1, S)
    valid = kpos < lengths[:, None]                      # (B, S)
    if window is not None:
        valid &= kpos >= (lengths[:, None] - window)
    return valid


def paged_attention_ref(
    q: jax.Array,           # (B, 1, Hq, hd)
    k_pool: jax.Array,      # (npages, page, Hkv, hd)
    v_pool: jax.Array,
    page_table: jax.Array,  # (B, maxp) int32, physical page per logical page
    lengths: jax.Array,     # (B,) int32, live tokens per slot (incl. current)
    *,
    k_scale: Optional[jax.Array] = None,  # (npages, page, Hkv) int8 pools
    v_scale: Optional[jax.Array] = None,
    window: Optional[int] = None,
    softcap: float = 0.0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Gather-dense paged decode attention (serving default off-TPU).

    Reconstructs each slot's logical (maxp*page) K/V view with one
    ``take`` over the page table, then runs the exact masked-softmax
    reduction of ``models.attention.decode_attention`` — the numerical
    reference the parity matrix pins the other impls against.
    ``k_scale``/``v_scale``: per-(row, kv-head) scales of int8 pools
    (DESIGN.md §8) — gathered pages are dequantized before the reduction.
    """
    b, one, hq, hd = q.shape
    npages, page, hkv, _ = k_pool.shape
    maxp = page_table.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else hd ** -0.5
    s = maxp * page

    def view(pool, sc):
        gathered = pool[page_table]                      # (B, maxp, page, Hkv, hd)
        if sc is not None:
            gathered = (gathered.astype(jnp.float32)
                        * sc[page_table][..., None]).astype(q.dtype)
        return gathered.reshape(b, s, hkv, hd)

    k_v = view(k_pool, k_scale)
    v_v = view(v_pool, v_scale)
    qg = q.reshape(b, hkv, g, hd)
    logits = jnp.einsum(
        "bhgd,bshd->bhgs", qg, k_v, preferred_element_type=jnp.float32
    ) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    valid = _mask_from(lengths, s, window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    # all-masked rows (empty slots) give a uniform p; zero them explicitly
    p = jnp.where(lengths[:, None, None, None] > 0, p, 0.0)
    out = jnp.einsum(
        "bhgs,bshd->bhgd", p.astype(v_v.dtype), v_v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# blocked: online softmax over logical pages (pure XLA)
# ---------------------------------------------------------------------------

def paged_attention_blocked(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    *,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    window: Optional[int] = None,
    softcap: float = 0.0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Flash-decode over pages: scan logical pages, gather one physical
    (B, page) K/V block per step, fold into a running (m, l, acc). Live
    memory is one page per slot instead of the whole gathered view.
    int8 pools (``k_scale``/``v_scale``) dequantize per gathered page."""
    b, one, hq, hd = q.shape
    npages, page, hkv, _ = k_pool.shape
    maxp = page_table.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(b, hkv, g, hd)

    def step(carry, j):
        m, l, acc = carry
        phys = page_table[:, j]                          # (B,)
        kb = k_pool[phys]                                # (B, page, Hkv, hd)
        vb = v_pool[phys]
        if k_scale is not None:
            kb = (kb.astype(jnp.float32)
                  * k_scale[phys][..., None]).astype(q.dtype)
        if v_scale is not None:
            vb = (vb.astype(jnp.float32)
                  * v_scale[phys][..., None]).astype(q.dtype)
        logits = jnp.einsum(
            "bhgd,bphd->bhgp", qg, kb, preferred_element_type=jnp.float32
        ) * scale
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        kpos = j * page + jnp.arange(page)[None, :]      # (1, page)
        valid = kpos < lengths[:, None]
        if window is not None:
            valid &= kpos >= (lengths[:, None] - window)
        logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        # all-masked blocks (empty slot): exp(NEG_INF - NEG_INF) would be 1
        p = jnp.where(
            valid[:, None, None, :], jnp.exp(logits - m_new[..., None]), 0.0
        )
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgp,bphd->bhgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(maxp))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# pallas kernel
# ---------------------------------------------------------------------------

def _paged_kernel(
    pt_ref,      # scalar prefetch (B, maxp) int32
    len_ref,     # scalar prefetch (B,) int32
    q_ref,       # (1, 1, G, hd)
    k_ref,       # (1, page, 1, hd) — physical page via pt_ref index map
    v_ref,
    *rest,       # [ks (1, page, 1), vs] o_ref, m_s, l_s, acc_s
    scale: float,
    page: int,
    window: Optional[int],
    softcap: float,
    quantized: bool,
):
    rest = list(rest)
    ks_ref = rest.pop(0) if quantized else None
    vs_ref = rest.pop(0) if quantized else None
    o_ref, m_s, l_s, acc_s = rest
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    length = len_ref[b]
    run = j * page < length
    if window is not None:
        run &= (j + 1) * page > length - window  # page wholly behind window

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)              # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # (page, hd)
        if quantized:
            # per-row dequant of the gathered int8 page (DESIGN.md §8)
            k = k * ks_ref[0, :, 0][:, None]
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                        # (G, page)
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        gdim = logits.shape[0]
        kpos = j * page + jax.lax.broadcasted_iota(
            jnp.int32, (gdim, page), 1
        )
        valid = kpos < length
        if window is not None:
            valid &= kpos >= length - window
        logits = jnp.where(valid, logits, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_s[...] = l_s[...] * alpha + p.sum(axis=1, keepdims=True)
        m_s[...] = m_new
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if quantized:
            v = v * vs_ref[0, :, 0][:, None]
        acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(j == nj - 1)
    def _done():
        o_ref[0, 0] = (
            acc_s[...] / jnp.maximum(l_s[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "interpret")
)
def paged_attention_pallas(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    *,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    window: Optional[int] = None,
    softcap: float = 0.0,
    interpret: bool | None = None,
) -> jax.Array:
    """One query row per slot, K/V gathered page-wise through the
    scalar-prefetched page table (grid = slot x kv-head x logical page).
    int8 pools ride with per-(row, head) scale pools whose pages follow
    the same table-indexed BlockSpec and dequantize in VMEM
    (DESIGN.md §8) — the int8 bytes are what cross HBM."""
    if interpret is None:
        interpret = pallas_interpret_default()
    b, one, hq, hd = q.shape
    npages, page, hkv, _ = k_pool.shape
    maxp = page_table.shape[1]
    g = hq // hkv
    scale = hd ** -0.5
    qg = q.reshape(b, hkv, g, hd)
    grid = (b, hkv, maxp)
    quantized = k_scale is not None

    cost = paged_attn_cost(
        [maxp * page] * b, page, hq, hkv, hd, k_pool.dtype.itemsize
    )

    kv_spec = pl.BlockSpec(
        (1, page, 1, hd), lambda bb, h, j, pt, ln: (pt[bb, j], 0, h, 0)
    )
    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda bb, h, j, pt, ln: (bb, h, 0, 0)),
        kv_spec,
        kv_spec,
    ]
    args = [qg, k_pool, v_pool]
    if quantized:
        sc_spec = pl.BlockSpec(
            (1, page, 1), lambda bb, h, j, pt, ln: (pt[bb, j], 0, h)
        )
        in_specs += [sc_spec, sc_spec]
        args += [k_scale, v_scale]

    out = pl.pallas_call(
        functools.partial(
            _paged_kernel, scale=scale, page=page, window=window,
            softcap=softcap, quantized=quantized,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (1, 1, g, hd), lambda bb, h, j, pt, ln: (bb, h, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        cost_estimate=pl.CostEstimate(
            flops=cost["flops"],
            bytes_accessed=cost["bytes_accessed"],
            transcendentals=cost["transcendentals"],
        ),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), *args)
    return out.reshape(b, 1, hq, hd)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def paged_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    *,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    window: Optional[int] = None,
    softcap: float = 0.0,
    impl: Optional[str] = None,
) -> jax.Array:
    """Impl dispatch, mirroring ``kernels.ops``: "pallas" | "blocked" |
    "ref"/"gather" (default off-TPU: the gather-dense reference — on CPU the
    page gather is memory-bound either way and the dense reduction is what
    the parity matrix pins). ``k_scale``/``v_scale``: int8-pool
    per-(row, head) scales (DESIGN.md §8)."""
    from repro.kernels import ops

    impl = impl or ops.get_default_impl()
    kw = dict(k_scale=k_scale, v_scale=v_scale, window=window,
              softcap=softcap)
    if impl == "pallas":
        return paged_attention_pallas(q, k_pool, v_pool, page_table,
                                      lengths, **kw)
    if impl == "blocked":
        return paged_attention_blocked(q, k_pool, v_pool, page_table,
                                       lengths, **kw)
    if impl in ("ref", "gather", "ragged"):
        return paged_attention_ref(q, k_pool, v_pool, page_table,
                                   lengths, **kw)
    raise ValueError(f"unknown paged attention impl {impl!r}")
