"""ESFK — expert-specific fused backward kernel (Pallas TPU).

Computes weight grads (ESTMM) and bias grads (ESS) in ONE pass over the
upstream-gradient tiles:

  dW[e] = sum_{rows i in e} x1[i]^T x2[i]        (paper Fig. 4(d))
  db[e] = sum_{rows i in e} x2[i]                (paper Fig. 4(c))

Adaptation note (DESIGN.md §2): the paper fuses ESS+ESTMM+ESMM by
concatenating CUDA thread grids to raise SM occupancy. On TPU the profitable
fusion is HBM-traffic fusion — x2 (= dy) is read once for both outputs.
dX remains a separate ESMM (different output layout, MXU-bound anyway).

Grid is (d1_blocks, d2_blocks, m_blocks) with m innermost so that revisits of
the accumulator output block (one per expert) are consecutive — the sorted
layout guarantees equal experts occupy consecutive m blocks.

The db output carries one junk row (shape (E+1, D2)): for d1-block index
i > 0 the kernel parks its write target on row E so the auto copy-back of the
revisited buffer never corrupts real rows. Caller slices [:E].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common import pallas_interpret_default, tpu_compiler_params


def _esfk_kernel(
    block_expert,  # (num_m_blocks,) scalar prefetch
    x1_ref,        # (BLK_M, BLK_D1)
    x2_ref,        # (BLK_M, BLK_D2)
    dw_ref,        # (1, BLK_D1, BLK_D2)
    db_ref,        # (1, BLK_D2)
    acc_dw,        # VMEM (BLK_D1, BLK_D2) f32
    acc_db,        # VMEM (1, BLK_D2) f32
):
    i = pl.program_id(0)
    m = pl.program_id(2)
    nm = pl.num_programs(2)

    cur = block_expert[m]
    prev = jnp.where(m == 0, -1, block_expert[jnp.maximum(m - 1, 0)])
    nxt = jnp.where(
        m == nm - 1, -1, block_expert[jnp.minimum(m + 1, nm - 1)]
    )
    is_first = cur != prev
    is_last = cur != nxt

    @pl.when(is_first)
    def _init():
        acc_dw[...] = jnp.zeros_like(acc_dw)
        acc_db[...] = jnp.zeros_like(acc_db)

    acc_dw[...] += jax.lax.dot_general(
        x1_ref[...],
        x2_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),  # x1^T @ x2
        preferred_element_type=jnp.float32,
    )
    # db accumulation costs BLK_M*BLK_D2 adds — negligible next to the
    # BLK_M*BLK_D1*BLK_D2 MACs above; keeping it unconditional keeps the
    # revisit/write logic uniform.
    acc_db[...] += jnp.sum(
        x2_ref[...].astype(jnp.float32), axis=0, keepdims=True
    )

    @pl.when(is_last)
    def _done():
        dw_ref[...] = acc_dw[...][None].astype(dw_ref.dtype)
        db_ref[...] = acc_db[...].astype(db_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "b1", "b2", "interpret")
)
def esfk_pallas(
    x1: jax.Array,
    x2: jax.Array,
    block_expert: jax.Array,
    counts: jax.Array,
    num_experts: int | None = None,
    *,
    bm: int = 128,
    b1: int = 128,
    b2: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused (dW, db) over the sorted layout.

    x1: (Np, D1) saved activations; x2: (Np, D2) upstream grads;
    block_expert: (Np // bm,); counts: (E,) true rows per expert (used to
    zero experts that received no tokens — their output blocks are never
    visited by the grid).
    Returns dW: (E, D1, D2) f32, db: (E, D2) f32.
    """
    if interpret is None:
        interpret = pallas_interpret_default()
    np_rows, d1 = x1.shape
    np2, d2 = x2.shape
    assert np_rows == np2
    e = counts.shape[0] if num_experts is None else num_experts
    bm = min(bm, np_rows)
    b1 = min(b1, d1)
    b2 = min(b2, d2)
    assert np_rows % bm == 0 and d1 % b1 == 0 and d2 % b2 == 0
    assert block_expert.shape[0] * bm == np_rows
    grid = (d1 // b1, d2 // b2, np_rows // bm)

    flops = 2 * np_rows * d1 * d2
    bytes_accessed = (
        (d2 // b2) * x1.size * x1.dtype.itemsize
        + (d1 // b1) * x2.size * x2.dtype.itemsize
        + e * d1 * d2 * 4
    )

    dw, db_full = pl.pallas_call(
        _esfk_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, b1), lambda i, j, m, be: (m, i)),
                pl.BlockSpec((bm, b2), lambda i, j, m, be: (m, j)),
            ],
            out_specs=[
                pl.BlockSpec(
                    (1, b1, b2), lambda i, j, m, be: (be[m], i, j)
                ),
                # Junk-row parking for i > 0 (see module docstring).
                pl.BlockSpec(
                    (1, b2),
                    lambda i, j, m, be: (jnp.where(i == 0, be[m], e), j),
                ),
            ],
            scratch_shapes=[
                pltpu.VMEM((b1, b2), jnp.float32),
                pltpu.VMEM((1, b2), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((e, d1, d2), jnp.float32),
            jax.ShapeDtypeStruct((e + 1, d2), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        cost_estimate=pl.CostEstimate(
            flops=flops, bytes_accessed=bytes_accessed, transcendentals=0
        ),
        interpret=interpret,
    )(block_expert, x1, x2)

    # Experts with zero routed tokens are never visited by the grid: their
    # HBM output blocks are undefined. Mask them to exact zeros.
    has = counts > 0
    dw = jnp.where(has[:, None, None], dw, 0.0)
    db = jnp.where(has[:, None], db_full[:e], 0.0)
    return dw, db
