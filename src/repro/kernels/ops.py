"""Public expert-specific ops: impl dispatch + custom autodiff.

Three interchangeable implementations of the same zero-redundancy semantics
over the expert-sorted layout (see ``core.reindex``):

  - ``pallas`` — the paper-faithful TPU kernels (esmm/esfk/ess/estmm);
    interpret mode on CPU.
  - ``ragged`` — ``lax.ragged_dot(_general)``: XLA's grouped-GeMM lowering.
    Used for the multi-pod dry-run/compile path and CPU benchmarks (a Pallas
    interpret-mode kernel would unroll its grid into the HLO).
  - ``ref``    — pure-jnp one-hot oracle (tests only).

The backward pass is wired by ``custom_vjp`` exactly as the paper's Table 5:
dX via ESMM with transposed weights, (dW, db) via the fused ESFK (or the
unfused ESTMM + ESS pair when ``fused=False``, paper Fig. 12 ablation).

The forward-side fusion (DESIGN.md §5) lives here too: ``esffn_glu`` /
``esffn_mlp`` run the whole expert FFN — gather, up/gate, activation, down,
gate weighting — as ONE op (the Pallas megakernel ``kernels.esffn`` on TPU,
a single fused XLA region for ``blocked``), with a flash-style custom_vjp
that saves only xs-level residuals and recomputes the hidden tile-wise in
the backward from the ESMM/ESFK ops above.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.common import ACTIVATIONS, on_tpu
from repro.kernels import ref as _ref
from repro.quant.core import dequantize_blockwise
from repro.kernels.esmm import esmm_pallas
from repro.kernels.esffn import esffn_glu_pallas, esffn_mlp_pallas
from repro.kernels.esfk import esfk_pallas
from repro.kernels.ess import ess_pallas
from repro.kernels.estmm import estmm_pallas

_DEFAULT_IMPL: Optional[str] = None
_FUSED_BACKWARD: bool = True


def set_default_impl(impl: Optional[str]) -> None:
    """Set the process-wide default implementation (None = auto)."""
    global _DEFAULT_IMPL
    assert impl in (None, "pallas", "ragged", "blocked", "ref")
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    if _DEFAULT_IMPL is not None:
        return _DEFAULT_IMPL
    return "pallas" if on_tpu() else "blocked"


def default_fused_ffn(impl: Optional[str] = None) -> bool:
    """Whether the fused forward FFN (DESIGN.md §5) is on by default.

    On for the TPU ``pallas`` path, where the megakernel removes real HBM
    round-trips; the XLA impls keep the unfused composition unless the
    caller (``ParallelConfig.fused_ffn`` / espec's ``fused=``) opts in.
    """
    return (impl or get_default_impl()) == "pallas"


# ---------------------------------------------------------------------------
# blocked (batched block-diagonal einsum) implementation
#
# Exploits the sorted layout's invariant directly in XLA: every BLK-row
# block uses ONE expert, so the grouped matmul is a plain batched matmul
# against per-block gathered weight tiles. Compiled FLOPs equal the
# zero-redundancy count (Np * D1 * D2 * 2) exactly — unlike
# lax.ragged_dot, whose CPU lowering computes every group densely (E x
# redundancy). This is both the dry-run compile path and the fastest
# CPU execution path; on TPU the Pallas kernels replace it (the per-block
# weight gather becomes the scalar-prefetched DMA).
# ---------------------------------------------------------------------------

def _blocked_esmm(xs, w, b, block_expert, transpose_rhs, w_scales=None):
    np_rows = xs.shape[0]
    nblk = block_expert.shape[0]
    blk = np_rows // nblk
    xb = xs.reshape(nblk, blk, -1)
    wb = w[block_expert]  # (nblk, D1, D2) or (nblk, D2, D1)
    if w_scales is not None:
        # int8/fp8 tiles gathered per block (the quantized bytes are what
        # move), dequantized block-wise right before the contraction —
        # the XLA analogue of the kernel's VMEM dequant (DESIGN.md §8).
        wb = dequantize_blockwise(wb, w_scales[block_expert], dtype=xs.dtype)
    if transpose_rhs:
        y = jnp.einsum(
            "gbk,gnk->gbn", xb, wb, preferred_element_type=xs.dtype
        )
    else:
        y = jnp.einsum(
            "gbk,gkn->gbn", xb, wb, preferred_element_type=xs.dtype
        )
    if b is not None:
        y = y + b[block_expert][:, None].astype(y.dtype)
    return y.reshape(np_rows, -1)


def _blocked_estmm(x1, x2, block_expert, num_experts):
    np_rows = x1.shape[0]
    nblk = block_expert.shape[0]
    blk = np_rows // nblk
    per_block = jnp.einsum(
        "gbd,gbf->gdf",
        x1.reshape(nblk, blk, -1),
        x2.reshape(nblk, blk, -1),
        preferred_element_type=jnp.float32,
    )
    out = jnp.zeros((num_experts,) + per_block.shape[1:], jnp.float32)
    return out.at[block_expert].add(per_block)


def set_fused_backward(fused: bool) -> None:
    """Toggle the ESFK fusion (paper Fig. 12 'fused kernel' ablation)."""
    global _FUSED_BACKWARD
    _FUSED_BACKWARD = fused


# ---------------------------------------------------------------------------
# ragged (lax.ragged_dot) implementation
# ---------------------------------------------------------------------------

def _full_group_sizes(padded_counts: jax.Array, np_rows) -> jax.Array:
    """Group sizes covering *all* rows: the tail (static over-allocation past
    the last group) is absorbed into the final group so no row is left with
    unspecified output. Tail rows are all-zero sentinels, so this is exact."""
    tail = np_rows - jnp.sum(padded_counts)
    return padded_counts.at[-1].add(tail.astype(padded_counts.dtype))


#: jax 0.4.x only ships the fixed-layout lax.ragged_dot; the general
#: dimension-numbers form arrived later. Fall back where possible.
_HAS_RAGGED_DN = hasattr(lax, "RaggedDotDimensionNumbers")


def _ragged_esmm(xs, w, b, block_expert, padded_counts, transpose_rhs):
    np_rows = xs.shape[0]
    gs = _full_group_sizes(padded_counts, np_rows)
    if transpose_rhs:
        if _HAS_RAGGED_DN:
            dn = lax.RaggedDotDimensionNumbers(
                dot_dimension_numbers=(((1,), (2,)), ((), ())),
                lhs_ragged_dimensions=[0],
                rhs_group_dimensions=[0],
            )
            y = lax.ragged_dot_general(
                xs, w, gs, dn, preferred_element_type=xs.dtype
            )
        else:  # materialise the transpose; XLA folds it into the dot
            y = lax.ragged_dot(
                xs, jnp.swapaxes(w, 1, 2), gs, preferred_element_type=xs.dtype
            )
    else:
        y = lax.ragged_dot(xs, w, gs, preferred_element_type=xs.dtype)
    if b is not None:
        nblk = block_expert.shape[0]
        blk = np_rows // nblk
        y = (
            y.reshape(nblk, blk, -1) + b[block_expert][:, None].astype(y.dtype)
        ).reshape(np_rows, -1)
    return y


def _ragged_estmm(x1, x2, padded_counts):
    gs = _full_group_sizes(padded_counts, x1.shape[0])
    dn = lax.RaggedDotDimensionNumbers(
        dot_dimension_numbers=(((0,), (0,)), ((), ())),
        lhs_ragged_dimensions=[0],
        rhs_group_dimensions=[],
    )
    return lax.ragged_dot_general(
        x1, x2, gs, dn, preferred_element_type=jnp.float32
    )


def _ragged_ess(x, block_expert, num_experts):
    blk = x.shape[0] // block_expert.shape[0]
    row_expert = jnp.repeat(block_expert, blk)
    return jax.ops.segment_sum(
        x.astype(jnp.float32),
        row_expert,
        num_segments=num_experts,
        indices_are_sorted=True,
    )


# ---------------------------------------------------------------------------
# impl dispatch (no autodiff)
# ---------------------------------------------------------------------------

def _esmm_any(impl, transpose_rhs, xs, w, b, block_expert, padded_counts,
              w_scales=None):
    if impl == "pallas":
        blk = xs.shape[0] // block_expert.shape[0]
        return esmm_pallas(
            xs, w, b, block_expert, w_scales=w_scales,
            transpose_rhs=transpose_rhs, bm=blk,
        )
    if impl == "blocked":
        return _blocked_esmm(xs, w, b, block_expert, transpose_rhs,
                             w_scales=w_scales)
    if w_scales is not None:
        # ragged/ref: semantics references — dequantize up front.
        w = dequantize_blockwise(w, w_scales, dtype=xs.dtype)
    if impl == "ragged":
        return _ragged_esmm(xs, w, b, block_expert, padded_counts, transpose_rhs)
    if impl == "ref":
        return _ref.esmm(xs, w, b, block_expert, transpose_rhs=transpose_rhs)
    raise ValueError(f"unknown impl {impl!r}")


def _esfk_any(impl, fused, x1, x2, block_expert, padded_counts, need_db):
    """(dW, db) with db=None when need_db is False."""
    e = padded_counts.shape[0]
    if impl == "pallas":
        blk = x1.shape[0] // block_expert.shape[0]
        if fused and need_db:
            dw, db = esfk_pallas(x1, x2, block_expert, padded_counts, bm=blk)
            return dw, db
        dw = estmm_pallas(x1, x2, block_expert, padded_counts, bm=blk)
        db = (
            ess_pallas(x2, block_expert, padded_counts, bm=blk)
            if need_db
            else None
        )
        return dw, db
    if impl == "ragged":
        if _HAS_RAGGED_DN:
            dw = _ragged_estmm(x1, x2, padded_counts)
        else:
            # grouped-transposed ragged dot is inexpressible with plain
            # lax.ragged_dot; the blocked form computes the same dW
            dw = _blocked_estmm(x1, x2, block_expert, e)
        db = _ragged_ess(x2, block_expert, e) if need_db else None
        return dw, db
    if impl == "blocked":
        dw = _blocked_estmm(x1, x2, block_expert, e)
        db = _ragged_ess(x2, block_expert, e) if need_db else None
        return dw, db
    if impl == "ref":
        dw = _ref.estmm(x1, x2, block_expert, e)
        db = _ref.ess(x2, block_expert, e) if need_db else None
        return dw, db
    raise ValueError(f"unknown impl {impl!r}")


# ---------------------------------------------------------------------------
# differentiable esmm (paper Table 5 wiring)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _esmm(impl, transpose_rhs, fused, xs, w, b, block_expert, padded_counts):
    return _esmm_any(impl, transpose_rhs, xs, w, b, block_expert, padded_counts)


def _esmm_fwd(impl, transpose_rhs, fused, xs, w, b, block_expert, padded_counts):
    y = _esmm_any(impl, transpose_rhs, xs, w, b, block_expert, padded_counts)
    return y, (xs, w, b is not None, block_expert, padded_counts)


def _esmm_bwd(impl, transpose_rhs, fused, res, dy):
    xs, w, has_b, block_expert, padded_counts = res
    # dX: ESMM with the opposite weight orientation (paper rows 6/10).
    dxs = _esmm_any(
        impl, not transpose_rhs, dy, w, None, block_expert, padded_counts
    )
    # dW (ESTMM) + db (ESS), fused as ESFK (paper rows 4/5/8/9).
    if transpose_rhs:
        dw, db = _esfk_any(
            impl, fused, dy, xs, block_expert, padded_counts, has_b
        )
    else:
        dw, db = _esfk_any(
            impl, fused, xs, dy, block_expert, padded_counts, has_b
        )
    dw = dw.astype(w.dtype)
    if db is not None:
        db = db.astype(dy.dtype)
    return (dxs, dw, db if has_b else None, None, None)


_esmm.defvjp(_esmm_fwd, _esmm_bwd)


# Quantized-weight ESMM (DESIGN.md §8): the int8/fp8 payload + block scales
# go through the fused-dequant kernels in forward; backward flows dX (and
# db) against the dequantized weights. The payload itself is frozen — no
# dW: training-side quantization is the STE ``quant.core.fake_quant`` on
# the full-precision master weights, not gradients into int8.

def _zero_cot(x):
    """Cotangent for a frozen operand: zeros for inexact dtypes (fp8
    scales/payloads), None for integer payloads (jax float0)."""
    return jnp.zeros_like(x) if jnp.issubdtype(x.dtype, jnp.inexact) else None


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _esmm_q(impl, transpose_rhs, xs, w, w_scales, b, block_expert,
            padded_counts):
    return _esmm_any(impl, transpose_rhs, xs, w, b, block_expert,
                     padded_counts, w_scales=w_scales)


def _esmm_q_fwd(impl, transpose_rhs, xs, w, w_scales, b, block_expert,
                padded_counts):
    y = _esmm_any(impl, transpose_rhs, xs, w, b, block_expert,
                  padded_counts, w_scales=w_scales)
    return y, (xs, w, w_scales, b is not None, block_expert, padded_counts)


def _esmm_q_bwd(impl, transpose_rhs, res, dy):
    xs, w, w_scales, has_b, block_expert, padded_counts = res
    w_dq = dequantize_blockwise(w, w_scales, dtype=xs.dtype)
    dxs = _esmm_any(
        impl, not transpose_rhs, dy, w_dq, None, block_expert, padded_counts
    )
    db = None
    if has_b:
        db = ess(dy, block_expert, padded_counts, impl=impl).astype(dy.dtype)
    return (dxs, _zero_cot(w), jnp.zeros_like(w_scales),
            db if has_b else None, None, None)


_esmm_q.defvjp(_esmm_q_fwd, _esmm_q_bwd)


def esmm(
    xs: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    block_expert: jax.Array,
    padded_counts: jax.Array,
    *,
    w_scales: Optional[jax.Array] = None,
    transpose_rhs: bool = False,
    impl: Optional[str] = None,
    fused: Optional[bool] = None,
) -> jax.Array:
    """Differentiable expert-specific matmul on the sorted layout.

    xs: (Np, K); w: (E, K, N) — or (E, N, K) with transpose_rhs; b: (E, N)
    or None; block_expert/padded_counts from ``core.reindex.build_reindex``.
    ``w_scales``: block-wise scales of a quantized ``w`` (DESIGN.md §8) —
    dequant fuses into the kernels; the payload is frozen (dX/db only).
    """
    impl = impl or get_default_impl()
    fused = _FUSED_BACKWARD if fused is None else fused
    if w_scales is not None:
        return _esmm_q(impl, transpose_rhs, xs, w, w_scales, b,
                       block_expert, padded_counts)
    return _esmm(impl, transpose_rhs, fused, xs, w, b, block_expert, padded_counts)


# Non-differentiable public wrappers (tests / ablation benchmarks).

def ess(x, block_expert, padded_counts, *, impl=None):
    impl = impl or get_default_impl()
    e = padded_counts.shape[0]
    if impl == "pallas":
        blk = x.shape[0] // block_expert.shape[0]
        return ess_pallas(x, block_expert, padded_counts, bm=blk)
    if impl in ("ragged", "blocked"):
        return _ragged_ess(x, block_expert, e)
    return _ref.ess(x, block_expert, e)


def estmm(x1, x2, block_expert, padded_counts, *, impl=None):
    impl = impl or get_default_impl()
    e = padded_counts.shape[0]
    if impl == "pallas":
        blk = x1.shape[0] // block_expert.shape[0]
        return estmm_pallas(x1, x2, block_expert, padded_counts, bm=blk)
    if impl == "ragged":
        return _ragged_estmm(x1, x2, padded_counts)
    if impl == "blocked":
        return _blocked_estmm(x1, x2, block_expert, e)
    return _ref.estmm(x1, x2, block_expert, e)


def esfk(x1, x2, block_expert, padded_counts, *, impl=None, fused=True):
    impl = impl or get_default_impl()
    return _esfk_any(impl, fused, x1, x2, block_expert, padded_counts, True)


# ---------------------------------------------------------------------------
# fused expert FFN (DESIGN.md §5): gather -> up/gate -> act -> down -> gate
#
# One differentiable op per expert-body type. Forward impls:
#   pallas  — kernels.esffn megakernel (the (Np, F) hidden never hits HBM).
#   blocked — one fused XLA region: rows gathered straight from the unsorted
#             x, expert weight tiles formed by exact one-hot contraction.
#   ragged/ref — staged composition inside the op (semantics reference).
# Backward (all impls) is flash-style: residuals are xs-level only (x +
# row maps + weights); the hidden is recomputed and grads flow through the
# same ESMM/ESFK kernels as the unfused path (paper Table 5 wiring).
# ---------------------------------------------------------------------------

def _gather_rows(x, row_token):
    """(Np, D) sorted rows from unsorted x; sentinel rows (== N) are zero."""
    from repro.core.reindex import gather_rows

    return gather_rows(x, row_token)


def _blocked_wtiles(onehot, w):
    """Per-block expert tiles w[block_expert] as a one-hot contraction.

    Exact (each one-hot row has a single 1; adding exact zeros changes
    nothing), but XLA lowers it as a multithreaded matmul instead of the
    memory-bound gather — measurably faster on CPU, and only available to
    the fused op because it owns every stage of the pipeline.
    """
    return jnp.einsum("ge,e...->g...", onehot, w,
                      preferred_element_type=w.dtype)


def _blocked_esffn_glu(x, row_token, row_gate, block_expert, wg, wu, wd,
                       act_fn, scales=None):
    np_rows = row_token.shape[0]
    nblk = block_expert.shape[0]
    blk = np_rows // nblk
    xb = _gather_rows(x, row_token).reshape(nblk, blk, -1)
    if scales is not None:
        # int8/fp8 payloads: gather the quantized expert tiles per block
        # (the quantized bytes move) and dequantize block-wise just before
        # the contraction — the XLA analogue of the kernel's VMEM dequant.
        sg, su, sd = scales
        tiles = [
            dequantize_blockwise(w[block_expert], s[block_expert],
                                 dtype=x.dtype)
            for w, s in ((wg, sg), (wu, su), (wd, sd))
        ]
    else:
        onehot = jax.nn.one_hot(block_expert, wg.shape[0], dtype=wg.dtype)
        tiles = [_blocked_wtiles(onehot, w) for w in (wg, wu, wd)]
    g = jnp.einsum("gbd,gdf->gbf", xb, tiles[0],
                   preferred_element_type=x.dtype)
    u = jnp.einsum("gbd,gdf->gbf", xb, tiles[1],
                   preferred_element_type=x.dtype)
    h = act_fn(g) * u
    y = jnp.einsum("gbf,gfd->gbd", h, tiles[2],
                   preferred_element_type=x.dtype)
    y = y * row_gate.reshape(nblk, blk, 1).astype(y.dtype)
    return y.reshape(np_rows, -1)


def _blocked_esffn_mlp(x, row_token, row_gate, block_expert, w1, b1, w2, b2,
                       act_fn, scales=None):
    np_rows = row_token.shape[0]
    nblk = block_expert.shape[0]
    blk = np_rows // nblk
    xb = _gather_rows(x, row_token).reshape(nblk, blk, -1)
    onehot = jax.nn.one_hot(block_expert, w1.shape[0],
                            dtype=b1.dtype if b1 is not None else w1.dtype)
    if scales is not None:
        s1, s2 = scales
        t1 = dequantize_blockwise(w1[block_expert], s1[block_expert],
                                  dtype=x.dtype)
        t2 = dequantize_blockwise(w2[block_expert], s2[block_expert],
                                  dtype=x.dtype)
    else:
        t1 = _blocked_wtiles(onehot.astype(w1.dtype), w1)
        t2 = _blocked_wtiles(onehot.astype(w2.dtype), w2)
    z = jnp.einsum("gbd,gdf->gbf", xb, t1, preferred_element_type=x.dtype)
    if b1 is not None:
        z = z + _blocked_wtiles(onehot.astype(b1.dtype), b1)[:, None].astype(
            z.dtype)
    h = act_fn(z)
    y = jnp.einsum("gbf,gfd->gbd", h, t2, preferred_element_type=x.dtype)
    if b2 is not None:
        y = y + _blocked_wtiles(onehot.astype(b2.dtype), b2)[:, None].astype(
            y.dtype)
    y = y * row_gate.reshape(nblk, blk, 1).astype(y.dtype)
    return y.reshape(np_rows, -1)


def _staged_esffn(impl, act_fn, x, row_token, row_gate, block_expert,
                  padded_counts, glu, ws, scales=None):
    """Per-stage composition inside the fused op (ragged / ref impls)."""
    xs = _gather_rows(x, row_token)
    if glu:
        wg, wu, wd = ws
        sg, su, sd = scales if scales is not None else (None, None, None)
        g = _esmm_any(impl, False, xs, wg, None, block_expert, padded_counts,
                      w_scales=sg)
        u = _esmm_any(impl, False, xs, wu, None, block_expert, padded_counts,
                      w_scales=su)
        h = act_fn(g) * u
        ys = _esmm_any(impl, False, h, wd, None, block_expert, padded_counts,
                       w_scales=sd)
    else:
        w1, b1, w2, b2 = ws
        s1, s2 = scales if scales is not None else (None, None)
        z = _esmm_any(impl, False, xs, w1, b1, block_expert, padded_counts,
                      w_scales=s1)
        h = act_fn(z)
        ys = _esmm_any(impl, False, h, w2, b2, block_expert, padded_counts,
                       w_scales=s2)
    return ys * row_gate[:, None].astype(ys.dtype)


def _esffn_fwd_any(impl, act, glu, x, row_token, row_gate, block_expert,
                   padded_counts, ws, scales=None):
    act_fn = ACTIVATIONS[act]
    if impl == "pallas":
        if glu:
            return esffn_glu_pallas(
                x, row_token, row_gate, block_expert, *ws,
                w_scales=scales, act=act,
            )
        return esffn_mlp_pallas(
            x, row_token, row_gate, block_expert, *ws,
            w_scales=scales, act=act,
        )
    if impl == "blocked":
        if glu:
            return _blocked_esffn_glu(
                x, row_token, row_gate, block_expert, *ws, act_fn=act_fn,
                scales=scales,
            )
        return _blocked_esffn_mlp(
            x, row_token, row_gate, block_expert, *ws, act_fn=act_fn,
            scales=scales,
        )
    if impl in ("ragged", "ref"):
        return _staged_esffn(
            impl, act_fn, x, row_token, row_gate, block_expert,
            padded_counts, glu, ws, scales=scales,
        )
    raise ValueError(f"unknown impl {impl!r}")


def _scatter_dx(x, row_token, dxs):
    """dX: scatter the sorted-row grads back to token order (pads dropped)."""
    return jnp.zeros_like(x).at[row_token].add(
        dxs.astype(x.dtype), mode="drop"
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _esffn_glu(impl, act, x, row_token, row_gate, block_expert,
               padded_counts, wg, wu, wd):
    return _esffn_fwd_any(
        impl, act, True, x, row_token, row_gate, block_expert,
        padded_counts, (wg, wu, wd),
    )


def _esffn_glu_fwd(impl, act, x, row_token, row_gate, block_expert,
                   padded_counts, wg, wu, wd):
    y = _esffn_fwd_any(
        impl, act, True, x, row_token, row_gate, block_expert,
        padded_counts, (wg, wu, wd),
    )
    # xs-level residuals only: no (Np, F) hidden is saved (flash-style).
    return y, (x, row_token, row_gate, block_expert, padded_counts,
               wg, wu, wd)


def _esffn_glu_bwd(impl, act, res, dys_w):
    """Flash-style recompute backward against DENSE weights; the quantized
    op's backward dequantizes first and reuses this body verbatim."""
    x, row_token, row_gate, block_expert, padded_counts, wg, wu, wd = res
    act_fn = ACTIVATIONS[act]
    fused = _FUSED_BACKWARD
    # Tile-wise recompute of the hidden from the xs-level residuals.
    xs = _gather_rows(x, row_token)
    g = _esmm_any(impl, False, xs, wg, None, block_expert, padded_counts)
    u = _esmm_any(impl, False, xs, wu, None, block_expert, padded_counts)
    h, h_vjp = jax.vjp(lambda g_, u_: act_fn(g_) * u_, g, u)
    # t = dys_w @ Wd[e]^T serves both dh (scaled by gate) and d_gate
    # (contracted against h): ys itself is never rebuilt.
    t = _esmm_any(impl, True, dys_w, wd, None, block_expert, padded_counts)
    d_gate = jnp.sum(
        t.astype(jnp.float32) * h.astype(jnp.float32), axis=-1
    )
    gate = row_gate[:, None].astype(dys_w.dtype)
    dys = dys_w * gate
    dg, du = h_vjp((t * gate).astype(h.dtype))
    dwd, _ = _esfk_any(impl, fused, h, dys, block_expert, padded_counts, False)
    dwg, _ = _esfk_any(impl, fused, xs, dg, block_expert, padded_counts, False)
    dwu, _ = _esfk_any(impl, fused, xs, du, block_expert, padded_counts, False)
    dxs = (
        _esmm_any(impl, True, dg, wg, None, block_expert, padded_counts)
        + _esmm_any(impl, True, du, wu, None, block_expert, padded_counts)
    )
    return (_scatter_dx(x, row_token, dxs), None,
            d_gate.astype(row_gate.dtype), None, None,
            dwg.astype(wg.dtype), dwu.astype(wu.dtype), dwd.astype(wd.dtype))


_esffn_glu.defvjp(_esffn_glu_fwd, _esffn_glu_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _esffn_mlp(impl, act, x, row_token, row_gate, block_expert,
               padded_counts, w1, b1, w2, b2):
    return _esffn_fwd_any(
        impl, act, False, x, row_token, row_gate, block_expert,
        padded_counts, (w1, b1, w2, b2),
    )


def _esffn_mlp_fwd(impl, act, x, row_token, row_gate, block_expert,
                   padded_counts, w1, b1, w2, b2):
    y = _esffn_fwd_any(
        impl, act, False, x, row_token, row_gate, block_expert,
        padded_counts, (w1, b1, w2, b2),
    )
    return y, (x, row_token, row_gate, block_expert, padded_counts,
               w1, b1, w2, b2)


def _esffn_mlp_bwd(impl, act, res, dys_w):
    x, row_token, row_gate, block_expert, padded_counts, w1, b1, w2, b2 = res
    act_fn = ACTIVATIONS[act]
    fused = _FUSED_BACKWARD
    xs = _gather_rows(x, row_token)
    z = _esmm_any(impl, False, xs, w1, b1, block_expert, padded_counts)
    h, act_vjp = jax.vjp(act_fn, z)
    t = _esmm_any(impl, True, dys_w, w2, None, block_expert, padded_counts)
    # d_gate[r] = dys_w[r]·ys[r] with ys = h@W2 + b2 — split so ys is never
    # rebuilt: the h@W2 term contracts t against h, the b2 term is direct.
    d_gate = jnp.sum(
        t.astype(jnp.float32) * h.astype(jnp.float32), axis=-1
    )
    if b2 is not None:
        blk = xs.shape[0] // block_expert.shape[0]
        b2_rows = b2[jnp.repeat(block_expert, blk)]
        d_gate = d_gate + jnp.sum(
            dys_w.astype(jnp.float32) * b2_rows.astype(jnp.float32), axis=-1
        )
    gate = row_gate[:, None].astype(dys_w.dtype)
    dys = dys_w * gate
    (dz,) = act_vjp((t * gate).astype(h.dtype))
    dw2, db2 = _esfk_any(
        impl, fused, h, dys, block_expert, padded_counts, b2 is not None
    )
    dw1, db1 = _esfk_any(
        impl, fused, xs, dz, block_expert, padded_counts, b1 is not None
    )
    dxs = _esmm_any(impl, True, dz, w1, None, block_expert, padded_counts)
    return (_scatter_dx(x, row_token, dxs), None,
            d_gate.astype(row_gate.dtype), None, None,
            dw1.astype(w1.dtype),
            db1.astype(b1.dtype) if b1 is not None else None,
            dw2.astype(w2.dtype),
            db2.astype(b2.dtype) if b2 is not None else None)


_esffn_mlp.defvjp(_esffn_mlp_fwd, _esffn_mlp_bwd)


# ---------------------------------------------------------------------------
# quantized fused expert FFN (DESIGN.md §8): int8/fp8 payloads + block-wise
# scales flow through the same fused forward (VMEM dequant in the Pallas
# kernel, per-block dequant in the blocked XLA region). Backward recomputes
# the hidden through the QUANTIZED esmm ops (the quantized bytes are what
# move there too) and flows dX / d_gate / bias grads; the payloads and
# scales are frozen — training quantization is the STE fake_quant on the
# full-precision masters, not gradients into int8.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _esffn_glu_q(impl, act, x, row_token, row_gate, block_expert,
                 padded_counts, wg, sg, wu, su, wd, sd):
    return _esffn_fwd_any(
        impl, act, True, x, row_token, row_gate, block_expert,
        padded_counts, (wg, wu, wd), scales=(sg, su, sd),
    )


def _esffn_glu_q_fwd(impl, act, x, row_token, row_gate, block_expert,
                     padded_counts, wg, sg, wu, su, wd, sd):
    y = _esffn_fwd_any(
        impl, act, True, x, row_token, row_gate, block_expert,
        padded_counts, (wg, wu, wd), scales=(sg, su, sd),
    )
    return y, (x, row_token, row_gate, block_expert, padded_counts,
               wg, sg, wu, su, wd, sd)


def _esffn_glu_q_bwd(impl, act, res, dys_w):
    x, row_token, row_gate, block_expert, padded_counts, \
        wg, sg, wu, su, wd, sd = res
    act_fn = ACTIVATIONS[act]
    xs = _gather_rows(x, row_token)
    g = _esmm_any(impl, False, xs, wg, None, block_expert, padded_counts,
                  w_scales=sg)
    u = _esmm_any(impl, False, xs, wu, None, block_expert, padded_counts,
                  w_scales=su)
    h, h_vjp = jax.vjp(lambda g_, u_: act_fn(g_) * u_, g, u)
    t = _esmm_any(impl, True, dys_w, wd, None, block_expert, padded_counts,
                  w_scales=sd)
    d_gate = jnp.sum(t.astype(jnp.float32) * h.astype(jnp.float32), axis=-1)
    gate = row_gate[:, None].astype(dys_w.dtype)
    dg, du = h_vjp((t * gate).astype(h.dtype))
    dxs = (
        _esmm_any(impl, True, dg, wg, None, block_expert, padded_counts,
                  w_scales=sg)
        + _esmm_any(impl, True, du, wu, None, block_expert, padded_counts,
                    w_scales=su)
    )
    return (_scatter_dx(x, row_token, dxs), None,
            d_gate.astype(row_gate.dtype), None, None,
            _zero_cot(wg), jnp.zeros_like(sg),
            _zero_cot(wu), jnp.zeros_like(su),
            _zero_cot(wd), jnp.zeros_like(sd))


_esffn_glu_q.defvjp(_esffn_glu_q_fwd, _esffn_glu_q_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _esffn_mlp_q(impl, act, x, row_token, row_gate, block_expert,
                 padded_counts, w1, s1, b1, w2, s2, b2):
    return _esffn_fwd_any(
        impl, act, False, x, row_token, row_gate, block_expert,
        padded_counts, (w1, b1, w2, b2), scales=(s1, s2),
    )


def _esffn_mlp_q_fwd(impl, act, x, row_token, row_gate, block_expert,
                     padded_counts, w1, s1, b1, w2, s2, b2):
    y = _esffn_fwd_any(
        impl, act, False, x, row_token, row_gate, block_expert,
        padded_counts, (w1, b1, w2, b2), scales=(s1, s2),
    )
    return y, (x, row_token, row_gate, block_expert, padded_counts,
               w1, s1, b1, w2, s2, b2)


def _esffn_mlp_q_bwd(impl, act, res, dys_w):
    x, row_token, row_gate, block_expert, padded_counts, \
        w1, s1, b1, w2, s2, b2 = res
    act_fn = ACTIVATIONS[act]
    xs = _gather_rows(x, row_token)
    z = _esmm_any(impl, False, xs, w1, b1, block_expert, padded_counts,
                  w_scales=s1)
    h, act_vjp = jax.vjp(act_fn, z)
    t = _esmm_any(impl, True, dys_w, w2, None, block_expert, padded_counts,
                  w_scales=s2)
    d_gate = jnp.sum(t.astype(jnp.float32) * h.astype(jnp.float32), axis=-1)
    if b2 is not None:
        blk = xs.shape[0] // block_expert.shape[0]
        b2_rows = b2[jnp.repeat(block_expert, blk)]
        d_gate = d_gate + jnp.sum(
            dys_w.astype(jnp.float32) * b2_rows.astype(jnp.float32), axis=-1
        )
    gate = row_gate[:, None].astype(dys_w.dtype)
    dys = dys_w * gate
    (dz,) = act_vjp((t * gate).astype(h.dtype))
    # Biases stay full precision, so their grads flow normally.
    db1 = (ess(dz, block_expert, padded_counts, impl=impl).astype(b1.dtype)
           if b1 is not None else None)
    db2 = (ess(dys, block_expert, padded_counts, impl=impl).astype(b2.dtype)
           if b2 is not None else None)
    dxs = _esmm_any(impl, True, dz, w1, None, block_expert, padded_counts,
                    w_scales=s1)
    return (_scatter_dx(x, row_token, dxs), None,
            d_gate.astype(row_gate.dtype), None, None,
            _zero_cot(w1), jnp.zeros_like(s1), db1,
            _zero_cot(w2), jnp.zeros_like(s2), db2)


_esffn_mlp_q.defvjp(_esffn_mlp_q_fwd, _esffn_mlp_q_bwd)


def esffn_glu(
    x: jax.Array,
    row_token: jax.Array,
    row_gate: jax.Array,
    block_expert: jax.Array,
    padded_counts: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    scales=None,
    act: str = "silu",
    impl: Optional[str] = None,
) -> jax.Array:
    """Differentiable fused GLU expert FFN over the sorted layout.

    x: (N, D) UNSORTED tokens; row maps from ``core.reindex.build_reindex``.
    Returns the gate-weighted sorted output (Np, D) — combine it with
    ``core.reindex.scatter_rows``. ``scales``: (sg, su, sd) block-wise
    scales of quantized weights (DESIGN.md §8); dequant fuses into the
    kernels and the payloads are frozen (dX/d_gate grads only).
    """
    impl = impl or get_default_impl()
    if scales is not None:
        sg, su, sd = scales
        return _esffn_glu_q(impl, act, x, row_token, row_gate, block_expert,
                            padded_counts, w_gate, sg, w_up, su, w_down, sd)
    return _esffn_glu(impl, act, x, row_token, row_gate, block_expert,
                      padded_counts, w_gate, w_up, w_down)


def esffn_mlp(
    x: jax.Array,
    row_token: jax.Array,
    row_gate: jax.Array,
    block_expert: jax.Array,
    padded_counts: jax.Array,
    w1: jax.Array,
    b1: Optional[jax.Array],
    w2: jax.Array,
    b2: Optional[jax.Array],
    *,
    scales=None,
    act: str = "gelu",
    impl: Optional[str] = None,
) -> jax.Array:
    """Differentiable fused 2-MLP expert FFN; see ``esffn_glu``.
    ``scales``: (s1, s2) for quantized w1/w2 (biases full precision)."""
    impl = impl or get_default_impl()
    if scales is not None:
        s1, s2 = scales
        return _esffn_mlp_q(impl, act, x, row_token, row_gate, block_expert,
                            padded_counts, w1, s1, b1, w2, s2, b2)
    return _esffn_mlp(impl, act, x, row_token, row_gate, block_expert,
                      padded_counts, w1, b1, w2, b2)
