"""Public expert-specific ops: impl dispatch + custom autodiff.

Three interchangeable implementations of the same zero-redundancy semantics
over the expert-sorted layout (see ``core.reindex``):

  - ``pallas`` — the paper-faithful TPU kernels (esmm/esfk/ess/estmm);
    interpret mode on CPU.
  - ``ragged`` — ``lax.ragged_dot(_general)``: XLA's grouped-GeMM lowering.
    Used for the multi-pod dry-run/compile path and CPU benchmarks (a Pallas
    interpret-mode kernel would unroll its grid into the HLO).
  - ``ref``    — pure-jnp one-hot oracle (tests only).

The backward pass is wired by ``custom_vjp`` exactly as the paper's Table 5:
dX via ESMM with transposed weights, (dW, db) via the fused ESFK (or the
unfused ESTMM + ESS pair when ``fused=False``, paper Fig. 12 ablation).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.common import on_tpu
from repro.kernels import ref as _ref
from repro.kernels.esmm import esmm_pallas
from repro.kernels.esfk import esfk_pallas
from repro.kernels.ess import ess_pallas
from repro.kernels.estmm import estmm_pallas

_DEFAULT_IMPL: Optional[str] = None
_FUSED_BACKWARD: bool = True


def set_default_impl(impl: Optional[str]) -> None:
    """Set the process-wide default implementation (None = auto)."""
    global _DEFAULT_IMPL
    assert impl in (None, "pallas", "ragged", "blocked", "ref")
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    if _DEFAULT_IMPL is not None:
        return _DEFAULT_IMPL
    return "pallas" if on_tpu() else "blocked"


# ---------------------------------------------------------------------------
# blocked (batched block-diagonal einsum) implementation
#
# Exploits the sorted layout's invariant directly in XLA: every BLK-row
# block uses ONE expert, so the grouped matmul is a plain batched matmul
# against per-block gathered weight tiles. Compiled FLOPs equal the
# zero-redundancy count (Np * D1 * D2 * 2) exactly — unlike
# lax.ragged_dot, whose CPU lowering computes every group densely (E x
# redundancy). This is both the dry-run compile path and the fastest
# CPU execution path; on TPU the Pallas kernels replace it (the per-block
# weight gather becomes the scalar-prefetched DMA).
# ---------------------------------------------------------------------------

def _blocked_esmm(xs, w, b, block_expert, transpose_rhs):
    np_rows = xs.shape[0]
    nblk = block_expert.shape[0]
    blk = np_rows // nblk
    xb = xs.reshape(nblk, blk, -1)
    wb = w[block_expert]  # (nblk, D1, D2) or (nblk, D2, D1)
    if transpose_rhs:
        y = jnp.einsum(
            "gbk,gnk->gbn", xb, wb, preferred_element_type=xs.dtype
        )
    else:
        y = jnp.einsum(
            "gbk,gkn->gbn", xb, wb, preferred_element_type=xs.dtype
        )
    if b is not None:
        y = y + b[block_expert][:, None].astype(y.dtype)
    return y.reshape(np_rows, -1)


def _blocked_estmm(x1, x2, block_expert, num_experts):
    np_rows = x1.shape[0]
    nblk = block_expert.shape[0]
    blk = np_rows // nblk
    per_block = jnp.einsum(
        "gbd,gbf->gdf",
        x1.reshape(nblk, blk, -1),
        x2.reshape(nblk, blk, -1),
        preferred_element_type=jnp.float32,
    )
    out = jnp.zeros((num_experts,) + per_block.shape[1:], jnp.float32)
    return out.at[block_expert].add(per_block)


def set_fused_backward(fused: bool) -> None:
    """Toggle the ESFK fusion (paper Fig. 12 'fused kernel' ablation)."""
    global _FUSED_BACKWARD
    _FUSED_BACKWARD = fused


# ---------------------------------------------------------------------------
# ragged (lax.ragged_dot) implementation
# ---------------------------------------------------------------------------

def _full_group_sizes(padded_counts: jax.Array, np_rows) -> jax.Array:
    """Group sizes covering *all* rows: the tail (static over-allocation past
    the last group) is absorbed into the final group so no row is left with
    unspecified output. Tail rows are all-zero sentinels, so this is exact."""
    tail = np_rows - jnp.sum(padded_counts)
    return padded_counts.at[-1].add(tail.astype(padded_counts.dtype))


#: jax 0.4.x only ships the fixed-layout lax.ragged_dot; the general
#: dimension-numbers form arrived later. Fall back where possible.
_HAS_RAGGED_DN = hasattr(lax, "RaggedDotDimensionNumbers")


def _ragged_esmm(xs, w, b, block_expert, padded_counts, transpose_rhs):
    np_rows = xs.shape[0]
    gs = _full_group_sizes(padded_counts, np_rows)
    if transpose_rhs:
        if _HAS_RAGGED_DN:
            dn = lax.RaggedDotDimensionNumbers(
                dot_dimension_numbers=(((1,), (2,)), ((), ())),
                lhs_ragged_dimensions=[0],
                rhs_group_dimensions=[0],
            )
            y = lax.ragged_dot_general(
                xs, w, gs, dn, preferred_element_type=xs.dtype
            )
        else:  # materialise the transpose; XLA folds it into the dot
            y = lax.ragged_dot(
                xs, jnp.swapaxes(w, 1, 2), gs, preferred_element_type=xs.dtype
            )
    else:
        y = lax.ragged_dot(xs, w, gs, preferred_element_type=xs.dtype)
    if b is not None:
        nblk = block_expert.shape[0]
        blk = np_rows // nblk
        y = (
            y.reshape(nblk, blk, -1) + b[block_expert][:, None].astype(y.dtype)
        ).reshape(np_rows, -1)
    return y


def _ragged_estmm(x1, x2, padded_counts):
    gs = _full_group_sizes(padded_counts, x1.shape[0])
    dn = lax.RaggedDotDimensionNumbers(
        dot_dimension_numbers=(((0,), (0,)), ((), ())),
        lhs_ragged_dimensions=[0],
        rhs_group_dimensions=[],
    )
    return lax.ragged_dot_general(
        x1, x2, gs, dn, preferred_element_type=jnp.float32
    )


def _ragged_ess(x, block_expert, num_experts):
    blk = x.shape[0] // block_expert.shape[0]
    row_expert = jnp.repeat(block_expert, blk)
    return jax.ops.segment_sum(
        x.astype(jnp.float32),
        row_expert,
        num_segments=num_experts,
        indices_are_sorted=True,
    )


# ---------------------------------------------------------------------------
# impl dispatch (no autodiff)
# ---------------------------------------------------------------------------

def _esmm_any(impl, transpose_rhs, xs, w, b, block_expert, padded_counts):
    if impl == "pallas":
        blk = xs.shape[0] // block_expert.shape[0]
        return esmm_pallas(
            xs, w, b, block_expert, transpose_rhs=transpose_rhs, bm=blk
        )
    if impl == "ragged":
        return _ragged_esmm(xs, w, b, block_expert, padded_counts, transpose_rhs)
    if impl == "blocked":
        return _blocked_esmm(xs, w, b, block_expert, transpose_rhs)
    if impl == "ref":
        return _ref.esmm(xs, w, b, block_expert, transpose_rhs=transpose_rhs)
    raise ValueError(f"unknown impl {impl!r}")


def _esfk_any(impl, fused, x1, x2, block_expert, padded_counts, need_db):
    """(dW, db) with db=None when need_db is False."""
    e = padded_counts.shape[0]
    if impl == "pallas":
        blk = x1.shape[0] // block_expert.shape[0]
        if fused and need_db:
            dw, db = esfk_pallas(x1, x2, block_expert, padded_counts, bm=blk)
            return dw, db
        dw = estmm_pallas(x1, x2, block_expert, padded_counts, bm=blk)
        db = (
            ess_pallas(x2, block_expert, padded_counts, bm=blk)
            if need_db
            else None
        )
        return dw, db
    if impl == "ragged":
        if _HAS_RAGGED_DN:
            dw = _ragged_estmm(x1, x2, padded_counts)
        else:
            # grouped-transposed ragged dot is inexpressible with plain
            # lax.ragged_dot; the blocked form computes the same dW
            dw = _blocked_estmm(x1, x2, block_expert, e)
        db = _ragged_ess(x2, block_expert, e) if need_db else None
        return dw, db
    if impl == "blocked":
        dw = _blocked_estmm(x1, x2, block_expert, e)
        db = _ragged_ess(x2, block_expert, e) if need_db else None
        return dw, db
    if impl == "ref":
        dw = _ref.estmm(x1, x2, block_expert, e)
        db = _ref.ess(x2, block_expert, e) if need_db else None
        return dw, db
    raise ValueError(f"unknown impl {impl!r}")


# ---------------------------------------------------------------------------
# differentiable esmm (paper Table 5 wiring)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _esmm(impl, transpose_rhs, fused, xs, w, b, block_expert, padded_counts):
    return _esmm_any(impl, transpose_rhs, xs, w, b, block_expert, padded_counts)


def _esmm_fwd(impl, transpose_rhs, fused, xs, w, b, block_expert, padded_counts):
    y = _esmm_any(impl, transpose_rhs, xs, w, b, block_expert, padded_counts)
    return y, (xs, w, b is not None, block_expert, padded_counts)


def _esmm_bwd(impl, transpose_rhs, fused, res, dy):
    xs, w, has_b, block_expert, padded_counts = res
    # dX: ESMM with the opposite weight orientation (paper rows 6/10).
    dxs = _esmm_any(
        impl, not transpose_rhs, dy, w, None, block_expert, padded_counts
    )
    # dW (ESTMM) + db (ESS), fused as ESFK (paper rows 4/5/8/9).
    if transpose_rhs:
        dw, db = _esfk_any(
            impl, fused, dy, xs, block_expert, padded_counts, has_b
        )
    else:
        dw, db = _esfk_any(
            impl, fused, xs, dy, block_expert, padded_counts, has_b
        )
    dw = dw.astype(w.dtype)
    if db is not None:
        db = db.astype(dy.dtype)
    return (dxs, dw, db if has_b else None, None, None)


_esmm.defvjp(_esmm_fwd, _esmm_bwd)


def esmm(
    xs: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    block_expert: jax.Array,
    padded_counts: jax.Array,
    *,
    transpose_rhs: bool = False,
    impl: Optional[str] = None,
    fused: Optional[bool] = None,
) -> jax.Array:
    """Differentiable expert-specific matmul on the sorted layout.

    xs: (Np, K); w: (E, K, N) — or (E, N, K) with transpose_rhs; b: (E, N)
    or None; block_expert/padded_counts from ``core.reindex.build_reindex``.
    """
    impl = impl or get_default_impl()
    fused = _FUSED_BACKWARD if fused is None else fused
    return _esmm(impl, transpose_rhs, fused, xs, w, b, block_expert, padded_counts)


# Non-differentiable public wrappers (tests / ablation benchmarks).

def ess(x, block_expert, padded_counts, *, impl=None):
    impl = impl or get_default_impl()
    e = padded_counts.shape[0]
    if impl == "pallas":
        blk = x.shape[0] // block_expert.shape[0]
        return ess_pallas(x, block_expert, padded_counts, bm=blk)
    if impl in ("ragged", "blocked"):
        return _ragged_ess(x, block_expert, e)
    return _ref.ess(x, block_expert, e)


def estmm(x1, x2, block_expert, padded_counts, *, impl=None):
    impl = impl or get_default_impl()
    e = padded_counts.shape[0]
    if impl == "pallas":
        blk = x1.shape[0] // block_expert.shape[0]
        return estmm_pallas(x1, x2, block_expert, padded_counts, bm=blk)
    if impl == "ragged":
        return _ragged_estmm(x1, x2, padded_counts)
    if impl == "blocked":
        return _blocked_estmm(x1, x2, block_expert, e)
    return _ref.estmm(x1, x2, block_expert, e)


def esfk(x1, x2, block_expert, padded_counts, *, impl=None, fused=True):
    impl = impl or get_default_impl()
    return _esfk_any(impl, fused, x1, x2, block_expert, padded_counts, True)
